//! Cluster-scale simulation driver (paper Figs. 7, 9, 11 + Table 1).
//!
//!     cargo run --release --example cluster_sim -- [--experiment all]
//!
//! Prints each experiment in the paper's row/series format and writes the
//! series to results/*.csv for plotting.

use anyhow::Result;

use mindspeed_rl::metrics::CsvWriter;
use mindspeed_rl::sim;
use mindspeed_rl::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let which = args.str_or("experiment", "all");
    let all = which == "all";

    if all || which == "table1" {
        sim::run_named_experiment("table1")?;
        println!();
    }
    if all || which == "fig7" {
        sim::run_named_experiment("fig7")?;
        let mut csv = CsvWriter::new(&["model", "system", "tps", "speedup"]);
        for r in sim::fig7_rows() {
            csv.row(&[
                r.model.name().to_string(),
                r.system.name().to_string(),
                format!("{:.1}", r.tps),
                format!("{:.3}", r.speedup_vs_openrlhf),
            ]);
        }
        csv.write("results/fig7.csv")?;
        println!();
    }
    if all || which == "fig9" {
        sim::run_named_experiment("fig9")?;
        let mut csv = CsvWriter::new(&["system", "nodes", "npus", "tps_per_dev", "linearity"]);
        for r in sim::fig9_rows() {
            csv.row(&[
                r.system.name().to_string(),
                r.nodes.to_string(),
                r.npus.to_string(),
                format!("{:.2}", r.tps_per_device),
                format!("{:.4}", r.linearity),
            ]);
        }
        csv.write("results/fig9.csv")?;
        println!();
    }
    if all || which == "fig11" {
        sim::run_named_experiment("fig11")?;
        let mut csv = CsvWriter::new(&["iteration", "tps"]);
        for (i, tps) in sim::fig11_series(100, 0) {
            csv.row_f64(&[i as f64, tps]);
        }
        csv.write("results/fig11.csv")?;
        println!();
    }
    if all || which == "scaling" {
        sim::run_named_experiment("scaling")?;
        let mut csv = CsvWriter::new(&["gen_replicas", "gen_secs", "wall_secs", "tps", "speedup"]);
        for r in sim::scaling_rows() {
            csv.row_f64(&[
                r.gen_replicas as f64,
                r.gen_secs,
                r.wall_secs,
                r.tps,
                r.speedup,
            ]);
        }
        csv.write("results/scaling.csv")?;
    }
    println!("\nCSV series written to results/");
    Ok(())
}
