//! Regenerate every paper table/figure in one run and print a compact
//! paper-vs-measured comparison (the EXPERIMENTS.md source of truth).
//!
//!     cargo run --release --example paper_tables

use anyhow::Result;

use mindspeed_rl::sim::{self, SystemKind};
use mindspeed_rl::util::bench::Table;

fn main() -> Result<()> {
    // Table 1: paper's published values vs ours
    let paper_t1: [(f64, f64, f64); 6] = [
        (0.96, 9.92, 0.97),
        (3.81, 39.0, 3.81),
        (15.2, 156.1, 15.2),
        (97.0, 993.3, 97.0),
        (388.0, 3900.0, 388.0),
        (3100.0, 31000.0, 3100.0),
    ];
    let mut t = Table::new(
        "Table 1 — paper vs reproduced",
        &["G", "N", "TCV paper", "TCV ours", "T100 paper", "T100 ours", "T1K paper", "T1K ours"],
    );
    for (r, p) in sim::table1_rows_out().iter().zip(&paper_t1) {
        t.row(vec![
            r.params.g.to_string(),
            r.params.n_resp.to_string(),
            format!("{}", p.0),
            format!("{:.2}", r.tcv_gb),
            format!("{}", p.1),
            format!("{:.1}", r.t100_s),
            format!("{}", p.2),
            format!("{:.2}", r.t1k_s),
        ]);
    }
    t.print();
    println!();

    // Fig 7: speedup factors (paper claims 1.42–3.97× vs baselines)
    let rows = sim::fig7_rows();
    let mut t = Table::new(
        "Fig. 7 — MSRL speedup vs baselines (paper band: 1.42–3.97×)",
        &["model", "vs OpenRLHF", "vs VeRL", "vs MSRLP"],
    );
    for model in [
        sim::PaperModel::Qwen25Dense7B,
        sim::PaperModel::Qwen25Dense32B,
        sim::PaperModel::Qwen3Moe30B,
    ] {
        let get = |k: SystemKind| {
            rows.iter().find(|r| r.model == model && r.system == k).unwrap().tps
        };
        let msrl = get(SystemKind::Msrl);
        t.row(vec![
            model.name().into(),
            format!("{:.2}x", msrl / get(SystemKind::OpenRlhf)),
            format!("{:.2}x", msrl / get(SystemKind::Verl)),
            format!("{:.2}x", msrl / get(SystemKind::Msrlp)),
        ]);
    }
    t.print();
    println!();

    // Fig 9: linearity at 192 NPUs (paper: MSRL 81.1, MSRLB 61.9, VeRL 40.4)
    let rows = sim::fig9_rows();
    let last = |k: SystemKind| {
        rows.iter().filter(|r| r.system == k).last().unwrap().linearity * 100.0
    };
    let mut t = Table::new(
        "Fig. 9 — linearity at 192 NPUs, paper vs reproduced",
        &["system", "paper", "ours"],
    );
    t.row(vec!["MSRL".into(), "81.1%".into(), format!("{:.1}%", last(SystemKind::Msrl))]);
    t.row(vec!["MSRLB".into(), "61.9%".into(), format!("{:.1}%", last(SystemKind::Msrlb))]);
    t.row(vec!["VeRL".into(), "40.4%".into(), format!("{:.1}%", last(SystemKind::Verl))]);
    t.print();
    println!();

    // Fig 11: DeepSeek-671B TPS band
    let series = sim::fig11_series(100, 0);
    let mean = series.iter().map(|(_, t)| t).sum::<f64>() / series.len() as f64;
    let min = series.iter().map(|(_, t)| *t).fold(f64::INFINITY, f64::min);
    let max = series.iter().map(|(_, t)| *t).fold(0.0f64, f64::max);
    println!(
        "Fig. 11 — DeepSeek-R1-671B @384 NPUs: ours {min:.0}–{max:.0} TPS (mean {mean:.0}); paper: 200–250 TPS"
    );
    println!("\n(memory figure: see examples/resharding_demo.rs --scale 32b; reward curves: examples/train_e2e.rs)");
    Ok(())
}
