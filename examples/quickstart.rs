//! Quickstart: load the AOT artifacts, run one full GRPO iteration by
//! hand, and print every intermediate quantity.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! This walks the exact dataflow of paper Fig. 1: prompts → transfer dock
//! → actor generation → (old/ref logprob, rule reward) → group advantages
//! → GRPO update, all on the PJRT runtime — no Python anywhere.

use anyhow::Result;

use mindspeed_rl::data::TaskGenerator;
use mindspeed_rl::generation::{GenEngine, SamplingParams};
use mindspeed_rl::runtime::{artifact_dir, Engine, Policy};
use mindspeed_rl::trainers::{run_grpo, GrpoConfig};
use mindspeed_rl::transfer_dock::{DockTopology, Sample, SampleFlow, TransferDock};
use mindspeed_rl::util::rng::Rng;
use mindspeed_rl::workers::ActorWorker;

fn main() -> Result<()> {
    // 1. runtime: compile artifacts once
    let engine = Engine::load(artifact_dir("tiny"))?;
    println!(
        "model: {} ({} params, {} layers)",
        engine.manifest.model.name,
        engine.manifest.model.param_count,
        engine.manifest.model.n_layers
    );

    // 2. the distributed transfer dock: 4 warehouses, 5 controllers
    let dock = TransferDock::new(DockTopology::spread(4));
    println!("dock: {} warehouses, {} controllers", dock.n_warehouses(), dock.n_controllers());

    // 3. one manual taste of the sample flow
    let mut tasks = TaskGenerator::train(0);
    let policy = Policy::load_initial(&engine, 1e-3)?;
    let gen = GenEngine::from_manifest(&engine, SamplingParams::default())?;
    // emit behavior logprobs straight from the sampler (old_lp rides the
    // generation writeback, so the old-logprob state has nothing to fill)
    let actor = ActorWorker::new(&engine, 0, gen, 6, true);
    let batch = tasks.batch(4);
    println!("prompts: {:?}", batch.iter().map(|t| t.prompt.as_str()).collect::<Vec<_>>());
    let samples: Vec<Sample> = batch
        .iter()
        .enumerate()
        .map(|(i, t)| Sample::new_prompt(u64::MAX, i as u64, t.prompt.clone(), t.answer))
        .collect();
    dock.put_samples(samples)?;
    let mut rng = Rng::new(0);
    // the initial parameters are weight version 1 — samples are stamped
    // with the version that generated them (their behavior policy)
    let out = actor.run_generation(&engine, &policy, &dock, &mut rng, 8, 1)?;
    println!(
        "generated {} sequences, {} tokens, batcher occupancy {:.0}%",
        out.sequences,
        out.tokens,
        out.occupancy * 100.0
    );

    // 4. now the full loop for a few iterations via the trainer
    let report = run_grpo(
        &engine,
        &GrpoConfig {
            iterations: 5,
            prompts_per_iter: 8,
            group_size: 4,
            max_new_tokens: 6,
            log_every: 1,
            ..Default::default()
        },
    )?;
    println!("{}", report.summary());
    println!(
        "sample-flow bytes: {} inter-node, {} local, {} requests",
        report.final_ledger.inter_node_bytes,
        report.final_ledger.local_bytes,
        report.final_ledger.requests
    );
    Ok(())
}
