//! Resharding-flow demo (paper Figs. 3, 5, 10): run the naive and the
//! allgather–swap reshard over real weight payloads on the tracked memory
//! substrate, verify bit-exactness, and print the memory timeline.
//!
//!     cargo run --release --example resharding_demo -- [--scale 32b] [--ep N] [--gen-ep M]
//!
//! `--ep`/`--gen-ep` pick the expert-parallel degree of the update and
//! generation layouts at the small scale (default 2 → 4, i.e. the
//! paper's Fig. 3 MoE case). Asymmetric pairs exercise the EP
//! allgather–swap path; `--ep` must divide the 4-way non-PP grid and be
//! compatible with the 4 experts (one of the two must divide the other).

use anyhow::Result;

use mindspeed_rl::parallel::{ModelWeights, ParallelLayout};
use mindspeed_rl::resharding::{eq3_redundant_bytes, Resharder};
use mindspeed_rl::transfer_dock::NetworkModel;
use mindspeed_rl::util::cli::Args;
use mindspeed_rl::util::fmt_bytes;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let scale = args.str_or("scale", "small");
    let ep = args.usize_or("ep", 2)?;
    let gen_ep = args.usize_or("gen-ep", 4)?;

    // Two configurations:
    //  * small — real payloads, verified bit-exact (the correctness story)
    //  * 32b   — the paper's Fig. 10 shape (Qwen2.5-32B, TP8DP2 → TP4DP4),
    //            metadata-only weights at true sizes (the memory story)
    let (weights, update, gen, cap) = if scale == "32b" {
        // 32 "layers" of Qwen2.5-32B dims: our payloads are f32 while the
        // paper reshards bf16, so half the layer count makes the BYTE
        // sizes match the real 64-layer bf16 model (TW ≈ 63 GiB)
        let w = ModelWeights::dense_like(32, 5120, 27648);
        (
            w,
            ParallelLayout::dense(8, 1, 2),
            ParallelLayout::dense(4, 1, 4),
            128u64 << 30,
        )
    } else {
        let w = ModelWeights::moe_like(4, 256, 512, 4).with_test_data(7);
        (
            w,
            ParallelLayout::new(2, 1, 2, ep),
            ParallelLayout::new(1, 1, 4, gen_ep),
            1u64 << 30,
        )
    };
    update.validate()?;
    gen.validate()?;

    println!(
        "model: {} total weights ({} TP-sharded, {} expert, {} common)",
        fmt_bytes(weights.total_bytes()),
        fmt_bytes(weights.tp_bytes()),
        fmt_bytes(weights.expert_bytes()),
        fmt_bytes(weights.common_bytes()),
    );
    println!("reshard {} -> {}", update.describe(), gen.describe());
    println!(
        "Eq.(3) predicted redundancy: {}",
        fmt_bytes(eq3_redundant_bytes(&weights, &update, &gen))
    );

    // --- naive (Fig. 3)
    let mut naive = Resharder::new(
        weights.clone(),
        update,
        gen,
        cap,
        16 * cap,
        8,
        NetworkModel::paper(),
    )?;
    let rep = naive.reshard_naive()?;
    println!("\n[naive]          {}", rep.summary());
    if scale != "32b" {
        println!("  verified {} gen shards bit-exact", naive.verify_gen_shards()?);
    }
    println!("  KV headroom per device: {}", fmt_bytes(naive.kv_headroom()[0]));

    // --- allgather-swap (Fig. 5)
    let mut swap = Resharder::new(
        weights.clone(),
        update,
        gen,
        cap,
        16 * cap,
        8,
        NetworkModel::paper(),
    )?;
    let rep = swap.reshard_allgather_swap()?;
    println!("\n[allgather-swap] {}", rep.summary());
    if scale != "32b" {
        println!("  verified {} gen shards bit-exact", swap.verify_gen_shards()?);
    }
    println!("  KV headroom per device: {}", fmt_bytes(swap.kv_headroom()[0]));

    // memory timeline of device 0 (Fig. 10)
    println!("\ndevice 0 memory timeline (allgather-swap):");
    for ev in swap.device_pools[0].timeline() {
        println!("  {:<24} live={}", ev.label, fmt_bytes(ev.live_bytes));
    }

    // H2D swap-back before the next update (overlappable)
    let t = swap.swap_back_h2d()?;
    println!("\nH2D swap-back: {}", mindspeed_rl::util::fmt_secs(t));
    Ok(())
}
