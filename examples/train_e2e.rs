//! End-to-end validation driver (EXPERIMENTS.md §E2E): train a transformer
//! with GRPO for a few hundred iterations on the synthetic verifiable-math
//! corpus, logging the reward/loss curve and the Table-3-style eval scores.
//! Proves all layers compose: Pallas kernels → JAX AOT artifacts → PJRT
//! runtime → transfer dock → GRPO trainer.
//!
//!     make artifacts && cargo run --release --example train_e2e -- \
//!         [--preset small] [--iterations 300] [--replay-buffer]

use anyhow::Result;

use mindspeed_rl::config::Config;
use mindspeed_rl::metrics::CsvWriter;
use mindspeed_rl::runtime::{artifact_dir, Engine};
use mindspeed_rl::trainers::run_grpo;
use mindspeed_rl::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let mut cfg = Config::from_args(&args)?;
    // e2e defaults (flags still win because apply_args already ran on the
    // defaults; only fill what the user left at Default)
    if !args.has("iterations") {
        cfg.grpo.iterations = 300;
    }
    if !args.has("prompts-per-iter") {
        cfg.grpo.prompts_per_iter = 16;
    }
    if !args.has("group-size") {
        cfg.grpo.group_size = 4;
    }
    if !args.has("max-new-tokens") {
        cfg.grpo.max_new_tokens = 6;
    }
    if !args.has("eval-every") {
        cfg.grpo.eval_every = 100;
    }
    if !args.has("log-every") {
        cfg.grpo.log_every = 10;
    }

    let t0 = std::time::Instant::now();
    let engine = Engine::load(artifact_dir(&cfg.preset))?;
    println!(
        "e2e: preset={} params={} iterations={} GxN={}x{}",
        cfg.preset,
        engine.manifest.model.param_count,
        cfg.grpo.iterations,
        cfg.grpo.prompts_per_iter,
        cfg.grpo.group_size
    );
    let report = run_grpo(&engine, &cfg.grpo)?;
    println!("{}", report.summary());
    for (iter, evals) in &report.evals {
        for e in evals {
            println!(
                "eval@{iter} {}: pass@1={:.3} avg@{}={:.3} (n={})",
                e.tier.name(),
                e.pass_at_1,
                e.k,
                e.avg_at_k,
                e.n_tasks
            );
        }
    }

    let mut csv = CsvWriter::new(&[
        "iter", "reward", "exact", "loss", "kl", "ratio", "gen_secs", "update_secs", "tps",
    ]);
    for m in &report.iterations {
        csv.row_f64(&[
            m.iter as f64,
            m.reward_mean as f64,
            m.exact_frac as f64,
            m.loss as f64,
            m.kl as f64,
            m.ratio as f64,
            m.gen_secs,
            m.update_secs,
            m.tps,
        ]);
    }
    let path = format!("results/e2e_{}.csv", cfg.preset);
    csv.write(&path)?;
    println!(
        "e2e done in {}; curve → {path}",
        mindspeed_rl::util::fmt_secs(t0.elapsed().as_secs_f64())
    );

    // engine-level execution stats (perf accounting)
    for (kind, st) in engine.stats_snapshot() {
        println!(
            "  artifact {kind}: {} calls, {} total, {} mean",
            st.calls,
            mindspeed_rl::util::fmt_secs(st.total_secs),
            mindspeed_rl::util::fmt_secs(st.total_secs / st.calls.max(1) as f64)
        );
    }
    Ok(())
}
