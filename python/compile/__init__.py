"""Build-time Python for the MindSpeed RL reproduction (L1 kernels + L2 model).

Never imported at runtime: `make artifacts` runs compile.aot once and the
Rust binary is self-contained afterwards.
"""
