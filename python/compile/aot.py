"""AOT export: lower the L2 programs to HLO text + manifest for Rust.

Interchange format is HLO TEXT (not serialized HloModuleProto): jax >= 0.5
emits protos with 64-bit instruction ids that the xla crate's xla_extension
0.5.1 rejects; the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Outputs per preset `<p>`:
    artifacts/<p>/decode_step.hlo.txt   incremental decode (KV cache)
    artifacts/<p>/logprobs.hlo.txt      per-token log-probs (ref + old-policy)
    artifacts/<p>/train_step.hlo.txt    GRPO fwd/bwd + Adam
    artifacts/<p>/params_init.bin       raw little-endian f32, manifest order
    artifacts/<p>/manifest.json         shapes/orders/vocab — the Rust contract
"""

import argparse
import json
import os
from typing import List

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import configs, generate, losses, model
from .configs import PAD_ID, BOS_ID, EOS_ID, VOCAB, ModelConfig


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _param_specs(params: List[jax.Array]):
    return [_sds(p.shape, p.dtype) for p in params]


def _sig(entries):
    return [
        {"name": n, "shape": list(map(int, s)), "dtype": d} for (n, s, d) in entries
    ]


def export_preset(preset: str, out_dir: str, batch: int, seed: int,
                  use_kernels_train: bool) -> dict:
    cfg = configs.PRESETS[preset]
    key = jax.random.PRNGKey(seed)
    params = model.init_params(cfg, key)
    names = model.param_names(cfg)
    assert len(params) == len(names)
    pdir = os.path.join(out_dir, preset)
    os.makedirs(pdir, exist_ok=True)

    s = cfg.max_seq
    b = batch
    np_count = len(params)
    hyper = losses.TrainHyper()

    # ------------------------------------------------ params_init.bin
    offset = 0
    pinfo = []
    with open(os.path.join(pdir, "params_init.bin"), "wb") as f:
        for n, p in zip(names, params):
            arr = np.asarray(p, dtype=np.float32)
            f.write(arr.tobytes())
            pinfo.append(
                {
                    "name": n,
                    "shape": list(arr.shape),
                    "dtype": "f32",
                    "offset": offset,
                    "numel": int(arr.size),
                }
            )
            offset += arr.size * 4

    artifacts = []

    # ------------------------------------------------ decode_step
    kv_shape = (cfg.n_layers, 2, b, cfg.n_heads, cfg.max_seq, cfg.head_dim)

    def decode_fn(params, kv, pos, token):
        return generate.decode_step(cfg, params, kv, pos, token)

    lowered = jax.jit(decode_fn).lower(
        _param_specs(params),
        _sds(kv_shape),
        _sds((b,), jnp.int32),
        _sds((b,), jnp.int32),
    )
    with open(os.path.join(pdir, "decode_step.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))
    artifacts.append(
        {
            "kind": "decode_step",
            "file": "decode_step.hlo.txt",
            "batch": b,
            "seq": s,
            "inputs": _sig(
                [(n, p.shape, "f32") for n, p in zip(names, params)]
                + [
                    ("kv", kv_shape, "f32"),
                    ("pos", (b,), "i32"),
                    ("token", (b,), "i32"),
                ]
            ),
            "outputs": _sig(
                [("logits", (b, cfg.vocab_size), "f32"), ("kv", kv_shape, "f32")]
            ),
            "use_kernels": False,
        }
    )

    # ------------------------------------------------ logprobs
    def logprobs_fn(params, tokens):
        return (model.logprobs(cfg, params, tokens, use_kernels=True),)

    lowered = jax.jit(logprobs_fn).lower(_param_specs(params), _sds((b, s), jnp.int32))
    with open(os.path.join(pdir, "logprobs.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))
    artifacts.append(
        {
            "kind": "logprobs",
            "file": "logprobs.hlo.txt",
            "batch": b,
            "seq": s,
            "inputs": _sig(
                [(n, p.shape, "f32") for n, p in zip(names, params)]
                + [("tokens", (b, s), "i32")]
            ),
            "outputs": _sig([("logprobs", (b, s - 1), "f32")]),
            "use_kernels": True,
        }
    )

    # ------------------------------------------------ train_step
    def train_fn(params, m, v, step, lr, tokens, mask, old_lp, ref_lp, adv):
        batch_t = (tokens, mask, old_lp, ref_lp, adv)
        new_p, new_m, new_v, loss, kl, ratio = losses.train_step(
            cfg, params, m, v, step, lr, batch_t, hyper, use_kernels_train
        )
        return tuple(new_p) + tuple(new_m) + tuple(new_v) + (loss, kl, ratio)

    lowered = jax.jit(train_fn).lower(
        _param_specs(params),
        _param_specs(params),
        _param_specs(params),
        _sds((), jnp.float32),
        _sds((), jnp.float32),
        _sds((b, s), jnp.int32),
        _sds((b, s - 1), jnp.float32),
        _sds((b, s - 1), jnp.float32),
        _sds((b, s - 1), jnp.float32),
        _sds((b,), jnp.float32),
    )
    with open(os.path.join(pdir, "train_step.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))
    artifacts.append(
        {
            "kind": "train_step",
            "file": "train_step.hlo.txt",
            "batch": b,
            "seq": s,
            "inputs": _sig(
                [(n, p.shape, "f32") for n, p in zip(names, params)]
                + [(f"m.{n}", p.shape, "f32") for n, p in zip(names, params)]
                + [(f"v.{n}", p.shape, "f32") for n, p in zip(names, params)]
                + [
                    ("step", (), "f32"),
                    ("lr", (), "f32"),
                    ("tokens", (b, s), "i32"),
                    ("resp_mask", (b, s - 1), "f32"),
                    ("old_lp", (b, s - 1), "f32"),
                    ("ref_lp", (b, s - 1), "f32"),
                    ("adv", (b,), "f32"),
                ]
            ),
            "outputs": _sig(
                [(n, p.shape, "f32") for n, p in zip(names, params)]
                + [(f"m.{n}", p.shape, "f32") for n, p in zip(names, params)]
                + [(f"v.{n}", p.shape, "f32") for n, p in zip(names, params)]
                + [("loss", (), "f32"), ("kl", (), "f32"), ("ratio", (), "f32")]
            ),
            "use_kernels": use_kernels_train,
        }
    )

    manifest = {
        "preset": preset,
        "model": {
            "name": cfg.name,
            "d_model": cfg.d_model,
            "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads,
            "d_ff": cfg.d_ff,
            "max_seq": cfg.max_seq,
            "vocab_size": cfg.vocab_size,
            "head_dim": cfg.head_dim,
            "rope_base": cfg.rope_base,
            "norm_eps": cfg.norm_eps,
            "param_count": cfg.param_count(),
            "moe": (
                {"num_experts": cfg.moe.num_experts, "top_k": cfg.moe.top_k}
                if cfg.moe
                else None
            ),
        },
        "vocab": VOCAB,
        "pad_id": PAD_ID,
        "bos_id": BOS_ID,
        "eos_id": EOS_ID,
        "hyper": {
            "clip_eps": hyper.clip_eps,
            "kl_coef": hyper.kl_coef,
            "beta1": hyper.beta1,
            "beta2": hyper.beta2,
            "adam_eps": hyper.adam_eps,
        },
        "n_params": np_count,
        "params": pinfo,
        "params_file": "params_init.bin",
        "artifacts": artifacts,
        "seed": seed,
    }
    with open(os.path.join(pdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--presets",
        default="tiny,small,moe_tiny",
        help="comma-separated preset names (see configs.PRESETS)",
    )
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--train-kernels",
        action="store_true",
        help="lower the train_step through the Pallas kernels (slower CPU "
        "lowering; logprobs always uses them)",
    )
    args = ap.parse_args()
    for preset in args.presets.split(","):
        preset = preset.strip()
        m = export_preset(preset, args.out_dir, args.batch, args.seed, args.train_kernels)
        sizes = {
            a["kind"]: os.path.getsize(os.path.join(args.out_dir, preset, a["file"]))
            for a in m["artifacts"]
        }
        print(f"[aot] {preset}: params={m['model']['param_count']:,} {sizes}")


if __name__ == "__main__":
    main()
