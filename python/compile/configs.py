"""Model + artifact configurations for the AOT pipeline.

The vocabulary is defined HERE and exported through the artifact manifest;
the Rust tokenizer (rust/src/tokenizer) is constructed from the manifest so
the two sides cannot drift.
"""

from dataclasses import dataclass, field
from typing import Optional

# Char-level vocab: PAD, BOS, EOS, then printable task characters.
# Index == token id. Padded to 64 entries at the model level.
SPECIALS = ["<pad>", "<bos>", "<eos>"]
CHARS = "0123456789+-*/=()., ?xyabcdefghijklmnopqrstuvwz"
VOCAB = SPECIALS + list(CHARS)
VOCAB_SIZE = 64  # model embedding rows (>= len(VOCAB), MXU-friendly)

PAD_ID = 0
BOS_ID = 1
EOS_ID = 2


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 4
    top_k: int = 2


@dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    max_seq: int
    vocab_size: int = VOCAB_SIZE
    moe: Optional[MoEConfig] = None
    rope_base: float = 10000.0
    norm_eps: float = 1e-6

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def param_count(self) -> int:
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        per_layer = d + 3 * d * d + d * d + d  # norms + qkv + o
        if self.moe is None:
            per_layer += 3 * d * f
        else:
            e = self.moe.num_experts
            per_layer += d * e + e * 3 * d * f
        return v * d + self.n_layers * per_layer + d + d * v


@dataclass(frozen=True)
class ArtifactSpec:
    """One AOT-exported HLO program."""

    kind: str  # "decode" | "logprobs" | "train_step"
    batch: int
    seq: int


@dataclass(frozen=True)
class BuildConfig:
    model: ModelConfig
    artifacts: tuple
    seed: int = 0


# ---------------------------------------------------------------- presets
TINY = ModelConfig(name="tiny", d_model=64, n_layers=2, n_heads=4, d_ff=128, max_seq=64)
SMALL = ModelConfig(name="small", d_model=256, n_layers=4, n_heads=8, d_ff=704, max_seq=128)
# ~100M-class dense model for the end-to-end experiment (EXPERIMENTS.md)
E2E = ModelConfig(name="e2e", d_model=512, n_layers=8, n_heads=8, d_ff=1408, max_seq=96)
MOE_TINY = ModelConfig(
    name="moe_tiny",
    d_model=64,
    n_layers=2,
    n_heads=4,
    d_ff=128,
    max_seq=64,
    moe=MoEConfig(num_experts=4, top_k=2),
)

PRESETS = {m.name: m for m in [TINY, SMALL, E2E, MOE_TINY]}


def build_config(name: str) -> BuildConfig:
    """Default artifact set per preset: one decode shape, one logprobs shape,
    one train-step shape, all sized to the model's max_seq."""
    m = PRESETS[name]
    arts = (
        ArtifactSpec("decode", batch=8, seq=m.max_seq),
        ArtifactSpec("logprobs", batch=8, seq=m.max_seq),
        ArtifactSpec("train_step", batch=8, seq=m.max_seq),
    )
    return BuildConfig(model=m, artifacts=arts)
