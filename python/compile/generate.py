"""Incremental (KV-cache) decode step — the generation-engine compute.

The Rust generation engine (rust/src/generation) is a vLLM-style continuous
batcher: each slot in the batch holds an independent sequence at its own
position. The decode artifact therefore takes per-slot positions and a
packed KV cache, exactly the interface a paged-attention engine presents:

    decode_step(params, kv [L,2,B,H,Smax,hd], pos [B] i32, token [B] i32)
        -> (logits [B, V], new_kv)

Attention over the cache is masked per-slot (j <= pos), so slots at
different depths coexist in one batch — this is what makes continuous
batching work. The full-sequence Pallas flash kernel is the prefill/training
path; this masked single-query attention is the decode path (the same
prefill/decode kernel split vLLM and the paper's generation engine use).
"""

from typing import List, Tuple

import jax
import jax.numpy as jnp

from . import kernels
from .configs import ModelConfig


def init_kv_cache(cfg: ModelConfig, batch: int) -> jax.Array:
    return jnp.zeros(
        (cfg.n_layers, 2, batch, cfg.n_heads, cfg.max_seq, cfg.head_dim),
        jnp.float32,
    )


def _rope_at(x: jax.Array, pos: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Apply RoPE to single-position vectors x: [B, H, hd] at angle pos[B]."""
    d2 = cfg.head_dim // 2
    inv_freq = 1.0 / (cfg.rope_base ** (jnp.arange(0, d2, dtype=jnp.float32) / d2))
    angles = pos[:, None].astype(jnp.float32) * inv_freq[None, :]  # [B, d2]
    cos = jnp.cos(angles)[:, None, :]  # [B, 1, d2]
    sin = jnp.sin(angles)[:, None, :]
    x1, x2 = x[..., :d2], x[..., d2:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def decode_step(cfg: ModelConfig, params: List[jax.Array], kv: jax.Array,
                pos: jax.Array, token: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """One incremental decode step. Returns (logits [B, V], new_kv)."""
    b = token.shape[0]
    d, nh, hd, smax = cfg.d_model, cfg.n_heads, cfg.head_dim, cfg.max_seq
    it = iter(params)
    embed = next(it)
    x = embed[token]  # [B, D]
    scale = 1.0 / (hd**0.5)
    col = jnp.arange(smax)  # [Smax]
    attn_mask = (col[None, :] <= pos[:, None])[:, None, None, :]  # [B,1,1,Smax]

    new_kv_layers = []
    for li in range(cfg.n_layers):
        attn_norm = next(it)
        wqkv = next(it)
        wo = next(it)
        ffn_norm = next(it)

        h = kernels.ref.rmsnorm(x, attn_norm, cfg.norm_eps)
        qkv = h @ wqkv  # [B, 3D]
        q, k, v = jnp.split(qkv, 3, axis=-1)
        q = _rope_at(q.reshape(b, nh, hd), pos, cfg)  # [B, H, hd]
        k = _rope_at(k.reshape(b, nh, hd), pos, cfg)
        v = v.reshape(b, nh, hd)

        # scatter k, v into the cache at each slot's position
        k_cache = kv[li, 0]  # [B, H, Smax, hd]
        v_cache = kv[li, 1]
        onehot = (col[None, :] == pos[:, None]).astype(jnp.float32)  # [B, Smax]
        oh = onehot[:, None, :, None]  # [B,1,Smax,1]
        k_cache = k_cache * (1.0 - oh) + k[:, :, None, :] * oh
        v_cache = v_cache * (1.0 - oh) + v[:, :, None, :] * oh
        new_kv_layers.append(jnp.stack([k_cache, v_cache]))

        scores = jnp.einsum("bhd,bhsd->bhs", q, k_cache) * scale  # [B,H,Smax]
        scores = jnp.where(attn_mask[:, :, 0, :], scores, -1e30)
        p = jax.nn.softmax(scores, axis=-1)
        o = jnp.einsum("bhs,bhsd->bhd", p, v_cache).reshape(b, d)
        x = x + o @ wo

        h2 = kernels.ref.rmsnorm(x, ffn_norm, cfg.norm_eps)
        if cfg.moe is None:
            w_gate, w_up, w_down = next(it), next(it), next(it)
            ff = kernels.ref.swiglu(h2 @ w_gate, h2 @ w_up) @ w_down
        else:
            router_w, e_gate, e_up, e_down = next(it), next(it), next(it), next(it)
            # decode-time MoE: dense dispatch over top-k (B is small)
            logits_r = h2 @ router_w
            topv, topi = jax.lax.top_k(logits_r, cfg.moe.top_k)
            gates = jax.nn.softmax(topv, axis=-1)  # [B, k]
            eg = e_gate[topi]  # [B, k, D, F]
            eu = e_up[topi]
            ed = e_down[topi]  # [B, k, F, D]
            gt = jnp.einsum("bd,bkdf->bkf", h2, eg)
            up = jnp.einsum("bd,bkdf->bkf", h2, eu)
            hidden = kernels.ref.swiglu(gt, up)
            ff = jnp.einsum("bkf,bkfd,bk->bd", hidden, ed, gates)
        x = x + ff

    final_norm = next(it)
    lm_head = next(it)
    x = kernels.ref.rmsnorm(x, final_norm, cfg.norm_eps)
    logits = x @ lm_head  # [B, V]
    return logits, jnp.stack(new_kv_layers)
