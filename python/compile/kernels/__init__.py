"""L1 — Pallas kernels for the MindSpeed RL reproduction.

Each kernel has a pure-jnp oracle in :mod:`ref` and a hypothesis sweep in
``python/tests/test_kernels.py``. All kernels run interpret=True (see
common.py) and lower into the same HLO as the surrounding L2 model.
"""

from .attention import attention
from .gmm import gmm
from .grpo_loss import grpo_loss
from .rmsnorm import rmsnorm
from .rope import rope, rope_tables
from .swiglu import swiglu
from . import ref

__all__ = [
    "attention",
    "gmm",
    "grpo_loss",
    "rmsnorm",
    "rope",
    "rope_tables",
    "swiglu",
    "ref",
]
