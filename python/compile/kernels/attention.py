"""Blocked causal attention Pallas kernel (FlashAttention-style, fwd + bwd).

Structure follows the TPU flash pattern (DESIGN.md §Hardware-Adaptation):

- forward: grid (R, Q-blocks, K-blocks) with the K dimension innermost and
  sequential; the output block and the online-softmax running statistics
  (m, l) are carried across K steps by read-modify-write on output refs —
  the interpret-mode equivalent of VMEM scratch accumulators. Emits both
  the attention output and the per-row LSE for the backward pass.
- backward: two kernels, both recomputing the probability blocks from
  (q, k, lse) instead of materializing the S×S matrix (the flash trick):
  a dQ pass with grid (R, Q-blocks, K-blocks) and a dK/dV pass with grid
  (R, K-blocks, Q-blocks).

Causal masking is done on global row/column indices, so padded rows/columns
(sequence padded up to a block multiple) are masked exactly.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, pad_axis, pick_block, round_up

DEFAULT_BLOCK_Q = 64
DEFAULT_BLOCK_K = 64
NEG_INF = -1e30


def _idx(axis_pid: int, block: int):
    return pl.program_id(axis_pid) * block + jax.lax.broadcasted_iota(
        jnp.int32, (block, 1), 0
    )


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, lse_ref, *, scale, s_real, nk):
    kb = pl.program_id(2)
    q = q_ref[0]  # [bq, d]
    k = k_ref[0]  # [bk, d]
    v = v_ref[0]

    bq = q.shape[0]
    bk = k.shape[0]

    @pl.when(kb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        o_ref[...] = jnp.zeros_like(o_ref)

    qi = _idx(1, bq)  # [bq, 1] global row ids
    kj = _idx(2, bk)  # [bk, 1] global col ids
    s = jnp.dot(q, k.T) * scale  # [bq, bk]
    mask = (qi >= kj.T) & (kj.T < s_real)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[0]  # [bq, 1]
    l_prev = l_ref[0]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
    m_ref[0] = m_new
    l_ref[0] = l_new
    o_ref[0] = o_ref[0] * alpha + jnp.dot(p, v)

    @pl.when(kb == nk - 1)
    def _finalize():
        l_fin = l_ref[0]
        l_safe = jnp.maximum(l_fin, 1e-30)
        o_ref[0] = o_ref[0] / l_safe
        lse_ref[0] = m_ref[0] + jnp.log(l_safe)


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref, *, scale, s_real, nk):
    kb = pl.program_id(2)
    q = q_ref[0]
    k = k_ref[0]
    v = v_ref[0]
    do = do_ref[0]
    lse = lse_ref[0]  # [bq, 1]
    delta = delta_ref[0]  # [bq, 1]
    bq, bk = q.shape[0], k.shape[0]

    @pl.when(kb == 0)
    def _init():
        dq_ref[...] = jnp.zeros_like(dq_ref)

    qi = _idx(1, bq)
    kj = _idx(2, bk)
    s = jnp.dot(q, k.T) * scale
    mask = (qi >= kj.T) & (kj.T < s_real)
    p = jnp.where(mask, jnp.exp(s - lse), 0.0)  # [bq, bk]
    dp = jnp.dot(do, v.T)  # [bq, bk]
    ds = p * (dp - delta)
    dq_ref[0] = dq_ref[0] + jnp.dot(ds, k) * scale


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dk_ref, dv_ref, *, scale, s_real, nq):
    qb = pl.program_id(2)
    q = q_ref[0]
    k = k_ref[0]
    v = v_ref[0]
    do = do_ref[0]
    lse = lse_ref[0]
    delta = delta_ref[0]
    bq, bk = q.shape[0], k.shape[0]

    @pl.when(qb == 0)
    def _init():
        dk_ref[...] = jnp.zeros_like(dk_ref)
        dv_ref[...] = jnp.zeros_like(dv_ref)

    qi = pl.program_id(2) * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0)
    kj = pl.program_id(1) * bk + jax.lax.broadcasted_iota(jnp.int32, (bk, 1), 0)
    s = jnp.dot(q, k.T) * scale
    mask = (qi >= kj.T) & (kj.T < s_real) & (qi < s_real)
    p = jnp.where(mask, jnp.exp(s - lse), 0.0)
    dv_ref[0] = dv_ref[0] + jnp.dot(p.T, do)
    dp = jnp.dot(do, v.T)
    ds = p * (dp - delta)
    dk_ref[0] = dk_ref[0] + jnp.dot(ds.T, q) * scale


def _pad_rsd(x, sp):
    return pad_axis(x, 1, sp)


def _flash_fwd(q3, k3, v3, block_q, block_k):
    r, s, d = q3.shape
    bq = pick_block(s, block_q)
    bk = pick_block(s, block_k)
    sp = round_up(s, max(bq, bk))
    nq, nk = sp // bq, sp // bk
    scale = 1.0 / (d**0.5)
    qp, kp, vp = _pad_rsd(q3, sp), _pad_rsd(k3, sp), _pad_rsd(v3, sp)
    o, _m, _l, lse = pl.pallas_call(
        functools.partial(_fwd_kernel, scale=scale, s_real=s, nk=nk),
        grid=(r, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda rr, qq, kk: (rr, qq, 0)),
            pl.BlockSpec((1, bk, d), lambda rr, qq, kk: (rr, kk, 0)),
            pl.BlockSpec((1, bk, d), lambda rr, qq, kk: (rr, kk, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, d), lambda rr, qq, kk: (rr, qq, 0)),
            pl.BlockSpec((1, bq, 1), lambda rr, qq, kk: (rr, qq, 0)),
            pl.BlockSpec((1, bq, 1), lambda rr, qq, kk: (rr, qq, 0)),
            pl.BlockSpec((1, bq, 1), lambda rr, qq, kk: (rr, qq, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, sp, d), q3.dtype),
            jax.ShapeDtypeStruct((r, sp, 1), q3.dtype),
            jax.ShapeDtypeStruct((r, sp, 1), q3.dtype),
            jax.ShapeDtypeStruct((r, sp, 1), q3.dtype),
        ],
        interpret=INTERPRET,
    )(qp, kp, vp)
    return o[:, :s], lse


def _flash_bwd(q3, k3, v3, o3, lse3, do3, block_q, block_k):
    r, s, d = q3.shape
    bq = pick_block(s, block_q)
    bk = pick_block(s, block_k)
    sp = round_up(s, max(bq, bk))
    nq, nk = sp // bq, sp // bk
    scale = 1.0 / (d**0.5)
    delta = jnp.sum(do3 * o3, axis=-1, keepdims=True)  # [r, s, 1]
    qp, kp, vp = _pad_rsd(q3, sp), _pad_rsd(k3, sp), _pad_rsd(v3, sp)
    dop = _pad_rsd(do3, sp)
    lsep = _pad_rsd(lse3, sp)
    deltap = _pad_rsd(delta, sp)

    q_spec = pl.BlockSpec((1, bq, d), lambda rr, qq, kk: (rr, qq, 0))
    k_spec = pl.BlockSpec((1, bk, d), lambda rr, qq, kk: (rr, kk, 0))
    stat_spec = pl.BlockSpec((1, bq, 1), lambda rr, qq, kk: (rr, qq, 0))
    dq = pl.pallas_call(
        functools.partial(_dq_kernel, scale=scale, s_real=s, nk=nk),
        grid=(r, nq, nk),
        in_specs=[q_spec, k_spec, k_spec, q_spec, stat_spec, stat_spec],
        out_specs=q_spec,
        out_shape=jax.ShapeDtypeStruct((r, sp, d), q3.dtype),
        interpret=INTERPRET,
    )(qp, kp, vp, dop, lsep, deltap)

    # dK/dV pass: grid iterates (r, k-block, q-block)
    q_spec2 = pl.BlockSpec((1, bq, d), lambda rr, kk, qq: (rr, qq, 0))
    k_spec2 = pl.BlockSpec((1, bk, d), lambda rr, kk, qq: (rr, kk, 0))
    stat_spec2 = pl.BlockSpec((1, bq, 1), lambda rr, kk, qq: (rr, qq, 0))
    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, scale=scale, s_real=s, nq=nq),
        grid=(r, nk, nq),
        in_specs=[q_spec2, k_spec2, k_spec2, q_spec2, stat_spec2, stat_spec2],
        out_specs=[k_spec2, k_spec2],
        out_shape=[
            jax.ShapeDtypeStruct((r, sp, d), q3.dtype),
            jax.ShapeDtypeStruct((r, sp, d), q3.dtype),
        ],
        interpret=INTERPRET,
    )(qp, kp, vp, dop, lsep, deltap)
    return dq[:, :s], dk[:, :s], dv[:, :s]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def attention(q, k, v, block_q: int = DEFAULT_BLOCK_Q, block_k: int = DEFAULT_BLOCK_K):
    """Causal multi-head attention. q, k, v: [B, H, S, D] → [B, H, S, D]."""
    b, h, s, d = q.shape
    o, _ = _flash_fwd(
        q.reshape(-1, s, d), k.reshape(-1, s, d), v.reshape(-1, s, d), block_q, block_k
    )
    return o.reshape(b, h, s, d)


def _vjp_fwd(q, k, v, block_q, block_k):
    b, h, s, d = q.shape
    q3, k3, v3 = (x.reshape(-1, s, d) for x in (q, k, v))
    o, lse = _flash_fwd(q3, k3, v3, block_q, block_k)
    return o.reshape(b, h, s, d), (q3, k3, v3, o, lse, (b, h, s, d))


def _vjp_bwd(block_q, block_k, res, dy):
    q3, k3, v3, o, lse, (b, h, s, d) = res
    do3 = dy.reshape(-1, s, d)
    dq, dk, dv = _flash_bwd(q3, k3, v3, o, lse, do3, block_q, block_k)
    return (
        dq.reshape(b, h, s, d),
        dk.reshape(b, h, s, d),
        dv.reshape(b, h, s, d),
    )


attention.defvjp(_vjp_fwd, _vjp_bwd)
