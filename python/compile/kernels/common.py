"""Shared helpers for the Pallas kernels.

All kernels in this package run with ``interpret=True``: the CPU PJRT plugin
cannot execute Mosaic custom-calls, so interpret mode is how the kernels
lower into plain HLO that the Rust runtime can load (see DESIGN.md
§Hardware-Adaptation). Block sizes are nevertheless chosen as if for a real
TPU — VMEM-resident blocks, MXU-friendly (multiple-of-128 where matmuls are
involved) — because the BlockSpec structure is what we profile.
"""

import jax.numpy as jnp

INTERPRET = True  # flipped to False only when targeting a real TPU backend


def round_up(x: int, m: int) -> int:
    """Smallest multiple of m that is >= x."""
    return ((x + m - 1) // m) * m


def pad_axis(x, axis: int, to: int, value=0.0):
    """Zero-pad axis `axis` of x up to length `to`."""
    if x.shape[axis] == to:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, to - x.shape[axis])
    return jnp.pad(x, pads, constant_values=value)


def pick_block(n: int, preferred: int) -> int:
    """Choose a block size: `preferred` when n is large, else the whole axis.

    Keeps tiny test shapes on a single block while production shapes tile.
    """
    return preferred if n >= preferred else max(1, n)
