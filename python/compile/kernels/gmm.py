"""Grouped matmul (GMM) Pallas kernel for MoE expert dispatch.

Rows of x are sorted by expert; ``group_sizes[e]`` rows belong to expert e
and multiply its weight ``w[e]``. The kernel grid is (experts × M-tiles):
each step computes one M-tile's contribution from one expert, masked to the
rows that actually belong to that expert, and accumulates into the output
tile (read-modify-write across the sequential expert dimension). This is
the per-core tiling schedule the paper's Ascend GMM op expresses — here via
BlockSpec (DESIGN.md §Hardware-Adaptation).

Backward: dx is a GMM against the transposed weights (same kernel); dw is a
per-expert [D, F] accumulation kernel with grid (experts × M-tiles).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, pad_axis, pick_block, round_up

DEFAULT_BLOCK_M = 128


def _row_bounds(group_sizes):
    """start[e], end[e] row offsets per expert."""
    ends = jnp.cumsum(group_sizes)
    starts = ends - group_sizes
    return starts, ends


def _gmm_kernel(x_ref, w_ref, start_ref, end_ref, o_ref, *, block_m):
    e = pl.program_id(0)
    x = x_ref[...]  # [bm, D]
    w = w_ref[0]  # [D, F]

    @pl.when(e == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    row = pl.program_id(1) * block_m + jax.lax.broadcasted_iota(
        jnp.int32, (x.shape[0], 1), 0
    )
    in_group = (row >= start_ref[0]) & (row < end_ref[0])
    xm = jnp.where(in_group, x, 0.0)
    o_ref[...] = o_ref[...] + jnp.dot(xm, w)


def _dw_kernel(x_ref, dy_ref, start_ref, end_ref, dw_ref, *, block_m):
    m = pl.program_id(1)
    x = x_ref[...]  # [bm, D]
    dy = dy_ref[...]  # [bm, F]

    @pl.when(m == 0)
    def _init():
        dw_ref[...] = jnp.zeros_like(dw_ref)

    row = pl.program_id(1) * block_m + jax.lax.broadcasted_iota(
        jnp.int32, (x.shape[0], 1), 0
    )
    in_group = (row >= start_ref[0]) & (row < end_ref[0])
    xm = jnp.where(in_group, x, 0.0)
    dw_ref[0] = dw_ref[0] + jnp.dot(xm.T, dy)


def _run_gmm(x, w, group_sizes, block_m):
    t, d = x.shape
    e, _, f = w.shape
    bm = pick_block(t, block_m)
    tp = round_up(t, bm)
    xp = pad_axis(x, 0, tp)
    starts, ends = _row_bounds(group_sizes)
    starts = starts.astype(jnp.int32).reshape(e, 1)
    ends = ends.astype(jnp.int32).reshape(e, 1)
    out = pl.pallas_call(
        functools.partial(_gmm_kernel, block_m=bm),
        grid=(e, tp // bm),
        in_specs=[
            pl.BlockSpec((bm, d), lambda ee, mm: (mm, 0)),
            pl.BlockSpec((1, d, f), lambda ee, mm: (ee, 0, 0)),
            pl.BlockSpec((1, 1), lambda ee, mm: (ee, 0)),
            pl.BlockSpec((1, 1), lambda ee, mm: (ee, 0)),
        ],
        out_specs=pl.BlockSpec((bm, f), lambda ee, mm: (mm, 0)),
        out_shape=jax.ShapeDtypeStruct((tp, f), x.dtype),
        interpret=INTERPRET,
    )(xp, w, starts, ends)
    return out[:t]


def _run_dw(x, dy, group_sizes, e, block_m):
    t, d = x.shape
    f = dy.shape[-1]
    bm = pick_block(t, block_m)
    tp = round_up(t, bm)
    xp = pad_axis(x, 0, tp)
    dyp = pad_axis(dy, 0, tp)
    starts, ends = _row_bounds(group_sizes)
    starts = starts.astype(jnp.int32).reshape(e, 1)
    ends = ends.astype(jnp.int32).reshape(e, 1)
    dw = pl.pallas_call(
        functools.partial(_dw_kernel, block_m=bm),
        grid=(e, tp // bm),
        in_specs=[
            pl.BlockSpec((bm, d), lambda ee, mm: (mm, 0)),
            pl.BlockSpec((bm, f), lambda ee, mm: (mm, 0)),
            pl.BlockSpec((1, 1), lambda ee, mm: (ee, 0)),
            pl.BlockSpec((1, 1), lambda ee, mm: (ee, 0)),
        ],
        out_specs=pl.BlockSpec((1, d, f), lambda ee, mm: (ee, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((e, d, f), x.dtype),
        interpret=INTERPRET,
    )(xp, dyp, starts, ends)
    return dw


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def gmm(x, w, group_sizes, block_m: int = DEFAULT_BLOCK_M):
    """Grouped matmul. x: [T, D] (rows sorted by expert), w: [E, D, F],
    group_sizes: [E] int32 with sum == T. Returns [T, F]."""
    return _run_gmm(x, w, group_sizes, block_m)


def _vjp_fwd(x, w, group_sizes, block_m):
    return gmm(x, w, group_sizes, block_m), (x, w, group_sizes)


def _vjp_bwd(block_m, res, dy):
    x, w, group_sizes = res
    # dx[t] = dy[t] @ w[e(t)].T  — a GMM against transposed weights
    dx = _run_gmm(dy, jnp.swapaxes(w, 1, 2), group_sizes, block_m)
    dw = _run_dw(x, dy, group_sizes, w.shape[0], block_m)
    return dx, dw, None


gmm.defvjp(_vjp_fwd, _vjp_bwd)
