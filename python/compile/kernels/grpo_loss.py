"""Fused per-token GRPO loss Pallas kernel (forward + custom-VJP backward).

Fuses the importance ratio, the PPO-style clipped surrogate, and the k3 KL
penalty into a single elementwise pass — the RL-specific fusion the paper's
update stage relies on. Only lp_new (the current policy's log-probs) carries
a gradient; lp_old / lp_ref / advantages are treated as constants, exactly
as in GRPO.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, pad_axis, pick_block, round_up

DEFAULT_BLOCK_ROWS = 64


def _fwd_kernel(lp_new_ref, lp_old_ref, lp_ref_ref, adv_ref, mask_ref, o_ref, *, clip_eps, kl_coef):
    lp_new = lp_new_ref[...]
    lp_old = lp_old_ref[...]
    lp_ref = lp_ref_ref[...]
    a = adv_ref[...]  # [rows, 1]
    mask = mask_ref[...]
    ratio = jnp.exp(lp_new - lp_old)
    s1 = ratio * a
    s2 = jnp.clip(ratio, 1.0 - clip_eps, 1.0 + clip_eps) * a
    pg = -jnp.minimum(s1, s2)
    d = lp_ref - lp_new
    kl = jnp.exp(d) - d - 1.0
    o_ref[...] = (pg + kl_coef * kl) * mask


def _bwd_kernel(lp_new_ref, lp_old_ref, lp_ref_ref, adv_ref, mask_ref, dy_ref, dlp_ref, *, clip_eps, kl_coef):
    lp_new = lp_new_ref[...]
    lp_old = lp_old_ref[...]
    lp_ref = lp_ref_ref[...]
    a = adv_ref[...]
    mask = mask_ref[...]
    dy = dy_ref[...]
    ratio = jnp.exp(lp_new - lp_old)
    s1 = ratio * a
    s2 = jnp.clip(ratio, 1.0 - clip_eps, 1.0 + clip_eps) * a
    # d(pg)/d(lp_new): -ratio*a where the unclipped branch is active, else 0
    unclipped = s1 <= s2
    in_window = (ratio >= 1.0 - clip_eps) & (ratio <= 1.0 + clip_eps)
    active = unclipped | in_window
    dpg = jnp.where(active, -ratio * a, 0.0)
    # d(kl)/d(lp_new) = -exp(ref-new) + 1
    d = lp_ref - lp_new
    dkl = 1.0 - jnp.exp(d)
    dlp_ref[...] = dy * (dpg + kl_coef * dkl) * mask


def _run(kernel, arrays, n_out_rows_cols, clip_eps, kl_coef, block_rows):
    b, t = n_out_rows_cols
    br = pick_block(b, block_rows)
    bp = round_up(b, br)
    padded = [pad_axis(x, 0, bp) for x in arrays]
    row_spec = pl.BlockSpec((br, t), lambda i: (i, 0))
    adv_spec = pl.BlockSpec((br, 1), lambda i: (i, 0))
    specs = [row_spec, row_spec, row_spec, adv_spec, row_spec]
    if len(arrays) == 6:
        specs.append(row_spec)
    out = pl.pallas_call(
        functools.partial(kernel, clip_eps=clip_eps, kl_coef=kl_coef),
        grid=(bp // br,),
        in_specs=specs,
        out_specs=row_spec,
        out_shape=jax.ShapeDtypeStruct((bp, t), arrays[0].dtype),
        interpret=INTERPRET,
    )(*padded)
    return out[:b]


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7))
def grpo_loss(lp_new, lp_old, lp_ref, adv, mask, clip_eps: float = 0.2,
              kl_coef: float = 0.01, block_rows: int = DEFAULT_BLOCK_ROWS):
    """Per-token GRPO loss. lp_*: [B, T]; adv: [B]; mask: [B, T] → [B, T]."""
    return _run(
        _fwd_kernel,
        [lp_new, lp_old, lp_ref, adv[:, None], mask],
        lp_new.shape,
        clip_eps,
        kl_coef,
        block_rows,
    )


def _vjp_fwd(lp_new, lp_old, lp_ref, adv, mask, clip_eps, kl_coef, block_rows):
    y = grpo_loss(lp_new, lp_old, lp_ref, adv, mask, clip_eps, kl_coef, block_rows)
    return y, (lp_new, lp_old, lp_ref, adv, mask)


def _vjp_bwd(clip_eps, kl_coef, block_rows, res, dy):
    lp_new, lp_old, lp_ref, adv, mask = res
    dlp = _run(
        _bwd_kernel,
        [lp_new, lp_old, lp_ref, adv[:, None], mask, dy],
        lp_new.shape,
        clip_eps,
        kl_coef,
        block_rows,
    )
    return dlp, None, None, None, None


grpo_loss.defvjp(_vjp_fwd, _vjp_bwd)
