"""Pure-jnp reference oracles for every Pallas kernel.

These are the CORE correctness signal: each kernel in this package must match
its oracle to float32 tolerance across a hypothesis-driven sweep of shapes
(see python/tests/test_kernels.py). They are also used directly by the L2
model when ``use_kernels=False`` so the model itself can be A/B-tested
kernel-vs-reference.
"""

import jax
import jax.numpy as jnp


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMS normalization over the last axis: x / rms(x) * w."""
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def swiglu(gate: jax.Array, up: jax.Array) -> jax.Array:
    """Fused SwiGLU activation: silu(gate) * up."""
    return jax.nn.silu(gate) * up


def rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """Rotary position embedding.

    x:   [..., S, D] with D even — pairs are (x[..., :D/2], x[..., D/2:])
    cos/sin: [S, D/2]
    """
    d2 = x.shape[-1] // 2
    x1, x2 = x[..., :d2], x[..., d2:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def attention(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Causal multi-head attention.

    q, k, v: [B, H, S, D]. Returns [B, H, S, D].
    """
    d = q.shape[-1]
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(d).astype(q.dtype)
    s = q.shape[-2]
    mask = jnp.tril(jnp.ones((s, s), dtype=bool))
    scores = jnp.where(mask, scores, jnp.finfo(scores.dtype).min)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v)


def grpo_loss(
    lp_new: jax.Array,
    lp_old: jax.Array,
    lp_ref: jax.Array,
    adv: jax.Array,
    mask: jax.Array,
    clip_eps: float = 0.2,
    kl_coef: float = 0.01,
) -> jax.Array:
    """Fused per-token GRPO loss.

    lp_*: [B, T] per-token log-probabilities; adv: [B] per-sequence
    advantage; mask: [B, T] response mask. Returns per-token loss [B, T]
    (clipped PG surrogate + k3 KL penalty, masked).
    """
    ratio = jnp.exp(lp_new - lp_old)
    a = adv[:, None]
    s1 = ratio * a
    s2 = jnp.clip(ratio, 1.0 - clip_eps, 1.0 + clip_eps) * a
    pg = -jnp.minimum(s1, s2)
    # k3 KL estimator: exp(ref-new) - (ref-new) - 1  (>= 0)
    d = lp_ref - lp_new
    kl = jnp.exp(d) - d - 1.0
    return (pg + kl_coef * kl) * mask


def gmm(x: jax.Array, w: jax.Array, group_sizes: jax.Array) -> jax.Array:
    """Grouped matmul (MoE expert dispatch).

    x: [T, D] rows sorted by expert; w: [E, D, F]; group_sizes: [E] with
    sum == T. Row t belonging to group e computes x[t] @ w[e].
    """
    t = x.shape[0]
    bounds = jnp.cumsum(group_sizes)
    # expert id per row: number of bounds <= row index
    row = jnp.arange(t)
    eid = jnp.sum(row[:, None] >= bounds[None, :], axis=-1)
    return jnp.einsum("td,tdf->tf", x, w[eid])
