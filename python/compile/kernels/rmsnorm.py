"""Fused RMSNorm Pallas kernel (forward + custom-VJP backward).

The Ascend fused RMSNorm op the paper integrates normalizes a row and
applies the gain in one pass over the unified buffer; the TPU analogue keeps
a row-block resident in VMEM and fuses the mean-square reduction, rsqrt and
scale. Forward and backward are both Pallas kernels; the backward emits
per-row-block partial dw which the wrapper reduces (the cross-row reduction
is the only part XLA sees).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, pad_axis, pick_block, round_up

DEFAULT_BLOCK_ROWS = 128


def _fwd_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...]
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = x * jax.lax.rsqrt(var + eps) * w_ref[...]


def _bwd_kernel(x_ref, w_ref, dy_ref, dx_ref, dwp_ref, *, eps: float):
    x = x_ref[...]
    w = w_ref[...]
    dy = dy_ref[...]
    d = x.shape[-1]
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    xhat = x * inv
    dxhat = dy * w
    # dx = inv * (dxhat - xhat * mean(dxhat * xhat))
    dx_ref[...] = inv * (dxhat - xhat * jnp.mean(dxhat * xhat, axis=-1, keepdims=True))
    dwp_ref[...] = jnp.sum(dy * xhat, axis=0, keepdims=True)
    del d


def _run_fwd(x2, w, eps, block_rows):
    n, d = x2.shape
    br = pick_block(n, block_rows)
    np_ = round_up(n, br)
    xp = pad_axis(x2, 0, np_)
    out = pl.pallas_call(
        functools.partial(_fwd_kernel, eps=eps),
        grid=(np_ // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((np_, d), x2.dtype),
        interpret=INTERPRET,
    )(xp, w[None, :])
    return out[:n]


def _run_bwd(x2, w, dy2, eps, block_rows):
    n, d = x2.shape
    br = pick_block(n, block_rows)
    np_ = round_up(n, br)
    nblk = np_ // br
    xp = pad_axis(x2, 0, np_)
    dyp = pad_axis(dy2, 0, np_)
    dx, dwp = pl.pallas_call(
        functools.partial(_bwd_kernel, eps=eps),
        grid=(nblk,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((br, d), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((np_, d), x2.dtype),
            jax.ShapeDtypeStruct((nblk, d), x2.dtype),
        ],
        interpret=INTERPRET,
    )(xp, w[None, :], dyp)
    return dx[:n], jnp.sum(dwp, axis=0)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def rmsnorm(x, w, eps: float = 1e-6, block_rows: int = DEFAULT_BLOCK_ROWS):
    """RMSNorm over the last axis. x: [..., D], w: [D]."""
    shape = x.shape
    y = _run_fwd(x.reshape(-1, shape[-1]), w, eps, block_rows)
    return y.reshape(shape)


def _vjp_fwd(x, w, eps, block_rows):
    return rmsnorm(x, w, eps, block_rows), (x, w)


def _vjp_bwd(eps, block_rows, res, dy):
    x, w = res
    shape = x.shape
    dx, dw = _run_bwd(
        x.reshape(-1, shape[-1]), w, dy.reshape(-1, shape[-1]), eps, block_rows
    )
    return dx.reshape(shape), dw


rmsnorm.defvjp(_vjp_fwd, _vjp_bwd)
