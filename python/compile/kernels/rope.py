"""Rotary position embedding (RoPE) Pallas kernel.

Applies the rotation in half-split layout (x1, x2 halves of the head dim)
with cos/sin tables streamed per sequence-block. The backward pass is the
inverse rotation (angle negated), so the same kernel serves both directions
— the custom VJP simply flips the sign of sin.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, pad_axis, pick_block, round_up

DEFAULT_BLOCK_SEQ = 128


def _rope_kernel(x_ref, cos_ref, sin_ref, o_ref):
    x = x_ref[...]  # [rows, S_blk, D]
    cos = cos_ref[...]  # [1, S_blk, D/2]
    sin = sin_ref[...]
    d2 = x.shape[-1] // 2
    x1 = x[..., :d2]
    x2 = x[..., d2:]
    o_ref[...] = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _run(x, cos, sin, block_seq):
    """x: [R, S, D] (R = collapsed batch*heads), cos/sin: [S, D/2]."""
    r, s, d = x.shape
    bs = pick_block(s, block_seq)
    sp = round_up(s, bs)
    xp = pad_axis(x, 1, sp)
    cosp = pad_axis(cos, 0, sp)[None]
    sinp = pad_axis(sin, 0, sp)[None]
    out = pl.pallas_call(
        _rope_kernel,
        grid=(sp // bs,),
        in_specs=[
            pl.BlockSpec((r, bs, d), lambda i: (0, i, 0)),
            pl.BlockSpec((1, bs, d // 2), lambda i: (0, i, 0)),
            pl.BlockSpec((1, bs, d // 2), lambda i: (0, i, 0)),
        ],
        out_specs=pl.BlockSpec((r, bs, d), lambda i: (0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, sp, d), x.dtype),
        interpret=INTERPRET,
    )(xp, cosp, sinp)
    return out[:, :s]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def rope(x, cos, sin, block_seq: int = DEFAULT_BLOCK_SEQ):
    """Apply RoPE. x: [..., S, D] (D even), cos/sin: [S, D/2]."""
    shape = x.shape
    y = _run(x.reshape(-1, shape[-2], shape[-1]), cos, sin, block_seq)
    return y.reshape(shape)


def _vjp_fwd(x, cos, sin, block_seq):
    return rope(x, cos, sin, block_seq), (cos, sin)


def _vjp_bwd(block_seq, res, dy):
    cos, sin = res
    # Rotation is orthogonal: the cotangent is rotated by the inverse angle.
    dx = rope(dy, cos, -sin, block_seq)
    return dx, None, None


rope.defvjp(_vjp_fwd, _vjp_bwd)


def rope_tables(seq_len: int, head_dim: int, base: float = 10000.0):
    """Standard RoPE cos/sin tables: [S, D/2] each."""
    d2 = head_dim // 2
    inv_freq = 1.0 / (base ** (jnp.arange(0, d2, dtype=jnp.float32) / d2))
    t = jnp.arange(seq_len, dtype=jnp.float32)
    angles = jnp.outer(t, inv_freq)
    return jnp.cos(angles), jnp.sin(angles)
