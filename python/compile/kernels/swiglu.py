"""Fused SwiGLU activation Pallas kernel (forward + custom-VJP backward).

Computes silu(gate) * up in one VMEM-resident pass, the fusion boundary the
paper's Ascend SwiGLU op uses (the surrounding matmuls are left to the MXU /
XLA dot fusion). Backward is also a Pallas kernel: both input cotangents are
elementwise in the saved activations, so no cross-row reduction is needed.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, pad_axis, pick_block, round_up

DEFAULT_BLOCK_ROWS = 128


def _fwd_kernel(g_ref, u_ref, o_ref):
    g = g_ref[...]
    o_ref[...] = g * jax.nn.sigmoid(g) * u_ref[...]


def _bwd_kernel(g_ref, u_ref, dy_ref, dg_ref, du_ref):
    g = g_ref[...]
    u = u_ref[...]
    dy = dy_ref[...]
    sig = jax.nn.sigmoid(g)
    silu = g * sig
    # d/dg silu(g) = sig(g) * (1 + g * (1 - sig(g)))
    dg_ref[...] = dy * u * sig * (1.0 + g * (1.0 - sig))
    du_ref[...] = dy * silu


def _blocked_call(kernel, inputs, n_out, shape, dtype, block_rows):
    n, d = shape
    br = pick_block(n, block_rows)
    np_ = round_up(n, br)
    padded = [pad_axis(x, 0, np_) for x in inputs]
    spec = pl.BlockSpec((br, d), lambda i: (i, 0))
    out = pl.pallas_call(
        kernel,
        grid=(np_ // br,),
        in_specs=[spec] * len(inputs),
        out_specs=spec if n_out == 1 else [spec] * n_out,
        out_shape=(
            jax.ShapeDtypeStruct((np_, d), dtype)
            if n_out == 1
            else [jax.ShapeDtypeStruct((np_, d), dtype)] * n_out
        ),
        interpret=INTERPRET,
    )(*padded)
    if n_out == 1:
        return out[:n]
    return tuple(o[:n] for o in out)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def swiglu(gate, up, block_rows: int = DEFAULT_BLOCK_ROWS):
    """Fused silu(gate) * up. gate, up: [..., F] of equal shape."""
    shape = gate.shape
    g2 = gate.reshape(-1, shape[-1])
    u2 = up.reshape(-1, shape[-1])
    y = _blocked_call(_fwd_kernel, [g2, u2], 1, g2.shape, gate.dtype, block_rows)
    return y.reshape(shape)


def _vjp_fwd(gate, up, block_rows):
    return swiglu(gate, up, block_rows), (gate, up)


def _vjp_bwd(block_rows, res, dy):
    gate, up = res
    shape = gate.shape
    g2 = gate.reshape(-1, shape[-1])
    u2 = up.reshape(-1, shape[-1])
    dy2 = dy.reshape(-1, shape[-1])
    dg, du = _blocked_call(
        _bwd_kernel, [g2, u2, dy2], 2, g2.shape, gate.dtype, block_rows
    )
    return dg.reshape(shape), du.reshape(shape)


swiglu.defvjp(_vjp_fwd, _vjp_bwd)
