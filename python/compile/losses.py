"""GRPO loss assembly + Adam — the body of the `train_step` artifact."""

from typing import List, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from . import kernels, model
from .configs import ModelConfig
from .kernels import ref


class TrainHyper(NamedTuple):
    clip_eps: float = 0.2
    kl_coef: float = 0.01
    beta1: float = 0.9
    beta2: float = 0.999
    adam_eps: float = 1e-8


def grpo_objective(cfg: ModelConfig, params, batch, hyper: TrainHyper,
                   use_kernels: bool = True):
    """Scalar GRPO loss + aux metrics.

    batch = (tokens [B,S] i32, resp_mask [B,S-1] f32, old_lp, ref_lp [B,S-1],
    adv [B]).
    """
    tokens, resp_mask, old_lp, ref_lp, adv = batch
    lp = model.logprobs(cfg, params, tokens, use_kernels)
    loss_fn = kernels.grpo_loss if use_kernels else ref.grpo_loss
    per_tok = loss_fn(lp, old_lp, ref_lp, adv, resp_mask, hyper.clip_eps, hyper.kl_coef)
    denom = jnp.maximum(jnp.sum(resp_mask), 1.0)
    loss = jnp.sum(per_tok) / denom
    # aux metrics (no grad): mean k3-KL and mean ratio over response tokens
    d = ref_lp - lp
    kl = (jnp.exp(d) - d - 1.0) * resp_mask
    ratio = jnp.exp(lp - old_lp) * resp_mask
    return loss, (jnp.sum(kl) / denom, jnp.sum(ratio) / denom)


def adam_update(params: List[jax.Array], grads, m, v, step, lr,
                hyper: TrainHyper) -> Tuple[list, list, list]:
    """One Adam step over the flat param list. step is 1-based (f32)."""
    b1, b2, eps = hyper.beta1, hyper.beta2, hyper.adam_eps
    c1 = 1.0 - b1**step
    c2 = 1.0 - b2**step
    new_p, new_m, new_v = [], [], []
    for p, g, mi, vi in zip(params, grads, m, v):
        mi = b1 * mi + (1.0 - b1) * g
        vi = b2 * vi + (1.0 - b2) * jnp.square(g)
        update = (mi / c1) / (jnp.sqrt(vi / c2) + eps)
        new_p.append(p - lr * update)
        new_m.append(mi)
        new_v.append(vi)
    return new_p, new_m, new_v


def train_step(cfg: ModelConfig, params, m, v, step, lr, batch,
               hyper: TrainHyper = TrainHyper(), use_kernels: bool = True):
    """Full GRPO update: fwd/bwd + Adam.

    Returns (new_params, new_m, new_v, loss, kl, ratio).
    """
    (loss, (kl, ratio)), grads = jax.value_and_grad(
        lambda p: grpo_objective(cfg, p, batch, hyper, use_kernels), has_aux=True
    )(params)
    new_p, new_m, new_v = adam_update(params, grads, m, v, step, lr, hyper)
    return new_p, new_m, new_v, loss, kl, ratio
