"""L2 — the JAX transformer (dense + MoE) used by every RL worker state.

The model is written against the L1 Pallas kernels (attention, rmsnorm,
swiglu, rope, gmm, grpo_loss) so that `jax.jit(...).lower()` folds the
kernels into the same HLO artifact the Rust runtime executes. Setting
``use_kernels=False`` swaps in the pure-jnp oracles — used by the pytest
suite to A/B the full model, and by the trainer artifact when a faster
CPU lowering is preferred (numerics are verified identical either way).

Parameters are a FLAT LIST of arrays with a parallel name list
(`param_names`); the AOT manifest records the order, and the Rust side
threads the same flat list through every execute call.
"""

from typing import List, Tuple

import jax
import jax.numpy as jnp

from . import kernels
from .configs import ModelConfig
from .kernels import ref


def _ops(use_kernels: bool):
    if use_kernels:
        return kernels.rmsnorm, kernels.swiglu, kernels.rope, kernels.attention, kernels.gmm
    return ref.rmsnorm, ref.swiglu, ref.rope, ref.attention, ref.gmm


# ---------------------------------------------------------------- params
def param_names(cfg: ModelConfig) -> List[str]:
    names = ["embed"]
    for i in range(cfg.n_layers):
        names += [f"l{i}.attn_norm", f"l{i}.wqkv", f"l{i}.wo", f"l{i}.ffn_norm"]
        if cfg.moe is None:
            names += [f"l{i}.w_gate", f"l{i}.w_up", f"l{i}.w_down"]
        else:
            names += [f"l{i}.router", f"l{i}.e_gate", f"l{i}.e_up", f"l{i}.e_down"]
    names += ["final_norm", "lm_head"]
    return names


def init_params(cfg: ModelConfig, key: jax.Array) -> List[jax.Array]:
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab_size
    std = 0.02
    out_std = std / (2.0 * cfg.n_layers) ** 0.5
    params: List[jax.Array] = []

    def nrm(key, shape, s):
        return jax.random.normal(key, shape, dtype=jnp.float32) * s

    keys = iter(jax.random.split(key, 4 + cfg.n_layers * 8))
    params.append(nrm(next(keys), (v, d), std))  # embed
    for _ in range(cfg.n_layers):
        params.append(jnp.ones((d,), jnp.float32))  # attn_norm
        params.append(nrm(next(keys), (d, 3 * d), std))  # wqkv
        params.append(nrm(next(keys), (d, d), out_std))  # wo
        params.append(jnp.ones((d,), jnp.float32))  # ffn_norm
        if cfg.moe is None:
            params.append(nrm(next(keys), (d, f), std))  # w_gate
            params.append(nrm(next(keys), (d, f), std))  # w_up
            params.append(nrm(next(keys), (f, d), out_std))  # w_down
        else:
            e = cfg.moe.num_experts
            params.append(nrm(next(keys), (d, e), std))  # router
            params.append(nrm(next(keys), (e, d, f), std))  # e_gate
            params.append(nrm(next(keys), (e, d, f), std))  # e_up
            params.append(nrm(next(keys), (e, f, d), out_std))  # e_down
    params.append(jnp.ones((d,), jnp.float32))  # final_norm
    params.append(nrm(next(keys), (d, v), std))  # lm_head
    return params


# ---------------------------------------------------------------- forward
def _moe_ffn(cfg, h, router_w, e_gate, e_up, e_down, swiglu_fn, gmm_fn):
    """Top-k routed MoE FFN over flattened tokens via the GMM kernel."""
    b, s, d = h.shape
    e = cfg.moe.num_experts
    k = cfg.moe.top_k
    x = h.reshape(-1, d)  # [T, D]
    t = x.shape[0]
    logits = x @ router_w  # [T, E]
    topv, topi = jax.lax.top_k(logits, k)  # [T, k]
    gates = jax.nn.softmax(topv, axis=-1)  # [T, k]

    flat_expert = topi.reshape(-1)  # [T*k]
    flat_tok = jnp.repeat(jnp.arange(t), k)  # [T*k]
    order = jnp.argsort(flat_expert, stable=True)
    xs = x[flat_tok[order]]  # [T*k, D] sorted by expert
    group_sizes = jnp.bincount(flat_expert, length=e).astype(jnp.int32)

    hidden = swiglu_fn(gmm_fn(xs, e_gate, group_sizes), gmm_fn(xs, e_up, group_sizes))
    ys = gmm_fn(hidden, e_down, group_sizes)  # [T*k, D]

    unsort = jnp.argsort(order)
    ys = ys[unsort].reshape(t, k, d)
    out = jnp.einsum("tkd,tk->td", ys, gates)
    return out.reshape(b, s, d)


def forward(cfg: ModelConfig, params: List[jax.Array], tokens: jax.Array,
            use_kernels: bool = True) -> jax.Array:
    """Token ids [B, S] → logits [B, S, V]."""
    rmsnorm_fn, swiglu_fn, rope_fn, attn_fn, gmm_fn = _ops(use_kernels)
    b, s = tokens.shape
    d, nh, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    it = iter(params)
    embed = next(it)
    x = embed[tokens]  # [B, S, D]
    cos, sin = kernels.rope_tables(s, hd, cfg.rope_base)
    for _ in range(cfg.n_layers):
        attn_norm = next(it)
        wqkv = next(it)
        wo = next(it)
        ffn_norm = next(it)

        h = rmsnorm_fn(x, attn_norm, cfg.norm_eps)
        qkv = h @ wqkv  # [B, S, 3D]
        q, k, v = jnp.split(qkv, 3, axis=-1)

        def heads(z):
            return z.reshape(b, s, nh, hd).transpose(0, 2, 1, 3)

        q, k, v = heads(q), heads(k), heads(v)
        q = rope_fn(q, cos, sin)
        k = rope_fn(k, cos, sin)
        o = attn_fn(q, k, v)  # [B, H, S, hd]
        o = o.transpose(0, 2, 1, 3).reshape(b, s, d)
        x = x + o @ wo

        h2 = rmsnorm_fn(x, ffn_norm, cfg.norm_eps)
        if cfg.moe is None:
            w_gate, w_up, w_down = next(it), next(it), next(it)
            ff = swiglu_fn(h2 @ w_gate, h2 @ w_up) @ w_down
        else:
            router_w, e_gate, e_up, e_down = next(it), next(it), next(it), next(it)
            ff = _moe_ffn(cfg, h2, router_w, e_gate, e_up, e_down, swiglu_fn, gmm_fn)
        x = x + ff

    final_norm = next(it)
    lm_head = next(it)
    x = rmsnorm_fn(x, final_norm, cfg.norm_eps)
    return x @ lm_head  # [B, S, V]


def logprobs(cfg: ModelConfig, params, tokens, use_kernels: bool = True) -> jax.Array:
    """Per-token log-prob of the realized next token: [B, S-1]."""
    logits = forward(cfg, params, tokens, use_kernels)
    lsm = jax.nn.log_softmax(logits[:, :-1], axis=-1)  # predicts tokens[:,1:]
    tgt = tokens[:, 1:]
    return jnp.take_along_axis(lsm, tgt[..., None], axis=-1)[..., 0]


def logprobs_and_entropy(cfg, params, tokens, use_kernels: bool = True
                         ) -> Tuple[jax.Array, jax.Array]:
    logits = forward(cfg, params, tokens, use_kernels)
    lsm = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    tgt = tokens[:, 1:]
    lp = jnp.take_along_axis(lsm, tgt[..., None], axis=-1)[..., 0]
    entropy = -jnp.sum(jnp.exp(lsm) * lsm, axis=-1)  # [B, S-1]
    return lp, entropy
