"""AOT export tests: manifest/params/HLO consistency for a fresh export
into a temp dir (does not touch artifacts/)."""

import json
import os

import jax
import numpy as np
import pytest

from compile import aot, configs, model

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def export(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    manifest = aot.export_preset("tiny", out, batch=4, seed=0, use_kernels_train=False)
    return out, manifest


class TestManifest:
    def test_files_exist(self, export):
        out, m = export
        d = os.path.join(out, "tiny")
        for a in m["artifacts"]:
            assert os.path.getsize(os.path.join(d, a["file"])) > 0
        assert os.path.exists(os.path.join(d, "params_init.bin"))
        assert os.path.exists(os.path.join(d, "manifest.json"))

    def test_manifest_json_round_trip(self, export):
        out, m = export
        with open(os.path.join(out, "tiny", "manifest.json")) as f:
            loaded = json.load(f)
        assert loaded["n_params"] == m["n_params"]
        assert loaded["model"]["param_count"] == configs.TINY.param_count()
        assert loaded["vocab"][: 3] == ["<pad>", "<bos>", "<eos>"]

    def test_param_offsets_contiguous(self, export):
        _, m = export
        off = 0
        for p in m["params"]:
            assert p["offset"] == off
            off += p["numel"] * 4

    def test_params_bin_matches_total(self, export):
        out, m = export
        total = sum(p["numel"] * 4 for p in m["params"])
        assert os.path.getsize(os.path.join(out, "tiny", "params_init.bin")) == total

    def test_params_bin_reproduces_init(self, export):
        out, m = export
        params = model.init_params(configs.TINY, jax.random.PRNGKey(0))
        with open(os.path.join(out, "tiny", "params_init.bin"), "rb") as f:
            raw = np.frombuffer(f.read(), dtype="<f4")
        flat = np.concatenate([np.asarray(p).ravel() for p in params])
        np.testing.assert_array_equal(raw, flat)

    def test_artifact_signatures(self, export):
        _, m = export
        n = m["n_params"]
        train = next(a for a in m["artifacts"] if a["kind"] == "train_step")
        assert len(train["inputs"]) == 3 * n + 7
        assert len(train["outputs"]) == 3 * n + 3
        lp = next(a for a in m["artifacts"] if a["kind"] == "logprobs")
        assert len(lp["inputs"]) == n + 1
        assert lp["outputs"][0]["shape"] == [4, configs.TINY.max_seq - 1]
        dec = next(a for a in m["artifacts"] if a["kind"] == "decode_step")
        assert dec["inputs"][-1]["name"] == "token"
        assert dec["inputs"][-1]["dtype"] == "i32"

    def test_hlo_text_is_parseable_header(self, export):
        out, m = export
        for a in m["artifacts"]:
            with open(os.path.join(out, "tiny", a["file"])) as f:
                head = f.read(200)
            assert "HloModule" in head, f"{a['kind']} missing HloModule header"


class TestHloRoundTrip:
    """The artifact bytes must round-trip through XLA's HLO text parser —
    this is exactly what the Rust runtime does (HloModuleProto::from_text).
    Authoritative *execution* of the artifacts is covered by
    rust/tests/runtime_smoke.rs on the PJRT CPU client."""

    def test_hlo_text_parses_back_to_module(self, export):
        out, m = export
        from jax._src.lib import xla_client as xc

        for a in m["artifacts"]:
            with open(os.path.join(out, "tiny", a["file"])) as f:
                hlo_text = f.read()
            mod = xc._xla.hlo_module_from_text(hlo_text)
            proto = mod.as_serialized_hlo_module_proto()
            assert len(proto) > 0, f"{a['kind']} failed HLO text round-trip"

    def test_logprobs_jit_matches_eager(self, export):
        cfg = configs.TINY
        params = model.init_params(cfg, jax.random.PRNGKey(0))
        tokens = jax.random.randint(
            jax.random.PRNGKey(2), (4, cfg.max_seq), 0, 40, dtype=jax.numpy.int32
        )
        want = model.logprobs(cfg, params, tokens, use_kernels=True)
        got = jax.jit(lambda p, t: model.logprobs(cfg, p, t, use_kernels=True))(
            params, tokens
        )
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
