"""Kernel-vs-oracle correctness: every Pallas kernel against its pure-jnp
reference, including gradients through the custom VJPs, swept over shapes
with hypothesis."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

SETTINGS = dict(max_examples=10, deadline=None)


def rand(key, *shape):
    return jax.random.normal(key, shape, dtype=jnp.float32)


def keys(n, seed=0):
    return jax.random.split(jax.random.PRNGKey(seed), n)


# ---------------------------------------------------------------- rmsnorm
@settings(**SETTINGS)
@given(
    rows=st.integers(1, 300),
    d=st.sampled_from([8, 16, 64, 96]),
    seed=st.integers(0, 2**31 - 1),
)
def test_rmsnorm_matches_ref(rows, d, seed):
    k1, k2 = keys(2, seed)
    x = rand(k1, rows, d)
    w = rand(k2, d)
    got = kernels.rmsnorm(x, w)
    want = ref.rmsnorm(x, w)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(**SETTINGS)
@given(rows=st.integers(1, 130), d=st.sampled_from([8, 32]), seed=st.integers(0, 2**31 - 1))
def test_rmsnorm_grad_matches_ref(rows, d, seed):
    k1, k2, k3 = keys(3, seed)
    x = rand(k1, rows, d)
    w = rand(k2, d)
    dy = rand(k3, rows, d)

    def f_kernel(x, w):
        return jnp.sum(kernels.rmsnorm(x, w) * dy)

    def f_ref(x, w):
        return jnp.sum(ref.rmsnorm(x, w) * dy)

    gx, gw = jax.grad(f_kernel, argnums=(0, 1))(x, w)
    rx, rw = jax.grad(f_ref, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(gx, rx, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(gw, rw, rtol=1e-4, atol=1e-4)


def test_rmsnorm_3d_shape():
    k1, k2 = keys(2)
    x = rand(k1, 2, 5, 16)
    w = rand(k2, 16)
    np.testing.assert_allclose(
        kernels.rmsnorm(x, w), ref.rmsnorm(x, w), rtol=1e-5, atol=1e-5
    )


# ---------------------------------------------------------------- swiglu
@settings(**SETTINGS)
@given(
    rows=st.integers(1, 300),
    f=st.sampled_from([4, 16, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_swiglu_matches_ref(rows, f, seed):
    k1, k2 = keys(2, seed)
    g = rand(k1, rows, f)
    u = rand(k2, rows, f)
    np.testing.assert_allclose(
        kernels.swiglu(g, u), ref.swiglu(g, u), rtol=1e-5, atol=1e-5
    )


@settings(**SETTINGS)
@given(rows=st.integers(1, 130), seed=st.integers(0, 2**31 - 1))
def test_swiglu_grad_matches_ref(rows, seed):
    k1, k2, k3 = keys(3, seed)
    g = rand(k1, rows, 16)
    u = rand(k2, rows, 16)
    dy = rand(k3, rows, 16)
    gg, gu = jax.grad(lambda a, b: jnp.sum(kernels.swiglu(a, b) * dy), (0, 1))(g, u)
    rg, ru = jax.grad(lambda a, b: jnp.sum(ref.swiglu(a, b) * dy), (0, 1))(g, u)
    np.testing.assert_allclose(gg, rg, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(gu, ru, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------- rope
@settings(**SETTINGS)
@given(
    s=st.integers(1, 200),
    d=st.sampled_from([4, 8, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_rope_matches_ref(s, d, seed):
    (k1,) = keys(1, seed)
    x = rand(k1, 2, 3, s, d)
    cos, sin = kernels.rope_tables(s, d)
    np.testing.assert_allclose(
        kernels.rope(x, cos, sin), ref.rope(x, cos, sin), rtol=1e-5, atol=1e-5
    )


def test_rope_grad_is_inverse_rotation():
    k1, k2 = keys(2)
    x = rand(k1, 1, 2, 33, 8)
    dy = rand(k2, 1, 2, 33, 8)
    cos, sin = kernels.rope_tables(33, 8)
    gx = jax.grad(lambda a: jnp.sum(kernels.rope(a, cos, sin) * dy))(x)
    rx = jax.grad(lambda a: jnp.sum(ref.rope(a, cos, sin) * dy))(x)
    np.testing.assert_allclose(gx, rx, rtol=1e-4, atol=1e-4)


def test_rope_norm_preserved():
    # rotation is orthogonal: per-pair norms must be preserved
    (k1,) = keys(1)
    x = rand(k1, 1, 1, 17, 8)
    cos, sin = kernels.rope_tables(17, 8)
    y = kernels.rope(x, cos, sin)
    np.testing.assert_allclose(
        jnp.linalg.norm(x, axis=-1), jnp.linalg.norm(y, axis=-1), rtol=1e-5
    )


# ---------------------------------------------------------------- attention
@settings(**SETTINGS)
@given(
    s=st.integers(1, 120),
    d=st.sampled_from([4, 8, 16]),
    b=st.integers(1, 2),
    h=st.integers(1, 2),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_matches_ref(s, d, b, h, seed):
    k1, k2, k3 = keys(3, seed)
    q = rand(k1, b, h, s, d)
    k = rand(k2, b, h, s, d)
    v = rand(k3, b, h, s, d)
    got = kernels.attention(q, k, v)
    want = ref.attention(q, k, v)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=5, deadline=None)
@given(s=st.integers(2, 80), seed=st.integers(0, 2**31 - 1))
def test_attention_grad_matches_ref(s, seed):
    k1, k2, k3, k4 = keys(4, seed)
    q = rand(k1, 1, 2, s, 8)
    k = rand(k2, 1, 2, s, 8)
    v = rand(k3, 1, 2, s, 8)
    dy = rand(k4, 1, 2, s, 8)
    gq, gk, gv = jax.grad(
        lambda a, b_, c: jnp.sum(kernels.attention(a, b_, c) * dy), (0, 1, 2)
    )(q, k, v)
    rq, rk, rv = jax.grad(
        lambda a, b_, c: jnp.sum(ref.attention(a, b_, c) * dy), (0, 1, 2)
    )(q, k, v)
    np.testing.assert_allclose(gq, rq, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(gk, rk, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(gv, rv, rtol=1e-3, atol=1e-3)


def test_attention_causality():
    # changing a future token must not change earlier outputs
    k1, k2, k3 = keys(3)
    q = rand(k1, 1, 1, 16, 8)
    k = rand(k2, 1, 1, 16, 8)
    v = rand(k3, 1, 1, 16, 8)
    out1 = kernels.attention(q, k, v)
    k2_ = k.at[0, 0, 15].set(99.0)
    v2_ = v.at[0, 0, 15].set(-99.0)
    out2 = kernels.attention(q, k2_, v2_)
    np.testing.assert_allclose(out1[0, 0, :15], out2[0, 0, :15], rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------- grpo_loss
@settings(**SETTINGS)
@given(
    b=st.integers(1, 100),
    t=st.sampled_from([4, 16, 33]),
    seed=st.integers(0, 2**31 - 1),
)
def test_grpo_loss_matches_ref(b, t, seed):
    k1, k2, k3, k4 = keys(4, seed)
    lp_new = -jnp.abs(rand(k1, b, t))
    lp_old = -jnp.abs(rand(k2, b, t))
    lp_ref = -jnp.abs(rand(k3, b, t))
    adv = rand(k4, b)
    mask = (jnp.arange(t)[None, :] < (t - 1)).astype(jnp.float32).repeat(b, 0)
    got = kernels.grpo_loss(lp_new, lp_old, lp_ref, adv, mask)
    want = ref.grpo_loss(lp_new, lp_old, lp_ref, adv, mask)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@settings(**SETTINGS)
@given(b=st.integers(1, 40), seed=st.integers(0, 2**31 - 1))
def test_grpo_loss_grad_matches_ref(b, seed):
    t = 12
    k1, k2, k3, k4 = keys(4, seed)
    lp_new = -jnp.abs(rand(k1, b, t))
    lp_old = -jnp.abs(rand(k2, b, t))
    lp_ref = -jnp.abs(rand(k3, b, t))
    adv = rand(k4, b)
    mask = jnp.ones((b, t), dtype=jnp.float32)
    g = jax.grad(lambda lp: jnp.sum(kernels.grpo_loss(lp, lp_old, lp_ref, adv, mask)))(lp_new)
    r = jax.grad(lambda lp: jnp.sum(ref.grpo_loss(lp, lp_old, lp_ref, adv, mask)))(lp_new)
    np.testing.assert_allclose(g, r, rtol=1e-4, atol=1e-5)


def test_grpo_loss_zero_when_new_equals_old_equals_ref_zero_adv():
    lp = -jnp.ones((2, 4))
    adv = jnp.zeros(2)
    mask = jnp.ones((2, 4))
    out = kernels.grpo_loss(lp, lp, lp, adv, mask)
    np.testing.assert_allclose(out, jnp.zeros((2, 4)), atol=1e-7)


# ---------------------------------------------------------------- gmm
@settings(**SETTINGS)
@given(
    e=st.integers(1, 6),
    d=st.sampled_from([4, 16]),
    f=st.sampled_from([8, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_gmm_matches_ref(e, d, f, seed):
    k1, k2, k3 = keys(3, seed)
    sizes = jax.random.randint(k3, (e,), 0, 50)
    t = int(jnp.sum(sizes))
    if t == 0:
        return
    x = rand(k1, t, d)
    w = rand(k2, e, d, f)
    got = kernels.gmm(x, w, sizes.astype(jnp.int32))
    want = ref.gmm(x, w, sizes)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(e=st.integers(1, 4), seed=st.integers(0, 2**31 - 1))
def test_gmm_grad_matches_ref(e, seed):
    d, f = 8, 8
    k1, k2, k3, k4 = keys(4, seed)
    sizes = jax.random.randint(k3, (e,), 1, 20)
    t = int(jnp.sum(sizes))
    x = rand(k1, t, d)
    w = rand(k2, e, d, f)
    dy = rand(k4, t, f)
    sizes32 = sizes.astype(jnp.int32)
    gx, gw = jax.grad(lambda a, b: jnp.sum(kernels.gmm(a, b, sizes32) * dy), (0, 1))(x, w)
    rx, rw = jax.grad(lambda a, b: jnp.sum(ref.gmm(a, b, sizes) * dy), (0, 1))(x, w)
    np.testing.assert_allclose(gx, rx, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(gw, rw, rtol=1e-4, atol=1e-4)


def test_gmm_empty_group():
    # an expert with zero rows must contribute nothing and get zero dw
    x = jnp.ones((4, 4))
    w = jnp.ones((3, 4, 4))
    sizes = jnp.array([4, 0, 0], dtype=jnp.int32)
    out = kernels.gmm(x, w, sizes)
    np.testing.assert_allclose(out, jnp.full((4, 4), 4.0), rtol=1e-6)
