"""L2 model tests: kernel-path vs reference-path parity, shapes, gradients,
MoE routing, and training-step behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, generate, losses, model

jax.config.update("jax_platform_name", "cpu")

TINY = configs.TINY
MOE = configs.MOE_TINY


@pytest.fixture(scope="module")
def tiny_params():
    return model.init_params(TINY, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def moe_params():
    return model.init_params(MOE, jax.random.PRNGKey(1))


def toks(cfg, b, s, seed=0):
    return jax.random.randint(jax.random.PRNGKey(seed), (b, s), 0, cfg.vocab_size)


class TestParams:
    def test_param_names_match_init(self, tiny_params):
        names = model.param_names(TINY)
        assert len(names) == len(tiny_params)
        assert names[0] == "embed"
        assert names[-1] == "lm_head"

    def test_param_count_formula(self, tiny_params):
        total = sum(int(p.size) for p in tiny_params)
        assert total == TINY.param_count()

    def test_moe_param_count_formula(self, moe_params):
        total = sum(int(p.size) for p in moe_params)
        assert total == MOE.param_count()

    def test_deterministic_init(self):
        a = model.init_params(TINY, jax.random.PRNGKey(7))
        b = model.init_params(TINY, jax.random.PRNGKey(7))
        for x, y in zip(a, b):
            np.testing.assert_array_equal(x, y)


class TestForward:
    def test_logits_shape(self, tiny_params):
        logits = model.forward(TINY, tiny_params, toks(TINY, 2, 16))
        assert logits.shape == (2, 16, TINY.vocab_size)

    def test_kernel_vs_ref_path(self, tiny_params):
        t = toks(TINY, 2, 24)
        a = model.forward(TINY, tiny_params, t, use_kernels=True)
        b = model.forward(TINY, tiny_params, t, use_kernels=False)
        np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)

    def test_moe_kernel_vs_ref_path(self, moe_params):
        t = toks(MOE, 2, 16)
        a = model.forward(MOE, moe_params, t, use_kernels=True)
        b = model.forward(MOE, moe_params, t, use_kernels=False)
        np.testing.assert_allclose(a, b, rtol=3e-4, atol=3e-4)

    def test_causality(self, tiny_params):
        t = toks(TINY, 1, 12)
        base = model.forward(TINY, tiny_params, t)
        t2 = t.at[0, -1].set((t[0, -1] + 1) % TINY.vocab_size)
        pert = model.forward(TINY, tiny_params, t2)
        np.testing.assert_allclose(base[0, :-1], pert[0, :-1], rtol=1e-5, atol=1e-5)

    def test_logprobs_are_log_probabilities(self, tiny_params):
        lp = model.logprobs(TINY, tiny_params, toks(TINY, 2, 10))
        assert lp.shape == (2, 9)
        assert bool(jnp.all(lp <= 0.0))

    def test_entropy_positive(self, tiny_params):
        _, ent = model.logprobs_and_entropy(TINY, tiny_params, toks(TINY, 2, 10))
        assert bool(jnp.all(ent >= 0.0))


class TestDecodeStep:
    def test_incremental_matches_full_forward(self, tiny_params):
        """Feeding tokens one at a time through the KV cache must produce
        the same logits as the full-sequence forward (ref path)."""
        seq = jnp.array([[1, 5, 9, 12, 3, 7]], dtype=jnp.int32)
        b, s = seq.shape
        kv = generate.init_kv_cache(TINY, b)
        inc_logits = []
        for i in range(s):
            pos = jnp.full((b,), i, dtype=jnp.int32)
            logits, kv = generate.decode_step(TINY, tiny_params, kv, pos, seq[:, i])
            inc_logits.append(logits)
        full = model.forward(TINY, tiny_params, seq, use_kernels=False)
        inc = jnp.stack(inc_logits, axis=1)  # [b, s, V]
        np.testing.assert_allclose(inc, full, rtol=2e-3, atol=2e-3)

    def test_moe_decode_matches_forward(self, moe_params):
        seq = jnp.array([[1, 4, 8]], dtype=jnp.int32)
        kv = generate.init_kv_cache(MOE, 1)
        outs = []
        for i in range(seq.shape[1]):
            pos = jnp.array([i], dtype=jnp.int32)
            logits, kv = generate.decode_step(MOE, moe_params, kv, pos, seq[:, i])
            outs.append(logits)
        full = model.forward(MOE, moe_params, seq, use_kernels=False)
        np.testing.assert_allclose(jnp.stack(outs, 1), full, rtol=3e-3, atol=3e-3)

    def test_per_slot_positions(self, tiny_params):
        """Slots at different depths must be independent (continuous
        batching invariant)."""
        kv = generate.init_kv_cache(TINY, 2)
        # advance slot 0 by two tokens, slot 1 stays at pos 0
        logits0, kv = generate.decode_step(
            TINY, tiny_params, kv, jnp.array([0, 0], jnp.int32), jnp.array([1, 1], jnp.int32)
        )
        _, kv = generate.decode_step(
            TINY, tiny_params, kv, jnp.array([1, 0], jnp.int32), jnp.array([5, 1], jnp.int32)
        )
        # slot 1 re-fed token 1 at pos 0: logits must equal slot 1's first step
        kv2 = generate.init_kv_cache(TINY, 2)
        logits1, _ = generate.decode_step(
            TINY, tiny_params, kv2, jnp.array([0, 0], jnp.int32), jnp.array([1, 1], jnp.int32)
        )
        np.testing.assert_allclose(logits0[1], logits1[1], rtol=1e-5, atol=1e-5)


class TestTrainStep:
    def _batch(self, cfg, b=2, s=12):
        tokens = toks(cfg, b, s, seed=3)
        mask = jnp.ones((b, s - 1), jnp.float32)
        old_lp = -jnp.abs(jax.random.normal(jax.random.PRNGKey(4), (b, s - 1)))
        ref_lp = -jnp.abs(jax.random.normal(jax.random.PRNGKey(5), (b, s - 1)))
        adv = jax.random.normal(jax.random.PRNGKey(6), (b,))
        return tokens, mask, old_lp, ref_lp, adv

    def test_updates_all_params(self, tiny_params):
        m = [jnp.zeros_like(p) for p in tiny_params]
        v = [jnp.zeros_like(p) for p in tiny_params]
        batch = self._batch(TINY)
        new_p, new_m, new_v, loss, kl, ratio = losses.train_step(
            TINY, tiny_params, m, v, 1.0, 1e-3, batch, use_kernels=False
        )
        assert jnp.isfinite(loss)
        assert jnp.isfinite(kl) and jnp.isfinite(ratio)
        changed = sum(int(not jnp.allclose(a, b)) for a, b in zip(tiny_params, new_p))
        assert changed == len(tiny_params), "every tensor must receive a gradient"

    def test_kernel_and_ref_train_agree(self, tiny_params):
        m = [jnp.zeros_like(p) for p in tiny_params]
        v = [jnp.zeros_like(p) for p in tiny_params]
        batch = self._batch(TINY)
        _, _, _, loss_k, _, _ = losses.train_step(
            TINY, tiny_params, m, v, 1.0, 1e-3, batch, use_kernels=True
        )
        _, _, _, loss_r, _, _ = losses.train_step(
            TINY, tiny_params, m, v, 1.0, 1e-3, batch, use_kernels=False
        )
        np.testing.assert_allclose(loss_k, loss_r, rtol=1e-4, atol=1e-5)

    def test_zero_mask_means_no_update(self, tiny_params):
        m = [jnp.zeros_like(p) for p in tiny_params]
        v = [jnp.zeros_like(p) for p in tiny_params]
        tokens, _, old_lp, ref_lp, adv = self._batch(TINY)
        mask = jnp.zeros_like(old_lp)
        new_p, _, _, loss, _, _ = losses.train_step(
            TINY, tiny_params, m, v, 1.0, 1e-3, (tokens, mask, old_lp, ref_lp, adv),
            use_kernels=False,
        )
        assert float(loss) == 0.0
        for a, b in zip(tiny_params, new_p):
            np.testing.assert_array_equal(a, b)

    def test_adam_bias_correction(self):
        params = [jnp.ones((4,))]
        grads = [jnp.full((4,), 0.5)]
        m = [jnp.zeros((4,))]
        v = [jnp.zeros((4,))]
        hyper = losses.TrainHyper()
        new_p, _, _ = losses.adam_update(params, grads, m, v, 1.0, 0.1, hyper)
        # first step with bias correction moves by ~lr regardless of scale
        np.testing.assert_allclose(new_p[0], 1.0 - 0.1, rtol=1e-4)
