//! Bench: streaming generation (continuous batching) vs batch decode —
//! modeled and measured.
//!
//! Part 1 (always runs, deterministic, the CI perf gate's input): the
//! token-level cost-model comparison on the long-tail response-length
//! workload (`sim::streaming_rows`, same table as
//! `simulate --experiment streaming`). At every slot count, continuous
//! batching must deliver strictly higher modeled TPS than admission-
//! order batch decode — the tentpole's headline claim — and the slot
//! occupancies of both policies are recorded alongside.
//!
//! Part 2 (artifact-gated): a real-executor A/B on the tiny preset —
//! pipelined batch-decode vs `--gen-streaming` — printing walls and the
//! stream report (occupancy, TTFT, per-step retirement, KV deferrals).
//! Wall-clock numbers are informational (CPU testbed, no gate).
//!
//! `--json` emits the single-line summary for `ci/bench_gate.py`.

use mindspeed_rl::runtime::{artifact_dir, Engine};
use mindspeed_rl::sim::streaming_rows;
use mindspeed_rl::trainers::{run_grpo, GrpoConfig, PipelineMode};
use mindspeed_rl::util::bench::{BenchJson, Table};
use mindspeed_rl::util::cli::Args;
use mindspeed_rl::util::fmt_secs;

fn main() {
    let args = Args::from_env().unwrap();
    let json_mode = args.has("json");
    let mut json = BenchJson::new("continuous_batching");

    // ---- part 1: deterministic cost-model sweep (the gated metrics)
    let rows = streaming_rows(0);
    let mut t = Table::new(
        "Continuous batching vs batch decode — modeled TPS on the \
         long-tail workload (Qwen2.5-7B decode, exponential lengths)",
        &["slots", "stream TPS", "batch TPS", "speedup", "stream occ", "batch occ"],
    );
    for r in &rows {
        t.row(vec![
            r.slots.to_string(),
            format!("{:.1}", r.streaming_tps),
            format!("{:.1}", r.batch_tps),
            format!("{:.2}x", r.speedup),
            format!("{:.0}%", r.streaming_occupancy * 100.0),
            format!("{:.0}%", r.batch_occupancy * 100.0),
        ]);
    }
    if !json_mode {
        t.print();
    }
    for r in &rows {
        // the acceptance criterion, asserted here so the bench itself
        // fails loudly if the model ever loses the streaming advantage
        assert!(
            r.speedup > 1.0,
            "streaming must strictly beat batch decode at {} slots: {:.3}x",
            r.slots,
            r.speedup
        );
        json.higher(&format!("streaming_tps_s{}", r.slots), r.streaming_tps);
        json.higher(&format!("streaming_over_batch_speedup_s{}", r.slots), r.speedup);
        json.higher(&format!("streaming_occupancy_s{}", r.slots), r.streaming_occupancy);
        json.info(&format!("batch_tps_s{}", r.slots), r.batch_tps);
        json.info(&format!("batch_occupancy_s{}", r.slots), r.batch_occupancy);
    }

    // ---- part 2: real-executor A/B (informational; needs artifacts)
    match Engine::load(artifact_dir("tiny")) {
        Ok(engine) => {
            let base = GrpoConfig {
                iterations: 4,
                prompts_per_iter: 8,
                group_size: 4,
                max_new_tokens: 6,
                nodes: 4,
                pipeline: PipelineMode::Pipelined,
                max_inflight_iters: 2,
                log_every: 0,
                ..Default::default()
            };
            let configs: Vec<(&str, GrpoConfig)> = vec![
                ("batch decode", base.clone()),
                (
                    "streaming (chunk=2, blk=8)",
                    GrpoConfig {
                        gen_streaming: true,
                        prefill_chunk: 2,
                        kv_block_tokens: 8,
                        ..base.clone()
                    },
                ),
            ];
            for (i, (name, cfg)) in configs.into_iter().enumerate() {
                let t0 = std::time::Instant::now();
                let report = run_grpo(&engine, &cfg).unwrap();
                let wall = t0.elapsed().as_secs_f64();
                json.info(&format!("real_wall_secs_cfg{i}"), wall);
                let gs = &report.pipeline.gen_stream;
                if cfg.gen_streaming {
                    assert!(gs.active(), "streaming run must record a stream report");
                    assert_eq!(gs.kv_deferrals, 0, "sized pool must never defer");
                    json.info("real_stream_occupancy", gs.occupancy());
                    // a mean over zero sequences is n/a, not a number —
                    // emit the metric only when it exists so the gate
                    // baseline never records a NaN placeholder
                    if let Some(ttft) = gs.mean_ttft_steps() {
                        json.info("real_stream_ttft_steps", ttft);
                    }
                }
                if !json_mode {
                    println!("\n{name:<28} wall={}", fmt_secs(wall));
                    println!("  {}", report.pipeline.summary());
                }
            }
        }
        Err(e) => {
            if !json_mode {
                eprintln!("skipping real-executor A/B (run `make artifacts`): {e}");
            }
        }
    }

    if json_mode {
        json.emit().unwrap();
    }
}
