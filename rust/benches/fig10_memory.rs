//! Bench: Fig. 10 — memory profiling of the resharding flow
//! (Qwen2.5-32B-shaped weights, TP8DP2 → TP4DP4 on 16 devices).
//!
//! The paper's claim: the allgather–swap technique releases ~8 GB of
//! redundant memory per device for the KV cache. We run both reshard
//! implementations over the tracked memory substrate at true 32B sizes
//! (metadata-only payloads) and print per-device residency + the released
//! headroom, plus a timed small-scale run with real payloads.

use std::sync::Arc;

use mindspeed_rl::memory::MemoryPool;
use mindspeed_rl::parallel::{ModelWeights, ParallelLayout};
use mindspeed_rl::resharding::{eq3_redundant_bytes, Resharder};
use mindspeed_rl::transfer_dock::NetworkModel;
use mindspeed_rl::util::bench::{bench, BenchJson, Table};
use mindspeed_rl::util::cli::Args;
use mindspeed_rl::util::fmt_bytes;

fn main() {
    let json_mode = Args::from_env().unwrap().has("json");
    // Qwen2.5-32B dims at bf16-equivalent byte sizes: our payload type is
    // f32 while the paper reshards bf16, so 32 "layers" of the 64-layer
    // model make the BYTES match (TW ≈ 63 GiB, like the real model)
    let weights = ModelWeights::dense_like(32, 5120, 27648);
    let update = ParallelLayout::dense(8, 1, 2);
    let gen = ParallelLayout::dense(4, 1, 4);
    println!(
        "weights: total={} (tp={} common={}), reshard {} -> {}",
        fmt_bytes(weights.total_bytes()),
        fmt_bytes(weights.tp_bytes()),
        fmt_bytes(weights.common_bytes()),
        update.describe(),
        gen.describe()
    );

    let cap = 128u64 << 30;
    let mk = || {
        Resharder::new(
            weights.clone(),
            update,
            gen,
            cap,
            16 * cap,
            8,
            NetworkModel::paper(),
        )
        .unwrap()
    };

    let mut naive = mk();
    let rep_naive = naive.reshard_naive().unwrap();
    let mut swap = mk();
    let rep_swap = swap.reshard_allgather_swap().unwrap();

    let mut t = Table::new(
        "Fig. 10 — resharding memory (per-device, 32B dense)",
        &["technique", "redundant", "post live", "peak", "KV headroom", "t_total"],
    );
    for (rep, r) in [(&rep_naive, &naive), (&rep_swap, &swap)] {
        t.row(vec![
            rep.technique.clone(),
            fmt_bytes(rep.redundant_bytes / update.world() as u64),
            fmt_bytes(rep.post_device_bytes),
            fmt_bytes(rep.peak_device_bytes),
            fmt_bytes(r.kv_headroom()[0]),
            mindspeed_rl::util::fmt_secs(rep.t_total),
        ]);
    }
    t.print();
    let released = swap.kv_headroom()[0].saturating_sub(naive.kv_headroom()[0]);
    println!(
        "\nreleased for KV cache: {} per device (paper: ~8 GB); Eq.(3) total: {}",
        fmt_bytes(released),
        fmt_bytes(eq3_redundant_bytes(&weights, &update, &gen))
    );

    if json_mode {
        // tracked-pool byte counts are deterministic: gate the released
        // KV headroom and the swap flow's peak residency
        let mut json = BenchJson::new("fig10_memory");
        json.higher("released_kv_bytes_per_dev", released as f64);
        json.lower("swap_peak_device_bytes", rep_swap.peak_device_bytes as f64);
        json.lower(
            "naive_redundant_bytes_per_dev",
            (rep_naive.redundant_bytes / update.world() as u64) as f64,
        );
        json.emit().unwrap();
        return;
    }

    // timed: real-payload reshard at small scale (correctness-bearing path)
    let small = ModelWeights::dense_like(8, 512, 1024).with_test_data(3);
    println!("\n{}", mindspeed_rl::util::bench::header());
    let r = bench("reshard_allgather_swap (real payload, 8L d512)", 1, 10, || {
        let mut rs = Resharder::new(
            small.clone(),
            ParallelLayout::dense(4, 1, 2),
            ParallelLayout::dense(2, 1, 4),
            1 << 30,
            16 << 30,
            8,
            NetworkModel::paper(),
        )
        .unwrap();
        rs.reshard_allgather_swap().unwrap();
        rs.verify_gen_shards().unwrap();
    });
    println!("{}", r.line());
    let r = bench("reshard_naive          (real payload, 8L d512)", 1, 10, || {
        let mut rs = Resharder::new(
            small.clone(),
            ParallelLayout::dense(4, 1, 2),
            ParallelLayout::dense(2, 1, 4),
            1 << 30,
            16 << 30,
            8,
            NetworkModel::paper(),
        )
        .unwrap();
        rs.reshard_naive().unwrap();
        rs.verify_gen_shards().unwrap();
    });
    println!("{}", r.line());

    // --- weight-channel retention: the resharding flow publishes its
    // generation-layout slices straight into the versioned WeightBus
    // (shard-level, content-deduplicated retention charged to a tracked
    // pool). Each simulated iteration trains ONE layer's attention
    // weight, reshards, and republishes — retention grows by that
    // weight's slices only, vs a full-copy ring growing by a whole model
    // per version.
    println!("\nweight-bus retention (reshard→bus publish, one trained weight per iter):");
    let mut rs = Resharder::new(
        small.clone(),
        ParallelLayout::dense(4, 1, 2),
        ParallelLayout::dense(2, 1, 4),
        1 << 30,
        16 << 30,
        8,
        NetworkModel::paper(),
    )
    .unwrap();
    rs.reshard_allgather_swap().unwrap();
    let pool = Arc::new(MemoryPool::unbounded("weightbus"));
    let bus = rs.seed_weight_bus(8, Some(Arc::clone(&pool))).unwrap();
    let mut t = Table::new(
        "bus retention vs full-copy ring",
        &["iter", "versions", "unique shards", "retained", "full-copy equiv", "dedup"],
    );
    for iter in 0..5 {
        rs.swap_back_h2d().unwrap();
        rs.perturb_weight(&format!("l{}.attn", iter % 8), 0.01).unwrap();
        rs.reshard_allgather_swap_into(&bus).unwrap();
        let s = bus.retention_stats();
        t.row(vec![
            iter.to_string(),
            s.versions.to_string(),
            s.unique_shards.to_string(),
            fmt_bytes(s.retained_bytes),
            fmt_bytes(s.naive_equivalent_bytes),
            format!("{:.2}x", s.dedup_ratio()),
        ]);
    }
    t.print();
    println!(
        "pool-charged bus bytes: {} (peak {}) — equals Σ live unique shard bytes by construction",
        fmt_bytes(pool.live_bytes()),
        fmt_bytes(pool.peak_bytes())
    );
}
