//! Bench: Fig. 11 — DeepSeek-R1-MoE-671B RL training on 384 NPUs
//! (simulated) plus a real MoE reward-curve proxy on the moe_tiny PJRT
//! model (the paper's reward curve shape at laptop scale).

use mindspeed_rl::runtime::{artifact_dir, Engine};
use mindspeed_rl::sim::fig11_series;
use mindspeed_rl::trainers::{run_grpo, GrpoConfig};
use mindspeed_rl::util::bench::{BenchJson, Table};
use mindspeed_rl::util::cli::Args;

fn main() {
    let json_mode = Args::from_env().unwrap().has("json");
    // simulated throughput series
    let series = fig11_series(100, 0);
    if json_mode {
        // the fixed-seed simulated series is deterministic end to end
        let mean = series.iter().map(|(_, t)| t).sum::<f64>() / series.len() as f64;
        let min = series.iter().map(|(_, t)| *t).fold(f64::INFINITY, f64::min);
        let mut json = BenchJson::new("fig11_moe");
        json.higher("mean_tps_384npu", mean);
        json.higher("min_tps_384npu", min);
        json.emit().unwrap();
        return;
    }
    let mut t = Table::new(
        "Fig. 11 — DeepSeek-R1-671B @384 NPUs (MSRL, simulated)",
        &["iteration", "TPS"],
    );
    for (i, tps) in series.iter().step_by(10) {
        t.row(vec![i.to_string(), format!("{tps:.0}")]);
    }
    t.print();
    let mean = series.iter().map(|(_, t)| t).sum::<f64>() / series.len() as f64;
    let min = series.iter().map(|(_, t)| *t).fold(f64::INFINITY, f64::min);
    let max = series.iter().map(|(_, t)| *t).fold(0.0f64, f64::max);
    println!("TPS: min={min:.0} max={max:.0} mean={mean:.0}  (paper: fluctuates 200–250)");

    // real MoE training proxy: reward must rise on moe_tiny
    let engine = match Engine::load(artifact_dir("moe_tiny")) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("skipping real MoE proxy (run `make artifacts`): {e}");
            return;
        }
    };
    let report = run_grpo(
        &engine,
        &GrpoConfig {
            iterations: 8,
            prompts_per_iter: 8,
            group_size: 4,
            max_new_tokens: 4,
            log_every: 0,
            ..Default::default()
        },
    )
    .unwrap();
    let mut t = Table::new(
        "real MoE proxy (moe_tiny, top-2 of 4 experts, GMM kernel path)",
        &["iteration", "reward", "loss"],
    );
    for m in &report.iterations {
        t.row(vec![
            m.iter.to_string(),
            format!("{:.3}", m.reward_mean),
            format!("{:+.4}", m.loss),
        ]);
    }
    t.print();
}
