//! Bench: Fig. 11 — DeepSeek-R1-MoE-671B RL training on 384 NPUs
//! (simulated), a real MoE reward-curve proxy on the moe_tiny PJRT
//! model, and the expert-parallel resharding differential the ROADMAP
//! asks for: memory (peak/post/host) and reshard bytes for
//! dense-equivalent vs expert-sharded retention on the weight bus,
//! over an *asymmetric* EP train→infer pair (EP8 update → EP4 gen).
//!
//! JSON mode gates the differential too (it is deterministic byte
//! accounting over fixed-seed payloads) — the gate pins that
//! expert-sharded retention stays strictly below the dense-equivalent
//! full-copy retention.

use std::sync::Arc;

use mindspeed_rl::memory::MemoryPool;
use mindspeed_rl::parallel::{ModelWeights, ParallelLayout};
use mindspeed_rl::resharding::Resharder;
use mindspeed_rl::runtime::{artifact_dir, Engine};
use mindspeed_rl::sim::fig11_series;
use mindspeed_rl::trainers::{run_grpo, GrpoConfig};
use mindspeed_rl::transfer_dock::NetworkModel;
use mindspeed_rl::util::bench::{BenchJson, Table};
use mindspeed_rl::util::cli::Args;
use mindspeed_rl::util::fmt_bytes;

const GIB: u64 = 1 << 30;

struct MoeDifferential {
    swap_peak: u64,
    swap_post: u64,
    swap_host: u64,
    naive_redundant: u64,
    expert_stale: u64,
    expert_moved: u64,
    /// bytes the bus retained for the post-train publish (changed
    /// experts' slices only — shard-level dedup)
    expert_retained: u64,
    /// what a full-copy (dense-equivalent) retention would have added
    /// for the same publish: one more full generation layout
    dense_equiv_retained: u64,
}

/// The differential: asymmetric EP across the train→infer boundary —
/// EP8 update (fractional placement: each of 4 experts half-resident on
/// two EP ranks) → EP4 gen (whole experts) on 8 devices. One "train
/// step" touches 2 of 8 expert tensors; the reshard republishes into
/// the bus and only those experts' slices may mint retention.
fn moe_reshard_differential() -> MoeDifferential {
    let update = ParallelLayout::new(2, 1, 4, 8);
    let gen = ParallelLayout::new(1, 1, 8, 4);
    let mk = || ModelWeights::moe_like(2, 64, 128, 4).with_test_data(11);
    let mut rs =
        Resharder::new(mk(), update, gen, GIB, 64 * GIB, 8, NetworkModel::paper()).unwrap();
    rs.reshard_allgather_swap().unwrap();
    rs.verify_gen_shards().unwrap();
    let pool = Arc::new(MemoryPool::unbounded("weightbus"));
    let bus = rs.seed_weight_bus(4, Some(Arc::clone(&pool))).unwrap();
    rs.swap_back_h2d().unwrap();

    rs.perturb_weight("l0.expert1", 0.5).unwrap();
    rs.perturb_weight("l1.expert2", 0.5).unwrap();
    let before = bus.retained_bytes();
    let (rep, _v) = rs.reshard_allgather_swap_into(&bus).unwrap();
    rs.verify_gen_shards().unwrap();
    let expert_retained = bus.retained_bytes() - before;
    assert_eq!(pool.live_bytes(), bus.retained_bytes(), "bus pool accounting imbalance");
    assert!(
        expert_retained < rep.bus_version_bytes,
        "expert-sharded retention ({expert_retained}) must stay strictly below the \
         dense-equivalent full copy ({})",
        rep.bus_version_bytes
    );

    // the naive flow over the same pair, for the redundancy columns
    let mut naive =
        Resharder::new(mk(), update, gen, GIB, 64 * GIB, 8, NetworkModel::paper()).unwrap();
    let rep_n = naive.reshard_naive().unwrap();
    naive.verify_gen_shards().unwrap();

    MoeDifferential {
        swap_peak: rep.peak_device_bytes,
        swap_post: rep.post_device_bytes,
        swap_host: rep.host_bytes,
        naive_redundant: rep_n.redundant_bytes,
        expert_stale: rep_n.expert_redundant_bytes,
        expert_moved: rep.expert_bytes_moved,
        expert_retained,
        dense_equiv_retained: rep.bus_version_bytes,
    }
}

fn mib(b: u64) -> f64 {
    b as f64 / (1u64 << 20) as f64
}

fn main() {
    let json_mode = Args::from_env().unwrap().has("json");
    // simulated throughput series (fixed seed: deterministic end to end)
    let series = fig11_series(100, 0);
    let mean = series.iter().map(|(_, t)| t).sum::<f64>() / series.len() as f64;
    let min = series.iter().map(|(_, t)| *t).fold(f64::INFINITY, f64::min);
    let diff = moe_reshard_differential();
    if json_mode {
        let mut json = BenchJson::new("fig11_moe");
        json.higher("mean_tps_384npu", mean);
        json.higher("min_tps_384npu", min);
        json.lower("swap_peak_mib", mib(diff.swap_peak));
        json.lower("swap_post_mib", mib(diff.swap_post));
        json.lower("swap_host_mib", mib(diff.swap_host));
        json.lower("naive_redundant_mib", mib(diff.naive_redundant));
        json.lower("expert_stale_mib", mib(diff.expert_stale));
        json.lower("expert_retained_mib", mib(diff.expert_retained));
        json.higher(
            "retention_savings",
            mib(diff.dense_equiv_retained) / mib(diff.expert_retained).max(1e-9),
        );
        json.info("dense_equiv_retained_mib", mib(diff.dense_equiv_retained));
        json.info("expert_moved_mib", mib(diff.expert_moved));
        json.emit().unwrap();
        return;
    }
    let mut t = Table::new(
        "Fig. 11 — DeepSeek-R1-671B @384 NPUs (MSRL, simulated)",
        &["iteration", "TPS"],
    );
    for (i, tps) in series.iter().step_by(10) {
        t.row(vec![i.to_string(), format!("{tps:.0}")]);
    }
    t.print();
    let max = series.iter().map(|(_, t)| *t).fold(0.0f64, f64::max);
    println!("TPS: min={min:.0} max={max:.0} mean={mean:.0}  (paper: fluctuates 200–250)");

    let mut t = Table::new(
        "expert-parallel reshard differential (EP8 update -> EP4 gen, 4 experts, 8 devices)",
        &["metric", "bytes"],
    );
    t.row(vec!["swap peak/dev".into(), fmt_bytes(diff.swap_peak)]);
    t.row(vec!["swap post/dev".into(), fmt_bytes(diff.swap_post)]);
    t.row(vec!["swap host parked".into(), fmt_bytes(diff.swap_host)]);
    t.row(vec!["naive redundant".into(), fmt_bytes(diff.naive_redundant)]);
    t.row(vec!["  of which stale experts".into(), fmt_bytes(diff.expert_stale)]);
    t.row(vec!["expert bytes allgathered".into(), fmt_bytes(diff.expert_moved)]);
    t.row(vec![
        "bus retention (expert-sharded)".into(),
        fmt_bytes(diff.expert_retained),
    ]);
    t.row(vec![
        "bus retention (dense-equivalent)".into(),
        fmt_bytes(diff.dense_equiv_retained),
    ]);
    t.print();
    println!(
        "retention savings: {:.1}x (touched 2 of 8 expert tensors)",
        diff.dense_equiv_retained as f64 / diff.expert_retained.max(1) as f64
    );

    // real MoE training proxy: reward must rise on moe_tiny
    let engine = match Engine::load(artifact_dir("moe_tiny")) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("skipping real MoE proxy (run `make artifacts`): {e}");
            return;
        }
    };
    let report = run_grpo(
        &engine,
        &GrpoConfig {
            iterations: 8,
            prompts_per_iter: 8,
            group_size: 4,
            max_new_tokens: 4,
            log_every: 0,
            ..Default::default()
        },
    )
    .unwrap();
    let mut t = Table::new(
        "real MoE proxy (moe_tiny, top-2 of 4 experts, GMM kernel path)",
        &["iteration", "reward", "loss"],
    );
    for m in &report.iterations {
        t.row(vec![
            m.iter.to_string(),
            format!("{:.3}", m.reward_mean),
            format!("{:+.4}", m.loss),
        ]);
    }
    t.print();
}
