//! Bench: Fig. 7 — end-to-end throughput of OpenRLHF / VeRL / MSRLP /
//! MSRL on the paper's three models at 16 NPUs, plus a *real* (not
//! simulated) A/B of the dock vs replay buffer on the tiny PJRT model.

use std::sync::Arc;

use mindspeed_rl::runtime::{artifact_dir, Engine};
use mindspeed_rl::sim::fig7_rows;
use mindspeed_rl::trainers::{run_grpo_on_flow, GrpoConfig};
use mindspeed_rl::transfer_dock::{DockTopology, ReplayBuffer, SampleFlow, TransferDock};
use mindspeed_rl::util::bench::{BenchJson, Table};
use mindspeed_rl::util::cli::Args;

fn main() {
    let json_mode = Args::from_env().unwrap().has("json");
    // simulated cluster (the paper's configuration)
    let mut t = Table::new(
        "Fig. 7 — end-to-end TPS, 16 NPUs (G=256 N=16 PL=2K SL=8K)",
        &["model", "system", "TPS", "vs OpenRLHF"],
    );
    let rows = fig7_rows();
    for r in &rows {
        t.row(vec![
            r.model.name().into(),
            r.system.name().into(),
            format!("{:.0}", r.tps),
            format!("{:.2}x", r.speedup_vs_openrlhf),
        ]);
    }
    if json_mode {
        // deterministic cost-model headline: MSRL on Qwen2.5-7B
        let mut json = BenchJson::new("fig7_end_to_end");
        if let Some(msrl) = rows
            .iter()
            .find(|r| r.system.name() == "MSRL" && r.model.name().contains("7B"))
        {
            json.higher("msrl_tps_qwen7b", msrl.tps);
            json.higher("msrl_speedup_vs_openrlhf_qwen7b", msrl.speedup_vs_openrlhf);
        }
        json.emit().unwrap();
        return;
    }
    t.print();

    // real PJRT run, dock vs replay buffer, identical math (same seed)
    let engine = match Engine::load(artifact_dir("tiny")) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("skipping real-engine A/B (run `make artifacts`): {e}");
            return;
        }
    };
    let cfg = GrpoConfig {
        iterations: 3,
        prompts_per_iter: 8,
        group_size: 4,
        max_new_tokens: 4,
        log_every: 0,
        nodes: 8,
        ..Default::default()
    };
    let mut t = Table::new(
        "real PJRT A/B (tiny model, 3 iterations)",
        &["dataflow", "wall/iter", "implied dispatch", "reward"],
    );
    for (name, flow) in [
        (
            "transfer_dock",
            Arc::new(TransferDock::new(DockTopology::spread(8))) as Arc<dyn SampleFlow>,
        ),
        ("replay_buffer", Arc::new(ReplayBuffer::new(0)) as Arc<dyn SampleFlow>),
    ] {
        let t0 = std::time::Instant::now();
        let report = run_grpo_on_flow(&engine, &cfg, flow.clone()).unwrap();
        let wall = t0.elapsed().as_secs_f64() / cfg.iterations as f64;
        let net = mindspeed_rl::transfer_dock::NetworkModel::paper();
        t.row(vec![
            name.into(),
            mindspeed_rl::util::fmt_secs(wall),
            mindspeed_rl::util::fmt_secs(flow.dispatch_secs(&net)),
            format!("{:.3}", report.iterations.last().unwrap().reward_mean),
        ]);
    }
    t.print();
}
