//! Bench: Fig. 9 — weak-scaling linearity of VeRL / MSRLB / MSRL
//! (64 prompts per node, 2 → 24 nodes), plus a measured scaling sweep of
//! the real transfer dock vs replay buffer under growing offered load,
//! and the sharded-controller dispatch sweep into the hundreds of nodes
//! (`--dock-shards`, simulate --experiment dispatch).

use mindspeed_rl::runtime::Tensor;
use mindspeed_rl::sim::{dispatch_rows, dispatch_rows_for, fig9_rows, SystemKind};
use mindspeed_rl::transfer_dock::{
    DockTopology, FieldKind, NetworkModel, ReplayBuffer, Sample, SampleFlow, Stage,
    TransferDock,
};
use mindspeed_rl::util::bench::{BenchJson, Table};
use mindspeed_rl::util::cli::Args;

/// Drive one "iteration" of sample flow with 64 prompts per node and
/// return the implied dispatch seconds (paper bandwidths).
fn implied_dispatch(flow: &dyn SampleFlow, nodes: usize) -> f64 {
    let n = 64 * nodes;
    let samples: Vec<Sample> = (0..n)
        .map(|i| Sample::new_prompt(u64::MAX, i as u64 / 8, format!("{i}+1="), 1))
        .collect();
    let idx = flow.put_samples(samples).unwrap();
    let metas = flow.request_ready(Stage::Generation, n).unwrap();
    // workers are spread over the nodes (DP), so fetches originate from
    // every node — the regime where centralization hurts
    for (i, m) in metas.iter().enumerate() {
        let _ = flow.fetch(i % nodes, &[*m]).unwrap();
    }
    for (i, &ix) in idx.iter().enumerate() {
        flow.store_generation(
            i % nodes,
            ix,
            vec![(FieldKind::Tokens, Tensor::i32(&[1024], vec![1; 1024]).unwrap())],
            "1".into(),
            2,
            1,
        )
        .unwrap();
    }
    for (i, &ix) in idx.iter().enumerate() {
        flow.store_fields(i % nodes, ix, vec![(FieldKind::OldLp, Tensor::zeros(&[1023]))])
            .unwrap();
        flow.retire(ix);
    }
    flow.dispatch_secs(&NetworkModel::paper())
}

fn main() {
    let json_mode = Args::from_env().unwrap().has("json");
    if json_mode {
        // deterministic gated metrics: cost-model linearity at the far
        // end of the sweep, and the *ledger-derived* (byte-count, not
        // wall-clock) dispatch of the real structures at 8 nodes
        let mut json = BenchJson::new("fig9_linearity");
        let rows = fig9_rows();
        let last = |k: SystemKind| rows.iter().filter(|r| r.system == k).last().unwrap().linearity;
        json.higher("msrl_linearity_24n", last(SystemKind::Msrl));
        let dock = TransferDock::new(DockTopology::spread(8));
        let d = implied_dispatch(&dock, 8);
        let rb = ReplayBuffer::new(0);
        let r = implied_dispatch(&rb, 8);
        json.lower("dock_dispatch_secs_8n", d);
        json.higher("rb_over_dock_dispatch_8n", r / d);
        // sharded controllers at the far end of the weak-scaling sweep:
        // dispatch must stay near-flat from 8 to 384 nodes (flatness is
        // a ratio ≥ 1; 1.0 would be perfectly linear scaling) while the
        // centralized buffer's gap keeps widening
        let sweep = dispatch_rows_for(&[8, 384]).unwrap();
        let (base, top) = (&sweep[0], &sweep[1]);
        json.lower("sharded_dispatch_secs_384n", top.sharded_secs);
        json.lower("sharded_flatness_384n_over_8n", top.sharded_secs / base.sharded_secs);
        json.higher("central_over_sharded_384n", top.central_secs / top.sharded_secs);
        json.higher("sharded_linearity_384n", top.sharded_linearity);
        json.emit().unwrap();
        return;
    }
    let mut t = Table::new(
        "Fig. 9 — simulated linearity (paper @192 NPUs: MSRL 81.1 / MSRLB 61.9 / VeRL 40.4)",
        &["system", "nodes", "NPUs", "TPS/dev", "linearity"],
    );
    for r in fig9_rows() {
        t.row(vec![
            r.system.name().into(),
            r.nodes.to_string(),
            r.npus.to_string(),
            format!("{:.1}", r.tps_per_device),
            format!("{:.1}%", r.linearity * 100.0),
        ]);
    }
    t.print();

    // measured: per-prompt dispatch cost of the real dataflows as load
    // and node count grow together (weak scaling)
    let mut t = Table::new(
        "measured dataflow weak scaling (real structures, paper bandwidths)",
        &["nodes", "prompts", "dock disp", "dock/prompt", "rb disp", "rb/prompt"],
    );
    let mut base: Option<(f64, f64)> = None;
    for nodes in [2usize, 4, 8, 16, 24] {
        let dock = TransferDock::new(DockTopology::spread(nodes));
        let d = implied_dispatch(&dock, nodes);
        let rb = ReplayBuffer::new(0);
        let r = implied_dispatch(&rb, nodes);
        let n = (64 * nodes) as f64;
        base.get_or_insert((d / n, r / n));
        t.row(vec![
            nodes.to_string(),
            format!("{}", 64 * nodes),
            mindspeed_rl::util::fmt_secs(d),
            format!("{:.2}µs", d / n * 1e6),
            mindspeed_rl::util::fmt_secs(r),
            format!("{:.2}µs", r / n * 1e6),
        ]);
    }
    t.print();
    println!(
        "\n(dock per-prompt dispatch stays ~flat; the centralized buffer's grows\n\
         with cluster size — the mechanism behind the Fig. 9 linearity gap)"
    );

    // sharded controllers into the hundreds of nodes: the full
    // central-vs-sharded sweep behind `simulate --experiment dispatch`
    let mut t = Table::new(
        "sharded dock controllers — dispatch weak scaling to 384 nodes (K = nodes)",
        &["nodes", "central (s)", "dock K=1 (s)", "dock K=n (s)", "central lin", "sharded lin"],
    );
    for r in dispatch_rows().unwrap() {
        t.row(vec![
            r.nodes.to_string(),
            format!("{:.2}", r.central_secs),
            format!("{:.3}", r.dock_secs),
            format!("{:.3}", r.sharded_secs),
            format!("{:.1}%", r.central_linearity * 100.0),
            format!("{:.1}%", r.sharded_linearity * 100.0),
        ]);
    }
    t.print();

    // sanity: ordering must match the paper
    let rows = fig9_rows();
    let last = |k: SystemKind| rows.iter().filter(|r| r.system == k).last().unwrap().linearity;
    assert!(last(SystemKind::Msrl) > last(SystemKind::Msrlb));
    assert!(last(SystemKind::Msrlb) > last(SystemKind::Verl));
}
