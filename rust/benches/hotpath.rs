//! Bench: L3 hot-path micro-benchmarks (the §Perf working set).
//!
//! Times the coordinator-side operations that sit on the per-iteration
//! critical path, independent of XLA compute: transfer-dock round trips,
//! tensor↔literal conversion, batch assembly, sampling, advantage math.

use mindspeed_rl::rewards::group_advantages;
use mindspeed_rl::runtime::Tensor;
use mindspeed_rl::transfer_dock::{
    DockTopology, FieldKind, Sample, SampleFlow, Stage, TransferDock,
};
use mindspeed_rl::util::bench::{bench, header, BenchJson};
use mindspeed_rl::util::cli::Args;
use mindspeed_rl::util::rng::Rng;

fn main() {
    if Args::from_env().unwrap().has("json") {
        // gated metric: the dock round trip's *ledger bytes* — a
        // deterministic function of the dataflow code, unlike the timed
        // loops below (which stay out of the gate)
        let mut json = BenchJson::new("hotpath");
        let dock = TransferDock::new(DockTopology::spread(8));
        let samples: Vec<Sample> = (0..256)
            .map(|i| Sample::new_prompt(u64::MAX, i / 8, format!("{i}+1="), 1))
            .collect();
        let idx = dock.put_samples(samples).unwrap();
        let metas = dock.request_ready(Stage::Generation, 256).unwrap();
        let _ = dock.fetch(0, &metas).unwrap();
        for &i in &idx {
            dock.store_generation(
                0,
                i,
                vec![(FieldKind::Tokens, Tensor::i32(&[256], vec![1; 256]).unwrap())],
                "1".into(),
                1,
                1,
            )
            .unwrap();
            dock.retire(i);
        }
        let led = dock.ledger();
        json.lower("dock_roundtrip_256_total_bytes", led.total_bytes() as f64);
        json.lower(
            "dock_roundtrip_256_round_trips",
            (led.requests + led.local_requests) as f64,
        );
        json.emit().unwrap();
        return;
    }
    println!("{}", header());

    // tensor → literal → tensor round trip (the PJRT boundary cost)
    for n in [1usize << 10, 1 << 16, 1 << 20] {
        let t = Tensor::f32(&[n], vec![1.0; n]).unwrap();
        let r = bench(&format!("tensor<->literal {n} f32"), 3, 30, || {
            let lit = t.to_literal().unwrap();
            let back = Tensor::from_literal(&lit).unwrap();
            std::hint::black_box(back);
        });
        println!("{}", r.line());
    }

    // transfer dock full round trip per sample
    let r = bench("dock round-trip 256 samples (1KiB payloads)", 2, 20, || {
        let dock = TransferDock::new(DockTopology::spread(8));
        let samples: Vec<Sample> = (0..256)
            .map(|i| Sample::new_prompt(u64::MAX, i / 8, format!("{i}+1="), 1))
            .collect();
        let idx = dock.put_samples(samples).unwrap();
        let metas = dock.request_ready(Stage::Generation, 256).unwrap();
        let _ = dock.fetch(0, &metas).unwrap();
        for &i in &idx {
            dock.store_generation(
                0,
                i,
                vec![(FieldKind::Tokens, Tensor::i32(&[256], vec![1; 256]).unwrap())],
                "1".into(),
                1,
                1,
            )
            .unwrap();
            dock.retire(i);
        }
    });
    println!("{}", r.line());

    // sampling from logits (per decode step, per slot)
    let mut rng = Rng::new(0);
    let logits: Vec<f32> = (0..64).map(|i| (i as f32 * 0.37).sin()).collect();
    let params = mindspeed_rl::generation::SamplingParams::default();
    let r = bench("sample 64-logit row x1000", 3, 30, || {
        for _ in 0..1000 {
            std::hint::black_box(params.sample(&logits, &mut rng));
        }
    });
    println!("{}", r.line());

    // group advantage math at update-batch scale
    let rewards: Vec<f32> = (0..4096).map(|i| (i % 3) as f32 * 0.5).collect();
    let r = bench("group_advantages 4096 rewards (groups of 16)", 3, 50, || {
        std::hint::black_box(group_advantages(&rewards, 16));
    });
    println!("{}", r.line());
}
