//! Bench: multi-tenant scheduling — weighted-fair claims and per-tenant
//! quotas over one shared replica pool.
//!
//! Part 1 (always runs, deterministic, the CI perf gate's input): the
//! backlogged handout probe from `sim::tenancy_claim_probe` — 64 samples
//! striped across two tenants, 32 single-sample claims handed out by the
//! real dock's deficit-weighted round robin. The gated metric is the
//! worst Jain fairness index over weight-normalized claim shares across
//! several weight ratios (1.0 = the handout tracks the weights exactly).
//! Measuring over a *backlogged* dock is deliberate: a drain-to-
//! completion run claims every sample exactly once, so its cumulative
//! shares track the dataset split, not the weights.
//!
//! Part 2 (always runs, deterministic, gated): shared pool vs static
//! slices through the cost model (`sim::tenancy_pool_summary`) — a
//! short-prompt reward-model job and a long-CoT math job either carve
//! the 16-NPU cluster into halves or time-share the whole pool. The
//! gated `aggregate_tps_ratio` is the shared pool's speedup over the
//! slices; work conservation (an idle tenant's share is donated) must
//! keep it ≥ 1.
//!
//! Part 3 (always runs, informational): a full chaos drain with quotas —
//! deferral counts and losslessness under real backpressure. Counters
//! land in the ungated "info" bucket (thread interleaving varies them).
//!
//! `--json` emits the single-line summary for `ci/bench_gate.py`.

use mindspeed_rl::sim::chaos::{run_chaos, ChaosConfig};
use mindspeed_rl::sim::{tenancy_claim_probe, tenancy_pool_summary};
use mindspeed_rl::util::bench::{BenchJson, Table};
use mindspeed_rl::util::cli::Args;

/// Jain fairness index over weight-normalized claim shares: 1.0 means
/// every tenant's share/weight ratio is identical.
fn jain(shares: &[(u64, u32)]) -> f64 {
    let total: u64 = shares.iter().map(|(c, _)| c).sum();
    if total == 0 || shares.len() < 2 {
        return 1.0;
    }
    let x: Vec<f64> = shares
        .iter()
        .map(|&(c, w)| c as f64 / total as f64 / w as f64)
        .collect();
    let sum: f64 = x.iter().sum();
    let sq: f64 = x.iter().map(|v| v * v).sum();
    sum * sum / (x.len() as f64 * sq)
}

fn main() {
    let args = Args::from_env().unwrap();
    let json_mode = args.has("json");
    let mut json = BenchJson::new("multi_tenant");

    // ---- part 1: backlogged handout fairness (the gated metric)
    let mut t = Table::new(
        "Multi-tenant — DRR handout over a backlogged dock \
         (64 samples striped over 2 tenants, 32 single-sample claims)",
        &["weights", "claims t0/t1", "share t0", "fair t0", "Jain"],
    );
    let mut worst_jain = 1.0f64;
    for (w0, w1) in [(1u32, 1u32), (2, 1), (3, 1), (7, 1)] {
        let (c0, c1) = tenancy_claim_probe(w0, w1).unwrap();
        let j = jain(&[(c0, w0), (c1, w1)]);
        worst_jain = worst_jain.min(j);
        t.row(vec![
            format!("{w0}:{w1}"),
            format!("{c0}/{c1}"),
            format!("{:.0}%", c0 as f64 / (c0 + c1) as f64 * 100.0),
            format!("{:.0}%", w0 as f64 / (w0 + w1) as f64 * 100.0),
            format!("{j:.3}"),
        ]);
        json.info(&format!("claims_w{w0}_{w1}_t0"), c0 as f64);
        json.info(&format!("claims_w{w0}_{w1}_t1"), c1 as f64);
    }
    // the acceptance criterion, asserted here so the bench itself fails
    // loudly if the handout ever stops tracking the weights
    assert!(
        worst_jain >= 0.9,
        "weighted-fair handout must keep Jain >= 0.9 at every ratio: {worst_jain:.3}"
    );
    json.higher("jain_fairness", worst_jain);
    if !json_mode {
        t.print();
    }

    // ---- part 2: shared pool vs static slices (gated)
    let pool = tenancy_pool_summary();
    assert!(
        pool.speedup >= 1.0,
        "a work-conserving shared pool cannot lose to static slices: {pool:?}"
    );
    json.higher("aggregate_tps_ratio", pool.speedup);
    json.info("slice_wall_secs", pool.slice_wall_secs);
    json.info("shared_wall_secs", pool.shared_wall_secs);
    if !json_mode {
        println!(
            "\nshared pool vs static slices (short-prompt RM job + long-CoT math job, \
             16 NPUs): {:.0}s -> {:.0}s per iteration pair ({:.2}x)",
            pool.slice_wall_secs, pool.shared_wall_secs, pool.speedup
        );
    }

    // ---- part 3: quota backpressure through a full chaos drain (info)
    let cfg = ChaosConfig {
        iterations: 8,
        prompts_per_iter: 4,
        group_size: 2,
        max_inflight_iters: 8,
        lease_ticks: 256,
        seed: 42,
        tenants: 2,
        tenant_weights: vec![3, 1],
        tenant_quota_mb: vec![1, 1],
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let out = run_chaos(&cfg).unwrap();
    let wall = t0.elapsed().as_secs_f64();
    assert!(out.lossless(&cfg), "quota backpressure lost samples: {:?}", out.recovery);
    assert!(out.tenant_deferrals > 0, "the 1 MiB quotas must actually defer admissions");
    json.info("quota_wall_secs", wall);
    json.info("quota_deferrals", out.tenant_deferrals as f64);
    json.info("quota_retired", out.retired.len() as f64);
    if !json_mode {
        println!(
            "\nquota drain (2 tenants, 1 MiB each): retired={} deferrals={} \
             wall={wall:.3}s — lossless under backpressure",
            out.retired.len(),
            out.tenant_deferrals
        );
    }

    if json_mode {
        json.emit().unwrap();
    }
}
