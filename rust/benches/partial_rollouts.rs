//! Bench: partial rollouts — what resuming from persisted prefixes
//! saves over regenerating abandoned sequences from scratch.
//!
//! Part 1 (always runs, deterministic, the CI perf gate's input): a
//! closed-form recompute model over the long-tail response-length
//! workload (`sim::long_tail_lengths`, the CoT rollout regime). For a
//! sequence of length `L` abandoned after `t` decoded tokens, a
//! non-resumable pipeline regenerates all `t` tokens; a resumable one
//! replays only the tokens decoded since the last persisted segment,
//! `t mod cadence`. Averaging the abandonment point uniformly over the
//! sequence gives the exact expected recompute of both policies — no
//! randomness, no scheduler — and the saved fraction is gated at
//! several checkpoint cadences.
//!
//! Part 2 (always runs, informational): the real chaos harness under a
//! seeded kill plan with `partial_rollouts` on — actual persists,
//! resumes, saved/recomputed decode steps from the dock machinery. The
//! loss and recompute-bound invariants are asserted here so the bench
//! fails loudly if resumability ever regresses; the counters land in
//! the ungated "info" bucket (they depend on thread interleaving).
//!
//! Part 3 (artifact-gated): a real-executor run with `--gen-streaming
//! --partial-rollouts` under chaos kills, printing the partial-rollout
//! ledger. Wall-clock numbers are informational (CPU testbed, no gate).
//!
//! `--json` emits the single-line summary for `ci/bench_gate.py`.

use mindspeed_rl::runtime::{artifact_dir, Engine};
use mindspeed_rl::sim::chaos::{run_chaos, ChaosConfig, SYNTH_CKPT_STEPS};
use mindspeed_rl::sim::long_tail_lengths;
use mindspeed_rl::trainers::faults::FaultPlan;
use mindspeed_rl::trainers::{run_grpo, GrpoConfig, PipelineMode};
use mindspeed_rl::util::bench::{BenchJson, Table};
use mindspeed_rl::util::cli::Args;
use mindspeed_rl::util::fmt_secs;

/// Σ over t in 1..=len of (t mod cadence): the exact total recompute of
/// a resumable pipeline when the abandonment point sweeps the sequence.
fn resumable_recompute(len: u64, cadence: u64) -> u64 {
    let (c, l) = (cadence, len);
    let full_cycles = l / c;
    let rem = l % c;
    full_cycles * (c * (c - 1) / 2) + rem * (rem + 1) / 2
}

fn main() {
    let args = Args::from_env().unwrap();
    let json_mode = args.has("json");
    let mut json = BenchJson::new("partial_rollouts");

    // ---- part 1: closed-form recompute model (the gated metrics)
    let lengths = long_tail_lengths(512, 512.0, 8192, 0);
    let total_tokens: u64 = lengths.iter().sum();
    // one abandonment per sequence, point uniform over the sequence:
    // a non-resumable pipeline regenerates every decoded token
    let scratch_recompute: f64 =
        lengths.iter().map(|&l| (l + 1) as f64 / 2.0).sum();
    let mut t = Table::new(
        "Partial rollouts — expected recompute per abandonment \
         (long-tail workload: exp(512) capped 8K, 512 seqs)",
        &["ckpt cadence", "scratch tok", "resume tok", "saved"],
    );
    for cadence in [4u64, 8, 16] {
        let resume_recompute: f64 = lengths
            .iter()
            .map(|&l| resumable_recompute(l, cadence) as f64 / l as f64)
            .sum();
        let saved_frac = 1.0 - resume_recompute / scratch_recompute;
        t.row(vec![
            cadence.to_string(),
            format!("{:.0}", scratch_recompute),
            format!("{:.0}", resume_recompute),
            format!("{:.1}%", saved_frac * 100.0),
        ]);
        // the acceptance criterion, asserted here so the bench itself
        // fails loudly if resuming ever stops paying for itself
        assert!(
            saved_frac > 0.9,
            "resume must eliminate >90% of abandonment recompute at cadence {cadence}: \
             {saved_frac:.3}"
        );
        json.higher(&format!("resume_saved_frac_c{cadence}"), saved_frac);
        json.lower(&format!("resume_recompute_tokens_c{cadence}"), resume_recompute);
    }
    json.lower("scratch_recompute_tokens", scratch_recompute);
    json.info("workload_tokens", total_tokens as f64);
    if !json_mode {
        t.print();
    }

    // ---- part 2: real dock machinery under seeded kills (info)
    let cfg = ChaosConfig {
        iterations: 5,
        prompts_per_iter: 4,
        group_size: 2,
        gen_streaming: true,
        partial_rollouts: true,
        seed: 42,
        plan: FaultPlan { seed: 7, kill_rate: 0.4, ..Default::default() },
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let out = run_chaos(&cfg).unwrap();
    let wall = t0.elapsed().as_secs_f64();
    assert!(out.lossless(&cfg), "chaos run lost samples: {:?}", out.recovery);
    assert!(
        out.work.recomputed_steps() <= out.recovery.reclaimed * SYNTH_CKPT_STEPS,
        "recompute {} exceeds the checkpoint bound: {:?} {:?}",
        out.work.recomputed_steps(),
        out.work,
        out.recovery
    );
    json.info("chaos_wall_secs", wall);
    json.info("chaos_kills", out.recovery.kills as f64);
    json.info("chaos_persists", out.work.persists as f64);
    json.info("chaos_resumes", out.work.resumes as f64);
    json.info("chaos_saved_steps", out.work.saved_steps as f64);
    json.info("chaos_recomputed_steps", out.work.recomputed_steps() as f64);
    if !json_mode {
        println!(
            "\nchaos (kill=40%): kills={} persists={} resumes={} saved={} recomputed={} \
             wall={}",
            out.recovery.kills,
            out.work.persists,
            out.work.resumes,
            out.work.saved_steps,
            out.work.recomputed_steps(),
            fmt_secs(wall)
        );
    }

    // ---- part 3: real-executor run (informational; needs artifacts)
    match Engine::load(artifact_dir("tiny")) {
        Ok(engine) => {
            let cfg = GrpoConfig {
                iterations: 3,
                prompts_per_iter: 4,
                group_size: 2,
                max_new_tokens: 6,
                pipeline: PipelineMode::Pipelined,
                max_inflight_iters: 2,
                lease_ticks: 4,
                gen_streaming: true,
                partial_rollouts: true,
                chaos_kill_rate: 0.3,
                chaos_seed: 5,
                log_every: 0,
                ..Default::default()
            };
            let t0 = std::time::Instant::now();
            let report = run_grpo(&engine, &cfg).unwrap();
            let wall = t0.elapsed().as_secs_f64();
            let pr = &report.pipeline.partial;
            json.info("real_wall_secs", wall);
            json.info("real_persisted", pr.persisted as f64);
            json.info("real_resumed", pr.resumed as f64);
            json.info("real_saved_tokens", pr.saved_tokens as f64);
            if !json_mode {
                println!("\nreal executor wall={}", fmt_secs(wall));
                println!("  {}", report.pipeline.summary());
            }
        }
        Err(e) => {
            if !json_mode {
                eprintln!("skipping real-executor run (run `make artifacts`): {e}");
            }
        }
    }

    if json_mode {
        json.emit().unwrap();
    }
}
