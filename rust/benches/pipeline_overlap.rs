//! Bench: sync vs pipelined executor wall-clock on the tiny preset.
//!
//! A/Bs the two execution models of `trainers::executor` with everything
//! else fixed (same dock topology, same workload). The pipelined mode's
//! win comes from overlap: generation of iteration k+1 proceeds while
//! iteration k's old-logprob / reference / reward / update stages drain,
//! bounded by the `--max-inflight` staleness window. The per-stage busy
//! breakdown shows the overlap directly: busy seconds sum to more than
//! the wall clock.

use std::sync::Arc;

use mindspeed_rl::runtime::{artifact_dir, Engine};
use mindspeed_rl::trainers::{run_grpo_on_flow, GrpoConfig, PipelineMode};
use mindspeed_rl::transfer_dock::{DockTopology, SampleFlow, TransferDock};
use mindspeed_rl::util::bench::BenchJson;
use mindspeed_rl::util::cli::Args;
use mindspeed_rl::util::fmt_secs;

fn main() {
    let json_mode = Args::from_env().unwrap().has("json");
    let mut json = BenchJson::new("pipeline_overlap");
    let engine = match Engine::load(artifact_dir("tiny")) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("skipping pipeline A/B (run `make artifacts`): {e}");
            if json_mode {
                // artifact-dependent throughout: emit an empty (ungated)
                // summary so the CI merge step still sees the bench
                json.emit().unwrap();
            }
            return;
        }
    };
    let base = GrpoConfig {
        iterations: 6,
        prompts_per_iter: 8,
        group_size: 4,
        max_new_tokens: 6,
        nodes: 4,
        max_inflight_iters: 2,
        log_every: 0,
        ..Default::default()
    };

    println!("pipeline A/B (tiny preset, {} iters, G={} N={}):\n", base.iterations, base.prompts_per_iter, base.group_size);
    let mut walls = Vec::new();
    for mode in [PipelineMode::Sync, PipelineMode::Pipelined] {
        let cfg = GrpoConfig { pipeline: mode, ..base.clone() };
        let flow: Arc<dyn SampleFlow> =
            Arc::new(TransferDock::new(DockTopology::spread(cfg.nodes)));
        let t0 = std::time::Instant::now();
        let report = run_grpo_on_flow(&engine, &cfg, flow).unwrap();
        let wall = t0.elapsed().as_secs_f64();
        walls.push(wall);
        json.info(&format!("{}_wall_secs", mode.name()), wall);
        json.info(
            &format!("{}_overlap_ratio", mode.name()),
            report.pipeline.overlap_ratio(),
        );
        json.info(
            &format!("{}_bus_retained_bytes", mode.name()),
            report.pipeline.bus.retained_bytes as f64,
        );
        println!(
            "{:<10} wall={}  reward {:.3} → {:.3}",
            mode.name(),
            fmt_secs(wall),
            report.iterations.first().map(|m| m.reward_mean).unwrap_or(0.0),
            report.iterations.last().map(|m| m.reward_mean).unwrap_or(0.0),
        );
        println!("           {}", report.pipeline.summary());
        let lag = report.pipeline.lag_total();
        println!(
            "           busy total={} ({:.2}x the wall clock), behavior-policy lag mean={:.2} max={} publishes",
            fmt_secs(report.pipeline.busy_total()),
            report.pipeline.overlap_ratio(),
            lag.mean(),
            lag.max,
        );
        let bus = &report.pipeline.bus;
        if bus.versions > 0 {
            println!(
                "           weight bus: {} versions over {} unique shards — retained {} (peak {}), full-copy ring would hold {} ({:.2}x dedup)",
                bus.versions,
                bus.unique_shards,
                mindspeed_rl::util::fmt_bytes(bus.retained_bytes),
                mindspeed_rl::util::fmt_bytes(bus.peak_retained_bytes),
                mindspeed_rl::util::fmt_bytes(bus.naive_equivalent_bytes),
                bus.dedup_ratio(),
            );
        }
        println!();
    }
    let (sync_wall, pipe_wall) = (walls[0], walls[1]);
    println!(
        "pipelined / sync wall-clock = {:.2} ({})",
        pipe_wall / sync_wall,
        if pipe_wall < sync_wall { "pipelined wins" } else { "sync wins" }
    );
    if json_mode {
        json.info("pipelined_over_sync_wall", pipe_wall / sync_wall);
        json.emit().unwrap();
    }
}
