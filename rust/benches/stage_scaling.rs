//! Bench: elastic data-parallel stage replicas — modeled and measured.
//!
//! Part 1 (always runs, deterministic, the CI perf gate's input): the
//! cost-model sweep of generation replica counts on the Qwen2.5-7B
//! long-CoT configuration (`sim::scaling_rows`, same table as
//! `simulate --experiment scaling`). Each added generation replica must
//! strictly raise modeled throughput while generation stays the binding
//! stage — the tentpole's headline claim.
//!
//! Part 2 (artifact-gated): a real-executor A/B on the tiny preset —
//! single-replica pipelined vs `--stage-replicas gen=2,logprob=2` vs
//! autoscaled — printing walls, replica-aware utilization, and the
//! scaling report. Wall-clock numbers are informational (CPU testbed,
//! no gate).
//!
//! `--json` emits the single-line summary for `ci/bench_gate.py`.

use std::sync::Arc;

use mindspeed_rl::runtime::{artifact_dir, Engine};
use mindspeed_rl::sim::scaling_rows;
use mindspeed_rl::trainers::{
    run_grpo_on_flow, GrpoConfig, PipelineMode, StageReplicas,
};
use mindspeed_rl::transfer_dock::{DockTopology, SampleFlow, TransferDock};
use mindspeed_rl::util::bench::{BenchJson, Table};
use mindspeed_rl::util::cli::Args;
use mindspeed_rl::util::fmt_secs;

fn main() {
    let args = Args::from_env().unwrap();
    let json_mode = args.has("json");
    let mut json = BenchJson::new("stage_scaling");

    // ---- part 1: deterministic cost-model sweep (the gated metrics)
    let rows = scaling_rows();
    let mut t = Table::new(
        "Elastic stage replicas — modeled TPS vs generation replicas \
         (Qwen2.5-7B long-CoT, 16 NPUs, MSRL, logprob=2)",
        &["gen replicas", "gen (s)", "wall (s)", "TPS", "speedup"],
    );
    for r in &rows {
        t.row(vec![
            r.gen_replicas.to_string(),
            format!("{:.0}", r.gen_secs),
            format!("{:.0}", r.wall_secs),
            format!("{:.1}", r.tps),
            format!("{:.2}x", r.speedup),
        ]);
    }
    if !json_mode {
        t.print();
    }
    for r in &rows {
        json.higher(&format!("modeled_tps_r{}", r.gen_replicas), r.tps);
    }
    let last = rows.last().unwrap();
    json.higher(&format!("modeled_speedup_r{}", last.gen_replicas), last.speedup);

    // ---- part 2: real-executor A/B (informational; needs artifacts)
    match Engine::load(artifact_dir("tiny")) {
        Ok(engine) => {
            let base = GrpoConfig {
                iterations: 4,
                prompts_per_iter: 8,
                group_size: 4,
                max_new_tokens: 6,
                nodes: 4,
                pipeline: PipelineMode::Pipelined,
                max_inflight_iters: 2,
                log_every: 0,
                ..Default::default()
            };
            let configs: Vec<(&str, GrpoConfig)> = vec![
                ("1 replica/stage", base.clone()),
                (
                    "gen=2,logprob=2",
                    GrpoConfig {
                        stage_replicas: StageReplicas::parse("gen=2,logprob=2").unwrap(),
                        ..base.clone()
                    },
                ),
                (
                    "autoscaled (max 3)",
                    GrpoConfig {
                        autoscale: true,
                        autoscale_max: 3,
                        autoscale_backlog_hi: 8,
                        autoscale_up_ticks: 2,
                        ..base.clone()
                    },
                ),
            ];
            for (i, (name, cfg)) in configs.into_iter().enumerate() {
                let flow: Arc<dyn SampleFlow> =
                    Arc::new(TransferDock::new(DockTopology::spread(cfg.nodes)));
                let t0 = std::time::Instant::now();
                let report = run_grpo_on_flow(&engine, &cfg, flow).unwrap();
                let wall = t0.elapsed().as_secs_f64();
                json.info(&format!("real_wall_secs_cfg{i}"), wall);
                if !json_mode {
                    println!("\n{name:<20} wall={}", fmt_secs(wall));
                    println!("  {}", report.pipeline.summary());
                    for stage in ["generation", "old_logprob"] {
                        let u = report.pipeline.utilization(stage);
                        assert!(
                            (0.0..=1.0).contains(&u),
                            "replica-aware utilization out of range: {stage} {u}"
                        );
                        println!("  {stage} utilization={:.0}% (slot-time basis)", u * 100.0);
                    }
                }
            }
        }
        Err(e) => {
            if !json_mode {
                eprintln!("skipping real-executor A/B (run `make artifacts`): {e}");
            }
        }
    }

    if json_mode {
        json.emit().unwrap();
    }
}
