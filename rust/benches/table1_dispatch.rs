//! Bench: Table 1 — sample-flow communication volume and dispatch time.
//!
//! Two parts:
//!  1. the analytic rows exactly as the paper prints them (Eq. 2 at
//!     100 MB/s and 1 GB/s), checked against the published values;
//!  2. a *measured* dispatch micro-benchmark: drive the real transfer
//!     dock and the real replay buffer with the Table-1 shapes (scaled
//!     payloads) and time request→fetch→store round trips.

use mindspeed_rl::runtime::Tensor;
use mindspeed_rl::sim::table1_rows_out;
use mindspeed_rl::transfer_dock::{
    DockTopology, FieldKind, ReplayBuffer, Sample, SampleFlow, Stage, TransferDock,
};
use mindspeed_rl::util::bench::{bench, header, BenchJson, Table};
use mindspeed_rl::util::cli::Args;

fn drive_flow(flow: &dyn SampleFlow, n_samples: usize, payload_elems: usize) {
    let samples: Vec<Sample> = (0..n_samples)
        .map(|i| Sample::new_prompt(u64::MAX, i as u64 / 8, format!("{i}+1="), i as i64 + 1))
        .collect();
    let idx = flow.put_samples(samples).unwrap();
    let metas = flow.request_ready(Stage::Generation, n_samples).unwrap();
    let _ = flow.fetch(1, &metas).unwrap();
    for &i in &idx {
        flow.store_generation(
            1,
            i,
            vec![(
                FieldKind::Tokens,
                Tensor::i32(&[payload_elems], vec![1; payload_elems]).unwrap(),
            )],
            "42".into(),
            3,
            1,
        )
        .unwrap();
    }
    let metas = flow.request_ready(Stage::OldLogprob, n_samples).unwrap();
    let _ = flow.fetch(2, &metas).unwrap();
    for &i in &idx {
        flow.store_fields(2, i, vec![(FieldKind::OldLp, Tensor::zeros(&[payload_elems]))])
            .unwrap();
        flow.retire(i);
    }
}

fn main() {
    let json_mode = Args::from_env().unwrap().has("json");
    // Part 1: the paper's table
    let paper: [(f64, f64, f64); 6] = [
        (0.96, 9.92, 0.97),
        (3.81, 39.0, 3.81),
        (15.2, 156.1, 15.2),
        (97.0, 993.3, 97.0),
        (388.0, 3900.0, 388.0),
        (3100.0, 31000.0, 3100.0),
    ];
    let mut t = Table::new(
        "Table 1 (reproduced): TCV & dispatch vs paper",
        &["G", "N", "SL", "TCV ours", "TCV paper", "T100 ours", "T100 paper", "T1K ours", "T1K paper"],
    );
    for (r, p) in table1_rows_out().iter().zip(&paper) {
        t.row(vec![
            r.params.g.to_string(),
            r.params.n_resp.to_string(),
            r.params.sl.to_string(),
            format!("{:.2}", r.tcv_gb),
            format!("{}", p.0),
            format!("{:.1}", r.t100_s),
            format!("{}", p.1),
            format!("{:.2}", r.t1k_s),
            format!("{}", p.2),
        ]);
    }
    t.print();

    if json_mode {
        // fast deterministic config only: the analytic Table-1 row the
        // paper headlines (G=256 N=16 SL=8K → row 2) plus the
        // ledger-implied dispatch seconds, all byte-derived — no
        // wall-clock in the gated set
        let mut json = BenchJson::new("table1_dispatch");
        let rows = table1_rows_out();
        json.lower("tcv_gb_row2", rows[2].tcv_gb);
        json.lower("t100_secs_row2", rows[2].t100_s);
        let dock = TransferDock::new(DockTopology::spread(8));
        drive_flow(&dock, 256, 1024);
        let rb = ReplayBuffer::new(0);
        drive_flow(&rb, 256, 1024);
        let net = mindspeed_rl::transfer_dock::NetworkModel::paper();
        json.lower("dock_dispatch_secs_256", dock.dispatch_secs(&net));
        json.higher("rb_over_dock_dispatch_256", rb.dispatch_secs(&net) / dock.dispatch_secs(&net));
        // the same load through K=8 controller shards (--dock-shards 8):
        // controller sharding must not cost dispatch at fixed scale
        let sharded = TransferDock::with_shards(DockTopology::spread(8), 64, 8, 0);
        drive_flow(&sharded, 256, 1024);
        json.lower("dock_sharded_dispatch_secs_256", sharded.dispatch_secs(&net));
        json.emit().unwrap();
        return;
    }

    // Part 2: measured round-trip micro-bench (payloads scaled down so
    // the bench finishes; the ledger bytes scale exactly)
    println!("\n{}", header());
    for (n_samples, elems) in [(64usize, 512usize), (256, 1024), (1024, 2048)] {
        let r = bench(
            &format!("transfer_dock  n={n_samples} elems={elems}"),
            1,
            10,
            || {
                let dock = TransferDock::new(DockTopology::spread(8));
                drive_flow(&dock, n_samples, elems);
            },
        );
        println!("{}", r.line());
        let r = bench(
            &format!("replay_buffer  n={n_samples} elems={elems}"),
            1,
            10,
            || {
                let rb = ReplayBuffer::new(0);
                drive_flow(&rb, n_samples, elems);
            },
        );
        println!("{}", r.line());
    }

    // simulated dispatch seconds implied by each flow's ledger
    let dock = TransferDock::new(DockTopology::spread(8));
    drive_flow(&dock, 1024, 2048);
    let rb = ReplayBuffer::new(0);
    drive_flow(&rb, 1024, 2048);
    let sharded = TransferDock::with_shards(DockTopology::spread(8), 64, 8, 0);
    drive_flow(&sharded, 1024, 2048);
    let net = mindspeed_rl::transfer_dock::NetworkModel::paper();
    println!(
        "\nimplied dispatch @paper bandwidths (1024 samples): dock={} dock(K=8)={} replay_buffer={}",
        mindspeed_rl::util::fmt_secs(dock.dispatch_secs(&net)),
        mindspeed_rl::util::fmt_secs(sharded.dispatch_secs(&net)),
        mindspeed_rl::util::fmt_secs(rb.dispatch_secs(&net)),
    );
}
