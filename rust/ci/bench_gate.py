#!/usr/bin/env python3
"""Bench-JSON merge + perf gate for the CI `bench` job.

Every bench's `--json` mode writes a single-line summary to
`target/bench/<name>.json` with two buckets of metrics:

    {"bench": "...",
     "gated": {"higher": {...}, "lower": {...}},   # deterministic, gated
     "info":  {...}}                               # context, never gated

Subcommands:

  merge <dir> -o <out>      merge every *.json summary in <dir> into one
                            {"benches": {name: summary}} document
                            (uploaded as the BENCH_PR.json artifact).
                            With --expect <name,...> (names split on
                            commas/whitespace — the workflow passes its
                            bench list verbatim), a summary that is
                            missing, or present but lacking a 'bench'
                            key, FAILS the merge: a bench that silently
                            stopped emitting must not slip past the gate
                            as "no regression".

  gate <baseline> <pr>      compare the PR's merged document against the
                            committed baseline: any gated metric that
                            regresses by more than --tolerance (default
                            10%) fails with exit code 1. A missing
                            baseline is "seed mode": print how to commit
                            one and exit 0 — the first commit seeds the
                            perf trajectory.

Direction semantics: "higher" metrics fail when
`new < old * (1 - tol)`; "lower" metrics fail when
`new > old * (1 + tol) + eps` (eps absorbs float noise near zero).
Improvements are reported but never fail; to ratchet the baseline
forward, re-run the bench job and commit the uploaded BENCH_PR.json as
`rust/bench-baseline.json`.
"""

import argparse
import json
import pathlib
import sys

EPS = 1e-9


def parse_expect(spec: str) -> list:
    """Bench names from --expect: commas and/or whitespace separate."""
    return [n for n in spec.replace(",", " ").split() if n]


def merge(args: argparse.Namespace) -> int:
    src = pathlib.Path(args.dir)
    expected = parse_expect(args.expect) if args.expect else []
    benches = {}
    errors = []
    for path in sorted(src.glob("*.json")):
        if path.name == "BENCH_PR.json":
            continue
        with path.open() as f:
            doc = json.load(f)
        name = doc.get("bench")
        if not name:
            if expected:
                errors.append(f"{path} has no 'bench' key")
            else:
                print(f"::warning::{path} has no 'bench' key; skipped")
            continue
        benches[name] = doc
    for name in expected:
        if name not in benches:
            errors.append(f"expected bench summary '{name}' is missing")
    if errors:
        for e in errors:
            print(f"::error::merge: {e}")
        return 1
    if not benches:
        print(f"::error::no bench summaries found under {src}")
        return 1
    out = pathlib.Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps({"benches": benches}, sort_keys=True) + "\n")
    print(f"merged {len(benches)} bench summaries -> {out}")
    return 0


def gated_metrics(doc: dict) -> dict:
    """(key -> (value, direction)) for one bench summary."""
    out = {}
    gated = doc.get("gated", {})
    for direction in ("higher", "lower"):
        for key, value in gated.get(direction, {}).items():
            out[key] = (float(value), direction)
    return out


def gate(args: argparse.Namespace) -> int:
    baseline_path = pathlib.Path(args.baseline)
    if not baseline_path.exists():
        print(
            f"::notice::no committed baseline at {baseline_path} — seed mode. "
            "Download this run's BENCH_PR.json artifact and commit it as "
            f"{baseline_path} to arm the perf gate."
        )
        return 0
    with baseline_path.open() as f:
        baseline = json.load(f)
    with pathlib.Path(args.pr).open() as f:
        pr = json.load(f)

    tol = args.tolerance
    failures = []
    rows = []
    for bench, base_doc in sorted(baseline.get("benches", {}).items()):
        base_metrics = gated_metrics(base_doc)
        pr_doc = pr.get("benches", {}).get(bench)
        if pr_doc is None:
            # unconditional: even an all-info bench vanishing from the PR
            # doc means a bench target silently stopped running
            failures.append(f"{bench}: bench missing from PR run")
            continue
        pr_metrics = gated_metrics(pr_doc)
        for key, (old, direction) in sorted(base_metrics.items()):
            if key not in pr_metrics:
                failures.append(f"{bench}.{key}: gated metric missing from PR run")
                continue
            new = pr_metrics[key][0]
            if direction == "higher":
                regressed = new < old * (1.0 - tol) - EPS
                delta = (new - old) / old if old else 0.0
            else:
                regressed = new > old * (1.0 + tol) + EPS
                delta = (old - new) / old if old else 0.0
            mark = "REGRESSED" if regressed else "ok"
            rows.append(
                f"  {bench}.{key} ({direction}): {old:g} -> {new:g} "
                f"({delta:+.1%}) {mark}"
            )
            if regressed:
                failures.append(
                    f"{bench}.{key}: {old:g} -> {new:g} "
                    f"(worse than the {tol:.0%} tolerance, {direction} is better)"
                )

    print(f"perf gate vs {baseline_path} (tolerance {tol:.0%}):")
    for row in rows:
        print(row)
    if failures:
        for f_ in failures:
            print(f"::error::perf gate: {f_}")
        return 1
    print("perf gate passed")
    return 0


def main() -> int:
    parser = argparse.ArgumentParser(description=__doc__)
    sub = parser.add_subparsers(dest="cmd", required=True)
    m = sub.add_parser("merge", help="merge per-bench JSON summaries")
    m.add_argument("dir", help="directory holding the per-bench *.json files")
    m.add_argument("-o", "--out", required=True, help="merged output path")
    m.add_argument(
        "--expect",
        default="",
        help="bench names (comma/whitespace separated) that MUST each "
        "contribute a well-formed summary; any absence fails the merge",
    )
    m.set_defaults(func=merge)
    g = sub.add_parser("gate", help="fail on >tolerance regressions vs baseline")
    g.add_argument("baseline", help="committed bench-baseline.json")
    g.add_argument("pr", help="this run's merged BENCH_PR.json")
    g.add_argument("--tolerance", type=float, default=0.10)
    g.set_defaults(func=gate)
    args = parser.parse_args()
    return args.func(args)


if __name__ == "__main__":
    sys.exit(main())
