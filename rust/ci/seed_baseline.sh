#!/usr/bin/env bash
# Seed (or ratchet) the committed perf-gate baseline from a real bench
# run on a toolchain-equipped machine.
#
# The CI perf gate (ci/bench_gate.py, wired in .github/workflows/ci.yml)
# compares every gated metric in the PR's merged BENCH_PR.json against
# the committed rust/bench-baseline.json. Until that baseline exists the
# gate runs in "seed mode" (informational, exit 0). Baselines must come
# from an actual `cargo bench` run — never hand-written numbers: a
# fabricated baseline would make the first honest run look like a
# regression (or mask a real one).
#
# Usage, from rust/ on a machine with the Rust toolchain:
#
#   ci/seed_baseline.sh            # build, test, bench, install baseline
#   ci/seed_baseline.sh --no-test  # skip the tier-1 pass (already green)
#
# then commit the resulting rust/bench-baseline.json. Re-run any time to
# ratchet the baseline forward after a deliberate perf change.

set -euo pipefail
cd "$(dirname "$0")/.."

# Keep in lockstep with BENCH_LIST in .github/workflows/ci.yml — the
# merge below runs with --expect, so a missing or malformed summary
# fails here exactly like it fails in CI.
BENCHES=(
    table1_dispatch fig7_end_to_end fig9_linearity fig10_memory
    fig11_moe hotpath pipeline_overlap stage_scaling
    continuous_batching partial_rollouts multi_tenant
)

if ! command -v cargo >/dev/null 2>&1; then
    echo "error: cargo not found — the baseline must come from a real bench run" >&2
    exit 1
fi

if [[ "${1:-}" != "--no-test" ]]; then
    echo "== tier-1 pass (anything broken here would poison the baseline) =="
    cargo build --release
    cargo test -q
fi

echo "== benches (json mode, deterministic gated metrics only) =="
rm -rf target/bench
for b in "${BENCHES[@]}"; do
    cargo bench --bench "$b" -- --json
done

python3 ci/bench_gate.py merge target/bench -o target/bench/BENCH_PR.json \
    --expect "${BENCHES[*]}"
cp target/bench/BENCH_PR.json bench-baseline.json
echo "baseline installed at rust/bench-baseline.json — review and commit it"
