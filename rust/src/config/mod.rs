//! Config system: JSON experiment/training configs with CLI overrides.
//!
//! (TOML was planned but the offline environment has no toml crate; the
//! in-crate JSON parser serves the same role. See DESIGN.md substitutions.)

use anyhow::{Context, Result};
use std::path::Path;

use crate::trainers::{GrpoConfig, PipelineMode, StageReplicas};
use crate::util::cli::Args;
use crate::util::json::Json;

/// Top-level runtime configuration.
#[derive(Debug, Clone)]
pub struct Config {
    /// artifact preset to load (tiny | small | moe_tiny | e2e ...)
    pub preset: String,
    pub grpo: GrpoConfig,
    /// where to write result CSVs
    pub results_dir: String,
}

impl Default for Config {
    fn default() -> Self {
        Self {
            preset: "small".into(),
            grpo: GrpoConfig::default(),
            results_dir: "results".into(),
        }
    }
}

impl Config {
    /// Load from a JSON file; missing keys fall back to defaults.
    pub fn from_file(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading config {:?}", path.as_ref()))?;
        let j = Json::parse(&text).context("parsing config JSON")?;
        let mut cfg = Config::default();
        if let Some(v) = j.opt("preset") {
            cfg.preset = v.str()?.to_string();
        }
        if let Some(v) = j.opt("results_dir") {
            cfg.results_dir = v.str()?.to_string();
        }
        if let Some(g) = j.opt("grpo") {
            let d = &mut cfg.grpo;
            if let Some(v) = g.opt("iterations") {
                d.iterations = v.usize()?;
            }
            if let Some(v) = g.opt("prompts_per_iter") {
                d.prompts_per_iter = v.usize()?;
            }
            if let Some(v) = g.opt("group_size") {
                d.group_size = v.usize()?;
            }
            if let Some(v) = g.opt("lr") {
                d.lr = v.num()? as f32;
            }
            if let Some(v) = g.opt("max_new_tokens") {
                d.max_new_tokens = v.usize()?;
            }
            if let Some(v) = g.opt("temperature") {
                d.temperature = v.num()? as f32;
            }
            if let Some(v) = g.opt("seed") {
                d.seed = v.u64()?;
            }
            if let Some(v) = g.opt("nodes") {
                d.nodes = v.usize()?;
            }
            if let Some(v) = g.opt("use_replay_buffer") {
                d.use_replay_buffer = v.bool()?;
            }
            if let Some(v) = g.opt("pipeline") {
                d.pipeline = PipelineMode::parse(v.str()?)?;
            }
            if let Some(v) = g.opt("max_inflight_iters") {
                d.max_inflight_iters = v.usize()?;
            }
            if let Some(v) = g.opt("gen_logprobs") {
                d.gen_logprobs = v.bool()?;
            }
            if let Some(v) = g.opt("lease_ticks") {
                d.lease_ticks = v.u64()?;
            }
            if let Some(v) = g.opt("dock_shards") {
                d.dock_shards = v.usize()?;
            }
            if let Some(v) = g.opt("steal_threshold") {
                d.steal_threshold = v.usize()?;
            }
            if let Some(v) = g.opt("chaos_kill_rate") {
                d.chaos_kill_rate = v.num()?;
            }
            if let Some(v) = g.opt("chaos_stall_rate") {
                d.chaos_stall_rate = v.num()?;
            }
            if let Some(v) = g.opt("chaos_stall_ticks") {
                d.chaos_stall_ticks = v.u64()?;
            }
            if let Some(v) = g.opt("chaos_seed") {
                d.chaos_seed = v.u64()?;
            }
            if let Some(v) = g.opt("chaos_max_faults") {
                d.chaos_max_faults = v.u64()?;
            }
            if let Some(v) = g.opt("stage_replicas") {
                d.stage_replicas = StageReplicas::parse(v.str()?)?;
            }
            if let Some(v) = g.opt("autoscale") {
                d.autoscale = v.bool()?;
            }
            if let Some(v) = g.opt("autoscale_min") {
                d.autoscale_min = v.usize()?;
            }
            if let Some(v) = g.opt("autoscale_max") {
                d.autoscale_max = v.usize()?;
            }
            if let Some(v) = g.opt("autoscale_backlog_hi") {
                d.autoscale_backlog_hi = v.usize()?;
            }
            if let Some(v) = g.opt("autoscale_backlog_lo") {
                d.autoscale_backlog_lo = v.usize()?;
            }
            if let Some(v) = g.opt("autoscale_up_ticks") {
                d.autoscale_up_ticks = v.usize()? as u32;
            }
            if let Some(v) = g.opt("autoscale_down_ticks") {
                d.autoscale_down_ticks = v.usize()? as u32;
            }
            if let Some(v) = g.opt("gen_streaming") {
                d.gen_streaming = v.bool()?;
            }
            if let Some(v) = g.opt("prefill_chunk") {
                d.prefill_chunk = v.usize()?;
            }
            if let Some(v) = g.opt("kv_block_tokens") {
                d.kv_block_tokens = v.usize()?;
            }
            if let Some(v) = g.opt("partial_rollouts") {
                d.partial_rollouts = v.bool()?;
            }
            if let Some(v) = g.opt("preempt_on_publish") {
                d.preempt_on_publish = v.bool()?;
            }
            if let Some(v) = g.opt("tenants") {
                d.tenants = v.usize()?;
            }
            if let Some(v) = g.opt("tenant_weights") {
                d.tenant_weights = v
                    .arr()?
                    .iter()
                    .map(|x| Ok(x.u64()? as u32))
                    .collect::<Result<_>>()?;
            }
            if let Some(v) = g.opt("tenant_quota_mb") {
                d.tenant_quota_mb =
                    v.arr()?.iter().map(|x| x.u64()).collect::<Result<_>>()?;
            }
            if let Some(v) = g.opt("eval_every") {
                d.eval_every = v.usize()?;
            }
            if let Some(v) = g.opt("eval_size") {
                d.eval_size = v.usize()?;
            }
            if let Some(v) = g.opt("log_every") {
                d.log_every = v.usize()?;
            }
        }
        Ok(cfg)
    }

    /// Apply CLI flag overrides on top (flags win over file).
    pub fn apply_args(&mut self, args: &Args) -> Result<()> {
        if let Some(p) = args.get("preset") {
            self.preset = p.to_string();
        }
        if let Some(r) = args.get("results-dir") {
            self.results_dir = r.to_string();
        }
        let g = &mut self.grpo;
        g.iterations = args.usize_or("iterations", g.iterations)?;
        g.prompts_per_iter = args.usize_or("prompts-per-iter", g.prompts_per_iter)?;
        g.group_size = args.usize_or("group-size", g.group_size)?;
        g.lr = args.f32_or("lr", g.lr)?;
        g.max_new_tokens = args.usize_or("max-new-tokens", g.max_new_tokens)?;
        g.temperature = args.f32_or("temperature", g.temperature)?;
        g.seed = args.u64_or("seed", g.seed)?;
        g.nodes = args.usize_or("nodes", g.nodes)?;
        if args.has("replay-buffer") {
            g.use_replay_buffer = true;
        }
        if let Some(p) = args.get("pipeline") {
            g.pipeline = PipelineMode::parse(p)?;
        }
        g.max_inflight_iters = args.usize_or("max-inflight", g.max_inflight_iters)?;
        if args.has("gen-logprobs") {
            g.gen_logprobs = true;
        }
        g.lease_ticks = args.u64_or("lease-ticks", g.lease_ticks)?;
        g.dock_shards = args.usize_or("dock-shards", g.dock_shards)?;
        g.steal_threshold = args.usize_or("steal-threshold", g.steal_threshold)?;
        g.chaos_kill_rate = args.f64_or("chaos-kill-rate", g.chaos_kill_rate)?;
        g.chaos_stall_rate = args.f64_or("chaos-stall-rate", g.chaos_stall_rate)?;
        g.chaos_stall_ticks = args.u64_or("chaos-stall-ticks", g.chaos_stall_ticks)?;
        g.chaos_seed = args.u64_or("chaos-seed", g.chaos_seed)?;
        g.chaos_max_faults = args.u64_or("chaos-max-faults", g.chaos_max_faults)?;
        if let Some(s) = args.get("stage-replicas") {
            g.stage_replicas = StageReplicas::parse(s)?;
        }
        if args.has("autoscale") {
            g.autoscale = true;
        }
        g.autoscale_min = args.usize_or("autoscale-min", g.autoscale_min)?;
        g.autoscale_max = args.usize_or("autoscale-max", g.autoscale_max)?;
        g.autoscale_backlog_hi = args.usize_or("autoscale-backlog-hi", g.autoscale_backlog_hi)?;
        g.autoscale_backlog_lo = args.usize_or("autoscale-backlog-lo", g.autoscale_backlog_lo)?;
        g.autoscale_up_ticks =
            args.usize_or("autoscale-up-ticks", g.autoscale_up_ticks as usize)? as u32;
        g.autoscale_down_ticks =
            args.usize_or("autoscale-down-ticks", g.autoscale_down_ticks as usize)? as u32;
        if args.has("gen-streaming") {
            g.gen_streaming = true;
        }
        if args.has("partial-rollouts") {
            g.partial_rollouts = true;
        }
        if args.has("preempt-on-publish") {
            g.preempt_on_publish = true;
        }
        g.prefill_chunk = args.usize_or("prefill-chunk", g.prefill_chunk)?;
        g.kv_block_tokens = args.usize_or("kv-block-tokens", g.kv_block_tokens)?;
        g.tenants = args.usize_or("tenants", g.tenants)?;
        if let Some(s) = args.get("tenant-weight") {
            g.tenant_weights = parse_u32_list(s).context("--tenant-weight")?;
        }
        if let Some(s) = args.get("tenant-quota-mb") {
            g.tenant_quota_mb = parse_u64_list(s).context("--tenant-quota-mb")?;
        }
        g.eval_every = args.usize_or("eval-every", g.eval_every)?;
        g.eval_size = args.usize_or("eval-size", g.eval_size)?;
        g.log_every = args.usize_or("log-every", g.log_every)?;
        Ok(())
    }

    /// Load optional `--config file.json` then apply flag overrides.
    /// Validates the merged result so degenerate values (e.g.
    /// `--max-inflight 0`) fail here, not mid-run.
    pub fn from_args(args: &Args) -> Result<Self> {
        let mut cfg = match args.get("config") {
            Some(path) => Config::from_file(path)?,
            None => Config::default(),
        };
        cfg.apply_args(args)?;
        cfg.grpo.validate()?;
        Ok(cfg)
    }
}

/// Parse a comma-separated numeric flag value (`--tenant-weight 3,1`).
fn parse_u64_list(s: &str) -> Result<Vec<u64>> {
    s.split(',')
        .map(|p| {
            p.trim()
                .parse::<u64>()
                .with_context(|| format!("bad list item {p:?} (expected comma-separated numbers)"))
        })
        .collect()
}

fn parse_u32_list(s: &str) -> Result<Vec<u32>> {
    Ok(parse_u64_list(s)?.into_iter().map(|v| v as u32).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn file_then_flags() {
        let dir = std::env::temp_dir().join("msrl_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("cfg.json");
        std::fs::write(
            &p,
            r#"{"preset": "tiny", "grpo": {"iterations": 7, "lr": 0.01}}"#,
        )
        .unwrap();
        let mut cfg = Config::from_file(&p).unwrap();
        assert_eq!(cfg.preset, "tiny");
        assert_eq!(cfg.grpo.iterations, 7);
        assert_eq!(cfg.grpo.lr, 0.01);

        let args = Args::parse(
            ["--iterations", "9", "--preset", "small"].iter().map(|s| s.to_string()),
        )
        .unwrap();
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.grpo.iterations, 9);
        assert_eq!(cfg.preset, "small");
        assert_eq!(cfg.grpo.lr, 0.01, "file value survives when not overridden");
    }

    #[test]
    fn defaults_without_file() {
        let args = Args::parse(std::iter::empty()).unwrap();
        let cfg = Config::from_args(&args).unwrap();
        assert_eq!(cfg.preset, "small");
        assert_eq!(cfg.grpo.pipeline, PipelineMode::Sync);
        assert_eq!(cfg.grpo.max_inflight_iters, 2);
    }

    #[test]
    fn pipeline_flags_parse() {
        let args = Args::parse(
            ["--pipeline", "pipelined", "--max-inflight", "3", "--gen-logprobs"]
                .iter()
                .map(|s| s.to_string()),
        )
        .unwrap();
        let cfg = Config::from_args(&args).unwrap();
        assert_eq!(cfg.grpo.pipeline, PipelineMode::Pipelined);
        assert_eq!(cfg.grpo.max_inflight_iters, 3);
        assert!(cfg.grpo.gen_logprobs);

        let json = Args::parse(std::iter::empty()).unwrap();
        let dflt = Config::from_args(&json).unwrap();
        assert!(!dflt.grpo.gen_logprobs, "fast path must stay opt-in for seed parity");

        let bad = Args::parse(["--pipeline", "warp"].iter().map(|s| s.to_string())).unwrap();
        assert!(Config::from_args(&bad).is_err());
    }

    #[test]
    fn chaos_flags_parse_and_validate() {
        let args = Args::parse(
            [
                "--pipeline",
                "pipelined",
                "--chaos-kill-rate",
                "0.2",
                "--chaos-stall-rate",
                "0.1",
                "--chaos-stall-ticks",
                "9",
                "--chaos-seed",
                "77",
                "--chaos-max-faults",
                "5",
                "--lease-ticks",
                "6",
            ]
            .iter()
            .map(|s| s.to_string()),
        )
        .unwrap();
        let cfg = Config::from_args(&args).unwrap();
        assert_eq!(cfg.grpo.chaos_kill_rate, 0.2);
        assert_eq!(cfg.grpo.chaos_stall_rate, 0.1);
        assert_eq!(cfg.grpo.chaos_stall_ticks, 9);
        assert_eq!(cfg.grpo.chaos_seed, 77);
        assert_eq!(cfg.grpo.chaos_max_faults, 5);
        assert_eq!(cfg.grpo.lease_ticks, 6);
        let plan = cfg.grpo.fault_plan().expect("plan");
        assert_eq!(plan.seed, 77);

        // chaos without the pipelined executor is rejected at load time
        let bad = Args::parse(
            ["--chaos-kill-rate", "0.2"].iter().map(|s| s.to_string()),
        )
        .unwrap();
        assert!(Config::from_args(&bad).is_err());
        // so is a nonsense rate
        let bad = Args::parse(
            ["--pipeline", "pipelined", "--chaos-kill-rate", "1.5"]
                .iter()
                .map(|s| s.to_string()),
        )
        .unwrap();
        assert!(Config::from_args(&bad).is_err());
        // and file-config keys land too
        let dir = std::env::temp_dir().join("msrl_cfg_chaos_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("cfg.json");
        std::fs::write(
            &p,
            r#"{"grpo": {"pipeline": "pipelined", "chaos_kill_rate": 0.3, "lease_ticks": 5}}"#,
        )
        .unwrap();
        let cfg = Config::from_file(&p).unwrap();
        assert_eq!(cfg.grpo.chaos_kill_rate, 0.3);
        assert_eq!(cfg.grpo.lease_ticks, 5);
    }

    #[test]
    fn elastic_flags_parse_and_validate() {
        let args = Args::parse(
            [
                "--pipeline",
                "pipelined",
                "--stage-replicas",
                "gen=4,logprob=2",
                "--autoscale-max",
                "6",
                "--autoscale-up-ticks",
                "2",
                "--autoscale", // boolean flags last (see Args::parse note)
            ]
            .iter()
            .map(|s| s.to_string()),
        )
        .unwrap();
        let cfg = Config::from_args(&args).unwrap();
        assert_eq!(cfg.grpo.stage_replicas.generation, 4);
        assert_eq!(cfg.grpo.stage_replicas.old_logprob, 2);
        assert!(cfg.grpo.autoscale);
        let ac = cfg.grpo.autoscale_config().unwrap();
        assert_eq!(ac.max_replicas, 6);
        assert_eq!(ac.up_ticks, 2);

        // replicas without the pipelined executor are rejected at load
        let bad =
            Args::parse(["--stage-replicas", "gen=2"].iter().map(|s| s.to_string())).unwrap();
        assert!(Config::from_args(&bad).is_err());
        // malformed replica spec is a parse error, not a silent default
        let bad = Args::parse(
            ["--pipeline", "pipelined", "--stage-replicas", "gen=zero"]
                .iter()
                .map(|s| s.to_string()),
        )
        .unwrap();
        assert!(Config::from_args(&bad).is_err());
        // file-config keys land too
        let dir = std::env::temp_dir().join("msrl_cfg_elastic_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("cfg.json");
        std::fs::write(
            &p,
            r#"{"grpo": {"pipeline": "pipelined", "stage_replicas": "gen=3",
                "autoscale": true, "autoscale_max": 8, "autoscale_backlog_hi": 32}}"#,
        )
        .unwrap();
        let cfg = Config::from_file(&p).unwrap();
        assert_eq!(cfg.grpo.stage_replicas.generation, 3);
        assert!(cfg.grpo.autoscale);
        assert_eq!(cfg.grpo.autoscale_max, 8);
        assert_eq!(cfg.grpo.autoscale_backlog_hi, 32);
    }

    #[test]
    fn tenancy_flags_parse_and_validate() {
        let args = Args::parse(
            ["--tenants", "2", "--tenant-weight", "3,1", "--tenant-quota-mb", "64,32"]
                .iter()
                .map(|s| s.to_string()),
        )
        .unwrap();
        let cfg = Config::from_args(&args).unwrap();
        assert_eq!(cfg.grpo.tenants, 2);
        assert_eq!(cfg.grpo.tenant_weights, vec![3, 1]);
        assert_eq!(cfg.grpo.tenant_quota_mb, vec![64, 32]);
        let roster = cfg.grpo.tenant_set().unwrap();
        assert_eq!(roster.weights(), vec![(0, 3), (1, 1)]);

        // more weights than tenants is rejected at load time, not mid-run
        let bad = Args::parse(
            ["--tenants", "1", "--tenant-weight", "3,1"].iter().map(|s| s.to_string()),
        )
        .unwrap();
        assert!(Config::from_args(&bad).is_err());
        // malformed list items are parse errors, not silent defaults
        let bad = Args::parse(
            ["--tenants", "2", "--tenant-weight", "3,x"].iter().map(|s| s.to_string()),
        )
        .unwrap();
        assert!(Config::from_args(&bad).is_err());
        // and file-config keys land too
        let dir = std::env::temp_dir().join("msrl_cfg_tenancy_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("cfg.json");
        std::fs::write(
            &p,
            r#"{"grpo": {"tenants": 3, "tenant_weights": [2, 1, 1], "tenant_quota_mb": [16]}}"#,
        )
        .unwrap();
        let cfg = Config::from_file(&p).unwrap();
        assert_eq!(cfg.grpo.tenants, 3);
        assert_eq!(cfg.grpo.tenant_weights, vec![2, 1, 1]);
        assert_eq!(cfg.grpo.tenant_quota_mb, vec![16]);
    }

    #[test]
    fn streaming_flags_parse_and_validate() {
        let args = Args::parse(
            [
                "--pipeline",
                "pipelined",
                "--prefill-chunk",
                "8",
                "--kv-block-tokens",
                "32",
                "--gen-streaming", // boolean flags last (see Args::parse note)
            ]
            .iter()
            .map(|s| s.to_string()),
        )
        .unwrap();
        let cfg = Config::from_args(&args).unwrap();
        assert!(cfg.grpo.gen_streaming);
        assert_eq!(cfg.grpo.prefill_chunk, 8);
        assert_eq!(cfg.grpo.kv_block_tokens, 32);

        // streaming without the pipelined executor is rejected at load
        let bad = Args::parse(["--gen-streaming"].iter().map(|s| s.to_string())).unwrap();
        assert!(Config::from_args(&bad).is_err());
        // degenerate paging knobs are rejected
        let bad = Args::parse(
            ["--pipeline", "pipelined", "--kv-block-tokens", "0", "--gen-streaming"]
                .iter()
                .map(|s| s.to_string()),
        )
        .unwrap();
        assert!(Config::from_args(&bad).is_err());
        // defaults: streaming stays opt-in, knobs carry their documented values
        let dflt = Config::from_args(&Args::parse(std::iter::empty()).unwrap()).unwrap();
        assert!(!dflt.grpo.gen_streaming);
        assert_eq!(dflt.grpo.prefill_chunk, 4);
        assert_eq!(dflt.grpo.kv_block_tokens, 16);
        // file-config keys land too
        let dir = std::env::temp_dir().join("msrl_cfg_streaming_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("cfg.json");
        std::fs::write(
            &p,
            r#"{"grpo": {"pipeline": "pipelined", "gen_streaming": true,
                "prefill_chunk": 2, "kv_block_tokens": 64}}"#,
        )
        .unwrap();
        let cfg = Config::from_file(&p).unwrap();
        assert!(cfg.grpo.gen_streaming);
        assert_eq!(cfg.grpo.prefill_chunk, 2);
        assert_eq!(cfg.grpo.kv_block_tokens, 64);
    }

    #[test]
    fn partial_rollout_flags_parse_and_validate() {
        let args = Args::parse(
            [
                "--pipeline",
                "pipelined",
                "--gen-streaming",
                "--partial-rollouts",
                "--preempt-on-publish", // boolean flags last (see Args::parse note)
            ]
            .iter()
            .map(|s| s.to_string()),
        )
        .unwrap();
        let cfg = Config::from_args(&args).unwrap();
        assert!(cfg.grpo.partial_rollouts);
        assert!(cfg.grpo.preempt_on_publish);

        // partial rollouts without the streaming scheduler are rejected
        let bad = Args::parse(
            ["--pipeline", "pipelined", "--partial-rollouts"].iter().map(|s| s.to_string()),
        )
        .unwrap();
        assert!(Config::from_args(&bad).is_err());
        // preemption without persistence is rejected
        let bad = Args::parse(
            ["--pipeline", "pipelined", "--gen-streaming", "--preempt-on-publish"]
                .iter()
                .map(|s| s.to_string()),
        )
        .unwrap();
        assert!(Config::from_args(&bad).is_err());
        // both stay opt-in
        let dflt = Config::from_args(&Args::parse(std::iter::empty()).unwrap()).unwrap();
        assert!(!dflt.grpo.partial_rollouts);
        assert!(!dflt.grpo.preempt_on_publish);
        // file-config keys land too
        let dir = std::env::temp_dir().join("msrl_cfg_partial_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("cfg.json");
        std::fs::write(
            &p,
            r#"{"grpo": {"pipeline": "pipelined", "gen_streaming": true,
                "partial_rollouts": true, "preempt_on_publish": true}}"#,
        )
        .unwrap();
        let cfg = Config::from_file(&p).unwrap();
        assert!(cfg.grpo.partial_rollouts);
        assert!(cfg.grpo.preempt_on_publish);
        assert!(cfg.grpo.validate().is_ok());
    }

    #[test]
    fn sharded_dock_flags_parse_and_validate() {
        let args = Args::parse(
            ["--dock-shards", "4", "--steal-threshold", "2"].iter().map(|s| s.to_string()),
        )
        .unwrap();
        let cfg = Config::from_args(&args).unwrap();
        assert_eq!(cfg.grpo.dock_shards, 4);
        assert_eq!(cfg.grpo.steal_threshold, 2);

        // K=0 is rejected at load time
        let bad = Args::parse(["--dock-shards", "0"].iter().map(|s| s.to_string())).unwrap();
        assert!(Config::from_args(&bad).is_err());
        // a steal threshold without siblings is rejected
        let bad =
            Args::parse(["--steal-threshold", "2"].iter().map(|s| s.to_string())).unwrap();
        assert!(Config::from_args(&bad).is_err());
        // the replay-buffer baseline cannot shard (boolean flag last)
        let bad = Args::parse(
            ["--dock-shards", "4", "--replay-buffer"].iter().map(|s| s.to_string()),
        )
        .unwrap();
        assert!(Config::from_args(&bad).is_err());
        // defaults: the single-controller dock, no stealing
        let dflt = Config::from_args(&Args::parse(std::iter::empty()).unwrap()).unwrap();
        assert_eq!(dflt.grpo.dock_shards, 1);
        assert_eq!(dflt.grpo.steal_threshold, 0);
        // file-config keys land too
        let dir = std::env::temp_dir().join("msrl_cfg_sharded_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("cfg.json");
        std::fs::write(
            &p,
            r#"{"grpo": {"dock_shards": 8, "steal_threshold": 1}}"#,
        )
        .unwrap();
        let cfg = Config::from_file(&p).unwrap();
        assert_eq!(cfg.grpo.dock_shards, 8);
        assert_eq!(cfg.grpo.steal_threshold, 1);
        assert!(cfg.grpo.validate().is_ok());
    }

    #[test]
    fn degenerate_values_rejected_at_load_time() {
        // --max-inflight 0 used to build a bus that failed mid-run; now
        // the merged config fails validation up front
        for flags in [
            ["--max-inflight", "0"],
            ["--prompts-per-iter", "0"],
            ["--group-size", "0"],
        ] {
            let args = Args::parse(flags.iter().map(|s| s.to_string())).unwrap();
            assert!(Config::from_args(&args).is_err(), "{flags:?} must be rejected");
        }
        let ok = Args::parse(["--max-inflight", "1"].iter().map(|s| s.to_string())).unwrap();
        assert!(Config::from_args(&ok).is_ok());
    }
}
