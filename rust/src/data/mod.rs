//! Prompt dataset substrate.
//!
//! The paper trains on DeepScaleR (verifiable math problems with a rule
//! reward). That dataset and its 7B-scale models are unavailable here, so
//! this module generates the closest synthetic equivalent: arithmetic
//! tasks with exactly-checkable integer answers, in three difficulty
//! tiers that stand in for the paper's MATH500 / AIME24 / GPQA evaluation
//! splits (DESIGN.md substitution table). Train and eval splits are
//! disjoint by construction (seed namespaces).

pub mod tasks;

pub use tasks::{Task, TaskGenerator, Tier};
