//! Synthetic verifiable arithmetic tasks (DeepScaleR substitute).

use crate::util::rng::Rng;

/// Difficulty tiers, standing in for the paper's eval suites:
/// `Easy` ↔ MATH500-like, `Medium` ↔ GPQA-like, `Hard` ↔ AIME24-like.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Tier {
    Easy,
    Medium,
    Hard,
}

impl Tier {
    pub fn all() -> [Tier; 3] {
        [Tier::Easy, Tier::Medium, Tier::Hard]
    }

    pub fn name(&self) -> &'static str {
        match self {
            Tier::Easy => "easy",
            Tier::Medium => "medium",
            Tier::Hard => "hard",
        }
    }
}

/// One verifiable task: prompt text ends with '=', the model must emit the
/// integer answer.
#[derive(Debug, Clone, PartialEq)]
pub struct Task {
    pub prompt: String,
    pub answer: i64,
    pub tier: Tier,
}

/// Deterministic task generator. Train and eval draws come from disjoint
/// seed namespaces so eval tasks can never leak into training.
#[derive(Debug, Clone)]
pub struct TaskGenerator {
    rng: Rng,
}

const EVAL_NAMESPACE: u64 = 0xE7A1_5EED_0000_0001;

impl TaskGenerator {
    pub fn train(seed: u64) -> Self {
        Self { rng: Rng::new(seed.wrapping_mul(2).wrapping_add(1)) }
    }

    pub fn eval(seed: u64) -> Self {
        Self { rng: Rng::new(seed.wrapping_mul(2) ^ EVAL_NAMESPACE) }
    }

    pub fn next(&mut self, tier: Tier) -> Task {
        let r = &mut self.rng;
        let (prompt, answer) = match tier {
            Tier::Easy => {
                // single-digit-ish addition: learnable by a char model fast
                let a = r.range(0, 10);
                let b = r.range(0, 10);
                (format!("{a}+{b}="), a + b)
            }
            Tier::Medium => match r.below(2) {
                0 => {
                    let a = r.range(0, 100);
                    let b = r.range(0, 100);
                    (format!("{a}+{b}="), a + b)
                }
                _ => {
                    let a = r.range(0, 100);
                    let b = r.range(0, a + 1);
                    (format!("{a}-{b}="), a - b)
                }
            },
            Tier::Hard => match r.below(3) {
                0 => {
                    let a = r.range(2, 13);
                    let b = r.range(2, 13);
                    (format!("{a}*{b}="), a * b)
                }
                1 => {
                    let a = r.range(2, 10);
                    let b = r.range(2, 10);
                    let c = r.range(0, 50);
                    (format!("{a}*{b}+{c}="), a * b + c)
                }
                _ => {
                    let a = r.range(0, 50);
                    let b = r.range(0, 50);
                    let c = r.range(0, 50);
                    (format!("{a}+{b}-{c}="), a + b - c)
                }
            },
        };
        Task { prompt, answer, tier }
    }

    /// A mixed-tier batch (the training distribution).
    pub fn batch(&mut self, n: usize) -> Vec<Task> {
        (0..n)
            .map(|_| {
                let tier = match self.rng.below(4) {
                    0 | 1 => Tier::Easy,
                    2 => Tier::Medium,
                    _ => Tier::Hard,
                };
                self.next(tier)
            })
            .collect()
    }

    /// Fixed-size eval set for one tier (paper's Table 3 substitute).
    pub fn eval_set(seed: u64, tier: Tier, n: usize) -> Vec<Task> {
        let mut g = Self::eval(seed);
        (0..n).map(|_| g.next(tier)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = TaskGenerator::train(5);
        let mut b = TaskGenerator::train(5);
        for _ in 0..20 {
            assert_eq!(a.next(Tier::Hard), b.next(Tier::Hard));
        }
    }

    #[test]
    fn answers_are_correct() {
        let mut g = TaskGenerator::train(1);
        for _ in 0..200 {
            for tier in Tier::all() {
                let t = g.next(tier);
                let expr = t.prompt.trim_end_matches('=');
                assert_eq!(eval_expr(expr), t.answer, "{}", t.prompt);
            }
        }
    }

    #[test]
    fn train_and_eval_disjoint_streams() {
        let mut tr = TaskGenerator::train(7);
        let mut ev = TaskGenerator::eval(7);
        let a: Vec<Task> = (0..10).map(|_| tr.next(Tier::Easy)).collect();
        let b: Vec<Task> = (0..10).map(|_| ev.next(Tier::Easy)).collect();
        assert_ne!(a, b);
    }

    #[test]
    fn prompts_fit_vocab() {
        let mut g = TaskGenerator::train(3);
        let allowed = "0123456789+-*/=()., ?";
        for _ in 0..300 {
            let t = g.next(Tier::Hard);
            assert!(t.prompt.chars().all(|c| allowed.contains(c)), "{}", t.prompt);
        }
    }

    /// Tiny evaluator for the generated grammar: `*` binds tighter than
    /// `+`/`-` (no parens in the current tiers).
    fn eval_expr(s: &str) -> i64 {
        // tokenize
        let mut nums: Vec<i64> = Vec::new();
        let mut ops: Vec<char> = Vec::new();
        let mut cur = String::new();
        for c in s.chars() {
            if c.is_ascii_digit() {
                cur.push(c);
            } else {
                nums.push(cur.parse().unwrap());
                cur.clear();
                ops.push(c);
            }
        }
        nums.push(cur.parse().unwrap());
        // fold '*'
        let mut terms = vec![nums[0]];
        let mut signs = vec![1i64];
        for (op, &n) in ops.iter().zip(&nums[1..]) {
            match op {
                '*' => {
                    let last = terms.last_mut().unwrap();
                    *last *= n;
                }
                '+' => {
                    terms.push(n);
                    signs.push(1);
                }
                '-' => {
                    terms.push(n);
                    signs.push(-1);
                }
                _ => panic!("unexpected op {op}"),
            }
        }
        terms.iter().zip(&signs).map(|(t, s)| t * s).sum()
    }
}
