//! Continuous batcher over the incremental decode artifact.

use anyhow::Result;
use std::collections::VecDeque;
use std::time::Instant;

use super::sampler::{token_logprob, SamplingParams};
use crate::runtime::{Engine, Policy, Tensor};
use crate::util::rng::Rng;

/// One generation request (a prompt to complete).
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub id: u64,
    pub prompt_ids: Vec<i32>,
    pub max_new_tokens: usize,
}

/// A finished completion.
#[derive(Debug, Clone)]
pub struct GenResult {
    pub id: u64,
    /// generated tokens (response only), including the EOS if emitted
    pub response_ids: Vec<i32>,
    /// behavior log-prob of each response token under softmax of the raw
    /// decode logits (temperature 1, full support — the `logprobs`
    /// artifact's definition), captured at sampling time; one entry per
    /// `response_ids` entry
    pub response_logprobs: Vec<f32>,
    pub finished_by_eos: bool,
}

#[derive(Debug, Clone, Copy, Default)]
pub struct GenStats {
    pub decode_steps: u64,
    pub tokens_generated: u64,
    pub prompt_tokens: u64,
    pub wall_secs: f64,
    /// slot-steps that carried a live sequence — kept as a raw counter
    /// (not a pre-divided ratio) so merges across claims and replicas of
    /// different sizes stay slot-step-weighted
    pub busy_slot_steps: u64,
    /// total slot-steps (busy + idle)
    pub total_slot_steps: u64,
}

impl GenStats {
    /// Fraction of slot-steps that carried a live sequence.
    pub fn occupancy(&self) -> f64 {
        if self.total_slot_steps == 0 {
            0.0
        } else {
            self.busy_slot_steps as f64 / self.total_slot_steps as f64
        }
    }
}

/// State of one batch slot.
enum Slot {
    Idle,
    Busy {
        req: GenRequest,
        /// tokens fed so far (prompt progress), then generated tokens
        fed: usize,
        pos: i32,
        response: Vec<i32>,
        /// behavior log-prob of each sampled response token
        logprobs: Vec<f32>,
    },
}

/// Continuous batcher: keeps the decode artifact's batch slots full.
pub struct GenEngine {
    pub batch: usize,
    pub max_seq: usize,
    pub eos_id: i32,
    pub pad_id: i32,
    pub params: SamplingParams,
}

/// Pop the next runnable request. Degenerate requests — `max_new_tokens
/// == 0`, or a prompt already at/over `max_seq` (no position left to
/// sample into) — complete immediately with an empty response instead of
/// occupying a slot; without this guard a zero-budget request would emit
/// one token before its length check and an over-long prompt would feed
/// past the KV tensor's last row.
fn pop_runnable(
    queue: &mut VecDeque<GenRequest>,
    results: &mut Vec<GenResult>,
    max_seq: usize,
) -> Option<GenRequest> {
    while let Some(req) = queue.pop_front() {
        if req.max_new_tokens == 0 || req.prompt_ids.len() + 1 > max_seq {
            results.push(GenResult {
                id: req.id,
                response_ids: Vec::new(),
                response_logprobs: Vec::new(),
                finished_by_eos: false,
            });
            continue;
        }
        return Some(req);
    }
    None
}

impl GenEngine {
    pub fn from_manifest(engine: &Engine, params: SamplingParams) -> Result<Self> {
        let a = engine.manifest.artifact("decode_step")?;
        Ok(Self {
            batch: a.batch,
            max_seq: engine.manifest.model.max_seq,
            eos_id: engine.manifest.eos_id as i32,
            pad_id: engine.manifest.pad_id as i32,
            params,
        })
    }

    /// Run all requests to completion with continuous slot refill.
    /// Returns results in completion order plus batch statistics.
    pub fn generate(
        &self,
        engine: &Engine,
        policy: &Policy,
        requests: Vec<GenRequest>,
        rng: &mut Rng,
    ) -> Result<(Vec<GenResult>, GenStats)> {
        let t0 = Instant::now();
        let mut queue: VecDeque<GenRequest> = requests.into();
        let n_total = queue.len();
        let mut slots: Vec<Slot> = (0..self.batch).map(|_| Slot::Idle).collect();
        let mut results = Vec::with_capacity(n_total);
        let mut stats = GenStats::default();

        let mut kv = policy.init_kv(engine)?;
        let mut pos_v = vec![0i32; self.batch];
        let mut tok_v = vec![self.pad_id; self.batch];

        // admit initial requests
        for slot in slots.iter_mut() {
            if let Some(req) = pop_runnable(&mut queue, &mut results, self.max_seq) {
                stats.prompt_tokens += req.prompt_ids.len() as u64;
                *slot = Slot::Busy {
                    req,
                    fed: 0,
                    pos: 0,
                    response: Vec::new(),
                    logprobs: Vec::new(),
                };
            }
        }

        loop {
            // prepare this step's inputs: each busy slot feeds its next
            // prompt token (prefill) or its last sampled token (decode)
            let mut any_busy = false;
            for (i, slot) in slots.iter_mut().enumerate() {
                stats.total_slot_steps += 1;
                match slot {
                    Slot::Idle => {
                        tok_v[i] = self.pad_id;
                        // pos stays wherever it was; idle slots are ignored
                    }
                    Slot::Busy { req, fed, pos, response, .. } => {
                        any_busy = true;
                        stats.busy_slot_steps += 1;
                        let next = if *fed < req.prompt_ids.len() {
                            req.prompt_ids[*fed]
                        } else {
                            *response.last().expect("decode phase has a last token")
                        };
                        tok_v[i] = next;
                        pos_v[i] = *pos;
                    }
                }
            }
            if !any_busy {
                break;
            }

            let pos_t = Tensor::i32(&[self.batch], pos_v.clone())?;
            let tok_t = Tensor::i32(&[self.batch], tok_v.clone())?;
            let (logits, new_kv) = policy.decode_step(engine, &kv, &pos_t, &tok_t)?;
            kv = new_kv;
            stats.decode_steps += 1;
            let v = engine.manifest.model.vocab_size;
            let lraw = logits.as_f32()?;

            // advance each busy slot
            for (i, slot) in slots.iter_mut().enumerate() {
                let mut finished: Option<GenResult> = None;
                if let Slot::Busy { req, fed, pos, response, logprobs } = slot {
                    *pos += 1;
                    if *fed < req.prompt_ids.len() {
                        *fed += 1;
                        // still prefilling: sample only once the full
                        // prompt is in
                        if *fed < req.prompt_ids.len() {
                            continue;
                        }
                    }
                    // sample the next token from this slot's logits row
                    let row = &lraw[i * v..(i + 1) * v];
                    let tok = self.params.sample(row, rng) as i32;
                    response.push(tok);
                    logprobs.push(token_logprob(row, tok as usize));
                    stats.tokens_generated += 1;
                    let (fin, by_eos) = super::scheduler::seq_finished(
                        tok,
                        self.eos_id,
                        response.len(),
                        req.max_new_tokens,
                        *pos,
                        self.max_seq,
                    );
                    if fin {
                        finished = Some(GenResult {
                            id: req.id,
                            response_ids: std::mem::take(response),
                            response_logprobs: std::mem::take(logprobs),
                            finished_by_eos: by_eos,
                        });
                    }
                }
                if let Some(r) = finished {
                    results.push(r);
                    // continuous batching: swap the next request in now
                    *slot = match pop_runnable(&mut queue, &mut results, self.max_seq) {
                        Some(req) => {
                            stats.prompt_tokens += req.prompt_ids.len() as u64;
                            pos_v[i] = 0;
                            Slot::Busy {
                                req,
                                fed: 0,
                                pos: 0,
                                response: Vec::new(),
                                logprobs: Vec::new(),
                            }
                        }
                        None => Slot::Idle,
                    };
                }
            }
        }

        stats.wall_secs = t0.elapsed().as_secs_f64();
        debug_assert_eq!(results.len(), n_total);
        Ok((results, stats))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact_dir;

    fn setup() -> (Engine, Policy) {
        let engine = Engine::load(artifact_dir("tiny")).expect("make artifacts first");
        let policy = Policy::load_initial(&engine, 1e-3).unwrap();
        (engine, policy)
    }

    #[test]
    fn generates_all_requests_even_beyond_batch() {
        let (engine, policy) = setup();
        let ge = GenEngine::from_manifest(&engine, SamplingParams::default()).unwrap();
        let n = ge.batch * 2 + 3; // forces continuous refill
        let reqs: Vec<GenRequest> = (0..n)
            .map(|i| GenRequest {
                id: i as u64,
                prompt_ids: vec![1, 5, 6, 7],
                max_new_tokens: 5,
            })
            .collect();
        let mut rng = Rng::new(0);
        let (results, stats) = ge.generate(&engine, &policy, reqs, &mut rng).unwrap();
        assert_eq!(results.len(), n);
        let mut ids: Vec<u64> = results.iter().map(|r| r.id).collect();
        ids.sort();
        assert_eq!(ids, (0..n as u64).collect::<Vec<_>>());
        for r in &results {
            assert!(!r.response_ids.is_empty() && r.response_ids.len() <= 5);
            assert_eq!(
                r.response_logprobs.len(),
                r.response_ids.len(),
                "one behavior logprob per sampled token"
            );
            assert!(r.response_logprobs.iter().all(|lp| lp.is_finite() && *lp <= 0.0));
        }
        assert!(
            stats.occupancy() > 0.5,
            "refill should keep slots busy: {}",
            stats.occupancy()
        );
        assert!(stats.busy_slot_steps <= stats.total_slot_steps);
        assert!(stats.tokens_generated >= n as u64);
    }

    #[test]
    fn empty_request_list_returns_empty() {
        let (engine, policy) = setup();
        let ge = GenEngine::from_manifest(&engine, SamplingParams::default()).unwrap();
        let mut rng = Rng::new(0);
        let (results, stats) = ge.generate(&engine, &policy, Vec::new(), &mut rng).unwrap();
        assert!(results.is_empty());
        assert_eq!(stats.tokens_generated, 0);
        assert_eq!(stats.occupancy(), 0.0);
    }

    #[test]
    fn zero_max_new_tokens_yields_empty_response() {
        let (engine, policy) = setup();
        let ge = GenEngine::from_manifest(&engine, SamplingParams::default()).unwrap();
        let reqs = vec![
            GenRequest { id: 0, prompt_ids: vec![1, 3], max_new_tokens: 0 },
            GenRequest { id: 1, prompt_ids: vec![1, 3], max_new_tokens: 3 },
        ];
        let mut rng = Rng::new(0);
        let (results, _) = ge.generate(&engine, &policy, reqs, &mut rng).unwrap();
        assert_eq!(results.len(), 2);
        let zero = results.iter().find(|r| r.id == 0).unwrap();
        assert!(zero.response_ids.is_empty(), "zero budget must not emit a token");
        assert!(!zero.finished_by_eos);
        let live = results.iter().find(|r| r.id == 1).unwrap();
        assert!(!live.response_ids.is_empty());
    }

    #[test]
    fn prompt_at_or_over_max_seq_yields_empty_response() {
        let (engine, policy) = setup();
        let ge = GenEngine::from_manifest(&engine, SamplingParams::default()).unwrap();
        let ms = engine.manifest.model.max_seq;
        let reqs = vec![
            GenRequest { id: 0, prompt_ids: vec![1; ms], max_new_tokens: 4 },
            GenRequest { id: 1, prompt_ids: vec![1; ms + 5], max_new_tokens: 4 },
        ];
        let mut rng = Rng::new(0);
        let (results, _) = ge.generate(&engine, &policy, reqs, &mut rng).unwrap();
        assert_eq!(results.len(), 2);
        assert!(
            results.iter().all(|r| r.response_ids.is_empty()),
            "a prompt with no room to sample must complete empty, not overrun KV"
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let (engine, policy) = setup();
        let ge = GenEngine::from_manifest(&engine, SamplingParams::default()).unwrap();
        let mk = || {
            (0..4)
                .map(|i| GenRequest {
                    id: i as u64,
                    prompt_ids: vec![1, 3, 4],
                    max_new_tokens: 4,
                })
                .collect::<Vec<_>>()
        };
        let (a, _) = ge.generate(&engine, &policy, mk(), &mut Rng::new(7)).unwrap();
        let (b, _) = ge.generate(&engine, &policy, mk(), &mut Rng::new(7)).unwrap();
        let ta: Vec<_> = a.iter().map(|r| r.response_ids.clone()).collect();
        let tb: Vec<_> = b.iter().map(|r| r.response_ids.clone()).collect();
        assert_eq!(ta, tb);
    }

    #[test]
    fn respects_max_seq() {
        let (engine, policy) = setup();
        let ge = GenEngine::from_manifest(&engine, SamplingParams::default()).unwrap();
        let long = engine.manifest.model.max_seq + 10;
        let reqs = vec![GenRequest { id: 0, prompt_ids: vec![1, 3], max_new_tokens: long }];
        let mut rng = Rng::new(1);
        let (results, _) = ge.generate(&engine, &policy, reqs, &mut rng).unwrap();
        assert!(results[0].response_ids.len() + 2 <= engine.manifest.model.max_seq);
    }
}
