//! Paged KV-cache accounting for the streaming generation scheduler.
//!
//! The decode artifact's KV cache is one monolithic tensor sized for
//! `batch × max_seq` tokens, so this module does not move bytes — it makes
//! the cache's *occupancy* visible to the tracked [`MemoryPool`] the way
//! vLLM's block tables make it visible to the allocator. Every admitted
//! sequence charges fixed-size token blocks (`block_tokens` tokens each)
//! against the pool; retirement frees them. The invariant the tests pin:
//!
//! ```text
//! pool.live_bytes() == live_blocks() × block_bytes()
//! ```
//!
//! Paging is **reservation-at-admission**: a sequence reserves its full
//! worst-case block count (`min(prompt_len + max_new, max_seq)` tokens,
//! rounded up to whole blocks) when it is admitted, so a mid-decode
//! allocation can never fail — admission is the single backpressure
//! point. When the pool is tight, [`KvBlockAllocator::try_admit`] returns
//! `None` and the scheduler defers the sequence (it stays queued; nothing
//! errors and nothing tramples live cache rows — the failure mode the
//! vLLM-on-NPU memory patches exist to prevent is exactly an implicit
//! allocator letting a new sequence land on pages a live one still owns).

use std::collections::HashMap;
use std::sync::Arc;

use crate::memory::{BufferId, MemoryPool, TenantQuotas};

/// A sequence's block reservation: the pool buffer ids backing it.
#[derive(Debug)]
struct SeqBlocks {
    blocks: Vec<BufferId>,
    tokens_reserved: usize,
    /// tenant the reservation is charged to (0 = default tenant)
    tenant: u32,
}

/// Block-granular KV accounting against a tracked [`MemoryPool`].
#[derive(Debug)]
pub struct KvBlockAllocator {
    pool: Arc<MemoryPool>,
    /// tokens per block (the paging granularity)
    block_tokens: usize,
    /// bytes one block charges to the pool
    block_bytes: u64,
    seqs: HashMap<u64, SeqBlocks>,
    live_blocks: u64,
    /// admissions deferred because the pool was tight (backpressure events)
    deferrals: u64,
    /// per-tenant quota registry: when set, every reservation is charged
    /// to its sequence's tenant *before* touching the pool, so one
    /// tenant's burst defers its own admissions instead of exhausting the
    /// shared pool under its siblings (tenant-level backpressure in front
    /// of the pool-level kind)
    quotas: Option<Arc<TenantQuotas>>,
}

impl KvBlockAllocator {
    /// `bytes_per_token` is the KV footprint of one token in one slot —
    /// for the monolithic decode artifact, `kv.size_bytes() / (batch ×
    /// max_seq)`.
    pub fn new(pool: Arc<MemoryPool>, block_tokens: usize, bytes_per_token: u64) -> Self {
        assert!(block_tokens >= 1, "kv block size must be at least one token");
        Self {
            pool,
            block_tokens,
            block_bytes: block_tokens as u64 * bytes_per_token,
            seqs: HashMap::new(),
            live_blocks: 0,
            deferrals: 0,
            quotas: None,
        }
    }

    /// Attach a per-tenant quota registry; subsequent admissions via
    /// [`Self::try_admit_for`] charge their tenant before reserving.
    pub fn set_tenant_quotas(&mut self, quotas: Arc<TenantQuotas>) {
        self.quotas = Some(quotas);
    }

    /// Pool capacity (in blocks) that exactly covers a `batch × max_seq`
    /// monolithic cache after block rounding — sized so a full slot set
    /// of worst-case sequences always fits, mirroring the physical
    /// tensor.
    pub fn capacity_bytes_for(batch: usize, max_seq: usize, block_tokens: usize, bytes_per_token: u64) -> u64 {
        let blocks_per_seq = max_seq.div_ceil(block_tokens.max(1)) as u64;
        batch as u64 * blocks_per_seq * block_tokens.max(1) as u64 * bytes_per_token
    }

    /// Blocks needed to hold `tokens` tokens.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_tokens).max(1)
    }

    /// Reserve blocks for a sequence's worst case. Returns the block
    /// count on success; `None` means the pool is tight and admission
    /// must be deferred (counted as a backpressure event). Never panics
    /// and never partially reserves: a failed admission rolls back every
    /// block it grabbed.
    pub fn try_admit(&mut self, seq_id: u64, worst_case_tokens: usize) -> Option<usize> {
        self.try_admit_for(seq_id, 0, worst_case_tokens)
    }

    /// [`Self::try_admit`] with an explicit tenant: when a quota registry
    /// is attached, the reservation's bytes are charged to the tenant
    /// first and a tenant over quota is deferred *without touching the
    /// pool* — other tenants' admissions see the same free pool they
    /// would have seen had the over-quota tenant never asked.
    pub fn try_admit_for(&mut self, seq_id: u64, tenant: u32, worst_case_tokens: usize) -> Option<usize> {
        debug_assert!(!self.seqs.contains_key(&seq_id), "sequence {seq_id} admitted twice");
        let n = self.blocks_for(worst_case_tokens);
        let bytes = n as u64 * self.block_bytes;
        if let Some(q) = &self.quotas {
            if !q.try_charge(tenant, bytes) {
                self.deferrals += 1;
                return None;
            }
        }
        let mut blocks = Vec::with_capacity(n);
        for b in 0..n {
            match self.pool.alloc(format!("kv.t{tenant}.seq{seq_id}.b{b}"), self.block_bytes) {
                Ok(id) => blocks.push(id),
                Err(_) => {
                    // backpressure, not an error: roll back and defer
                    for id in blocks {
                        self.pool.free(id).expect("rollback frees blocks we just allocated");
                    }
                    if let Some(q) = &self.quotas {
                        q.uncharge(tenant, bytes);
                    }
                    self.deferrals += 1;
                    return None;
                }
            }
        }
        self.live_blocks += n as u64;
        self.seqs.insert(seq_id, SeqBlocks { blocks, tokens_reserved: n * self.block_tokens, tenant });
        Some(n)
    }

    /// Free every block a retired sequence holds. Unknown ids are a
    /// caller bug only in debug builds (a reclaimed-then-retired claim
    /// may legitimately release twice under chaos).
    pub fn release(&mut self, seq_id: u64) {
        if let Some(s) = self.seqs.remove(&seq_id) {
            self.live_blocks -= s.blocks.len() as u64;
            if let Some(q) = &self.quotas {
                q.uncharge(s.tenant, s.blocks.len() as u64 * self.block_bytes);
            }
            for id in s.blocks {
                self.pool.free(id).expect("kv blocks are pool-backed until release");
            }
        }
    }

    /// Tenant a live sequence's reservation is charged to.
    pub fn tenant_of(&self, seq_id: u64) -> Option<u32> {
        self.seqs.get(&seq_id).map(|s| s.tenant)
    }

    /// Would this tenant's quota alone reject a reservation of
    /// `worst_case_tokens` right now? Pure check (nothing charged, no
    /// deferral counted) — the scheduler uses it after a failed admission
    /// to tell quota backpressure (skip just this request; siblings
    /// behind it stay admissible) from pool backpressure (head-block,
    /// FIFO). Always false without a quota registry.
    pub fn quota_would_defer(&self, tenant: u32, worst_case_tokens: usize) -> bool {
        let Some(q) = &self.quotas else { return false };
        let n = self.blocks_for(worst_case_tokens);
        !q.can_charge(tenant, n as u64 * self.block_bytes)
    }

    pub fn holds(&self, seq_id: u64) -> bool {
        self.seqs.contains_key(&seq_id)
    }

    /// Tokens reserved for a live sequence (block-rounded).
    pub fn reserved_tokens(&self, seq_id: u64) -> Option<usize> {
        self.seqs.get(&seq_id).map(|s| s.tokens_reserved)
    }

    pub fn live_blocks(&self) -> u64 {
        self.live_blocks
    }

    pub fn live_seqs(&self) -> usize {
        self.seqs.len()
    }

    pub fn block_bytes(&self) -> u64 {
        self.block_bytes
    }

    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    /// Admissions deferred on pool pressure so far.
    pub fn deferrals(&self) -> u64 {
        self.deferrals
    }

    /// The paging invariant: every live block is exactly one pool buffer.
    pub fn invariant_holds(&self) -> bool {
        self.pool.live_bytes() == self.live_blocks * self.block_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool(blocks: u64, block_tokens: usize, bytes_per_token: u64) -> Arc<MemoryPool> {
        Arc::new(MemoryPool::new(
            "kv-test",
            blocks * block_tokens as u64 * bytes_per_token,
        ))
    }

    #[test]
    fn admission_charges_block_rounded_bytes() {
        let p = pool(8, 16, 4);
        let mut a = KvBlockAllocator::new(Arc::clone(&p), 16, 4);
        // 20 tokens → 2 blocks of 16
        assert_eq!(a.try_admit(1, 20), Some(2));
        assert_eq!(a.live_blocks(), 2);
        assert_eq!(a.reserved_tokens(1), Some(32));
        assert_eq!(p.live_bytes(), 2 * 16 * 4);
        assert!(a.invariant_holds());
    }

    #[test]
    fn exhaustion_defers_instead_of_erroring() {
        // room for exactly 3 blocks
        let p = pool(3, 8, 2);
        let mut a = KvBlockAllocator::new(Arc::clone(&p), 8, 2);
        assert_eq!(a.try_admit(0, 16), Some(2));
        // needs 2 blocks, only 1 free: deferred, partial grab rolled back
        assert_eq!(a.try_admit(1, 16), None);
        assert_eq!(a.deferrals(), 1);
        assert!(!a.holds(1));
        assert_eq!(a.live_blocks(), 2, "failed admission must roll back fully");
        assert!(a.invariant_holds());
        // a 1-block sequence still fits
        assert_eq!(a.try_admit(2, 5), Some(1));
        assert!(a.invariant_holds());
    }

    #[test]
    fn release_returns_pool_to_baseline() {
        let p = pool(16, 4, 8);
        let baseline = p.live_bytes();
        let mut a = KvBlockAllocator::new(Arc::clone(&p), 4, 8);
        for id in 0..5u64 {
            assert!(a.try_admit(id, 4 + id as usize).is_some());
        }
        assert!(a.invariant_holds());
        for id in 0..5u64 {
            a.release(id);
        }
        assert_eq!(a.live_blocks(), 0);
        assert_eq!(a.live_seqs(), 0);
        assert_eq!(p.live_bytes(), baseline, "drain must return the pool to baseline");
        assert!(a.invariant_holds());
    }

    #[test]
    fn double_release_is_harmless() {
        let p = pool(4, 4, 1);
        let mut a = KvBlockAllocator::new(Arc::clone(&p), 4, 1);
        a.try_admit(7, 4).unwrap();
        a.release(7);
        a.release(7); // chaos: reclaimed claim retired twice
        assert_eq!(p.live_bytes(), 0);
        assert!(a.invariant_holds());
    }

    #[test]
    fn zero_token_admission_still_reserves_one_block() {
        let p = pool(2, 4, 1);
        let mut a = KvBlockAllocator::new(Arc::clone(&p), 4, 1);
        // max_new_tokens = 0 with an empty prompt still occupies a slot
        assert_eq!(a.try_admit(0, 0), Some(1));
        assert!(a.invariant_holds());
    }

    #[test]
    fn tenant_quota_defers_before_the_pool_is_touched() {
        use crate::memory::TenantQuotas;
        // pool has room for 8 blocks, but tenant 1 is capped at 2
        let p = pool(8, 4, 1);
        let mut a = KvBlockAllocator::new(Arc::clone(&p), 4, 1);
        let q = Arc::new(TenantQuotas::new());
        q.set_quota(1, Some(2 * a.block_bytes()));
        a.set_tenant_quotas(Arc::clone(&q));
        assert_eq!(a.try_admit_for(0, 1, 8), Some(2));
        // tenant 1 at quota: deferred with the pool untouched
        let before = p.live_bytes();
        assert_eq!(a.try_admit_for(1, 1, 4), None);
        assert_eq!(p.live_bytes(), before, "quota deferral must not touch the pool");
        assert_eq!(a.deferrals(), 1);
        // tenant 2 (uncapped) still admits into the shared headroom
        assert_eq!(a.try_admit_for(2, 2, 8), Some(2));
        assert_eq!(a.tenant_of(2), Some(2));
        assert!(a.invariant_holds());
        // releasing tenant 1's reservation reopens its quota
        a.release(0);
        assert_eq!(q.charged(1), 0);
        assert_eq!(a.try_admit_for(3, 1, 4), Some(1));
        assert!(a.invariant_holds());
    }

    #[test]
    fn default_admission_charges_tenant_zero() {
        use crate::memory::TenantQuotas;
        let p = pool(4, 4, 1);
        let mut a = KvBlockAllocator::new(Arc::clone(&p), 4, 1);
        let q = Arc::new(TenantQuotas::new());
        a.set_tenant_quotas(Arc::clone(&q));
        assert_eq!(a.try_admit(9, 4), Some(1));
        assert_eq!(a.tenant_of(9), Some(0));
        assert_eq!(q.charged(0), a.block_bytes());
        a.release(9);
        assert_eq!(q.charged(0), 0, "release must uncharge the tenant");
    }

    #[test]
    fn capacity_helper_always_fits_a_full_slot_set() {
        for (batch, max_seq, block) in [(4, 64, 16), (3, 100, 7), (8, 33, 32)] {
            let cap = KvBlockAllocator::capacity_bytes_for(batch, max_seq, block, 2);
            let p = Arc::new(MemoryPool::new("kv", cap));
            let mut a = KvBlockAllocator::new(Arc::clone(&p), block, 2);
            for id in 0..batch as u64 {
                assert!(
                    a.try_admit(id, max_seq).is_some(),
                    "batch={batch} max_seq={max_seq} block={block}: slot {id} must fit"
                );
            }
            assert!(a.invariant_holds());
        }
    }
}
