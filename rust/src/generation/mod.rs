//! Generation engine: the actor worker's generation state.
//!
//! A vLLM-style continuous batcher over the AOT `decode_step` artifact:
//! the artifact's batch dimension is a set of *slots*, each holding an
//! independent sequence at its own position (the decode program masks
//! attention per-slot). When a slot finishes (EOS / length cap) the next
//! waiting request is swapped in immediately — no draining barrier — which
//! is what keeps the batch full under the long-tail response lengths the
//! paper's generation stage faces.

mod batcher;
mod sampler;

pub use batcher::{GenEngine, GenRequest, GenResult, GenStats};
pub use sampler::{token_logprob, SamplingParams};
