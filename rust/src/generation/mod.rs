//! Generation engine: the actor worker's generation state.
//!
//! A vLLM-style continuous batcher over the AOT `decode_step` artifact:
//! the artifact's batch dimension is a set of *slots*, each holding an
//! independent sequence at its own position (the decode program masks
//! attention per-slot). When a slot finishes (EOS / length cap) the next
//! waiting request is swapped in immediately — no draining barrier — which
//! is what keeps the batch full under the long-tail response lengths the
//! paper's generation stage faces.
//!
//! [`GenEngine`] refills slots within one `generate()` call; [`GenSession`]
//! (`--gen-streaming`) extends the same slot machinery *across* claims:
//! a persistent session the stage worker steps externally, admitting newly
//! claimed samples at decode-step granularity, chunking prefill, retiring
//! finished sequences one at a time, and charging KV occupancy through the
//! paged [`KvBlockAllocator`].

mod batcher;
mod kv_cache;
mod sampler;
mod scheduler;

pub use batcher::{GenEngine, GenRequest, GenResult, GenStats};
pub use kv_cache::KvBlockAllocator;
pub use sampler::{token_logprob, SamplingParams};
pub use scheduler::{GenSession, SeqExport, StreamConfig, StreamStats};
