//! Token sampling from decode logits.

use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy)]
pub struct SamplingParams {
    pub temperature: f32,
    /// consider only the top-k logits (0 = all)
    pub top_k: usize,
}

impl Default for SamplingParams {
    fn default() -> Self {
        Self { temperature: 1.0, top_k: 0 }
    }
}

impl SamplingParams {
    pub fn greedy() -> Self {
        Self { temperature: 0.0, top_k: 0 }
    }

    /// Sample a token id from one slot's logits row.
    pub fn sample(&self, logits: &[f32], rng: &mut Rng) -> usize {
        if self.top_k == 0 || self.top_k >= logits.len() {
            return rng.sample_logits(logits, self.temperature);
        }
        // top-k: mask everything below the k-th largest logit
        let mut idx: Vec<usize> = (0..logits.len()).collect();
        idx.sort_by(|&a, &b| logits[b].partial_cmp(&logits[a]).unwrap());
        let keep = &idx[..self.top_k];
        let mut masked = vec![f32::NEG_INFINITY; logits.len()];
        for &i in keep {
            masked[i] = logits[i];
        }
        rng.sample_logits(&masked, self.temperature)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_argmax() {
        let mut rng = Rng::new(0);
        let p = SamplingParams::greedy();
        assert_eq!(p.sample(&[0.1, 0.9, 0.5], &mut rng), 1);
    }

    #[test]
    fn top_k_restricts_support() {
        let mut rng = Rng::new(1);
        let p = SamplingParams { temperature: 1.0, top_k: 2 };
        let logits = [5.0, 4.9, -10.0, -10.0];
        for _ in 0..100 {
            let t = p.sample(&logits, &mut rng);
            assert!(t < 2, "sampled outside top-k: {t}");
        }
    }

    #[test]
    fn temperature_spreads_mass() {
        let mut rng = Rng::new(2);
        let hot = SamplingParams { temperature: 5.0, top_k: 0 };
        let logits = [2.0, 0.0];
        let picks: usize =
            (0..2000).map(|_| hot.sample(&logits, &mut rng)).filter(|&t| t == 1).count();
        assert!(picks > 300, "high temperature must visit the low-logit arm ({picks})");
    }
}
