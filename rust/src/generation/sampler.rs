//! Token sampling from decode logits, plus the behavior-logprob helper
//! the generation stage uses to emit `old_lp` directly (the logits are
//! already in hand when sampling, so the old-logprob recompute becomes a
//! verify-or-fill state instead of a mandatory second forward pass).

use crate::util::rng::Rng;

/// Log-probability of `token` under `softmax(logits)` — temperature 1 and
/// full support regardless of the sampling parameters, matching the
/// `logprobs` artifact's definition (log-softmax of the raw logits), so a
/// generation-emitted behavior logprob is directly comparable to a
/// recompute through the inference path under the same weights.
pub fn token_logprob(logits: &[f32], token: usize) -> f32 {
    let max = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max) as f64;
    let sum: f64 = logits.iter().map(|&l| (l as f64 - max).exp()).sum();
    (logits[token] as f64 - max - sum.ln()) as f32
}

#[derive(Debug, Clone, Copy)]
pub struct SamplingParams {
    pub temperature: f32,
    /// consider only the top-k logits (0 = all)
    pub top_k: usize,
}

impl Default for SamplingParams {
    fn default() -> Self {
        Self { temperature: 1.0, top_k: 0 }
    }
}

impl SamplingParams {
    pub fn greedy() -> Self {
        Self { temperature: 0.0, top_k: 0 }
    }

    /// RNG draws consumed per sampled token under these parameters.
    /// Stochastic sampling makes exactly one draw per token (`categorical`
    /// draws once in every branch, including its degenerate fallback; the
    /// top-k mask changes the weights, not the draw count); greedy argmax
    /// makes none. Resuming a sequence from a persisted prefix of `n`
    /// tokens therefore means `Rng::skip(n * draws_per_token())` — the
    /// continuation is then bit-identical to an uninterrupted run.
    pub fn draws_per_token(&self) -> usize {
        if self.temperature > 1e-6 { 1 } else { 0 }
    }

    /// Sample a token id from one slot's logits row.
    pub fn sample(&self, logits: &[f32], rng: &mut Rng) -> usize {
        if self.top_k == 0 || self.top_k >= logits.len() {
            return rng.sample_logits(logits, self.temperature);
        }
        // top-k: mask everything below the k-th largest logit
        let mut idx: Vec<usize> = (0..logits.len()).collect();
        idx.sort_by(|&a, &b| logits[b].partial_cmp(&logits[a]).unwrap());
        let keep = &idx[..self.top_k];
        let mut masked = vec![f32::NEG_INFINITY; logits.len()];
        for &i in keep {
            masked[i] = logits[i];
        }
        rng.sample_logits(&masked, self.temperature)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_argmax() {
        let mut rng = Rng::new(0);
        let p = SamplingParams::greedy();
        assert_eq!(p.sample(&[0.1, 0.9, 0.5], &mut rng), 1);
    }

    #[test]
    fn top_k_restricts_support() {
        let mut rng = Rng::new(1);
        let p = SamplingParams { temperature: 1.0, top_k: 2 };
        let logits = [5.0, 4.9, -10.0, -10.0];
        for _ in 0..100 {
            let t = p.sample(&logits, &mut rng);
            assert!(t < 2, "sampled outside top-k: {t}");
        }
    }

    #[test]
    fn token_logprob_is_log_softmax() {
        let logits = [1.0f32, 2.0, 3.0];
        let z: f64 = logits.iter().map(|&l| (l as f64).exp()).sum();
        for (t, &l) in logits.iter().enumerate() {
            let want = (l as f64 - z.ln()) as f32;
            assert!((token_logprob(&logits, t) - want).abs() < 1e-6);
        }
        // a proper distribution: probs sum to 1
        let total: f64 = (0..3).map(|t| (token_logprob(&logits, t) as f64).exp()).sum();
        assert!((total - 1.0).abs() < 1e-6, "{total}");
    }

    #[test]
    fn token_logprob_stable_for_large_logits() {
        let logits = [1000.0f32, 999.0];
        let lp = token_logprob(&logits, 0);
        assert!(lp.is_finite() && lp < 0.0);
        assert!((lp - (-(1.0 + (-1.0f64).exp()).ln()) as f32).abs() < 1e-5);
    }

    #[test]
    fn skipped_rng_resumes_bit_identical_token_stream() {
        // the resume invariant in miniature: sample k tokens, throw the
        // session away, then fast-forward a fresh RNG by k draws — the
        // continuation must match the uninterrupted stream exactly
        for p in [
            SamplingParams { temperature: 1.0, top_k: 0 },
            SamplingParams { temperature: 0.7, top_k: 3 },
            SamplingParams::greedy(),
        ] {
            let logits: Vec<Vec<f32>> =
                (0..40).map(|i| (0..8).map(|j| ((i * 7 + j * 3) % 11) as f32 * 0.3).collect()).collect();
            let mut uninterrupted = Rng::new(99);
            let full: Vec<usize> = logits.iter().map(|l| p.sample(l, &mut uninterrupted)).collect();
            let k = 13;
            let mut resumed = Rng::new(99);
            resumed.skip(k * p.draws_per_token());
            let tail: Vec<usize> = logits[k..].iter().map(|l| p.sample(l, &mut resumed)).collect();
            assert_eq!(tail, full[k..], "resume diverged at top_k={} temp={}", p.top_k, p.temperature);
        }
    }

    #[test]
    fn temperature_spreads_mass() {
        let mut rng = Rng::new(2);
        let hot = SamplingParams { temperature: 5.0, top_k: 0 };
        let logits = [2.0, 0.0];
        let picks: usize =
            (0..2000).map(|_| hot.sample(&logits, &mut rng)).filter(|&t| t == 1).count();
        assert!(picks > 300, "high temperature must visit the low-logit arm ({picks})");
    }
}
