//! Streaming generation scheduler: cross-claim continuous batching.
//!
//! [`GenEngine::generate`](super::GenEngine::generate) refills slots
//! *within* one call but still runs a claimed batch to completion — the
//! long tail of each claim holds every finished sequence's writeback
//! hostage and newly ready samples cannot join in-flight decode. A
//! [`GenSession`] is the long-lived alternative: it owns the decode
//! slots, the KV tensor, and the paged KV accounting **across claims**,
//! and exposes decode as an externally driven [`GenSession::step`] so the
//! gen stage worker can, between steps,
//!
//! * admit newly claimed samples at decode-step granularity
//!   ([`GenSession::submit`] into any idle slot, gated by
//!   [`KvBlockAllocator`] admission),
//! * retire finished sequences immediately (each `step` returns the
//!   sequences that completed on that step, for per-sequence writeback),
//! * renew its claim leases on a decode-tick cadence so long sequences
//!   never expire mid-decode.
//!
//! **Chunked prefill.** The decode artifact consumes one token per slot
//! per call, so a prompt of `P` tokens classically costs `P` steps during
//! which the slot produces nothing. With `prefill_chunk = K > 1`, a
//! `step` runs up to `K` back-to-back decode calls in which *prefilling*
//! slots consume one prompt token each while *decoding* slots are frozen:
//! a frozen slot re-feeds the token it fed on its last advancing call at
//! the same position, which rewrites its current KV row with identical
//! bytes (a slot's KV row depends only on its own token at that position
//! and its own earlier rows — per-slot attention masking isolates lanes),
//! so freezing is idempotent and prefill drains `K×` faster without
//! perturbing in-flight decodes.
//!
//! **Per-sequence sampling streams.** The batch engine draws from one
//! shared RNG, so its token stream depends on slot packing. A session
//! derives an independent stream per sequence (`seed ⊕ id`), making each
//! sequence's tokens a pure function of `(seed, id, prompt)` — invariant
//! under admission timing, chunk size, and slot assignment. That is what
//! lets streaming mode retire the identical sample set as batch mode in
//! the differential suites.

use anyhow::Result;
use std::collections::{HashMap, VecDeque};
use std::sync::Arc;

use super::batcher::{GenRequest, GenResult};
use super::kv_cache::KvBlockAllocator;
use super::sampler::{token_logprob, SamplingParams};
use crate::memory::TenantQuotas;
use crate::runtime::{Engine, Policy, Tensor};
use crate::util::rng::Rng;

/// Session geometry + sampling configuration.
#[derive(Debug, Clone)]
pub struct StreamConfig {
    /// decode artifact batch — the slot count
    pub batch: usize,
    pub max_seq: usize,
    pub eos_id: i32,
    pub pad_id: i32,
    pub params: SamplingParams,
    /// prompt tokens a prefilling slot may consume per scheduler step
    pub prefill_chunk: usize,
    /// base seed for the per-sequence sampling streams
    pub seed: u64,
}

impl StreamConfig {
    pub fn from_manifest(
        engine: &Engine,
        params: SamplingParams,
        prefill_chunk: usize,
        seed: u64,
    ) -> Result<Self> {
        let a = engine.manifest.artifact("decode_step")?;
        Ok(Self {
            batch: a.batch,
            max_seq: engine.manifest.model.max_seq,
            eos_id: engine.manifest.eos_id as i32,
            pad_id: engine.manifest.pad_id as i32,
            params,
            prefill_chunk: prefill_chunk.max(1),
            seed,
        })
    }
}

/// Cumulative session statistics. Occupancy is carried as raw slot-step
/// counters (never a pre-divided ratio) so merges across sessions and
/// replicas stay weighted correctly.
#[derive(Debug, Clone, Copy, Default)]
pub struct StreamStats {
    /// scheduler steps ([`GenSession::step`] calls that did work)
    pub steps: u64,
    /// engine decode calls (≥ steps: chunked prefill adds micro-calls)
    pub decode_calls: u64,
    /// slot-calls that advanced a live sequence
    pub busy_slot_steps: u64,
    /// slot-calls total (busy + idle + frozen)
    pub total_slot_steps: u64,
    pub tokens_generated: u64,
    pub prompt_tokens: u64,
    /// sequences admitted into a slot
    pub admitted: u64,
    /// sequences retired (incl. degenerate immediate completions)
    pub retired: u64,
    /// steps on which at least one sequence retired
    pub retire_steps: u64,
    /// most sequences retired on a single step
    pub max_retired_in_step: u64,
    /// Σ (admission step − submit step) over admitted sequences
    pub admit_wait_steps: u64,
    /// Σ (first-token step − admission step) over started sequences
    pub first_token_steps: u64,
    /// sequences that have sampled at least one token
    pub first_token_seqs: u64,
    /// admissions deferred on KV-pool backpressure
    pub kv_deferrals: u64,
    /// sequences admitted from a persisted partial prefix
    pub resumed: u64,
    /// prefix tokens handed back at resume — decode work *not* redone
    pub resumed_tokens: u64,
}

impl StreamStats {
    pub fn occupancy(&self) -> f64 {
        if self.total_slot_steps == 0 {
            0.0
        } else {
            self.busy_slot_steps as f64 / self.total_slot_steps as f64
        }
    }

    /// Mean scheduler steps from admission to first sampled token, or
    /// `None` before any sequence has produced one — a mean over zero
    /// sequences has no value, and the raw `0/0` quotient is NaN, which
    /// must never reach gated bench JSON (callers print `n/a` or omit
    /// the metric, mirroring the `MIN_WALL_SECS` convention for rates).
    pub fn mean_ttft_steps(&self) -> Option<f64> {
        (self.first_token_seqs > 0)
            .then(|| self.first_token_steps as f64 / self.first_token_seqs as f64)
    }

    /// Mean scheduler steps a request waited before getting a slot, or
    /// `None` before any admission (same no-data convention as
    /// [`Self::mean_ttft_steps`]).
    pub fn mean_admit_wait_steps(&self) -> Option<f64> {
        (self.admitted > 0).then(|| self.admit_wait_steps as f64 / self.admitted as f64)
    }
}

/// The decode-loop finish rule, shared by the batch engine and the
/// session and unit-tested directly: `tok` was just sampled as the
/// `resp_len`-th response token with the slot now at `pos`.
/// Returns `(finished, by_eos)`.
pub(crate) fn seq_finished(
    tok: i32,
    eos_id: i32,
    resp_len: usize,
    max_new_tokens: usize,
    pos: i32,
    max_seq: usize,
) -> (bool, bool) {
    let by_eos = tok == eos_id;
    let by_len = resp_len >= max_new_tokens || (pos as usize) + 1 >= max_seq;
    (by_eos || by_len, by_eos)
}

struct ActiveSeq {
    req: GenRequest,
    /// feed tokens consumed so far (feed = prompt ++ resumed prefix)
    fed: usize,
    pos: i32,
    /// response tokens; indices `0..prefix_len` came from a resumed
    /// prefix (re-prefilled, never re-sampled)
    response: Vec<i32>,
    logprobs: Vec<f32>,
    rng: Rng,
    /// token/pos fed on this slot's last advancing decode call — what a
    /// frozen slot re-feeds (identical KV rewrite)
    frozen: (i32, i32),
    /// resumed-prefix length (0 for a fresh sequence)
    prefix_len: usize,
    admitted_at: u64,
}

impl ActiveSeq {
    /// Total tokens the engine must consume before sampling starts.
    fn feed_len(&self) -> usize {
        self.req.prompt_ids.len() + self.prefix_len
    }

    /// The `i`-th feed token: prompt first, then the resumed prefix
    /// (which lives at the front of `response`).
    fn feed_token(&self, i: usize) -> i32 {
        let np = self.req.prompt_ids.len();
        if i < np {
            self.req.prompt_ids[i]
        } else {
            self.response[i - np]
        }
    }
}

enum Slot {
    Idle,
    Busy(Box<ActiveSeq>),
}

struct Pending {
    req: GenRequest,
    /// resumed prefix (empty for fresh submissions): already-decoded
    /// response tokens to re-prefill instead of re-sample
    prefix_ids: Vec<i32>,
    prefix_lps: Vec<f32>,
    submitted_at: u64,
}

/// A live sequence's decoded state, exported when the session abandons
/// it (kill, cooperative drain, weight-publish preemption) so the caller
/// can persist it through the transfer dock and a redispatch can resume
/// from the prefix instead of the prompt.
#[derive(Debug, Clone)]
pub struct SeqExport {
    pub id: u64,
    /// full decoded response so far, including any resumed prefix
    pub response_ids: Vec<i32>,
    pub response_logprobs: Vec<f32>,
    /// how many leading response tokens were themselves resumed (decoded
    /// by an *earlier* session incarnation) — tokens `resumed_from..` are
    /// the ones this session actually sampled
    pub resumed_from: usize,
}

impl SeqExport {
    /// Tokens this session decoded beyond the resumed prefix.
    pub fn fresh_tokens(&self) -> usize {
        self.response_ids.len() - self.resumed_from
    }
}

/// A persistent streaming decode session (one per generation replica).
pub struct GenSession {
    cfg: StreamConfig,
    slots: Vec<Slot>,
    kv: Option<Tensor>,
    /// submitted requests waiting for a slot + KV admission, FIFO
    pending: VecDeque<Pending>,
    /// degenerate submissions completed without touching the engine
    immediate: Vec<GenResult>,
    kv_alloc: KvBlockAllocator,
    stats: StreamStats,
    /// bumped whenever the held-claim set changes (admission to the
    /// pending queue, retirement, export) — lets the worker skip lease
    /// renewal entirely on steps where nothing joined or left
    held_rev: u64,
    /// tenant per in-flight request id; default-tenant (0) requests are
    /// never inserted, so single-tenant sessions keep an empty map and
    /// the exact pre-tenancy admission path
    tenant_by_id: HashMap<u64, u32>,
}

impl GenSession {
    pub fn new(cfg: StreamConfig, kv_alloc: KvBlockAllocator) -> Self {
        let slots = (0..cfg.batch).map(|_| Slot::Idle).collect();
        Self {
            cfg,
            slots,
            kv: None,
            pending: VecDeque::new(),
            immediate: Vec::new(),
            kv_alloc,
            stats: StreamStats::default(),
            held_rev: 0,
            tenant_by_id: HashMap::new(),
        }
    }

    /// Attach a per-tenant quota registry: subsequent admissions charge
    /// their sequence's tenant, and quota-blocked requests are skipped
    /// in [`Self::place`] instead of head-blocking siblings.
    pub fn attach_tenant_quotas(&mut self, quotas: Arc<TenantQuotas>) {
        self.kv_alloc.set_tenant_quotas(quotas);
    }

    fn seq_rng(&self, id: u64) -> Rng {
        Rng::new(self.cfg.seed ^ id.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// Submit a claimed request. Degenerate requests (`max_new_tokens ==
    /// 0`, or a prompt already at/over `max_seq`, which has no position
    /// left to sample into) complete immediately with an empty response —
    /// they never occupy a slot or KV blocks. Everything else queues for
    /// admission on the next step.
    pub fn submit(&mut self, req: GenRequest) {
        self.submit_resume(req, Vec::new(), Vec::new());
    }

    /// [`Self::submit`] with an explicit tenant: the sequence's KV
    /// reservation is charged to `tenant` when a quota registry is
    /// attached. Tenant 0 takes the plain path.
    pub fn submit_for_tenant(&mut self, req: GenRequest, tenant: u32) {
        self.submit_resume_for_tenant(req, Vec::new(), Vec::new(), tenant);
    }

    /// [`Self::submit_resume`] with an explicit tenant.
    pub fn submit_resume_for_tenant(
        &mut self,
        req: GenRequest,
        prefix_ids: Vec<i32>,
        prefix_lps: Vec<f32>,
        tenant: u32,
    ) {
        if tenant != 0 {
            self.tenant_by_id.insert(req.id, tenant);
        }
        self.submit_resume(req, prefix_ids, prefix_lps);
    }

    /// Submit a request that resumes from a persisted partial prefix: the
    /// prefix tokens are re-prefilled (KV only, no sampling) and the
    /// per-sequence RNG is fast-forwarded by the prefix's draw count, so
    /// the continued token stream is bit-identical to an uninterrupted
    /// run under the same weights. A prefix that already exhausts the
    /// budget (or the sequence window) completes immediately *as* the
    /// response — no slot, no KV.
    pub fn submit_resume(&mut self, req: GenRequest, prefix_ids: Vec<i32>, prefix_lps: Vec<f32>) {
        debug_assert_eq!(prefix_ids.len(), prefix_lps.len(), "one logprob per prefix token");
        let done_by_budget = req.max_new_tokens <= prefix_ids.len();
        let done_by_window = req.prompt_ids.len() + prefix_ids.len() + 1 > self.cfg.max_seq;
        if done_by_budget || done_by_window {
            // degenerate completion: never occupied a slot, charges nothing
            self.tenant_by_id.remove(&req.id);
            self.immediate.push(GenResult {
                id: req.id,
                finished_by_eos: prefix_ids.last() == Some(&self.cfg.eos_id),
                response_ids: prefix_ids,
                response_logprobs: prefix_lps,
            });
            return;
        }
        self.held_rev += 1;
        self.pending.push_back(Pending {
            req,
            prefix_ids,
            prefix_lps,
            submitted_at: self.stats.steps,
        });
        self.place();
    }

    /// Move pending requests into idle slots while KV admission allows.
    /// FIFO and head-blocking on *pool* pressure: a pool-deferred head is
    /// not overtaken by a smaller later request, so KV backpressure
    /// cannot starve a long prompt forever. *Quota*-deferred requests are
    /// the exception: their backpressure belongs to one tenant, so they
    /// are set aside (keeping their FIFO position) and the requests
    /// behind them stay admissible — one tenant at its quota must not
    /// stall its siblings' admission.
    fn place(&mut self) {
        let mut quota_skipped: Vec<Pending> = Vec::new();
        'slots: for slot in self.slots.iter_mut() {
            if !matches!(slot, Slot::Idle) {
                continue;
            }
            let p = loop {
                let Some(head) = self.pending.front() else { break 'slots };
                let worst =
                    (head.req.prompt_ids.len() + head.req.max_new_tokens).min(self.cfg.max_seq);
                let tenant = self.tenant_by_id.get(&head.req.id).copied().unwrap_or(0);
                if self.kv_alloc.try_admit_for(head.req.id, tenant, worst).is_some() {
                    break self.pending.pop_front().unwrap();
                }
                self.stats.kv_deferrals = self.kv_alloc.deferrals();
                if self.kv_alloc.quota_would_defer(tenant, worst) {
                    // per-tenant backpressure: skip, don't block siblings
                    quota_skipped.push(self.pending.pop_front().unwrap());
                } else {
                    break 'slots; // pool-tight: FIFO head-blocking stands
                }
            };
            self.stats.admitted += 1;
            self.stats.admit_wait_steps += self.stats.steps - p.submitted_at;
            self.stats.prompt_tokens += p.req.prompt_ids.len() as u64;
            let mut rng = self.seq_rng(p.req.id);
            if !p.prefix_ids.is_empty() {
                // fast-forward past the draws the prefix consumed: the
                // resumed stream continues exactly where an uninterrupted
                // run would be
                rng.skip(p.prefix_ids.len() * self.cfg.params.draws_per_token());
                self.stats.resumed += 1;
                self.stats.resumed_tokens += p.prefix_ids.len() as u64;
            }
            *slot = Slot::Busy(Box::new(ActiveSeq {
                rng,
                frozen: (self.cfg.pad_id, 0),
                fed: 0,
                pos: 0,
                prefix_len: p.prefix_ids.len(),
                response: p.prefix_ids,
                logprobs: p.prefix_lps,
                admitted_at: self.stats.steps,
                req: p.req,
            }));
        }
        // quota-skipped requests resume their original FIFO position at
        // the head, so they admit first once their tenant's quota reopens
        for p in quota_skipped.into_iter().rev() {
            self.pending.push_front(p);
        }
    }

    /// Drain completions that never needed the engine (degenerate
    /// submissions). `step` drains these too; this exists so a caller
    /// holding only degenerate work need not run a decode step.
    pub fn poll_finished(&mut self) -> Vec<GenResult> {
        std::mem::take(&mut self.immediate)
    }

    /// Sequences resident in the session (busy slots + pending queue).
    pub fn in_flight(&self) -> usize {
        self.busy_count() + self.pending.len()
    }

    /// Claim indices the session currently holds — what the worker
    /// renews its leases for on decode ticks.
    pub fn held_ids(&self) -> Vec<u64> {
        let mut ids = Vec::new();
        self.held_ids_into(&mut ids);
        ids
    }

    /// [`Self::held_ids`] into a caller-owned scratch buffer: the worker
    /// calls this every decode tick, and a fresh `Vec` per tick is pure
    /// allocator churn for a set that rarely changes. Clears `buf` first.
    pub fn held_ids_into(&self, buf: &mut Vec<u64>) {
        buf.clear();
        buf.extend(self.slots.iter().filter_map(|s| match s {
            Slot::Busy(a) => Some(a.req.id),
            Slot::Idle => None,
        }));
        buf.extend(self.pending.iter().map(|p| p.req.id));
    }

    /// Monotone revision of the held-claim set. Unchanged revision ⇒
    /// identical held set ⇒ the caller may skip refilling its scratch
    /// buffer (and, if the lease clock also hasn't advanced, skip the
    /// renewal round-trip entirely).
    pub fn held_revision(&self) -> u64 {
        self.held_rev
    }

    /// Abandon every in-flight sequence and hand back its decoded state:
    /// busy slots are exported with their full response-so-far (resumed
    /// prefix included), queued requests with just their prefix; KV
    /// blocks and slots are freed. The caller persists each export as a
    /// partial rollout and releases/abandons the claims — this is the
    /// kill / drain / preempt path made lossless.
    pub fn export_partials(&mut self) -> Vec<SeqExport> {
        self.export_partials_for(|_| true)
    }

    /// [`Self::export_partials`] restricted to sequences whose tenant
    /// satisfies `victim` — the per-tenant quota-preemption path: an
    /// over-quota tenant's in-flight work is persisted and handed back
    /// while every other tenant's sequences keep decoding untouched.
    pub fn export_partials_for(&mut self, victim: impl Fn(u32) -> bool) -> Vec<SeqExport> {
        let mut out = Vec::new();
        for slot in self.slots.iter_mut() {
            if let Slot::Busy(a) = slot {
                if !victim(self.tenant_by_id.get(&a.req.id).copied().unwrap_or(0)) {
                    continue;
                }
                self.kv_alloc.release(a.req.id);
                self.tenant_by_id.remove(&a.req.id);
                out.push(SeqExport {
                    id: a.req.id,
                    response_ids: std::mem::take(&mut a.response),
                    response_logprobs: std::mem::take(&mut a.logprobs),
                    resumed_from: a.prefix_len,
                });
                *slot = Slot::Idle;
            }
        }
        let mut kept = VecDeque::with_capacity(self.pending.len());
        for p in self.pending.drain(..) {
            if !victim(self.tenant_by_id.get(&p.req.id).copied().unwrap_or(0)) {
                kept.push_back(p);
                continue;
            }
            self.tenant_by_id.remove(&p.req.id);
            out.push(SeqExport {
                id: p.req.id,
                resumed_from: p.prefix_ids.len(),
                response_ids: p.prefix_ids,
                response_logprobs: p.prefix_lps,
            });
        }
        self.pending = kept;
        if !out.is_empty() {
            self.held_rev += 1;
        }
        out
    }

    /// Tenants with at least one in-flight sequence (busy or pending),
    /// deduplicated — the candidate set the executor checks for quota
    /// preemption. Empty for single-tenant sessions (tenant 0 is never
    /// tracked).
    pub fn tenants_in_flight(&self) -> Vec<u32> {
        let mut ts: Vec<u32> = self.tenant_by_id.values().copied().collect();
        ts.sort_unstable();
        ts.dedup();
        ts
    }

    /// Non-destructive snapshot of every busy sequence that has decoded
    /// at least one token beyond its resumed prefix — the periodic
    /// checkpoint feed that bounds recompute after an *unclean* death
    /// (a stalled worker cannot export at stall time; its last snapshot
    /// is what survives).
    pub fn partial_snapshots(&self) -> Vec<SeqExport> {
        self.slots
            .iter()
            .filter_map(|s| match s {
                Slot::Busy(a) if a.response.len() > a.prefix_len => Some(SeqExport {
                    id: a.req.id,
                    response_ids: a.response.clone(),
                    response_logprobs: a.logprobs.clone(),
                    resumed_from: a.prefix_len,
                }),
                _ => None,
            })
            .collect()
    }

    fn busy_count(&self) -> usize {
        self.slots.iter().filter(|s| matches!(s, Slot::Busy(_))).count()
    }

    /// Idle slots not already spoken for by the pending queue — how many
    /// more claims are worth taking right now. Zero while KV-deferred
    /// requests queue, which is the admission backpressure reaching the
    /// dock: the worker stops claiming and the samples stay grantable to
    /// other replicas.
    pub fn room(&self) -> usize {
        let idle = self.cfg.batch - self.busy_count();
        idle.saturating_sub(self.pending.len())
    }

    /// Nothing decoding, nothing queued, nothing to drain.
    pub fn is_idle(&self) -> bool {
        self.busy_count() == 0 && self.pending.is_empty() && self.immediate.is_empty()
    }

    pub fn stats(&self) -> StreamStats {
        self.stats
    }

    /// One scheduler step: up to `prefill_chunk` engine decode calls in
    /// which prefilling slots consume one prompt token each while decoding
    /// slots advance exactly once (on the first call) and are frozen
    /// after. Returns every sequence that finished on this step, in slot
    /// order — the caller writes each back and releases it immediately.
    pub fn step(&mut self, engine: &Engine, policy: &Policy) -> Result<Vec<GenResult>> {
        let mut finished: Vec<GenResult> = self.poll_finished();
        self.place();
        if self.busy_count() == 0 {
            if !finished.is_empty() {
                self.note_retired(finished.len() as u64);
            }
            return Ok(finished);
        }
        self.stats.steps += 1;

        if self.kv.is_none() {
            self.kv = Some(policy.init_kv(engine)?);
        }
        let batch = self.cfg.batch;
        let v = engine.manifest.model.vocab_size;
        let mut pos_v = vec![0i32; batch];
        let mut tok_v = vec![self.cfg.pad_id; batch];

        for micro in 0..self.cfg.prefill_chunk {
            // a micro-call runs iff it is the step's first call, or some
            // slot still has prefill budget to spend
            let any_prefill = self.slots.iter().any(|s| match s {
                Slot::Busy(a) => a.fed < a.feed_len(),
                Slot::Idle => false,
            });
            if micro > 0 && !any_prefill {
                break;
            }
            // phase 1: choose each slot's input
            let mut advancing = vec![false; batch];
            for (i, slot) in self.slots.iter_mut().enumerate() {
                self.stats.total_slot_steps += 1;
                match slot {
                    Slot::Idle => {
                        tok_v[i] = self.cfg.pad_id;
                        pos_v[i] = 0;
                    }
                    Slot::Busy(a) => {
                        // the feed is prompt ++ resumed prefix: a resumed
                        // sequence prefills its own earlier tokens (KV
                        // rebuild) before sampling continues
                        let prefilling = a.fed < a.feed_len();
                        let advance = prefilling || micro == 0;
                        if advance {
                            let next = if prefilling {
                                a.feed_token(a.fed)
                            } else {
                                *a.response.last().expect("decode phase has a last token")
                            };
                            tok_v[i] = next;
                            pos_v[i] = a.pos;
                            a.frozen = (next, a.pos);
                            advancing[i] = true;
                            self.stats.busy_slot_steps += 1;
                        } else {
                            // frozen: identical KV rewrite, logits discarded
                            let (t, p) = a.frozen;
                            tok_v[i] = t;
                            pos_v[i] = p;
                        }
                    }
                }
            }

            let pos_t = Tensor::i32(&[batch], pos_v.clone())?;
            let tok_t = Tensor::i32(&[batch], tok_v.clone())?;
            let kv = self.kv.as_ref().expect("kv initialized above");
            let (logits, new_kv) = policy.decode_step(engine, kv, &pos_t, &tok_t)?;
            self.kv = Some(new_kv);
            self.stats.decode_calls += 1;
            let lraw = logits.as_f32()?;

            // phase 2: advance the slots that fed a fresh token
            for (i, slot) in self.slots.iter_mut().enumerate() {
                if !advancing[i] {
                    continue;
                }
                let mut done: Option<GenResult> = None;
                if let Slot::Busy(a) = slot {
                    a.pos += 1;
                    if a.fed < a.feed_len() {
                        a.fed += 1;
                        // sample only once the full feed (prompt plus any
                        // resumed prefix) is in
                        if a.fed < a.feed_len() {
                            continue;
                        }
                    }
                    let row = &lraw[i * v..(i + 1) * v];
                    let tok = self.cfg.params.sample(row, &mut a.rng) as i32;
                    if a.response.len() == a.prefix_len {
                        // first token sampled by *this* session incarnation
                        self.stats.first_token_seqs += 1;
                        self.stats.first_token_steps += self.stats.steps - a.admitted_at;
                    }
                    a.response.push(tok);
                    a.logprobs.push(token_logprob(row, tok as usize));
                    self.stats.tokens_generated += 1;
                    let (fin, by_eos) = seq_finished(
                        tok,
                        self.cfg.eos_id,
                        a.response.len(),
                        a.req.max_new_tokens,
                        a.pos,
                        self.cfg.max_seq,
                    );
                    if fin {
                        done = Some(GenResult {
                            id: a.req.id,
                            response_ids: std::mem::take(&mut a.response),
                            response_logprobs: std::mem::take(&mut a.logprobs),
                            finished_by_eos: by_eos,
                        });
                    }
                }
                if let Some(r) = done {
                    // per-sequence retirement: free the KV blocks and the
                    // slot now; the caller writes the sample back as soon
                    // as this step returns
                    self.kv_alloc.release(r.id);
                    self.tenant_by_id.remove(&r.id);
                    finished.push(r);
                    *slot = Slot::Idle;
                    self.held_rev += 1;
                }
            }
            // freed slots admit pending work between micro-calls too
            self.place();
        }

        if !finished.is_empty() {
            self.note_retired(finished.len() as u64);
        }
        self.stats.kv_deferrals = self.kv_alloc.deferrals();
        Ok(finished)
    }

    fn note_retired(&mut self, n: u64) {
        self.stats.retired += n;
        self.stats.retire_steps += 1;
        self.stats.max_retired_in_step = self.stats.max_retired_in_step.max(n);
    }

    /// The paging invariant, re-exported for tests and debug asserts.
    pub fn kv_invariant_holds(&self) -> bool {
        self.kv_alloc.invariant_holds()
    }

    pub fn kv_live_blocks(&self) -> u64 {
        self.kv_alloc.live_blocks()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::MemoryPool;
    use std::sync::Arc;

    fn cfg(batch: usize, max_seq: usize) -> StreamConfig {
        StreamConfig {
            batch,
            max_seq,
            eos_id: 2,
            pad_id: 0,
            params: SamplingParams::default(),
            prefill_chunk: 4,
            seed: 7,
        }
    }

    fn session(batch: usize, max_seq: usize, kv_blocks: u64) -> GenSession {
        let block_tokens = 8;
        let pool = Arc::new(MemoryPool::new("kv", kv_blocks * block_tokens as u64));
        let alloc = KvBlockAllocator::new(pool, block_tokens, 1);
        GenSession::new(cfg(batch, max_seq), alloc)
    }

    fn req(id: u64, prompt: usize, max_new: usize) -> GenRequest {
        GenRequest { id, prompt_ids: vec![1; prompt], max_new_tokens: max_new }
    }

    // ------------------------------------------------ finish rule (pure)

    #[test]
    fn finish_rule_eos_on_first_token() {
        let (fin, by_eos) = seq_finished(2, 2, 1, 8, 5, 64);
        assert!(fin && by_eos);
    }

    #[test]
    fn finish_rule_max_new_cap() {
        let (fin, by_eos) = seq_finished(9, 2, 8, 8, 12, 64);
        assert!(fin && !by_eos);
        let (fin, _) = seq_finished(9, 2, 7, 8, 12, 64);
        assert!(!fin);
    }

    #[test]
    fn finish_rule_max_seq_cap() {
        // slot at pos 63 of a 64-seq model: no room for another token
        let (fin, by_eos) = seq_finished(9, 2, 1, 100, 63, 64);
        assert!(fin && !by_eos);
        let (fin, _) = seq_finished(9, 2, 1, 100, 62, 64);
        assert!(!fin);
    }

    // -------------------------------------- degenerate submissions (no engine)

    #[test]
    fn zero_max_new_tokens_completes_immediately() {
        let mut s = session(2, 64, 16);
        s.submit(req(5, 4, 0));
        assert!(s.room() == 2, "degenerate request must not occupy a slot");
        let out = s.poll_finished();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].id, 5);
        assert!(out[0].response_ids.is_empty());
        assert!(!out[0].finished_by_eos);
        assert!(s.is_idle());
        assert!(s.kv_invariant_holds());
        assert_eq!(s.kv_live_blocks(), 0);
    }

    #[test]
    fn prompt_at_or_over_max_seq_completes_immediately() {
        let mut s = session(2, 16, 16);
        s.submit(req(1, 16, 4)); // prompt fills max_seq: nowhere to sample
        s.submit(req(2, 20, 4)); // prompt over max_seq
        let out = s.poll_finished();
        assert_eq!(out.len(), 2);
        assert!(out.iter().all(|r| r.response_ids.is_empty()));
        assert_eq!(s.kv_live_blocks(), 0, "degenerates must not charge KV");
    }

    #[test]
    fn empty_submission_set_is_idle() {
        let mut s = session(2, 64, 16);
        assert!(s.is_idle());
        assert!(s.poll_finished().is_empty());
        assert_eq!(s.stats().steps, 0);
    }

    // ------------------------------------------- admission + backpressure

    #[test]
    fn kv_exhaustion_defers_admission_without_panic() {
        // 2 blocks of 8 tokens total; each request reserves 2 blocks
        // (prompt 4 + max_new 8 = 12 tokens → 2 blocks)
        let mut s = session(4, 64, 2);
        s.submit(req(0, 4, 8));
        s.submit(req(1, 4, 8));
        // slot 0 admitted, request 1 deferred on KV despite 3 idle slots
        assert_eq!(s.in_flight(), 2);
        assert_eq!(s.held_ids(), vec![0, 1]);
        assert_eq!(s.kv_live_blocks(), 2);
        assert!(s.kv_invariant_holds());
        assert_eq!(s.room(), 0, "deferred pending must stop further claiming");
        assert!(s.stats().kv_deferrals >= 1, "deferral must be counted");
    }

    #[test]
    fn room_tracks_slots_and_pending() {
        let mut s = session(3, 64, 64);
        assert_eq!(s.room(), 3);
        s.submit(req(0, 2, 4));
        assert_eq!(s.room(), 2, "admitted request occupies a slot");
        s.submit(req(1, 2, 0));
        assert_eq!(s.room(), 2, "degenerate completion holds nothing");
    }

    #[test]
    fn admission_is_fifo_under_backpressure() {
        // one 8-token block free after the first admit; the big head
        // request must not be overtaken by the small one behind it
        let mut s = session(4, 64, 3);
        s.submit(req(0, 4, 8)); // 2 blocks
        s.submit(req(1, 30, 30)); // needs 8 blocks: deferred
        s.submit(req(2, 2, 2)); // 1 block would fit, but queues behind 1
        assert_eq!(s.kv_live_blocks(), 2, "only request 0 admitted");
        assert_eq!(s.held_ids(), vec![0, 1, 2]);
    }

    // ------------------------------------------------ resume + export

    #[test]
    fn resume_with_exhausted_budget_completes_immediately() {
        let mut s = session(2, 64, 16);
        // prefix already hits max_new: the "resume" IS the response
        s.submit_resume(req(7, 4, 3), vec![5, 6, 9], vec![-0.1, -0.2, -0.3]);
        let out = s.poll_finished();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].response_ids, vec![5, 6, 9]);
        assert_eq!(out[0].response_logprobs.len(), 3);
        assert!(!out[0].finished_by_eos);
        assert_eq!(s.kv_live_blocks(), 0, "degenerate resume must not charge KV");
        assert!(s.is_idle());
    }

    #[test]
    fn resume_over_sequence_window_completes_immediately() {
        let mut s = session(2, 16, 16);
        // prompt 12 + prefix 4 + 1 > 16: nowhere left to sample
        s.submit_resume(req(3, 12, 8), vec![1, 1, 1, 1], vec![0.0; 4]);
        let out = s.poll_finished();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].response_ids.len(), 4);
    }

    #[test]
    fn resumed_admission_counts_saved_tokens_and_skips_rng() {
        let mut s = session(2, 64, 64);
        s.submit_resume(req(0, 4, 10), vec![5, 6], vec![-0.5, -0.6]);
        assert_eq!(s.stats().resumed, 1);
        assert_eq!(s.stats().resumed_tokens, 2);
        assert_eq!(s.in_flight(), 1, "resume occupies a slot like any admission");
        // the slot's RNG must equal a fresh per-seq RNG fast-forwarded by
        // prefix × draws-per-token — observe it via export + fields
        let ex = s.export_partials();
        assert_eq!(ex.len(), 1);
        assert_eq!(ex[0].response_ids, vec![5, 6]);
        assert_eq!(ex[0].resumed_from, 2, "prefix tokens are not fresh work");
        assert_eq!(ex[0].fresh_tokens(), 0);
    }

    #[test]
    fn export_partials_frees_slots_kv_and_queue() {
        let mut s = session(2, 64, 3);
        s.submit(req(0, 4, 8)); // admitted: 2 blocks
        s.submit(req(1, 4, 8)); // deferred on KV, queues
        assert_eq!(s.kv_live_blocks(), 2);
        let ex = s.export_partials();
        assert_eq!(ex.len(), 2, "busy slot and queued request both export");
        assert_eq!(ex[0].id, 0);
        assert!(ex[0].response_ids.is_empty(), "nothing decoded yet");
        assert_eq!(ex[1].id, 1);
        assert_eq!(s.kv_live_blocks(), 0, "export releases KV reservations");
        assert!(s.kv_invariant_holds());
        assert!(s.is_idle());
        assert!(s.held_ids().is_empty());
        // a fresh resume of the exported work is admissible again
        s.submit_resume(req(0, 4, 8), Vec::new(), Vec::new());
        assert_eq!(s.in_flight(), 1);
    }

    #[test]
    fn held_revision_tracks_set_changes_only() {
        let mut s = session(2, 64, 64);
        let r0 = s.held_revision();
        s.submit(req(0, 2, 4));
        let r1 = s.held_revision();
        assert_ne!(r0, r1, "admission changes the held set");
        let mut buf = vec![99; 8];
        s.held_ids_into(&mut buf);
        assert_eq!(buf, vec![0], "scratch buffer is cleared then refilled");
        assert_eq!(s.held_revision(), r1, "introspection does not bump the revision");
        s.submit(req(1, 2, 0)); // degenerate: never held
        assert_eq!(s.held_revision(), r1, "immediate completions never join the set");
        s.export_partials();
        assert_ne!(s.held_revision(), r1, "export empties the held set");
    }

    #[test]
    fn partial_snapshots_skip_sequences_with_no_fresh_tokens() {
        let mut s = session(2, 64, 64);
        s.submit_resume(req(0, 4, 10), vec![5, 6], vec![-0.5, -0.6]);
        assert!(
            s.partial_snapshots().is_empty(),
            "a resumed prefix alone is already persisted — nothing new to checkpoint"
        );
    }

    #[test]
    fn quota_blocked_tenant_does_not_head_block_siblings() {
        use crate::memory::TenantQuotas;
        // pool has room for 16 blocks — only tenant 1's quota is tight
        let mut s = session(2, 64, 16);
        let q = Arc::new(TenantQuotas::new());
        q.set_quota(1, Some(0));
        s.attach_tenant_quotas(Arc::clone(&q));
        s.submit_for_tenant(req(0, 4, 4), 1);
        assert_eq!(s.kv_live_blocks(), 0, "quota-blocked request reserves nothing");
        // a sibling tenant queued *behind* the blocked head still admits
        s.submit_for_tenant(req(1, 4, 4), 2);
        assert!(s.kv_live_blocks() > 0, "sibling must overtake a quota-blocked head");
        assert_eq!(s.in_flight(), 2, "blocked request stays queued, not dropped");
        assert!(s.kv_invariant_holds());
        assert_eq!(s.tenants_in_flight(), vec![1, 2]);
        // reopening the quota admits the parked request in FIFO order:
        // it takes the last idle slot ahead of the newly submitted one
        q.set_quota(1, Some(1 << 20));
        s.submit_for_tenant(req(2, 4, 4), 2); // any submit re-runs placement
        assert_eq!(s.kv_live_blocks(), 2, "parked request admitted after quota reopens");
        assert_eq!(q.charged(1), s.kv_alloc.block_bytes(), "tenant 1 charged for its block");
    }

    #[test]
    fn export_partials_for_preempts_one_tenant_only() {
        use crate::memory::TenantQuotas;
        let mut s = session(2, 64, 16);
        let q = Arc::new(TenantQuotas::new());
        s.attach_tenant_quotas(Arc::clone(&q));
        s.submit_for_tenant(req(0, 4, 4), 1);
        s.submit_for_tenant(req(1, 4, 4), 2);
        s.submit_for_tenant(req(2, 4, 4), 1); // queued: both slots busy
        assert_eq!(s.in_flight(), 3);
        let charged_before = q.charged(2);
        let ex = s.export_partials_for(|t| t == 1);
        let mut ids: Vec<u64> = ex.iter().map(|e| e.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 2], "busy and queued victims both export");
        assert_eq!(s.in_flight(), 1, "the sibling keeps decoding");
        assert_eq!(s.tenants_in_flight(), vec![2]);
        assert_eq!(q.charged(1), 0, "victim's KV charges released");
        assert_eq!(q.charged(2), charged_before, "sibling's charges untouched");
        assert!(s.kv_invariant_holds());
    }
}
