//! # MindSpeed RL — reproduction library
//!
//! Reproduction of *MindSpeed RL: Distributed Dataflow for Scalable and
//! Efficient RL Training on Ascend NPU Cluster* (Feng et al., 2025) as a
//! three-layer Rust + JAX + Pallas stack. This crate is Layer 3: the
//! coordinator. It owns the event loop, the worker topology, and the two
//! dataflow mechanisms the paper contributes:
//!
//! * [`transfer_dock`] — the distributed transfer-dock sample flow
//!   (per-worker-state controllers + per-node warehouses), plus the
//!   centralized replay-buffer baseline it replaces.
//! * [`resharding`] — the allgather–swap resharding flow (and the naive
//!   baseline), over a simulated multi-device memory substrate.
//! * [`weights`] — the versioned train→infer weight channel
//!   (`WeightBus` ring with shard-level, content-deduplicated
//!   retention): behavior-policy identity as a first-class concept, so
//!   the pipelined executor scores old-logprobs under each sample's
//!   stamped generation-time weights; the resharding flow publishes its
//!   generation-layout slices directly into the bus.
//!
//! Compute (model forward/backward, GRPO loss, Adam) lives in AOT-compiled
//! HLO artifacts produced by `python/compile` and executed through
//! [`runtime`] on the PJRT CPU client. Python is never on the request path.

//!
//! Execution is driven by the [`trainers`] dataflow executor: `sync`
//! (barrier-per-stage, deterministic) or `pipelined` (one thread per
//! worker state pulling from the dock). See `rust/DESIGN.md` for the
//! executor architecture and the sync/pipelined trade-off.

// Modules are added as they are built; see rust/DESIGN.md system inventory.
pub mod config;
pub mod data;
pub mod generation;
pub mod metrics;
pub mod trainers;
pub mod workers;
pub mod memory;
pub mod parallel;
pub mod resharding;
pub mod rewards;
pub mod runtime;
pub mod sim;
pub mod tokenizer;
pub mod transfer_dock;
pub mod util;
pub mod weights;
