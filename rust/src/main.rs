//! MindSpeed RL leader entrypoint (CLI).
//!
//! ```text
//! mindspeed-rl smoke    [--preset tiny]           load + run every artifact
//! mindspeed-rl train    [--preset small] [--config cfg.json] [--iterations N]
//!                       [--pipeline sync|pipelined] [--max-inflight K]
//!                       [--stage-replicas gen=4,logprob=2] [--autoscale]
//!                       [--autoscale-min N] [--autoscale-max N]
//!                       [--autoscale-backlog-hi D] [--autoscale-backlog-lo D]
//!                       [--autoscale-up-ticks K] [--autoscale-down-ticks K]
//!                       [--gen-streaming] [--prefill-chunk K]
//!                       [--kv-block-tokens B]
//!                       [--partial-rollouts] [--preempt-on-publish]
//!                       [--tenants N] [--tenant-weight W0,W1,...]
//!                       [--tenant-quota-mb Q0,Q1,...]
//!                       [--replay-buffer] [--gen-logprobs] [--eval-every K]
//!                       [--lease-ticks T] [--dock-shards K]
//!                       [--steal-threshold D] [--chaos-kill-rate P]
//!                       [--chaos-stall-rate P] [--chaos-stall-ticks T]
//!                       [--chaos-seed S] [--chaos-max-faults N] ...
//! mindspeed-rl eval     [--preset small] [--k 4] [--n 64]    evaluate init policy
//! mindspeed-rl simulate --experiment table1|fig7|fig9|fig11|overlap|chaos|scaling|streaming|dispatch|tenancy
//! ```
//!
//! `--pipeline pipelined` runs every worker state (generation,
//! old-logprobs, reference, reward, update) as its own thread pulling from
//! the transfer dock; `--max-inflight` bounds how many iterations may be
//! admitted ahead of the last completed update (off-policy staleness
//! window). `--stage-replicas` widens any pull-driven state into N
//! data-parallel replica threads over the same dock controller (leases
//! prevent double dispatch; claims fair-share across pullers), and
//! `--autoscale` lets the backlog-driven autoscaler grow/shrink the
//! replica counts within bounds on lease ticks — scale-down is
//! drain-then-retire, so no claim is ever abandoned. See rust/DESIGN.md
//! "Elastic stages". `--gen-streaming` replaces the claim-a-batch-and-drain
//! generation loop with a persistent continuous-batching session: new
//! claims join at decode-step granularity, finished sequences retire (and
//! write back) individually, prefill is chunked (`--prefill-chunk`), and
//! KV is charged through a paged block allocator (`--kv-block-tokens`)
//! whose exhaustion defers admission instead of failing. See
//! rust/DESIGN.md "Streaming generation". `--partial-rollouts` makes
//! streaming generation resumable: an abandoned sequence (kill, lease
//! reclaim, scale-down drain) persists its decoded prefix through the
//! sample flow as version-stamped segments and redispatch resumes from
//! the prefix — bit-identical to an uninterrupted run — while
//! old-logprob scores each segment under the version it was decoded
//! under. `--preempt-on-publish` additionally preempts in-flight
//! sequences whenever a new weight version lands, so resumed tails are
//! decoded under the freshest policy. See rust/DESIGN.md
//! "Partial rollouts".
//! Weights flow over a versioned bus: every sample is stamped
//! with the weight version that generated it and its old-logprob is
//! scored under that exact version. `--gen-logprobs` emits the behavior
//! logprobs straight from the sampler (old-logprob becomes
//! verify-or-fill). `--pipeline sync` (default) keeps barrier-per-stage
//! semantics and is deterministic per seed.
//!
//! Sample dispatch is **lease-based**: a stage worker that claims work
//! and then dies or stalls loses its claims after `--lease-ticks` logical
//! ticks and the samples are redispatched (reclaim/redispatch counts land
//! in the run summary). The `--chaos-*` flags inject seeded worker
//! kills/stalls into the pipelined executor to exercise exactly that
//! recovery path; `simulate --experiment chaos` runs the artifact-free
//! harness sweep. See rust/DESIGN.md "Fault model & leases".
//!
//! `--dock-shards K` partitions each stage's dock controller into K
//! shards (samples hash to a home shard; warehouse placement follows the
//! shard's node), and `--steal-threshold D` lets a drained shard steal
//! claims from siblings once its ready pool is ≤ D — every steal is an
//! extra cross-node RPC charged to the ledger. K=1 (default) is
//! bit-identical to the unsharded dock. `simulate --experiment dispatch`
//! sweeps central-vs-sharded dispatch cost into the hundreds of nodes.
//! See rust/DESIGN.md "Sharded dock".
//!
//! `--tenants N` multiplexes N tenant jobs over the shared stage pools:
//! the prompt stream stripes round-robin by group, claim handouts are
//! deficit-weighted round robin over backlogged tenants
//! (`--tenant-weight 3,1` gives tenant 0 a 3:1 claim share while both
//! are backlogged; an idle tenant's share is donated), and
//! `--tenant-quota-mb` caps each tenant's shared-pool bytes — a tenant
//! at its quota has its own admissions deferred and (with
//! `--partial-rollouts`) its in-flight sequences preempted via the
//! persist-then-release path; siblings are untouched. `--tenants 1`
//! (default) is bit-identical to the pre-tenancy scheduler. `simulate
//! --experiment tenancy` compares a weighted shared run against
//! isolated slices. See rust/DESIGN.md "Multi-tenant scheduling".

use anyhow::Result;

use mindspeed_rl::config::Config;
use mindspeed_rl::runtime::{artifact_dir, Engine, Policy, Tensor, TrainBatch};
use mindspeed_rl::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "smoke" => smoke(&args.str_or("preset", "tiny")),
        "train" => train(&args),
        "eval" => eval(&args),
        "simulate" => {
            mindspeed_rl::sim::run_named_experiment(&args.str_or("experiment", "fig9"))
        }
        _ => {
            eprintln!(
                "usage: mindspeed-rl <smoke|train|eval|simulate> [flags]\n\
                 see rust/src/main.rs header for flag reference"
            );
            Ok(())
        }
    }
}

fn train(args: &Args) -> Result<()> {
    let cfg = Config::from_args(args)?;
    let engine = Engine::load(artifact_dir(&cfg.preset))?;
    let report = mindspeed_rl::trainers::run_grpo(&engine, &cfg.grpo)?;
    println!("{}", report.summary());
    for (iter, evals) in &report.evals {
        for e in evals {
            println!(
                "  eval@{iter} {}: pass@1={:.3} avg@{}={:.3} (n={})",
                e.tier.name(),
                e.pass_at_1,
                e.k,
                e.avg_at_k,
                e.n_tasks
            );
        }
    }
    // dump the reward curve for plotting
    let mut csv = mindspeed_rl::metrics::CsvWriter::new(&[
        "iter", "reward", "exact", "loss", "kl", "tps", "dispatch_secs",
    ]);
    for m in &report.iterations {
        csv.row_f64(&[
            m.iter as f64,
            m.reward_mean as f64,
            m.exact_frac as f64,
            m.loss as f64,
            m.kl as f64,
            m.tps,
            m.dispatch_secs,
        ]);
    }
    let path = format!("{}/train_{}.csv", cfg.results_dir, cfg.preset);
    csv.write(&path)?;
    println!("curve written to {path}");
    Ok(())
}

fn eval(args: &Args) -> Result<()> {
    let cfg = Config::from_args(args)?;
    let engine = Engine::load(artifact_dir(&cfg.preset))?;
    let policy = Policy::load_initial(&engine, 0.0)?;
    let k = args.usize_or("k", 1)?;
    let n = args.usize_or("n", 64)?;
    for e in mindspeed_rl::trainers::evaluate(&engine, &policy, n, cfg.grpo.seed, k)? {
        println!(
            "{}: pass@1={:.3} avg@{}={:.3} (n={})",
            e.tier.name(),
            e.pass_at_1,
            e.k,
            e.avg_at_k,
            e.n_tasks
        );
    }
    Ok(())
}

fn smoke(preset: &str) -> Result<()> {
    let engine = Engine::load(artifact_dir(preset))?;
    let m = &engine.manifest;
    println!("preset={} params={}", m.preset, m.model.param_count);
    let mut policy = Policy::load_initial(&engine, 1e-3)?;
    let a = m.artifact("logprobs")?.clone();
    let (b, s) = (a.batch, a.seq);

    let tokens = Tensor::i32(&[b, s], vec![1; b * s])?;
    let t0 = std::time::Instant::now();
    let lp = policy.logprobs(&engine, &tokens)?;
    println!("logprobs {:?} in {:.3}s", lp.shape(), t0.elapsed().as_secs_f64());

    let kv = policy.init_kv(&engine)?;
    let pos = Tensor::i32(&[b], vec![0; b])?;
    let tok = Tensor::i32(&[b], vec![1; b])?;
    let t0 = std::time::Instant::now();
    let (logits, _) = policy.decode_step(&engine, &kv, &pos, &tok)?;
    println!("decode_step {:?} in {:.3}s", logits.shape(), t0.elapsed().as_secs_f64());

    let batch = TrainBatch {
        tokens: Tensor::i32(&[b, s], vec![1; b * s])?,
        resp_mask: Tensor::f32(&[b, s - 1], vec![1.0; b * (s - 1)])?,
        old_lp: lp.clone(),
        ref_lp: lp,
        adv: Tensor::f32(&[b], vec![0.5; b])?,
    };
    let t0 = std::time::Instant::now();
    let stats = policy.train_step(&engine, &batch)?;
    println!(
        "train_step loss={:.4} kl={:.6} ratio={:.4} in {:.3}s",
        stats.loss, stats.kl, stats.ratio, t0.elapsed().as_secs_f64()
    );
    println!("smoke OK");
    Ok(())
}
