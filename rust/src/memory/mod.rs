//! Device/host memory substrate: allocation-tracked buffer pools.
//!
//! The paper's allgather–swap claim (Fig. 5 / Fig. 10) is about *which
//! buffers exist on the device when*. This module provides the accounting
//! ground truth: every buffer in the resharding flow is allocated from a
//! per-device [`MemoryPool`] with capacity, live/peak tracking and a
//! timeline of (label, live-bytes) events — Fig. 10 is replayed directly
//! from that timeline.

use anyhow::{bail, Result};
use std::collections::BTreeMap;
use std::sync::Mutex;

/// Per-tenant byte accounting over a shared pool: quotas, charges,
/// high-water marks and backpressure counters.
///
/// The pool itself stays tenant-blind (one capacity, one OOM rule); this
/// registry sits *in front* of it and answers "may tenant `t` take
/// another `b` bytes?". Callers charge before allocating and uncharge
/// after freeing, so a tenant at its quota is **deferred** (its own
/// admission blocks) instead of tripping the pool-wide OOM that would
/// punish its siblings. A tenant with no registered quota is uncapped —
/// charges are still tracked (for the per-tenant report) but never
/// refused, which is also the single-tenant default.
#[derive(Debug, Default)]
pub struct TenantQuotas {
    inner: Mutex<BTreeMap<u32, TenantQuotaState>>,
}

#[derive(Debug, Default, Clone)]
struct TenantQuotaState {
    quota: Option<u64>,
    charged: u64,
    high_water: u64,
    deferrals: u64,
    preemptions: u64,
}

/// One tenant's quota accounting, snapshotted for reports.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct TenantQuotaSnapshot {
    pub quota: Option<u64>,
    pub charged: u64,
    pub high_water: u64,
    pub deferrals: u64,
    pub preemptions: u64,
}

impl TenantQuotas {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register (or clear) a tenant's byte quota. Clearing does not
    /// forget accumulated charges — only the cap.
    pub fn set_quota(&self, tenant: u32, bytes: Option<u64>) {
        self.inner.lock().unwrap().entry(tenant).or_default().quota = bytes;
    }

    /// Try to charge `bytes` to `tenant`. Returns `false` — and counts a
    /// deferral — when the charge would push the tenant past its quota;
    /// the caller must then defer the admission (nothing was charged).
    pub fn try_charge(&self, tenant: u32, bytes: u64) -> bool {
        let mut g = self.inner.lock().unwrap();
        let s = g.entry(tenant).or_default();
        if let Some(q) = s.quota {
            if s.charged + bytes > q {
                s.deferrals += 1;
                return false;
            }
        }
        s.charged += bytes;
        s.high_water = s.high_water.max(s.charged);
        true
    }

    /// Can `bytes` be charged to `tenant` right now? Pure check: no
    /// charge lands, no deferral is counted — schedulers use it to tell
    /// quota backpressure (skip just this tenant's request) apart from
    /// pool backpressure (head-block everyone, FIFO).
    pub fn can_charge(&self, tenant: u32, bytes: u64) -> bool {
        let g = self.inner.lock().unwrap();
        match g.get(&tenant) {
            Some(s) => s.quota.map_or(true, |q| s.charged + bytes <= q),
            None => true,
        }
    }

    /// Charge bytes unconditionally (residency of state that is already
    /// in the shared flow, where the backpressure point is the *next*
    /// admission via [`Self::over_quota`], not this charge). High-water
    /// tracking still applies, and the overrun is what arms preemption.
    pub fn charge_forced(&self, tenant: u32, bytes: u64) {
        let mut g = self.inner.lock().unwrap();
        let s = g.entry(tenant).or_default();
        s.charged += bytes;
        s.high_water = s.high_water.max(s.charged);
    }

    /// Count an admission the caller deferred on this tenant's quota
    /// (used by callers that gate on [`Self::over_quota`] rather than
    /// [`Self::try_charge`]).
    pub fn note_deferral(&self, tenant: u32) {
        self.inner.lock().unwrap().entry(tenant).or_default().deferrals += 1;
    }

    /// Return bytes a tenant no longer holds (saturating: a chaos-path
    /// double release must not underflow the sibling accounting).
    pub fn uncharge(&self, tenant: u32, bytes: u64) {
        let mut g = self.inner.lock().unwrap();
        let s = g.entry(tenant).or_default();
        s.charged = s.charged.saturating_sub(bytes);
    }

    /// A tenant currently at or over its quota (uncapped tenants never
    /// are) — the signal the executor uses to pick preemption victims.
    pub fn over_quota(&self, tenant: u32) -> bool {
        let g = self.inner.lock().unwrap();
        match g.get(&tenant) {
            Some(s) => s.quota.is_some_and(|q| s.charged >= q),
            None => false,
        }
    }

    /// Record that a tenant's live work was preempted (drained and
    /// persisted) to bring it back under quota.
    pub fn note_preemption(&self, tenant: u32) {
        self.inner.lock().unwrap().entry(tenant).or_default().preemptions += 1;
    }

    pub fn charged(&self, tenant: u32) -> u64 {
        self.inner.lock().unwrap().get(&tenant).map_or(0, |s| s.charged)
    }

    /// Per-tenant snapshots (tenant-id ascending) for report assembly.
    pub fn snapshot(&self) -> Vec<(u32, TenantQuotaSnapshot)> {
        let g = self.inner.lock().unwrap();
        g.iter()
            .map(|(&t, s)| {
                (
                    t,
                    TenantQuotaSnapshot {
                        quota: s.quota,
                        charged: s.charged,
                        high_water: s.high_water,
                        deferrals: s.deferrals,
                        preemptions: s.preemptions,
                    },
                )
            })
            .collect()
    }
}

/// Identifies a tracked buffer within a pool.
pub type BufferId = u64;

/// One memory event for profiling timelines (Fig. 10).
#[derive(Debug, Clone, PartialEq)]
pub struct MemEvent {
    pub label: String,
    pub live_bytes: u64,
}

/// An allocation-tracked memory pool (one per simulated device, plus one
/// per host).
#[derive(Debug)]
pub struct MemoryPool {
    pub name: String,
    pub capacity: u64,
    /// record per-event timelines (Fig. 10 replay). Off for pure
    /// accounting pools (`unbounded`), whose alloc/free churn over a
    /// whole training run would grow an unread event log without bound.
    record_timeline: bool,
    inner: Mutex<Inner>,
}

#[derive(Debug, Default)]
struct Inner {
    next_id: BufferId,
    buffers: BTreeMap<BufferId, (String, u64)>,
    live: u64,
    peak: u64,
    timeline: Vec<MemEvent>,
}

impl MemoryPool {
    pub fn new(name: impl Into<String>, capacity: u64) -> Self {
        Self {
            name: name.into(),
            capacity,
            record_timeline: true,
            inner: Mutex::new(Inner::default()),
        }
    }

    /// A pool used purely for accounting (no OOM enforcement, no event
    /// timeline) — e.g. the weight bus's retention pool, where the
    /// interesting output is the live/peak watermark, not an allocation
    /// failure or a replayable event log.
    pub fn unbounded(name: impl Into<String>) -> Self {
        Self {
            name: name.into(),
            capacity: u64::MAX,
            record_timeline: false,
            inner: Mutex::new(Inner::default()),
        }
    }

    /// Allocate a named buffer; fails if capacity would be exceeded (the
    /// OOM the paper's naive resharding flow risks).
    pub fn alloc(&self, label: impl Into<String>, bytes: u64) -> Result<BufferId> {
        let label = label.into();
        let mut g = self.inner.lock().unwrap();
        if g.live + bytes > self.capacity {
            bail!(
                "pool {}: OOM allocating {} for {label:?} (live {}, capacity {})",
                self.name,
                crate::util::fmt_bytes(bytes),
                crate::util::fmt_bytes(g.live),
                crate::util::fmt_bytes(self.capacity)
            );
        }
        let id = g.next_id;
        g.next_id += 1;
        g.live += bytes;
        g.peak = g.peak.max(g.live);
        g.buffers.insert(id, (label.clone(), bytes));
        if self.record_timeline {
            let ev = MemEvent { label: format!("+{label}"), live_bytes: g.live };
            g.timeline.push(ev);
        }
        Ok(id)
    }

    /// Free the first live buffer whose label matches exactly (used where
    /// callers track labels rather than ids, e.g. host swap space).
    pub fn free_by_label(&self, label: &str) -> Result<()> {
        let id = {
            let g = self.inner.lock().unwrap();
            g.buffers
                .iter()
                .find(|(_, (l, _))| l == label)
                .map(|(&id, _)| id)
        };
        match id {
            Some(id) => self.free(id),
            None => bail!("pool {}: no live buffer labeled {label:?}", self.name),
        }
    }

    pub fn free(&self, id: BufferId) -> Result<()> {
        let mut g = self.inner.lock().unwrap();
        let (label, bytes) = match g.buffers.remove(&id) {
            Some(x) => x,
            None => bail!("pool {}: double free of buffer {id}", self.name),
        };
        g.live -= bytes;
        if self.record_timeline {
            let ev = MemEvent { label: format!("-{label}"), live_bytes: g.live };
            g.timeline.push(ev);
        }
        Ok(())
    }

    pub fn live_bytes(&self) -> u64 {
        self.inner.lock().unwrap().live
    }

    pub fn peak_bytes(&self) -> u64 {
        self.inner.lock().unwrap().peak
    }

    pub fn free_bytes(&self) -> u64 {
        self.capacity - self.live_bytes()
    }

    pub fn buffer_count(&self) -> usize {
        self.inner.lock().unwrap().buffers.len()
    }

    /// Bytes held by buffers whose label matches a predicate.
    pub fn live_bytes_matching(&self, pred: impl Fn(&str) -> bool) -> u64 {
        let g = self.inner.lock().unwrap();
        g.buffers.values().filter(|(l, _)| pred(l)).map(|(_, b)| *b).sum()
    }

    pub fn timeline(&self) -> Vec<MemEvent> {
        self.inner.lock().unwrap().timeline.clone()
    }

    /// Reset peak/timeline (between experiment phases), keeping live
    /// buffers.
    pub fn reset_stats(&self) {
        let mut g = self.inner.lock().unwrap();
        g.peak = g.live;
        g.timeline.clear();
    }

    /// Reset only the peak watermark to the current live bytes (start of
    /// a new measurement phase), keeping the timeline — used by the
    /// resharder so each reshard's reported peak covers that reshard,
    /// not every run since the pool was created.
    pub fn reset_peak(&self) {
        let mut g = self.inner.lock().unwrap();
        g.peak = g.live;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_free_peak() {
        let p = MemoryPool::new("dev0", 1000);
        let a = p.alloc("weights", 600).unwrap();
        let b = p.alloc("kv", 300).unwrap();
        assert_eq!(p.live_bytes(), 900);
        assert_eq!(p.peak_bytes(), 900);
        p.free(a).unwrap();
        assert_eq!(p.live_bytes(), 300);
        assert_eq!(p.peak_bytes(), 900, "peak persists");
        p.free(b).unwrap();
        assert_eq!(p.buffer_count(), 0);
    }

    #[test]
    fn oom_when_over_capacity() {
        let p = MemoryPool::new("dev0", 100);
        p.alloc("a", 80).unwrap();
        assert!(p.alloc("b", 30).is_err());
        assert_eq!(p.live_bytes(), 80, "failed alloc must not leak");
    }

    #[test]
    fn double_free_rejected() {
        let p = MemoryPool::new("dev0", 100);
        let a = p.alloc("a", 10).unwrap();
        p.free(a).unwrap();
        assert!(p.free(a).is_err());
    }

    #[test]
    fn timeline_records_transitions() {
        let p = MemoryPool::new("dev0", 100);
        let a = p.alloc("w", 40).unwrap();
        p.free(a).unwrap();
        let t = p.timeline();
        assert_eq!(t.len(), 2);
        assert_eq!(t[0], MemEvent { label: "+w".into(), live_bytes: 40 });
        assert_eq!(t[1], MemEvent { label: "-w".into(), live_bytes: 0 });
    }

    #[test]
    fn unbounded_pool_tracks_watermarks_without_timeline() {
        let p = MemoryPool::unbounded("acct");
        let a = p.alloc("w", 40).unwrap();
        assert_eq!(p.live_bytes(), 40);
        assert_eq!(p.peak_bytes(), 40);
        p.free(a).unwrap();
        assert_eq!(p.live_bytes(), 0);
        assert!(p.timeline().is_empty(), "accounting pools record no events");
    }

    #[test]
    fn reset_peak_keeps_timeline() {
        let p = MemoryPool::new("dev0", 100);
        let a = p.alloc("w", 40).unwrap();
        p.free(a).unwrap();
        assert_eq!(p.peak_bytes(), 40);
        p.reset_peak();
        assert_eq!(p.peak_bytes(), 0, "peak rebased to live");
        assert_eq!(p.timeline().len(), 2, "timeline preserved");
    }

    #[test]
    fn label_filtering() {
        let p = MemoryPool::new("dev0", 100);
        p.alloc("update.w1", 10).unwrap();
        p.alloc("gen.w1", 20).unwrap();
        assert_eq!(p.live_bytes_matching(|l| l.starts_with("update.")), 10);
    }

    #[test]
    fn quota_defers_only_the_offending_tenant() {
        let q = TenantQuotas::new();
        q.set_quota(1, Some(100));
        assert!(q.try_charge(1, 80));
        assert!(!q.try_charge(1, 30), "would exceed quota");
        assert_eq!(q.charged(1), 80, "refused charge must not land");
        assert_eq!(q.snapshot()[0].1.deferrals, 1);
        // a sibling with headroom (or no quota at all) is unaffected
        assert!(q.try_charge(2, 1 << 30), "uncapped tenant never defers");
        q.set_quota(3, Some(50));
        assert!(q.try_charge(3, 50), "exactly at quota is admitted");
        assert!(q.over_quota(3));
        assert!(!q.over_quota(2), "uncapped tenants are never over quota");
    }

    #[test]
    fn high_water_survives_uncharge() {
        let q = TenantQuotas::new();
        q.set_quota(0, Some(1000));
        assert!(q.try_charge(0, 600));
        q.uncharge(0, 600);
        assert_eq!(q.charged(0), 0);
        let (_, s) = q.snapshot()[0];
        assert_eq!(s.high_water, 600, "high water persists across frees");
        // saturating: a chaos double-release must not underflow
        q.uncharge(0, 999);
        assert_eq!(q.charged(0), 0);
    }

    #[test]
    fn uncharge_reopens_admission() {
        let q = TenantQuotas::new();
        q.set_quota(7, Some(64));
        assert!(q.try_charge(7, 64));
        assert!(!q.try_charge(7, 1));
        q.uncharge(7, 32);
        assert!(q.try_charge(7, 32), "freed bytes reopen the quota");
        q.note_preemption(7);
        let (_, s) = q.snapshot()[0];
        assert_eq!(s.preemptions, 1);
        assert_eq!(s.deferrals, 1);
    }
}
