//! Device/host memory substrate: allocation-tracked buffer pools.
//!
//! The paper's allgather–swap claim (Fig. 5 / Fig. 10) is about *which
//! buffers exist on the device when*. This module provides the accounting
//! ground truth: every buffer in the resharding flow is allocated from a
//! per-device [`MemoryPool`] with capacity, live/peak tracking and a
//! timeline of (label, live-bytes) events — Fig. 10 is replayed directly
//! from that timeline.

use anyhow::{bail, Result};
use std::collections::BTreeMap;
use std::sync::Mutex;

/// Identifies a tracked buffer within a pool.
pub type BufferId = u64;

/// One memory event for profiling timelines (Fig. 10).
#[derive(Debug, Clone, PartialEq)]
pub struct MemEvent {
    pub label: String,
    pub live_bytes: u64,
}

/// An allocation-tracked memory pool (one per simulated device, plus one
/// per host).
#[derive(Debug)]
pub struct MemoryPool {
    pub name: String,
    pub capacity: u64,
    /// record per-event timelines (Fig. 10 replay). Off for pure
    /// accounting pools (`unbounded`), whose alloc/free churn over a
    /// whole training run would grow an unread event log without bound.
    record_timeline: bool,
    inner: Mutex<Inner>,
}

#[derive(Debug, Default)]
struct Inner {
    next_id: BufferId,
    buffers: BTreeMap<BufferId, (String, u64)>,
    live: u64,
    peak: u64,
    timeline: Vec<MemEvent>,
}

impl MemoryPool {
    pub fn new(name: impl Into<String>, capacity: u64) -> Self {
        Self {
            name: name.into(),
            capacity,
            record_timeline: true,
            inner: Mutex::new(Inner::default()),
        }
    }

    /// A pool used purely for accounting (no OOM enforcement, no event
    /// timeline) — e.g. the weight bus's retention pool, where the
    /// interesting output is the live/peak watermark, not an allocation
    /// failure or a replayable event log.
    pub fn unbounded(name: impl Into<String>) -> Self {
        Self {
            name: name.into(),
            capacity: u64::MAX,
            record_timeline: false,
            inner: Mutex::new(Inner::default()),
        }
    }

    /// Allocate a named buffer; fails if capacity would be exceeded (the
    /// OOM the paper's naive resharding flow risks).
    pub fn alloc(&self, label: impl Into<String>, bytes: u64) -> Result<BufferId> {
        let label = label.into();
        let mut g = self.inner.lock().unwrap();
        if g.live + bytes > self.capacity {
            bail!(
                "pool {}: OOM allocating {} for {label:?} (live {}, capacity {})",
                self.name,
                crate::util::fmt_bytes(bytes),
                crate::util::fmt_bytes(g.live),
                crate::util::fmt_bytes(self.capacity)
            );
        }
        let id = g.next_id;
        g.next_id += 1;
        g.live += bytes;
        g.peak = g.peak.max(g.live);
        g.buffers.insert(id, (label.clone(), bytes));
        if self.record_timeline {
            let ev = MemEvent { label: format!("+{label}"), live_bytes: g.live };
            g.timeline.push(ev);
        }
        Ok(id)
    }

    /// Free the first live buffer whose label matches exactly (used where
    /// callers track labels rather than ids, e.g. host swap space).
    pub fn free_by_label(&self, label: &str) -> Result<()> {
        let id = {
            let g = self.inner.lock().unwrap();
            g.buffers
                .iter()
                .find(|(_, (l, _))| l == label)
                .map(|(&id, _)| id)
        };
        match id {
            Some(id) => self.free(id),
            None => bail!("pool {}: no live buffer labeled {label:?}", self.name),
        }
    }

    pub fn free(&self, id: BufferId) -> Result<()> {
        let mut g = self.inner.lock().unwrap();
        let (label, bytes) = match g.buffers.remove(&id) {
            Some(x) => x,
            None => bail!("pool {}: double free of buffer {id}", self.name),
        };
        g.live -= bytes;
        if self.record_timeline {
            let ev = MemEvent { label: format!("-{label}"), live_bytes: g.live };
            g.timeline.push(ev);
        }
        Ok(())
    }

    pub fn live_bytes(&self) -> u64 {
        self.inner.lock().unwrap().live
    }

    pub fn peak_bytes(&self) -> u64 {
        self.inner.lock().unwrap().peak
    }

    pub fn free_bytes(&self) -> u64 {
        self.capacity - self.live_bytes()
    }

    pub fn buffer_count(&self) -> usize {
        self.inner.lock().unwrap().buffers.len()
    }

    /// Bytes held by buffers whose label matches a predicate.
    pub fn live_bytes_matching(&self, pred: impl Fn(&str) -> bool) -> u64 {
        let g = self.inner.lock().unwrap();
        g.buffers.values().filter(|(l, _)| pred(l)).map(|(_, b)| *b).sum()
    }

    pub fn timeline(&self) -> Vec<MemEvent> {
        self.inner.lock().unwrap().timeline.clone()
    }

    /// Reset peak/timeline (between experiment phases), keeping live
    /// buffers.
    pub fn reset_stats(&self) {
        let mut g = self.inner.lock().unwrap();
        g.peak = g.live;
        g.timeline.clear();
    }

    /// Reset only the peak watermark to the current live bytes (start of
    /// a new measurement phase), keeping the timeline — used by the
    /// resharder so each reshard's reported peak covers that reshard,
    /// not every run since the pool was created.
    pub fn reset_peak(&self) {
        let mut g = self.inner.lock().unwrap();
        g.peak = g.live;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_free_peak() {
        let p = MemoryPool::new("dev0", 1000);
        let a = p.alloc("weights", 600).unwrap();
        let b = p.alloc("kv", 300).unwrap();
        assert_eq!(p.live_bytes(), 900);
        assert_eq!(p.peak_bytes(), 900);
        p.free(a).unwrap();
        assert_eq!(p.live_bytes(), 300);
        assert_eq!(p.peak_bytes(), 900, "peak persists");
        p.free(b).unwrap();
        assert_eq!(p.buffer_count(), 0);
    }

    #[test]
    fn oom_when_over_capacity() {
        let p = MemoryPool::new("dev0", 100);
        p.alloc("a", 80).unwrap();
        assert!(p.alloc("b", 30).is_err());
        assert_eq!(p.live_bytes(), 80, "failed alloc must not leak");
    }

    #[test]
    fn double_free_rejected() {
        let p = MemoryPool::new("dev0", 100);
        let a = p.alloc("a", 10).unwrap();
        p.free(a).unwrap();
        assert!(p.free(a).is_err());
    }

    #[test]
    fn timeline_records_transitions() {
        let p = MemoryPool::new("dev0", 100);
        let a = p.alloc("w", 40).unwrap();
        p.free(a).unwrap();
        let t = p.timeline();
        assert_eq!(t.len(), 2);
        assert_eq!(t[0], MemEvent { label: "+w".into(), live_bytes: 40 });
        assert_eq!(t[1], MemEvent { label: "-w".into(), live_bytes: 0 });
    }

    #[test]
    fn unbounded_pool_tracks_watermarks_without_timeline() {
        let p = MemoryPool::unbounded("acct");
        let a = p.alloc("w", 40).unwrap();
        assert_eq!(p.live_bytes(), 40);
        assert_eq!(p.peak_bytes(), 40);
        p.free(a).unwrap();
        assert_eq!(p.live_bytes(), 0);
        assert!(p.timeline().is_empty(), "accounting pools record no events");
    }

    #[test]
    fn reset_peak_keeps_timeline() {
        let p = MemoryPool::new("dev0", 100);
        let a = p.alloc("w", 40).unwrap();
        p.free(a).unwrap();
        assert_eq!(p.peak_bytes(), 40);
        p.reset_peak();
        assert_eq!(p.peak_bytes(), 0, "peak rebased to live");
        assert_eq!(p.timeline().len(), 2, "timeline preserved");
    }

    #[test]
    fn label_filtering() {
        let p = MemoryPool::new("dev0", 100);
        p.alloc("update.w1", 10).unwrap();
        p.alloc("gen.w1", 20).unwrap();
        assert_eq!(p.live_bytes_matching(|l| l.starts_with("update.")), 10);
    }
}
