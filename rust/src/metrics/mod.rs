//! Metrics: the paper's throughput definition (Eq. 5), stage timers, and
//! CSV/markdown emitters used by EXPERIMENTS.md.

use std::collections::BTreeMap;
use std::time::Instant;

/// Wall-clock readings below this are degenerate (a zero-duration run):
/// ratios computed against them would report absurd values (the old
/// `max(1e-12)` guard turned a zero wall into a `1e12×` overlap), so
/// every rate in this module reports 0 instead — and summaries print
/// `n/a`.
pub const MIN_WALL_SECS: f64 = 1e-9;

/// Eq. (5): `T = G × N × (PL + SL) / ND / ETE` (tokens/sec/device).
/// Degenerate `ete_secs` (below [`MIN_WALL_SECS`]) reports 0, not a
/// fantastical throughput.
pub fn throughput_tps(
    g: u64,
    n_resp: u64,
    pl: u64,
    sl: u64,
    n_devices: u64,
    ete_secs: f64,
) -> f64 {
    if ete_secs < MIN_WALL_SECS {
        return 0.0;
    }
    (g * n_resp * (pl + sl)) as f64 / n_devices as f64 / ete_secs
}

/// Named stage timers (generation / inference / update / dispatch...).
#[derive(Debug, Default)]
pub struct StageTimers {
    totals: BTreeMap<String, f64>,
    counts: BTreeMap<String, u64>,
}

impl StageTimers {
    pub fn time<T>(&mut self, stage: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.add(stage, t0.elapsed().as_secs_f64());
        out
    }

    pub fn add(&mut self, stage: &str, secs: f64) {
        *self.totals.entry(stage.to_string()).or_default() += secs;
        *self.counts.entry(stage.to_string()).or_default() += 1;
    }

    pub fn total(&self, stage: &str) -> f64 {
        self.totals.get(stage).copied().unwrap_or(0.0)
    }

    pub fn summary(&self) -> String {
        self.totals
            .iter()
            .map(|(k, v)| format!("{k}={}", crate::util::fmt_secs(*v)))
            .collect::<Vec<_>>()
            .join(" ")
    }

    pub fn entries(&self) -> Vec<(String, f64, u64)> {
        self.totals
            .iter()
            .map(|(k, &v)| (k.clone(), v, self.counts[k]))
            .collect()
    }
}

/// Behavior-policy staleness accounting for version-stamped samples: how
/// many weight publishes behind the consuming update each sample's
/// generation-time weights were. In `sync` mode the lag is 0 by
/// construction; in `pipelined` mode it reports how stale generation
/// actually ran inside the `max_inflight_iters` window.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct VersionLag {
    /// samples measured
    pub samples: u64,
    /// Σ (update-time head version − stamped behavior version)
    pub sum: u64,
    /// worst single-sample lag
    pub max: u64,
}

impl VersionLag {
    pub fn record(&mut self, lag: u64) {
        self.samples += 1;
        self.sum += lag;
        self.max = self.max.max(lag);
    }

    pub fn merge(&mut self, other: &VersionLag) {
        self.samples += other.samples;
        self.sum += other.sum;
        self.max = self.max.max(other.max);
    }

    /// Mean publishes-behind across measured samples.
    pub fn mean(&self) -> f64 {
        if self.samples == 0 {
            0.0
        } else {
            self.sum as f64 / self.samples as f64
        }
    }
}

/// Weight-bus retention accounting: what the shard-level deduplicated
/// ring actually holds vs what a full-copy ring of the same versions
/// would hold (the Fig-10-style number for the sample-flow weight
/// channel). Produced by `weights::WeightBus::retention_stats`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct BusRetention {
    /// versions currently retained in the ring
    pub versions: usize,
    /// unique (tensor, content-epoch) shards backing those versions
    pub unique_shards: usize,
    /// Σ bytes of unique retained shards (== the bus pool's live bytes)
    pub retained_bytes: u64,
    /// high-water mark of `retained_bytes`
    pub peak_retained_bytes: u64,
    /// what full-copy retention of the same versions would hold
    pub naive_equivalent_bytes: u64,
}

impl BusRetention {
    /// Bytes the shard-level retention saves over full copies.
    pub fn savings_bytes(&self) -> u64 {
        self.naive_equivalent_bytes.saturating_sub(self.retained_bytes)
    }

    /// naive / retained: 1.0 = no sharing, `versions`× = perfect dedup.
    pub fn dedup_ratio(&self) -> f64 {
        if self.retained_bytes == 0 {
            1.0
        } else {
            self.naive_equivalent_bytes as f64 / self.retained_bytes as f64
        }
    }
}

/// Fault-recovery accounting for a lease-based sample flow: what the
/// claim leases did over a run (granted / renewed / reclaimed after
/// expiry / re-dispatched), plus the faults the executor injected
/// (kills, stalls, stage restarts) when a chaos plan was active.
///
/// Conservation invariants, pinned by `tests/chaos.rs`:
/// * every reclaim bumps exactly one attempt counter, so
///   `reclaimed == attempt_bumps` always;
/// * a redispatch is a grant of a sample some earlier lease lost, so
///   `redispatched <= reclaimed`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FlowRecovery {
    /// claim leases handed out
    pub leases_granted: u64,
    /// lease extensions (writeback activity or explicit `renew`)
    pub leases_renewed: u64,
    /// leases that expired and returned their sample to the ready pool
    pub reclaimed: u64,
    /// grants of a sample whose earlier lease expired (attempt > 0)
    pub redispatched: u64,
    /// Σ attempt-counter bumps (== `reclaimed` by construction)
    pub attempt_bumps: u64,
    /// worst per-sample attempt count observed
    pub max_attempt: u32,
    /// writebacks dropped as stale (late writer after reclaim/retire)
    pub superseded_writebacks: u64,
    /// fault injections: stage workers killed mid-claim
    pub kills: u64,
    /// fault injections: stage workers stalled past their lease
    pub stalls: u64,
    /// stage-worker restarts after a kill
    pub restarts: u64,
}

impl FlowRecovery {
    pub fn merge(&mut self, other: &FlowRecovery) {
        self.leases_granted += other.leases_granted;
        self.leases_renewed += other.leases_renewed;
        self.reclaimed += other.reclaimed;
        self.redispatched += other.redispatched;
        self.attempt_bumps += other.attempt_bumps;
        self.max_attempt = self.max_attempt.max(other.max_attempt);
        self.superseded_writebacks += other.superseded_writebacks;
        self.kills += other.kills;
        self.stalls += other.stalls;
        self.restarts += other.restarts;
    }

    /// The lease-accounting invariants that must hold at any quiescent
    /// point (no tick in flight): see the struct docs.
    pub fn consistent(&self) -> bool {
        self.reclaimed == self.attempt_bumps && self.redispatched <= self.reclaimed
    }

    /// Anything to report? (fault-free, never-expired runs stay silent)
    pub fn any_recovery(&self) -> bool {
        self.reclaimed > 0
            || self.superseded_writebacks > 0
            || self.kills > 0
            || self.stalls > 0
            || self.restarts > 0
    }
}

/// One stage's elastic-replica accounting over a run: how the replica
/// count moved (timeline of `(lease tick, live replicas)` at each
/// change), what drove it (backlog high-water, idle observations), and
/// the replica-second integral that replica-aware utilization divides
/// by. Produced by the executor's `ReplicaSet`s plus the `Autoscaler`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct StageScale {
    /// replicas at run start (after the configured initial spawn)
    pub initial: usize,
    /// replicas live when the run ended
    pub final_replicas: usize,
    /// most replicas ever live at once
    pub max_replicas: usize,
    /// autoscaler grow decisions applied
    pub grows: u64,
    /// autoscaler drain-then-retire decisions applied
    pub shrinks: u64,
    /// worst ready-queue depth the autoscaler observed
    pub backlog_high_water: usize,
    /// observations with at least one idle replica
    pub idle_obs: u64,
    /// total autoscaler observations of this stage
    pub obs: u64,
    /// Σ over time of (live replicas × seconds) — the slot-time
    /// denominator for replica-aware utilization
    pub replica_secs: f64,
    /// `(lease tick, live replicas)` at every count change
    pub timeline: Vec<(u64, usize)>,
}

impl StageScale {
    /// Fraction of observations with an idle replica.
    pub fn idle_ratio(&self) -> f64 {
        if self.obs == 0 {
            0.0
        } else {
            self.idle_obs as f64 / self.obs as f64
        }
    }
}

/// Per-stage elastic-replica report for a whole run (empty for sync mode
/// and for pipelined runs that never configured replicas).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct StageScaling {
    pub stages: BTreeMap<String, StageScale>,
    /// high-water mark of the tracked `stage-replicas` pool: what the
    /// replicas' materialized weight views (generation head-trackers,
    /// old-logprob pinned caches) cost at their widest
    pub replica_weight_bytes_peak: u64,
}

impl StageScaling {
    /// Anything beyond the one-thread-per-stage baseline?
    pub fn any_scaled(&self) -> bool {
        self.stages
            .values()
            .any(|s| s.max_replicas > 1 || s.grows + s.shrinks > 0)
    }

    /// Replica-second denominator for `stage`, when recorded.
    pub fn replica_secs(&self, stage: &str) -> Option<f64> {
        self.stages.get(stage).map(|s| s.replica_secs).filter(|&s| s >= MIN_WALL_SECS)
    }

    /// Compact `gen 1→4 …` clause for run summaries.
    pub fn summary(&self) -> String {
        self.stages
            .iter()
            .filter(|(_, s)| s.max_replicas > 1 || s.grows + s.shrinks > 0)
            .map(|(name, s)| {
                format!(
                    "{name} {}→{} (max={} bklg^={})",
                    s.initial, s.final_replicas, s.max_replicas, s.backlog_high_water
                )
            })
            .collect::<Vec<_>>()
            .join(" ")
    }
}

/// Streaming-generation scheduler telemetry, aggregated across every
/// session (one per generation replica incarnation) of a run. All-zero
/// when the run decoded claim-at-a-time (`--gen-streaming` off).
///
/// Everything is a raw counter — occupancy, time-to-first-token, and
/// admit latency are derived on read, so reports from differently-sized
/// sessions merge slot-step- and sequence-weighted rather than
/// session-weighted.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StreamGenReport {
    /// sessions absorbed (≥ replica incarnations that decoded anything)
    pub sessions: u64,
    /// scheduler steps across all sessions
    pub steps: u64,
    /// engine decode calls (≥ steps: chunked prefill adds micro-calls)
    pub decode_calls: u64,
    /// slot-calls that advanced a live sequence
    pub busy_slot_steps: u64,
    /// slot-calls total (busy + idle + frozen)
    pub total_slot_steps: u64,
    /// response tokens sampled
    pub tokens: u64,
    /// sequences retired by the scheduler
    pub retired: u64,
    /// steps on which at least one sequence retired
    pub retire_steps: u64,
    /// most sequences retired on a single step
    pub max_retired_in_step: u64,
    /// sequences admitted into a slot
    pub admitted: u64,
    /// Σ (admission step − submit step)
    pub admit_wait_steps: u64,
    /// Σ (first-token step − admission step)
    pub first_token_steps: u64,
    /// sequences that sampled at least one token
    pub first_token_seqs: u64,
    /// admissions deferred on KV-pool backpressure
    pub kv_deferrals: u64,
    /// sequences admitted from a persisted partial prefix
    pub resumed: u64,
    /// prefix tokens handed back at resume — decode work *not* redone
    pub resumed_tokens: u64,
}

impl StreamGenReport {
    /// Fold one session's cumulative stats in.
    pub fn absorb(&mut self, s: &crate::generation::StreamStats) {
        self.sessions += 1;
        self.steps += s.steps;
        self.decode_calls += s.decode_calls;
        self.busy_slot_steps += s.busy_slot_steps;
        self.total_slot_steps += s.total_slot_steps;
        self.tokens += s.tokens_generated;
        self.retired += s.retired;
        self.retire_steps += s.retire_steps;
        self.max_retired_in_step = self.max_retired_in_step.max(s.max_retired_in_step);
        self.admitted += s.admitted;
        self.admit_wait_steps += s.admit_wait_steps;
        self.first_token_steps += s.first_token_steps;
        self.first_token_seqs += s.first_token_seqs;
        self.kv_deferrals += s.kv_deferrals;
        self.resumed += s.resumed;
        self.resumed_tokens += s.resumed_tokens;
    }

    /// Fraction of slot-calls that advanced a live sequence.
    pub fn occupancy(&self) -> f64 {
        if self.total_slot_steps == 0 {
            0.0
        } else {
            self.busy_slot_steps as f64 / self.total_slot_steps as f64
        }
    }

    /// Mean scheduler steps from admission to first sampled token —
    /// `None` when no sequence produced a token (the mean does not
    /// exist; the raw `0/0` is NaN and must never reach gated bench
    /// JSON — callers print `n/a` or omit the metric, the same
    /// convention [`MIN_WALL_SECS`] imposes on degenerate rates).
    pub fn mean_ttft_steps(&self) -> Option<f64> {
        (self.first_token_seqs > 0)
            .then(|| self.first_token_steps as f64 / self.first_token_seqs as f64)
    }

    /// Mean scheduler steps a request waited before getting a slot —
    /// `None` before any admission (same no-data convention as
    /// [`Self::mean_ttft_steps`]).
    pub fn mean_admit_wait_steps(&self) -> Option<f64> {
        (self.admitted > 0).then(|| self.admit_wait_steps as f64 / self.admitted as f64)
    }

    /// Mean sequences retired per retiring step (per-sequence retirement
    /// keeps this near 1; batch-style draining pushes it toward the slot
    /// count).
    pub fn mean_retired_per_retire_step(&self) -> f64 {
        if self.retire_steps == 0 {
            0.0
        } else {
            self.retired as f64 / self.retire_steps as f64
        }
    }

    /// Did the run stream at all? (quiet-summary gate)
    pub fn active(&self) -> bool {
        self.sessions > 0 && self.total_slot_steps > 0
    }
}

/// Partial-rollout (resumable generation) accounting for one run: how
/// much interrupted decode work was persisted, how much a later
/// redispatch got back for free, and how much had to be recomputed. All
/// raw counters so replica reports merge additively.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PartialRolloutReport {
    /// partial prefixes persisted through the flow (kills, drains,
    /// publish preemptions, periodic checkpoints)
    pub persisted: u64,
    /// tokens carried by those persists
    pub persisted_tokens: u64,
    /// sequences admitted from a persisted prefix
    pub resumed: u64,
    /// prefix tokens handed back at resume — decode steps *not* redone
    /// (the prefix is re-prefilled, never re-sampled)
    pub saved_tokens: u64,
    /// sequences exported + persisted + released because a weight
    /// publish landed (`--preempt-on-publish`)
    pub publish_preemptions: u64,
    /// finished responses whose segment list spans ≥ 2 behavior
    /// versions (each segment scored under its own stamped version)
    pub multi_segment_responses: u64,
}

impl PartialRolloutReport {
    pub fn merge(&mut self, other: &Self) {
        self.persisted += other.persisted;
        self.persisted_tokens += other.persisted_tokens;
        self.resumed += other.resumed;
        self.saved_tokens += other.saved_tokens;
        self.publish_preemptions += other.publish_preemptions;
        self.multi_segment_responses += other.multi_segment_responses;
    }

    /// Did partial rollouts do anything this run? (quiet-summary gate)
    pub fn active(&self) -> bool {
        self.persisted > 0 || self.resumed > 0
    }
}

/// One controller shard's dispatch counters over a run. All raw counts —
/// steal fractions and balance ratios are derived on read, so per-shard
/// records from replica reports merge additively (the PR 6 occupancy
/// convention: never mean-of-ratios).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DockShard {
    /// samples this shard handed to a claimant whose home it was
    pub claims: u64,
    /// samples stolen *from* this shard by a sibling's claimant
    pub stolen: u64,
    /// leases this shard's tables reclaimed after expiry
    pub reclaimed: u64,
}

/// Per-controller-shard dispatch report for a sharded transfer dock
/// (`--dock-shards K`). Empty / shards ≤ 1 for unsharded flows.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct DockShardReport {
    /// controller shards per worker state (K); 0 when no dock reported
    pub shards: usize,
    /// one record per shard, indexed by shard id
    pub per_shard: Vec<DockShard>,
}

impl DockShardReport {
    /// Merge another report in: raw counters add elementwise per shard
    /// (reports from different runs of the same dock share shard ids).
    pub fn merge(&mut self, other: &Self) {
        self.shards = self.shards.max(other.shards);
        if self.per_shard.len() < other.per_shard.len() {
            self.per_shard.resize(other.per_shard.len(), DockShard::default());
        }
        for (mine, theirs) in self.per_shard.iter_mut().zip(&other.per_shard) {
            mine.claims += theirs.claims;
            mine.stolen += theirs.stolen;
            mine.reclaimed += theirs.reclaimed;
        }
    }

    /// Σ over shards (the additive totals ratios are derived from).
    pub fn totals(&self) -> DockShard {
        let mut t = DockShard::default();
        for s in &self.per_shard {
            t.claims += s.claims;
            t.stolen += s.stolen;
            t.reclaimed += s.reclaimed;
        }
        t
    }

    /// Fraction of all handouts that crossed shards (total stolen over
    /// total handed out) — derived on read from the raw totals, never
    /// averaged per shard.
    pub fn steal_fraction(&self) -> f64 {
        let t = self.totals();
        let handed = t.claims + t.stolen;
        if handed == 0 {
            0.0
        } else {
            t.stolen as f64 / handed as f64
        }
    }

    /// Anything to report? Single-shard docks stay out of summaries —
    /// their numbers duplicate the recovery clause and stage counters.
    pub fn active(&self) -> bool {
        self.shards > 1
    }
}

/// One tenant's raw scheduling/quota counters (additive across merges;
/// every ratio is derived on read).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct TenantLane {
    /// tenant id (0 = the default tenant)
    pub tenant: u32,
    /// configured claim weight the fair-share gate compares against
    pub weight: u32,
    /// samples the flow handed this tenant's claimants
    pub claims: u64,
    /// response tokens this tenant's retired samples carried
    pub tokens: u64,
    /// per-tenant quota high-water mark (bytes)
    pub quota_high_water: u64,
    /// admissions deferred because this tenant hit its quota
    pub quota_deferrals: u64,
    /// times this tenant's live work was preempted to reclaim quota
    pub preemptions: u64,
}

/// Per-tenant accounting for a multi-tenant run (`--tenants N`): claim
/// share vs configured weight, per-tenant throughput, quota pressure.
/// Empty (or a single lane) for single-tenant runs, which stay out of
/// summaries entirely.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct TenantReport {
    /// one lane per tenant, tenant-id ascending
    pub lanes: Vec<TenantLane>,
}

impl TenantReport {
    /// Merge another report in: lanes match on tenant id, raw counters
    /// add, weights agree by construction (same run config) — a lane
    /// only seen on one side is appended as-is.
    pub fn merge(&mut self, other: &Self) {
        for theirs in &other.lanes {
            match self.lanes.iter_mut().find(|l| l.tenant == theirs.tenant) {
                Some(mine) => {
                    if mine.weight == 0 {
                        mine.weight = theirs.weight;
                    }
                    mine.claims += theirs.claims;
                    mine.tokens += theirs.tokens;
                    mine.quota_high_water = mine.quota_high_water.max(theirs.quota_high_water);
                    mine.quota_deferrals += theirs.quota_deferrals;
                    mine.preemptions += theirs.preemptions;
                }
                None => self.lanes.push(theirs.clone()),
            }
        }
        self.lanes.sort_by_key(|l| l.tenant);
    }

    pub fn total_claims(&self) -> u64 {
        self.lanes.iter().map(|l| l.claims).sum()
    }

    pub fn total_tokens(&self) -> u64 {
        self.lanes.iter().map(|l| l.tokens).sum()
    }

    /// This tenant's fraction of all claims handed out (0 when nothing
    /// was handed out yet).
    pub fn claim_share(&self, tenant: u32) -> f64 {
        let total = self.total_claims();
        if total == 0 {
            return 0.0;
        }
        self.lanes
            .iter()
            .find(|l| l.tenant == tenant)
            .map_or(0.0, |l| l.claims as f64 / total as f64)
    }

    /// Jain fairness index over weight-normalized claim shares,
    /// `J = (Σx)² / (n·Σx²)` with `x_t = claim_share_t / weight_t`.
    /// 1.0 = every tenant's share exactly tracks its weight; `1/n` =
    /// one tenant took everything. Degenerate inputs (≤1 lane, or no
    /// claims yet) report 1.0 — nothing has been shared unfairly.
    pub fn jain_index(&self) -> f64 {
        if self.lanes.len() <= 1 || self.total_claims() == 0 {
            return 1.0;
        }
        let xs: Vec<f64> = self
            .lanes
            .iter()
            .map(|l| self.claim_share(l.tenant) / l.weight.max(1) as f64)
            .collect();
        let sum: f64 = xs.iter().sum();
        let sq: f64 = xs.iter().map(|x| x * x).sum();
        if sq == 0.0 {
            return 1.0;
        }
        (sum * sum) / (xs.len() as f64 * sq)
    }

    /// Anything to report? Single-tenant runs stay out of summaries —
    /// one lane's share is 100% by definition and its quota counters
    /// already surface through the stream/partial clauses.
    pub fn active(&self) -> bool {
        self.lanes.len() > 1
    }
}

/// Wall-clock vs per-stage busy time for one trainer run — the overlap
/// accounting the pipelined executor reports.
///
/// In `sync` mode stages run back-to-back, so `busy_total ≈ wall` and the
/// overlap ratio sits near 1.0. In `pipelined` mode stage threads run
/// concurrently; the sum of busy seconds exceeds the wall clock and the
/// ratio tells you how much of the dataflow graph actually overlapped.
#[derive(Debug, Clone, Default)]
pub struct PipelineReport {
    /// executor mode the run used ("sync" | "pipelined")
    pub mode: String,
    /// end-to-end wall-clock of the training loop
    pub wall_secs: f64,
    /// busy seconds per stage (time inside compute, excluding waits)
    pub busy: BTreeMap<String, f64>,
    /// per-iteration behavior-policy staleness, in finalize order
    pub version_lag: Vec<(usize, VersionLag)>,
    /// weight-bus retention at the end of the run (all-zero when the run
    /// had no bus: sync mode without `keep_weight_history`)
    pub bus: BusRetention,
    /// lease/reclaim/fault accounting (all-zero for fault-free runs whose
    /// leases never expired)
    pub recovery: FlowRecovery,
    /// elastic stage-replica accounting (empty when every stage ran one
    /// thread, i.e. sync mode or an unreplicated pipelined run)
    pub scaling: StageScaling,
    /// streaming-generation scheduler telemetry (all-zero when the run
    /// decoded claim-at-a-time)
    pub gen_stream: StreamGenReport,
    /// partial-rollout persistence/resume accounting (all-zero unless
    /// `--partial-rollouts` interrupted and resumed something)
    pub partial: PartialRolloutReport,
    /// per-controller-shard dispatch counters (empty unless the run drove
    /// a sharded dock, `--dock-shards > 1`)
    pub dock: DockShardReport,
    /// per-tenant claim/quota accounting (≤ 1 lane unless the run
    /// multiplexed tenants, `--tenants > 1`)
    pub tenants: TenantReport,
}

impl PipelineReport {
    pub fn busy_total(&self) -> f64 {
        self.busy.values().sum()
    }

    /// Σ busy / wall: 1.0 = fully serial, >1.0 = stages overlapped.
    /// A degenerate wall clock (below [`MIN_WALL_SECS`]) reports 0.
    pub fn overlap_ratio(&self) -> f64 {
        if self.wall_secs < MIN_WALL_SECS {
            0.0
        } else {
            self.busy_total() / self.wall_secs
        }
    }

    /// Fraction of the stage's *slot time* it was busy. With elastic
    /// replicas the denominator is the stage's replica-seconds (Σ live
    /// replicas × seconds), so utilization stays in [0, 1] however many
    /// replicas ran; stages without a replica record (sync mode, the
    /// update driver) fall back to the wall clock, the one-thread case
    /// where slot time == wall time. 0 for a degenerate denominator.
    pub fn utilization(&self, stage: &str) -> f64 {
        let denom = self.scaling.replica_secs(stage).unwrap_or(self.wall_secs);
        if denom < MIN_WALL_SECS {
            0.0
        } else {
            self.busy.get(stage).copied().unwrap_or(0.0) / denom
        }
    }

    /// Run-level behavior-policy staleness (all iterations merged).
    pub fn lag_total(&self) -> VersionLag {
        let mut total = VersionLag::default();
        for (_, lag) in &self.version_lag {
            total.merge(lag);
        }
        total
    }

    pub fn summary(&self) -> String {
        let stages = self
            .busy
            .iter()
            .map(|(k, v)| {
                format!("{k}={} ({:.0}%)", crate::util::fmt_secs(*v), self.utilization(k) * 100.0)
            })
            .collect::<Vec<_>>()
            .join(" ");
        let overlap = if self.wall_secs < MIN_WALL_SECS {
            "n/a".to_string()
        } else {
            format!("{:.2}x", self.overlap_ratio())
        };
        let lag = self.lag_total();
        let lag = if lag.samples == 0 {
            String::new()
        } else {
            format!(" lag(mean={:.2},max={})", lag.mean(), lag.max)
        };
        let bus = if self.bus.versions == 0 {
            String::new()
        } else {
            format!(
                " bus[{}v/{}sh {} vs {} full-copy]",
                self.bus.versions,
                self.bus.unique_shards,
                crate::util::fmt_bytes(self.bus.retained_bytes),
                crate::util::fmt_bytes(self.bus.naive_equivalent_bytes)
            )
        };
        let scaling = if !self.scaling.any_scaled() {
            String::new()
        } else {
            format!(" scaling[{}]", self.scaling.summary())
        };
        // a mean over zero sequences has no value: print `n/a`, never a
        // raw 0/0 (which is NaN)
        let fmt_mean = |m: Option<f64>| m.map_or_else(|| "n/a".to_string(), |v| format!("{v:.1}"));
        let stream = if !self.gen_stream.active() {
            String::new()
        } else {
            format!(
                " stream[occ={:.0}% ttft={}st admit={}st retire/st={:.1} kv-defer={}]",
                self.gen_stream.occupancy() * 100.0,
                fmt_mean(self.gen_stream.mean_ttft_steps()),
                fmt_mean(self.gen_stream.mean_admit_wait_steps()),
                self.gen_stream.mean_retired_per_retire_step(),
                self.gen_stream.kv_deferrals
            )
        };
        let partial = if !self.partial.active() {
            String::new()
        } else {
            format!(
                " partial[persist={} resume={} saved={}tok preempt={} multiseg={}]",
                self.partial.persisted,
                self.partial.resumed,
                self.partial.saved_tokens,
                self.partial.publish_preemptions,
                self.partial.multi_segment_responses
            )
        };
        let rec = if !self.recovery.any_recovery() {
            String::new()
        } else {
            format!(
                " recovery[reclaim={} redisp={} stale-wb={} kills={} stalls={} restarts={}]",
                self.recovery.reclaimed,
                self.recovery.redispatched,
                self.recovery.superseded_writebacks,
                self.recovery.kills,
                self.recovery.stalls,
                self.recovery.restarts
            )
        };
        let dock = if !self.dock.active() {
            String::new()
        } else {
            let t = self.dock.totals();
            format!(
                " dock[shards={} claims={} stolen={} ({:.0}%) reclaim={}]",
                self.dock.shards,
                t.claims,
                t.stolen,
                self.dock.steal_fraction() * 100.0,
                t.reclaimed
            )
        };
        let tenants = if !self.tenants.active() {
            String::new()
        } else {
            let lanes = self
                .tenants
                .lanes
                .iter()
                .map(|l| {
                    format!(
                        "t{}:w{}={:.0}%",
                        l.tenant,
                        l.weight,
                        self.tenants.claim_share(l.tenant) * 100.0
                    )
                })
                .collect::<Vec<_>>()
                .join(" ");
            let defer: u64 = self.tenants.lanes.iter().map(|l| l.quota_deferrals).sum();
            let preempt: u64 = self.tenants.lanes.iter().map(|l| l.preemptions).sum();
            format!(
                " tenants[jain={:.2} {lanes} defer={defer} preempt={preempt}]",
                self.tenants.jain_index()
            )
        };
        format!(
            "[{}] wall={} overlap={}{}{}{}{}{}{}{}{} {}",
            self.mode,
            crate::util::fmt_secs(self.wall_secs),
            overlap,
            lag,
            bus,
            scaling,
            stream,
            partial,
            rec,
            dock,
            tenants,
            stages
        )
    }
}

/// Minimal CSV writer for experiment curves.
pub struct CsvWriter {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl CsvWriter {
    pub fn new(header: &[&str]) -> Self {
        Self { header: header.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells.to_vec());
    }

    pub fn row_f64(&mut self, cells: &[f64]) {
        self.row(&cells.iter().map(|c| format!("{c}")).collect::<Vec<_>>());
    }

    #[allow(clippy::inherent_to_string)]
    pub fn to_string(&self) -> String {
        let mut s = self.header.join(",");
        s.push('\n');
        for r in &self.rows {
            s.push_str(&r.join(","));
            s.push('\n');
        }
        s
    }

    pub fn write(&self, path: impl AsRef<std::path::Path>) -> anyhow::Result<()> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_string())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq5_matches_paper_units() {
        // 256 prompts × 16 responses × (2K+8K) tokens over 16 devices in
        // 1000s → 2621.44 TPS
        let t = throughput_tps(256, 16, 2048, 8192, 16, 1000.0);
        assert!((t - 2621.44).abs() < 0.01, "{t}");
    }

    #[test]
    fn timers_accumulate() {
        let mut t = StageTimers::default();
        t.add("gen", 1.0);
        t.add("gen", 0.5);
        t.add("update", 2.0);
        assert_eq!(t.total("gen"), 1.5);
        assert!(t.summary().contains("gen"));
        assert_eq!(t.entries().len(), 2);
    }

    #[test]
    fn pipeline_report_overlap() {
        let mut r = PipelineReport { mode: "pipelined".into(), wall_secs: 2.0, ..Default::default() };
        r.busy.insert("generation".into(), 1.8);
        r.busy.insert("update".into(), 1.2);
        assert!((r.busy_total() - 3.0).abs() < 1e-9);
        assert!((r.overlap_ratio() - 1.5).abs() < 1e-9);
        assert!((r.utilization("generation") - 0.9).abs() < 1e-9);
        assert_eq!(r.utilization("missing"), 0.0);
        assert!(r.summary().contains("overlap=1.50x"));
    }

    #[test]
    fn version_lag_statistics() {
        let mut a = VersionLag::default();
        a.record(0);
        a.record(2);
        a.record(1);
        assert_eq!(a.samples, 3);
        assert_eq!(a.max, 2);
        assert!((a.mean() - 1.0).abs() < 1e-12);
        let mut b = VersionLag::default();
        b.record(5);
        a.merge(&b);
        assert_eq!(a.samples, 4);
        assert_eq!(a.max, 5);
        assert_eq!(VersionLag::default().mean(), 0.0);

        let mut r = PipelineReport { mode: "pipelined".into(), wall_secs: 1.0, ..Default::default() };
        r.version_lag.push((0, a));
        r.version_lag.push((1, b));
        let total = r.lag_total();
        assert_eq!(total.samples, 5);
        assert_eq!(total.max, 5);
        assert!(r.summary().contains("lag(mean="));
    }

    #[test]
    fn degenerate_wall_clock_reports_zero_not_1e12() {
        // the regression: busy / wall.max(1e-12) on a zero-wall run
        // reported an absurd ~1e12x overlap in summaries
        let mut r = PipelineReport { mode: "pipelined".into(), wall_secs: 0.0, ..Default::default() };
        r.busy.insert("generation".into(), 1.0);
        assert_eq!(r.overlap_ratio(), 0.0);
        assert_eq!(r.utilization("generation"), 0.0);
        assert!(r.summary().contains("overlap=n/a"), "{}", r.summary());
        // just under the epsilon behaves the same
        r.wall_secs = MIN_WALL_SECS / 2.0;
        assert_eq!(r.overlap_ratio(), 0.0);
        // a sane wall clock is unaffected
        r.wall_secs = 2.0;
        assert!((r.overlap_ratio() - 0.5).abs() < 1e-12);
        assert!(r.summary().contains("overlap=0.50x"));
        // same guard on Eq. (5)
        assert_eq!(throughput_tps(256, 16, 2048, 8192, 16, 0.0), 0.0);
        assert!(throughput_tps(256, 16, 2048, 8192, 16, 1.0) > 0.0);
    }

    #[test]
    fn bus_retention_arithmetic_and_summary() {
        let b = BusRetention {
            versions: 3,
            unique_shards: 5,
            retained_bytes: 400,
            peak_retained_bytes: 500,
            naive_equivalent_bytes: 1200,
        };
        assert_eq!(b.savings_bytes(), 800);
        assert!((b.dedup_ratio() - 3.0).abs() < 1e-12);
        assert_eq!(BusRetention::default().dedup_ratio(), 1.0);
        let r = PipelineReport { mode: "pipelined".into(), wall_secs: 1.0, bus: b, ..Default::default() };
        assert!(r.summary().contains("bus[3v/5sh"), "{}", r.summary());
        // no bus in the run → no bus clause in the summary
        let r0 = PipelineReport { mode: "sync".into(), wall_secs: 1.0, ..Default::default() };
        assert!(!r0.summary().contains("bus["));
    }

    #[test]
    fn flow_recovery_invariants_and_summary() {
        let mut a = FlowRecovery {
            leases_granted: 10,
            leases_renewed: 3,
            reclaimed: 2,
            redispatched: 2,
            attempt_bumps: 2,
            max_attempt: 1,
            superseded_writebacks: 1,
            kills: 1,
            stalls: 1,
            restarts: 1,
        };
        assert!(a.consistent());
        assert!(a.any_recovery());
        let b = FlowRecovery { reclaimed: 1, attempt_bumps: 1, max_attempt: 3, ..Default::default() };
        a.merge(&b);
        assert_eq!(a.reclaimed, 3);
        assert_eq!(a.max_attempt, 3);
        assert!(a.consistent());
        // broken bookkeeping is detectable
        let bad = FlowRecovery { reclaimed: 2, attempt_bumps: 1, ..Default::default() };
        assert!(!bad.consistent());
        let bad2 = FlowRecovery { redispatched: 3, reclaimed: 1, attempt_bumps: 1, ..Default::default() };
        assert!(!bad2.consistent());

        // a quiet run keeps the summary free of the recovery clause
        let quiet = PipelineReport { mode: "pipelined".into(), wall_secs: 1.0, ..Default::default() };
        assert!(!quiet.summary().contains("recovery["));
        let loud = PipelineReport {
            mode: "pipelined".into(),
            wall_secs: 1.0,
            recovery: a,
            ..Default::default()
        };
        assert!(loud.summary().contains("recovery[reclaim=3"), "{}", loud.summary());
    }

    #[test]
    fn utilization_is_replica_aware() {
        // the satellite regression: with N replica threads the old
        // busy/wall ratio exceeded 1.0 — slot time must divide instead
        let mut r = PipelineReport { mode: "pipelined".into(), wall_secs: 2.0, ..Default::default() };
        r.busy.insert("generation".into(), 3.6);
        // two generation replicas for the whole run: 4 replica-seconds
        r.scaling.stages.insert(
            "generation".into(),
            StageScale {
                initial: 2,
                final_replicas: 2,
                max_replicas: 2,
                replica_secs: 4.0,
                ..Default::default()
            },
        );
        let u = r.utilization("generation");
        assert!((u - 0.9).abs() < 1e-12, "{u}");
        assert!(u <= 1.0);
        // a stage without a replica record keeps the wall denominator
        r.busy.insert("update".into(), 1.0);
        assert!((r.utilization("update") - 0.5).abs() < 1e-12);
        // scaled runs advertise the replica timeline in the summary
        assert!(r.scaling.any_scaled());
        assert!(r.summary().contains("scaling[generation 2→2"), "{}", r.summary());
        // unscaled runs stay silent
        let quiet = PipelineReport { mode: "pipelined".into(), wall_secs: 1.0, ..Default::default() };
        assert!(!quiet.summary().contains("scaling["));
        // idle-ratio arithmetic
        let s = StageScale { idle_obs: 3, obs: 4, ..Default::default() };
        assert!((s.idle_ratio() - 0.75).abs() < 1e-12);
        assert_eq!(StageScale::default().idle_ratio(), 0.0);
    }

    #[test]
    fn stream_report_merges_slot_step_weighted() {
        use crate::generation::StreamStats;
        let mut r = StreamGenReport::default();
        assert!(!r.active());
        assert_eq!(r.occupancy(), 0.0);
        assert_eq!(r.mean_ttft_steps(), None, "no sequences → no mean, not 0/0");
        // a big busy session and a small idle one: the merged occupancy
        // must weight by slot-steps, not average the two ratios
        r.absorb(&StreamStats {
            steps: 100,
            decode_calls: 120,
            busy_slot_steps: 900,
            total_slot_steps: 1000,
            tokens_generated: 900,
            retired: 30,
            retire_steps: 25,
            max_retired_in_step: 3,
            admitted: 30,
            admit_wait_steps: 15,
            first_token_steps: 60,
            first_token_seqs: 30,
            kv_deferrals: 2,
            ..Default::default()
        });
        r.absorb(&StreamStats {
            steps: 10,
            busy_slot_steps: 10,
            total_slot_steps: 100,
            ..Default::default()
        });
        assert!(r.active());
        assert_eq!(r.sessions, 2);
        // 910 / 1100, NOT (0.9 + 0.1) / 2
        assert!((r.occupancy() - 910.0 / 1100.0).abs() < 1e-12, "{}", r.occupancy());
        assert!((r.mean_ttft_steps().unwrap() - 2.0).abs() < 1e-12);
        assert!((r.mean_admit_wait_steps().unwrap() - 0.5).abs() < 1e-12);
        assert!((r.mean_retired_per_retire_step() - 30.0 / 25.0).abs() < 1e-12);
        assert_eq!(r.max_retired_in_step, 3);
        assert_eq!(r.kv_deferrals, 2);

        // summary clause appears only for streaming runs
        let quiet = PipelineReport { mode: "pipelined".into(), wall_secs: 1.0, ..Default::default() };
        assert!(!quiet.summary().contains("stream["));
        let loud = PipelineReport {
            mode: "pipelined".into(),
            wall_secs: 1.0,
            gen_stream: r,
            ..Default::default()
        };
        assert!(loud.summary().contains("stream[occ=83%"), "{}", loud.summary());
    }

    #[test]
    fn degenerate_stream_means_are_na_never_nan() {
        // a session that admitted work but retired / started nothing yet:
        // the means do not exist, and the summary must say so instead of
        // interpolating a NaN (which would poison gated bench JSON)
        let mut r = StreamGenReport::default();
        r.absorb(&crate::generation::StreamStats {
            steps: 5,
            total_slot_steps: 20,
            busy_slot_steps: 4,
            ..Default::default()
        });
        assert_eq!(r.mean_ttft_steps(), None);
        assert_eq!(r.mean_admit_wait_steps(), None);
        let rep = PipelineReport {
            mode: "pipelined".into(),
            wall_secs: 1.0,
            gen_stream: r,
            ..Default::default()
        };
        let s = rep.summary();
        assert!(s.contains("ttft=n/ast"), "{s}");
        assert!(s.contains("admit=n/ast"), "{s}");
        assert!(!s.contains("NaN"), "{s}");
    }

    #[test]
    fn partial_report_merges_and_gates_summary() {
        let mut a = PartialRolloutReport {
            persisted: 3,
            persisted_tokens: 30,
            resumed: 2,
            saved_tokens: 20,
            publish_preemptions: 1,
            multi_segment_responses: 2,
        };
        a.merge(&PartialRolloutReport { resumed: 1, saved_tokens: 5, ..Default::default() });
        assert_eq!(a.resumed, 3);
        assert_eq!(a.saved_tokens, 25);
        assert!(a.active());
        let rep = PipelineReport {
            mode: "pipelined".into(),
            wall_secs: 1.0,
            partial: a,
            ..Default::default()
        };
        assert!(rep.summary().contains("partial[persist=3 resume=3 saved=25tok"), "{}", rep.summary());
        // fault-free, never-interrupted runs stay silent
        let quiet = PipelineReport { mode: "pipelined".into(), wall_secs: 1.0, ..Default::default() };
        assert!(!quiet.summary().contains("partial["));
    }

    #[test]
    fn dock_shard_report_merges_raw_counters_not_ratios() {
        // two reports from the same 2-shard dock: one heavily stolen-from,
        // one barely — the merged steal fraction must come from the raw
        // totals (30 / 130), never the mean of the two per-run ratios
        let mut a = DockShardReport {
            shards: 2,
            per_shard: vec![
                DockShard { claims: 10, stolen: 20, reclaimed: 1 },
                DockShard { claims: 40, stolen: 5, reclaimed: 0 },
            ],
        };
        let b = DockShardReport {
            shards: 2,
            per_shard: vec![
                DockShard { claims: 50, stolen: 5, reclaimed: 2 },
                DockShard { claims: 0, stolen: 0, reclaimed: 0 },
            ],
        };
        a.merge(&b);
        let t = a.totals();
        assert_eq!(t.claims, 100);
        assert_eq!(t.stolen, 30);
        assert_eq!(t.reclaimed, 3);
        assert!((a.steal_fraction() - 30.0 / 130.0).abs() < 1e-12, "{}", a.steal_fraction());
        // merging a wider report grows the shard list
        let wide = DockShardReport {
            shards: 4,
            per_shard: vec![DockShard::default(); 4],
        };
        a.merge(&wide);
        assert_eq!(a.shards, 4);
        assert_eq!(a.per_shard.len(), 4);
        assert_eq!(a.totals().claims, 100, "zero-extend must not lose counts");
        // empty report: no handouts → fraction 0, never 0/0
        assert_eq!(DockShardReport::default().steal_fraction(), 0.0);

        // summary clause appears only for sharded runs
        let quiet = PipelineReport { mode: "pipelined".into(), wall_secs: 1.0, ..Default::default() };
        assert!(!quiet.summary().contains("dock["));
        let single = PipelineReport {
            mode: "pipelined".into(),
            wall_secs: 1.0,
            dock: DockShardReport {
                shards: 1,
                per_shard: vec![DockShard { claims: 9, stolen: 0, reclaimed: 0 }],
            },
            ..Default::default()
        };
        assert!(!single.summary().contains("dock["), "K=1 duplicates recovery: stay silent");
        let loud = PipelineReport {
            mode: "pipelined".into(),
            wall_secs: 1.0,
            dock: a,
            ..Default::default()
        };
        assert!(
            loud.summary().contains("dock[shards=4 claims=100 stolen=30 (23%)"),
            "{}",
            loud.summary()
        );
    }

    #[test]
    fn tenant_report_jain_tracks_weight_normalized_shares() {
        // perfect 3:1 split at weights 3:1 → weight-normalized shares are
        // equal → J = 1.0
        let fair = TenantReport {
            lanes: vec![
                TenantLane { tenant: 0, weight: 3, claims: 75, ..Default::default() },
                TenantLane { tenant: 1, weight: 1, claims: 25, ..Default::default() },
            ],
        };
        assert!((fair.jain_index() - 1.0).abs() < 1e-12, "{}", fair.jain_index());
        assert!((fair.claim_share(0) - 0.75).abs() < 1e-12);
        // the same split at equal weights is maximally skewed for n=2
        // short of total starvation
        let skewed = TenantReport {
            lanes: vec![
                TenantLane { tenant: 0, weight: 1, claims: 75, ..Default::default() },
                TenantLane { tenant: 1, weight: 1, claims: 25, ..Default::default() },
            ],
        };
        assert!(skewed.jain_index() < 0.9, "{}", skewed.jain_index());
        // total starvation bottoms out at 1/n
        let starved = TenantReport {
            lanes: vec![
                TenantLane { tenant: 0, weight: 1, claims: 100, ..Default::default() },
                TenantLane { tenant: 1, weight: 1, claims: 0, ..Default::default() },
            ],
        };
        assert!((starved.jain_index() - 0.5).abs() < 1e-12);
        // degenerate inputs report 1.0, never NaN
        assert_eq!(TenantReport::default().jain_index(), 1.0);
        let idle = TenantReport {
            lanes: vec![
                TenantLane { tenant: 0, weight: 1, ..Default::default() },
                TenantLane { tenant: 1, weight: 1, ..Default::default() },
            ],
        };
        assert_eq!(idle.jain_index(), 1.0, "no claims yet: nothing unfair");
    }

    #[test]
    fn tenant_report_merges_lanes_by_id() {
        let mut a = TenantReport {
            lanes: vec![TenantLane {
                tenant: 0,
                weight: 3,
                claims: 10,
                tokens: 100,
                quota_high_water: 64,
                quota_deferrals: 1,
                preemptions: 0,
            }],
        };
        let b = TenantReport {
            lanes: vec![
                TenantLane {
                    tenant: 1,
                    weight: 1,
                    claims: 5,
                    tokens: 50,
                    ..Default::default()
                },
                TenantLane {
                    tenant: 0,
                    weight: 3,
                    claims: 2,
                    tokens: 20,
                    quota_high_water: 32,
                    quota_deferrals: 0,
                    preemptions: 1,
                },
            ],
        };
        a.merge(&b);
        assert_eq!(a.lanes.len(), 2);
        assert_eq!(a.lanes[0].tenant, 0, "lanes sorted by tenant id");
        assert_eq!(a.lanes[0].claims, 12);
        assert_eq!(a.lanes[0].tokens, 120);
        assert_eq!(a.lanes[0].quota_high_water, 64, "high water is a max, not a sum");
        assert_eq!(a.lanes[0].preemptions, 1);
        assert_eq!(a.lanes[1].claims, 5);
        assert_eq!(a.total_claims(), 17);
        assert_eq!(a.total_tokens(), 170);
    }

    #[test]
    fn tenant_summary_clause_gated_on_multi_tenant() {
        let quiet = PipelineReport { mode: "pipelined".into(), wall_secs: 1.0, ..Default::default() };
        assert!(!quiet.summary().contains("tenants["));
        // a single lane (the default tenant) also stays silent
        let single = PipelineReport {
            mode: "pipelined".into(),
            wall_secs: 1.0,
            tenants: TenantReport {
                lanes: vec![TenantLane { tenant: 0, weight: 1, claims: 40, ..Default::default() }],
            },
            ..Default::default()
        };
        assert!(!single.summary().contains("tenants["), "single tenant: share is 100% by definition");
        let loud = PipelineReport {
            mode: "pipelined".into(),
            wall_secs: 1.0,
            tenants: TenantReport {
                lanes: vec![
                    TenantLane { tenant: 0, weight: 3, claims: 75, ..Default::default() },
                    TenantLane {
                        tenant: 1,
                        weight: 1,
                        claims: 25,
                        quota_deferrals: 2,
                        preemptions: 1,
                        ..Default::default()
                    },
                ],
            },
            ..Default::default()
        };
        let s = loud.summary();
        assert!(s.contains("tenants[jain=1.00 t0:w3=75% t1:w1=25% defer=2 preempt=1]"), "{s}");
    }

    #[test]
    fn csv_round_trip() {
        let mut w = CsvWriter::new(&["iter", "reward"]);
        w.row_f64(&[1.0, 0.25]);
        let s = w.to_string();
        assert!(s.starts_with("iter,reward\n"));
        assert!(s.contains("1,0.25"));
    }
}
