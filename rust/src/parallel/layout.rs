//! Rank grids for TP/PP/DP/EP/CP layouts.

use anyhow::{bail, Result};

/// A parallelization strategy, e.g. the paper's `TP4PP6EP16DP2` update
/// layout for DeepSeek-671B. World size is `tp * pp * dp * cp`; EP
/// partitions the expert dimension *within* the data-parallel replicas
/// (ep must divide dp * tp in this grid — experts are spread over the
/// non-pipeline ranks of each replica group, matching Megatron-style
/// expert parallelism).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ParallelLayout {
    pub tp: usize,
    pub pp: usize,
    pub dp: usize,
    pub ep: usize,
    pub cp: usize,
}

impl ParallelLayout {
    pub fn new(tp: usize, pp: usize, dp: usize, ep: usize) -> Self {
        Self { tp, pp, dp, ep, cp: 1 }
    }

    pub fn dense(tp: usize, pp: usize, dp: usize) -> Self {
        Self { tp, pp, dp, ep: 1, cp: 1 }
    }

    pub fn world(&self) -> usize {
        self.tp * self.pp * self.dp * self.cp
    }

    pub fn validate(&self) -> Result<()> {
        if self.tp == 0 || self.pp == 0 || self.dp == 0 || self.ep == 0 || self.cp == 0 {
            bail!("all parallel degrees must be >= 1");
        }
        let non_pp = self.tp * self.dp * self.cp;
        if self.ep > 1 && non_pp % self.ep != 0 {
            bail!(
                "ep={} must divide tp*dp*cp={} (expert ranks are drawn from the non-pipeline grid)",
                self.ep,
                non_pp
            );
        }
        Ok(())
    }

    /// Decompose a flat device id into grid coordinates. Rank order (fast
    /// → slow): tp, cp, dp, pp — TP groups are innermost so they sit on
    /// the same node's high-bandwidth links, the standard placement.
    pub fn assignment(&self, device: usize) -> Result<DeviceAssignment> {
        self.validate()?;
        if device >= self.world() {
            bail!("device {device} out of range for world {}", self.world());
        }
        let tp_rank = device % self.tp;
        let rest = device / self.tp;
        let cp_rank = rest % self.cp;
        let rest = rest / self.cp;
        let dp_rank = rest % self.dp;
        let pp_stage = rest / self.dp;
        // expert rank: position within the replica's non-pipeline grid,
        // folded onto the ep groups
        let non_pp_index = device % (self.tp * self.cp * self.dp);
        let ep_rank = if self.ep > 1 { non_pp_index % self.ep } else { 0 };
        Ok(DeviceAssignment { device, tp_rank, pp_stage, dp_rank, ep_rank, cp_rank })
    }

    pub fn describe(&self) -> String {
        let mut s = format!("TP{}PP{}", self.tp, self.pp);
        if self.ep > 1 {
            s.push_str(&format!("EP{}", self.ep));
        }
        s.push_str(&format!("DP{}", self.dp));
        if self.cp > 1 {
            s.push_str(&format!("CP{}", self.cp));
        }
        s
    }
}

/// Where one device sits in the rank grid.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DeviceAssignment {
    pub device: usize,
    pub tp_rank: usize,
    pub pp_stage: usize,
    pub dp_rank: usize,
    pub ep_rank: usize,
    pub cp_rank: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn world_size() {
        assert_eq!(ParallelLayout::new(2, 1, 2, 2).world(), 4);
        // the paper's DeepSeek update layout: TP4 PP6 EP16 DP2 → 48 ranks/stage ... 4*6*2 = 48
        assert_eq!(ParallelLayout::new(4, 6, 2, 16).world(), 48);
    }

    #[test]
    fn paper_layouts_validate() {
        // update TP4PP6EP16DP2 (ep 16 divides tp*dp*cp = 8? No — see below)
        // The paper's EP16 spans tp*dp = 8 ranks only if cp used; in our
        // grid EP must divide tp*dp*cp, so this checks the rule fires.
        assert!(ParallelLayout::new(4, 6, 2, 16).validate().is_err());
        // generation TP2PP1EP64DP6: non-pp grid = 12, 64 does not divide
        assert!(ParallelLayout::new(2, 1, 6, 64).validate().is_err());
        // adapted equivalents used in the repro (same world sizes, valid
        // ep): see DESIGN.md §Hardware-Adaptation
        assert!(ParallelLayout::new(4, 6, 2, 8).validate().is_ok());
        assert!(ParallelLayout::new(2, 1, 6, 12).validate().is_ok());
    }

    #[test]
    fn assignment_round_trip_unique() {
        let l = ParallelLayout::new(2, 2, 2, 2);
        let mut seen = std::collections::HashSet::new();
        for d in 0..l.world() {
            let a = l.assignment(d).unwrap();
            assert!(seen.insert((a.tp_rank, a.cp_rank, a.dp_rank, a.pp_stage)));
            assert!(a.tp_rank < 2 && a.pp_stage < 2 && a.dp_rank < 2);
            assert!(a.ep_rank < 2);
        }
    }

    #[test]
    fn out_of_range_device() {
        assert!(ParallelLayout::dense(2, 1, 1).assignment(2).is_err());
    }

    #[test]
    fn describe_matches_paper_notation() {
        assert_eq!(ParallelLayout::new(2, 1, 4, 4).describe(), "TP2PP1EP4DP4");
        assert_eq!(ParallelLayout::dense(8, 1, 2).describe(), "TP8PP1DP2");
    }
}
