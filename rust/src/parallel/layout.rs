//! Rank grids for TP/PP/DP/EP/CP layouts.

use anyhow::{bail, Result};

/// A parallelization strategy, e.g. the paper's `TP4PP6EP16DP2` update
/// layout for DeepSeek-671B. World size is `tp * pp * dp * cp`; EP
/// partitions the expert dimension over the **non-pipeline grid** of each
/// pipeline stage (`ep` must divide `tp * dp * cp`): EP groups tile that
/// grid tp-fastest, so each expert slice has exactly
/// `(tp * dp * cp) / ep` holders per owning stage
/// ([`Self::expert_replication`]). Two regimes fall out of the fold:
///
/// * `ep ≤ tp * cp` (and divides it): every EP group sits inside one
///   data-parallel replica, so **each DP replica holds a complete expert
///   set** — Megatron-style expert parallelism.
/// * `ep > tp * cp`: EP groups span DP replicas (a replica holds only
///   the experts of its portion of the EP groups) — the vLLM
///   data-parallel expert-group regime SNIPPETS.md's DeepSeek recipe
///   uses, and the production norm for large inference EP.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ParallelLayout {
    pub tp: usize,
    pub pp: usize,
    pub dp: usize,
    pub ep: usize,
    pub cp: usize,
}

impl ParallelLayout {
    pub fn new(tp: usize, pp: usize, dp: usize, ep: usize) -> Self {
        Self { tp, pp, dp, ep, cp: 1 }
    }

    pub fn dense(tp: usize, pp: usize, dp: usize) -> Self {
        Self { tp, pp, dp, ep: 1, cp: 1 }
    }

    pub fn world(&self) -> usize {
        self.tp * self.pp * self.dp * self.cp
    }

    pub fn validate(&self) -> Result<()> {
        if self.tp == 0 || self.pp == 0 || self.dp == 0 || self.ep == 0 || self.cp == 0 {
            bail!("all parallel degrees must be >= 1");
        }
        let non_pp = self.tp * self.dp * self.cp;
        if self.ep > 1 && non_pp % self.ep != 0 {
            bail!(
                "ep={} must divide tp*dp*cp={} (expert ranks are drawn from the non-pipeline grid)",
                self.ep,
                non_pp
            );
        }
        Ok(())
    }

    /// Decompose a flat device id into grid coordinates. Rank order (fast
    /// → slow): tp, cp, dp, pp — TP groups are innermost so they sit on
    /// the same node's high-bandwidth links, the standard placement.
    pub fn assignment(&self, device: usize) -> Result<DeviceAssignment> {
        self.validate()?;
        if device >= self.world() {
            bail!("device {device} out of range for world {}", self.world());
        }
        let tp_rank = device % self.tp;
        let rest = device / self.tp;
        let cp_rank = rest % self.cp;
        let rest = rest / self.cp;
        let dp_rank = rest % self.dp;
        let pp_stage = rest / self.dp;
        // expert rank: position within the replica's non-pipeline grid,
        // folded onto the ep groups
        let non_pp_index = device % (self.tp * self.cp * self.dp);
        let ep_rank = if self.ep > 1 { non_pp_index % self.ep } else { 0 };
        Ok(DeviceAssignment { device, tp_rank, pp_stage, dp_rank, ep_rank, cp_rank })
    }

    /// Holders of each expert slice within one pipeline stage:
    /// `(tp * dp * cp) / ep` — the expert-data-parallel degree. 1 means
    /// every expert slice lives on exactly one rank of the stage.
    pub fn expert_replication(&self) -> usize {
        let non_pp = self.tp * self.dp * self.cp;
        if self.ep > 1 { non_pp / self.ep } else { non_pp }
    }

    /// Whether every data-parallel replica holds a complete expert set
    /// (the Megatron-style regime: each EP group fits inside one
    /// replica's `tp * cp` ranks). When false, EP groups span DP
    /// replicas (vLLM DP expert groups).
    pub fn experts_replicated_per_dp(&self) -> bool {
        self.ep <= self.tp * self.cp && (self.tp * self.cp) % self.ep == 0
    }

    pub fn describe(&self) -> String {
        let mut s = format!("TP{}PP{}", self.tp, self.pp);
        if self.ep > 1 {
            s.push_str(&format!("EP{}", self.ep));
        }
        s.push_str(&format!("DP{}", self.dp));
        if self.cp > 1 {
            s.push_str(&format!("CP{}", self.cp));
        }
        s
    }
}

/// Where one device sits in the rank grid.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DeviceAssignment {
    pub device: usize,
    pub tp_rank: usize,
    pub pp_stage: usize,
    pub dp_rank: usize,
    pub ep_rank: usize,
    pub cp_rank: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn world_size() {
        assert_eq!(ParallelLayout::new(2, 1, 2, 2).world(), 4);
        // the paper's DeepSeek update layout: TP4 PP6 EP16 DP2 → 48 ranks/stage ... 4*6*2 = 48
        assert_eq!(ParallelLayout::new(4, 6, 2, 16).world(), 48);
    }

    #[test]
    fn paper_layouts_validate() {
        // update TP4PP6EP16DP2 (ep 16 divides tp*dp*cp = 8? No — see below)
        // The paper's EP16 spans tp*dp = 8 ranks only if cp used; in our
        // grid EP must divide tp*dp*cp, so this checks the rule fires.
        assert!(ParallelLayout::new(4, 6, 2, 16).validate().is_err());
        // generation TP2PP1EP64DP6: non-pp grid = 12, 64 does not divide
        assert!(ParallelLayout::new(2, 1, 6, 64).validate().is_err());
        // adapted equivalents used in the repro (same world sizes, valid
        // ep): see DESIGN.md §Hardware-Adaptation
        assert!(ParallelLayout::new(4, 6, 2, 8).validate().is_ok());
        assert!(ParallelLayout::new(2, 1, 6, 12).validate().is_ok());
    }

    #[test]
    fn assignment_round_trip_unique() {
        let l = ParallelLayout::new(2, 2, 2, 2);
        let mut seen = std::collections::HashSet::new();
        for d in 0..l.world() {
            let a = l.assignment(d).unwrap();
            assert!(seen.insert((a.tp_rank, a.cp_rank, a.dp_rank, a.pp_stage)));
            assert!(a.tp_rank < 2 && a.pp_stage < 2 && a.dp_rank < 2);
            assert!(a.ep_rank < 2);
        }
    }

    #[test]
    fn ep_fold_regimes() {
        // Megatron regime: ep divides tp*cp, EP groups stay inside one DP
        // replica, so every replica sees the full ep-rank range
        let l = ParallelLayout::new(2, 1, 2, 2);
        assert!(l.experts_replicated_per_dp());
        assert_eq!(l.expert_replication(), 2);
        for dp in 0..2 {
            let ranks: std::collections::HashSet<usize> = (0..l.world())
                .map(|d| l.assignment(d).unwrap())
                .filter(|a| a.dp_rank == dp)
                .map(|a| a.ep_rank)
                .collect();
            assert_eq!(ranks.len(), 2, "dp replica {dp} must span all ep ranks");
        }
        // vLLM DP-expert-group regime: ep spans DP replicas — each
        // replica sees only its portion of the ep-rank range
        let l = ParallelLayout::new(2, 1, 2, 4);
        assert!(!l.experts_replicated_per_dp());
        assert_eq!(l.expert_replication(), 1);
        let replica0: std::collections::HashSet<usize> = (0..l.world())
            .map(|d| l.assignment(d).unwrap())
            .filter(|a| a.dp_rank == 0)
            .map(|a| a.ep_rank)
            .collect();
        assert_eq!(replica0, [0usize, 1].into_iter().collect());
        // the paper's adapted DeepSeek layouts sit in each regime
        assert!(ParallelLayout::new(4, 6, 2, 8).expert_replication() == 1);
        assert!(!ParallelLayout::new(4, 6, 2, 8).experts_replicated_per_dp());
        assert!(ParallelLayout::new(2, 1, 6, 12).expert_replication() == 1);
    }

    #[test]
    fn out_of_range_device() {
        assert!(ParallelLayout::dense(2, 1, 1).assignment(2).is_err());
    }

    #[test]
    fn describe_matches_paper_notation() {
        assert_eq!(ParallelLayout::new(2, 1, 4, 4).describe(), "TP2PP1EP4DP4");
        assert_eq!(ParallelLayout::dense(8, 1, 2).describe(), "TP8PP1DP2");
    }
}
