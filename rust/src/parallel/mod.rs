//! Parallelization layouts (TP/PP/DP/EP/CP) and weight shard placement.
//!
//! The resharding flow (paper Fig. 3/5) moves actor weights between an
//! *update* layout and a *generation* layout over the same device pool.
//! This module defines the layouts, the rank grid, and which slice of
//! which weight lives on which device — the substrate both the naive and
//! allgather–swap resharding implementations operate on.

mod layout;
mod weights;

pub use layout::{DeviceAssignment, ParallelLayout};
pub use weights::{shard_range, ModelWeights, WeightKind, WeightSpec};
