//! Weight inventory + shard placement: which slice of each weight a
//! device holds under a given [`ParallelLayout`].

use anyhow::{bail, Result};

use super::layout::ParallelLayout;

/// How a weight is partitioned across the grid.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WeightKind {
    /// replicated within a pipeline stage (norms, embeddings here): the
    /// paper's "common weights C"
    Common,
    /// split 1/TP per tensor-parallel rank (attention/ffn matmuls): "T_i"
    TpSharded,
    /// one expert tensor, placed on the EP rank(s) owning it: "E_j".
    /// When `ep ≤ num_experts` each rank owns whole experts; when
    /// `ep > num_experts` each expert is sliced across `ep / num_experts`
    /// consecutive EP ranks (expert-TP). Either way each element of the
    /// expert has exactly [`ParallelLayout::expert_replication`] holders
    /// on its owning pipeline stage.
    Expert { expert: usize, num_experts: usize },
}

/// One logical weight tensor (payload optional: tests carry real data,
/// paper-scale accounting runs carry only sizes).
#[derive(Debug, Clone)]
pub struct WeightSpec {
    pub name: String,
    pub numel: usize,
    pub kind: WeightKind,
    /// which pipeline stage owns it (layer → stage mapping)
    pub pp_stage_of: fn(layer: usize, pp: usize, n_layers: usize) -> usize,
    pub layer: usize,
    pub data: Option<Vec<f32>>,
}

fn default_stage(layer: usize, pp: usize, n_layers: usize) -> usize {
    if pp <= 1 {
        0
    } else {
        (layer * pp / n_layers.max(1)).min(pp - 1)
    }
}

impl WeightSpec {
    pub fn new(name: impl Into<String>, layer: usize, numel: usize, kind: WeightKind) -> Self {
        Self { name: name.into(), numel, kind, pp_stage_of: default_stage, layer, data: None }
    }

    pub fn with_data(mut self, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), self.numel);
        self.data = Some(data);
        self
    }

    pub fn bytes(&self) -> u64 {
        (self.numel * 4) as u64
    }
}

/// Element range `[start, end)` of shard `rank` of `deg` over a weight of
/// `numel` elements (contiguous equal split; numel must divide evenly,
/// which model dims guarantee).
pub fn shard_range(numel: usize, rank: usize, deg: usize) -> (usize, usize) {
    let per = numel / deg;
    (rank * per, (rank + 1) * per)
}

/// The full weight inventory of a model under resharding.
#[derive(Debug, Clone)]
pub struct ModelWeights {
    pub n_layers: usize,
    pub weights: Vec<WeightSpec>,
}

impl ModelWeights {
    pub fn new(n_layers: usize, weights: Vec<WeightSpec>) -> Self {
        Self { n_layers, weights }
    }

    /// Synthetic inventory shaped like a dense transformer: per layer a
    /// common norm, TP-sharded attention + FFN blocks.
    pub fn dense_like(n_layers: usize, d_model: usize, d_ff: usize) -> Self {
        let mut weights = Vec::new();
        weights.push(WeightSpec::new("embed", 0, d_model * 64, WeightKind::Common));
        for l in 0..n_layers {
            weights.push(WeightSpec::new(format!("l{l}.norms"), l, 2 * d_model, WeightKind::Common));
            weights.push(WeightSpec::new(
                format!("l{l}.attn"),
                l,
                4 * d_model * d_model,
                WeightKind::TpSharded,
            ));
            weights.push(WeightSpec::new(
                format!("l{l}.ffn"),
                l,
                3 * d_model * d_ff,
                WeightKind::TpSharded,
            ));
        }
        Self::new(n_layers, weights)
    }

    /// Synthetic MoE inventory: adds per-layer experts.
    pub fn moe_like(
        n_layers: usize,
        d_model: usize,
        d_ff: usize,
        num_experts: usize,
    ) -> Self {
        let mut base = Self::dense_like(n_layers, d_model, d_ff);
        // replace dense ffn with router + experts
        base.weights.retain(|w| !w.name.ends_with(".ffn"));
        for l in 0..n_layers {
            base.weights.push(WeightSpec::new(
                format!("l{l}.router"),
                l,
                d_model * num_experts,
                WeightKind::Common,
            ));
            for e in 0..num_experts {
                base.weights.push(WeightSpec::new(
                    format!("l{l}.expert{e}"),
                    l,
                    3 * d_model * d_ff,
                    WeightKind::Expert { expert: e, num_experts },
                ));
            }
        }
        base
    }

    /// Attach deterministic data to every weight (tests).
    pub fn with_test_data(mut self, seed: u64) -> Self {
        let mut rng = crate::util::rng::Rng::new(seed);
        for w in &mut self.weights {
            let data: Vec<f32> = (0..w.numel).map(|_| rng.f32() - 0.5).collect();
            w.data = Some(data);
        }
        self
    }

    /// Total bytes of one full copy of the weights.
    pub fn total_bytes(&self) -> u64 {
        self.weights.iter().map(|w| w.bytes()).sum()
    }

    /// Bytes of TP-sharded weights (Eq. 3's `TW`).
    pub fn tp_bytes(&self) -> u64 {
        self.weights
            .iter()
            .filter(|w| matches!(w.kind, WeightKind::TpSharded))
            .map(|w| w.bytes())
            .sum()
    }

    /// Bytes of expert weights (Eq. 3's `EW`).
    pub fn expert_bytes(&self) -> u64 {
        self.weights
            .iter()
            .filter(|w| matches!(w.kind, WeightKind::Expert { .. }))
            .map(|w| w.bytes())
            .sum()
    }

    /// Bytes of common weights.
    pub fn common_bytes(&self) -> u64 {
        self.weights
            .iter()
            .filter(|w| matches!(w.kind, WeightKind::Common))
            .map(|w| w.bytes())
            .sum()
    }

    /// Which slice (element range) of weight `w` device `dev` holds under
    /// `layout`; `None` if the device holds none of it.
    pub fn placement(
        &self,
        w: &WeightSpec,
        layout: &ParallelLayout,
        dev: usize,
    ) -> Result<Option<(usize, usize)>> {
        let a = layout.assignment(dev)?;
        let stage = (w.pp_stage_of)(w.layer, layout.pp, self.n_layers);
        if stage != a.pp_stage {
            return Ok(None);
        }
        match w.kind {
            WeightKind::Common => Ok(Some((0, w.numel))),
            WeightKind::TpSharded => {
                if w.numel % layout.tp != 0 {
                    bail!("weight {} numel {} not divisible by tp {}", w.name, w.numel, layout.tp);
                }
                Ok(Some(shard_range(w.numel, a.tp_rank, layout.tp)))
            }
            WeightKind::Expert { expert, num_experts } => {
                if num_experts % layout.ep == 0 {
                    // whole experts per EP rank (num_experts / ep each)
                    let per = num_experts / layout.ep;
                    if expert / per == a.ep_rank {
                        Ok(Some((0, w.numel)))
                    } else {
                        Ok(None)
                    }
                } else if layout.ep % num_experts == 0 {
                    // more EP ranks than experts: each expert tensor is
                    // sliced across `ep / num_experts` consecutive EP
                    // ranks (expert-TP), so asymmetric train→infer pairs
                    // like EP4 → EP8 over 4 experts produce *partial*
                    // expert slices on the gen side — the holder shapes
                    // that stress the gather's coverage logic
                    let ways = layout.ep / num_experts;
                    if w.numel % ways != 0 {
                        bail!(
                            "expert weight {} numel {} not divisible by its {}-way EP slicing",
                            w.name,
                            w.numel,
                            ways
                        );
                    }
                    let base = expert * ways;
                    if a.ep_rank >= base && a.ep_rank < base + ways {
                        Ok(Some(shard_range(w.numel, a.ep_rank - base, ways)))
                    } else {
                        Ok(None)
                    }
                } else {
                    bail!(
                        "ep {} incompatible with {} experts for {} (one must divide the other)",
                        layout.ep,
                        num_experts,
                        w.name
                    );
                }
            }
        }
    }

    /// Bytes device `dev` holds under `layout`.
    pub fn device_bytes(&self, layout: &ParallelLayout, dev: usize) -> Result<u64> {
        let mut total = 0u64;
        for w in &self.weights {
            if let Some((s, e)) = self.placement(w, layout, dev)? {
                total += ((e - s) * 4) as u64;
            }
        }
        Ok(total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_ranges_partition() {
        let (a0, a1) = shard_range(100, 0, 4);
        let (b0, b1) = shard_range(100, 3, 4);
        assert_eq!((a0, a1), (0, 25));
        assert_eq!((b0, b1), (75, 100));
    }

    #[test]
    fn dense_placement_covers_everything_once_per_dp() {
        let m = ModelWeights::dense_like(4, 64, 128);
        let layout = ParallelLayout::dense(2, 1, 2);
        // each weight: union of slices over tp ranks of one dp replica == full
        for w in &m.weights {
            let mut covered = vec![false; w.numel];
            for dev in 0..layout.world() {
                let a = layout.assignment(dev).unwrap();
                if a.dp_rank != 0 {
                    continue;
                }
                if let Some((s, e)) = m.placement(w, &layout, dev).unwrap() {
                    match w.kind {
                        WeightKind::TpSharded => {
                            for c in &mut covered[s..e] {
                                *c = true;
                            }
                        }
                        _ => covered.iter_mut().for_each(|c| *c = true),
                    }
                }
            }
            assert!(covered.iter().all(|&c| c), "weight {} not fully covered", w.name);
        }
    }

    /// Elementwise holder count of every expert equals the layout's
    /// expert replication degree `(tp*dp*cp)/ep`, across whole-expert,
    /// ep-spans-DP, and fractional (expert-TP) placements.
    #[test]
    fn expert_coverage_matches_replication_degree() {
        let m = ModelWeights::moe_like(2, 32, 64, 4);
        for layout in [
            ParallelLayout::new(2, 1, 2, 2), // Megatron regime: ep | tp*cp
            ParallelLayout::new(2, 1, 2, 4), // ep spans DP replicas
            ParallelLayout::new(2, 1, 4, 8), // fractional: 8 ranks, 4 experts
            ParallelLayout::new(1, 2, 4, 2), // with pipeline stages
        ] {
            layout.validate().unwrap();
            let rep = layout.expert_replication();
            for w in m.weights.iter().filter(|w| matches!(w.kind, WeightKind::Expert { .. })) {
                let mut count = vec![0usize; w.numel];
                for d in 0..layout.world() {
                    if let Some((s, e)) = m.placement(w, &layout, d).unwrap() {
                        for c in &mut count[s..e] {
                            *c += 1;
                        }
                    }
                }
                assert!(
                    count.iter().all(|&c| c == rep),
                    "{}: expert {} coverage != replication {rep}",
                    layout.describe(),
                    w.name
                );
            }
        }
    }

    /// Megatron regime (`ep | tp*cp`): every DP replica holds the full
    /// expert set, one holder per expert per replica.
    #[test]
    fn experts_replicated_per_dp_group_when_ep_fits_replica() {
        let m = ModelWeights::moe_like(2, 32, 64, 4);
        let layout = ParallelLayout::new(2, 1, 2, 2);
        assert!(layout.experts_replicated_per_dp());
        for w in m.weights.iter().filter(|w| matches!(w.kind, WeightKind::Expert { .. })) {
            for dp in 0..layout.dp {
                let holders: Vec<usize> = (0..layout.world())
                    .filter(|&d| layout.assignment(d).unwrap().dp_rank == dp)
                    .filter(|&d| m.placement(w, &layout, d).unwrap().is_some())
                    .collect();
                assert_eq!(
                    holders.len(),
                    1,
                    "expert {} must have one holder inside dp replica {dp}",
                    w.name
                );
            }
        }
    }

    /// vLLM DP-expert-group regime (`ep > tp*cp`): EP spans DP replicas,
    /// so each expert lives on exactly `(tp*dp*cp)/ep` ranks of the
    /// whole stage and a single replica holds only its share.
    #[test]
    fn ep_spanning_dp_places_each_expert_once_in_the_world() {
        let m = ModelWeights::moe_like(2, 32, 64, 4);
        let layout = ParallelLayout::new(2, 1, 2, 4);
        assert!(!layout.experts_replicated_per_dp());
        assert_eq!(layout.expert_replication(), 1);
        for w in m.weights.iter().filter(|w| matches!(w.kind, WeightKind::Expert { .. })) {
            let holders: Vec<usize> = (0..layout.world())
                .filter(|&d| m.placement(w, &layout, d).unwrap().is_some())
                .collect();
            assert_eq!(holders.len(), 1, "expert {} holders {holders:?}", w.name);
        }
    }

    /// Fractional (expert-TP) placement: ep > num_experts slices each
    /// expert across `ep/num_experts` consecutive EP ranks, and the
    /// slices tile the tensor exactly.
    #[test]
    fn fractional_expert_slices_tile_the_tensor() {
        let m = ModelWeights::moe_like(1, 32, 64, 4);
        let layout = ParallelLayout::new(2, 1, 4, 8); // ways = 2
        for w in m.weights.iter().filter(|w| matches!(w.kind, WeightKind::Expert { .. })) {
            let mut ranges: Vec<(usize, usize)> = (0..layout.world())
                .filter_map(|d| m.placement(w, &layout, d).unwrap())
                .collect();
            ranges.sort();
            ranges.dedup();
            assert_eq!(ranges.len(), 2, "expert {} must split 2 ways", w.name);
            assert_eq!(ranges[0], (0, w.numel / 2));
            assert_eq!(ranges[1], (w.numel / 2, w.numel));
        }
    }

    #[test]
    fn device_bytes_match_eq3_inputs() {
        let m = ModelWeights::dense_like(2, 64, 128);
        let layout = ParallelLayout::dense(2, 1, 1);
        let per_dev = m.device_bytes(&layout, 0).unwrap();
        assert_eq!(per_dev, m.common_bytes() + m.tp_bytes() / 2);
    }

    #[test]
    fn pp_splits_layers() {
        let m = ModelWeights::dense_like(4, 32, 64);
        let layout = ParallelLayout::dense(1, 2, 1);
        let d0 = m.device_bytes(&layout, 0).unwrap();
        let d1 = m.device_bytes(&layout, 1).unwrap();
        assert!(d0 > 0 && d1 > 0);
        // embed (layer 0) is on stage 0 only
        assert!(d0 > d1);
        assert_eq!(d0 + d1, m.total_bytes());
    }
}
