//! The resharding engine: executes naive / allgather–swap reshards with
//! real payload movement over tracked memory pools.
//!
//! Faithful to practice, each device's update-layout weights live in ONE
//! contiguous buffer ("update.block", as Megatron-style trainers allocate
//! them) — which is exactly why the naive flow cannot free the lingering
//! TP shard: it shares a buffer with the still-needed common weights
//! (paper Fig. 3). The allgather–swap flow escapes by moving the whole
//! block to host memory.

use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::sync::Arc;

use super::planner::{eq3_redundant_bytes, ReshardPlan, ReshardReport};
use crate::memory::{BufferId, MemoryPool};
use crate::parallel::{ModelWeights, ParallelLayout, WeightKind};
use crate::runtime::Tensor;
use crate::transfer_dock::{LinkClass, NetworkModel};
use crate::weights::{WeightBus, WeightVersion};

/// Where a device's update-layout weight block currently resides.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardLocation {
    Device,
    Host,
}

#[derive(Debug)]
struct UpdateBlock {
    buffer: BufferId,
    bytes: u64,
    location: ShardLocation,
    /// per-weight slice data (tests attach payloads; accounting runs don't)
    slices: HashMap<String, (usize, usize, Option<Vec<f32>>)>,
}

pub struct Resharder {
    pub weights: ModelWeights,
    pub update: ParallelLayout,
    pub gen: ParallelLayout,
    pub device_pools: Vec<Arc<MemoryPool>>,
    pub host_pools: Vec<Arc<MemoryPool>>,
    pub devices_per_node: usize,
    pub net: NetworkModel,
    update_blocks: Vec<UpdateBlock>,
    /// generation-layout shards: (device, weight) → data
    gen_buffers: HashMap<usize, Vec<BufferId>>,
    gen_data: HashMap<(usize, String), Vec<f32>>,
}

impl Resharder {
    pub fn new(
        weights: ModelWeights,
        update: ParallelLayout,
        gen: ParallelLayout,
        device_capacity: u64,
        host_capacity: u64,
        devices_per_node: usize,
        net: NetworkModel,
    ) -> Result<Self> {
        update.validate()?;
        gen.validate()?;
        anyhow::ensure!(update.world() == gen.world(), "layouts must share the device pool");
        let world = update.world();
        let n_nodes = world.div_ceil(devices_per_node);
        let device_pools: Vec<_> = (0..world)
            .map(|d| Arc::new(MemoryPool::new(format!("npu{d}"), device_capacity)))
            .collect();
        let host_pools: Vec<_> = (0..n_nodes)
            .map(|n| Arc::new(MemoryPool::new(format!("host{n}"), host_capacity)))
            .collect();

        // allocate each device's contiguous update block and fill slices
        let mut update_blocks = Vec::with_capacity(world);
        for dev in 0..world {
            let mut slices = HashMap::new();
            let mut bytes = 0u64;
            for w in &weights.weights {
                if let Some((s, e)) = weights.placement(w, &update, dev)? {
                    let data = w.data.as_ref().map(|d| d[s..e].to_vec());
                    slices.insert(w.name.clone(), (s, e, data));
                    bytes += ((e - s) * 4) as u64;
                }
            }
            let buffer = device_pools[dev]
                .alloc("update.block", bytes)
                .with_context(|| format!("device {dev} update block"))?;
            update_blocks.push(UpdateBlock {
                buffer,
                bytes,
                location: ShardLocation::Device,
                slices,
            });
        }
        Ok(Self {
            weights,
            update,
            gen,
            device_pools,
            host_pools,
            devices_per_node,
            net,
            update_blocks,
            gen_buffers: HashMap::new(),
            gen_data: HashMap::new(),
        })
    }

    fn node_of(&self, dev: usize) -> usize {
        dev / self.devices_per_node
    }

    /// Every reshard starts from update-resident blocks; a parked block
    /// means the caller skipped `swap_back_h2d` — resharding on top of it
    /// would free a stale buffer and double-park host swap space.
    fn ensure_update_resident(&self) -> Result<()> {
        for (d, blk) in self.update_blocks.iter().enumerate() {
            anyhow::ensure!(
                blk.location == ShardLocation::Device,
                "device {d}: update block is parked on host — call swap_back_h2d() before \
                 resharding again"
            );
        }
        Ok(())
    }

    /// Free every generation-layout buffer left over from a previous
    /// reshard and drop the shard payloads. Both reshard flows call this
    /// eagerly on entry: the naive flow's gathered buffers used to linger
    /// indefinitely ("for cleanup between runs" that never came), so
    /// alternating naive / allgather–swap experiments in one process
    /// leaked device pool bytes and corrupted peak/timeline accounting.
    pub fn release_generation_buffers(&mut self) -> Result<()> {
        for (dev, bufs) in std::mem::take(&mut self.gen_buffers) {
            for b in bufs {
                self.device_pools[dev].free(b)?;
            }
        }
        self.gen_data.clear();
        Ok(())
    }

    /// Entry protocol shared by both reshard flows: blocks must be
    /// device-resident, stale generation buffers are freed eagerly, and
    /// peak watermarks rebase so each report's peak covers *this*
    /// reshard (timelines are kept — they are the Fig. 10 replay).
    fn begin_reshard(&mut self) -> Result<()> {
        self.ensure_update_resident()?;
        self.release_generation_buffers()?;
        for p in self.device_pools.iter().chain(self.host_pools.iter()) {
            p.reset_peak();
        }
        Ok(())
    }

    /// Gather the full payload of weight `w` from update-layout shards,
    /// as seen by `dest` device. Returns (data?, bytes_received_remote,
    /// bytes_received_local).
    fn gather_full(&self, w_name: &str, dest: usize) -> Result<(Option<Vec<f32>>, u64, u64)> {
        let w = self
            .weights
            .weights
            .iter()
            .find(|w| w.name == w_name)
            .ok_or_else(|| anyhow!("unknown weight {w_name}"))?;
        let mut data = w.data.as_ref().map(|_| vec![0f32; w.numel]);
        let mut remote = 0u64;
        let mut local = 0u64;
        // group holders by the exact slice they hold, pick the cheapest
        // holder per slice (dest itself, then same node, then remote)
        let mut slices: std::collections::BTreeMap<(usize, usize), usize> =
            std::collections::BTreeMap::new();
        let rank = |d: usize| {
            if d == dest {
                0
            } else if self.node_of(d) == self.node_of(dest) {
                1
            } else {
                2
            }
        };
        for d in 0..self.update.world() {
            if let Some((s, e, _)) = self.update_blocks[d].slices.get(w_name) {
                slices
                    .entry((*s, *e))
                    .and_modify(|best| {
                        if rank(d) < rank(*best) {
                            *best = d;
                        }
                    })
                    .or_insert(d);
            }
        }
        // Decide the gather shape up front. Equal-split shards make the
        // distinct partial ranges disjoint-or-identical, so the partial
        // set tiles the weight iff its lengths sum to numel. Use the
        // partials when they tile (the allgather pattern — this is what
        // asymmetric-EP holder sets look like); only fall back to the
        // full copy when the partials do NOT complete coverage. A
        // dest-resident full copy always wins: it moves zero bytes.
        // (The old logic skipped the full copy whenever *any* partial
        // coverage existed, so a full copy + non-tiling partials errored
        // "not fully covered" despite a full copy being available.)
        let full = (0usize, w.numel);
        let full_holder = slices.get(&full).copied();
        let partial_cover: usize =
            slices.keys().filter(|&&k| k != full).map(|&(s, e)| e - s).sum();
        let use_full = match full_holder {
            Some(h) => rank(h) == 0 || partial_cover < w.numel,
            None => false,
        };
        let mut covered = 0usize;
        for (&(s, e), &holder) in &slices {
            if ((s, e) == full) != use_full {
                continue;
            }
            covered += e - s;
            if let (Some(out), Some((_, _, Some(src)))) =
                (data.as_mut(), self.update_blocks[holder].slices.get(w_name))
            {
                out[s..e].copy_from_slice(src);
            }
            let b = ((e - s) * 4) as u64;
            match rank(holder) {
                0 => {}
                1 => local += b,
                _ => remote += b,
            }
            if (s, e) == full {
                break;
            }
        }
        anyhow::ensure!(
            covered >= w.numel,
            "weight {w_name} not fully covered by update shards"
        );
        Ok((data, remote, local))
    }

    /// Names of weights device `dev` needs slices of for generation.
    fn gen_needs(&self, dev: usize) -> Result<Vec<(String, usize, usize)>> {
        let mut out = Vec::new();
        for w in &self.weights.weights {
            if let Some((s, e)) = self.weights.placement(w, &self.gen, dev)? {
                out.push((w.name.clone(), s, e));
            }
        }
        Ok(out)
    }

    /// The paper's allgather–swap reshard (Fig. 5). Returns the report;
    /// generation shards become available via [`Self::gen_shard`].
    pub fn reshard_allgather_swap(&mut self) -> Result<ReshardReport> {
        self.begin_reshard()?;
        let world = self.update.world();
        let mut t_ag_max = 0f64;
        let mut t_sel_max = 0f64;
        let mut t_d2h_max = 0f64;
        let mut expert_moved = 0u64;

        for dev in 0..world {
            let needs = self.gen_needs(dev)?;
            // steps 1+2 proceed weight-by-weight, as real resharders do:
            // the temp buffer holds ONE allgathered tensor at a time, so
            // its peak is the largest single weight, not the model
            let mut remote = 0u64;
            let mut local = 0u64;
            let mut sel_bytes = 0u64;
            let mut bufs = Vec::new();
            for (name, s, e) in &needs {
                let w = self.weights.weights.iter().find(|w| &w.name == name).unwrap();
                let temp = self.device_pools[dev].alloc("temp.allgather", w.bytes())?;
                let (data, r, l) = self.gather_full(name, dev)?;
                remote += r;
                local += l;
                if matches!(w.kind, WeightKind::Expert { .. }) {
                    expert_moved += r + l;
                }
                let bytes = ((*e - *s) * 4) as u64;
                sel_bytes += bytes;
                let b = self.device_pools[dev].alloc(format!("gen.{name}"), bytes)?;
                bufs.push(b);
                if let Some(full) = data {
                    self.gen_data.insert((dev, name.clone()), full[*s..*e].to_vec());
                }
                self.device_pools[dev].free(temp)?;
            }
            self.gen_buffers.insert(dev, bufs);
            t_ag_max = t_ag_max.max(
                self.net.transfer_secs(LinkClass::InterNode, remote)
                    + self.net.transfer_secs(LinkClass::Local, local),
            );
            t_sel_max = t_sel_max.max(self.net.transfer_secs(LinkClass::Local, sel_bytes));

            // 3. swap the update block D2H
            let blk = &mut self.update_blocks[dev];
            let node = dev / self.devices_per_node;
            self.host_pools[node].alloc(format!("swap.dev{dev}"), blk.bytes)?;
            self.device_pools[dev].free(blk.buffer)?;
            blk.location = ShardLocation::Host;
            t_d2h_max =
                t_d2h_max.max(self.net.transfer_secs(LinkClass::HostDevice, blk.bytes));
        }

        let peak = self.device_pools.iter().map(|p| p.peak_bytes()).max().unwrap_or(0);
        let post = self.device_pools.iter().map(|p| p.live_bytes()).max().unwrap_or(0);
        let host: u64 = self.host_pools.iter().map(|p| p.live_bytes()).sum();
        let naive_r = eq3_redundant_bytes(&self.weights, &self.update, &self.gen);
        Ok(ReshardReport {
            technique: "allgather_swap".into(),
            redundant_bytes: 0,
            released_bytes: naive_r,
            peak_device_bytes: peak,
            post_device_bytes: post,
            host_bytes: host,
            t_allgather: t_ag_max,
            t_select: t_sel_max,
            t_d2h: t_d2h_max,
            t_h2d: 0.0,
            t_total: t_ag_max + t_sel_max + t_d2h_max,
            bus_published_bytes: 0,
            bus_version_bytes: 0,
            expert_bytes_moved: expert_moved,
            expert_redundant_bytes: 0,
        })
    }

    /// The naive reshard (Fig. 3): gather into fresh buffers, keep the
    /// update block resident, reuse resident experts in place.
    pub fn reshard_naive(&mut self) -> Result<ReshardReport> {
        self.begin_reshard()?;
        let world = self.update.world();
        let mut t_ag_max = 0f64;
        let mut expert_moved = 0u64;

        for dev in 0..world {
            let needs = self.gen_needs(dev)?;
            let mut bufs = Vec::new();
            let mut remote = 0u64;
            let mut local = 0u64;
            for (name, s, e) in &needs {
                let w = self.weights.weights.iter().find(|w| &w.name == name).unwrap();
                let resident =
                    self.update_blocks[dev].slices.get(name).map(|(rs, re, _)| (*rs, *re));
                let fully_resident = matches!(resident, Some((rs, re)) if rs <= *s && re >= *e);
                if fully_resident && !matches!(w.kind, WeightKind::TpSharded) {
                    // reuse in place (e.g. expert E4, common C in Fig. 3)
                    if let Some((rs, _, Some(d))) = self.update_blocks[dev].slices.get(name) {
                        self.gen_data
                            .insert((dev, name.clone()), d[*s - rs..*e - rs].to_vec());
                    }
                    continue;
                }
                // gather the full weight into a fresh buffer (the original
                // block cannot be freed — shared with common weights)
                let (data, r, l) = self.gather_full(name, dev)?;
                remote += r;
                local += l;
                if matches!(w.kind, WeightKind::Expert { .. }) {
                    expert_moved += r + l;
                }
                let bytes = ((*e - *s) * 4) as u64;
                let b = self.device_pools[dev].alloc(format!("gen.{name}"), bytes)?;
                bufs.push(b);
                if let Some(full) = data {
                    self.gen_data.insert((dev, name.clone()), full[*s..*e].to_vec());
                }
            }
            self.gen_buffers.insert(dev, bufs);
            t_ag_max = t_ag_max.max(
                self.net.transfer_secs(LinkClass::InterNode, remote)
                    + self.net.transfer_secs(LinkClass::Local, local),
            );
        }

        // redundancy: whatever is live but not needed by generation
        let mut redundant = 0u64;
        for dev in 0..world {
            let live = self.device_pools[dev].live_bytes();
            let needed = self.weights.device_bytes(&self.gen, dev)?;
            redundant += live.saturating_sub(needed);
        }
        // the expert component of that redundancy, measured directly:
        // update-resident expert slices generation does not serve (the
        // stale experts of Fig. 3) — Eq. 3's `EW/GEP` term as an actual
        // byte count over the inventory, not a planner constant
        let expert_redundant = self.expert_redundant_bytes()?;
        let peak = self.device_pools.iter().map(|p| p.peak_bytes()).max().unwrap_or(0);
        let post = self.device_pools.iter().map(|p| p.live_bytes()).max().unwrap_or(0);
        Ok(ReshardReport {
            technique: "naive".into(),
            redundant_bytes: redundant,
            released_bytes: 0,
            peak_device_bytes: peak,
            post_device_bytes: post,
            host_bytes: 0,
            t_allgather: t_ag_max,
            t_select: 0.0,
            t_d2h: 0.0,
            t_h2d: 0.0,
            t_total: t_ag_max,
            bus_published_bytes: 0,
            bus_version_bytes: 0,
            expert_bytes_moved: expert_moved,
            expert_redundant_bytes: expert_redundant,
        })
    }

    /// Bytes of update-resident expert slices that generation does not
    /// need, summed over devices — the measured counterpart of Eq. 3's
    /// expert term (stale experts the naive flow leaves on-device).
    fn expert_redundant_bytes(&self) -> Result<u64> {
        let mut stale = 0u64;
        for dev in 0..self.update.world() {
            for w in &self.weights.weights {
                if !matches!(w.kind, WeightKind::Expert { .. }) {
                    continue;
                }
                let Some((rs, re, _)) = self.update_blocks[dev].slices.get(&w.name) else {
                    continue;
                };
                let overlap = match self.weights.placement(w, &self.gen, dev)? {
                    Some((gs, ge)) => ge.min(*re).saturating_sub(gs.max(*rs)),
                    None => 0,
                };
                stale += (((re - rs) - overlap) * 4) as u64;
            }
        }
        Ok(stale)
    }

    /// H2D swap-back before the next update stage (overlappable with
    /// inference — the caller decides where to account the time).
    pub fn swap_back_h2d(&mut self) -> Result<f64> {
        let mut t_max = 0f64;
        for dev in 0..self.update.world() {
            let node = dev / self.devices_per_node;
            let blk = &mut self.update_blocks[dev];
            if blk.location != ShardLocation::Host {
                continue;
            }
            // free the generation buffers first (generation is done)
            if let Some(bufs) = self.gen_buffers.remove(&dev) {
                for b in bufs {
                    self.device_pools[dev].free(b)?;
                }
            }
            let buffer = self.device_pools[dev].alloc("update.block", blk.bytes)?;
            // find + free the host-side parked buffer
            let host = &self.host_pools[node];
            // host buffers are labelled swap.dev{dev}; the pool API frees
            // by id, so track it via live-bytes bookkeeping: realloc path
            // keeps a 1:1 label so we can free the matching bytes
            host_free_labeled(host, &format!("swap.dev{dev}"))?;
            blk.buffer = buffer;
            blk.location = ShardLocation::Device;
            t_max = t_max.max(self.net.transfer_secs(LinkClass::HostDevice, blk.bytes));
        }
        Ok(t_max)
    }

    // ------------------------------------------------- weight-bus publish
    //
    // The resharding flow publishes straight into the versioned
    // `WeightBus`: one bus version = the full generation-layout sharding
    // of the model, one tensor per (device, weight) slice in a stable
    // order. No full-model copy is ever materialized — the slices the
    // gather loop already produced are handed over as-is, and the bus's
    // shard-level dedup keeps only the slices whose content changed since
    // the previous reshard (after a train step that touched a subset of
    // weights, retention grows by exactly those weights' slices).

    /// Stable (device, weight) enumeration of the generation layout's
    /// slices — the bus tensor universe for reshard-published versions.
    pub fn gen_slice_names(&self) -> Result<Vec<(usize, String)>> {
        let mut out = Vec::new();
        for dev in 0..self.gen.world() {
            for (name, _, _) in self.gen_needs(dev)? {
                out.push((dev, name));
            }
        }
        Ok(out)
    }

    /// Current generation-layout slices as tensors, in
    /// [`Self::gen_slice_names`] order. Requires a completed reshard with
    /// real payloads (`with_test_data`); accounting-only runs have no
    /// payload to publish.
    fn gen_slice_tensors(&self) -> Result<Vec<Tensor>> {
        let names = self.gen_slice_names()?;
        let mut out = Vec::with_capacity(names.len());
        for (dev, name) in names {
            let data = self.gen_data.get(&(dev, name.clone())).ok_or_else(|| {
                anyhow!(
                    "no generation shard payload for ({dev}, {name}) — publish requires a \
                     completed reshard over weights with real data (with_test_data)"
                )
            })?;
            out.push(Tensor::f32(&[data.len()], data.clone())?);
        }
        Ok(out)
    }

    /// Build a weight bus whose version 1 is the *current* generation
    /// layout (call after the first reshard), charging retention to
    /// `pool` when given. Later reshards publish into it via
    /// [`Self::publish_gen_layout`] / [`Self::reshard_allgather_swap_into`].
    pub fn seed_weight_bus(
        &self,
        capacity: usize,
        pool: Option<Arc<MemoryPool>>,
    ) -> Result<WeightBus> {
        let slices = self.gen_slice_tensors()?;
        Ok(match pool {
            Some(p) => WeightBus::new_with_pool(slices, capacity, p)?,
            None => WeightBus::new(slices, capacity),
        })
    }

    /// Publish the current generation-layout slices as one bus version
    /// via [`WeightBus::publish_delta`]: slices are compared against the
    /// bus head *in place* (a `&[f32]` compare, no allocation) and only
    /// the changed ones are materialized as tensors — so a reshard after
    /// a train step that touched a subset of weights hands over exactly
    /// those weights' slices. Returns the minted version and the bytes
    /// `publish_delta` actually minted (the retention delta, computed
    /// under the bus lock — not the full version size).
    pub fn publish_gen_layout(&self, bus: &WeightBus) -> Result<(WeightVersion, u64)> {
        let names = self.gen_slice_names()?;
        let (_, head) = bus.head();
        anyhow::ensure!(
            head.len() == names.len(),
            "bus universe ({} tensors) does not match this resharder's generation layout ({})",
            head.len(),
            names.len()
        );
        let mut changed = Vec::new();
        for (i, (dev, name)) in names.iter().enumerate() {
            let data = self.gen_data.get(&(*dev, name.clone())).ok_or_else(|| {
                anyhow!(
                    "no generation shard payload for ({dev}, {name}) — publish requires a \
                     completed reshard over weights with real data (with_test_data)"
                )
            })?;
            if head.tensor(i).as_f32()? != data.as_slice() {
                changed.push((i, Tensor::f32(&[data.len()], data.clone())?));
            }
        }
        let (version, minted) = bus.publish_delta(&changed)?;
        Ok((version, minted))
    }

    /// The allgather–swap reshard, publishing its generation layout
    /// directly into `bus` as one version — the paper's resharding flow
    /// feeding the sample flow's weight channel without an intermediate
    /// full-model snapshot. Returns the reshard report and the minted
    /// version. `bus_published_bytes` is the **delta** actually handed
    /// to `publish_delta` (what this reshard cost the bus);
    /// `bus_version_bytes` is the full reconstructed size of the minted
    /// version (what a full-copy publish would have cost).
    pub fn reshard_allgather_swap_into(
        &mut self,
        bus: &WeightBus,
    ) -> Result<(ReshardReport, WeightVersion)> {
        let mut report = self.reshard_allgather_swap()?;
        let (version, published) = self.publish_gen_layout(bus)?;
        report.bus_published_bytes = published;
        report.bus_version_bytes = bus.get(version)?.total_bytes();
        Ok((report, version))
    }

    /// Apply a uniform delta to one weight's payload (the testbed's
    /// stand-in for a train step touching that weight), keeping the
    /// update-layout slice copies coherent so the next gather sees the
    /// new content.
    pub fn perturb_weight(&mut self, name: &str, delta: f32) -> Result<()> {
        let w = self
            .weights
            .weights
            .iter_mut()
            .find(|w| w.name == name)
            .ok_or_else(|| anyhow!("unknown weight {name}"))?;
        let data = w
            .data
            .as_mut()
            .ok_or_else(|| anyhow!("weight {name} carries no payload to perturb"))?;
        for x in data.iter_mut() {
            *x += delta;
        }
        let full = data.clone();
        for blk in &mut self.update_blocks {
            if let Some((s, e, d)) = blk.slices.get_mut(name) {
                if let Some(d) = d {
                    *d = full[*s..*e].to_vec();
                }
            }
        }
        Ok(())
    }

    /// Generation-layout shard payload (tests/verification).
    pub fn gen_shard(&self, dev: usize, name: &str) -> Option<&Vec<f32>> {
        self.gen_data.get(&(dev, name.to_string()))
    }

    /// Verify every generation shard against direct sharding of the full
    /// weights (bit-exact).
    pub fn verify_gen_shards(&self) -> Result<usize> {
        let mut checked = 0;
        for dev in 0..self.gen.world() {
            for w in &self.weights.weights {
                let Some(full) = w.data.as_ref() else { continue };
                if let Some((s, e)) = self.weights.placement(w, &self.gen, dev)? {
                    let got = self
                        .gen_shard(dev, &w.name)
                        .ok_or_else(|| anyhow!("missing gen shard {} on dev {dev}", w.name))?;
                    anyhow::ensure!(
                        got == &full[s..e],
                        "gen shard {} on dev {dev} differs from direct sharding",
                        w.name
                    );
                    checked += 1;
                }
            }
        }
        Ok(checked)
    }

    pub fn where_is_update_block(&self, dev: usize) -> ShardLocation {
        self.update_blocks[dev].location
    }

    /// Free device bytes available for KV cache after resharding.
    pub fn kv_headroom(&self) -> Vec<u64> {
        self.device_pools.iter().map(|p| p.free_bytes()).collect()
    }
}

/// Free a host buffer by label (the pool tracks ids internally; this
/// helper exists because the swap-back path knows labels, not ids).
fn host_free_labeled(pool: &MemoryPool, label: &str) -> Result<()> {
    pool.free_by_label(label)
}

#[cfg(test)]
mod tests {
    use super::*;

    const GIB: u64 = 1 << 30;

    fn net() -> NetworkModel {
        NetworkModel::paper()
    }

    fn dense_resharder(utp: usize, udp: usize, gtp: usize, gdp: usize) -> Resharder {
        let m = ModelWeights::dense_like(4, 64, 128).with_test_data(1);
        Resharder::new(
            m,
            ParallelLayout::dense(utp, 1, udp),
            ParallelLayout::dense(gtp, 1, gdp),
            GIB,
            16 * GIB,
            8,
            net(),
        )
        .unwrap()
    }

    /// World-8 MoE resharder with 4 experts: EP degree 8 exercises the
    /// fractional (expert-TP) placement where each expert splits across
    /// two EP ranks — the asymmetric-EP holder shapes.
    fn moe_resharder(uep: usize, gep: usize, seed: u64) -> Resharder {
        let m = ModelWeights::moe_like(2, 32, 64, 4).with_test_data(seed);
        Resharder::new(
            m,
            ParallelLayout::new(2, 1, 4, uep),
            ParallelLayout::new(1, 1, 8, gep),
            GIB,
            64 * GIB,
            8,
            net(),
        )
        .unwrap()
    }

    #[test]
    fn gather_full_uses_full_copy_when_partials_do_not_tile() {
        // the bugfix regression: a holder set {full copy, partial slice}
        // where the partials do NOT tile the weight used to error "not
        // fully covered" — any partial coverage skipped the full copy
        let mut r = dense_resharder(2, 2, 2, 2);
        let w = r.weights.weights.iter().find(|w| w.name == "l0.attn").unwrap();
        let (name, numel) = (w.name.clone(), w.numel);
        let full: Vec<f32> = w.data.clone().unwrap();
        r.update_blocks[0]
            .slices
            .insert(name.clone(), (0, numel / 2, Some(full[..numel / 2].to_vec())));
        r.update_blocks[1].slices.insert(name.clone(), (0, numel, Some(full.clone())));
        r.update_blocks[2].slices.remove(&name);
        r.update_blocks[3].slices.remove(&name);
        let (data, remote, local) = r.gather_full(&name, 3).unwrap();
        assert_eq!(data.unwrap(), full);
        // exactly the full copy is charged — not the overlapping partial
        assert_eq!(remote + local, (numel * 4) as u64);

        // partials that DO tile still win over a non-dest full copy
        r.update_blocks[2]
            .slices
            .insert(name.clone(), (numel / 2, numel, Some(full[numel / 2..].to_vec())));
        let (data, remote, local) = r.gather_full(&name, 3).unwrap();
        assert_eq!(data.unwrap(), full);
        assert_eq!(remote + local, (numel * 4) as u64);

        // a dest-resident full copy moves zero bytes
        let (data, remote, local) = r.gather_full(&name, 1).unwrap();
        assert_eq!(data.unwrap(), full);
        assert_eq!((remote, local), (0, 0));
    }

    #[test]
    fn asymmetric_ep_allgather_swap_bit_exact() {
        // EP degree changes across the train→infer boundary in both
        // directions, including through the fractional EP8 placement
        for (uep, gep) in [(8, 4), (4, 8), (2, 8), (8, 2), (4, 1), (1, 4)] {
            let mut r = moe_resharder(uep, gep, 3);
            let rep = r.reshard_allgather_swap().unwrap();
            let n = r.verify_gen_shards().unwrap();
            assert!(n > 0, "EP{uep}->EP{gep} verified nothing");
            if uep > 1 {
                assert!(
                    rep.expert_bytes_moved > 0,
                    "EP{uep}->EP{gep} must move expert bytes over the EP groups"
                );
            } else {
                // EP1 replicates every expert on every update rank, so
                // every gather is dest-resident and free
                assert_eq!(rep.expert_bytes_moved, 0);
            }
            assert_eq!(rep.redundant_bytes, 0);
            r.swap_back_h2d().unwrap();
            // naive over the same asymmetric pair is also bit-exact and
            // accounts its stale experts separately
            let rep = r.reshard_naive().unwrap();
            r.verify_gen_shards().unwrap();
            assert!(rep.redundant_bytes >= rep.expert_redundant_bytes);
        }
    }

    #[test]
    fn naive_expert_redundancy_is_measured() {
        // Fig. 3: TP2EP2DP2 → TP1EP4DP4. Stale experts by hand: devices
        // keep (1, 2, 2, 1) non-serving experts per layer × 2 layers =
        // 12 expert-tensor instances of the 8-tensor inventory → 3·EW/2.
        let m = ModelWeights::moe_like(2, 32, 64, 4).with_test_data(2);
        let update = ParallelLayout::new(2, 1, 2, 2);
        let gen = ParallelLayout::new(1, 1, 4, 4);
        let mut r = Resharder::new(m.clone(), update, gen, GIB, 16 * GIB, 8, net()).unwrap();
        let rep = r.reshard_naive().unwrap();
        assert_eq!(rep.expert_redundant_bytes, 3 * m.expert_bytes() / 2);
        assert!(rep.expert_redundant_bytes <= rep.redundant_bytes);
        // dense inventories have no expert component
        let mut d = dense_resharder(4, 1, 2, 2);
        assert_eq!(d.reshard_naive().unwrap().expert_redundant_bytes, 0);
    }

    #[test]
    fn bus_published_bytes_is_the_delta_not_the_version() {
        let mut r = dense_resharder(4, 1, 2, 2);
        r.reshard_allgather_swap().unwrap();
        let bus = r.seed_weight_bus(4, None).unwrap();
        r.swap_back_h2d().unwrap();
        // nothing trained between reshards: the republished layout is
        // bit-identical, so the delta is zero even though the minted
        // version still reconstructs the full generation layout
        let (rep, v) = r.reshard_allgather_swap_into(&bus).unwrap();
        assert_eq!(rep.bus_published_bytes, 0, "unchanged reshard must publish no bytes");
        assert_eq!(rep.bus_version_bytes, bus.get(v).unwrap().total_bytes());
        assert!(rep.bus_version_bytes > 0);
    }

    #[test]
    fn moe_bus_publish_retains_only_touched_expert() {
        let mut r = moe_resharder(2, 8, 5);
        r.reshard_allgather_swap().unwrap();
        let pool = Arc::new(MemoryPool::unbounded("weightbus"));
        let bus = r.seed_weight_bus(4, Some(Arc::clone(&pool))).unwrap();
        let names = r.gen_slice_names().unwrap();
        r.swap_back_h2d().unwrap();
        r.perturb_weight("l0.expert2", 0.5).unwrap();
        let before = bus.retained_bytes();
        let (rep, v) = r.reshard_allgather_swap_into(&bus).unwrap();
        r.verify_gen_shards().unwrap();
        let grew = bus.retained_bytes() - before;
        let touched: u64 = names
            .iter()
            .enumerate()
            .filter(|(_, (_, n))| n == "l0.expert2")
            .map(|(i, _)| bus.get(v).unwrap().tensor(i).size_bytes() as u64)
            .sum();
        assert!(touched > 0, "the touched expert must appear in the gen universe");
        assert_eq!(grew, touched, "only the touched expert's slices may mint shards");
        assert_eq!(rep.bus_published_bytes, grew);
        assert_eq!(pool.live_bytes(), bus.retained_bytes());
    }

    #[test]
    fn allgather_swap_dense_bit_exact() {
        let mut r = dense_resharder(4, 1, 2, 2);
        let rep = r.reshard_allgather_swap().unwrap();
        assert!(r.verify_gen_shards().unwrap() > 0);
        assert_eq!(rep.redundant_bytes, 0);
        assert!(rep.host_bytes > 0, "update block must be parked on host");
        assert_eq!(r.where_is_update_block(0), ShardLocation::Host);
    }

    #[test]
    fn naive_dense_bit_exact_but_redundant() {
        let mut r = dense_resharder(4, 1, 2, 2);
        let rep = r.reshard_naive().unwrap();
        assert!(r.verify_gen_shards().unwrap() > 0);
        assert!(rep.redundant_bytes > 0, "naive must leave redundant bytes");
    }

    #[test]
    fn fig3_moe_case_redundancy_matches_eq3() {
        // Fig. 3: TP2EP2DP2 → TP1EP4DP4 on 4 devices
        let m = ModelWeights::moe_like(2, 32, 64, 4).with_test_data(2);
        let update = ParallelLayout::new(2, 1, 2, 2);
        let gen = ParallelLayout::new(1, 1, 4, 4);
        let mut r =
            Resharder::new(m.clone(), update, gen, GIB, 16 * GIB, 8, net()).unwrap();
        let rep = r.reshard_naive().unwrap();
        r.verify_gen_shards().unwrap();
        // Eq. (3) is the paper's idealized lower bound: it counts the
        // lingering TP shard + one stale expert per device, but not the
        // extra buffers a device must gather when its generation expert
        // was not resident under the update layout (devices whose
        // update-EP group differs from their gen-EP expert). The measured
        // redundancy therefore brackets eq3 from above by up to EW/2.
        let eq3 = eq3_redundant_bytes(&m, &update, &gen);
        assert!(rep.redundant_bytes >= eq3, "measured {} < eq3 {}", rep.redundant_bytes, eq3);
        assert!(
            rep.redundant_bytes <= eq3 + m.expert_bytes() / 2,
            "measured {} too far above eq3 {}",
            rep.redundant_bytes,
            eq3
        );
    }

    #[test]
    fn swap_back_restores_update_state() {
        let mut r = dense_resharder(2, 2, 1, 4);
        r.reshard_allgather_swap().unwrap();
        let t = r.swap_back_h2d().unwrap();
        assert!(t > 0.0);
        assert_eq!(r.where_is_update_block(1), ShardLocation::Device);
        // all host swap space released
        assert_eq!(r.host_pools.iter().map(|p| p.live_bytes()).sum::<u64>(), 0);
    }

    #[test]
    fn swap_frees_more_kv_headroom_than_naive() {
        let mut a = dense_resharder(4, 1, 2, 2);
        a.reshard_allgather_swap().unwrap();
        let free_swap = a.kv_headroom()[0];
        let mut b = dense_resharder(4, 1, 2, 2);
        b.reshard_naive().unwrap();
        let free_naive = b.kv_headroom()[0];
        assert!(
            free_swap > free_naive,
            "allgather-swap must leave more KV headroom ({free_swap} vs {free_naive})"
        );
    }

    #[test]
    fn alternating_reshards_free_gen_buffers_and_return_to_baseline() {
        // the leak regression: naive-mode gathered buffers used to park in
        // a "cleanup between runs" map that nothing ever drained, so
        // alternating naive / allgather–swap runs grew device pools
        // without bound and peak accounting compounded across runs
        let mut r = dense_resharder(4, 1, 2, 2);
        let baseline: Vec<u64> =
            r.device_pools.iter().map(|p| p.live_bytes()).collect();
        let mut naive_live: Option<Vec<u64>> = None;
        for cycle in 0..3 {
            r.reshard_naive().unwrap();
            let live: Vec<u64> = r.device_pools.iter().map(|p| p.live_bytes()).collect();
            match &naive_live {
                None => naive_live = Some(live),
                Some(first) => assert_eq!(
                    &live, first,
                    "cycle {cycle}: naive residency grew — gen buffers leaked"
                ),
            }
            let rep = r.reshard_allgather_swap().unwrap();
            // peak is rebased per reshard: it cannot exceed what a single
            // swap reshard can touch (update block + temp + gen slices)
            assert!(rep.peak_device_bytes > 0);
            r.swap_back_h2d().unwrap();
            let live: Vec<u64> = r.device_pools.iter().map(|p| p.live_bytes()).collect();
            assert_eq!(live, baseline, "cycle {cycle}: live bytes did not return to baseline");
            assert_eq!(
                r.host_pools.iter().map(|p| p.live_bytes()).sum::<u64>(),
                0,
                "cycle {cycle}: host swap space leaked"
            );
        }
        // explicit release also restores the baseline after a naive run
        r.reshard_naive().unwrap();
        r.release_generation_buffers().unwrap();
        let live: Vec<u64> = r.device_pools.iter().map(|p| p.live_bytes()).collect();
        assert_eq!(live, baseline);
    }

    #[test]
    fn resharding_over_a_parked_block_is_rejected() {
        let mut r = dense_resharder(4, 1, 2, 2);
        r.reshard_allgather_swap().unwrap();
        let err = r.reshard_allgather_swap().unwrap_err().to_string();
        assert!(err.contains("swap_back_h2d"), "unhelpful error: {err}");
        assert!(r.reshard_naive().is_err());
        r.swap_back_h2d().unwrap();
        r.reshard_allgather_swap().unwrap();
    }

    #[test]
    fn reshard_publishes_gen_layout_into_bus_with_dedup() {
        let mut r = dense_resharder(4, 1, 2, 2);
        r.reshard_allgather_swap().unwrap();
        let pool = Arc::new(MemoryPool::unbounded("weightbus"));
        let bus = r.seed_weight_bus(4, Some(Arc::clone(&pool))).unwrap();
        let v1 = bus.head_version();
        // the seeded version is the gen layout, slice for slice
        let names = r.gen_slice_names().unwrap();
        let view = bus.get(v1).unwrap();
        assert_eq!(view.len(), names.len());
        for (i, (dev, name)) in names.iter().enumerate() {
            assert_eq!(
                view.tensor(i).as_f32().unwrap(),
                r.gen_shard(*dev, name).unwrap().as_slice(),
                "slice ({dev}, {name}) differs from the published version"
            );
        }
        assert_eq!(pool.live_bytes(), bus.retained_bytes());

        // next "iteration": one weight trains, the reshard republished —
        // only that weight's slices mint new shards
        r.swap_back_h2d().unwrap();
        r.perturb_weight("l0.attn", 0.25).unwrap();
        let before = bus.retained_bytes();
        let (rep, v2) = r.reshard_allgather_swap_into(&bus).unwrap();
        assert!(rep.bus_published_bytes > 0);
        assert_eq!(v2.as_u64(), v1.as_u64() + 1);
        let grew = bus.retained_bytes() - before;
        let attn_bytes: u64 = names
            .iter()
            .enumerate()
            .filter(|(_, (_, n))| n == "l0.attn")
            .map(|(i, _)| bus.get(v2).unwrap().tensor(i).size_bytes() as u64)
            .sum();
        assert_eq!(grew, attn_bytes, "only the perturbed weight's slices may mint shards");
        // published bytes report the delta, not the full version
        assert_eq!(rep.bus_published_bytes, grew);
        assert_eq!(rep.bus_version_bytes, bus.get(v2).unwrap().total_bytes());
        assert!(
            rep.bus_published_bytes < rep.bus_version_bytes,
            "a partial-update publish must cost less than the full version"
        );
        assert_eq!(pool.live_bytes(), bus.retained_bytes());
        // both versions reconstruct bit-identically against the payloads
        let v2_view = bus.get(v2).unwrap();
        for (i, (dev, name)) in names.iter().enumerate() {
            assert_eq!(
                v2_view.tensor(i).as_f32().unwrap(),
                r.gen_shard(*dev, name).unwrap().as_slice()
            );
        }
    }

    #[test]
    fn d2h_time_uses_host_device_bandwidth() {
        let mut r = dense_resharder(4, 1, 2, 2);
        let block0 = r.weights.device_bytes(&r.update, 0).unwrap();
        let rep = r.reshard_allgather_swap().unwrap();
        // every device swaps its whole update block at 50 GB/s; blocks are
        // equal here, so t_d2h == block_bytes / 50e9
        let expect = block0 as f64 / 50e9;
        assert!((rep.t_d2h - expect).abs() / expect < 1e-6, "{} vs {}", rep.t_d2h, expect);
    }
}
