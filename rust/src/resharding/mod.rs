//! Resharding flow: moving actor weights from the update-stage layout to
//! the generation-stage layout (paper Figs. 3 & 5).
//!
//! Two implementations over the same device-memory substrate:
//!
//! * [`naive`]: allgather TP weights into a fresh buffer while the
//!   original (common + TP-shard) buffer stays live, and keep unused
//!   experts resident — the redundant memory of Eq. (3).
//! * [`allgather_swap`]: the paper's technique — allgather into a
//!   *temporary* buffer, select/copy the generation slices, swap the
//!   update-layout weights D2H (fully releasing their device buffers),
//!   free the temp, and H2D them back (overlappable) before the next
//!   update.
//!
//! Payload movement is real (`Vec<f32>` slices are actually gathered,
//! sliced and verified bit-exact against direct sharding); *time* comes
//! from the bandwidth model; *memory* from the tracked pools (Fig. 10).
//!
//! The allgather–swap flow can also publish its generation-layout slices
//! directly into the versioned weight bus
//! ([`Resharder::reshard_allgather_swap_into`]) — one bus version per
//! reshard, shard-deduplicated against the previous one, with retention
//! charged to a tracked pool.

mod engine;
mod planner;

pub use engine::{Resharder, ShardLocation};
pub use planner::{eq3_redundant_bytes, plan_summary, ReshardPlan, ReshardReport};
