//! Resharding plans + the paper's Eq. (3) closed form.

use anyhow::Result;

use crate::parallel::{ModelWeights, ParallelLayout};

/// Eq. (3): redundant memory (bytes) of the naive resharding flow.
/// `R = GDP × (TW/UTP + EW/GEP)`.
pub fn eq3_redundant_bytes(
    weights: &ModelWeights,
    update: &ParallelLayout,
    gen: &ParallelLayout,
) -> u64 {
    let tw = weights.tp_bytes() as f64;
    let ew = weights.expert_bytes() as f64;
    let r = gen.dp as f64 * (tw / update.tp as f64 + ew / gen.ep as f64);
    r as u64
}

/// What a reshard between two layouts will move and hold.
#[derive(Debug, Clone)]
pub struct ReshardPlan {
    pub update: ParallelLayout,
    pub gen: ParallelLayout,
    /// per-device bytes resident under the update layout
    pub update_bytes_per_dev: Vec<u64>,
    /// per-device bytes resident under the generation layout
    pub gen_bytes_per_dev: Vec<u64>,
    /// per-device temp (allgather) buffer bytes
    pub temp_bytes_per_dev: Vec<u64>,
}

impl ReshardPlan {
    pub fn build(
        weights: &ModelWeights,
        update: ParallelLayout,
        gen: ParallelLayout,
    ) -> Result<Self> {
        anyhow::ensure!(
            update.world() == gen.world(),
            "reshard layouts must cover the same devices ({} vs {})",
            update.world(),
            gen.world()
        );
        let world = update.world();
        let mut update_bytes = Vec::with_capacity(world);
        let mut gen_bytes = Vec::with_capacity(world);
        let mut temp_bytes = Vec::with_capacity(world);
        for dev in 0..world {
            update_bytes.push(weights.device_bytes(&update, dev)?);
            gen_bytes.push(weights.device_bytes(&gen, dev)?);
            // temp: the allgather buffer holds one tensor at a time, so
            // the requirement is the largest weight this device gathers
            let mut t = 0u64;
            for w in &weights.weights {
                if weights.placement(w, &gen, dev)?.is_some() {
                    t = t.max(w.bytes());
                }
            }
            temp_bytes.push(t);
        }
        Ok(Self {
            update,
            gen,
            update_bytes_per_dev: update_bytes,
            gen_bytes_per_dev: gen_bytes,
            temp_bytes_per_dev: temp_bytes,
        })
    }
}

/// Outcome of a reshard run: memory + timing accounting.
#[derive(Debug, Clone, Default)]
pub struct ReshardReport {
    /// technique name ("naive" | "allgather_swap")
    pub technique: String,
    /// bytes still resident on devices that generation does not need
    pub redundant_bytes: u64,
    /// device bytes freed for the KV cache relative to naive
    pub released_bytes: u64,
    /// peak device bytes during the reshard (any single device)
    pub peak_device_bytes: u64,
    /// device bytes live after the reshard (max over devices)
    pub post_device_bytes: u64,
    /// host bytes parked by the swap
    pub host_bytes: u64,
    /// timing breakdown (seconds, from the bandwidth model)
    pub t_allgather: f64,
    pub t_select: f64,
    pub t_d2h: f64,
    pub t_h2d: f64,
    pub t_total: f64,
    /// bytes of generation-layout slices actually published (the delta
    /// handed to `publish_delta`) by `reshard_allgather_swap_into` —
    /// 0 when resharding standalone or when nothing changed since the
    /// bus head
    pub bus_published_bytes: u64,
    /// full reconstructed size of the bus version the reshard minted
    /// (what a full-copy publish would have cost); 0 standalone
    pub bus_version_bytes: u64,
    /// allgather traffic attributable to expert weights (Eq. 3's `EW`
    /// class measured on the wire; dense/common traffic is the rest)
    pub expert_bytes_moved: u64,
    /// naive flow only: update-resident expert slices generation does
    /// not serve — the measured `EW/GEP` component of `redundant_bytes`
    pub expert_redundant_bytes: u64,
}

impl ReshardReport {
    pub fn summary(&self) -> String {
        let bus = if self.bus_version_bytes == 0 && self.bus_published_bytes == 0 {
            String::new()
        } else {
            format!(
                " bus_pub={}/{}",
                crate::util::fmt_bytes(self.bus_published_bytes),
                crate::util::fmt_bytes(self.bus_version_bytes)
            )
        };
        let expert = if self.expert_bytes_moved == 0 && self.expert_redundant_bytes == 0 {
            String::new()
        } else {
            format!(
                " expert_moved={} expert_stale={}",
                crate::util::fmt_bytes(self.expert_bytes_moved),
                crate::util::fmt_bytes(self.expert_redundant_bytes)
            )
        };
        format!(
            "{}: redundant={} released={} peak={} post={} host={} t_ag={} t_d2h={} t_h2d={} total={}{expert}{bus}",
            self.technique,
            crate::util::fmt_bytes(self.redundant_bytes),
            crate::util::fmt_bytes(self.released_bytes),
            crate::util::fmt_bytes(self.peak_device_bytes),
            crate::util::fmt_bytes(self.post_device_bytes),
            crate::util::fmt_bytes(self.host_bytes),
            crate::util::fmt_secs(self.t_allgather),
            crate::util::fmt_secs(self.t_d2h),
            crate::util::fmt_secs(self.t_h2d),
            crate::util::fmt_secs(self.t_total),
        )
    }
}

/// Human-readable plan line for DESIGN/EXPERIMENTS tables.
pub fn plan_summary(plan: &ReshardPlan) -> String {
    format!(
        "{} -> {}: update≤{}/dev gen≤{}/dev temp≤{}/dev",
        plan.update.describe(),
        plan.gen.describe(),
        crate::util::fmt_bytes(*plan.update_bytes_per_dev.iter().max().unwrap_or(&0)),
        crate::util::fmt_bytes(*plan.gen_bytes_per_dev.iter().max().unwrap_or(&0)),
        crate::util::fmt_bytes(*plan.temp_bytes_per_dev.iter().max().unwrap_or(&0)),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq3_fig3_case() {
        // Fig. 3: TP2EP2DP2 → TP1EP4DP4 over 4 devices
        let m = ModelWeights::moe_like(2, 32, 64, 4);
        let update = ParallelLayout::new(2, 1, 2, 2);
        let gen = ParallelLayout::new(1, 1, 4, 4);
        let r = eq3_redundant_bytes(&m, &update, &gen);
        let expect = 4 * (m.tp_bytes() / 2 + m.expert_bytes() / 4);
        assert_eq!(r, expect);
    }

    #[test]
    fn plan_requires_same_world() {
        let m = ModelWeights::dense_like(2, 32, 64);
        assert!(ReshardPlan::build(
            &m,
            ParallelLayout::dense(2, 1, 1),
            ParallelLayout::dense(1, 1, 4)
        )
        .is_err());
    }

    #[test]
    fn plan_byte_conservation() {
        let m = ModelWeights::dense_like(4, 64, 128);
        let update = ParallelLayout::dense(4, 1, 1);
        let gen = ParallelLayout::dense(2, 1, 2);
        let plan = ReshardPlan::build(&m, update, gen).unwrap();
        // one dp replica of the gen layout holds one full copy of the TP
        // weights plus gtp replicas of the common weights
        let per_replica: u64 = plan.gen_bytes_per_dev[..2].iter().sum();
        assert_eq!(per_replica, m.tp_bytes() + 2 * m.common_bytes());
        // update layout (dp=1) spreads one copy over all 4
        assert_eq!(
            plan.update_bytes_per_dev.iter().sum::<u64>(),
            m.common_bytes() * 4 + m.tp_bytes()
        );
    }
}
