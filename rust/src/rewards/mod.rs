//! Rule-based reward workers (the paper uses a rule reward on DeepScaleR).
//!
//! The reward worker performs no model inference: it parses the generated
//! completion and scores it against the task's verified answer. A small
//! format shaping term rewards producing *any* well-formed integer, which
//! keeps early GRPO gradients alive before exact answers appear (standard
//! rule-reward practice).

use crate::data::Task;

/// Scoring breakdown for one completion.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Score {
    pub reward: f32,
    pub exact: bool,
    pub well_formed: bool,
}

pub const EXACT_REWARD: f32 = 1.0;
pub const FORMAT_REWARD: f32 = 0.1;
/// shaping: parsed integer with the right digit count (incl. sign)
pub const LENGTH_REWARD: f32 = 0.15;
/// shaping: correct leading digit
pub const LEAD_REWARD: f32 = 0.2;

/// Parse the leading integer of a completion ("-12abc" → Some(-12)).
/// Anything after the integer is ignored (the model is free to stop or
/// ramble; only the parsed prefix is scored).
pub fn parse_answer(completion: &str) -> Option<i64> {
    let t = completion.trim_start();
    let mut chars = t.char_indices().peekable();
    let mut end = 0usize;
    let mut saw_digit = false;
    if let Some(&(_, c)) = chars.peek() {
        if c == '-' {
            chars.next();
            end = 1;
        }
    }
    for (i, c) in chars {
        if c.is_ascii_digit() {
            saw_digit = true;
            end = i + 1;
        } else {
            break;
        }
    }
    if !saw_digit {
        return None;
    }
    t[..end].parse().ok()
}

/// Score one completion against its task.
///
/// Graded shaping (beyond the paper's binary rule reward) keeps the GRPO
/// group advantage non-degenerate when training from scratch: an exact
/// answer scores 1.0; a well-formed integer earns partial credit for
/// matching the answer's digit count and leading digit. The paper's models
/// are SFT-pretrained so binary suffices there; ours starts from random
/// init (DESIGN.md substitutions).
pub fn score(task: &Task, completion: &str) -> Score {
    match parse_answer(completion) {
        Some(ans) if ans == task.answer => {
            Score { reward: EXACT_REWARD, exact: true, well_formed: true }
        }
        Some(ans) => {
            let mut r = FORMAT_REWARD;
            let (a, b) = (ans.to_string(), task.answer.to_string());
            if a.len() == b.len() {
                r += LENGTH_REWARD;
            }
            if a.chars().next() == b.chars().next() {
                r += LEAD_REWARD;
            }
            Score { reward: r, exact: false, well_formed: true }
        }
        None => Score { reward: 0.0, exact: false, well_formed: false },
    }
}

/// GRPO group advantage: per-group mean-centered, std-normalized rewards.
/// `rewards` is laid out group-major: `n_groups × group_size`.
pub fn group_advantages(rewards: &[f32], group_size: usize) -> Vec<f32> {
    assert!(group_size > 0 && rewards.len() % group_size == 0);
    let mut adv = Vec::with_capacity(rewards.len());
    for group in rewards.chunks(group_size) {
        let mean = group.iter().sum::<f32>() / group_size as f32;
        let var = group.iter().map(|r| (r - mean) * (r - mean)).sum::<f32>()
            / group_size as f32;
        let std = var.sqrt().max(1e-6);
        for &r in group {
            adv.push((r - mean) / std);
        }
    }
    adv
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Task, Tier};

    fn task(answer: i64) -> Task {
        Task { prompt: "1+1=".into(), answer, tier: Tier::Easy }
    }

    #[test]
    fn parses_integers() {
        assert_eq!(parse_answer("42"), Some(42));
        assert_eq!(parse_answer("-7 rest"), Some(-7));
        assert_eq!(parse_answer("  13"), Some(13));
        assert_eq!(parse_answer("13.5"), Some(13)); // prefix
        assert_eq!(parse_answer("abc"), None);
        assert_eq!(parse_answer(""), None);
        assert_eq!(parse_answer("-"), None);
    }

    #[test]
    fn exact_beats_format_beats_garbage() {
        let t = task(4);
        assert_eq!(score(&t, "4").reward, EXACT_REWARD);
        assert!(score(&t, "4").exact);
        // same digit count + wrong lead digit → format + length shaping
        assert_eq!(score(&t, "5").reward, FORMAT_REWARD + LENGTH_REWARD);
        assert_eq!(score(&t, "??").reward, 0.0);
        // graded: right length and lead beats right length alone
        let t2 = task(42);
        assert!(score(&t2, "41").reward > score(&t2, "51").reward);
        assert!(score(&t2, "51").reward > score(&t2, "5131").reward);
        assert!(score(&t2, "42").reward > score(&t2, "41").reward);
    }

    #[test]
    fn advantages_are_group_centered() {
        let adv = group_advantages(&[1.0, 0.0, 0.0, 0.0], 4);
        assert!(adv[0] > 0.0);
        assert!(adv[1] < 0.0);
        let sum: f32 = adv.iter().sum();
        assert!(sum.abs() < 1e-5);
    }

    #[test]
    fn uniform_group_zero_advantage() {
        let adv = group_advantages(&[0.5; 8], 4);
        assert!(adv.iter().all(|a| a.abs() < 1e-3));
    }

    #[test]
    #[should_panic]
    fn group_size_must_divide() {
        group_advantages(&[1.0; 5], 4);
    }
}
