//! PJRT execution engine: loads `artifacts/<preset>/*.hlo.txt`, compiles
//! them once on the CPU PJRT client, and executes them from the L3 hot
//! path. Adapted from /opt/xla-example/load_hlo.

use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::Path;
use std::time::Instant;

use super::manifest::Manifest;
use super::tensor::Tensor;

/// A compiled artifact plus its manifest signature.
pub struct CompiledArtifact {
    pub kind: String,
    pub exe: xla::PjRtLoadedExecutable,
    pub n_inputs: usize,
    pub n_outputs: usize,
}

/// The engine owns the PJRT client and every compiled executable for one
/// preset. Compilation happens once at startup; `execute` is the hot path.
pub struct Engine {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    artifacts: HashMap<String, CompiledArtifact>,
    /// cumulative execute statistics, keyed by artifact kind
    pub exec_stats: std::sync::Mutex<HashMap<String, ExecStats>>,
}

#[derive(Debug, Default, Clone)]
pub struct ExecStats {
    pub calls: u64,
    pub total_secs: f64,
}

impl Engine {
    /// Load and compile every artifact in `dir` (e.g. `artifacts/small`).
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut artifacts = HashMap::new();
        for a in &manifest.artifacts {
            let path = manifest.artifact_path(a);
            let t0 = Instant::now();
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )
            .with_context(|| format!("parsing HLO text {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling artifact {}", a.kind))?;
            tracing_info(&format!(
                "compiled {} ({} inputs, {} outputs) in {:.2}s",
                a.kind,
                a.inputs.len(),
                a.outputs.len(),
                t0.elapsed().as_secs_f64()
            ));
            artifacts.insert(
                a.kind.clone(),
                CompiledArtifact {
                    kind: a.kind.clone(),
                    exe,
                    n_inputs: a.inputs.len(),
                    n_outputs: a.outputs.len(),
                },
            );
        }
        Ok(Self { manifest, client, artifacts, exec_stats: Default::default() })
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    pub fn has_artifact(&self, kind: &str) -> bool {
        self.artifacts.contains_key(kind)
    }

    /// Execute an artifact with host literals, returning the decomposed
    /// output tuple as literals.
    pub fn execute_literals(&self, kind: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let art = self
            .artifacts
            .get(kind)
            .with_context(|| format!("unknown artifact kind {kind:?}"))?;
        anyhow::ensure!(
            inputs.len() == art.n_inputs,
            "artifact {} expects {} inputs, got {}",
            kind,
            art.n_inputs,
            inputs.len()
        );
        let t0 = Instant::now();
        let result = art.exe.execute::<xla::Literal>(inputs)?;
        let tuple = result[0][0].to_literal_sync()?;
        let outs = tuple.to_tuple()?;
        anyhow::ensure!(
            outs.len() == art.n_outputs,
            "artifact {} returned {} outputs, expected {}",
            kind,
            outs.len(),
            art.n_outputs
        );
        let dt = t0.elapsed().as_secs_f64();
        let mut stats = self.exec_stats.lock().unwrap();
        let e = stats.entry(kind.to_string()).or_default();
        e.calls += 1;
        e.total_secs += dt;
        Ok(outs)
    }

    /// Execute with borrowed literals (callers that cache conversions).
    pub fn execute_borrowed(&self, kind: &str, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let art = self
            .artifacts
            .get(kind)
            .with_context(|| format!("unknown artifact kind {kind:?}"))?;
        anyhow::ensure!(
            inputs.len() == art.n_inputs,
            "artifact {} expects {} inputs, got {}",
            kind,
            art.n_inputs,
            inputs.len()
        );
        let t0 = Instant::now();
        let result = art.exe.execute::<&xla::Literal>(inputs)?;
        let tuple = result[0][0].to_literal_sync()?;
        let outs = tuple.to_tuple()?;
        let dt = t0.elapsed().as_secs_f64();
        let mut stats = self.exec_stats.lock().unwrap();
        let e = stats.entry(kind.to_string()).or_default();
        e.calls += 1;
        e.total_secs += dt;
        Ok(outs)
    }

    /// Execute with host tensors (converted to literals at the boundary).
    pub fn execute(&self, kind: &str, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        let lits: Vec<xla::Literal> =
            inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let outs = self.execute_literals(kind, &lits)?;
        outs.iter().map(Tensor::from_literal).collect()
    }

    pub fn stats_snapshot(&self) -> HashMap<String, ExecStats> {
        self.exec_stats.lock().unwrap().clone()
    }
}

fn tracing_info(msg: &str) {
    if std::env::var_os("MSRL_QUIET").is_none() {
        eprintln!("[engine] {msg}");
    }
}
