//! The artifact manifest — the contract between `python/compile/aot.py`
//! and the Rust runtime. Parsed from `artifacts/<preset>/manifest.json`
//! with the in-crate JSON parser (util::json).

use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct Manifest {
    pub preset: String,
    pub model: ModelInfo,
    pub vocab: Vec<String>,
    pub pad_id: u32,
    pub bos_id: u32,
    pub eos_id: u32,
    pub hyper: Hyper,
    pub n_params: usize,
    pub params: Vec<ParamInfo>,
    pub params_file: String,
    pub artifacts: Vec<ArtifactInfo>,
    pub seed: u64,
    pub dir: PathBuf,
}

#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub name: String,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub vocab_size: usize,
    pub head_dim: usize,
    pub rope_base: f64,
    pub norm_eps: f64,
    pub param_count: u64,
    pub moe: Option<MoeInfo>,
}

#[derive(Debug, Clone)]
pub struct MoeInfo {
    pub num_experts: usize,
    pub top_k: usize,
}

#[derive(Debug, Clone)]
pub struct Hyper {
    pub clip_eps: f64,
    pub kl_coef: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub adam_eps: f64,
}

#[derive(Debug, Clone)]
pub struct ParamInfo {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
    pub offset: u64,
    pub numel: u64,
}

#[derive(Debug, Clone)]
pub struct TensorSig {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

#[derive(Debug, Clone)]
pub struct ArtifactInfo {
    pub kind: String,
    pub file: String,
    pub batch: usize,
    pub seq: usize,
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
    pub use_kernels: bool,
}

fn sig(j: &Json) -> Result<TensorSig> {
    Ok(TensorSig {
        name: j.get("name")?.str()?.to_string(),
        shape: j.get("shape")?.usize_vec()?,
        dtype: j.get("dtype")?.str()?.to_string(),
    })
}

impl Manifest {
    /// Load `manifest.json` from an artifact directory (e.g.
    /// `artifacts/small`).
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading manifest {path:?} (run `make artifacts`)"))?;
        let j = Json::parse(&text).with_context(|| format!("parsing manifest {path:?}"))?;

        let mj = j.get("model")?;
        let model = ModelInfo {
            name: mj.get("name")?.str()?.to_string(),
            d_model: mj.get("d_model")?.usize()?,
            n_layers: mj.get("n_layers")?.usize()?,
            n_heads: mj.get("n_heads")?.usize()?,
            d_ff: mj.get("d_ff")?.usize()?,
            max_seq: mj.get("max_seq")?.usize()?,
            vocab_size: mj.get("vocab_size")?.usize()?,
            head_dim: mj.get("head_dim")?.usize()?,
            rope_base: mj.get("rope_base")?.num()?,
            norm_eps: mj.get("norm_eps")?.num()?,
            param_count: mj.get("param_count")?.u64()?,
            moe: match mj.opt("moe") {
                Some(moe) => Some(MoeInfo {
                    num_experts: moe.get("num_experts")?.usize()?,
                    top_k: moe.get("top_k")?.usize()?,
                }),
                None => None,
            },
        };

        let hj = j.get("hyper")?;
        let hyper = Hyper {
            clip_eps: hj.get("clip_eps")?.num()?,
            kl_coef: hj.get("kl_coef")?.num()?,
            beta1: hj.get("beta1")?.num()?,
            beta2: hj.get("beta2")?.num()?,
            adam_eps: hj.get("adam_eps")?.num()?,
        };

        let params = j
            .get("params")?
            .arr()?
            .iter()
            .map(|p| {
                Ok(ParamInfo {
                    name: p.get("name")?.str()?.to_string(),
                    shape: p.get("shape")?.usize_vec()?,
                    dtype: p.get("dtype")?.str()?.to_string(),
                    offset: p.get("offset")?.u64()?,
                    numel: p.get("numel")?.u64()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        let artifacts = j
            .get("artifacts")?
            .arr()?
            .iter()
            .map(|a| {
                Ok(ArtifactInfo {
                    kind: a.get("kind")?.str()?.to_string(),
                    file: a.get("file")?.str()?.to_string(),
                    batch: a.get("batch")?.usize()?,
                    seq: a.get("seq")?.usize()?,
                    inputs: a.get("inputs")?.arr()?.iter().map(sig).collect::<Result<_>>()?,
                    outputs: a.get("outputs")?.arr()?.iter().map(sig).collect::<Result<_>>()?,
                    use_kernels: a.get("use_kernels")?.bool()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        let m = Manifest {
            preset: j.get("preset")?.str()?.to_string(),
            model,
            vocab: j.get("vocab")?.str_vec()?,
            pad_id: j.get("pad_id")?.u64()? as u32,
            bos_id: j.get("bos_id")?.u64()? as u32,
            eos_id: j.get("eos_id")?.u64()? as u32,
            hyper,
            n_params: j.get("n_params")?.usize()?,
            params,
            params_file: j.get("params_file")?.str()?.to_string(),
            artifacts,
            seed: j.get("seed")?.u64()?,
            dir: dir.to_path_buf(),
        };
        m.validate()?;
        Ok(m)
    }

    pub fn artifact(&self, kind: &str) -> Result<&ArtifactInfo> {
        self.artifacts
            .iter()
            .find(|a| a.kind == kind)
            .with_context(|| format!("manifest has no artifact of kind {kind:?}"))
    }

    pub fn artifact_path(&self, a: &ArtifactInfo) -> PathBuf {
        self.dir.join(&a.file)
    }

    pub fn params_path(&self) -> PathBuf {
        self.dir.join(&self.params_file)
    }

    fn validate(&self) -> Result<()> {
        if self.params.len() != self.n_params {
            bail!(
                "manifest inconsistency: n_params={} but {} param entries",
                self.n_params,
                self.params.len()
            );
        }
        let mut expected_offset = 0u64;
        for p in &self.params {
            if p.offset != expected_offset {
                bail!("param {} offset {} != expected {}", p.name, p.offset, expected_offset);
            }
            let numel: u64 = p.shape.iter().map(|&d| d as u64).product::<u64>().max(1);
            if numel != p.numel {
                bail!("param {} numel mismatch", p.name);
            }
            expected_offset += p.numel * 4;
        }
        for a in &self.artifacts {
            // every artifact's leading inputs must be the params in order
            if a.inputs.len() < self.n_params {
                bail!("artifact {} has fewer inputs than params", a.kind);
            }
            for (sig, p) in a.inputs.iter().zip(&self.params) {
                if sig.name != p.name || sig.shape != p.shape {
                    bail!(
                        "artifact {} input {:?} does not match param {:?}",
                        a.kind,
                        sig.name,
                        p.name
                    );
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny")
    }

    #[test]
    fn load_tiny_manifest() {
        let m = Manifest::load(tiny_dir()).unwrap();
        assert_eq!(m.preset, "tiny");
        assert_eq!(m.params.len(), m.n_params);
        assert!(m.artifact("train_step").is_ok());
        assert!(m.artifact("logprobs").is_ok());
        assert!(m.artifact("decode_step").is_ok());
        assert!(m.artifact("nonexistent").is_err());
        assert!(m.model.moe.is_none());
    }

    #[test]
    fn load_moe_manifest() {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/moe_tiny");
        let m = Manifest::load(dir).unwrap();
        let moe = m.model.moe.expect("moe preset must carry moe info");
        assert_eq!(moe.num_experts, 4);
        assert_eq!(moe.top_k, 2);
    }

    #[test]
    fn param_count_matches_binary_size() {
        let m = Manifest::load(tiny_dir()).unwrap();
        let total: u64 = m.params.iter().map(|p| p.numel * 4).sum();
        let size = std::fs::metadata(m.params_path()).unwrap().len();
        assert_eq!(total, size);
    }
}
