//! Runtime layer: PJRT client wrapper executing the AOT artifacts.
//!
//! `Engine` compiles `artifacts/<preset>/*.hlo.txt` once (HLO text → proto
//! → XlaComputation → PJRT executable); `Policy` threads parameters and
//! optimizer state through the train/inference/decode programs. Python is
//! never involved at runtime.

mod engine;
mod manifest;
mod policy;
mod tensor;

pub use engine::{Engine, ExecStats};
pub use manifest::{ArtifactInfo, Hyper, Manifest, ModelInfo, MoeInfo, ParamInfo, TensorSig};
pub use policy::{Policy, TrainBatch, TrainStats};
pub use tensor::Tensor;

use std::path::PathBuf;

/// Resolve the artifact directory for a preset, honouring `MSRL_ARTIFACTS`
/// and falling back to `<crate root>/artifacts/<preset>`.
pub fn artifact_dir(preset: &str) -> PathBuf {
    let base = std::env::var_os("MSRL_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"));
    base.join(preset)
}
