//! The policy: model parameters + optimizer state threaded through the
//! AOT train-step artifact, plus logprob inference and incremental decode.
//!
//! This is the actor worker's compute substrate: `train_step` is the update
//! state, `logprobs` the inference state, and `decode_step` the generation
//! state (driven by `generation::Engine`).

use anyhow::{bail, Context, Result};
use std::cell::RefCell;

use super::engine::Engine;
use super::tensor::Tensor;

/// Model parameters + Adam state, kept as host tensors in manifest order.
///
/// §Perf: inference paths (`logprobs`, `decode_step`) are called many
/// times per iteration with unchanged parameters, so the param→Literal
/// conversion is cached and invalidated only when `train_step` replaces
/// the weights (≈19% end-to-end win on the tiny preset, EXPERIMENTS.md
/// §Perf L3-1).
pub struct Policy {
    pub params: Vec<Tensor>,
    pub m: Vec<Tensor>,
    pub v: Vec<Tensor>,
    pub step: u64,
    pub lr: f32,
    param_literals: RefCell<Option<Vec<xla::Literal>>>,
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TrainStats {
    pub loss: f32,
    pub kl: f32,
    pub ratio: f32,
    pub step: u64,
}

/// One GRPO update batch, shaped for the train_step artifact.
#[derive(Debug, Clone)]
pub struct TrainBatch {
    pub tokens: Tensor,    // [B, S] i32
    pub resp_mask: Tensor, // [B, S-1] f32
    pub old_lp: Tensor,    // [B, S-1] f32
    pub ref_lp: Tensor,    // [B, S-1] f32
    pub adv: Tensor,       // [B] f32
}

impl Policy {
    /// Load the initial parameters from `params_init.bin` and zero-init
    /// the Adam moments.
    pub fn load_initial(engine: &Engine, lr: f32) -> Result<Self> {
        let manifest = &engine.manifest;
        let bytes = std::fs::read(manifest.params_path())
            .with_context(|| format!("reading {:?}", manifest.params_path()))?;
        let mut params = Vec::with_capacity(manifest.n_params);
        let mut m = Vec::with_capacity(manifest.n_params);
        let mut v = Vec::with_capacity(manifest.n_params);
        for p in &manifest.params {
            let start = p.offset as usize;
            let end = start + (p.numel as usize) * 4;
            if end > bytes.len() {
                bail!("params_init.bin too short for {}", p.name);
            }
            let data: Vec<f32> = bytes[start..end]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            params.push(Tensor::f32(&p.shape, data)?);
            m.push(Tensor::zeros(&p.shape));
            v.push(Tensor::zeros(&p.shape));
        }
        Ok(Self { params, m, v, step: 0, lr, param_literals: RefCell::new(None) })
    }

    /// Build an inference-only replica around a snapshot of weights.
    ///
    /// The pipelined executor gives each inference stage thread (actor
    /// generation, actor old-logprobs) its own replica, refreshed from the
    /// update thread's published weights — the testbed analogue of the
    /// paper's train→infer weight resharding. Replicas serve only
    /// `logprobs`/`decode_step`; the Adam moments are left empty (a
    /// replica that reached `train_step` would fail the artifact's input
    /// arity check), keeping a refresh to one params clone instead of
    /// three param-sized allocations.
    pub fn from_params(params: Vec<Tensor>) -> Self {
        Self {
            params,
            m: Vec::new(),
            v: Vec::new(),
            step: 0,
            lr: 0.0,
            param_literals: RefCell::new(None),
        }
    }

    /// Cached literal views of the parameters (rebuilt after updates).
    fn cached_param_literals(&self) -> Result<std::cell::Ref<'_, Option<Vec<xla::Literal>>>> {
        {
            let mut guard = self.param_literals.borrow_mut();
            if guard.is_none() {
                let lits: Vec<xla::Literal> =
                    self.params.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
                *guard = Some(lits);
            }
        }
        Ok(self.param_literals.borrow())
    }

    /// Total parameter bytes (one copy of the weights).
    pub fn param_bytes(&self) -> usize {
        self.params.iter().map(|t| t.size_bytes()).sum()
    }

    /// Run one GRPO update through the train_step artifact, replacing the
    /// parameters and optimizer state in place.
    pub fn train_step(&mut self, engine: &Engine, batch: &TrainBatch) -> Result<TrainStats> {
        let n = self.params.len();
        self.step += 1;
        let step_t = Tensor::scalar_f32(self.step as f32);
        let lr_t = Tensor::scalar_f32(self.lr);
        let mut inputs: Vec<&Tensor> = Vec::with_capacity(3 * n + 7);
        inputs.extend(self.params.iter());
        inputs.extend(self.m.iter());
        inputs.extend(self.v.iter());
        inputs.push(&step_t);
        inputs.push(&lr_t);
        inputs.push(&batch.tokens);
        inputs.push(&batch.resp_mask);
        inputs.push(&batch.old_lp);
        inputs.push(&batch.ref_lp);
        inputs.push(&batch.adv);

        let mut outs = engine.execute("train_step", &inputs)?;
        anyhow::ensure!(outs.len() == 3 * n + 3, "train_step output arity");
        // weights change: drop the cached inference literals
        *self.param_literals.borrow_mut() = None;
        let ratio = outs.pop().unwrap().scalar()?;
        let kl = outs.pop().unwrap().scalar()?;
        let loss = outs.pop().unwrap().scalar()?;
        let new_v: Vec<Tensor> = outs.split_off(2 * n);
        let new_m: Vec<Tensor> = outs.split_off(n);
        self.params = outs;
        self.m = new_m;
        self.v = new_v;
        Ok(TrainStats { loss, kl, ratio, step: self.step })
    }

    /// Per-token log-probs of the realized tokens: input [B, S] i32 →
    /// output [B, S-1] f32 (row-major).
    pub fn logprobs(&self, engine: &Engine, tokens: &Tensor) -> Result<Tensor> {
        let guard = self.cached_param_literals()?;
        let params = guard.as_ref().unwrap();
        let mut lits: Vec<&xla::Literal> = params.iter().collect();
        let tok_lit = tokens.to_literal()?;
        lits.push(&tok_lit);
        let mut outs = engine.execute_borrowed("logprobs", &lits)?;
        anyhow::ensure!(outs.len() == 1, "logprobs output arity");
        Tensor::from_literal(&outs.pop().unwrap())
    }

    /// One incremental decode step: (kv, pos[B], token[B]) → (logits [B,V],
    /// new kv).
    pub fn decode_step(
        &self,
        engine: &Engine,
        kv: &Tensor,
        pos: &Tensor,
        token: &Tensor,
    ) -> Result<(Tensor, Tensor)> {
        let guard = self.cached_param_literals()?;
        let params = guard.as_ref().unwrap();
        let mut lits: Vec<&xla::Literal> = params.iter().collect();
        let kv_lit = kv.to_literal()?;
        let pos_lit = pos.to_literal()?;
        let tok_lit = token.to_literal()?;
        lits.push(&kv_lit);
        lits.push(&pos_lit);
        lits.push(&tok_lit);
        let mut outs = engine.execute_borrowed("decode_step", &lits)?;
        anyhow::ensure!(outs.len() == 2, "decode_step output arity");
        let new_kv = Tensor::from_literal(&outs.pop().unwrap())?;
        let logits = Tensor::from_literal(&outs.pop().unwrap())?;
        Ok((logits, new_kv))
    }

    /// Fresh zeroed KV cache shaped for the decode artifact.
    pub fn init_kv(&self, engine: &Engine) -> Result<Tensor> {
        let a = engine.manifest.artifact("decode_step")?;
        let kv_sig = a
            .inputs
            .iter()
            .find(|s| s.name == "kv")
            .context("decode_step artifact missing kv input")?;
        Ok(Tensor::zeros(&kv_sig.shape))
    }
}
