//! Host tensors: the coordinator-side representation of model data.
//!
//! Thin, owned buffers (f32 / i32) with shape, convertible to and from
//! `xla::Literal` at the PJRT boundary. All sample-flow payloads
//! (transfer-dock warehouses), weight shards (resharding flow), and batch
//! tensors are `Tensor`s; Literals exist only at the execute call site.

use anyhow::{bail, Context, Result};
use xla::{ElementType, Literal};

#[derive(Debug, Clone, PartialEq)]
pub enum Tensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product::<usize>().max(1);
        Tensor::F32 { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn scalar_f32(v: f32) -> Self {
        Tensor::F32 { shape: vec![], data: vec![v] }
    }

    pub fn f32(shape: &[usize], data: Vec<f32>) -> Result<Self> {
        let n = shape.iter().product::<usize>().max(1);
        if n != data.len() {
            bail!("shape {:?} needs {} elements, got {}", shape, n, data.len());
        }
        Ok(Tensor::F32 { shape: shape.to_vec(), data })
    }

    pub fn i32(shape: &[usize], data: Vec<i32>) -> Result<Self> {
        let n = shape.iter().product::<usize>().max(1);
        if n != data.len() {
            bail!("shape {:?} needs {} elements, got {}", shape, n, data.len());
        }
        Ok(Tensor::I32 { shape: shape.to_vec(), data })
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32 { shape, .. } | Tensor::I32 { shape, .. } => shape,
        }
    }

    pub fn numel(&self) -> usize {
        self.shape().iter().product::<usize>().max(1)
    }

    /// Size in bytes of the payload (both dtypes are 4-byte).
    pub fn size_bytes(&self) -> usize {
        self.numel() * 4
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            Tensor::I32 { .. } => bail!("tensor is i32, expected f32"),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            Tensor::I32 { .. } => bail!("tensor is i32, expected f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Tensor::I32 { data, .. } => Ok(data),
            Tensor::F32 { .. } => bail!("tensor is f32, expected i32"),
        }
    }

    pub fn scalar(&self) -> Result<f32> {
        let d = self.as_f32()?;
        if d.len() != 1 {
            bail!("expected scalar tensor, shape {:?}", self.shape());
        }
        Ok(d[0])
    }

    pub fn to_literal(&self) -> Result<Literal> {
        match self {
            Tensor::F32 { shape, data } => {
                let bytes: &[u8] = bytemuck_cast_f32(data);
                Literal::create_from_shape_and_untyped_data(ElementType::F32, shape, bytes)
                    .context("creating f32 literal")
            }
            Tensor::I32 { shape, data } => {
                let bytes: &[u8] = bytemuck_cast_i32(data);
                Literal::create_from_shape_and_untyped_data(ElementType::S32, shape, bytes)
                    .context("creating i32 literal")
            }
        }
    }

    pub fn from_literal(lit: &Literal) -> Result<Self> {
        let shape = lit.array_shape().context("literal has no array shape")?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            ElementType::F32 => Ok(Tensor::F32 { shape: dims, data: lit.to_vec::<f32>()? }),
            ElementType::S32 => Ok(Tensor::I32 { shape: dims, data: lit.to_vec::<i32>()? }),
            other => bail!("unsupported literal element type {other:?}"),
        }
    }
}

// Safe because f32/i32 have no padding and we only reinterpret to bytes.
fn bytemuck_cast_f32(data: &[f32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) }
}

fn bytemuck_cast_i32(data: &[i32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_checks() {
        assert!(Tensor::f32(&[2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::f32(&[2, 3], vec![0.0; 5]).is_err());
        assert!(Tensor::i32(&[4], vec![1, 2, 3, 4]).is_ok());
    }

    #[test]
    fn literal_round_trip_f32() {
        let t = Tensor::f32(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let lit = t.to_literal().unwrap();
        let back = Tensor::from_literal(&lit).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn literal_round_trip_i32() {
        let t = Tensor::i32(&[3], vec![7, -1, 42]).unwrap();
        let lit = t.to_literal().unwrap();
        let back = Tensor::from_literal(&lit).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn scalar_round_trip() {
        let t = Tensor::scalar_f32(3.5);
        let lit = t.to_literal().unwrap();
        assert_eq!(Tensor::from_literal(&lit).unwrap().scalar().unwrap(), 3.5);
    }

    #[test]
    fn size_bytes() {
        assert_eq!(Tensor::zeros(&[8, 4]).size_bytes(), 128);
    }
}
