//! Chaos harness: drives the **real** sample-flow machinery (the
//! transfer dock or the replay-buffer baseline — actual warehouses,
//! controllers, leases, notification) with *synthetic* stage workers, so
//! lease-based recovery can be exercised deterministically without HLO
//! artifacts or a real engine.
//!
//! Stage outputs are pure functions of the sample (tokens derived from
//! the prompt, logprobs zeros, reward from the answer), which makes every
//! redispatch byte-idempotent: however many times a kill/stall forces a
//! sample through a stage, the surviving writeback is identical. The
//! harness's contract — pinned by `tests/chaos.rs` and printed by
//! `simulate --experiment chaos` — is the paper's reliability claim in
//! miniature: under any seeded `FaultPlan`, the run drains to the **same
//! retired-sample set** as a fault-free run, with zero loss and exact
//! byte conservation.

use anyhow::Result;
use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::data::TaskGenerator;
use crate::memory::TenantQuotas;
use crate::metrics::{FlowRecovery, StageScaling};
use crate::runtime::Tensor;
use crate::trainers::TenantSet;
use crate::trainers::autoscale::{
    finish_scaling, observe_and_scale, spawn_initial, AutoscaleConfig, Autoscaler, ReplicaSet,
    StageReplicas, SCALABLE_STAGES,
};
use crate::trainers::faults::{FaultInjector, FaultKind, FaultPlan, StageExit};
use crate::transfer_dock::{
    push_segment, Conservation, DockTopology, FieldKind, PartialRollout, ReplayBuffer, Sample,
    SampleFlow, Stage, TransferDock,
};

/// One chaos run's shape.
#[derive(Debug, Clone)]
pub struct ChaosConfig {
    pub iterations: usize,
    pub prompts_per_iter: usize,
    pub group_size: usize,
    pub nodes: usize,
    /// admission window (iterations admitted ahead of the last fully
    /// retired one; 1 = lockstep — the executor's `max_inflight_iters`)
    pub max_inflight_iters: usize,
    pub lease_ticks: u64,
    /// workload seed (the prompt stream)
    pub seed: u64,
    /// the fault schedule (rates of 0 = fault-free)
    pub plan: FaultPlan,
    /// concurrent workers per pull-driven stage (2+ exercises the
    /// redispatch-to-a-peer path: a stalled worker's reclaimed samples
    /// are re-processed by its twin and the late writebacks land as
    /// superseded duplicates)
    pub workers_per_stage: usize,
    /// per-stage initial replica counts; overrides the uniform
    /// `workers_per_stage` when set (the executor's `--stage-replicas`)
    pub stage_replicas: Option<StageReplicas>,
    /// backlog-driven elastic autoscaling of the stage workers, driven
    /// by the harness driver on its lease ticks
    pub autoscale: Option<AutoscaleConfig>,
    /// generation replicas run the streaming (continuous-batching)
    /// worker: a persistent slot set that admits claims between decode
    /// steps and retires finished sequences individually — the harness
    /// twin of the executor's `--gen-streaming` stage
    pub gen_streaming: bool,
    /// streaming generation workers persist each held sequence's decoded
    /// prefix through the flow (every [`SYNTH_CKPT_STEPS`] decode steps
    /// and once more when a fault kill takes the worker down), and a
    /// claim that arrives carrying a persisted prefix resumes from it
    /// instead of decoding from scratch — the harness twin of the
    /// executor's `--partial-rollouts`. Only meaningful with
    /// `gen_streaming` (the batch worker has no mid-sequence state).
    pub partial_rollouts: bool,
    /// controller shards per worker state (K) for the dock under test —
    /// the harness twin of `--dock-shards`. 1 = the single-controller
    /// dock; any K must retire the identical `(set, stamps)` (the
    /// sharding differential oracle, pinned by `tests/sharded_dock.rs`)
    pub dock_shards: usize,
    /// cross-shard steal threshold — the harness twin of
    /// `--steal-threshold` (only meaningful with `dock_shards > 1`)
    pub steal_threshold: usize,
    /// tenant roster size — the harness twin of `--tenants`. Groups
    /// stripe round-robin over tenants by group id; 1 (default) is the
    /// single-tenant bit-identical pre-tenancy path
    pub tenants: usize,
    /// positional per-tenant claim weights (short list pads with 1) —
    /// the harness twin of `--tenant-weight`; installs deficit-weighted
    /// round-robin handout on the flow when `tenants > 1`
    pub tenant_weights: Vec<u32>,
    /// positional per-tenant quotas in MiB (short list = uncapped) — the
    /// harness twin of `--tenant-quota-mb`. Each admitted sample charges
    /// a flat [`SYNTH_TENANT_BYTES`] against its tenant until retire, so
    /// a quota of Q MiB bounds that tenant to Q·16 samples in flight;
    /// over-quota tenants' fresh admissions defer (per-tenant FIFO)
    /// while siblings admit freely
    pub tenant_quota_mb: Vec<u64>,
    /// admit only this tenant's groups — the isolated-slice run of the
    /// multi-tenant differential oracle. The task stream is consumed in
    /// full either way, so the filtered run sees exactly the groups the
    /// shared run assigns that tenant
    pub tenant_filter: Option<u32>,
    /// hard wall-clock bound — a wedged run fails loudly, never hangs CI
    pub deadline: Duration,
}

impl Default for ChaosConfig {
    fn default() -> Self {
        Self {
            iterations: 4,
            prompts_per_iter: 4,
            group_size: 2,
            nodes: 4,
            max_inflight_iters: 2,
            lease_ticks: 4,
            seed: 0,
            plan: FaultPlan::default(),
            workers_per_stage: 1,
            stage_replicas: None,
            autoscale: None,
            gen_streaming: false,
            partial_rollouts: false,
            dock_shards: 1,
            steal_threshold: 0,
            tenants: 1,
            tenant_weights: Vec::new(),
            tenant_quota_mb: Vec::new(),
            tenant_filter: None,
            deadline: Duration::from_secs(60),
        }
    }
}

impl ChaosConfig {
    /// Samples this run admits (and must retire): every group under the
    /// full roster, only the filtered tenant's groups in an
    /// isolated-slice run.
    pub fn total_samples(&self) -> usize {
        let groups = self.iterations * self.prompts_per_iter;
        let owned = match self.tenant_filter {
            Some(t) => {
                let n = self.tenants.max(1);
                (0..groups).filter(|g| g % n == t as usize).count()
            }
            None => groups,
        };
        owned * self.group_size
    }

    /// The validated tenant roster for this run.
    pub fn roster(&self) -> Result<TenantSet> {
        TenantSet::from_config(self.tenants.max(1), &self.tenant_weights, &self.tenant_quota_mb)
    }

    /// Which tenant owns a group (groups stripe round-robin).
    pub fn tenant_of_group(&self, group: u64) -> u32 {
        (group % self.tenants.max(1) as u64) as u32
    }

    /// Initial replicas per stage: the explicit per-stage counts when
    /// set, else `workers_per_stage` uniformly.
    pub fn initial_replicas(&self) -> StageReplicas {
        self.stage_replicas
            .unwrap_or_else(|| StageReplicas::uniform(self.workers_per_stage.max(1)))
    }
}

/// Synthetic checkpoint cadence: a streaming generation worker under
/// `partial_rollouts` persists each held sequence's decoded prefix
/// through the flow every this-many decode steps — the harness twin of
/// the executor's `PARTIAL_CKPT_STEPS`, shrunk so short synthetic
/// budgets (1..=7 steps) still cross a checkpoint boundary.
pub const SYNTH_CKPT_STEPS: u64 = 2;

/// Flat synthetic per-sample quota charge: every admitted sample holds
/// this many bytes against its tenant's quota until it retires, so a
/// `tenant_quota_mb` of Q bounds the tenant to exactly Q·16 resident
/// samples — deterministic backpressure without a real KV pool. The
/// 1 MiB quota floor therefore always admits at least 16 samples:
/// quota deferral can stall a tenant, never wedge it.
pub const SYNTH_TENANT_BYTES: u64 = 64 << 10;

/// Streaming decode-work accounting: decode steps actually executed vs
/// the workload's intrinsic budget — the bounded-recompute half of the
/// partial-rollout differential. All zeros for batch-mode runs and the
/// baseline (whose decode work is by construction exactly the budget).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct DecodeWork {
    /// decode steps executed across every streaming generation worker
    /// incarnation — a stalled zombie's post-reclaim steps count too:
    /// duplicated work must be visible, never hidden
    pub decoded_steps: u64,
    /// Σ per-sequence step budgets over every admitted sample: what an
    /// uninterrupted fault-free run decodes
    pub budget_steps: u64,
    /// partial prefixes persisted through the flow (periodic
    /// checkpoints + kill-path persists)
    pub persists: u64,
    /// claims that arrived carrying a persisted prefix and resumed
    /// from it instead of decoding from scratch
    pub resumes: u64,
    /// decode steps the resumes skipped — the work the dock saved
    pub saved_steps: u64,
}

impl DecodeWork {
    /// Steps decoded beyond the intrinsic budget: replayed
    /// (post-abandonment) or zombie (post-reclaim duplicate) work.
    pub fn recomputed_steps(&self) -> u64 {
        self.decoded_steps.saturating_sub(self.budget_steps)
    }
}

/// Shared decode-work counters the streaming workers bump as they run.
#[derive(Default)]
struct StreamCounters {
    decoded: AtomicU64,
    persists: AtomicU64,
    resumes: AtomicU64,
    saved: AtomicU64,
}

/// What a chaos run produced.
#[derive(Debug)]
pub struct ChaosOutcome {
    /// retired samples: index → (group, prompt text, behavior stamp) —
    /// the loss detector, and (since the stamp is a pure function of the
    /// sample here) the elastic differential's stamp-identity detector
    pub retired: BTreeMap<u64, (u64, String, u64)>,
    /// lease/fault accounting at the end of the run
    pub recovery: FlowRecovery,
    /// per-store byte conservation (one entry per warehouse; one total
    /// for the replay buffer)
    pub conservation: Vec<Conservation>,
    /// samples still resident after the drain (must be 0)
    pub resident_after: usize,
    /// logical lease-clock ticks the driver issued
    pub ticks: u64,
    /// elastic replica accounting: one entry per pull-driven stage
    /// (recorded unconditionally in the harness — unlike the executor's
    /// report, which stays empty for unreplicated runs); the baseline
    /// drain leaves it default
    pub scaling: StageScaling,
    /// streaming decode-work accounting (default for batch-mode runs
    /// and the baseline)
    pub work: DecodeWork,
    /// per-tenant claim counts from the flow's weighted-fair ledger
    /// (empty for single-tenant runs — the fast path never counts)
    pub tenant_claims: Vec<(u32, u64)>,
    /// quota-deferred admissions summed over tenants (0 without quotas)
    pub tenant_deferrals: u64,
}

impl ChaosOutcome {
    /// Zero-loss check: every admitted sample retired exactly once and
    /// every store conserves bytes.
    pub fn lossless(&self, cfg: &ChaosConfig) -> bool {
        self.retired.len() == cfg.total_samples()
            && self.resident_after == 0
            && self.conservation.iter().all(|c| c.holds())
            && self.recovery.consistent()
    }
}

/// Deterministic synthetic generation output for a sample: tokens *and
/// the behavior-version stamp* are pure functions of the prompt bytes,
/// so any redispatch regenerates the same response with the same stamp —
/// which is exactly what makes the elastic differential meaningful: if
/// replicas or the autoscaler could lose, duplicate, or re-stamp a
/// sample, the retired `(set, stamps)` comparison would catch it.
fn synth_hash(s: &Sample) -> u32 {
    let mut h = 0x9E37_79B9u32;
    for b in s.prompt_text.bytes() {
        h = h.wrapping_mul(31).wrapping_add(b as u32);
    }
    h
}

fn synth_generation(s: &Sample) -> (Vec<(FieldKind, Tensor)>, String, usize, u64) {
    let h = synth_hash(s);
    let tokens: Vec<i32> = (0..8).map(|i| ((h >> (i * 4)) & 0xF) as i32 + 1).collect();
    let fields = vec![
        (FieldKind::Tokens, Tensor::i32(&[8], tokens).unwrap()),
        (FieldKind::RespMask, Tensor::zeros(&[7])),
    ];
    // a non-trivial stamp (1..=4): distinct per prompt, identical across
    // redispatches and replica configurations
    let stamp = 1 + (h % 4) as u64;
    (fields, format!("{}", s.answer), 2, stamp)
}

/// Long-tail per-sequence decode budget (1..=7 steps) of the streaming
/// worker — a pure function of the prompt, so admission order, slot
/// assignment, kills, and resumes cannot change how much decode work a
/// sequence intrinsically needs.
fn synth_budget(s: &Sample) -> u64 {
    1 + (synth_hash(s) % 7) as u64
}

/// One synthetic pull-driven stage worker (runs until shutdown; a
/// fault-kill exits `Killed` and the supervisor respawns it; a set
/// retire flag — autoscale scale-down — exits `Retired` between claim
/// batches, never while holding one).
fn synthetic_stage(
    flow: &dyn SampleFlow,
    stage: Stage,
    retire: &AtomicBool,
    busy_slots: &AtomicUsize,
    faults: Option<&FaultInjector>,
    shutdown: &AtomicBool,
) -> Result<StageExit> {
    loop {
        if retire.load(Ordering::Relaxed) {
            return Ok(StageExit::Retired);
        }
        let metas = flow.wait_ready(stage, 16, Duration::from_millis(5))?;
        if metas.is_empty() {
            if shutdown.load(Ordering::Relaxed) {
                return Ok(StageExit::Completed);
            }
            continue;
        }
        if let Some(inj) = faults {
            match inj.decide(stage) {
                Some(FaultKind::Kill) => {
                    // abandon the claimed batch: no writeback, no release
                    // — only the lease can bring the samples back
                    return Ok(StageExit::Killed);
                }
                Some(FaultKind::Stall) => inj.stall(flow, shutdown),
                None => {}
            }
        }
        busy_slots.fetch_add(1, Ordering::Relaxed);
        let done = (|| -> Result<()> {
            let samples = flow.fetch_resident(0, &metas)?;
            for s in &samples {
                match stage {
                    Stage::Generation => {
                        let (fields, completion, resp_len, stamp) = synth_generation(s);
                        flow.store_generation(0, s.index, fields, completion, resp_len, stamp)?;
                    }
                    Stage::OldLogprob => flow.store_fields(
                        0,
                        s.index,
                        vec![(FieldKind::OldLp, Tensor::zeros(&[7]))],
                    )?,
                    Stage::RefLogprob => flow.store_fields(
                        0,
                        s.index,
                        vec![(FieldKind::RefLp, Tensor::zeros(&[7]))],
                    )?,
                    Stage::Reward => flow.store_fields(
                        0,
                        s.index,
                        vec![(FieldKind::Reward, Tensor::scalar_f32(1.0))],
                    )?,
                    Stage::Update => unreachable!("the driver consumes update-ready samples"),
                }
            }
            Ok(())
        })();
        busy_slots.fetch_sub(1, Ordering::Relaxed);
        done?;
    }
}

/// Streaming twin of the generation arm of [`synthetic_stage`]: a
/// persistent slot set (continuous batching in miniature). Between
/// decode steps it claims newly ready samples *incrementally*
/// ([`SampleFlow::try_claim`]), each held sequence gets a long-tail
/// step budget derived from its prompt hash, leases are renewed every
/// step for exactly the held indices, and each sequence writes back and
/// leaves **individually** the step its budget drains — no batch
/// barrier. The writeback is byte-identical to the batch worker's
/// ([`synth_generation`]), so the retired `(set, stamps)` must match
/// batch mode under any admission interleaving, replica count, or fault
/// schedule — the harness form of the ISSUE's streaming differential.
fn synthetic_streaming_gen(
    flow: &dyn SampleFlow,
    retire: &AtomicBool,
    busy_slots: &AtomicUsize,
    faults: Option<&FaultInjector>,
    shutdown: &AtomicBool,
    partial_rollouts: bool,
    counters: &StreamCounters,
) -> Result<StageExit> {
    const SLOTS: usize = 4;
    struct HeldSeq {
        index: u64,
        budget: u64,
        /// decode steps finished (resumes start above zero)
        done: u64,
        /// prefix length already persisted through the flow
        persisted: u64,
        sample: Sample,
    }
    /// Persist a held sequence's decoded prefix as a first-class
    /// partial rollout: `done` synthetic progress tokens (pure
    /// functions of the prompt, so a replay regenerates the identical
    /// prefix), one zero logprob per token, a single segment spanning
    /// the prefix at the sample's deterministic behavior stamp.
    fn persist_prefix(
        flow: &dyn SampleFlow,
        h: &mut HeldSeq,
        counters: &StreamCounters,
    ) -> Result<()> {
        let hash = synth_hash(&h.sample);
        let n = h.done as usize;
        let mut segments = Vec::new();
        push_segment(&mut segments, 0, n, 1 + (hash % 4) as u64);
        let partial = PartialRollout {
            response_ids: (0..n).map(|j| ((hash >> (j % 8)) & 0x7) as i32 + 1).collect(),
            response_logprobs: vec![0.0; n],
            segments,
        };
        flow.store_partial_generation(0, h.index, partial)?;
        h.persisted = h.done;
        counters.persists.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }
    let mut held: Vec<HeldSeq> = Vec::new();
    loop {
        let metas = if held.is_empty() {
            // drained: safe points for retirement and shutdown
            if retire.load(Ordering::Relaxed) {
                return Ok(StageExit::Retired);
            }
            let m = flow.wait_ready(Stage::Generation, SLOTS, Duration::from_millis(5))?;
            if m.is_empty() {
                if shutdown.load(Ordering::Relaxed) {
                    return Ok(StageExit::Completed);
                }
                continue;
            }
            m
        } else if held.len() < SLOTS {
            // mid-flight: non-blocking admission between decode steps
            flow.try_claim(Stage::Generation, SLOTS - held.len())?
        } else {
            Vec::new()
        };
        if !metas.is_empty() {
            if let Some(inj) = faults {
                match inj.decide(Stage::Generation) {
                    Some(FaultKind::Kill) => {
                        // abandon the fresh claims AND every held slot:
                        // no writeback, no release — only the lease can
                        // bring them back. Under partial rollouts the
                        // dying worker's last act is to persist each
                        // held prefix (the executor's kill path does
                        // the same), so the resumer replays at most
                        // nothing; the periodic checkpoint covers
                        // deaths that get no last act (stall zombies
                        // losing a reclaim race)
                        if partial_rollouts {
                            for h in held.iter_mut() {
                                if h.done > h.persisted {
                                    persist_prefix(flow, h, counters)?;
                                }
                            }
                        }
                        return Ok(StageExit::Killed);
                    }
                    Some(FaultKind::Stall) => inj.stall(flow, shutdown),
                    None => {}
                }
            }
            let samples = flow.fetch_resident(0, &metas)?;
            for mut s in samples {
                if held.iter().any(|h| h.index == s.index) {
                    continue;
                }
                let budget = synth_budget(&s);
                let mut done = 0u64;
                if partial_rollouts {
                    if let Some(p) = s.partial.take() {
                        // resume from the persisted prefix instead of
                        // decoding from scratch
                        done = (p.token_len() as u64).min(budget);
                        counters.resumes.fetch_add(1, Ordering::Relaxed);
                        counters.saved.fetch_add(done, Ordering::Relaxed);
                    }
                }
                held.push(HeldSeq { index: s.index, budget, done, persisted: done, sample: s });
            }
        }
        // one decode step over the live slot set
        busy_slots.fetch_add(1, Ordering::Relaxed);
        let step = (|| -> Result<()> {
            let indices: Vec<u64> = held.iter().map(|h| h.index).collect();
            flow.renew(Stage::Generation, &indices);
            for h in held.iter_mut() {
                if h.done < h.budget {
                    h.done += 1;
                    counters.decoded.fetch_add(1, Ordering::Relaxed);
                }
            }
            // per-sequence retirement: finished sequences write back and
            // leave the slot set individually, mid-step
            let mut i = 0;
            while i < held.len() {
                if held[i].done >= held[i].budget {
                    let h = held.swap_remove(i);
                    let (fields, completion, resp_len, stamp) = synth_generation(&h.sample);
                    flow.store_generation(0, h.index, fields, completion, resp_len, stamp)?;
                } else {
                    i += 1;
                }
            }
            // periodic checkpoint over the surviving slots: bounds what
            // an unclean death can force a resumer to replay
            if partial_rollouts {
                for h in held.iter_mut() {
                    if h.done - h.persisted >= SYNTH_CKPT_STEPS {
                        persist_prefix(flow, h, counters)?;
                    }
                }
            }
            Ok(())
        })();
        busy_slots.fetch_sub(1, Ordering::Relaxed);
        step?;
    }
}

/// Build one iteration's sample groups, tenant-striped by group id. An
/// isolated-slice run (`tenant_filter`) keeps only the filtered tenant's
/// groups but still consumes the full task stream, so the i-th group
/// tenant `t` sees here is exactly the i-th group the shared run assigns
/// it — the alignment the differential oracle re-keys on.
fn build_iteration(
    task_gen: &mut TaskGenerator,
    cfg: &ChaosConfig,
    iter: usize,
) -> Vec<Sample> {
    let tasks = task_gen.batch(cfg.prompts_per_iter);
    let mut samples = Vec::with_capacity(cfg.prompts_per_iter * cfg.group_size);
    for (gi, t) in tasks.iter().enumerate() {
        let group = (iter * cfg.prompts_per_iter + gi) as u64;
        let tenant = cfg.tenant_of_group(group);
        if cfg.tenant_filter.is_some_and(|f| f != tenant) {
            continue;
        }
        for _ in 0..cfg.group_size {
            samples.push(
                Sample::new_prompt(u64::MAX, group, t.prompt.clone(), t.answer)
                    .with_tenant(tenant),
            );
        }
    }
    samples
}

/// Admit one iteration's sample groups; returns the decode-step budget
/// the admission added (Σ [`synth_budget`] — the uninterrupted decode
/// work, the yardstick of the bounded-recompute differential).
fn admit_iteration(
    flow: &dyn SampleFlow,
    task_gen: &mut TaskGenerator,
    cfg: &ChaosConfig,
    iter: usize,
) -> Result<u64> {
    let samples = build_iteration(task_gen, cfg, iter);
    let budget = samples.iter().map(synth_budget).sum();
    if !samples.is_empty() {
        flow.put_samples(samples)?;
    }
    Ok(budget)
}

/// Pipelined chaos run over the real transfer dock: elastic replica sets
/// of synthetic stage workers under supervisor restart loops, the driver
/// playing the update state (windowed admission, retire-on-ready,
/// lease-clock ticking — and autoscale decisions — on idle passes).
pub fn run_chaos(cfg: &ChaosConfig) -> Result<ChaosOutcome> {
    cfg.plan.validate()?;
    if let Some(ac) = &cfg.autoscale {
        ac.validate()?;
    }
    let flow: Arc<TransferDock> = Arc::new(TransferDock::with_shards(
        DockTopology::spread(cfg.nodes),
        cfg.lease_ticks,
        cfg.dock_shards,
        cfg.steal_threshold,
    ));
    let roster = cfg.roster()?;
    // weighted-fair handout + quotas apply only to the shared run: an
    // isolated-slice run (`tenant_filter`) has nothing to arbitrate
    if roster.is_multi() && cfg.tenant_filter.is_none() {
        flow.set_tenant_weights(&roster.weights());
    }
    let quotas: Option<TenantQuotas> = (cfg.tenant_filter.is_none() && roster.has_quotas())
        .then(|| {
            let q = TenantQuotas::new();
            for s in roster.specs() {
                q.set_quota(s.id, s.quota_bytes);
            }
            q
        });
    let injector: Option<Arc<FaultInjector>> =
        cfg.plan.enabled().then(|| Arc::new(FaultInjector::new(cfg.plan)));
    let shutdown = Arc::new(AtomicBool::new(false));
    let mut task_gen = TaskGenerator::train(cfg.seed);
    let window = cfg.max_inflight_iters.max(1);
    let replicas0 = cfg.initial_replicas();

    let stream_counters = Arc::new(StreamCounters::default());

    let mut retired: BTreeMap<u64, (u64, String, u64)> = BTreeMap::new();
    let mut remaining: BTreeMap<usize, usize> = BTreeMap::new();
    let mut admitted = 0usize;
    let mut completed = 0usize;
    let mut ticks = 0u64;
    let mut budget_steps = 0u64;
    // replica sets + autoscaler outlive the scope so their slot-time
    // accounting closes only after every worker thread has joined
    let mut sets: Vec<ReplicaSet> =
        SCALABLE_STAGES.iter().map(|&s| ReplicaSet::new(s)).collect();
    let mut scaler = cfg.autoscale.map(Autoscaler::new);
    let deadline = Instant::now() + cfg.deadline;

    std::thread::scope(|scope| -> Result<()> {
        // one spawner for every synthetic stage replica; the autoscaler
        // calls it again mid-run (scoped threads may be spawned while
        // the scope is live). The thread flips `exited` on its way out,
        // ending the replica's slot-time accounting.
        let spawn_replica = |stage: Stage,
                             retire: Arc<AtomicBool>,
                             busy_slots: Arc<AtomicUsize>,
                             exited: Arc<AtomicBool>| {
            let flow = Arc::clone(&flow);
            let shutdown = Arc::clone(&shutdown);
            let faults = injector.clone();
            let streaming = cfg.gen_streaming && stage == Stage::Generation;
            let partial = cfg.partial_rollouts;
            let counters = Arc::clone(&stream_counters);
            scope.spawn(move || {
                loop {
                    let exit = if streaming {
                        synthetic_streaming_gen(
                            flow.as_ref(),
                            &retire,
                            &busy_slots,
                            faults.as_deref(),
                            &shutdown,
                            partial,
                            &counters,
                        )
                    } else {
                        synthetic_stage(
                            flow.as_ref(),
                            stage,
                            &retire,
                            &busy_slots,
                            faults.as_deref(),
                            &shutdown,
                        )
                    };
                    match exit {
                        Ok(StageExit::Completed) | Ok(StageExit::Retired) => break,
                        Ok(StageExit::Killed) => {
                            if let Some(inj) = faults.as_deref() {
                                inj.note_restart();
                            }
                            if shutdown.load(Ordering::Relaxed) {
                                break;
                            }
                        }
                        Err(e) => {
                            eprintln!("[chaos] {stage:?} worker failed: {e:#}");
                            shutdown.store(true, Ordering::Relaxed);
                            break;
                        }
                    }
                }
                exited.store(true, Ordering::Release);
            });
        };
        spawn_initial(&mut sets, flow.as_ref(), replicas0, |st, _id, r, b, e| {
            spawn_replica(st, r, b, e)
        });

        // ---- driver: the update state
        let mut drive = |retired: &mut BTreeMap<u64, (u64, String, u64)>,
                     remaining: &mut BTreeMap<usize, usize>,
                     admitted: &mut usize,
                     completed: &mut usize,
                     ticks: &mut u64,
                     budget_steps: &mut u64,
                     sets: &mut Vec<ReplicaSet>,
                     scaler: &mut Option<Autoscaler>|
         -> Result<()> {
            // per-tenant FIFO of quota-deferred samples: an over-quota
            // tenant's admissions park here (order preserved) while its
            // siblings admit freely past it
            let mut deferred: BTreeMap<u32, VecDeque<Sample>> = BTreeMap::new();
            while *completed < cfg.iterations {
                anyhow::ensure!(
                    Instant::now() < deadline,
                    "chaos run wedged: {} of {} samples retired, recovery {:?}",
                    retired.len(),
                    cfg.total_samples(),
                    flow.lease_stats()
                );
                // re-open tenants whose retires cleared the quota: drain
                // each FIFO while the tenant stays under, in park order
                if let Some(q) = &quotas {
                    for (t, queue) in deferred.iter_mut() {
                        let mut batch = Vec::new();
                        while !queue.is_empty() && !q.over_quota(*t) {
                            q.charge_forced(*t, SYNTH_TENANT_BYTES);
                            batch.push(queue.pop_front().expect("checked non-empty"));
                        }
                        if !batch.is_empty() {
                            flow.put_samples(batch)?;
                        }
                    }
                }
                while *admitted < cfg.iterations && *admitted < *completed + window {
                    let samples = build_iteration(&mut task_gen, cfg, *admitted);
                    *budget_steps += samples.iter().map(synth_budget).sum::<u64>();
                    remaining.insert(*admitted, samples.len());
                    if let Some(q) = &quotas {
                        let mut ready = Vec::new();
                        for s in samples {
                            let t = s.tenant;
                            let queued_behind =
                                deferred.get(&t).is_some_and(|d| !d.is_empty());
                            if queued_behind || q.over_quota(t) {
                                q.note_deferral(t);
                                deferred.entry(t).or_default().push_back(s);
                            } else {
                                q.charge_forced(t, SYNTH_TENANT_BYTES);
                                ready.push(s);
                            }
                        }
                        if !ready.is_empty() {
                            flow.put_samples(ready)?;
                        }
                    } else if !samples.is_empty() {
                        flow.put_samples(samples)?;
                    }
                    *admitted += 1;
                }
                // a filtered run's iteration may own zero groups: it
                // completes right here, without ever seeing a retire
                while remaining.get(completed).copied() == Some(0) {
                    remaining.remove(completed);
                    *completed += 1;
                }
                let fresh = flow.wait_ready(Stage::Update, usize::MAX, Duration::from_millis(5))?;
                if fresh.is_empty() {
                    // idle pass: advance logical time so dead claims
                    // expire, and let the autoscaler observe each stage's
                    // backlog + idle ratio at this tick
                    flow.tick_lease_clock();
                    *ticks += 1;
                    if let Some(sc) = scaler.as_mut() {
                        observe_and_scale(sc, sets, flow.as_ref(), *ticks, |st, _id, r, b, e| {
                            spawn_replica(st, r, b, e)
                        });
                    }
                    continue;
                }
                for m in &fresh {
                    let Some(s) = flow.retire(m.index) else { continue };
                    if let Some(q) = &quotas {
                        q.uncharge(s.tenant, SYNTH_TENANT_BYTES);
                    }
                    let dup = retired
                        .insert(s.index, (s.group, s.prompt_text.clone(), s.behavior_version));
                    anyhow::ensure!(dup.is_none(), "sample {} retired twice", s.index);
                    let iter = (s.group as usize) / cfg.prompts_per_iter;
                    let r = remaining
                        .get_mut(&iter)
                        .ok_or_else(|| anyhow::anyhow!("retire for unadmitted iteration {iter}"))?;
                    *r -= 1;
                }
                while remaining.get(completed).copied() == Some(0) {
                    remaining.remove(completed);
                    *completed += 1;
                }
            }
            Ok(())
        };
        let out = drive(
            &mut retired,
            &mut remaining,
            &mut admitted,
            &mut completed,
            &mut ticks,
            &mut budget_steps,
            &mut sets,
            &mut scaler,
        );
        shutdown.store(true, Ordering::Relaxed);
        out
    })?;

    // every worker thread has joined: close the replica accounting
    let scaling = finish_scaling(scaler.take(), &mut sets);

    Ok(ChaosOutcome {
        retired,
        recovery: {
            let mut r = flow.lease_stats();
            if let Some(inj) = &injector {
                r.kills = inj.kills();
                r.stalls = inj.stalls();
                r.restarts = inj.restarts();
            }
            r
        },
        conservation: flow.conservation(),
        resident_after: flow.len(),
        ticks,
        scaling,
        work: DecodeWork {
            decoded_steps: stream_counters.decoded.load(Ordering::Relaxed),
            budget_steps,
            persists: stream_counters.persists.load(Ordering::Relaxed),
            resumes: stream_counters.resumes.load(Ordering::Relaxed),
            saved_steps: stream_counters.saved.load(Ordering::Relaxed),
        },
        tenant_claims: flow.tenant_claims(),
        tenant_deferrals: quotas
            .as_ref()
            .map_or(0, |q| q.snapshot().iter().map(|(_, s)| s.deferrals).sum()),
    })
}

/// Fault-free barrier-per-stage drain of the same seeded workload through
/// the centralized replay buffer — the differential baseline: its retired
/// set must equal any chaos run's.
pub fn run_baseline(cfg: &ChaosConfig) -> Result<ChaosOutcome> {
    let flow = ReplayBuffer::with_lease(0, cfg.lease_ticks);
    let mut task_gen = TaskGenerator::train(cfg.seed);
    let mut retired: BTreeMap<u64, (u64, String, u64)> = BTreeMap::new();
    for iter in 0..cfg.iterations {
        let _budget = admit_iteration(&flow, &mut task_gen, cfg, iter)?;
        // barrier per stage, like the sync executor
        for stage in [Stage::Generation, Stage::OldLogprob, Stage::RefLogprob, Stage::Reward] {
            loop {
                let metas = flow.request_ready(stage, 16)?;
                if metas.is_empty() {
                    break;
                }
                let samples = flow.fetch(0, &metas)?;
                for s in &samples {
                    match stage {
                        Stage::Generation => {
                            let (fields, completion, resp_len, stamp) = synth_generation(s);
                            flow.store_generation(
                                0, s.index, fields, completion, resp_len, stamp,
                            )?;
                        }
                        Stage::OldLogprob => flow.store_fields(
                            0,
                            s.index,
                            vec![(FieldKind::OldLp, Tensor::zeros(&[7]))],
                        )?,
                        Stage::RefLogprob => flow.store_fields(
                            0,
                            s.index,
                            vec![(FieldKind::RefLp, Tensor::zeros(&[7]))],
                        )?,
                        Stage::Reward => flow.store_fields(
                            0,
                            s.index,
                            vec![(FieldKind::Reward, Tensor::scalar_f32(1.0))],
                        )?,
                        Stage::Update => unreachable!(),
                    }
                }
            }
        }
        for m in flow.request_ready(Stage::Update, usize::MAX)? {
            let s = flow.retire(m.index).expect("update-ready sample must be resident");
            retired.insert(s.index, (s.group, s.prompt_text, s.behavior_version));
        }
    }
    Ok(ChaosOutcome {
        retired,
        recovery: flow.lease_stats(),
        conservation: vec![flow.conservation()],
        resident_after: flow.len(),
        ticks: 0,
        scaling: StageScaling::default(),
        work: DecodeWork::default(),
        tenant_claims: flow.tenant_claims(),
        tenant_deferrals: 0,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fault_free_chaos_matches_baseline() {
        // long lease: a fault-free run must not reclaim even under a
        // noisy CI scheduler
        let cfg = ChaosConfig { lease_ticks: 256, ..Default::default() };
        let a = run_chaos(&cfg).unwrap();
        let b = run_baseline(&cfg).unwrap();
        assert!(a.lossless(&cfg));
        assert!(b.lossless(&cfg));
        assert_eq!(a.retired, b.retired, "dataflows must retire identical sample sets");
        assert_eq!(a.recovery.reclaimed, 0, "fault-free run must not reclaim");
    }

    #[test]
    fn replicated_stages_match_baseline() {
        // gen=4,logprob=2 replicas, fault-free: the retired set AND the
        // per-sample stamps must equal the single-replica baseline's
        let cfg = ChaosConfig {
            lease_ticks: 256,
            stage_replicas: Some(StageReplicas::parse("gen=4,logprob=2").unwrap()),
            ..Default::default()
        };
        let a = run_chaos(&cfg).unwrap();
        let b = run_baseline(&cfg).unwrap();
        assert!(a.lossless(&cfg));
        assert_eq!(a.retired, b.retired, "replicas changed the retired set or stamps");
        assert_eq!(a.recovery.reclaimed, 0, "fault-free replicas must never reclaim");
        assert_eq!(a.scaling.stages["generation"].initial, 4);
        assert_eq!(a.scaling.stages["old_logprob"].initial, 2);
    }

    #[test]
    fn streaming_generation_matches_baseline() {
        // fault-free streaming drain: per-sequence retirement and
        // step-granularity admission must not change the retired set,
        // the stamps, or the conservation ledger
        let cfg =
            ChaosConfig { lease_ticks: 256, gen_streaming: true, ..Default::default() };
        let a = run_chaos(&cfg).unwrap();
        let b = run_baseline(&cfg).unwrap();
        assert!(a.lossless(&cfg));
        assert_eq!(a.retired, b.retired, "streaming changed the retired set or stamps");
        assert_eq!(a.recovery.reclaimed, 0, "fault-free streaming must not reclaim");
    }

    #[test]
    fn sharded_dock_matches_baseline() {
        // fault-free K=4 with aggressive stealing: hash partitioning and
        // cross-shard steals must not change the retired set or stamps
        // (the heavyweight K × faults × streaming sweep lives in
        // tests/sharded_dock.rs)
        let cfg = ChaosConfig {
            lease_ticks: 256,
            dock_shards: 4,
            steal_threshold: 1,
            workers_per_stage: 2,
            ..Default::default()
        };
        let a = run_chaos(&cfg).unwrap();
        let b = run_baseline(&cfg).unwrap();
        assert!(a.lossless(&cfg), "{:?}", a.recovery);
        assert_eq!(a.retired, b.retired, "sharding changed the retired set or stamps");
        assert_eq!(a.recovery.reclaimed, 0, "fault-free sharded run must not reclaim");
    }

    #[test]
    fn kills_recover_losslessly() {
        // a rate this aggressive fires across the run's claim events no
        // matter how the scheduler batches claims
        let cfg = ChaosConfig {
            iterations: 5,
            plan: FaultPlan { seed: 5, kill_rate: 0.4, ..Default::default() },
            ..Default::default()
        };
        let out = run_chaos(&cfg).unwrap();
        assert!(out.lossless(&cfg), "{:?}", out.recovery);
        assert!(out.recovery.kills > 0, "plan must actually fire: {:?}", out.recovery);
        assert!(out.recovery.reclaimed > 0, "kills must surface as reclaims");
        assert!(out.recovery.redispatched > 0);
        assert_eq!(out.recovery.restarts, out.recovery.kills);
    }

    #[test]
    fn fault_free_partial_rollouts_decode_exactly_the_budget() {
        // no faults: checkpoints are written but never consumed — the
        // retired set, the stamps, and the decode-work ledger must all
        // be indistinguishable from an uninterrupted run
        let cfg = ChaosConfig {
            lease_ticks: 256,
            gen_streaming: true,
            partial_rollouts: true,
            ..Default::default()
        };
        let a = run_chaos(&cfg).unwrap();
        let b = run_baseline(&cfg).unwrap();
        assert!(a.lossless(&cfg));
        assert_eq!(a.retired, b.retired, "partial rollouts changed the retired set or stamps");
        assert_eq!(a.recovery.reclaimed, 0, "fault-free run must not reclaim");
        assert_eq!(
            a.work.decoded_steps, a.work.budget_steps,
            "no abandonment means no recompute: {:?}",
            a.work
        );
        assert!(a.work.persists > 0, "checkpoint cadence must fire: {:?}", a.work);
        assert_eq!(a.work.resumes, 0, "nothing was abandoned, nothing may resume");
    }

    #[test]
    fn partial_rollout_kills_bound_the_recompute() {
        // the upgraded differential: zero-loss AND bounded-recompute. A
        // kill-only plan models clean abandonment — the dying worker's
        // kill path persists every held prefix, so a resumer replays at
        // most the steps decoded since that sequence's last persisted
        // segment (< SYNTH_CKPT_STEPS each). Stall zombies are excluded
        // here on purpose: a zombie keeps decoding sequences its twin
        // already resumed, which duplicates work outside any checkpoint
        // bound (that path is covered by the zero-loss stall test).
        let cfg = ChaosConfig {
            iterations: 5,
            gen_streaming: true,
            partial_rollouts: true,
            plan: FaultPlan { seed: 7, kill_rate: 0.4, ..Default::default() },
            ..Default::default()
        };
        let out = run_chaos(&cfg).unwrap();
        let base = run_baseline(&cfg).unwrap();
        assert!(out.lossless(&cfg), "{:?}", out.recovery);
        assert_eq!(out.retired, base.retired, "resumes changed the retired set or stamps");
        assert!(out.recovery.kills > 0, "plan must actually fire: {:?}", out.recovery);
        assert!(out.work.persists > 0, "kills must persist prefixes: {:?}", out.work);
        assert!(out.work.resumes > 0, "reclaimed prefixes must resume: {:?}", out.work);
        assert!(out.work.saved_steps > 0, "resumes must skip persisted work: {:?}", out.work);
        assert!(
            out.work.recomputed_steps() <= out.recovery.reclaimed * SYNTH_CKPT_STEPS,
            "recompute {} exceeds the checkpoint bound (reclaimed={}, cadence={}): {:?}",
            out.work.recomputed_steps(),
            out.recovery.reclaimed,
            SYNTH_CKPT_STEPS,
            out.work
        );
    }

    #[test]
    fn streaming_stalls_surface_superseded_not_loss() {
        // the FlowRecovery contract under streaming chaos: a stalled
        // worker outlives its lease, its held sequences are reclaimed
        // and resumed by the twin replica, and the zombie's late
        // writebacks land as superseded duplicates — every reclaim
        // bumps the attempt counter exactly once, redispatches never
        // exceed reclaims, and the retired set is still byte-identical
        // to the baseline's
        let cfg = ChaosConfig {
            iterations: 4,
            gen_streaming: true,
            partial_rollouts: true,
            workers_per_stage: 2,
            lease_ticks: 2,
            plan: FaultPlan {
                seed: 11,
                stall_rate: 0.3,
                stall_ticks: 6,
                ..Default::default()
            },
            ..Default::default()
        };
        let out = run_chaos(&cfg).unwrap();
        let base = run_baseline(&cfg).unwrap();
        assert!(out.lossless(&cfg), "{:?}", out.recovery);
        assert_eq!(out.retired, base.retired, "stall recovery changed the retired set");
        assert!(out.recovery.stalls > 0, "plan must actually fire: {:?}", out.recovery);
        assert!(out.recovery.reclaimed > 0, "stalls past the lease must reclaim");
        assert_eq!(
            out.recovery.reclaimed, out.recovery.attempt_bumps,
            "every reclaim must bump the attempt counter exactly once: {:?}",
            out.recovery
        );
        assert!(out.recovery.redispatched <= out.recovery.reclaimed);
        assert!(
            out.recovery.superseded_writebacks > 0,
            "the zombie's late writebacks must surface as superseded, not as loss \
             or duplication: {:?}",
            out.recovery
        );
    }

    /// Per-tenant view of a retired map: group → (members, prompt,
    /// stamp). Indices shift between shared and isolated runs (admission
    /// order assigns them), so the oracle compares group-keyed views.
    fn tenant_view(
        out: &ChaosOutcome,
        cfg: &ChaosConfig,
        tenant: u32,
    ) -> BTreeMap<u64, (usize, String, u64)> {
        let mut view: BTreeMap<u64, (usize, String, u64)> = BTreeMap::new();
        for (group, prompt, stamp) in out.retired.values() {
            if cfg.tenant_of_group(*group) != tenant {
                continue;
            }
            let e = view.entry(*group).or_insert_with(|| (0, prompt.clone(), *stamp));
            e.0 += 1;
            assert_eq!(&e.1, prompt, "group {group} members disagree on the prompt");
            assert_eq!(e.2, *stamp, "group {group} members disagree on the stamp");
        }
        view
    }

    #[test]
    fn multi_tenant_striping_matches_isolated_slices() {
        // the multi-tenant differential in miniature (the weight × quota
        // × faults × K sweep lives in tests/multi_tenant.rs): each
        // tenant's slice of a shared weighted run must equal the run
        // that admits only that tenant's groups
        let shared = ChaosConfig {
            lease_ticks: 256,
            tenants: 2,
            tenant_weights: vec![3, 1],
            ..Default::default()
        };
        let out = run_chaos(&shared).unwrap();
        assert!(out.lossless(&shared), "{:?}", out.recovery);
        assert!(!out.tenant_claims.is_empty(), "multi-tenant run must count claims");
        for t in 0..2 {
            let iso_cfg = ChaosConfig { tenant_filter: Some(t), ..shared.clone() };
            let iso = run_chaos(&iso_cfg).unwrap();
            assert!(iso.lossless(&iso_cfg), "{:?}", iso.recovery);
            assert_eq!(
                tenant_view(&out, &shared, t),
                tenant_view(&iso, &iso_cfg, t),
                "tenant {t}'s shared-run slice must equal its isolated run"
            );
        }
    }

    #[test]
    fn tenant_quota_defers_without_loss() {
        // a window wide enough to outrun the 1 MiB (= 16-sample) quotas:
        // admissions must park in the per-tenant FIFO and re-admit as
        // retires uncharge — reordering admission, never the outcome
        let cfg = ChaosConfig {
            iterations: 8,
            max_inflight_iters: 8,
            lease_ticks: 256,
            tenants: 2,
            tenant_quota_mb: vec![1, 1],
            ..Default::default()
        };
        let out = run_chaos(&cfg).unwrap();
        assert!(out.lossless(&cfg), "{:?}", out.recovery);
        assert!(out.tenant_deferrals > 0, "quota pressure must actually defer");
        let free = ChaosConfig { tenant_quota_mb: Vec::new(), ..cfg.clone() };
        let base = run_chaos(&free).unwrap();
        assert!(base.lossless(&free), "{:?}", base.recovery);
        for t in 0..2 {
            assert_eq!(
                tenant_view(&out, &cfg, t),
                tenant_view(&base, &free, t),
                "tenant {t}'s quota-deferred run diverged from the unquota'd run"
            );
        }
    }
}
