//! Roofline cost model: stage times from FLOPs/bytes of real model
//! configs under real parallel layouts.

use crate::parallel::ParallelLayout;

/// The models of the paper's evaluation, with their public configs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PaperModel {
    Qwen25Dense7B,
    Qwen25Dense32B,
    Qwen3Moe30B,
    DeepSeekR1Moe671B,
}

impl PaperModel {
    pub fn name(&self) -> &'static str {
        match self {
            PaperModel::Qwen25Dense7B => "Qwen2.5-Dense-7B",
            PaperModel::Qwen25Dense32B => "Qwen2.5-Dense-32B",
            PaperModel::Qwen3Moe30B => "Qwen3-MoE-30B",
            PaperModel::DeepSeekR1Moe671B => "DeepSeek-R1-MoE-671B",
        }
    }

    /// Total parameter count.
    pub fn params(&self) -> f64 {
        match self {
            PaperModel::Qwen25Dense7B => 7.6e9,
            PaperModel::Qwen25Dense32B => 32.8e9,
            PaperModel::Qwen3Moe30B => 30.5e9,
            PaperModel::DeepSeekR1Moe671B => 671e9,
        }
    }

    /// Activated parameters per token (== total for dense).
    pub fn active_params(&self) -> f64 {
        match self {
            PaperModel::Qwen25Dense7B => 7.6e9,
            PaperModel::Qwen25Dense32B => 32.8e9,
            PaperModel::Qwen3Moe30B => 3.3e9,
            PaperModel::DeepSeekR1Moe671B => 37e9,
        }
    }

    pub fn n_layers(&self) -> usize {
        match self {
            PaperModel::Qwen25Dense7B => 28,
            PaperModel::Qwen25Dense32B => 64,
            PaperModel::Qwen3Moe30B => 48,
            PaperModel::DeepSeekR1Moe671B => 61,
        }
    }

    /// KV-cache bytes per token (bf16, GQA/MLA head counts from the
    /// public configs).
    pub fn kv_bytes_per_token(&self) -> f64 {
        match self {
            // 28 layers × 4 kv heads × 128 dim × 2 (k,v) × 2 bytes
            PaperModel::Qwen25Dense7B => 28.0 * 4.0 * 128.0 * 2.0 * 2.0,
            PaperModel::Qwen25Dense32B => 64.0 * 8.0 * 128.0 * 2.0 * 2.0,
            PaperModel::Qwen3Moe30B => 48.0 * 4.0 * 128.0 * 2.0 * 2.0,
            // MLA compressed cache: 61 layers × (512+64) dim × 2 bytes
            PaperModel::DeepSeekR1Moe671B => 61.0 * 576.0 * 2.0,
        }
    }

    pub fn is_moe(&self) -> bool {
        matches!(self, PaperModel::Qwen3Moe30B | PaperModel::DeepSeekR1Moe671B)
    }

    /// Weight bytes (bf16).
    pub fn weight_bytes(&self) -> f64 {
        self.params() * 2.0
    }
}

/// One accelerator (paper: Ascend 910-class, 128 GB).
#[derive(Debug, Clone, Copy)]
pub struct DeviceSpec {
    /// peak dense bf16 FLOP/s
    pub peak_flops: f64,
    /// HBM bandwidth bytes/s
    pub hbm_bps: f64,
    /// device memory bytes
    pub mem_bytes: f64,
}

impl DeviceSpec {
    /// The paper's NPU (Ascend 910B-class public figures).
    pub fn ascend_128gb() -> Self {
        Self { peak_flops: 376e12, hbm_bps: 1.6e12, mem_bytes: 128e9 }
    }
}

#[derive(Debug, Clone, Copy)]
pub struct ClusterSpec {
    pub nodes: usize,
    pub devices_per_node: usize,
    pub device: DeviceSpec,
    /// inter-node bytes/s (paper: 300 MB/s)
    pub inter_node_bps: f64,
    /// host↔device bytes/s (paper: 50 GB/s)
    pub host_device_bps: f64,
}

impl ClusterSpec {
    pub fn paper(nodes: usize) -> Self {
        Self {
            nodes,
            devices_per_node: 8,
            device: DeviceSpec::ascend_128gb(),
            inter_node_bps: 300e6,
            host_device_bps: 50e9,
        }
    }

    pub fn world(&self) -> usize {
        self.nodes * self.devices_per_node
    }
}

/// RL workload hyperparameters (Eq. 5 inputs).
#[derive(Debug, Clone, Copy)]
pub struct RlWorkload {
    pub g: u64,
    pub n_resp: u64,
    pub pl: u64,
    pub sl: u64,
}

impl RlWorkload {
    pub fn tokens_per_iter(&self) -> f64 {
        (self.g * self.n_resp) as f64 * (self.pl + self.sl) as f64
    }

    pub fn sequences(&self) -> u64 {
        self.g * self.n_resp
    }
}

/// Per-stage seconds for one iteration.
#[derive(Debug, Clone, Copy, Default)]
pub struct StageTimes {
    pub generation: f64,
    pub inference: f64,
    pub update: f64,
    pub dispatch: f64,
    pub reshard: f64,
}

impl StageTimes {
    pub fn total(&self) -> f64 {
        self.generation + self.inference + self.update + self.dispatch + self.reshard
    }
}

/// Compute-stage roofline times. `mfu` / `gen_eff` are the calibrated
/// per-system efficiency constants (DESIGN.md §Calibration).
pub struct Roofline<'a> {
    pub model: PaperModel,
    pub cluster: &'a ClusterSpec,
    pub work: RlWorkload,
    pub gen_layout: ParallelLayout,
}

impl<'a> Roofline<'a> {
    /// Generation time: SL batched decode steps per replica, each step
    /// max(compute, weight-streaming) bound; `max_batch` is the KV-budget
    /// cap on concurrent sequences per replica. `hbm_eff` is the decode
    /// kernel's achieved fraction of HBM bandwidth (paged-KV quality).
    pub fn generation_secs(&self, gen_eff: f64, hbm_eff: f64, kv_free_bytes_per_dev: f64) -> f64 {
        let replicas = self.gen_layout.dp.max(1) as f64;
        let devs_per_replica = (self.cluster.world() as f64 / replicas).max(1.0);
        let seqs_per_replica = self.work.sequences() as f64 / replicas;
        // KV budget caps concurrency
        let kv_per_seq =
            self.model.kv_bytes_per_token() * (self.work.pl + self.work.sl) as f64;
        let kv_budget = kv_free_bytes_per_dev * devs_per_replica;
        let max_batch = (kv_budget / kv_per_seq).max(1.0);
        // wave-balanced batch: given the cap, run the fewest waves and
        // split sequences evenly across them
        let waves = (seqs_per_replica / max_batch.min(seqs_per_replica)).ceil();
        let batch = seqs_per_replica / waves;

        // one decode step for `batch` sequences on one replica
        let flops = 2.0 * self.model.active_params() * batch;
        let t_compute =
            flops / (devs_per_replica * self.cluster.device.peak_flops * gen_eff);
        // memory traffic per step: weights streamed once (amortized over
        // the batch — the reason KV headroom and therefore batch size
        // matters) plus each sequence's KV history read once
        let avg_ctx = (self.work.pl as f64) + (self.work.sl as f64) / 2.0;
        let kv_read = batch * self.model.kv_bytes_per_token() * avg_ctx;
        let t_memory = (self.model.weight_bytes() + kv_read)
            / (devs_per_replica * self.cluster.device.hbm_bps * hbm_eff);
        // MoE all-to-all per layer adds latency on the scale-out path
        let moe_factor = if self.model.is_moe() { 1.35 } else { 1.0 };
        let t_step = t_compute.max(t_memory) * moe_factor;
        // prefill: one forward over PL tokens per sequence (compute-bound)
        let prefill_flops =
            2.0 * self.model.active_params() * self.work.pl as f64 * seqs_per_replica;
        let t_prefill =
            prefill_flops / (devs_per_replica * self.cluster.device.peak_flops * gen_eff);
        waves * self.work.sl as f64 * t_step + t_prefill
    }

    /// Inference stage (reference + old-logprob forward passes).
    pub fn inference_secs(&self, mfu: f64, n_passes: f64) -> f64 {
        let flops = n_passes * 2.0 * self.model.active_params() * self.work.tokens_per_iter();
        flops / (self.cluster.world() as f64 * self.cluster.device.peak_flops * mfu)
    }

    /// Update stage (fwd+bwd ≈ 3× forward; response tokens only carry
    /// gradient but the full sequence is processed).
    pub fn update_secs(&self, mfu: f64) -> f64 {
        let flops = 6.0 * self.model.active_params() * self.work.tokens_per_iter();
        flops / (self.cluster.world() as f64 * self.cluster.device.peak_flops * mfu)
    }
}

// ------------------------------------------------ token-level decode
//
// The wave model above ([`Roofline::generation_secs`]) prices generation
// as SL identical full-batch steps — the right granularity for Fig. 7/9,
// where every response runs to the SL cap. It cannot see what continuous
// batching changes: with a *distribution* of response lengths, a batch
// engine's wave runs until its longest member finishes while freed slots
// sit idle, yet every step still streams the full weights. The
// step-by-step model below prices each decode step from its actual live
// lane count and KV context, so batch-decode and streaming admission
// policies become comparable on the same workload.

/// Decode workload of one sequence for the token-level model.
#[derive(Debug, Clone, Copy)]
pub struct SeqSpec {
    pub prompt: u64,
    pub resp: u64,
}

/// Deterministic long-tail (exponential) response lengths in `[1, cap]`
/// — the CoT rollout regime where a few stragglers dominate each wave.
pub fn long_tail_lengths(n: usize, mean: f64, cap: u64, seed: u64) -> Vec<u64> {
    let mut rng = crate::util::rng::Rng::new(seed);
    (0..n)
        .map(|_| {
            let u = rng.f64().max(1e-12);
            ((-u.ln() * mean) as u64).clamp(1, cap)
        })
        .collect()
}

/// Outcome of one token-level decode simulation. Occupancy is carried as
/// raw slot-step counters, the same contract as the real scheduler's
/// `StreamStats`.
#[derive(Debug, Clone, Copy, Default)]
pub struct GenSim {
    pub secs: f64,
    pub steps: u64,
    pub busy_slot_steps: u64,
    pub total_slot_steps: u64,
    pub tokens: u64,
}

impl GenSim {
    pub fn occupancy(&self) -> f64 {
        if self.total_slot_steps == 0 {
            0.0
        } else {
            self.busy_slot_steps as f64 / self.total_slot_steps as f64
        }
    }

    /// Generated tokens per second of modeled generation time.
    pub fn tps(&self) -> f64 {
        self.tokens as f64 / self.secs.max(1e-12)
    }
}

/// Token-level decode cost model: prices every decode step individually
/// from its live lane count and summed KV context, then runs a whole
/// workload under either admission policy.
pub struct TokenGenModel {
    pub model: PaperModel,
    pub device: DeviceSpec,
    /// concurrent decode lanes (the engine's batch dimension)
    pub slots: usize,
    /// achieved fraction of peak FLOP/s during decode
    pub gen_eff: f64,
    /// achieved fraction of HBM bandwidth (paged-KV kernel quality)
    pub hbm_eff: f64,
}

impl TokenGenModel {
    /// The paper's device with the calibration constants the wave model
    /// uses (DESIGN.md §Calibration).
    pub fn paper_decode(slots: usize) -> Self {
        Self {
            model: PaperModel::Qwen25Dense7B,
            device: DeviceSpec::ascend_128gb(),
            slots,
            gen_eff: 0.5,
            hbm_eff: 0.8,
        }
    }

    /// One decode step with `active` live lanes whose contexts sum to
    /// `ctx_tokens`: max(compute, HBM), with the full weight stream paid
    /// once per step no matter how few lanes are live — the cost idle
    /// slots waste and full slots amortize.
    fn step_secs(&self, active: usize, ctx_tokens: u64) -> f64 {
        if active == 0 {
            return 0.0;
        }
        let t_compute = 2.0 * self.model.active_params() * active as f64
            / (self.device.peak_flops * self.gen_eff);
        let kv_read = self.model.kv_bytes_per_token() * ctx_tokens as f64;
        let t_memory =
            (self.model.weight_bytes() + kv_read) / (self.device.hbm_bps * self.hbm_eff);
        t_compute.max(t_memory)
    }

    /// Prefill: one compute-bound pass over every prompt token. The same
    /// total under either admission policy, so the policies differ purely
    /// in decode occupancy.
    fn prefill_secs(&self, seqs: &[SeqSpec]) -> f64 {
        let toks: u64 = seqs.iter().map(|s| s.prompt).sum();
        2.0 * self.model.active_params() * toks as f64
            / (self.device.peak_flops * self.gen_eff)
    }

    /// Batch-decode baseline: sequences run in admission-order waves of
    /// `slots`; a wave ends only when its longest member finishes, so the
    /// long tail holds every freed slot idle until the next wave.
    pub fn batch_decode(&self, seqs: &[SeqSpec]) -> GenSim {
        let mut sim = GenSim::default();
        for wave in seqs.chunks(self.slots) {
            let wave_len = wave.iter().map(|s| s.resp).max().unwrap_or(0);
            for t in 0..wave_len {
                let mut active = 0usize;
                let mut ctx = 0u64;
                for s in wave {
                    if s.resp > t {
                        active += 1;
                        ctx += s.prompt + t;
                    }
                }
                sim.secs += self.step_secs(active, ctx);
                sim.steps += 1;
                sim.busy_slot_steps += active as u64;
                sim.total_slot_steps += self.slots as u64;
            }
        }
        sim.tokens = seqs.iter().map(|s| s.resp).sum();
        sim.secs += self.prefill_secs(seqs);
        sim
    }

    /// Continuous batching: a lane that retires its sequence admits the
    /// next queued one on the following step (the [`GenSession`] policy:
    /// per-sequence retirement + step-granularity admission).
    ///
    /// [`GenSession`]: crate::generation::GenSession
    pub fn continuous(&self, seqs: &[SeqSpec]) -> GenSim {
        let mut sim = GenSim::default();
        let mut queue: std::collections::VecDeque<SeqSpec> =
            seqs.iter().copied().collect();
        // (prompt, generated, resp) per lane
        let mut lanes: Vec<Option<(u64, u64, u64)>> = vec![None; self.slots];
        loop {
            for lane in lanes.iter_mut() {
                if lane.is_none() {
                    if let Some(s) = queue.pop_front() {
                        *lane = Some((s.prompt, 0, s.resp));
                    }
                }
            }
            let active = lanes.iter().flatten().count();
            if active == 0 {
                break;
            }
            let ctx: u64 = lanes.iter().flatten().map(|&(p, g, _)| p + g).sum();
            sim.secs += self.step_secs(active, ctx);
            sim.steps += 1;
            sim.busy_slot_steps += active as u64;
            sim.total_slot_steps += self.slots as u64;
            for lane in lanes.iter_mut() {
                let done = match lane.as_mut() {
                    Some((_, g, r)) => {
                        *g += 1;
                        *g >= *r
                    }
                    None => false,
                };
                if done {
                    *lane = None;
                }
            }
        }
        sim.tokens = seqs.iter().map(|s| s.resp).sum();
        sim.secs += self.prefill_secs(seqs);
        sim
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_model_configs_sane() {
        assert!(PaperModel::DeepSeekR1Moe671B.params() > 600e9);
        assert!(PaperModel::Qwen3Moe30B.active_params() < PaperModel::Qwen3Moe30B.params());
        assert!(!PaperModel::Qwen25Dense7B.is_moe());
        // MLA cache is far smaller per token than GQA at this scale
        assert!(
            PaperModel::DeepSeekR1Moe671B.kv_bytes_per_token()
                < PaperModel::Qwen25Dense32B.kv_bytes_per_token()
        );
    }

    #[test]
    fn update_dominates_inference_per_pass() {
        let cluster = ClusterSpec::paper(2);
        let work = RlWorkload { g: 256, n_resp: 16, pl: 2048, sl: 8192 };
        let r = Roofline {
            model: PaperModel::Qwen25Dense7B,
            cluster: &cluster,
            work,
            gen_layout: ParallelLayout::dense(2, 1, 8),
        };
        assert!(r.update_secs(0.35) > r.inference_secs(0.35, 1.0));
        // 3× forward cost ratio
        let ratio = r.update_secs(0.35) / r.inference_secs(0.35, 1.0);
        assert!((ratio - 3.0).abs() < 1e-9);
    }

    #[test]
    fn token_model_conserves_work_across_policies() {
        let lengths = long_tail_lengths(128, 256.0, 4096, 7);
        let seqs: Vec<SeqSpec> =
            lengths.iter().map(|&l| SeqSpec { prompt: 256, resp: l }).collect();
        let m = TokenGenModel::paper_decode(32);
        let b = m.batch_decode(&seqs);
        let s = m.continuous(&seqs);
        // both policies decode exactly the workload's tokens
        let total: u64 = lengths.iter().sum();
        assert_eq!(b.tokens, total);
        assert_eq!(s.tokens, total);
        assert_eq!(b.busy_slot_steps, total, "every busy slot-step emits one token");
        assert_eq!(s.busy_slot_steps, total);
        assert!(b.busy_slot_steps <= b.total_slot_steps);
        assert!(s.busy_slot_steps <= s.total_slot_steps);
    }

    #[test]
    fn continuous_batching_beats_batch_decode_on_long_tail() {
        let lengths = long_tail_lengths(256, 512.0, 8192, 0);
        let seqs: Vec<SeqSpec> =
            lengths.iter().map(|&l| SeqSpec { prompt: 512, resp: l }).collect();
        let m = TokenGenModel::paper_decode(32);
        let b = m.batch_decode(&seqs);
        let s = m.continuous(&seqs);
        // immediate refill needs strictly fewer steps than waves, which
        // is strictly less weight-streaming time
        assert!(s.steps < b.steps, "steps {} !< {}", s.steps, b.steps);
        assert!(s.secs < b.secs, "secs {} !< {}", s.secs, b.secs);
        assert!(s.tps() > b.tps());
        assert!(s.occupancy() > b.occupancy());
        assert!(s.occupancy() > 0.9, "streaming occupancy {}", s.occupancy());
    }

    #[test]
    fn uniform_lengths_erase_the_streaming_advantage() {
        // with no tail there is nothing to reclaim: both policies run the
        // same full waves (up to the final partial one)
        let seqs: Vec<SeqSpec> =
            (0..64).map(|_| SeqSpec { prompt: 128, resp: 100 }).collect();
        let m = TokenGenModel::paper_decode(32);
        let b = m.batch_decode(&seqs);
        let s = m.continuous(&seqs);
        assert_eq!(s.steps, b.steps);
        assert!((s.secs - b.secs).abs() < 1e-9);
    }

    #[test]
    fn kv_cap_slows_generation() {
        let cluster = ClusterSpec::paper(2);
        let work = RlWorkload { g: 256, n_resp: 16, pl: 2048, sl: 8192 };
        let r = Roofline {
            model: PaperModel::Qwen25Dense7B,
            cluster: &cluster,
            work,
            gen_layout: ParallelLayout::dense(2, 1, 8),
        };
        let plenty = r.generation_secs(0.5, 0.8, 64e9);
        let tight = r.generation_secs(0.5, 0.8, 4e9);
        assert!(tight > plenty, "less KV headroom must mean slower generation");
    }
}
