//! Named experiment runners: each regenerates one of the paper's tables
//! or figures and prints it in the paper's row/series format.

use anyhow::Result;

use crate::parallel::ParallelLayout;
use crate::runtime::Tensor;
use crate::transfer_dock::volume::{self, VolumeParams};
use crate::transfer_dock::{
    DockTopology, FieldKind, NetworkModel, ReplayBuffer, Sample, SampleFlow, Stage, TransferDock,
};
use crate::util::bench::Table;

use super::costmodel::{
    long_tail_lengths, ClusterSpec, PaperModel, RlWorkload, SeqSpec, TokenGenModel,
};
use super::systems::{SystemKind, SystemModel};

// ------------------------------------------------------------- Table 1
#[derive(Debug, Clone)]
pub struct Table1Row {
    pub params: VolumeParams,
    pub tcv_gb: f64,
    pub t100_s: f64,
    pub t1k_s: f64,
}

pub fn table1_rows_out() -> Vec<Table1Row> {
    volume::table1_rows()
        .into_iter()
        .map(|p| {
            let v = volume::tcv_gb(&p);
            Table1Row {
                params: p,
                tcv_gb: v,
                t100_s: volume::dispatch_secs(v, 100e6),
                t1k_s: volume::dispatch_secs(v, 1e9),
            }
        })
        .collect()
}

// ------------------------------------------------------------- Fig. 7
#[derive(Debug, Clone)]
pub struct Fig7Row {
    pub model: PaperModel,
    pub system: SystemKind,
    pub tps: f64,
    pub speedup_vs_openrlhf: f64,
}

/// Fig. 7 configuration: 16 NPUs, G=256, N=16, PL=2K, SL=8K.
pub fn fig7_rows() -> Vec<Fig7Row> {
    let cluster = ClusterSpec::paper(2);
    let work = RlWorkload { g: 256, n_resp: 16, pl: 2048, sl: 8192 };
    let mut rows = Vec::new();
    for model in [
        PaperModel::Qwen25Dense7B,
        PaperModel::Qwen25Dense32B,
        PaperModel::Qwen3Moe30B,
    ] {
        let base = SystemModel::new(SystemKind::OpenRlhf, model, cluster, work)
            .throughput_tps();
        for kind in [
            SystemKind::OpenRlhf,
            SystemKind::Verl,
            SystemKind::Msrlp,
            SystemKind::Msrl,
        ] {
            let tps = SystemModel::new(kind, model, cluster, work).throughput_tps();
            rows.push(Fig7Row { model, system: kind, tps, speedup_vs_openrlhf: tps / base });
        }
    }
    rows
}

// ------------------------------------------------------------- Fig. 9
#[derive(Debug, Clone)]
pub struct Fig9Row {
    pub system: SystemKind,
    pub nodes: usize,
    pub npus: usize,
    pub tps_per_device: f64,
    /// weak-scaling linearity vs the smallest cluster
    pub linearity: f64,
}

/// Fig. 9 configuration: 64 prompts per node, N=16, PL=2K, SL=8K,
/// Qwen2.5-7B; nodes swept 2 → 24 (16 → 192 NPUs).
pub fn fig9_rows() -> Vec<Fig9Row> {
    let mut rows = Vec::new();
    let node_sweep = [2usize, 4, 8, 12, 16, 24];
    for kind in [SystemKind::Verl, SystemKind::Msrlb, SystemKind::Msrl] {
        let mut base_tpd = None;
        for &nodes in &node_sweep {
            let cluster = ClusterSpec::paper(nodes);
            let work =
                RlWorkload { g: 64 * nodes as u64, n_resp: 16, pl: 2048, sl: 8192 };
            let sys = SystemModel::new(kind, PaperModel::Qwen25Dense7B, cluster, work);
            let tpd = sys.throughput_tps();
            let base = *base_tpd.get_or_insert(tpd);
            rows.push(Fig9Row {
                system: kind,
                nodes,
                npus: cluster.world(),
                tps_per_device: tpd,
                linearity: tpd / base,
            });
        }
    }
    rows
}

// ------------------------------------------------------------- Fig. 11
/// Fig. 11: DeepSeek-R1-671B on 384 NPUs, G=384, N=32, PL=1K, SL=2K,
/// update TP4PP6EP16DP2 → generation TP2PP1EP64DP6 (EP adapted to the
/// grid rule, see parallel::layout tests). Returns per-iteration TPS for
/// `iters` iterations with the simulator's response-length jitter.
pub fn fig11_series(iters: usize, seed: u64) -> Vec<(usize, f64)> {
    let cluster = ClusterSpec::paper(48);
    let mut rng = crate::util::rng::Rng::new(seed);
    let mut out = Vec::with_capacity(iters);
    for i in 0..iters {
        // response length varies per iteration (sampling); SL is the cap
        let sl = (1200.0 + 800.0 * rng.f64()) as u64;
        let work = RlWorkload { g: 384, n_resp: 32, pl: 1024, sl };
        let mut sys = SystemModel::new(
            SystemKind::Msrl,
            PaperModel::DeepSeekR1Moe671B,
            cluster,
            work,
        );
        sys.update_layout = ParallelLayout { tp: 4, pp: 6, dp: 2, ep: 8, cp: 1 };
        // EP adapted to the grid rule (ep | tp*dp*cp): the paper's EP32
        // doesn't divide the 12-way non-PP grid, EP12 is the adapted pick
        sys.gen_layout = ParallelLayout { tp: 2, pp: 1, dp: 6, ep: 12, cp: 1 };
        // Eq. 5 reports against the nominal PL+SL budget
        let t = sys.iteration().total();
        let tps = crate::metrics::throughput_tps(384, 32, 1024, 2048, 384, t);
        out.push((i, tps));
    }
    out
}

// ------------------------------------------------------------- overlap
#[derive(Debug, Clone)]
pub struct OverlapRow {
    pub model: PaperModel,
    pub sync_secs: f64,
    pub pipelined_secs: f64,
    pub speedup: f64,
}

/// Projected effect of the pipelined executor at paper scale (Fig. 7's
/// 16-NPU configuration, MSRL): with every worker state pulling from the
/// transfer dock concurrently, the steady-state iteration wall-clock
/// approaches `max(gen, infer, update) + dispatch + reshard` instead of
/// the barrier-per-stage sum. The real-engine counterpart is
/// `benches/pipeline_overlap.rs`.
pub fn overlap_rows() -> Vec<OverlapRow> {
    let cluster = ClusterSpec::paper(2);
    let work = RlWorkload { g: 256, n_resp: 16, pl: 2048, sl: 8192 };
    [
        PaperModel::Qwen25Dense7B,
        PaperModel::Qwen25Dense32B,
        PaperModel::Qwen3Moe30B,
    ]
    .into_iter()
    .map(|model| {
        let t = SystemModel::new(SystemKind::Msrl, model, cluster, work).iteration();
        let sync_secs = t.total();
        let bound = t.generation.max(t.inference).max(t.update);
        let pipelined_secs = bound + t.dispatch + t.reshard;
        OverlapRow { model, sync_secs, pipelined_secs, speedup: sync_secs / pipelined_secs }
    })
    .collect()
}

// ------------------------------------------------------------ scaling
#[derive(Debug, Clone)]
pub struct ScalingRow {
    pub gen_replicas: usize,
    pub gen_secs: f64,
    pub wall_secs: f64,
    pub tps: f64,
    pub speedup: f64,
}

/// Elastic stage replicas through the cost model: Qwen2.5-7B on the
/// paper's 16-NPU cluster under a long-CoT rollout (PL=2K, SL=48K — the
/// regime the paper's workloads live in, where generation dominates the
/// iteration), MSRL dataflow. The pipelined executor's steady-state wall
/// is `max(stage times) + dispatch + reshard`; widening the generation
/// node into `R` data-parallel replicas pulling from the same dock
/// divides its service time by `R` (leases make the concurrent pullers
/// safe; work is conserved) at a small coordination cost that grows with
/// the puller count (fair-share claim batching, dock contention —
/// modeled as `1 + 0.02·ln R`, the same ln-shape as the straggler term).
/// The old-logprob/ref inference states run the companion `logprob=2`
/// configuration throughout, so generation stays the binding constraint
/// across the sweep and every added replica strictly raises modeled
/// throughput — the bench gate's headline claim
/// (`benches/stage_scaling.rs`).
pub fn scaling_rows() -> Vec<ScalingRow> {
    let cluster = ClusterSpec::paper(2);
    let work = RlWorkload { g: 128, n_resp: 16, pl: 2048, sl: 49152 };
    let t = SystemModel::new(SystemKind::Msrl, PaperModel::Qwen25Dense7B, cluster, work)
        .iteration();
    // the two inference states (old-logprob + reference) at 2 replicas
    let inference = t.inference / 2.0;
    let mut rows = Vec::new();
    let mut base_tps = None;
    for r in [1usize, 2, 3, 4] {
        let coord = 1.0 + 0.02 * (r as f64).ln();
        let gen = t.generation / r as f64 * coord;
        let wall = gen.max(inference).max(t.update) + t.dispatch + t.reshard;
        let tps = crate::metrics::throughput_tps(
            work.g,
            work.n_resp,
            work.pl,
            work.sl,
            cluster.world() as u64,
            wall,
        );
        let base = *base_tps.get_or_insert(tps);
        rows.push(ScalingRow { gen_replicas: r, gen_secs: gen, wall_secs: wall, tps, speedup: tps / base });
    }
    rows
}

// ----------------------------------------------------------- streaming
#[derive(Debug, Clone)]
pub struct StreamingRow {
    pub slots: usize,
    pub streaming_tps: f64,
    pub batch_tps: f64,
    pub speedup: f64,
    pub streaming_occupancy: f64,
    pub batch_occupancy: f64,
}

/// Continuous batching vs batch-decode through the token-level cost
/// model: the same long-tail response-length workload (exponential, the
/// CoT rollout regime) decoded under both admission policies at several
/// slot counts. Batch decode runs admission-order waves that end with
/// their longest member; streaming refills each lane the step after it
/// retires — the [`crate::generation::GenSession`] policy. The
/// real-engine counterpart is `benches/continuous_batching.rs`.
pub fn streaming_rows(seed: u64) -> Vec<StreamingRow> {
    let lengths = long_tail_lengths(512, 512.0, 8192, seed);
    let seqs: Vec<SeqSpec> =
        lengths.iter().map(|&l| SeqSpec { prompt: 512, resp: l }).collect();
    [16usize, 32, 64]
        .into_iter()
        .map(|slots| {
            let m = TokenGenModel::paper_decode(slots);
            let b = m.batch_decode(&seqs);
            let s = m.continuous(&seqs);
            StreamingRow {
                slots,
                streaming_tps: s.tps(),
                batch_tps: b.tps(),
                speedup: s.tps() / b.tps(),
                streaming_occupancy: s.occupancy(),
                batch_occupancy: b.occupancy(),
            }
        })
        .collect()
}

// -------------------------------------------------------------- chaos
#[derive(Debug, Clone)]
pub struct ChaosRow {
    pub kill_rate: f64,
    pub stall_rate: f64,
    /// streaming generation with partial rollouts (resumable prefixes)
    pub partial: bool,
    pub samples: usize,
    pub reclaimed: u64,
    pub redispatched: u64,
    pub kills: u64,
    pub stalls: u64,
    pub restarts: u64,
    pub superseded: u64,
    /// decode steps a resume skipped (partial-rollout rows only)
    pub saved_steps: u64,
    /// decode steps replayed beyond the workload's intrinsic budget
    pub recomputed_steps: u64,
    pub lossless: bool,
}

/// Chaos sweep: the same seeded workload drained through the real
/// transfer dock under increasing worker kill/stall rates. Zero loss at
/// every rate is the reliability claim; the reclaim/redispatch columns
/// show what the lease machinery actually did to deliver it.
pub fn chaos_rows(seed: u64) -> Result<Vec<ChaosRow>> {
    use super::chaos::{run_chaos, ChaosConfig};
    use crate::trainers::faults::FaultPlan;
    let mut rows = Vec::new();
    // the final rows run the streaming generation worker with partial
    // rollouts: kills persist decoded prefixes and redispatch resumes
    // them, so the saved/recomputed columns show what resumability buys
    for (kill, stall, partial) in [
        (0.0, 0.0, false),
        (0.1, 0.0, false),
        (0.0, 0.1, false),
        (0.3, 0.2, false),
        (0.3, 0.0, true),
        (0.3, 0.2, true),
    ] {
        let cfg = ChaosConfig {
            iterations: 4,
            prompts_per_iter: 4,
            group_size: 2,
            // fault-free rows get a generous lease so a noisy scheduler
            // cannot fake a reclaim; faulted rows use a tight one
            lease_ticks: if kill + stall > 0.0 { 4 } else { 256 },
            plan: FaultPlan {
                seed: seed ^ 0xc4a0_5,
                kill_rate: kill,
                stall_rate: stall,
                ..Default::default()
            },
            seed,
            gen_streaming: partial,
            partial_rollouts: partial,
            workers_per_stage: if partial && stall > 0.0 { 2 } else { 1 },
            ..Default::default()
        };
        let out = run_chaos(&cfg)?;
        rows.push(ChaosRow {
            kill_rate: kill,
            stall_rate: stall,
            partial,
            samples: out.retired.len(),
            reclaimed: out.recovery.reclaimed,
            redispatched: out.recovery.redispatched,
            kills: out.recovery.kills,
            stalls: out.recovery.stalls,
            restarts: out.recovery.restarts,
            superseded: out.recovery.superseded_writebacks,
            saved_steps: out.work.saved_steps,
            recomputed_steps: out.work.recomputed_steps(),
            lossless: out.lossless(&cfg),
        });
    }
    Ok(rows)
}

// ----------------------------------------------------------- dispatch
#[derive(Debug, Clone)]
pub struct DispatchRow {
    pub nodes: usize,
    /// controller shards per stage in the sharded configuration (K = nodes)
    pub shards: usize,
    /// centralized replay buffer: every claim/writeback converges on one store
    pub central_secs: f64,
    /// warehouse-sharded dock, single controller per stage (`--dock-shards 1`)
    pub dock_secs: f64,
    /// warehouse-sharded dock with K = nodes controller shards
    pub sharded_secs: f64,
    /// weak-scaling linearity vs the smallest swept cluster at a nominal
    /// flat per-iteration compute time (dispatch is the only varying term)
    pub central_linearity: f64,
    pub sharded_linearity: f64,
}

/// Drain `64·nodes` samples (Fig. 9's per-node load, Table 1 row-2
/// payload shape) through generation + old-logprob writebacks with one
/// claim batch per node per pass; the accumulated ledger then implies
/// the flow's dispatch seconds under the paper's network model.
fn drive_dispatch(flow: &dyn SampleFlow, nodes: usize) -> Result<()> {
    const PER_NODE: usize = 64;
    const ELEMS: usize = 1024;
    let n = PER_NODE * nodes;
    let samples: Vec<Sample> = (0..n)
        .map(|i| Sample::new_prompt(u64::MAX, i as u64 / 8, format!("{i}+1="), i as i64 + 1))
        .collect();
    flow.put_samples(samples)?;
    let mut retired = 0usize;
    while retired < n {
        for node in 0..nodes {
            let metas = flow.request_ready(Stage::Generation, 8)?;
            if !metas.is_empty() {
                flow.fetch(node, &metas)?;
                for m in &metas {
                    flow.store_generation(
                        node,
                        m.index,
                        vec![(FieldKind::Tokens, Tensor::i32(&[ELEMS], vec![1; ELEMS])?)],
                        "42".into(),
                        3,
                        1,
                    )?;
                }
            }
            let ready = flow.request_ready(Stage::OldLogprob, 8)?;
            if ready.is_empty() {
                continue;
            }
            flow.fetch(node, &ready)?;
            for m in &ready {
                flow.store_fields(node, m.index, vec![(FieldKind::OldLp, Tensor::zeros(&[ELEMS]))])?;
                flow.retire(m.index);
                retired += 1;
            }
        }
    }
    Ok(())
}

/// Weak-scaling sweep of sample-dispatch cost: the same per-node
/// workload drained through the centralized replay buffer, the
/// warehouse-sharded dock with one controller per stage, and the dock
/// with K = nodes controller shards. The centralized store pays a
/// cross-node RPC per claim/writeback at one endpoint, so its dispatch
/// grows with the cluster; the sharded dock spreads both payload and
/// controller RPCs, staying near-flat into the hundreds of nodes.
pub fn dispatch_rows_for(node_sweep: &[usize]) -> Result<Vec<DispatchRow>> {
    // nominal per-iteration compute at Fig. 9's per-node load — flat
    // under weak scaling, so linearity is purely a dispatch story
    const COMPUTE_SECS: f64 = 60.0;
    let net = NetworkModel::paper();
    let mut rows = Vec::new();
    let mut base: Option<(f64, f64)> = None;
    for &nodes in node_sweep {
        let rb = ReplayBuffer::new(0);
        drive_dispatch(&rb, nodes)?;
        let central = rb.dispatch_secs(&net);
        let dock = TransferDock::with_shards(DockTopology::spread(nodes), 64, 1, 0);
        drive_dispatch(&dock, nodes)?;
        let dock_secs = dock.dispatch_secs(&net);
        let sharded_dock =
            TransferDock::with_shards(DockTopology::spread(nodes), 64, nodes, 0);
        drive_dispatch(&sharded_dock, nodes)?;
        let sharded = sharded_dock.dispatch_secs(&net);
        let (cb, sb) = *base.get_or_insert((central, sharded));
        rows.push(DispatchRow {
            nodes,
            shards: nodes,
            central_secs: central,
            dock_secs,
            sharded_secs: sharded,
            central_linearity: (COMPUTE_SECS + cb) / (COMPUTE_SECS + central),
            sharded_linearity: (COMPUTE_SECS + sb) / (COMPUTE_SECS + sharded),
        });
    }
    Ok(rows)
}

/// The printed experiment's sweep: 2 → 384 nodes.
pub fn dispatch_rows() -> Result<Vec<DispatchRow>> {
    dispatch_rows_for(&[2, 4, 8, 16, 32, 64, 128, 256, 384])
}

// ------------------------------------------------------------ tenancy
#[derive(Debug, Clone)]
pub struct TenancyRow {
    pub weights: (u32, u32),
    /// tenant 0's quota in MiB (`None` = uncapped) — the quota row shows
    /// backpressure deferring the capped tenant without touching its peer
    pub quota0_mb: Option<u64>,
    pub claims: (u64, u64),
    /// tenant 0's observed claim share vs its fair (weight) share
    pub share0: f64,
    pub fair0: f64,
    /// Jain fairness index over weight-normalized claim shares (1.0 =
    /// perfectly weighted-fair)
    pub jain: f64,
    pub deferrals: u64,
    pub lossless: bool,
}

/// Deficit-weighted handout share over a backlogged dock: stripe 64
/// samples across two tenants, then hand out 32 single-sample claims.
/// Measuring *while both tenants stay backlogged* is the point — a
/// drain-to-completion run claims every sample exactly once, so its
/// cumulative claim counts track the dataset split, not the weights.
pub fn tenancy_claim_probe(w0: u32, w1: u32) -> Result<(u64, u64)> {
    let flow = TransferDock::with_shards(DockTopology::spread(4), 64, 1, 0);
    flow.set_tenant_weights(&[(0, w0), (1, w1)]);
    let samples: Vec<Sample> = (0..64u64)
        .map(|g| {
            Sample::new_prompt(u64::MAX, g, format!("{g}+1="), g as i64 + 1)
                .with_tenant((g % 2) as u32)
        })
        .collect();
    flow.put_samples(samples)?;
    let mut counts = (0u64, 0u64);
    for _ in 0..32 {
        for m in flow.request_ready(Stage::Generation, 1)? {
            match m.tenant {
                0 => counts.0 += 1,
                _ => counts.1 += 1,
            }
        }
    }
    Ok(counts)
}

/// Weighted-fair claim arbitration through the real dock machinery: the
/// backlogged handout share under several weight ratios (the probe
/// above), plus the quota/deferral accounting of a full chaos drain for
/// each configuration (one row carries a 1 MiB quota on tenant 0).
pub fn tenancy_rows(seed: u64) -> Result<Vec<TenancyRow>> {
    use super::chaos::{run_chaos, ChaosConfig};
    let mut rows = Vec::new();
    for (w0, w1, quota0) in [(1, 1, None), (3, 1, None), (7, 1, None), (3, 1, Some(1u64))] {
        let (c0, c1) = tenancy_claim_probe(w0, w1)?;
        let cfg = ChaosConfig {
            iterations: 8,
            prompts_per_iter: 4,
            group_size: 2,
            // the quota row needs a window wide enough to outrun the
            // 1 MiB (16-sample) cap, or backpressure never fires
            max_inflight_iters: if quota0.is_some() { 8 } else { 2 },
            lease_ticks: 256,
            seed,
            tenants: 2,
            tenant_weights: vec![w0, w1],
            tenant_quota_mb: quota0.map(|q| vec![q]).unwrap_or_default(),
            ..Default::default()
        };
        let out = run_chaos(&cfg)?;
        let total = (c0 + c1) as f64;
        let share0 = if total > 0.0 { c0 as f64 / total } else { 0.0 };
        let x = [share0 / w0 as f64, (1.0 - share0) / w1 as f64];
        let (sum, sq) = (x[0] + x[1], x[0] * x[0] + x[1] * x[1]);
        rows.push(TenancyRow {
            weights: (w0, w1),
            quota0_mb: quota0,
            claims: (c0, c1),
            share0,
            fair0: w0 as f64 / (w0 + w1) as f64,
            jain: if sq > 0.0 { sum * sum / (2.0 * sq) } else { 1.0 },
            deferrals: out.tenant_deferrals,
            lossless: out.lossless(&cfg),
        });
    }
    Ok(rows)
}

#[derive(Debug, Clone)]
pub struct TenancyPoolSummary {
    /// one iteration of each job on static half-pool slices (they run
    /// concurrently, so the wall is the slower job's)
    pub slice_wall_secs: f64,
    /// one iteration of each job time-sharing the full pool
    pub shared_wall_secs: f64,
    pub speedup: f64,
}

/// Why share the pool at all: a short-prompt reward-model job (PL=256,
/// SL=512) and a long-CoT math job (PL=2K, SL=48K) on 16 NPUs, static
/// halves vs a weighted shared pool. The halves strand the short job's
/// slice idle while the long job's slice grinds; the shared pool is
/// work-conserving — the short job's unused share is donated, so both
/// jobs finish in roughly the long job's full-pool time.
pub fn tenancy_pool_summary() -> TenancyPoolSummary {
    let short = RlWorkload { g: 256, n_resp: 4, pl: 256, sl: 512 };
    let long = RlWorkload { g: 128, n_resp: 16, pl: 2048, sl: 49152 };
    let t = |nodes: usize, work: RlWorkload| {
        SystemModel::new(
            SystemKind::Msrl,
            PaperModel::Qwen25Dense7B,
            ClusterSpec::paper(nodes),
            work,
        )
        .iteration()
        .total()
    };
    let slice_wall_secs = t(1, short).max(t(1, long));
    let shared_wall_secs = t(2, short) + t(2, long);
    TenancyPoolSummary {
        slice_wall_secs,
        shared_wall_secs,
        speedup: slice_wall_secs / shared_wall_secs,
    }
}

// ------------------------------------------------------------- runner
pub fn run_named_experiment(name: &str) -> Result<()> {
    match name {
        "table1" => {
            let mut t = Table::new(
                "Table 1 — sample-flow TCV and dispatch time",
                &["G", "N", "PL", "n", "SL", "M", "TCV(GB)", "T100(s)", "T1K(s)"],
            );
            for r in table1_rows_out() {
                t.row(vec![
                    r.params.g.to_string(),
                    r.params.n_resp.to_string(),
                    r.params.pl.to_string(),
                    r.params.n_items.to_string(),
                    r.params.sl.to_string(),
                    r.params.m.to_string(),
                    format!("{:.2}", r.tcv_gb),
                    format!("{:.1}", r.t100_s),
                    format!("{:.2}", r.t1k_s),
                ]);
            }
            t.print();
        }
        "fig7" => {
            let mut t = Table::new(
                "Fig. 7 — end-to-end throughput, 16 NPUs (G=256 N=16 PL=2K SL=8K)",
                &["model", "system", "TPS", "vs OpenRLHF"],
            );
            for r in fig7_rows() {
                t.row(vec![
                    r.model.name().into(),
                    r.system.name().into(),
                    format!("{:.0}", r.tps),
                    format!("{:.2}x", r.speedup_vs_openrlhf),
                ]);
            }
            t.print();
        }
        "fig9" => {
            let mut t = Table::new(
                "Fig. 9 — weak-scaling linearity (64 prompts/node, Qwen2.5-7B)",
                &["system", "nodes", "NPUs", "TPS/dev", "linearity"],
            );
            for r in fig9_rows() {
                t.row(vec![
                    r.system.name().into(),
                    r.nodes.to_string(),
                    r.npus.to_string(),
                    format!("{:.1}", r.tps_per_device),
                    format!("{:.1}%", r.linearity * 100.0),
                ]);
            }
            t.print();
        }
        "fig11" => {
            let series = fig11_series(100, 0);
            let mut t = Table::new(
                "Fig. 11 — DeepSeek-R1-671B on 384 NPUs (MSRL)",
                &["iteration", "TPS"],
            );
            for (i, tps) in series.iter().step_by(10) {
                t.row(vec![i.to_string(), format!("{tps:.0}")]);
            }
            t.print();
            let mean = series.iter().map(|(_, t)| t).sum::<f64>() / series.len() as f64;
            println!("mean TPS = {mean:.0} (paper: fluctuates 200–250)");
        }
        "overlap" => {
            let mut t = Table::new(
                "Pipelined executor — projected iteration wall-clock (MSRL, 16 NPUs)",
                &["model", "sync (s)", "pipelined (s)", "speedup"],
            );
            for r in overlap_rows() {
                t.row(vec![
                    r.model.name().into(),
                    format!("{:.1}", r.sync_secs),
                    format!("{:.1}", r.pipelined_secs),
                    format!("{:.2}x", r.speedup),
                ]);
            }
            t.print();
        }
        "scaling" => {
            let mut t = Table::new(
                "Elastic stage replicas — modeled TPS vs generation replica count \
                 (Qwen2.5-7B long-CoT, 16 NPUs, MSRL, logprob=2)",
                &["gen replicas", "gen (s)", "wall (s)", "TPS", "speedup"],
            );
            for r in scaling_rows() {
                t.row(vec![
                    r.gen_replicas.to_string(),
                    format!("{:.0}", r.gen_secs),
                    format!("{:.0}", r.wall_secs),
                    format!("{:.1}", r.tps),
                    format!("{:.2}x", r.speedup),
                ]);
            }
            t.print();
            println!(
                "each added generation replica strictly raises modeled throughput \
                 while generation stays the binding stage; the real-executor \
                 counterpart is benches/stage_scaling.rs"
            );
        }
        "streaming" => {
            let mut t = Table::new(
                "Continuous batching — modeled decode TPS vs batch waves \
                 (Qwen2.5-7B, long-tail SL: exp(512) capped 8K, 512 seqs)",
                &["slots", "stream TPS", "batch TPS", "speedup", "stream occ", "batch occ"],
            );
            for r in streaming_rows(0) {
                t.row(vec![
                    r.slots.to_string(),
                    format!("{:.0}", r.streaming_tps),
                    format!("{:.0}", r.batch_tps),
                    format!("{:.2}x", r.speedup),
                    format!("{:.0}%", r.streaming_occupancy * 100.0),
                    format!("{:.0}%", r.batch_occupancy * 100.0),
                ]);
            }
            t.print();
            println!(
                "streaming refills each slot the step after it retires, so the \
                 long tail never idles the batch; the real-executor counterpart \
                 is benches/continuous_batching.rs and --gen-streaming"
            );
        }
        "chaos" => {
            let mut t = Table::new(
                "Chaos — lease-based recovery under seeded worker faults (transfer dock)",
                &[
                    "kill", "stall", "partial", "retired", "reclaim", "redisp", "kills",
                    "stalls", "restarts", "stale-wb", "saved", "recomp", "lossless",
                ],
            );
            for r in chaos_rows(0)? {
                t.row(vec![
                    format!("{:.0}%", r.kill_rate * 100.0),
                    format!("{:.0}%", r.stall_rate * 100.0),
                    if r.partial { "yes".into() } else { "-".into() },
                    r.samples.to_string(),
                    r.reclaimed.to_string(),
                    r.redispatched.to_string(),
                    r.kills.to_string(),
                    r.stalls.to_string(),
                    r.restarts.to_string(),
                    r.superseded.to_string(),
                    r.saved_steps.to_string(),
                    r.recomputed_steps.to_string(),
                    if r.lossless { "yes".into() } else { "NO".into() },
                ]);
            }
            t.print();
            println!(
                "every row retires the identical sample set; faulted rows recover it \
                 through lease reclaim + redispatch, and partial rows resume killed \
                 sequences from persisted prefixes instead of regenerating them \
                 (tests/chaos.rs + tests/partial_rollouts.rs pin the invariants)"
            );
        }
        "dispatch" => {
            let mut t = Table::new(
                "Dispatch scaling — central buffer vs sharded dock controllers \
                 (64 samples/node, Table-1 row-2 payloads)",
                &[
                    "nodes", "K", "central (s)", "dock K=1 (s)", "dock K=n (s)",
                    "central lin", "sharded lin",
                ],
            );
            for r in dispatch_rows()? {
                t.row(vec![
                    r.nodes.to_string(),
                    r.shards.to_string(),
                    format!("{:.2}", r.central_secs),
                    format!("{:.3}", r.dock_secs),
                    format!("{:.3}", r.sharded_secs),
                    format!("{:.1}%", r.central_linearity * 100.0),
                    format!("{:.1}%", r.sharded_linearity * 100.0),
                ]);
            }
            t.print();
            println!(
                "every claim and writeback converges on the centralized buffer, so \
                 its dispatch grows with the cluster; K controller shards per stage \
                 (--dock-shards) spread the controller RPCs like the warehouses \
                 spread payloads, holding dispatch near-flat into the hundreds of \
                 nodes — the gated counterpart is benches/fig9_linearity.rs"
            );
        }
        "tenancy" => {
            let p = tenancy_pool_summary();
            println!(
                "Static slices vs shared pool (Qwen2.5-7B, 16 NPUs): a short-prompt \
                 reward-model job + a long-CoT math job\n  static halves: {:.0}s/iter \
                 (the short job's slice sits idle)\n  weighted shared pool: {:.0}s/iter \
                 ({:.2}x — the idle share is donated, not stranded)\n",
                p.slice_wall_secs, p.shared_wall_secs, p.speedup
            );
            let mut t = Table::new(
                "Tenancy — weighted-fair claims through the real dock \
                 (2 tenant jobs, one replica pool)",
                &[
                    "weights", "quota0", "probe t0/t1", "share t0", "fair t0", "Jain",
                    "deferrals", "lossless",
                ],
            );
            for r in tenancy_rows(0)? {
                t.row(vec![
                    format!("{}:{}", r.weights.0, r.weights.1),
                    r.quota0_mb.map_or("-".into(), |q| format!("{q}MiB")),
                    format!("{}/{}", r.claims.0, r.claims.1),
                    format!("{:.0}%", r.share0 * 100.0),
                    format!("{:.0}%", r.fair0 * 100.0),
                    format!("{:.3}", r.jain),
                    r.deferrals.to_string(),
                    if r.lossless { "yes".into() } else { "NO".into() },
                ]);
            }
            t.print();
            println!(
                "handout shares (32 single-sample claims over a backlogged dock) \
                 track the configured weights — deficit-weighted round robin; the \
                 quota row's chaos drain shows the capped tenant deferring at its \
                 byte limit while its peer admits freely. Gated counterpart: \
                 benches/multi_tenant.rs; differential oracle: tests/multi_tenant.rs"
            );
        }
        other => {
            anyhow::bail!(
                "unknown experiment {other:?} \
                 (table1|fig7|fig9|fig11|overlap|chaos|scaling|streaming|dispatch|tenancy)"
            )
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig9_linearity_ordering_matches_paper() {
        let rows = fig9_rows();
        let last = |k: SystemKind| {
            rows.iter()
                .filter(|r| r.system == k)
                .last()
                .map(|r| r.linearity)
                .unwrap()
        };
        let msrl = last(SystemKind::Msrl);
        let msrlb = last(SystemKind::Msrlb);
        let verl = last(SystemKind::Verl);
        // paper at 192 NPUs: MSRL 81.1%, MSRLB 61.9%, VeRL 40.4%
        assert!(msrl > msrlb && msrlb > verl, "ordering: {msrl} {msrlb} {verl}");
        assert!(msrl > 0.70, "MSRL linearity {msrl}");
        assert!(verl < 0.65, "VeRL linearity {verl}");
    }

    #[test]
    fn fig11_tps_in_paper_band() {
        let series = fig11_series(50, 1);
        let mean = series.iter().map(|(_, t)| t).sum::<f64>() / series.len() as f64;
        // paper: 200–250 TPS; accept the band with simulator headroom
        assert!(mean > 120.0 && mean < 400.0, "mean TPS {mean}");
    }

    #[test]
    fn table1_row_count() {
        assert_eq!(table1_rows_out().len(), 6);
    }

    #[test]
    fn chaos_sweep_is_lossless_at_every_rate() {
        let rows = chaos_rows(3).unwrap();
        assert_eq!(rows.len(), 6);
        for r in &rows {
            assert!(r.lossless, "loss at kill={} stall={}: {r:?}", r.kill_rate, r.stall_rate);
            assert_eq!(r.samples, 4 * 4 * 2, "retired-set size must match the workload");
        }
        // the fault-free row is quiet; the mixed-fault row actually fired
        // and recovered (rates high enough that a fault-free schedule is
        // out of the question across the run's many claim events)
        assert_eq!(rows[0].reclaimed, 0);
        assert!(rows[3].kills + rows[3].stalls > 0, "{:?}", rows[3]);
        assert!(rows[3].reclaimed > 0, "{:?}", rows[3]);
        // the partial-rollout kill row resumes instead of regenerating
        assert!(rows[4].partial);
        assert!(rows[4].kills > 0, "{:?}", rows[4]);
        assert!(rows[4].saved_steps > 0, "kill row must bank resumed work: {:?}", rows[4]);
    }

    #[test]
    fn generation_replicas_strictly_increase_modeled_tps() {
        // the bench gate's headline claim: on the long-CoT Qwen2.5-7B
        // config every added generation replica raises throughput — i.e.
        // generation stays the binding stage across the swept range
        let rows = scaling_rows();
        assert_eq!(rows.len(), 4);
        assert_eq!(rows[0].gen_replicas, 1);
        for w in rows.windows(2) {
            assert!(
                w[1].tps > w[0].tps,
                "TPS must strictly increase: R={} {:.1} !> R={} {:.1}",
                w[1].gen_replicas,
                w[1].tps,
                w[0].gen_replicas,
                w[0].tps
            );
        }
        let last = rows.last().unwrap();
        assert!(last.speedup > 1.5, "4 replicas should speed up >1.5x, got {:.2}", last.speedup);
        assert!(last.speedup < 4.0, "speedup cannot exceed the replica count: {:.2}", last.speedup);
    }

    #[test]
    fn streaming_strictly_beats_batch_decode_on_long_tail() {
        // the continuous-batching bench gate's headline claim, across
        // seeds and slot counts: modeled streaming TPS strictly above
        // batch-decode, with strictly higher slot occupancy
        for seed in [0u64, 7, 42] {
            let rows = streaming_rows(seed);
            assert_eq!(rows.len(), 3);
            for r in &rows {
                assert!(
                    r.streaming_tps > r.batch_tps,
                    "slots={} seed={seed}: {} !> {}",
                    r.slots,
                    r.streaming_tps,
                    r.batch_tps
                );
                assert!(r.speedup > 1.0 && r.speedup < 4.0, "speedup {}", r.speedup);
                assert!(
                    r.streaming_occupancy > r.batch_occupancy,
                    "slots={} seed={seed}: occ {} !> {}",
                    r.slots,
                    r.streaming_occupancy,
                    r.batch_occupancy
                );
                assert!(r.streaming_occupancy > 0.9);
            }
        }
    }

    #[test]
    fn sharded_dispatch_stays_near_linear_into_hundreds_of_nodes() {
        // a two-point weak-scaling probe (the full 2→384 sweep is the
        // printed experiment and the release-mode bench gate)
        let rows = dispatch_rows_for(&[8, 192]).unwrap();
        let (base, top) = (&rows[0], &rows[1]);
        assert_eq!(top.nodes, 192);
        // the centralized buffer's dispatch grows roughly with the node
        // count (24x more samples, every RPC at one endpoint)...
        assert!(top.central_secs > 10.0 * base.central_secs, "{rows:?}");
        // ...while the sharded dock's stays near-flat under weak scaling
        assert!(top.sharded_secs < 5.0 * base.sharded_secs, "{rows:?}");
        // controller sharding must not regress the K=1 dock
        assert!(top.sharded_secs < top.dock_secs * 1.25, "{rows:?}");
        assert!(top.central_linearity < 0.95, "{rows:?}");
        assert!(top.sharded_linearity > 0.99, "{rows:?}");
        // and the central-over-sharded gap widens with scale
        let at_base = base.central_secs / base.sharded_secs;
        let at_top = top.central_secs / top.sharded_secs;
        assert!(at_top > 2.0 * at_base, "gap must widen: {at_base} -> {at_top}");
    }

    #[test]
    fn tenancy_fairness_tracks_weights() {
        let rows = tenancy_rows(0).unwrap();
        assert_eq!(rows.len(), 4);
        for r in &rows {
            assert!(r.lossless, "tenancy run lost samples: {r:?}");
            assert!(r.jain > 0.9, "claim share must track weights: {r:?}");
        }
        // the 3:1 row actually skews toward the heavy tenant
        assert!(rows[1].share0 > 0.6, "{:?}", rows[1]);
        // the quota row actually exercises backpressure
        assert!(rows[3].deferrals > 0, "{:?}", rows[3]);
        // and sharing the pool beats static slices on the uneven mix
        let p = tenancy_pool_summary();
        assert!(p.speedup > 1.2, "shared pool must beat static slices: {p:?}");
    }

    #[test]
    fn overlap_always_wins() {
        for r in overlap_rows() {
            assert!(
                r.pipelined_secs < r.sync_secs,
                "{:?}: pipelined {} !< sync {}",
                r.model,
                r.pipelined_secs,
                r.sync_secs
            );
            assert!(r.speedup > 1.0 && r.speedup < 3.0, "speedup {}", r.speedup);
        }
    }
}
