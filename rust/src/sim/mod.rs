//! Cluster-scale simulation: regenerates the paper's evaluation figures
//! on hardware we don't have (384 Ascend NPUs).
//!
//! The simulator is NOT a curve fit: stage times come from a roofline
//! cost model (FLOPs / bytes of the actual model configs under the actual
//! parallel layouts), the sample-flow dispatch times come from the same
//! Eq. (2)/(4) volume code the real transfer dock accounts with, and the
//! resharding memory effects come from the same planner the real
//! allgather–swap engine uses (redundant bytes shrink the KV budget and
//! therefore the generation batch). Device-efficiency constants are
//! calibrated once against the real PJRT run (DESIGN.md §Calibration).
//!
//! Regenerated experiments:
//! * Table 1 — dispatch volumes/times vs config
//! * Fig. 7 — end-to-end TPS: OpenRLHF / VeRL / MSRLP / MSRL, 3 models
//! * Fig. 9 — weak-scaling linearity: VeRL / MSRLB / MSRL
//! * Fig. 11 — DeepSeek-671B at 384 NPUs
//! * chaos  — lease-based recovery under seeded worker kills/stalls
//!   (drives the *real* dock machinery with synthetic stage workers —
//!   see [`chaos`])
//! * dispatch — central buffer vs K-sharded dock controllers: dispatch
//!   seconds and weak-scaling linearity to hundreds of nodes (drives the
//!   real flows and reads their ledgers)
//! * tenancy — two tenant jobs over one shared replica pool: static
//!   slices vs a weighted shared pool (cost model), and weighted-fair
//!   claim shares + quota backpressure through the real dock ([`chaos`])

pub mod chaos;
mod costmodel;
mod experiments;
mod systems;

pub use chaos::{
    run_baseline, run_chaos, ChaosConfig, ChaosOutcome, DecodeWork, SYNTH_CKPT_STEPS,
    SYNTH_TENANT_BYTES,
};
pub use costmodel::{
    long_tail_lengths, ClusterSpec, DeviceSpec, GenSim, PaperModel, RlWorkload, SeqSpec,
    StageTimes, TokenGenModel,
};
pub use experiments::{
    chaos_rows, dispatch_rows, dispatch_rows_for, fig11_series, fig7_rows, fig9_rows,
    overlap_rows, run_named_experiment, scaling_rows, streaming_rows, table1_rows_out,
    tenancy_claim_probe, tenancy_pool_summary, tenancy_rows, ChaosRow, DispatchRow, Fig7Row,
    Fig9Row, OverlapRow,
    ScalingRow, StreamingRow, Table1Row, TenancyPoolSummary, TenancyRow,
};
pub use systems::{SystemKind, SystemModel};
