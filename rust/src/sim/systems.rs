//! The five simulated systems of the paper's evaluation: MSRL, its two
//! ablations (MSRLP = no TD + no allgather-swap, MSRLB = central replay
//! buffer), and the baselines VeRL and OpenRLHF.
//!
//! Mechanisms are shared (Eqs. 2/4 volumes, Eq. 3 redundancy, roofline
//! compute); systems differ in:
//!  * dispatch path: driver-relayed / central store / sharded transfer dock
//!  * serialization: Ray pickle (bytes/s) vs TensorDict zero-copy
//!  * incast congestion at the central store (calibrated coefficient)
//!  * resharding: naive (Eq. 3 redundancy eats KV budget) vs
//!    allgather-swap (full release, small D2H cost)
//!  * kernel/parallelism efficiency (MFU, generation efficiency)

use crate::parallel::ParallelLayout;
use crate::transfer_dock::{tcv_gb, td_tcv_gb, VolumeParams};

use super::costmodel::{ClusterSpec, PaperModel, RlWorkload, Roofline, StageTimes};

const GB: f64 = 1024.0 * 1024.0 * 1024.0;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SystemKind {
    OpenRlhf,
    Verl,
    /// MindSpeed RL without transfer dock + allgather-swap (paper "MSRLP")
    Msrlp,
    /// MindSpeed RL with the conventional replay buffer (paper "MSRLB")
    Msrlb,
    Msrl,
}

impl SystemKind {
    pub fn name(&self) -> &'static str {
        match self {
            SystemKind::OpenRlhf => "OpenRLHF",
            SystemKind::Verl => "VeRL",
            SystemKind::Msrlp => "MSRLP",
            SystemKind::Msrlb => "MSRLB",
            SystemKind::Msrl => "MSRL",
        }
    }

    /// Training/inference MFU (kernel + parallelism quality).
    /// MSRL-family shares the Ascend fused kernels (Table 2).
    fn mfu(&self) -> f64 {
        match self {
            SystemKind::OpenRlhf => 0.22,
            SystemKind::Verl => 0.30,
            _ => 0.36,
        }
    }

    fn gen_eff(&self) -> f64 {
        match self {
            SystemKind::OpenRlhf => 0.30,
            SystemKind::Verl => 0.42,
            _ => 0.50,
        }
    }

    /// Achieved fraction of HBM bandwidth in the decode kernels
    /// (paged-KV + fused attention quality on this hardware).
    fn decode_hbm_eff(&self) -> f64 {
        match self {
            SystemKind::OpenRlhf => 0.45,
            SystemKind::Verl => 0.60,
            _ => 0.85,
        }
    }

    /// Long-tail straggler growth coefficient (× ln replicas). Stage
    /// fusion / partial rollout (Table 2) shrink it for the MSRL family.
    fn straggler_coeff(&self) -> f64 {
        match self {
            SystemKind::OpenRlhf => 0.22,
            SystemKind::Verl => 0.16,
            _ => 0.13,
        }
    }

    fn has_transfer_dock(&self) -> bool {
        matches!(self, SystemKind::Msrl)
    }

    fn has_allgather_swap(&self) -> bool {
        matches!(self, SystemKind::Msrl | SystemKind::Msrlb)
    }

    /// Driver-relayed transfers (Ray object path without direct
    /// worker-to-worker reads): every payload crosses the wire twice.
    fn relay_factor(&self) -> f64 {
        match self {
            SystemKind::OpenRlhf | SystemKind::Verl => 2.0,
            _ => 1.0,
        }
    }

    /// Serialization throughput of the sample path (bytes/s). Ray pickle
    /// for the baselines; MSRL-family uses TensorDict (near-memcpy).
    fn serde_bps(&self) -> f64 {
        match self {
            SystemKind::OpenRlhf => 1.2e9,
            SystemKind::Verl => 1.5e9,
            _ => 30e9,
        }
    }

    /// Incast congestion coefficient at the central store: effective
    /// dispatch multiplies by (1 + α·(nodes−1)). Calibrated against the
    /// paper's Fig. 9 (DESIGN.md §Calibration); zero for the sharded dock.
    fn incast_alpha(&self) -> f64 {
        match self {
            SystemKind::OpenRlhf => 0.40,
            SystemKind::Verl => 0.35,
            SystemKind::Msrlp | SystemKind::Msrlb => 0.22,
            SystemKind::Msrl => 0.0,
        }
    }
}

/// A fully-specified simulated deployment.
pub struct SystemModel {
    pub kind: SystemKind,
    pub model: PaperModel,
    pub cluster: ClusterSpec,
    pub work: RlWorkload,
    pub update_layout: ParallelLayout,
    pub gen_layout: ParallelLayout,
}

impl SystemModel {
    /// Default layouts per model/cluster size: TP covers a node for the
    /// big models, DP fills the rest (what the paper's per-framework
    /// tuning converges to).
    pub fn auto_layouts(
        model: PaperModel,
        cluster: &ClusterSpec,
    ) -> (ParallelLayout, ParallelLayout) {
        let world = cluster.world();
        let (utp, upp) = match model {
            PaperModel::Qwen25Dense7B => (2, 1),
            PaperModel::Qwen25Dense32B => (8, 1),
            PaperModel::Qwen3Moe30B => (4, 1),
            PaperModel::DeepSeekR1Moe671B => (4, 6),
        };
        let udp = (world / (utp * upp)).max(1);
        let uep = if model.is_moe() { (utp * udp).min(16) } else { 1 };
        let update = ParallelLayout { tp: utp, pp: upp, dp: udp, ep: uep, cp: 1 };
        let gtp = (utp / 2).max(1);
        let gdp = (world / gtp).max(1);
        let gep = if model.is_moe() { (gtp * gdp).min(64) } else { 1 };
        let gen = ParallelLayout { tp: gtp, pp: 1, dp: gdp, ep: gep, cp: 1 };
        (update, gen)
    }

    pub fn new(
        kind: SystemKind,
        model: PaperModel,
        cluster: ClusterSpec,
        work: RlWorkload,
    ) -> Self {
        let (update_layout, gen_layout) = Self::auto_layouts(model, &cluster);
        Self { kind, model, cluster, work, update_layout, gen_layout }
    }

    fn volume_params(&self) -> VolumeParams {
        VolumeParams {
            g: self.work.g,
            n_resp: self.work.n_resp,
            b: 4,
            pl: self.work.pl,
            sl: self.work.sl,
            n_items: 5,
            m: 3,
        }
    }

    /// Sample-flow dispatch seconds per iteration.
    pub fn dispatch_secs(&self) -> f64 {
        let p = self.volume_params();
        let kind = self.kind;
        let seqs = self.work.sequences() as f64;
        if kind.has_transfer_dock() {
            // Eq. 4: volume per warehouse; warehouses serve in parallel
            let s = self.cluster.nodes.max(1) as u64;
            let c = 5; // GRPO worker states
            let per_wh_bytes = td_tcv_gb(&p, c, s) * GB;
            let wire = per_wh_bytes / self.cluster.inter_node_bps;
            let serde = per_wh_bytes / kind.serde_bps();
            // controller round-trips are node-local: negligible latency
            wire + serde + seqs * 50e-6
        } else {
            // Eq. 2 through one store NIC, optionally relayed by a driver
            let bytes = tcv_gb(&p) * GB * kind.relay_factor();
            let wire = bytes / self.cluster.inter_node_bps;
            let serde = bytes / kind.serde_bps();
            let latency = seqs * 1e-3; // per-sample object handling
            let incast = 1.0 + kind.incast_alpha() * (self.cluster.nodes as f64 - 1.0);
            (wire + serde + latency) * incast
        }
    }

    /// Resharding seconds + redundant device bytes it leaves behind.
    pub fn reshard(&self) -> (f64, f64) {
        let weight_bytes = self.model.weight_bytes();
        let world = self.cluster.world() as f64;
        // allgather: each device pulls its generation shard; the portion
        // crossing node boundaries moves at inter-node speed
        let gen_devs_per_replica = world / self.gen_layout.dp.max(1) as f64;
        let shard_bytes = weight_bytes / gen_devs_per_replica.max(1.0);
        let cross_frac = if gen_devs_per_replica > self.cluster.devices_per_node as f64 {
            0.6
        } else {
            0.15 // most traffic stays on intra-node links
        };
        let t_ag = shard_bytes * cross_frac / self.cluster.inter_node_bps
            + shard_bytes * (1.0 - cross_frac) / 200e9;

        if self.kind.has_allgather_swap() {
            // swap the update state (weights + grads + optimizer ≈ 16
            // bytes/param sharded over the world) to host at 50 GB/s
            let update_state_per_dev = self.model.params() * 16.0 / world;
            let t_d2h = update_state_per_dev / self.cluster.host_device_bps;
            // H2D back is overlapped with inference (paper Fig. 5)
            ((t_ag + t_d2h), 0.0)
        } else if self.kind == SystemKind::OpenRlhf {
            // disaggregated engines: full weight broadcast over the wire
            let t_bcast =
                weight_bytes / (self.cluster.inter_node_bps * self.cluster.nodes as f64);
            let redundant_per_dev = eq3_per_device(self);
            (t_ag + t_bcast, redundant_per_dev)
        } else {
            (t_ag, eq3_per_device(self))
        }
    }

    /// Device bytes available for KV cache during generation.
    pub fn kv_free_bytes_per_dev(&self) -> f64 {
        let world = self.cluster.world() as f64;
        let gen_weights_per_dev =
            self.model.weight_bytes() / (world / self.gen_layout.dp.max(1) as f64);
        let (_t, redundant_per_dev) = self.reshard();
        let resident = if self.kind.has_allgather_swap() {
            // update state swapped out: only generation weights remain
            gen_weights_per_dev
        } else {
            // update state (16 B/param sharded) stays resident
            let update_state_per_dev = self.model.params() * 16.0 / world;
            gen_weights_per_dev + update_state_per_dev + redundant_per_dev
        };
        (self.cluster.device.mem_bytes - resident - 8e9).max(1e9) // 8 GB runtime reserve
    }

    /// Full per-iteration stage breakdown.
    pub fn iteration(&self) -> StageTimes {
        let roof = Roofline {
            model: self.model,
            cluster: &self.cluster,
            work: self.work,
            gen_layout: self.gen_layout,
        };
        let (t_reshard, _) = self.reshard();
        // long-tail straggler growth with replica count (synchronous RL)
        let replicas = self.gen_layout.dp.max(1) as f64;
        let straggler = 1.0 + self.kind.straggler_coeff() * replicas.ln().max(0.0);
        // DP gradient allreduce: each device ring-reduces its own grad
        // shard across the dp replicas (2·bytes·(dp−1)/dp at wire speed)
        let world = self.cluster.world() as f64;
        let grad_per_dev = self.model.weight_bytes() * self.update_layout.dp as f64 / world;
        let dp = self.update_layout.dp as f64;
        let t_allreduce = if self.update_layout.dp > 1 {
            2.0 * grad_per_dev * (dp - 1.0) / dp / self.cluster.inter_node_bps
        } else {
            0.0
        };
        StageTimes {
            generation: roof
                .generation_secs(
                    self.kind.gen_eff(),
                    self.kind.decode_hbm_eff(),
                    self.kv_free_bytes_per_dev(),
                )
                * straggler,
            inference: roof.inference_secs(self.kind.mfu(), 2.0),
            update: roof.update_secs(self.kind.mfu()) + t_allreduce,
            dispatch: self.dispatch_secs(),
            reshard: t_reshard,
        }
    }

    /// Eq. (5) throughput.
    pub fn throughput_tps(&self) -> f64 {
        crate::metrics::throughput_tps(
            self.work.g,
            self.work.n_resp,
            self.work.pl,
            self.work.sl,
            self.cluster.world() as u64,
            self.iteration().total(),
        )
    }
}

/// Eq. (3) redundancy expressed per device, using weight-class fractions
/// typical of the model family (TP-shardable fraction ≈ all matmul
/// weights; expert fraction for MoE).
fn eq3_per_device(sys: &SystemModel) -> f64 {
    let w = sys.model.weight_bytes();
    let (tp_frac, ep_frac) = if sys.model.is_moe() { (0.15, 0.80) } else { (0.95, 0.0) };
    let tw = w * tp_frac;
    let ew = w * ep_frac;
    let r_total = sys.gen_layout.dp as f64
        * (tw / sys.update_layout.tp as f64 + ew / sys.gen_layout.ep.max(1) as f64);
    r_total / sys.cluster.world() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fig7_system(kind: SystemKind, model: PaperModel) -> SystemModel {
        SystemModel::new(
            kind,
            model,
            ClusterSpec::paper(2), // 16 NPUs
            RlWorkload { g: 256, n_resp: 16, pl: 2048, sl: 8192 },
        )
    }

    #[test]
    fn msrl_beats_baselines_on_every_fig7_model() {
        for model in [
            PaperModel::Qwen25Dense7B,
            PaperModel::Qwen25Dense32B,
            PaperModel::Qwen3Moe30B,
        ] {
            let msrl = fig7_system(SystemKind::Msrl, model).throughput_tps();
            for base in [SystemKind::OpenRlhf, SystemKind::Verl, SystemKind::Msrlp] {
                let b = fig7_system(base, model).throughput_tps();
                assert!(
                    msrl > b,
                    "{} should beat {} on {} ({msrl:.0} vs {b:.0})",
                    SystemKind::Msrl.name(),
                    base.name(),
                    model.name()
                );
            }
        }
    }

    #[test]
    fn fig7_speedup_in_paper_band() {
        // paper: 1.42×–3.97× across models and baselines
        let mut ratios = Vec::new();
        for model in [
            PaperModel::Qwen25Dense7B,
            PaperModel::Qwen25Dense32B,
            PaperModel::Qwen3Moe30B,
        ] {
            let msrl = fig7_system(SystemKind::Msrl, model).throughput_tps();
            for base in [SystemKind::OpenRlhf, SystemKind::Verl] {
                ratios.push(msrl / fig7_system(base, model).throughput_tps());
            }
        }
        let lo = ratios.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = ratios.iter().cloned().fold(0.0f64, f64::max);
        assert!(lo > 1.2, "min speedup {lo:.2} too small: {ratios:?}");
        assert!(hi < 6.0, "max speedup {hi:.2} implausibly large: {ratios:?}");
    }

    #[test]
    fn allgather_swap_increases_kv_budget() {
        let msrl = fig7_system(SystemKind::Msrl, PaperModel::Qwen25Dense32B);
        let msrlp = fig7_system(SystemKind::Msrlp, PaperModel::Qwen25Dense32B);
        assert!(msrl.kv_free_bytes_per_dev() > msrlp.kv_free_bytes_per_dev());
    }

    #[test]
    fn transfer_dock_dispatch_scales_with_warehouses() {
        let mk = |nodes, kind| {
            SystemModel::new(
                kind,
                PaperModel::Qwen25Dense7B,
                ClusterSpec::paper(nodes),
                RlWorkload { g: 64 * nodes as u64, n_resp: 16, pl: 2048, sl: 8192 },
            )
            .dispatch_secs()
        };
        // central: dispatch grows superlinearly in nodes (volume × incast)
        let v2 = mk(2, SystemKind::Verl);
        let v24 = mk(24, SystemKind::Verl);
        assert!(v24 > 10.0 * v2, "central store must congest: {v2} → {v24}");
        // dock: per-warehouse volume is constant in weak scaling
        let m2 = mk(2, SystemKind::Msrl);
        let m24 = mk(24, SystemKind::Msrl);
        assert!(m24 < 3.0 * m2, "dock must stay near-flat: {m2} → {m24}");
    }
}
