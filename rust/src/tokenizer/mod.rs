//! Char-level tokenizer, constructed from the artifact manifest's vocab so
//! the Rust side can never drift from the Python side that trained/exported
//! the model.

use anyhow::{bail, Result};
use std::collections::HashMap;

#[derive(Debug, Clone)]
pub struct Tokenizer {
    vocab: Vec<String>,
    lookup: HashMap<char, i32>,
    pub pad_id: i32,
    pub bos_id: i32,
    pub eos_id: i32,
}

impl Tokenizer {
    pub fn from_manifest(m: &crate::runtime::Manifest) -> Self {
        Self::new(m.vocab.clone(), m.pad_id as i32, m.bos_id as i32, m.eos_id as i32)
    }

    pub fn new(vocab: Vec<String>, pad_id: i32, bos_id: i32, eos_id: i32) -> Self {
        let mut lookup = HashMap::new();
        for (i, tok) in vocab.iter().enumerate() {
            let mut chars = tok.chars();
            if let (Some(c), None) = (chars.next(), chars.next()) {
                lookup.insert(c, i as i32);
            }
        }
        Self { vocab, lookup, pad_id, bos_id, eos_id }
    }

    pub fn vocab_len(&self) -> usize {
        self.vocab.len()
    }

    /// Encode a prompt: BOS + chars. Unknown chars are an error (the task
    /// generator only emits vocab chars).
    pub fn encode(&self, text: &str) -> Result<Vec<i32>> {
        let mut ids = Vec::with_capacity(text.len() + 1);
        ids.push(self.bos_id);
        for c in text.chars() {
            match self.lookup.get(&c) {
                Some(&id) => ids.push(id),
                None => bail!("character {c:?} not in vocab"),
            }
        }
        Ok(ids)
    }

    /// Decode ids to text, stopping at EOS and skipping specials.
    pub fn decode(&self, ids: &[i32]) -> String {
        let mut out = String::new();
        for &id in ids {
            if id == self.eos_id {
                break;
            }
            if id == self.pad_id || id == self.bos_id {
                continue;
            }
            if let Some(tok) = self.vocab.get(id as usize) {
                if tok.chars().count() == 1 {
                    out.push_str(tok);
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Tokenizer {
        let mut vocab: Vec<String> =
            ["<pad>", "<bos>", "<eos>"].iter().map(|s| s.to_string()).collect();
        for c in "0123456789+-*/=()., ?".chars() {
            vocab.push(c.to_string());
        }
        Tokenizer::new(vocab, 0, 1, 2)
    }

    #[test]
    fn round_trip() {
        let t = toy();
        let ids = t.encode("12+34=").unwrap();
        assert_eq!(ids[0], t.bos_id);
        assert_eq!(t.decode(&ids), "12+34=");
    }

    #[test]
    fn decode_stops_at_eos() {
        let t = toy();
        let mut ids = t.encode("7").unwrap();
        ids.push(t.eos_id);
        ids.extend(t.encode("9").unwrap());
        assert_eq!(t.decode(&ids), "7");
    }

    #[test]
    fn unknown_char_errors() {
        let t = toy();
        assert!(t.encode("abc").is_err() || t.encode("Z").is_err());
    }

    #[test]
    fn pad_skipped() {
        let t = toy();
        let ids = vec![0, 0, 1, 3, 4, 0];
        assert_eq!(t.decode(&ids), "01");
    }
}
