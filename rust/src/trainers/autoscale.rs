//! Elastic data-parallel stage replicas: configuration, backlog-driven
//! autoscaling, and the replica-set bookkeeping shared by the pipelined
//! executor and the chaos harness.
//!
//! The paper's scalability claim is that throughput scales by *widening*
//! the dataflow graph's nodes, not only by pipelining them (DistFlow's
//! fully-distributed multi-worker stages; HybridFlow's tunable per-stage
//! resource ratios). PR 4's lease-based claims already make concurrent
//! pullers safe by construction — a grant latches a sample for exactly
//! one worker while its lease is live — so a worker state can run `N ≥ 1`
//! replica threads against the same controller with no new dispatch
//! machinery.
//!
//! On top of static replica counts ([`StageReplicas`], the
//! `--stage-replicas gen=4,logprob=2` flag), the [`Autoscaler`] grows and
//! shrinks each stage's replica set from two *logical* observations taken
//! on the driving executor's lease ticks:
//!
//! * **backlog** — the stage controller's ready-and-unclaimed queue depth
//!   (`SampleFlow::ready_depth`), and
//! * **idle ratio** — how many live replicas are currently not processing
//!   a claimed batch.
//!
//! Decisions are pure functions of tick counts and observed depths —
//! never wall time — so autoscaled runs stay reproducible in the same
//! sense as the chaos suite: whatever the OS scheduler does, a decision
//! at tick `t` depends only on what the flow looked like at ticks
//! `..= t`. Hysteresis (scale up only after `up_ticks` *consecutive*
//! over-backlog observations, down only after `down_ticks` consecutive
//! idle-and-drained ones) keeps an oscillating backlog from flapping the
//! replica count. Scale-down is **drain-then-retire**: the retiring
//! replica's flag is checked only between claim batches, so a live lease
//! is never abandoned — the replica finishes (and writes back) whatever
//! it holds, then exits.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::metrics::{StageScale, StageScaling};
use crate::transfer_dock::Stage;

/// The four pull-driven worker states replicas apply to. The update
/// state is the driver (it owns the policy and the lease clock) and is
/// never replicated — the analogue of the paper's controller process.
pub const SCALABLE_STAGES: [Stage; 4] =
    [Stage::Generation, Stage::OldLogprob, Stage::RefLogprob, Stage::Reward];

/// Per-stage replica counts for the pull-driven worker states.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StageReplicas {
    pub generation: usize,
    pub old_logprob: usize,
    pub ref_logprob: usize,
    pub reward: usize,
}

impl Default for StageReplicas {
    fn default() -> Self {
        Self { generation: 1, old_logprob: 1, ref_logprob: 1, reward: 1 }
    }
}

impl StageReplicas {
    pub fn get(&self, stage: Stage) -> usize {
        match stage {
            Stage::Generation => self.generation,
            Stage::OldLogprob => self.old_logprob,
            Stage::RefLogprob => self.ref_logprob,
            Stage::Reward => self.reward,
            Stage::Update => 1,
        }
    }

    pub fn set(&mut self, stage: Stage, n: usize) {
        match stage {
            Stage::Generation => self.generation = n,
            Stage::OldLogprob => self.old_logprob = n,
            Stage::RefLogprob => self.ref_logprob = n,
            Stage::Reward => self.reward = n,
            Stage::Update => {}
        }
    }

    /// Every stage at one replica (the pre-elastic executor shape).
    pub fn all_single(&self) -> bool {
        self.max_count() == 1
    }

    pub fn max_count(&self) -> usize {
        self.generation.max(self.old_logprob).max(self.ref_logprob).max(self.reward)
    }

    pub fn min_count(&self) -> usize {
        self.generation.min(self.old_logprob).min(self.ref_logprob).min(self.reward)
    }

    /// Uniform count for every pull-driven stage.
    pub fn uniform(n: usize) -> Self {
        Self { generation: n, old_logprob: n, ref_logprob: n, reward: n }
    }

    /// Parse the `--stage-replicas` syntax: comma-separated `key=count`
    /// pairs, e.g. `gen=4,logprob=2`. Unnamed stages keep 1 replica.
    /// Accepted keys (aliases): `gen|generation`, `logprob|old_logprob`,
    /// `ref|reference|ref_logprob`, `reward`.
    pub fn parse(s: &str) -> Result<Self> {
        let mut out = Self::default();
        for part in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (key, val) = part
                .split_once('=')
                .ok_or_else(|| anyhow!("--stage-replicas expects key=count, got {part:?}"))?;
            let n: usize = val
                .trim()
                .parse()
                .map_err(|_| anyhow!("--stage-replicas {key}: bad count {val:?}"))?;
            anyhow::ensure!(n >= 1, "--stage-replicas {key}: count must be >= 1");
            let stage = match key.trim() {
                "gen" | "generation" => Stage::Generation,
                "logprob" | "old_logprob" => Stage::OldLogprob,
                "ref" | "reference" | "ref_logprob" => Stage::RefLogprob,
                "reward" => Stage::Reward,
                other => {
                    anyhow::bail!(
                        "--stage-replicas: unknown stage {other:?} \
                         (gen|logprob|ref|reward)"
                    )
                }
            };
            out.set(stage, n);
        }
        Ok(out)
    }

    pub fn describe(&self) -> String {
        format!(
            "gen={} logprob={} ref={} reward={}",
            self.generation, self.old_logprob, self.ref_logprob, self.reward
        )
    }
}

/// Autoscaler knobs. Thresholds are in samples (controller ready-queue
/// depth); windows are in lease-clock ticks' worth of consecutive
/// observations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AutoscaleConfig {
    /// never shrink a stage below this many replicas
    pub min_replicas: usize,
    /// never grow a stage beyond this many replicas
    pub max_replicas: usize,
    /// scale-up pressure: backlog above this depth with zero idle
    /// replicas counts as an over-backlog observation
    pub backlog_hi: usize,
    /// scale-down pressure: backlog at or below this depth with at least
    /// one idle replica counts as an idle observation
    pub backlog_lo: usize,
    /// consecutive over-backlog observations before growing by one
    pub up_ticks: u32,
    /// consecutive idle observations before retiring one replica
    pub down_ticks: u32,
}

impl Default for AutoscaleConfig {
    fn default() -> Self {
        Self {
            min_replicas: 1,
            max_replicas: 4,
            backlog_hi: 16,
            backlog_lo: 0,
            up_ticks: 3,
            down_ticks: 6,
        }
    }
}

impl AutoscaleConfig {
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.min_replicas >= 1, "autoscale min_replicas must be >= 1");
        anyhow::ensure!(
            self.max_replicas >= self.min_replicas,
            "autoscale max_replicas {} below min_replicas {}",
            self.max_replicas,
            self.min_replicas
        );
        anyhow::ensure!(
            self.backlog_hi > self.backlog_lo,
            "autoscale backlog_hi ({}) must exceed backlog_lo ({})",
            self.backlog_hi,
            self.backlog_lo
        );
        anyhow::ensure!(self.up_ticks >= 1, "autoscale up_ticks must be >= 1");
        anyhow::ensure!(self.down_ticks >= 1, "autoscale down_ticks must be >= 1");
        Ok(())
    }
}

/// What the autoscaler wants done to one stage's replica set.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleDecision {
    /// spawn one more replica
    Grow,
    /// drain-then-retire one replica
    Shrink,
    Hold,
}

#[derive(Debug, Default)]
struct StageState {
    /// consecutive over-backlog observations
    over: u32,
    /// consecutive idle-and-drained observations
    under: u32,
}

/// Backlog-driven replica autoscaler. Pure bookkeeping: the caller (the
/// update thread, or the chaos-harness driver) takes the observations on
/// its lease ticks and applies the decisions; this type only decides and
/// records the [`StageScaling`] report.
#[derive(Debug)]
pub struct Autoscaler {
    pub cfg: AutoscaleConfig,
    state: BTreeMap<&'static str, StageState>,
    scaling: StageScaling,
}

impl Autoscaler {
    pub fn new(cfg: AutoscaleConfig) -> Self {
        Self { cfg, state: BTreeMap::new(), scaling: StageScaling::default() }
    }

    /// One observation of `stage` at logical tick `tick`: ready-queue
    /// depth `backlog`, `live` replicas (of which `idle` are not
    /// currently processing) plus `draining` retired-but-not-yet-exited
    /// ones. Returns the (already-bounded) decision; the caller must
    /// apply it, and decisions assume the caller does.
    ///
    /// The grow bound counts `live + draining`: a draining replica still
    /// runs a thread, holds its weight view, and pulls from the
    /// controller until it observes its flag, so `max_replicas` caps the
    /// *actual* concurrent replica count, not just the target.
    ///
    /// Hysteresis: the over/under counters reset whenever the opposing
    /// (or neutral) condition is observed, so an oscillating backlog
    /// never accumulates enough consecutive pressure to flap.
    pub fn observe(
        &mut self,
        stage: Stage,
        tick: u64,
        backlog: usize,
        live: usize,
        draining: usize,
        idle: usize,
    ) -> ScaleDecision {
        let cfg = self.cfg;
        let st = self.state.entry(stage.name()).or_default();
        let scale = self.scaling.stages.entry(stage.name().to_string()).or_default();
        scale.obs += 1;
        scale.backlog_high_water = scale.backlog_high_water.max(backlog);
        if idle > 0 {
            scale.idle_obs += 1;
        }
        if backlog > cfg.backlog_hi && idle == 0 {
            st.over += 1;
            st.under = 0;
        } else if backlog <= cfg.backlog_lo && idle > 0 {
            st.under += 1;
            st.over = 0;
        } else {
            st.over = 0;
            st.under = 0;
        }
        if st.over >= cfg.up_ticks && live + draining < cfg.max_replicas {
            st.over = 0;
            scale.grows += 1;
            scale.timeline.push((tick, live + 1));
            return ScaleDecision::Grow;
        }
        if st.under >= cfg.down_ticks && live > cfg.min_replicas {
            st.under = 0;
            scale.shrinks += 1;
            scale.timeline.push((tick, live - 1));
            return ScaleDecision::Shrink;
        }
        ScaleDecision::Hold
    }

    /// The scaling report accumulated so far (the caller fills in the
    /// wall-clock fields — `replica_secs`, initial/final counts — that
    /// only the replica sets know).
    pub fn into_report(self) -> StageScaling {
        self.scaling
    }
}

/// One replica's control handles: the drain-then-retire flag the set
/// flips, and the exited flag the replica's thread sets when its
/// supervisor loop returns for good.
struct Slot {
    retire: Arc<AtomicBool>,
    exited: Arc<AtomicBool>,
}

/// One stage's live replica set: retire flags for drain-then-retire
/// scale-down, a shared busy counter for idle-ratio observations, and
/// replica-second accounting (the denominator of replica-aware
/// utilization in [`crate::metrics::PipelineReport`]).
///
/// Slot-time accounting must *bound busy time from above* so
/// utilization never exceeds 1: a retired replica keeps draining its
/// claimed batch (and may even claim one more before it observes the
/// flag), so it moves to a `draining` list and keeps accruing slot time
/// until its thread confirms exit — never the reverse. Callers finalize
/// (`finish_into`) only after every replica thread has joined, at which
/// point the busy totals are final too.
pub struct ReplicaSet {
    pub stage: Stage,
    /// live replicas, in spawn order; `shrink` retires the most
    /// recently spawned one
    slots: Vec<Slot>,
    /// retired replicas whose threads have not yet confirmed exit:
    /// still occupying a slot for accounting purposes
    draining: Vec<Slot>,
    /// replicas currently inside a claimed batch (shared with the
    /// replica threads)
    busy: Arc<AtomicUsize>,
    next_id: usize,
    initial: usize,
    max_seen: usize,
    replica_secs: f64,
    last_change: Instant,
}

impl ReplicaSet {
    pub fn new(stage: Stage) -> Self {
        Self {
            stage,
            slots: Vec::new(),
            draining: Vec::new(),
            busy: Arc::new(AtomicUsize::new(0)),
            next_id: 0,
            initial: 0,
            max_seen: 0,
            replica_secs: 0.0,
            last_change: Instant::now(),
        }
    }

    /// Charge slot time for every live *and still-draining* replica,
    /// then drop draining entries whose threads have exited. Charging
    /// up to the sweep (not the unobservable exit instant) overcounts
    /// the denominator slightly — the safe direction for a utilization
    /// that must stay ≤ 1.
    fn account(&mut self) {
        let now = Instant::now();
        let occupied = self.slots.len() + self.draining.len();
        self.replica_secs += occupied as f64 * now.duration_since(self.last_change).as_secs_f64();
        self.last_change = now;
        self.draining.retain(|s| !s.exited.load(Ordering::Acquire));
    }

    /// Add one replica: `spawn` receives the replica id, its retire
    /// flag, the stage's shared busy counter, and the exited flag the
    /// thread must set (Release) when its supervisor loop returns.
    pub fn grow(
        &mut self,
        spawn: impl FnOnce(usize, Arc<AtomicBool>, Arc<AtomicUsize>, Arc<AtomicBool>),
    ) {
        self.account();
        let slot = Slot {
            retire: Arc::new(AtomicBool::new(false)),
            exited: Arc::new(AtomicBool::new(false)),
        };
        let id = self.next_id;
        self.next_id += 1;
        let (retire, exited) = (Arc::clone(&slot.retire), Arc::clone(&slot.exited));
        self.slots.push(slot);
        self.max_seen = self.max_seen.max(self.slots.len());
        spawn(id, retire, Arc::clone(&self.busy), exited);
    }

    /// Record the post-initial-spawn count as the run's starting point.
    pub fn mark_initial(&mut self) {
        self.initial = self.slots.len();
    }

    /// Drain-then-retire the most recent replica: its flag flips, and
    /// the worker exits at its next between-batches check — while it
    /// holds claims it keeps processing, so no live lease is abandoned
    /// (and its slot time keeps accruing until the thread exits).
    /// Returns false when no replica is left to retire.
    pub fn shrink(&mut self) -> bool {
        self.account();
        match self.slots.pop() {
            Some(slot) => {
                slot.retire.store(true, Ordering::Relaxed);
                self.draining.push(slot);
                true
            }
            None => false,
        }
    }

    pub fn live(&self) -> usize {
        self.slots.len()
    }

    /// Retired replicas whose threads have not yet confirmed exit.
    pub fn draining_count(&self) -> usize {
        self.draining.len()
    }

    pub fn idle(&self) -> usize {
        self.slots.len().saturating_sub(self.busy.load(Ordering::Relaxed))
    }

    pub fn busy_handle(&self) -> Arc<AtomicUsize> {
        Arc::clone(&self.busy)
    }

    /// Close the accounting and fold this set's wall-clock numbers into
    /// the stage's [`StageScale`] entry. Call only after the replica
    /// threads have joined (busy totals final, no further slot time).
    pub fn finish_into(&mut self, scale: &mut StageScale) {
        self.account();
        scale.initial = self.initial;
        scale.final_replicas = self.slots.len();
        scale.max_replicas = scale.max_replicas.max(self.max_seen);
        scale.replica_secs = self.replica_secs;
    }
}

/// The replica-scaling driver protocol, shared by the pipelined
/// executor and the chaos harness so the two cannot drift. `spawn`
/// receives `(stage, replica id, retire flag, busy counter, exited
/// flag)` and must start the worker thread.
///
/// Spawn the configured initial replicas and register the puller counts.
pub fn spawn_initial(
    sets: &mut [ReplicaSet],
    flow: &dyn crate::transfer_dock::SampleFlow,
    counts: StageReplicas,
    mut spawn: impl FnMut(Stage, usize, Arc<AtomicBool>, Arc<AtomicUsize>, Arc<AtomicBool>),
) {
    for set in sets.iter_mut() {
        let stage = set.stage;
        for _ in 0..counts.get(stage) {
            set.grow(|id, retire, busy, exited| spawn(stage, id, retire, busy, exited));
        }
        set.mark_initial();
        flow.note_pullers(stage, set.live());
    }
}

/// One autoscale round at lease tick `tick`: observe every stage's
/// backlog and idle ratio, apply the decisions (spawning replicas via
/// `spawn`, drain-then-retiring via the retire flags), and keep the
/// flow's puller registration current.
pub fn observe_and_scale(
    scaler: &mut Autoscaler,
    sets: &mut [ReplicaSet],
    flow: &dyn crate::transfer_dock::SampleFlow,
    tick: u64,
    mut spawn: impl FnMut(Stage, usize, Arc<AtomicBool>, Arc<AtomicUsize>, Arc<AtomicBool>),
) {
    for set in sets.iter_mut() {
        let stage = set.stage;
        let backlog = flow.ready_depth(stage);
        let decision =
            scaler.observe(stage, tick, backlog, set.live(), set.draining_count(), set.idle());
        match decision {
            ScaleDecision::Grow => {
                set.grow(|id, retire, busy, exited| spawn(stage, id, retire, busy, exited));
                flow.note_pullers(stage, set.live());
            }
            ScaleDecision::Shrink => {
                if set.shrink() {
                    flow.note_pullers(stage, set.live());
                }
            }
            ScaleDecision::Hold => {}
        }
    }
}

/// Close the run's replica accounting: autoscaler decision report plus
/// every set's slot time. Call only after the replica threads joined.
pub fn finish_scaling(scaler: Option<Autoscaler>, sets: &mut [ReplicaSet]) -> StageScaling {
    let mut scaling = scaler.map(Autoscaler::into_report).unwrap_or_default();
    for set in sets.iter_mut() {
        let entry = scaling.stages.entry(set.stage.name().to_string()).or_default();
        set.finish_into(entry);
    }
    scaling
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_replicas_parse_and_aliases() {
        let r = StageReplicas::parse("gen=4,logprob=2").unwrap();
        assert_eq!(r.generation, 4);
        assert_eq!(r.old_logprob, 2);
        assert_eq!(r.ref_logprob, 1);
        assert_eq!(r.reward, 1);
        assert_eq!(r.get(Stage::Update), 1, "the update driver is never replicated");
        assert!(!r.all_single());
        assert_eq!(r.max_count(), 4);

        let r = StageReplicas::parse("generation=2, reference=3 ,reward=2").unwrap();
        assert_eq!((r.generation, r.ref_logprob, r.reward), (2, 3, 2));
        assert!(StageReplicas::parse("").unwrap().all_single());

        for bad in ["gen", "gen=0", "gen=x", "warp=2"] {
            assert!(StageReplicas::parse(bad).is_err(), "{bad:?} must be rejected");
        }
    }

    #[test]
    fn autoscale_config_validates() {
        assert!(AutoscaleConfig::default().validate().is_ok());
        let bad = AutoscaleConfig { min_replicas: 0, ..Default::default() };
        assert!(bad.validate().is_err());
        let bad = AutoscaleConfig { max_replicas: 1, min_replicas: 2, ..Default::default() };
        assert!(bad.validate().is_err());
        let bad = AutoscaleConfig { backlog_hi: 0, backlog_lo: 0, ..Default::default() };
        assert!(bad.validate().is_err());
        let bad = AutoscaleConfig { up_ticks: 0, ..Default::default() };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn scale_up_requires_consecutive_pressure() {
        let cfg = AutoscaleConfig { up_ticks: 3, backlog_hi: 4, ..Default::default() };
        let mut a = Autoscaler::new(cfg);
        // two over-backlog ticks, then relief: counter must reset
        for t in 0..2 {
            assert_eq!(a.observe(Stage::Generation, t, 10, 1, 0, 0), ScaleDecision::Hold);
        }
        assert_eq!(a.observe(Stage::Generation, 2, 0, 1, 0, 1), ScaleDecision::Hold);
        for t in 3..5 {
            assert_eq!(a.observe(Stage::Generation, t, 10, 1, 0, 0), ScaleDecision::Hold);
        }
        // third consecutive over-backlog observation grows
        assert_eq!(a.observe(Stage::Generation, 5, 10, 1, 0, 0), ScaleDecision::Grow);
        let report = a.into_report();
        let g = &report.stages["generation"];
        assert_eq!(g.grows, 1);
        assert_eq!(g.backlog_high_water, 10);
        assert_eq!(g.timeline, vec![(5, 2)]);
    }

    #[test]
    fn oscillating_backlog_never_flaps() {
        // alternating hi/lo observations: neither counter can reach its
        // threshold, so the replica count must never change
        let cfg = AutoscaleConfig { up_ticks: 2, down_ticks: 2, backlog_hi: 4, ..Default::default() };
        let mut a = Autoscaler::new(cfg);
        for t in 0..100 {
            let d = if t % 2 == 0 {
                a.observe(Stage::Reward, t, 10, 2, 0, 0) // pressure
            } else {
                a.observe(Stage::Reward, t, 0, 2, 0, 1) // idle
            };
            assert_eq!(d, ScaleDecision::Hold, "flap at tick {t}");
        }
        let report = a.into_report();
        let g = &report.stages["reward"];
        assert_eq!(g.grows + g.shrinks, 0);
    }

    #[test]
    fn bounds_and_shrink_hysteresis() {
        let cfg = AutoscaleConfig {
            min_replicas: 1,
            max_replicas: 2,
            up_ticks: 1,
            down_ticks: 2,
            backlog_hi: 2,
            ..Default::default()
        };
        let mut a = Autoscaler::new(cfg);
        assert_eq!(a.observe(Stage::Generation, 0, 5, 1, 0, 0), ScaleDecision::Grow);
        // at max: pressure no longer grows
        assert_eq!(a.observe(Stage::Generation, 1, 5, 2, 0, 0), ScaleDecision::Hold);
        assert_eq!(a.observe(Stage::Generation, 2, 5, 2, 0, 0), ScaleDecision::Hold);
        // drained + idle long enough: shrink, but never below min
        assert_eq!(a.observe(Stage::Generation, 3, 0, 2, 0, 2), ScaleDecision::Hold);
        assert_eq!(a.observe(Stage::Generation, 4, 0, 2, 0, 2), ScaleDecision::Shrink);
        assert_eq!(a.observe(Stage::Generation, 5, 0, 1, 0, 1), ScaleDecision::Hold);
        assert_eq!(a.observe(Stage::Generation, 6, 0, 1, 0, 1), ScaleDecision::Hold);
        assert_eq!(
            a.observe(Stage::Generation, 7, 0, 1, 0, 1),
            ScaleDecision::Hold,
            "min_replicas must floor scale-down"
        );
    }

    #[test]
    fn draining_replicas_count_toward_the_max_bound() {
        // a retired-but-still-draining replica occupies a real thread
        // and weight copy: live=1 + draining=1 at max=2 must not grow,
        // or the actual concurrent count would exceed the cap
        let cfg = AutoscaleConfig { max_replicas: 2, up_ticks: 1, backlog_hi: 2, ..Default::default() };
        let mut a = Autoscaler::new(cfg);
        assert_eq!(a.observe(Stage::Generation, 0, 9, 1, 1, 0), ScaleDecision::Hold);
        assert_eq!(a.observe(Stage::Generation, 1, 9, 1, 1, 0), ScaleDecision::Hold);
        // the drained thread exits: the slot frees and growth resumes
        assert_eq!(a.observe(Stage::Generation, 2, 9, 1, 0, 0), ScaleDecision::Grow);
    }

    #[test]
    fn busy_replicas_block_scale_down() {
        // backlog drained but every replica is mid-batch: not idle, so
        // no shrink pressure accumulates (drain-then-retire would have
        // nobody safe to retire)
        let cfg = AutoscaleConfig { down_ticks: 1, ..Default::default() };
        let mut a = Autoscaler::new(cfg);
        for t in 0..10 {
            assert_eq!(a.observe(Stage::OldLogprob, t, 0, 2, 0, 0), ScaleDecision::Hold);
        }
    }

    #[test]
    fn replica_set_accounting() {
        let mut set = ReplicaSet::new(Stage::Generation);
        let mut spawned = Vec::new();
        for _ in 0..3 {
            set.grow(|id, retire, busy, exited| {
                spawned.push((id, retire, busy, exited));
            });
        }
        set.mark_initial();
        assert_eq!(set.live(), 3);
        assert_eq!(set.idle(), 3);
        // a replica goes busy
        spawned[0].2.fetch_add(1, Ordering::Relaxed);
        assert_eq!(set.idle(), 2);
        // shrink retires the most recent spawn via its flag; until the
        // thread confirms exit the slot still counts toward slot time
        assert!(set.shrink());
        assert_eq!(set.live(), 2);
        assert!(spawned[2].1.load(Ordering::Relaxed), "retire flag must flip");
        assert!(!spawned[0].1.load(Ordering::Relaxed));
        assert_eq!(set.draining.len(), 1, "retired replica drains until exit");
        // the thread exits: the next accounting sweep clears it
        spawned[2].3.store(true, Ordering::Release);
        set.account();
        assert!(set.draining.is_empty(), "exited replica must leave the drain list");
        let mut scale = StageScale::default();
        set.finish_into(&mut scale);
        assert_eq!(scale.initial, 3);
        assert_eq!(scale.final_replicas, 2);
        assert_eq!(scale.max_replicas, 3);
        assert!(scale.replica_secs >= 0.0);
    }
}
