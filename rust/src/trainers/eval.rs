//! Benchmark evaluation harness (Table 3 substitute): pass@1 / avg@k on
//! held-out synthetic tiers.

use anyhow::Result;

use crate::data::{TaskGenerator, Tier};
use crate::generation::{GenEngine, GenRequest, SamplingParams};
use crate::rewards;
use crate::runtime::{Engine, Policy};
use crate::tokenizer::Tokenizer;
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct EvalResult {
    pub tier: Tier,
    pub n_tasks: usize,
    pub k: usize,
    /// fraction of tasks whose greedy (or first) completion is exact
    pub pass_at_1: f64,
    /// mean exact rate over k samples per task (paper's Avg@k)
    pub avg_at_k: f64,
}

/// Evaluate the current policy on all three tiers.
pub fn evaluate(
    engine: &Engine,
    policy: &Policy,
    n_per_tier: usize,
    seed: u64,
    k: usize,
) -> Result<Vec<EvalResult>> {
    let tokenizer = Tokenizer::from_manifest(&engine.manifest);
    let mut results = Vec::new();
    for tier in Tier::all() {
        let tasks = TaskGenerator::eval_set(seed, tier, n_per_tier);
        let params = if k <= 1 {
            SamplingParams::greedy()
        } else {
            SamplingParams { temperature: 0.7, top_k: 0 }
        };
        let ge = GenEngine::from_manifest(engine, params)?;
        let mut rng = Rng::new(seed ^ EVAL_RNG_SALT);
        let mut requests = Vec::new();
        for (ti, t) in tasks.iter().enumerate() {
            for ki in 0..k.max(1) {
                requests.push(GenRequest {
                    id: (ti * k.max(1) + ki) as u64,
                    prompt_ids: tokenizer.encode(&t.prompt)?,
                    max_new_tokens: 8,
                });
            }
        }
        let (gen_results, _) = ge.generate(engine, policy, requests, &mut rng)?;
        let mut exact_first = 0usize;
        let mut exact_total = 0usize;
        for r in &gen_results {
            let ti = (r.id as usize) / k.max(1);
            let ki = (r.id as usize) % k.max(1);
            let text = tokenizer.decode(&r.response_ids);
            let score = rewards::score(&tasks[ti], &text);
            if score.exact {
                exact_total += 1;
                if ki == 0 {
                    exact_first += 1;
                }
            }
        }
        results.push(EvalResult {
            tier,
            n_tasks: tasks.len(),
            k: k.max(1),
            pass_at_1: exact_first as f64 / tasks.len().max(1) as f64,
            avg_at_k: exact_total as f64 / gen_results.len().max(1) as f64,
        });
    }
    Ok(results)
}

const EVAL_RNG_SALT: u64 = 0x5EED_E7A1;
