//! Pipelined dataflow executor: runs the GRPO worker states either as the
//! classic barrier-per-stage loop (`sync`) or as concurrent stage workers
//! driven by the transfer dock (`pipelined`).
//!
//! The paper models RL training as a dataflow graph whose nodes are worker
//! states (Fig. 1) and gives each state its own TD controller precisely so
//! stages can pull work independently. `sync` mode keeps the historical
//! semantics — admit → generate-until-drained → infer → reward → update as
//! strict sequential barriers, bit-identical to the seed trainer for a
//! given seed. `pipelined` mode turns each state into a long-lived thread
//! blocking on [`SampleFlow::wait_ready`]: a sample proceeds to
//! old-logprobs the moment its generation lands, and generation of
//! iteration `k+1` overlaps the update of iteration `k` up to a bounded
//! off-policy staleness window (`max_inflight_iters`).
//!
//! Weight flow in pipelined mode mirrors the paper's train→infer
//! resharding: the update thread owns the authoritative [`Policy`] and
//! publishes each post-update snapshot on the versioned
//! [`WeightBus`](crate::weights::WeightBus) (shard-level deduplicated
//! retention, charged to a tracked `weightbus` memory pool); publication
//! returns a monotonically increasing
//! [`WeightVersion`](crate::weights::WeightVersion).
//! The generation thread refreshes a head-tracking replica between
//! batches and stamps every sample it writes back with the version it
//! generated under; the old-logprob thread then scores each claimed
//! batch under the sample's *recorded* version (a ring `get`, not the
//! bus head), so the GRPO ratio's denominator is the true behavior
//! policy even while generation runs ahead of the update. See DESIGN.md.

use anyhow::{anyhow, Result};
use std::collections::{BTreeMap, HashMap, HashSet, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::data::TaskGenerator;
use crate::generation::{
    GenEngine, GenSession, KvBlockAllocator, SamplingParams, SeqExport, StreamConfig,
};
use crate::memory::{MemoryPool, TenantQuotas};
use crate::metrics::{
    throughput_tps, PartialRolloutReport, PipelineReport, StageScaling, StageTimers,
    StreamGenReport, TenantLane, TenantReport, VersionLag,
};
use crate::rewards::group_advantages;
use crate::runtime::{Engine, Policy, Tensor, TrainStats};
use crate::tokenizer::Tokenizer;
use crate::transfer_dock::{
    push_segment, FieldKind, NetworkModel, PartialRollout, Sample, SampleFlow, SampleMeta,
    Segment, Stage,
};
use crate::util::rng::Rng;
use crate::weights::{ReplicaCache, WeightBus, WeightReplica, WeightVersion};
use crate::workers::{ActorWorker, ReferenceWorker, RewardWorker};

use super::autoscale::{
    finish_scaling, observe_and_scale, spawn_initial, Autoscaler, ReplicaSet, SCALABLE_STAGES,
};
use super::eval::evaluate;
use super::faults::{FaultInjector, FaultKind, StageExit};
use super::grpo::{assemble_batch, GrpoConfig, IterationMetrics, TrainReport};
use super::tenancy::TenantSet;

/// Which execution model drives the worker states.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PipelineMode {
    /// barrier per stage, one thread (the seed trainer's semantics)
    #[default]
    Sync,
    /// one thread per worker state, samples flow stage-to-stage eagerly
    Pipelined,
}

impl PipelineMode {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "sync" => Ok(PipelineMode::Sync),
            "pipelined" => Ok(PipelineMode::Pipelined),
            other => Err(anyhow!("unknown pipeline mode {other:?} (sync|pipelined)")),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            PipelineMode::Sync => "sync",
            PipelineMode::Pipelined => "pipelined",
        }
    }
}

/// Node placement of the worker states across the simulated cluster.
/// The actor (generation + old-logprob compute) is pinned to one node;
/// reference, reward, and the update state's dock endpoint spread
/// round-robin so the comm ledger sees honest inter-node traffic.
#[derive(Debug, Clone, Copy)]
pub struct StagePlacement {
    pub actor: usize,
    pub reference: usize,
    pub reward: usize,
    pub update: usize,
}

impl StagePlacement {
    pub fn spread(nodes: usize) -> Self {
        let n = nodes.max(1);
        Self { actor: 0, reference: 1 % n, reward: 2 % n, update: 3 % n }
    }
}

/// how many generation-ready samples one claim may take (sync parity: 64)
const GEN_MAX_BATCH: usize = 64;
const REWARD_MAX_BATCH: usize = 64;
/// stage-worker wait quantum; only bounds shutdown latency (wakeups are
/// condvar-driven, not polled)
const STAGE_WAIT: Duration = Duration::from_millis(50);
const UPDATE_WAIT: Duration = Duration::from_millis(50);

/// Run under the configured mode.
pub(crate) fn run(
    engine: &Engine,
    cfg: &GrpoConfig,
    flow: Arc<dyn SampleFlow>,
) -> Result<TrainReport> {
    cfg.validate()?;
    match cfg.pipeline {
        PipelineMode::Sync => run_sync(engine, cfg, flow),
        PipelineMode::Pipelined => run_pipelined(engine, cfg, flow),
    }
}

/// Admit iteration `iter`'s G × N prompt samples into the flow.
///
/// Tenancy: the deterministic prompt stream stripes round-robin over the
/// roster by *group id* (one prompt = one group = one tenant — GRPO's
/// within-group advantage normalization must never span tenants), so the
/// i-th group tenant `t` sees in a shared run is exactly the i-th group
/// it would admit running isolated on its slice (the re-keying
/// `tests/multi_tenant.rs` relies on). A single-tenant roster tags
/// everything 0 and charges nothing: bit-identical to the pre-tenancy
/// admission path.
///
/// `backlog` enables per-tenant admission backpressure (pipelined mode):
/// a sample whose tenant is over quota — or queued behind earlier
/// deferred samples of the same tenant — parks in that tenant's FIFO
/// instead of entering the dock, and only that tenant waits. Sync mode
/// passes `None`: its barrier retires the whole iteration before the next
/// admission, so deferral would deadlock the barrier and quota pressure
/// degenerates to accounting (high-water, over-quota visibility).
fn admit_iteration(
    flow: &dyn SampleFlow,
    task_gen: &mut TaskGenerator,
    cfg: &GrpoConfig,
    iter: usize,
    roster: &TenantSet,
    charges: &mut PayloadCharges,
    backlog: Option<&mut BTreeMap<u32, VecDeque<Sample>>>,
) -> Result<()> {
    let tasks = task_gen.batch(cfg.prompts_per_iter);
    let mut samples = Vec::with_capacity(cfg.prompts_per_iter * cfg.group_size);
    for (gi, t) in tasks.iter().enumerate() {
        let group = (iter * cfg.prompts_per_iter + gi) as u64;
        let tenant = roster.tenant_of_position(group);
        for _ in 0..cfg.group_size {
            samples.push(
                Sample::new_prompt(u64::MAX, group, t.prompt.clone(), t.answer)
                    .with_tenant(tenant),
            );
        }
    }
    match backlog {
        Some(backlog) => admit_or_defer(flow, charges, backlog, samples),
        None => {
            for s in &samples {
                charges.charge(s.tenant, s.payload_bytes() as u64);
            }
            flow.put_samples(samples)
        }
    }
}

/// Per-tenant payload-residency charges held between admission and
/// retirement. Sample indices are assigned *inside* `put_samples`, so the
/// retire path cannot look its own admission charge up by index; instead
/// each tenant's open charges retire FIFO — conservation is exact (every
/// charge is uncharged exactly once) even when groups complete out of
/// admission order, and the instantaneous ledger is off by at most the
/// spread of per-sample payload sizes within one tenant.
///
/// Liveness: payload admission is soft-capped at **half** the tenant's
/// quota (`soft_cap`). The other half stays reserved for the KV side —
/// `KvBlockAllocator::try_admit_for` refuses strictly at the quota, so an
/// admission wave that consumed the whole budget would wedge the tenant's
/// own decode admission permanently (payload only drains at retire, and
/// retire needs decode). With the reserve, an admitted sample can always
/// eventually decode.
struct PayloadCharges {
    quotas: Option<Arc<TenantQuotas>>,
    /// per-tenant open admission charges, oldest first
    open: BTreeMap<u32, VecDeque<u64>>,
    /// per-tenant sum of `open` (the payload-only residency)
    held: BTreeMap<u32, u64>,
    /// half the tenant's quota; absent = uncapped
    soft_cap: BTreeMap<u32, u64>,
}

impl PayloadCharges {
    fn new(roster: &TenantSet, quotas: Option<Arc<TenantQuotas>>) -> Self {
        let soft_cap = roster
            .specs()
            .iter()
            .filter_map(|s| s.quota_bytes.map(|q| (s.id, (q / 2).max(1))))
            .collect();
        Self { quotas, open: BTreeMap::new(), held: BTreeMap::new(), soft_cap }
    }

    /// Would admitting another sample for `tenant` right now defer it?
    fn would_defer(&self, tenant: u32) -> bool {
        let Some(q) = &self.quotas else { return false };
        if q.over_quota(tenant) {
            return true;
        }
        match self.soft_cap.get(&tenant) {
            Some(cap) => self.held.get(&tenant).copied().unwrap_or(0) >= *cap,
            None => false,
        }
    }

    /// Charge an admission (forced: the breaching sample still enters —
    /// the backpressure point is the *next* admission).
    fn charge(&mut self, tenant: u32, bytes: u64) {
        let Some(q) = &self.quotas else { return };
        q.charge_forced(tenant, bytes);
        self.open.entry(tenant).or_default().push_back(bytes);
        *self.held.entry(tenant).or_insert(0) += bytes;
    }

    fn note_deferral(&self, tenant: u32) {
        if let Some(q) = &self.quotas {
            q.note_deferral(tenant);
        }
    }

    /// Retire one of `tenant`'s admissions: pop its oldest open charge.
    fn release(&mut self, tenant: u32) {
        let Some(q) = &self.quotas else { return };
        if let Some(bytes) = self.open.get_mut(&tenant).and_then(|d| d.pop_front()) {
            q.uncharge(tenant, bytes);
            let h = self.held.entry(tenant).or_insert(0);
            *h = h.saturating_sub(bytes);
        }
    }
}

/// Admit what fits, defer the rest per tenant. A tenant with queued
/// deferred samples keeps admitting through its queue (FIFO per tenant)
/// even if its quota momentarily reopened mid-batch.
fn admit_or_defer(
    flow: &dyn SampleFlow,
    charges: &mut PayloadCharges,
    backlog: &mut BTreeMap<u32, VecDeque<Sample>>,
    samples: Vec<Sample>,
) -> Result<()> {
    let mut admit = Vec::with_capacity(samples.len());
    for s in samples {
        let t = s.tenant;
        let queued = backlog.get(&t).is_some_and(|d| !d.is_empty());
        if queued || charges.would_defer(t) {
            charges.note_deferral(t);
            backlog.entry(t).or_default().push_back(s);
        } else {
            charges.charge(t, s.payload_bytes() as u64);
            admit.push(s);
        }
    }
    if admit.is_empty() {
        Ok(())
    } else {
        flow.put_samples(admit)
    }
}

/// Drain every tenant's deferred FIFO as far as its reopened quota
/// allows. Deferrals were counted when the samples first parked; a flush
/// retry is not another deferral.
fn flush_deferred(
    flow: &dyn SampleFlow,
    charges: &mut PayloadCharges,
    backlog: &mut BTreeMap<u32, VecDeque<Sample>>,
) -> Result<()> {
    let mut admit = Vec::new();
    for (t, dq) in backlog.iter_mut() {
        while !dq.is_empty() && !charges.would_defer(*t) {
            let s = dq.pop_front().unwrap();
            charges.charge(*t, s.payload_bytes() as u64);
            admit.push(s);
        }
    }
    if admit.is_empty() {
        Ok(())
    } else {
        flow.put_samples(admit)
    }
}

/// Assemble the run's per-tenant lanes: configured weights from the
/// roster, claim counts from the flow's DRR ledger, quota counters from
/// the registry, token counts from the driver's retire loop. Empty for a
/// plain single-tenant run — the report clause stays silent.
fn tenant_report(
    roster: &TenantSet,
    flow: &dyn SampleFlow,
    quotas: Option<&TenantQuotas>,
    tokens: &BTreeMap<u32, u64>,
) -> TenantReport {
    if !roster.is_multi() && quotas.is_none() {
        return TenantReport::default();
    }
    let claims: BTreeMap<u32, u64> = flow.tenant_claims().into_iter().collect();
    let snaps: BTreeMap<u32, crate::memory::TenantQuotaSnapshot> = quotas
        .map(|q| q.snapshot().into_iter().collect())
        .unwrap_or_default();
    let lanes = roster
        .specs()
        .iter()
        .map(|spec| {
            let snap = snaps.get(&spec.id);
            TenantLane {
                tenant: spec.id,
                weight: spec.weight,
                claims: claims.get(&spec.id).copied().unwrap_or(0),
                tokens: tokens.get(&spec.id).copied().unwrap_or(0),
                quota_high_water: snap.map_or(0, |s| s.high_water),
                quota_deferrals: snap.map_or(0, |s| s.deferrals),
                preemptions: snap.map_or(0, |s| s.preemptions),
            }
        })
        .collect();
    TenantReport { lanes }
}

/// Build the run's tenancy context from the config: DRR weights are
/// installed on the flow only for multi-tenant rosters (the single-tenant
/// flow keeps its index-order fast path, bit-identical to pre-tenancy),
/// and the quota registry exists only when some tenant is capped.
fn tenancy_setup(
    cfg: &GrpoConfig,
    flow: &dyn SampleFlow,
) -> Result<(TenantSet, Option<Arc<TenantQuotas>>)> {
    let roster = cfg.tenant_set()?;
    if roster.is_multi() {
        flow.set_tenant_weights(&roster.weights());
    }
    let quotas = roster.has_quotas().then(|| {
        let q = TenantQuotas::new();
        for spec in roster.specs() {
            q.set_quota(spec.id, spec.quota_bytes);
        }
        Arc::new(q)
    });
    Ok((roster, quotas))
}

// ----------------------------------------------------------------- sync

/// The barrier-per-stage loop. This is the seed trainer verbatim modulo
/// two accounting fixes (update-stage comm attributed to its placed node,
/// throughput computed from the samples' real prompt lengths), so for a
/// fixed seed it reproduces the seed's reward/loss numbers exactly.
fn run_sync(
    engine: &Engine,
    cfg: &GrpoConfig,
    flow: Arc<dyn SampleFlow>,
) -> Result<TrainReport> {
    let placement = StagePlacement::spread(cfg.nodes);
    let mut rng = Rng::new(cfg.seed);
    let mut task_gen = TaskGenerator::train(cfg.seed);
    let (roster, quotas) = tenancy_setup(cfg, flow.as_ref())?;
    let mut charges = PayloadCharges::new(&roster, quotas.clone());
    let mut tenant_tokens: BTreeMap<u32, u64> = BTreeMap::new();
    let tokenizer = Tokenizer::from_manifest(&engine.manifest);
    let net = NetworkModel::paper();

    let mut policy = Policy::load_initial(engine, cfg.lr)?;
    let reference = ReferenceWorker::new(engine, placement.reference)?;
    let gen_engine = GenEngine::from_manifest(
        engine,
        SamplingParams { temperature: cfg.temperature, top_k: 0 },
    )?;
    let actor = ActorWorker::new(
        engine,
        placement.actor,
        gen_engine,
        cfg.max_new_tokens,
        cfg.gen_logprobs,
    );
    let reward_worker = RewardWorker::new(placement.reward);

    let a = engine.manifest.artifact("train_step")?.clone();
    let (b, s) = (a.batch, a.seq);

    // sync mode's weight flow is trivially versioned: the whole iteration
    // runs under one version (initial params = v1), which advances by
    // exactly one per iteration's update barrier — so every sample of an
    // iteration carries the same stamp and old-logprobs are scored under
    // it with zero lag. The optional history bus retains the snapshots
    // for the behavior-policy property suite.
    let mut weight_version: u64 = 1;
    let history = cfg
        .keep_weight_history
        .then(|| Arc::new(WeightBus::new(policy.params.clone(), HISTORY_CAPACITY)));

    let mut timers = StageTimers::default();
    let mut iterations = Vec::with_capacity(cfg.iterations);
    let mut version_lags = Vec::with_capacity(cfg.iterations);
    let mut evals = Vec::new();
    let mut dispatch_prev = 0.0f64;
    let t_run = Instant::now();

    for iter in 0..cfg.iterations {
        let t_iter = Instant::now();

        // 1. admit prompts (G × N samples, grouped, tenant-striped)
        admit_iteration(flow.as_ref(), &mut task_gen, cfg, iter, &roster, &mut charges, None)?;

        // 2. generation until drained
        let t0 = Instant::now();
        loop {
            let out = actor.run_generation(
                engine,
                &policy,
                flow.as_ref(),
                &mut rng,
                GEN_MAX_BATCH,
                weight_version,
            )?;
            if out.sequences == 0 {
                break;
            }
        }
        let gen_secs = t0.elapsed().as_secs_f64();
        timers.add("generation", gen_secs);

        // 3. inference + reward
        let t0 = Instant::now();
        actor.run_old_logprobs(engine, &policy, flow.as_ref(), b)?;
        reference.run(engine, flow.as_ref(), b)?;
        let reward_out = reward_worker.run(flow.as_ref(), REWARD_MAX_BATCH)?;
        let infer_secs = t0.elapsed().as_secs_f64();
        timers.add("inference", infer_secs);

        // 4. update: collect ready samples, group advantages, train
        let t0 = Instant::now();
        let metas = flow.request_ready(Stage::Update, usize::MAX)?;
        let mut ready = flow.fetch(placement.update, &metas)?;
        ready.sort_by_key(|smp| (smp.group, smp.index));

        let mut stats_acc: Vec<TrainStats> = Vec::new();
        // complete groups only (all group members present by construction)
        let rewards: Vec<f32> = ready
            .iter()
            .map(|smp| smp.get(FieldKind::Reward).unwrap().scalar().unwrap_or(0.0))
            .collect();
        let advs = group_advantages(&rewards, cfg.group_size);

        for (chunk, adv_chunk) in ready.chunks(b).zip(advs.chunks(b)) {
            let batch = assemble_batch(chunk, adv_chunk, b, s, &tokenizer)?;
            let st = policy.train_step(engine, &batch)?;
            stats_acc.push(st);
        }
        for sm in &ready {
            flow.retire(sm.index);
            charges.release(sm.tenant);
            if roster.is_multi() {
                *tenant_tokens.entry(sm.tenant).or_insert(0) += sm.resp_len as u64;
            }
        }
        // the iteration ran entirely under one version: zero lag, by
        // construction — recorded so sync and pipelined reports stay
        // shape-compatible for the overlap bench
        version_lags.push((iter, VersionLag { samples: ready.len() as u64, sum: 0, max: 0 }));
        weight_version += 1;
        if let Some(h) = &history {
            let v = h.publish(&policy.params)?;
            debug_assert_eq!(v, WeightVersion(weight_version));
        }
        let update_secs = t0.elapsed().as_secs_f64();
        timers.add("update", update_secs);

        // 5. metrics
        let total_secs = t_iter.elapsed().as_secs_f64();
        let dispatch_total = flow.dispatch_secs(&net);
        let n = ready.len().max(1);
        let pl_mean = ready.iter().map(|smp| smp.prompt_len as u64).sum::<u64>() / n as u64;
        let n_stats = stats_acc.len().max(1) as f32;
        let m = IterationMetrics {
            iter,
            reward_mean: rewards.iter().sum::<f32>() / n as f32,
            exact_frac: reward_out.exact as f32 / reward_out.scored.max(1) as f32,
            loss: stats_acc.iter().map(|st| st.loss).sum::<f32>() / n_stats,
            kl: stats_acc.iter().map(|st| st.kl).sum::<f32>() / n_stats,
            ratio: stats_acc.iter().map(|st| st.ratio).sum::<f32>() / n_stats,
            gen_secs,
            infer_secs,
            update_secs,
            total_secs,
            tps: throughput_tps(
                cfg.prompts_per_iter as u64,
                cfg.group_size as u64,
                pl_mean,
                cfg.max_new_tokens as u64,
                1,
                total_secs,
            ),
            dispatch_secs: dispatch_total - dispatch_prev,
        };
        dispatch_prev = dispatch_total;
        if cfg.log_every > 0 && iter % cfg.log_every == 0 {
            eprintln!(
                "[grpo] iter {iter:>4} reward={:.3} exact={:.2} loss={:+.4} kl={:.4} gen={} upd={}",
                m.reward_mean,
                m.exact_frac,
                m.loss,
                m.kl,
                crate::util::fmt_secs(gen_secs),
                crate::util::fmt_secs(update_secs)
            );
        }
        iterations.push(m);

        if cfg.eval_every > 0 && (iter + 1) % cfg.eval_every == 0 {
            let ev = evaluate(engine, &policy, cfg.eval_size, cfg.seed, 1)?;
            evals.push((iter + 1, ev));
        }
    }

    let mut pipeline = PipelineReport {
        mode: PipelineMode::Sync.name().into(),
        wall_secs: t_run.elapsed().as_secs_f64(),
        busy: BTreeMap::new(),
        version_lag: version_lags,
        bus: history.as_ref().map(|h| h.retention_stats()).unwrap_or_default(),
        // sync never ticks the lease clock, so reclaims stay zero; the
        // grant counters still report for symmetry
        recovery: flow.lease_stats(),
        // one thread runs every stage: no replica accounting
        scaling: StageScaling::default(),
        // sync generation is the batch-decode baseline by definition
        gen_stream: StreamGenReport::default(),
        // sync never abandons a sequence mid-decode: nothing to persist
        partial: PartialRolloutReport::default(),
        dock: flow.dock_report(),
        tenants: tenant_report(&roster, flow.as_ref(), quotas.as_deref(), &tenant_tokens),
    };
    for (stage, secs, _count) in timers.entries() {
        pipeline.busy.insert(stage, secs);
    }

    Ok(TrainReport {
        config: cfg.clone(),
        iterations,
        evals,
        pipeline,
        final_ledger: flow.ledger(),
        weight_history: history,
    })
}

// ------------------------------------------------------------ pipelined

/// How many snapshots the versioned bus must retain so that no in-flight
/// sample's stamped version is ever evicted. While a sample S of
/// iteration `k` awaits its old-logprob, `k` cannot complete, but
/// *earlier* iterations can — `completed` advances up to `k` and
/// admission (gated at `completed + window`) reaches iteration
/// `k + window - 1`. With S admitted at the window's far edge
/// (`k = completed_at_admission + window - 1`), the iterations retirable
/// during S's flight span `2·window − 1` of them; every publish follows
/// a train round that retires at least one whole GRPO group and S's own
/// group never retires, so at most
/// `(2·window − 1) × prompts_per_iter − 1` publishes can land between
/// S's stamp and its scoring. Retaining that many versions plus the
/// stamp itself (+2 slop) makes eviction impossible regardless of claim
/// ordering.
fn bus_capacity(cfg: &GrpoConfig, window: usize) -> usize {
    if cfg.keep_weight_history {
        HISTORY_CAPACITY
    } else {
        WeightBus::required_capacity(window, cfg.prompts_per_iter)
    }
}

/// Effectively-unbounded ring size for `keep_weight_history` runs
/// (debug/test instrumentation: retain every published snapshot).
const HISTORY_CAPACITY: usize = usize::MAX / 2;

/// SAFETY: PJRT clients are built for concurrent dispatch — `Execute` is
/// thread-compatible and the CPU client runs executions on its own thread
/// pool; `Engine`'s only interior mutability (`exec_stats`) is behind a
/// `Mutex`. The `xla` binding types simply don't declare `Send`/`Sync`,
/// so the executor asserts it at this single boundary instead of
/// scattering `unsafe` through the workers. The executor still keeps the
/// *shared* `logprobs` executable single-flight across the old-logprob
/// and reference stages (`lp_serial`) and `train_step` on the update
/// thread alone (periodic eval on the update thread is the one
/// documented exception); `decode_step` runs concurrently across the
/// elastic generation replicas — each replica owns its engine state
/// (KV buffers, sampler RNG) and only the thread-compatible `Execute`
/// is shared, which is precisely the concurrency PJRT supports.
#[derive(Clone, Copy)]
struct EngineShare<'a>(&'a Engine);
unsafe impl Send for EngineShare<'_> {}
unsafe impl Sync for EngineShare<'_> {}

/// Record the first stage failure and ask every thread to wind down.
fn stage_failed(
    fail: &Mutex<Option<String>>,
    shutdown: &AtomicBool,
    stage: &str,
    e: anyhow::Error,
) {
    let mut g = fail.lock().unwrap();
    if g.is_none() {
        *g = Some(format!("{stage} stage failed: {e:#}"));
    }
    shutdown.store(true, Ordering::Relaxed);
}

/// Consult the chaos plan for a freshly claimed batch. `Some(Killed)`
/// means the worker abandons the claims (no writeback, no release — the
/// lease reclaims them) and asks the supervisor for a restart; a stall
/// parks here until the logical clock has moved past the stall window,
/// then falls through to process the (likely already reclaimed) batch and
/// write back late.
fn inject_fault(
    faults: Option<&FaultInjector>,
    stage: Stage,
    flow: &dyn SampleFlow,
    shutdown: &AtomicBool,
) -> Option<StageExit> {
    let inj = faults?;
    match inj.decide(stage)? {
        FaultKind::Kill => Some(StageExit::Killed),
        FaultKind::Stall => {
            inj.stall(flow, shutdown);
            None
        }
    }
}

/// Distinct per-replica RNG stream tag (replica 0 keeps the original
/// stream, so a single-replica run is bit-identical to the pre-elastic
/// executor).
fn replica_tag(replica: usize) -> u64 {
    (replica as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// Long-lived actor generation replica: claim → generate → write back.
/// `retire` is the drain-then-retire flag (checked only between claim
/// batches, so a set flag never abandons a live lease); `busy_slots`
/// counts replicas currently inside a batch (the autoscaler's idle-ratio
/// signal). Each replica holds its own head-tracking weight view,
/// charged to the tracked `replica_pool`.
#[allow(clippy::too_many_arguments)]
fn generation_stage(
    engine: &Engine,
    cfg: &GrpoConfig,
    placement: StagePlacement,
    flow: &dyn SampleFlow,
    bus: &WeightBus,
    replica_pool: &Arc<MemoryPool>,
    replica_id: usize,
    retire: &AtomicBool,
    busy_slots: &AtomicUsize,
    faults: Option<&FaultInjector>,
    shutdown: &AtomicBool,
    busy: &Mutex<StageTimers>,
    stream_acc: &Mutex<StreamGenReport>,
    partial_acc: &Mutex<PartialRolloutReport>,
    quotas: Option<&Arc<TenantQuotas>>,
) -> Result<StageExit> {
    if cfg.gen_streaming {
        return streaming_generation_stage(
            engine, cfg, placement, flow, bus, replica_pool, replica_id, retire, busy_slots,
            faults, shutdown, busy, stream_acc, partial_acc, quotas,
        );
    }
    let gen_engine = GenEngine::from_manifest(
        engine,
        SamplingParams { temperature: cfg.temperature, top_k: 0 },
    )?;
    let actor = ActorWorker::new(
        engine,
        placement.actor,
        gen_engine,
        cfg.max_new_tokens,
        cfg.gen_logprobs,
    );
    let mut rng = Rng::new(cfg.seed ^ 0x6765_6e65_7261_7465 ^ replica_tag(replica_id));
    let mut replica = WeightReplica::new_with_pool(
        bus,
        Arc::clone(replica_pool),
        &format!("gen{replica_id}"),
    )
    .map_err(|e| anyhow!(e))?;
    loop {
        if retire.load(Ordering::Relaxed) {
            return Ok(StageExit::Retired);
        }
        let metas = flow.wait_ready(Stage::Generation, GEN_MAX_BATCH, STAGE_WAIT)?;
        if metas.is_empty() {
            if shutdown.load(Ordering::Relaxed) {
                return Ok(StageExit::Completed);
            }
            continue;
        }
        if let Some(exit) = inject_fault(faults, Stage::Generation, flow, shutdown) {
            return Ok(exit);
        }
        busy_slots.fetch_add(1, Ordering::Relaxed);
        replica.refresh(bus).map_err(|e| anyhow!(e))?;
        let t0 = Instant::now();
        // the whole claimed batch generates under one snapshot; its
        // version is stamped onto every writeback — the sample's
        // behavior-policy identity from here on
        let out = actor.generate_claimed(
            engine,
            &replica.policy,
            flow,
            &mut rng,
            &metas,
            replica.version.as_u64(),
        );
        busy.lock().unwrap().add("generation", t0.elapsed().as_secs_f64());
        busy_slots.fetch_sub(1, Ordering::Relaxed);
        out?;
    }
}

/// Flips the autoscaler's busy-slot counter as the replica moves between
/// idle and in-flight, and guarantees the decrement on every exit path
/// (including errors unwinding through `?`).
struct BusySlotGuard<'a> {
    slots: &'a AtomicUsize,
    on: bool,
}

impl<'a> BusySlotGuard<'a> {
    fn new(slots: &'a AtomicUsize) -> Self {
        Self { slots, on: false }
    }

    fn set(&mut self, on: bool) {
        if on == self.on {
            return;
        }
        if on {
            self.slots.fetch_add(1, Ordering::Relaxed);
        } else {
            self.slots.fetch_sub(1, Ordering::Relaxed);
        }
        self.on = on;
    }
}

impl Drop for BusySlotGuard<'_> {
    fn drop(&mut self) {
        self.set(false);
    }
}

/// The streaming alternative to [`generation_stage`]: one persistent
/// [`GenSession`] per replica owns the decode slots and paged KV across
/// claims. The worker blocks on `wait_ready` only while the session is
/// empty; with sequences in flight it polls `try_claim` *between decode
/// steps* for however many slots are open ([`GenSession::room`], zero
/// under KV backpressure — admission deferral propagates to the dock as
/// simply not claiming), renews its claim leases every step, and writes
/// each sequence back the step it finishes instead of holding the batch
/// open for the long tail.
///
/// Versioning: each claim batch refreshes the head-tracking replica and
/// its sequences are stamped with the refreshed version — so unlike the
/// batch stage, sequences *within one session* may carry different
/// behavior stamps, which is exactly the stamp-then-score-under-stamp
/// contract the old-logprob stage already honors. The per-sequence
/// sampling streams come from the workload seed alone (no replica tag):
/// a sequence's tokens are invariant under which replica decodes it.
#[allow(clippy::too_many_arguments)]
fn streaming_generation_stage(
    engine: &Engine,
    cfg: &GrpoConfig,
    placement: StagePlacement,
    flow: &dyn SampleFlow,
    bus: &WeightBus,
    replica_pool: &Arc<MemoryPool>,
    replica_id: usize,
    retire: &AtomicBool,
    busy_slots: &AtomicUsize,
    faults: Option<&FaultInjector>,
    shutdown: &AtomicBool,
    busy: &Mutex<StageTimers>,
    stream_acc: &Mutex<StreamGenReport>,
    partial_acc: &Mutex<PartialRolloutReport>,
    quotas: Option<&Arc<TenantQuotas>>,
) -> Result<StageExit> {
    let gen_engine = GenEngine::from_manifest(
        engine,
        SamplingParams { temperature: cfg.temperature, top_k: 0 },
    )?;
    let actor = ActorWorker::new(
        engine,
        placement.actor,
        gen_engine,
        cfg.max_new_tokens,
        cfg.gen_logprobs,
    );
    let mut replica = WeightReplica::new_with_pool(
        bus,
        Arc::clone(replica_pool),
        &format!("gen{replica_id}"),
    )
    .map_err(|e| anyhow!(e))?;

    let scfg = StreamConfig::from_manifest(
        engine,
        SamplingParams { temperature: cfg.temperature, top_k: 0 },
        cfg.prefill_chunk,
        cfg.seed ^ 0x6765_6e65_7261_7465,
    )?;
    // size the KV pool off the real decode KV tensor: bytes per (slot ×
    // position), rounded up to whole blocks per slot, so a full slot set
    // always admits — production backpressure defers, never deadlocks
    let kv_probe = replica.policy.init_kv(engine)?;
    let bytes_per_token =
        (kv_probe.size_bytes() as u64 / (scfg.batch * scfg.max_seq) as u64).max(1);
    drop(kv_probe);
    let kv_pool = Arc::new(MemoryPool::new(
        format!("kv-gen{replica_id}"),
        KvBlockAllocator::capacity_bytes_for(
            scfg.batch,
            scfg.max_seq,
            cfg.kv_block_tokens,
            bytes_per_token,
        ),
    ));
    let mut session = GenSession::new(
        scfg,
        KvBlockAllocator::new(Arc::clone(&kv_pool), cfg.kv_block_tokens, bytes_per_token),
    );
    if let Some(q) = quotas {
        session.attach_tenant_quotas(Arc::clone(q));
    }
    // per-tenant quota preemption latch: fires once per over-quota
    // episode (cleared when the tenant drops back under), so the
    // tenant's re-claimed resumes may decode while it stays capped —
    // repeat-preempting payload-held quota would starve the tenant
    let mut quota_preempted: HashSet<u32> = HashSet::new();
    // per-sequence context a writeback needs: encoded prompt + the weight
    // version the sequence was admitted (stamped) under
    let mut prompts: HashMap<u64, Vec<i32>> = HashMap::new();
    let mut stamps: HashMap<u64, u64> = HashMap::new();
    // partial rollouts: segments closed by *previous* incarnations (from
    // the fetched partial), and the longest prefix already persisted per
    // sequence — the dedup that makes checkpoints idempotent
    let mut closed_segs: HashMap<u64, Vec<Segment>> = HashMap::new();
    let mut persisted_len: HashMap<u64, usize> = HashMap::new();
    let mut pr = PartialRolloutReport::default();
    let mut steps_since_ckpt = 0usize;
    // satellite: lease renewal bookkeeping — scratch buffer + the
    // (held-set revision, lease clock) pair the last renewal ran under
    let mut held_buf: Vec<u64> = Vec::new();
    let mut renewed_at: Option<(u64, u64)> = None;
    let mut last_version = replica.version.as_u64();
    let mut slot_guard = BusySlotGuard::new(busy_slots);
    let flush = |session: &GenSession, pr: &PartialRolloutReport| {
        stream_acc.lock().unwrap().absorb(&session.stats());
        partial_acc.lock().unwrap().merge(pr);
    };

    loop {
        // claim: block only when empty; at decode-step granularity
        // otherwise, and not at all while KV backpressure holds room at 0
        let metas = if session.is_idle() {
            slot_guard.set(false);
            // the paging invariant at every drain: all blocks released by
            // per-sequence retirement, pool back to baseline
            debug_assert!(session.kv_invariant_holds());
            debug_assert_eq!(kv_pool.live_bytes(), 0, "drained session must free all KV");
            if retire.load(Ordering::Relaxed) {
                flush(&session, &pr);
                return Ok(StageExit::Retired);
            }
            let m = flow.wait_ready(Stage::Generation, GEN_MAX_BATCH, STAGE_WAIT)?;
            if m.is_empty() {
                if shutdown.load(Ordering::Relaxed) {
                    flush(&session, &pr);
                    return Ok(StageExit::Completed);
                }
                continue;
            }
            m
        } else if retire.load(Ordering::Relaxed) {
            // drain-then-retire with work in flight: stop claiming. With
            // partial rollouts the drain is cooperative — persist every
            // live prefix and hand the claims back for another replica to
            // resume, instead of decoding the long tail out here.
            if cfg.partial_rollouts {
                persist_and_release(
                    flow, placement.actor, &mut session, &mut prompts, &mut stamps,
                    &mut closed_segs, &mut persisted_len, &mut pr,
                )?;
                continue; // next pass sees an idle session and retires
            }
            Vec::new()
        } else {
            let room = session.room().min(GEN_MAX_BATCH);
            if room > 0 {
                flow.try_claim(Stage::Generation, room)?
            } else {
                Vec::new()
            }
        };

        if !metas.is_empty() {
            if let Some(exit) = inject_fault(faults, Stage::Generation, flow, shutdown) {
                // abandon every claim the session holds (no writeback, no
                // release): the leases reclaim them, exactly as a killed
                // batch worker's claims are recovered. With partial
                // rollouts the decoded prefixes are persisted first —
                // the reclaimed samples redispatch as resumes, so the
                // kill costs at most the tokens since the last persist.
                if cfg.partial_rollouts {
                    let exports = session.export_partials();
                    persist_exports(
                        flow, placement.actor, exports, &stamps,
                        &mut closed_segs, &mut persisted_len, &mut pr,
                    )?;
                }
                flush(&session, &pr);
                return Ok(exit);
            }
            // one refresh per claim batch; the sequences admitted from it
            // are stamped with the refreshed version, even though older
            // sequences still decoding carry earlier stamps
            replica.refresh(bus).map_err(|e| anyhow!(e))?;
            let v = replica.version.as_u64();
            if cfg.preempt_on_publish && v != last_version && session.in_flight() > 0 {
                // a weight publish landed since the last claim: preempt
                // every in-flight sequence (all stamped with older
                // versions), persist the prefixes, and hand the claims
                // back — they redispatch immediately and resume under the
                // new head, closing a segment at the old version
                let n = persist_and_release(
                    flow, placement.actor, &mut session, &mut prompts, &mut stamps,
                    &mut closed_segs, &mut persisted_len, &mut pr,
                )?;
                pr.publish_preemptions += n as u64;
            }
            last_version = v;
            let samples = flow.fetch_resident(placement.actor, &metas)?;
            let (requests, prompt_map) = actor.prepare_requests(&samples)?;
            prompts.extend(prompt_map);
            // tenant tags, captured before the partials loop consumes
            // the fetched samples (request id == sample index)
            let tenants_of: HashMap<u64, u32> =
                samples.iter().map(|s| (s.index, s.tenant)).collect();
            // resumable sequences carry their persisted prefix with them
            let mut partials: HashMap<u64, PartialRollout> = HashMap::new();
            if cfg.partial_rollouts {
                for mut smp in samples {
                    if let Some(p) = smp.partial.take() {
                        partials.insert(smp.index, p);
                    }
                }
            }
            for r in requests {
                stamps.insert(r.id, v);
                let tenant = tenants_of.get(&r.id).copied().unwrap_or(0);
                match partials.remove(&r.id) {
                    Some(p) if !p.response_ids.is_empty() => {
                        pr.resumed += 1;
                        pr.saved_tokens += p.token_len() as u64;
                        // the fetched prefix is by definition persisted
                        persisted_len.insert(r.id, p.token_len());
                        closed_segs.insert(r.id, p.segments.clone());
                        session.submit_resume_for_tenant(
                            r,
                            p.response_ids,
                            p.response_logprobs,
                            tenant,
                        );
                    }
                    _ => session.submit_for_tenant(r, tenant),
                }
            }
        }

        // quota preemption: a tenant past its byte budget has its
        // in-flight sequences drained to persisted partial rollouts and
        // the claims handed back — the single-tenant drain-then-retire
        // path scoped to one tenant, so siblings' slots keep decoding
        // and no decoded token is lost
        if cfg.partial_rollouts {
            if let Some(q) = quotas {
                quota_preempted.retain(|t| q.over_quota(*t));
                for t in session.tenants_in_flight() {
                    if !q.over_quota(t) || !quota_preempted.insert(t) {
                        continue;
                    }
                    let exports = session.export_partials_for(|x| x == t);
                    if exports.is_empty() {
                        continue;
                    }
                    let ids = persist_exports(
                        flow, placement.actor, exports, &stamps,
                        &mut closed_segs, &mut persisted_len, &mut pr,
                    )?;
                    flow.release(Stage::Generation, &ids);
                    for id in &ids {
                        prompts.remove(id);
                        stamps.remove(id);
                        closed_segs.remove(id);
                        persisted_len.remove(id);
                    }
                    q.note_preemption(t);
                }
            }
        }

        slot_guard.set(true);
        // renew held claims: leases measure writeback silence, and a long
        // sequence is silent by design. A renewal only matters when the
        // lease clock has advanced or the held set changed since the last
        // one (same set + same clock ⇒ identical expiries), so both are
        // checked before refilling the scratch buffer — no fresh Vec and
        // no renew round-trip on the steady-state decode tick.
        let tick = (session.held_revision(), flow.lease_now());
        if renewed_at != Some(tick) {
            renewed_at = Some(tick);
            session.held_ids_into(&mut held_buf);
            if !held_buf.is_empty() {
                flow.renew(Stage::Generation, &held_buf);
            }
        }
        let t0 = Instant::now();
        let done = session.step(engine, &replica.policy)?;
        busy.lock().unwrap().add("generation", t0.elapsed().as_secs_f64());
        // periodic checkpoint: persist grown prefixes so an *unclean*
        // death (stall-expiry reclaim — no exit hook runs) loses at most
        // PARTIAL_CKPT_STEPS decode steps of work per slot
        steps_since_ckpt += 1;
        if cfg.partial_rollouts && steps_since_ckpt >= PARTIAL_CKPT_STEPS {
            steps_since_ckpt = 0;
            let snaps = session.partial_snapshots();
            persist_exports(
                flow, placement.actor, snaps, &stamps,
                &mut closed_segs, &mut persisted_len, &mut pr,
            )?;
        }
        // per-sequence retirement: each finished sequence is written back
        // (completing its claim) the step it finishes
        for r in &done {
            let prompt = prompts.remove(&r.id).ok_or_else(|| {
                anyhow!("finished sequence {} has no recorded prompt", r.id)
            })?;
            let v = stamps.remove(&r.id).unwrap_or_else(|| replica.version.as_u64());
            persisted_len.remove(&r.id);
            // final authoritative segment list: spans closed by earlier
            // incarnations, plus this incarnation's tail at its stamp
            let mut segments = closed_segs.remove(&r.id).unwrap_or_default();
            let start = segments.last().map(|g| g.end()).unwrap_or(0);
            if r.response_ids.len() > start {
                push_segment(&mut segments, start, r.response_ids.len() - start, v);
            }
            if segments.len() > 1 {
                pr.multi_segment_responses += 1;
            }
            actor.store_result_with_segments(engine, flow, r, &prompt, v, segments)?;
        }
    }
}

/// Persist cadence for `--partial-rollouts` periodic checkpoints, in
/// decode steps. Bounds the recompute after an unclean death to at most
/// this many steps of fresh tokens per slot; the clean paths (kill hook,
/// drain, preempt) persist exactly at the abandonment point.
const PARTIAL_CKPT_STEPS: usize = 8;

/// Persist a batch of exported decode prefixes as partial rollouts.
/// Each export with tokens beyond its last persisted length is written
/// through the flow: the segments closed by earlier incarnations, plus
/// one fresh span at the version this incarnation stamped the sequence
/// with. Returns every exported claim index (the cooperative paths
/// release them afterwards; the kill path leaves them to the lease).
#[allow(clippy::too_many_arguments)]
fn persist_exports(
    flow: &dyn SampleFlow,
    node: usize,
    exports: Vec<SeqExport>,
    stamps: &HashMap<u64, u64>,
    closed_segs: &mut HashMap<u64, Vec<Segment>>,
    persisted_len: &mut HashMap<u64, usize>,
    pr: &mut PartialRolloutReport,
) -> Result<Vec<u64>> {
    let mut ids = Vec::with_capacity(exports.len());
    for e in exports {
        ids.push(e.id);
        let total = e.response_ids.len();
        if total == 0 || persisted_len.get(&e.id).copied().unwrap_or(0) >= total {
            continue; // nothing decoded beyond the last persisted prefix
        }
        let mut segments = closed_segs.get(&e.id).cloned().unwrap_or_default();
        if total > e.resumed_from {
            let v = stamps
                .get(&e.id)
                .copied()
                .ok_or_else(|| anyhow!("no stamp for in-flight sequence {}", e.id))?;
            push_segment(&mut segments, e.resumed_from, total - e.resumed_from, v);
        }
        let partial = PartialRollout {
            response_ids: e.response_ids,
            response_logprobs: e.response_logprobs,
            segments,
        };
        pr.persisted += 1;
        pr.persisted_tokens += total as u64;
        persisted_len.insert(e.id, total);
        flow.store_partial_generation(node, e.id, partial)?;
    }
    Ok(ids)
}

/// Cooperative abandonment (scale-down drain, publish preemption):
/// persist every in-flight prefix, then *release* the claims — unlike a
/// kill, the worker is alive and hands the samples straight back instead
/// of waiting out its own lease. Per-sequence side state is dropped; a
/// re-claim (this replica or any other) rebuilds it from the fetched
/// partial. Returns how many sequences were handed back.
#[allow(clippy::too_many_arguments)]
fn persist_and_release(
    flow: &dyn SampleFlow,
    node: usize,
    session: &mut GenSession,
    prompts: &mut HashMap<u64, Vec<i32>>,
    stamps: &mut HashMap<u64, u64>,
    closed_segs: &mut HashMap<u64, Vec<Segment>>,
    persisted_len: &mut HashMap<u64, usize>,
    pr: &mut PartialRolloutReport,
) -> Result<usize> {
    let exports = session.export_partials();
    if exports.is_empty() {
        return Ok(0);
    }
    let ids = persist_exports(flow, node, exports, stamps, closed_segs, persisted_len, pr)?;
    flow.release(Stage::Generation, &ids);
    for id in &ids {
        prompts.remove(id);
        stamps.remove(id);
        closed_segs.remove(id);
        persisted_len.remove(id);
    }
    Ok(ids.len())
}

/// Long-lived actor old-logprob inference state. Runs the logprob path
/// directly (tokenizer + logprobs artifact) — it needs none of the
/// generation engine the actor's other state carries.
///
/// Each claimed batch is scored under the *stamped* behavior version of
/// its samples (a versioned ring `get`, never the bus head): the claim is
/// grouped by version and every group runs against a version-pinned
/// replica, so `old_lp` is the exact behavior-policy logprob no matter
/// how far the update thread has run ahead. An evicted stamp is a hard
/// error — the bus is sized so it cannot happen while the staleness
/// window holds (see `bus_capacity`), and stamps are immutable once set
/// (generation writebacks are first-writer-wins), so a stale reclaimed
/// claim still names a servable version.
#[allow(clippy::too_many_arguments)]
fn old_logprob_stage(
    engine: &Engine,
    placement: StagePlacement,
    flow: &dyn SampleFlow,
    bus: &WeightBus,
    replica_pool: &Arc<MemoryPool>,
    lp_serial: &Mutex<()>,
    retire: &AtomicBool,
    busy_slots: &AtomicUsize,
    faults: Option<&FaultInjector>,
    shutdown: &AtomicBool,
    busy: &Mutex<StageTimers>,
) -> Result<StageExit> {
    let tokenizer = Tokenizer::from_manifest(&engine.manifest);
    let a = engine.manifest.artifact("logprobs")?.clone();
    // each replica pins its own small set of version-pinned views,
    // charged to the shared replica pool (released when it retires)
    let mut replicas = ReplicaCache::with_pool(4, Arc::clone(replica_pool));
    loop {
        if retire.load(Ordering::Relaxed) {
            return Ok(StageExit::Retired);
        }
        let metas = flow.wait_ready(Stage::OldLogprob, a.batch, STAGE_WAIT)?;
        if metas.is_empty() {
            if shutdown.load(Ordering::Relaxed) {
                return Ok(StageExit::Completed);
            }
            continue;
        }
        if let Some(exit) = inject_fault(faults, Stage::OldLogprob, flow, shutdown) {
            return Ok(exit);
        }
        busy_slots.fetch_add(1, Ordering::Relaxed);
        let mut by_version: BTreeMap<u64, Vec<SampleMeta>> = BTreeMap::new();
        for m in &metas {
            by_version.entry(m.behavior_version).or_default().push(*m);
        }
        let done = (|| -> Result<()> {
            let _serial = lp_serial.lock().unwrap();
            // busy starts after the serialization lock: waiting for the
            // shared executable is not compute, and booking it would fake
            // overlap in PipelineReport
            let t0 = Instant::now();
            score_by_version(
                engine, placement, flow, bus, &tokenizer, &a, &mut replicas, by_version,
            )?;
            busy.lock().unwrap().add("old_logprob", t0.elapsed().as_secs_f64());
            Ok(())
        })();
        busy_slots.fetch_sub(1, Ordering::Relaxed);
        done?;
    }
}

/// Score each stamped-version group of one claimed batch under its
/// recorded behavior version (the old-logprob stage's core loop, split
/// out so the replica loop stays readable).
#[allow(clippy::too_many_arguments)]
fn score_by_version(
    engine: &Engine,
    placement: StagePlacement,
    flow: &dyn SampleFlow,
    bus: &WeightBus,
    tokenizer: &Tokenizer,
    a: &crate::runtime::ArtifactInfo,
    replicas: &mut ReplicaCache,
    by_version: BTreeMap<u64, Vec<SampleMeta>>,
) -> Result<()> {
    for (version, group) in by_version {
        anyhow::ensure!(
            version != 0,
            "old-logprob claim for unstamped sample (generation must stamp)"
        );
        // One fetch per version group; samples whose segment list spans
        // more than one behavior version (partial rollouts resumed across
        // a weight publish) are split out for per-segment scoring — the
        // scalar stamp names only the *final* segment's version.
        let samples = flow.fetch_resident(placement.actor, &group)?;
        if samples.is_empty() {
            continue;
        }
        let mut plain: Vec<&Sample> = Vec::new();
        let mut multi: Vec<&Sample> = Vec::new();
        for smp in &samples {
            if smp.segments.windows(2).any(|w| w[0].version != w[1].version) {
                multi.push(smp);
            } else {
                plain.push(smp);
            }
        }
        if !plain.is_empty() {
            match replicas.get_or_build(bus, WeightVersion(version)) {
                Ok(policy) => {
                    let rows = crate::workers::logprob_rows_fetched(
                        engine, policy, tokenizer, &plain, a.batch, a.seq,
                    )?;
                    for (smp, row) in plain.iter().zip(rows) {
                        flow.store_fields(
                            placement.actor,
                            smp.index,
                            vec![(FieldKind::OldLp, Tensor::f32(&[a.seq - 1], row)?)],
                        )?;
                    }
                }
                Err(e) => {
                    // The ring retains every version a resident *unscored*
                    // sample is stamped with (the sample blocks its
                    // iteration, bounding publishes — see bus_capacity).
                    // An evicted version can therefore only be named by
                    // stale claims: samples already re-processed by a
                    // redispatched peer (old_lp present) or retired. Those
                    // claims are residue of a reclaimed lease — drop them.
                    // Anything else is a real invariant violation.
                    anyhow::ensure!(
                        plain.iter().all(|s| s.has(FieldKind::OldLp)),
                        "behavior version {version} evicted while an unscored \
                         sample still needs it: {e}"
                    );
                }
            }
        }
        for smp in multi {
            score_segments(engine, placement, flow, bus, tokenizer, a, replicas, smp)?;
        }
    }
    Ok(())
}

/// Assemble one multi-version response's `old_lp` row per-segment: the
/// token row is scored once under each distinct behavior version in the
/// segment list, and every segment's span is spliced from the row
/// computed under the version that span was decoded under. The GRPO
/// importance ratio (token-wise by construction) then divides each token
/// by its *own* behavior policy — behavior-policy-exact across the
/// version boundaries a resumed rollout crossed.
#[allow(clippy::too_many_arguments)]
fn score_segments(
    engine: &Engine,
    placement: StagePlacement,
    flow: &dyn SampleFlow,
    bus: &WeightBus,
    tokenizer: &Tokenizer,
    a: &crate::runtime::ArtifactInfo,
    replicas: &mut ReplicaCache,
    smp: &Sample,
) -> Result<()> {
    let s = a.seq;
    // response token j lives at row index resp_start - 1 + j (the
    // `logprobs` artifact's shifted layout; see behavior_logprob_row)
    let resp_start = tokenizer.encode(&smp.prompt_text)?.len();
    let mut row = vec![0f32; s - 1];
    // segments are span-ordered with non-decreasing versions, so dedup
    // yields each distinct version once
    let mut versions: Vec<u64> = smp.segments.iter().map(|g| g.version).collect();
    versions.dedup();
    for dv in versions {
        anyhow::ensure!(dv != 0, "segment stamped with version 0 (generation must stamp)");
        let policy = match replicas.get_or_build(bus, WeightVersion(dv)) {
            Ok(p) => p,
            Err(e) => {
                // same stale-claim residue rule as the plain path
                anyhow::ensure!(
                    smp.has(FieldKind::OldLp),
                    "segment behavior version {dv} evicted while an unscored \
                     sample still needs it: {e}"
                );
                return Ok(());
            }
        };
        let vrow =
            &crate::workers::logprob_rows_fetched(engine, policy, tokenizer, &[smp], a.batch, s)?[0];
        for seg in smp.segments.iter().filter(|g| g.version == dv) {
            let lo = resp_start - 1 + seg.start;
            row[lo..lo + seg.len].copy_from_slice(&vrow[lo..lo + seg.len]);
        }
    }
    flow.store_fields(
        placement.actor,
        smp.index,
        vec![(FieldKind::OldLp, Tensor::f32(&[s - 1], row)?)],
    )?;
    Ok(())
}

/// Long-lived reference inference replica (frozen policy, owns its
/// weights — no version pinning needed, so no replica-pool charge beyond
/// the worker's own frozen copy).
#[allow(clippy::too_many_arguments)]
fn ref_logprob_stage(
    engine: &Engine,
    placement: StagePlacement,
    flow: &dyn SampleFlow,
    lp_serial: &Mutex<()>,
    retire: &AtomicBool,
    busy_slots: &AtomicUsize,
    faults: Option<&FaultInjector>,
    shutdown: &AtomicBool,
    busy: &Mutex<StageTimers>,
) -> Result<StageExit> {
    let reference = ReferenceWorker::new(engine, placement.reference)?;
    let lp_batch = engine.manifest.artifact("logprobs")?.batch;
    loop {
        if retire.load(Ordering::Relaxed) {
            return Ok(StageExit::Retired);
        }
        let metas = flow.wait_ready(Stage::RefLogprob, lp_batch, STAGE_WAIT)?;
        if metas.is_empty() {
            if shutdown.load(Ordering::Relaxed) {
                return Ok(StageExit::Completed);
            }
            continue;
        }
        if let Some(exit) = inject_fault(faults, Stage::RefLogprob, flow, shutdown) {
            return Ok(exit);
        }
        busy_slots.fetch_add(1, Ordering::Relaxed);
        let done = (|| -> Result<()> {
            let _serial = lp_serial.lock().unwrap();
            let t0 = Instant::now();
            reference.run_claimed(engine, flow, &metas)?;
            drop(_serial);
            busy.lock().unwrap().add("ref_logprob", t0.elapsed().as_secs_f64());
            Ok(())
        })();
        busy_slots.fetch_sub(1, Ordering::Relaxed);
        done?;
    }
}

/// Long-lived rule-reward replica.
#[allow(clippy::too_many_arguments)]
fn reward_stage(
    placement: StagePlacement,
    flow: &dyn SampleFlow,
    retire: &AtomicBool,
    busy_slots: &AtomicUsize,
    faults: Option<&FaultInjector>,
    shutdown: &AtomicBool,
    busy: &Mutex<StageTimers>,
) -> Result<StageExit> {
    let reward_worker = RewardWorker::new(placement.reward);
    loop {
        if retire.load(Ordering::Relaxed) {
            return Ok(StageExit::Retired);
        }
        let metas = flow.wait_ready(Stage::Reward, REWARD_MAX_BATCH, STAGE_WAIT)?;
        if metas.is_empty() {
            if shutdown.load(Ordering::Relaxed) {
                return Ok(StageExit::Completed);
            }
            continue;
        }
        if let Some(exit) = inject_fault(faults, Stage::Reward, flow, shutdown) {
            return Ok(exit);
        }
        busy_slots.fetch_add(1, Ordering::Relaxed);
        let t0 = Instant::now();
        let out = reward_worker.score_claimed(flow, &metas);
        busy.lock().unwrap().add("reward", t0.elapsed().as_secs_f64());
        busy_slots.fetch_sub(1, Ordering::Relaxed);
        out?;
    }
}

/// Per-iteration accounting kept by the update thread.
struct IterAcc {
    /// samples admitted but not yet trained + retired
    remaining: usize,
    rewards: Vec<f32>,
    /// exact answers, re-scored from the sample (same rule the reward
    /// state applies), so exact_frac matches sync mode's Score.exact
    /// semantics regardless of how reward shaping evolves
    exact: usize,
    stats: Vec<TrainStats>,
    prompt_tokens: u64,
    /// publishes-behind of each consumed sample's behavior policy
    /// relative to the head the update trained from
    lag: VersionLag,
}

impl IterAcc {
    fn new(total: usize) -> Self {
        Self {
            remaining: total,
            rewards: Vec::new(),
            exact: 0,
            stats: Vec::new(),
            prompt_tokens: 0,
            lag: VersionLag::default(),
        }
    }
}

fn gcd(mut a: usize, mut b: usize) -> usize {
    while b != 0 {
        (a, b) = (b, a % b);
    }
    a.max(1)
}

/// Smallest take size that is both whole GRPO groups and whole train
/// batches — training in these quanta avoids zero-mask padding steps.
fn take_quantum(batch: usize, group_size: usize) -> usize {
    batch / gcd(batch, group_size) * group_size
}

/// The concurrent executor: generation / old-logprob / reference / reward
/// run as stage threads pulling from the flow via `wait_ready`; the update
/// state runs on this thread, owns the authoritative policy, publishes
/// weights, and finalizes per-iteration metrics as groups complete.
fn run_pipelined(
    engine: &Engine,
    cfg: &GrpoConfig,
    flow: Arc<dyn SampleFlow>,
) -> Result<TrainReport> {
    let placement = StagePlacement::spread(cfg.nodes);
    let window = cfg.max_inflight_iters.max(1);
    let tokenizer = Tokenizer::from_manifest(&engine.manifest);
    let net = NetworkModel::paper();
    let mut task_gen = TaskGenerator::train(cfg.seed);
    let (roster, quotas) = tenancy_setup(cfg, flow.as_ref())?;

    let mut policy = Policy::load_initial(engine, cfg.lr)?;
    let a = engine.manifest.artifact("train_step")?.clone();
    let (b, s) = (a.batch, a.seq);

    // the bus ring is validated against the staleness window at build
    // time (typed CapacityBelowWindow instead of a mid-run Evicted), and
    // its shard-level retention is charged to a tracked pool so the
    // run's report carries Fig-10-style weight-channel accounting
    let bus_pool = Arc::new(MemoryPool::unbounded("weightbus"));
    let bus = Arc::new(WeightBus::new_checked(
        policy.params.clone(),
        bus_capacity(cfg, window),
        window,
        cfg.prompts_per_iter,
        Some(Arc::clone(&bus_pool)),
    )?);
    let shutdown = Arc::new(AtomicBool::new(false));
    let fail: Arc<Mutex<Option<String>>> = Arc::new(Mutex::new(None));
    let busy: Arc<Mutex<StageTimers>> = Arc::new(Mutex::new(StageTimers::default()));
    // chaos: one injector shared by every stage incarnation, so the
    // per-stage decision streams survive worker restarts
    let injector: Option<Arc<FaultInjector>> =
        cfg.fault_plan().map(|plan| Arc::new(FaultInjector::new(plan)));
    // keeps the shared `logprobs` executable single-flight across the
    // old-logprob and reference stages (see EngineShare's safety note)
    let lp_serial: Arc<Mutex<()>> = Arc::new(Mutex::new(()));
    // streaming generation accounting: every session incarnation folds
    // its raw slot-step counters in here when it exits
    let stream_acc: Arc<Mutex<StreamGenReport>> =
        Arc::new(Mutex::new(StreamGenReport::default()));
    // partial-rollout accounting, folded in the same way
    let partial_acc: Arc<Mutex<PartialRolloutReport>> =
        Arc::new(Mutex::new(PartialRolloutReport::default()));

    // elastic replicas: every materialized per-replica weight view
    // (generation head-trackers, old-logprob pinned caches) is charged
    // here, so the report can say what widening the stages cost in bytes
    let replica_pool = Arc::new(MemoryPool::unbounded("stage-replicas"));
    let elastic = !cfg.stage_replicas.all_single() || cfg.autoscale;

    let mut iterations = Vec::with_capacity(cfg.iterations);
    let mut version_lags = Vec::with_capacity(cfg.iterations);
    let mut evals = Vec::new();
    // tenancy driver state: open payload charges, per-tenant deferred
    // admission FIFOs, per-tenant retired-token counters — all owned by
    // the update thread (the only admitter and retirer)
    let mut charges = PayloadCharges::new(&roster, quotas.clone());
    let mut deferred: BTreeMap<u32, VecDeque<Sample>> = BTreeMap::new();
    let mut tenant_tokens: BTreeMap<u32, u64> = BTreeMap::new();
    // replica sets + autoscaler live outside the scope so their final
    // slot-time accounting runs after every replica thread has joined —
    // busy totals are final by then, which is what bounds replica-aware
    // utilization by 1
    let mut sets: Vec<ReplicaSet> =
        SCALABLE_STAGES.iter().map(|&s| ReplicaSet::new(s)).collect();
    let mut scaler = cfg.autoscale_config().map(Autoscaler::new);
    let t_run = Instant::now();

    let scope_result: Result<()> = std::thread::scope(|scope| {
        let eng = EngineShare(engine);
        let cfg_ref: &GrpoConfig = cfg;

        // Each replica thread runs under a supervisor loop: a fault-killed
        // incarnation abandons its claims (recovered by lease expiry) and
        // is respawned with fresh worker state — the in-process analogue
        // of a cluster restarting a dead worker pod. A drain-then-retire
        // exit (autoscale scale-down) leaves for good. Real errors still
        // fail the run through `stage_failed`.
        macro_rules! supervise {
            ($name:literal, $fail:ident, $shutdown:ident, $faults:ident, $run:expr) => {
                loop {
                    match $run {
                        Ok(StageExit::Completed) | Ok(StageExit::Retired) => break,
                        Ok(StageExit::Killed) => {
                            if let Some(inj) = $faults.as_deref() {
                                inj.note_restart();
                            }
                            if $shutdown.load(Ordering::Relaxed) {
                                break;
                            }
                        }
                        Err(e) => {
                            stage_failed(&$fail, &$shutdown, $name, e);
                            break;
                        }
                    }
                }
            };
        }

        // One spawner for every stage replica, callable again mid-run by
        // the autoscaler (scoped threads may be spawned while the scope
        // is live). Each call clones what the replica thread owns; the
        // thread sets `exited` when its supervisor loop returns, ending
        // the replica's slot-time accounting.
        let spawn_replica = |stage: Stage,
                             replica_id: usize,
                             retire: Arc<AtomicBool>,
                             busy_slots: Arc<AtomicUsize>,
                             exited: Arc<AtomicBool>| {
            let flow = Arc::clone(&flow);
            let bus = Arc::clone(&bus);
            let quotas = quotas.clone();
            let lp_serial = Arc::clone(&lp_serial);
            let replica_pool = Arc::clone(&replica_pool);
            let stream_acc = Arc::clone(&stream_acc);
            let partial_acc = Arc::clone(&partial_acc);
            let faults = injector.clone();
            let shutdown = Arc::clone(&shutdown);
            let fail = Arc::clone(&fail);
            let busy = Arc::clone(&busy);
            scope.spawn(move || {
                match stage {
                Stage::Generation => supervise!(
                    "generation",
                    fail,
                    shutdown,
                    faults,
                    generation_stage(
                        eng.0,
                        cfg_ref,
                        placement,
                        flow.as_ref(),
                        &bus,
                        &replica_pool,
                        replica_id,
                        &retire,
                        &busy_slots,
                        faults.as_deref(),
                        &shutdown,
                        &busy,
                        &stream_acc,
                        &partial_acc,
                        quotas.as_ref(),
                    )
                ),
                Stage::OldLogprob => supervise!(
                    "old_logprob",
                    fail,
                    shutdown,
                    faults,
                    old_logprob_stage(
                        eng.0,
                        placement,
                        flow.as_ref(),
                        &bus,
                        &replica_pool,
                        &lp_serial,
                        &retire,
                        &busy_slots,
                        faults.as_deref(),
                        &shutdown,
                        &busy,
                    )
                ),
                Stage::RefLogprob => supervise!(
                    "ref_logprob",
                    fail,
                    shutdown,
                    faults,
                    ref_logprob_stage(
                        eng.0,
                        placement,
                        flow.as_ref(),
                        &lp_serial,
                        &retire,
                        &busy_slots,
                        faults.as_deref(),
                        &shutdown,
                        &busy,
                    )
                ),
                Stage::Reward => supervise!(
                    "reward",
                    fail,
                    shutdown,
                    faults,
                    reward_stage(
                        placement,
                        flow.as_ref(),
                        &retire,
                        &busy_slots,
                        faults.as_deref(),
                        &shutdown,
                        &busy,
                    )
                ),
                Stage::Update => unreachable!("the update state is the driver"),
                }
                exited.store(true, Ordering::Release);
            });
        };

        // initial replica sets per the configured counts; the flow is
        // told the puller count so claim handouts fair-share across them
        spawn_initial(&mut sets, flow.as_ref(), cfg.stage_replicas, |st, id, r, b, e| {
            spawn_replica(st, id, r, b, e)
        });

        // ---- actor update state (this thread): admission window, group
        //      assembly, train steps, weight publication, metrics
        let mut update_loop = || -> Result<()> {
            let per_iter = cfg.prompts_per_iter * cfg.group_size;
            let mut accs: BTreeMap<usize, IterAcc> = BTreeMap::new();
            // update-ready claims whose groups are not yet complete
            let mut held: Vec<SampleMeta> = Vec::new();
            let mut admitted = 0usize;
            let mut completed = 0usize;
            let mut dispatch_prev = 0.0f64;
            let mut last_finalize = t_run;
            // newest published version (this thread is the only
            // publisher, so its view of the head is exact)
            let mut head_version: u64 = bus.head_version().as_u64();

            while completed < cfg.iterations {
                if let Some(msg) = fail.lock().unwrap().clone() {
                    anyhow::bail!(msg);
                }

                // quota-deferred admissions first (per-tenant FIFO,
                // reopened quotas drain oldest-first), then admit ahead,
                // bounded by the staleness window
                flush_deferred(flow.as_ref(), &mut charges, &mut deferred)?;
                while admitted < cfg.iterations && admitted < completed + window {
                    admit_iteration(
                        flow.as_ref(),
                        &mut task_gen,
                        cfg,
                        admitted,
                        &roster,
                        &mut charges,
                        Some(&mut deferred),
                    )?;
                    accs.insert(admitted, IterAcc::new(per_iter));
                    admitted += 1;
                }

                // claim whatever became update-ready; partial groups stay
                // *held* (claimed) rather than bounced through release —
                // the update state is the stage's only consumer, and
                // re-claiming every few ms would both spin this thread
                // and pollute the comm ledger with phantom round-trips.
                // Held claims are renewed every pass (this thread is
                // alive by definition), so they never lease-expire.
                if !held.is_empty() {
                    let held_idx: Vec<u64> = held.iter().map(|m| m.index).collect();
                    flow.renew(Stage::Update, &held_idx);
                }
                let fresh = flow.wait_ready(Stage::Update, usize::MAX, UPDATE_WAIT)?;
                if fresh.is_empty() {
                    // an idle driver pass is the "nothing is moving"
                    // signal: advance the logical lease clock so claims
                    // of dead/stalled stage workers can expire and their
                    // samples return to the ready pool. While stages make
                    // progress the clock stands still — leases measure
                    // silence, not wall time.
                    flow.tick_lease_clock();
                    // the same ticks pace the autoscaler: sample each
                    // stage's backlog (ready-queue depth) and idle ratio,
                    // grow under sustained pressure, drain-then-retire
                    // under sustained idleness — decisions are functions
                    // of tick counts and observed depths, never wall time
                    if let Some(sc) = scaler.as_mut() {
                        let tick = flow.lease_now();
                        observe_and_scale(sc, &mut sets, flow.as_ref(), tick, |st, id, r, b, e| {
                            spawn_replica(st, id, r, b, e)
                        });
                    }
                    if held.is_empty() {
                        continue;
                    }
                }
                // dedupe defensively: a reclaimed-and-regranted duplicate
                // of a held claim must not inflate its group
                for m in fresh {
                    if !held.iter().any(|h| h.index == m.index) {
                        held.push(m);
                    }
                }

                // bucket held claims into complete groups per iteration
                let mut by_group: BTreeMap<u64, Vec<SampleMeta>> = BTreeMap::new();
                for m in held.drain(..) {
                    by_group.entry(m.group).or_default().push(m);
                }
                let mut by_iter: BTreeMap<usize, Vec<SampleMeta>> = BTreeMap::new();
                for (g, ms) in by_group {
                    if ms.len() == cfg.group_size {
                        by_iter
                            .entry((g as usize) / cfg.prompts_per_iter)
                            .or_default()
                            .extend(ms);
                    } else {
                        held.extend(ms);
                    }
                }
                // train whole-group, whole-batch quanta only — a padded
                // partial batch mid-iteration would burn a full train
                // step on zero-mask rows that sync mode never pays. The
                // iteration's tail takes everything (sync pads there too)
                let quantum = take_quantum(b, cfg.group_size);
                let mut take: Vec<SampleMeta> = Vec::new();
                for (it, mut ms) in by_iter {
                    match accs.get(&it) {
                        Some(acc) => {
                            let n_take = if ms.len() == acc.remaining {
                                ms.len() // tail: drain the iteration
                            } else {
                                ms.len() / quantum * quantum
                            };
                            let rest = ms.split_off(n_take);
                            take.extend(ms);
                            held.extend(rest);
                        }
                        None => {
                            // cannot happen by construction (claims only
                            // exist for admitted, unfinalized iterations);
                            // drain defensively rather than abort the run
                            eprintln!(
                                "[grpo/pipelined] dropping {} update claims for unknown iteration {it}",
                                ms.len()
                            );
                            for m in &ms {
                                flow.retire(m.index);
                                charges.release(m.tenant);
                            }
                        }
                    }
                }
                if take.is_empty() {
                    continue;
                }

                let t0 = Instant::now();
                let mut ready = flow.fetch(placement.update, &take)?;
                ready.sort_by_key(|smp| (smp.group, smp.index));

                // process contiguous per-iteration slices
                let mut start = 0usize;
                while start < ready.len() {
                    let it = (ready[start].group as usize) / cfg.prompts_per_iter;
                    let end = ready[start..]
                        .iter()
                        .position(|smp| (smp.group as usize) / cfg.prompts_per_iter != it)
                        .map(|p| start + p)
                        .unwrap_or(ready.len());
                    let slice = &ready[start..end];
                    let rewards: Vec<f32> = slice
                        .iter()
                        .map(|smp| {
                            smp.get(FieldKind::Reward).unwrap().scalar().unwrap_or(0.0)
                        })
                        .collect();
                    let advs = group_advantages(&rewards, cfg.group_size);
                    let acc = accs
                        .get_mut(&it)
                        .ok_or_else(|| anyhow!("update for unadmitted iteration {it}"))?;
                    for (chunk, adv_chunk) in slice.chunks(b).zip(advs.chunks(b)) {
                        let batch = assemble_batch(chunk, adv_chunk, b, s, &tokenizer)?;
                        acc.stats.push(policy.train_step(engine, &batch)?);
                    }
                    for sm in slice {
                        flow.retire(sm.index);
                        charges.release(sm.tenant);
                        if roster.is_multi() {
                            *tenant_tokens.entry(sm.tenant).or_insert(0) +=
                                sm.resp_len as u64;
                        }
                        acc.prompt_tokens += sm.prompt_len as u64;
                        // behavior-policy staleness of this sample at the
                        // moment the update consumed it: publishes between
                        // its generation stamp and the current head
                        acc.lag.record(head_version.saturating_sub(sm.behavior_version));
                        // Score.exact by definition: the parsed completion
                        // equals the task answer (no Task clone, no
                        // re-run of the shaping arithmetic)
                        acc.exact += (crate::rewards::parse_answer(&sm.completion_text)
                            == Some(sm.answer)) as usize;
                    }
                    acc.remaining -= slice.len();
                    acc.rewards.extend(rewards);
                    start = end;
                }
                head_version = bus.publish(&policy.params)?.as_u64();
                busy.lock().unwrap().add("update", t0.elapsed().as_secs_f64());

                // finalize fully-updated iterations, in order
                loop {
                    match accs.get(&completed) {
                        Some(acc) if acc.remaining == 0 => {}
                        _ => break,
                    }
                    let acc = accs.remove(&completed).unwrap();
                    let now = Instant::now();
                    // marginal wall-clock attributed to this iteration;
                    // per-stage splits are meaningless under overlap (see
                    // the run's PipelineReport for the busy breakdown)
                    let wall = now.duration_since(last_finalize).as_secs_f64().max(1e-3);
                    last_finalize = now;
                    let dispatch_total = flow.dispatch_secs(&net);
                    let n = acc.rewards.len().max(1);
                    let n_stats = acc.stats.len().max(1) as f32;
                    let m = IterationMetrics {
                        iter: completed,
                        reward_mean: acc.rewards.iter().sum::<f32>() / n as f32,
                        exact_frac: acc.exact as f32 / n as f32,
                        loss: acc.stats.iter().map(|st| st.loss).sum::<f32>() / n_stats,
                        kl: acc.stats.iter().map(|st| st.kl).sum::<f32>() / n_stats,
                        ratio: acc.stats.iter().map(|st| st.ratio).sum::<f32>() / n_stats,
                        gen_secs: 0.0,
                        infer_secs: 0.0,
                        update_secs: 0.0,
                        total_secs: wall,
                        tps: throughput_tps(
                            cfg.prompts_per_iter as u64,
                            cfg.group_size as u64,
                            acc.prompt_tokens / n as u64,
                            cfg.max_new_tokens as u64,
                            1,
                            wall,
                        ),
                        dispatch_secs: dispatch_total - dispatch_prev,
                    };
                    dispatch_prev = dispatch_total;
                    if cfg.log_every > 0 && completed % cfg.log_every == 0 {
                        eprintln!(
                            "[grpo/pipelined] iter {completed:>4} reward={:.3} exact={:.2} loss={:+.4} lag(mean={:.2},max={}) wall={}",
                            m.reward_mean,
                            m.exact_frac,
                            m.loss,
                            acc.lag.mean(),
                            acc.lag.max,
                            crate::util::fmt_secs(wall)
                        );
                    }
                    iterations.push(m);
                    version_lags.push((completed, acc.lag));
                    completed += 1;
                    if cfg.eval_every > 0 && completed % cfg.eval_every == 0 {
                        evals.push((
                            completed,
                            evaluate(engine, &policy, cfg.eval_size, cfg.seed, 1)?,
                        ));
                    }
                }
            }
            Ok(())
        };
        let run_out = update_loop();
        shutdown.store(true, Ordering::Relaxed);
        run_out
    });
    scope_result?;
    // Every replica thread has joined: fold the run's replica accounting
    // into the report — autoscaler decisions/timelines plus the sets'
    // slot time, now exact (no busy second can accrue past this point).
    // Only elastic runs record entries: an unreplicated run keeps the
    // pre-elastic report shape (and the wall-clock utilization
    // denominator, which equals slot time for one thread).
    let mut scaling_out = StageScaling::default();
    if elastic {
        scaling_out = finish_scaling(scaler.take(), &mut sets);
        scaling_out.replica_weight_bytes_peak = replica_pool.peak_bytes();
    }
    debug_assert_eq!(
        replica_pool.live_bytes(),
        0,
        "every replica weight view must release its pool charge on exit"
    );

    let timers = Arc::try_unwrap(busy)
        .expect("stage threads joined; no other owners")
        .into_inner()
        .unwrap();
    debug_assert_eq!(
        bus_pool.live_bytes(),
        bus.retained_bytes(),
        "bus pool charges must track unique retained shard bytes"
    );
    let mut recovery = flow.lease_stats();
    if let Some(inj) = &injector {
        recovery.kills = inj.kills();
        recovery.stalls = inj.stalls();
        recovery.restarts = inj.restarts();
    }
    debug_assert!(
        recovery.consistent(),
        "lease accounting inconsistent: {recovery:?}"
    );
    let mut pipeline = PipelineReport {
        mode: PipelineMode::Pipelined.name().into(),
        wall_secs: t_run.elapsed().as_secs_f64(),
        busy: BTreeMap::new(),
        version_lag: version_lags,
        bus: bus.retention_stats(),
        recovery,
        scaling: scaling_out,
        gen_stream: *stream_acc.lock().unwrap(),
        partial: *partial_acc.lock().unwrap(),
        dock: flow.dock_report(),
        tenants: tenant_report(&roster, flow.as_ref(), quotas.as_deref(), &tenant_tokens),
    };
    for (stage, secs, _count) in timers.entries() {
        pipeline.busy.insert(stage, secs);
    }

    Ok(TrainReport {
        config: cfg.clone(),
        iterations,
        evals,
        pipeline,
        final_ledger: flow.ledger(),
        weight_history: cfg.keep_weight_history.then(|| Arc::clone(&bus)),
    })
}
