//! Deterministic fault injection for stage workers: the chaos half of the
//! lease-based recovery mechanism.
//!
//! A [`FaultPlan`] is a *pure function* of its seed: whether the `n`-th
//! claim a stage makes is killed, stalled, or untouched depends only on
//! `(seed, stage, n)` — never on thread timing or wall time — so a chaos
//! run's fault schedule is reproducible even though the OS scheduler
//! interleaves the stage threads differently every run. Time is the
//! flow's logical lease clock throughout (a stalled worker waits for
//! *ticks*, not milliseconds).
//!
//! Fault semantics, mirroring what a dead/stuck worker process does to a
//! real cluster:
//! * **Kill** — the worker abandons its freshly claimed batch without a
//!   writeback or a release and its stage loop exits; the executor
//!   respawns the stage (a *restart*, with fresh worker state). The
//!   abandoned claims are recovered by lease expiry.
//! * **Stall** — the worker holds its claims silently for `stall_ticks`
//!   logical ticks, then resumes and writes back *late*. If the stall
//!   outlives the lease, the samples are reclaimed and re-dispatched
//!   meanwhile, and the late writebacks land as superseded duplicates
//!   (dropped by the store's first-writer-wins / post-retire rules).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

use crate::transfer_dock::{SampleFlow, Stage};
use crate::util::rng::Rng;

/// What the plan does to one claim.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    Kill,
    Stall,
}

/// Seeded, rate-based fault schedule for the four pull-driven stage
/// workers (generation / old-logprob / ref-logprob / reward). The update
/// state is the driver and is never faulted — it plays the role of the
/// paper's controller process, whose failure is the run's failure.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultPlan {
    /// decision-stream seed (independent of the workload seed)
    pub seed: u64,
    /// probability a claim's worker is killed right after claiming
    pub kill_rate: f64,
    /// probability a claim's worker stalls before processing
    pub stall_rate: f64,
    /// how many logical lease-clock ticks a stall withholds writebacks
    /// (longer than the flow's lease → the claims get reclaimed)
    pub stall_ticks: u64,
    /// stop injecting after this many faults (0 = unbounded); a cheap
    /// guarantee of convergence for aggressive rates
    pub max_faults: u64,
}

impl Default for FaultPlan {
    fn default() -> Self {
        Self { seed: 0, kill_rate: 0.0, stall_rate: 0.0, stall_ticks: 12, max_faults: 0 }
    }
}

impl FaultPlan {
    pub fn enabled(&self) -> bool {
        self.kill_rate > 0.0 || self.stall_rate > 0.0
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        for (name, r) in [("kill_rate", self.kill_rate), ("stall_rate", self.stall_rate)] {
            anyhow::ensure!(r.is_finite() && (0.0..=1.0).contains(&r), "chaos {name} must be in [0,1]");
        }
        anyhow::ensure!(
            self.kill_rate + self.stall_rate <= 1.0,
            "chaos kill_rate + stall_rate must not exceed 1"
        );
        anyhow::ensure!(self.stall_ticks >= 1, "chaos stall_ticks must be >= 1");
        Ok(())
    }

    /// The deterministic decision for the `seq`-th claim of `stage`.
    pub fn decide_at(&self, stage: Stage, seq: u64) -> Option<FaultKind> {
        if !self.enabled() {
            return None;
        }
        let tag = stage_index(stage) as u64 + 1;
        let mut rng = Rng::new(
            self.seed
                ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15)
                ^ (seq + 1).wrapping_mul(0xD1B5_4A32_D192_ED03),
        );
        let d = rng.f64();
        if d < self.kill_rate {
            Some(FaultKind::Kill)
        } else if d < self.kill_rate + self.stall_rate {
            Some(FaultKind::Stall)
        } else {
            None
        }
    }
}

fn stage_index(stage: Stage) -> usize {
    Stage::ALL.iter().position(|&s| s == stage).unwrap()
}

/// How a stage loop ended: ran to shutdown, was fault-killed and wants
/// the supervisor to respawn it, or was drained-and-retired by an
/// autoscale scale-down (exits for good — no respawn, no abandoned
/// claims: the retire flag is only honored between claim batches).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StageExit {
    Completed,
    Killed,
    Retired,
}

/// Shared across stage-thread incarnations: per-stage claim sequence
/// numbers (so the decision stream survives restarts) plus injected-fault
/// accounting.
#[derive(Debug)]
pub struct FaultInjector {
    pub plan: FaultPlan,
    seq: [AtomicU64; 5],
    injected: AtomicU64,
    kills: AtomicU64,
    stalls: AtomicU64,
    restarts: AtomicU64,
}

impl FaultInjector {
    pub fn new(plan: FaultPlan) -> Self {
        Self {
            plan,
            seq: Default::default(),
            injected: AtomicU64::new(0),
            kills: AtomicU64::new(0),
            stalls: AtomicU64::new(0),
            restarts: AtomicU64::new(0),
        }
    }

    /// Consume the next decision of `stage`'s claim stream.
    pub fn decide(&self, stage: Stage) -> Option<FaultKind> {
        let seq = self.seq[stage_index(stage)].fetch_add(1, Ordering::Relaxed);
        let fault = self.plan.decide_at(stage, seq)?;
        if self.plan.max_faults > 0 {
            // reserve an injection slot atomically: concurrent stage
            // threads must not overshoot the cap (it is the convergence
            // guarantee for aggressive rates)
            let reserved = self.injected.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |n| {
                (n < self.plan.max_faults).then_some(n + 1)
            });
            if reserved.is_err() {
                return None;
            }
        } else {
            self.injected.fetch_add(1, Ordering::Relaxed);
        }
        match fault {
            FaultKind::Kill => self.kills.fetch_add(1, Ordering::Relaxed),
            FaultKind::Stall => self.stalls.fetch_add(1, Ordering::Relaxed),
        };
        Some(fault)
    }

    /// Deterministic stall: park until the flow's logical lease clock has
    /// advanced `stall_ticks` past the stall's start (or shutdown). The
    /// clock only moves on the driver's idle passes, so the stall's
    /// length is measured in reclaim opportunities, not milliseconds.
    pub fn stall(&self, flow: &dyn SampleFlow, shutdown: &AtomicBool) {
        let target = flow.lease_now().saturating_add(self.plan.stall_ticks);
        while flow.lease_now() < target && !shutdown.load(Ordering::Relaxed) {
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
    }

    /// Record a stage respawn after a kill.
    pub fn note_restart(&self) {
        self.restarts.fetch_add(1, Ordering::Relaxed);
    }

    pub fn kills(&self) -> u64 {
        self.kills.load(Ordering::Relaxed)
    }

    pub fn stalls(&self) -> u64 {
        self.stalls.load(Ordering::Relaxed)
    }

    pub fn restarts(&self) -> u64 {
        self.restarts.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decisions_are_pure_in_seed_stage_seq() {
        let plan = FaultPlan { seed: 42, kill_rate: 0.3, stall_rate: 0.3, ..Default::default() };
        for stage in Stage::ALL {
            for seq in 0..50 {
                assert_eq!(plan.decide_at(stage, seq), plan.decide_at(stage, seq));
            }
        }
        // different stages see different streams
        let a: Vec<_> = (0..50).map(|s| plan.decide_at(Stage::Generation, s)).collect();
        let b: Vec<_> = (0..50).map(|s| plan.decide_at(Stage::Reward, s)).collect();
        assert_ne!(a, b, "stage streams should decorrelate");
        // a different seed reshuffles the schedule
        let plan2 = FaultPlan { seed: 43, ..plan };
        let a2: Vec<_> = (0..50).map(|s| plan2.decide_at(Stage::Generation, s)).collect();
        assert_ne!(a, a2);
    }

    #[test]
    fn rates_partition_the_draw() {
        let never = FaultPlan::default();
        assert!(!never.enabled());
        assert_eq!(never.decide_at(Stage::Generation, 0), None);
        let always_kill = FaultPlan { kill_rate: 1.0, ..Default::default() };
        let always_stall = FaultPlan { stall_rate: 1.0, ..Default::default() };
        for seq in 0..20 {
            assert_eq!(always_kill.decide_at(Stage::Reward, seq), Some(FaultKind::Kill));
            assert_eq!(always_stall.decide_at(Stage::Reward, seq), Some(FaultKind::Stall));
        }
    }

    #[test]
    fn observed_rates_track_configured_rates() {
        let plan = FaultPlan { seed: 7, kill_rate: 0.25, stall_rate: 0.25, ..Default::default() };
        let n = 2000;
        let faults = (0..n)
            .filter(|&s| plan.decide_at(Stage::OldLogprob, s).is_some())
            .count() as f64;
        let frac = faults / n as f64;
        assert!((0.40..=0.60).contains(&frac), "observed fault rate {frac}");
    }

    #[test]
    fn injector_caps_and_counts() {
        let plan = FaultPlan { seed: 1, kill_rate: 1.0, max_faults: 3, ..Default::default() };
        let inj = FaultInjector::new(plan);
        let mut hit = 0;
        for _ in 0..10 {
            if inj.decide(Stage::Generation).is_some() {
                hit += 1;
            }
        }
        assert_eq!(hit, 3, "max_faults must cap injection");
        assert_eq!(inj.kills(), 3);
        inj.note_restart();
        assert_eq!(inj.restarts(), 1);
    }

    #[test]
    fn validation_rejects_bad_rates() {
        assert!(FaultPlan { kill_rate: -0.1, ..Default::default() }.validate().is_err());
        assert!(FaultPlan { kill_rate: 0.7, stall_rate: 0.7, ..Default::default() }
            .validate()
            .is_err());
        assert!(FaultPlan { stall_ticks: 0, stall_rate: 0.1, ..Default::default() }
            .validate()
            .is_err());
        assert!(FaultPlan { kill_rate: 0.5, stall_rate: 0.5, ..Default::default() }
            .validate()
            .is_ok());
    }
}
