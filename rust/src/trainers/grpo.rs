//! GRPO trainer shell: configuration, per-iteration metrics, and the
//! report type. The iteration loop itself lives in [`super::executor`],
//! which drives the worker states over the sample flow in either `sync`
//! (barrier-per-stage, the seed semantics) or `pipelined` (concurrent
//! stage threads) mode — see DESIGN.md for the execution model.
//!
//! One logical iteration (paper Fig. 1):
//!   1. admit G prompts × N group copies into the sample flow
//!   2. actor generation state: batched rollout (continuous batcher)
//!   3. actor inference (old log-probs), reference inference, rule reward
//!   4. group advantages (GRPO), assemble update batches, train_step
//!   5. retire finished samples; record metrics + comm accounting

use anyhow::Result;
use std::sync::Arc;

use crate::metrics::PipelineReport;
use crate::runtime::{Engine, Tensor, TrainBatch};
use crate::tokenizer::Tokenizer;
use crate::transfer_dock::{
    DockTopology, FieldKind, ReplayBuffer, Sample, SampleFlow, TransferDock,
};

use super::eval::EvalResult;
use super::executor::{self, PipelineMode};

#[derive(Debug, Clone)]
pub struct GrpoConfig {
    pub iterations: usize,
    /// G: prompts per iteration
    pub prompts_per_iter: usize,
    /// N: responses per prompt (group size)
    pub group_size: usize,
    pub lr: f32,
    pub max_new_tokens: usize,
    pub temperature: f32,
    pub seed: u64,
    /// simulated cluster nodes for dataflow accounting
    pub nodes: usize,
    /// run the centralized replay-buffer baseline instead of the dock
    pub use_replay_buffer: bool,
    /// execution model: barrier-per-stage or concurrent stage workers
    pub pipeline: PipelineMode,
    /// pipelined mode only: how many iterations may be admitted ahead of
    /// the last completed update (bounded off-policy staleness window);
    /// 1 = lockstep admission, 2+ lets generation overlap the update
    pub max_inflight_iters: usize,
    /// emit behavior logprobs (`old_lp`) directly from the generation
    /// stage's sampler instead of recomputing them through the logprobs
    /// artifact — the old-logprob state becomes verify-or-fill. Off by
    /// default: the emitted values come through the incremental decode
    /// path, so they match the recompute only to float tolerance and
    /// would break sync mode's bitwise seed-reproducibility.
    pub gen_logprobs: bool,
    /// retain every published weight snapshot and attach the bus to the
    /// [`TrainReport`] — test/debug instrumentation (memory grows with
    /// iterations; not exposed on the CLI). Used by the behavior-policy
    /// property suite to recompute each sample's old-logprob from scratch
    /// under its stamped version.
    pub keep_weight_history: bool,
    /// claim-lease duration in logical ticks (the executor ticks the
    /// clock only on idle driver passes): a stage worker that claims work
    /// and then shows no writeback activity for this many ticks loses the
    /// claim — the samples return to the ready pool for redispatch
    pub lease_ticks: u64,
    /// controller shards per worker state (K): the dock partitions the
    /// sample space across K controller shards per stage — each owning
    /// its own ready pool, lease table, and metadata-broadcast lock —
    /// with cross-shard work stealing when a shard's pool drains. 1 (the
    /// default) is the single-controller dock, bit-identical to the
    /// pre-sharding behavior
    pub dock_shards: usize,
    /// work stealing fires when the home shard's ready pool has drained
    /// to at most this depth after a short claim (0 = steal only when
    /// the home pool is empty); requires `dock_shards > 1`
    pub steal_threshold: usize,
    /// chaos: probability each stage claim's worker is killed (pipelined
    /// mode only; 0 disables)
    pub chaos_kill_rate: f64,
    /// chaos: probability each stage claim's worker stalls past its lease
    pub chaos_stall_rate: f64,
    /// chaos: stall length in logical lease-clock ticks
    pub chaos_stall_ticks: u64,
    /// chaos: fault-schedule seed (independent of the workload seed so
    /// the same training stream can be replayed under different faults)
    pub chaos_seed: u64,
    /// chaos: stop injecting after this many faults (0 = unbounded)
    pub chaos_max_faults: u64,
    /// pipelined mode only: data-parallel replica threads per pull-driven
    /// worker state (`--stage-replicas gen=4,logprob=2`); leases make the
    /// concurrent pullers safe, fair-share batching splits claims across
    /// them, and the update driver stays single — it owns the policy
    pub stage_replicas: super::autoscale::StageReplicas,
    /// enable the backlog-driven replica autoscaler (pipelined only):
    /// replica counts move within [autoscale_min, autoscale_max] from
    /// backlog/idle observations taken on lease ticks, with hysteresis
    pub autoscale: bool,
    pub autoscale_min: usize,
    pub autoscale_max: usize,
    /// scale-up pressure threshold: ready-queue depth that counts as
    /// over-backlog when no replica is idle
    pub autoscale_backlog_hi: usize,
    /// scale-down threshold: depth at or below this with an idle replica
    /// counts as idle pressure
    pub autoscale_backlog_lo: usize,
    /// consecutive over-backlog ticks before growing by one replica
    pub autoscale_up_ticks: u32,
    /// consecutive idle ticks before drain-then-retiring one replica
    pub autoscale_down_ticks: u32,
    /// pipelined mode only: run the generation stage as a persistent
    /// streaming scheduler ([`crate::generation::GenSession`]) instead of
    /// the claim-a-batch-and-drain loop — newly claimed samples join at
    /// decode-step granularity, finished sequences retire immediately,
    /// and KV is charged through a paged block allocator
    pub gen_streaming: bool,
    /// streaming only: max prompt tokens consumed per scheduler step per
    /// prefilling sequence (chunked prefill; decode lanes pause while a
    /// chunk runs)
    pub prefill_chunk: usize,
    /// streaming only: KV page size in tokens for the block allocator
    /// (admission reserves worst-case blocks up front)
    pub kv_block_tokens: usize,
    /// streaming only: make generation resumable. Abandoned sequences
    /// (kill, stall-expiry reclaim, cooperative scale-down drain) persist
    /// their decoded prefix through the sample flow as a partial rollout
    /// — a segment list stamping every token span with the behavior
    /// version it was decoded under — and a redispatch resumes from the
    /// prefix with the per-sequence RNG fast-forwarded, bit-identical to
    /// an uninterrupted run at the same versions. Old-logprob scores each
    /// segment under its own version, so the GRPO ratio stays
    /// behavior-policy-exact across version boundaries.
    pub partial_rollouts: bool,
    /// partial rollouts only: when a weight publish lands, preempt every
    /// in-flight sequence (persist + release) so it resumes under the new
    /// head instead of finishing its long tail under stale weights —
    /// trades a resume round-trip for fresher behavior policy
    pub preempt_on_publish: bool,
    /// tenant jobs multiplexed over the shared stage pools (1 = the
    /// single default tenant, bit-identical to pre-tenancy behavior).
    /// Tenants stripe the prompt stream round-robin by admission
    /// position; claims are handed out deficit-weighted round robin
    pub tenants: usize,
    /// positional per-tenant claim weights (`--tenant-weight 3,1`);
    /// omitted tenants weigh 1
    pub tenant_weights: Vec<u32>,
    /// positional per-tenant byte quotas in MiB (`--tenant-quota-mb 64`);
    /// omitted tenants are uncapped. A tenant at its quota has its own
    /// admissions deferred (KV and prompt alike); with
    /// `--partial-rollouts` an over-quota tenant's in-flight decodes are
    /// preempted via persist-and-release, losing no tokens
    pub tenant_quota_mb: Vec<u64>,
    /// evaluate every k iterations (0 = only at the end)
    pub eval_every: usize,
    pub eval_size: usize,
    pub log_every: usize,
}

impl GrpoConfig {
    /// Structural validation, run at config load and again by the
    /// executor before any thread spawns. Catches the degenerate values
    /// that used to fail mid-run — most notably a staleness window of 0,
    /// which would size the weight-bus ring below what in-flight samples
    /// need and surface as an `Evicted` error deep inside the
    /// old-logprob stage.
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.prompts_per_iter >= 1, "prompts_per_iter must be >= 1");
        anyhow::ensure!(self.group_size >= 1, "group_size must be >= 1");
        anyhow::ensure!(
            self.max_inflight_iters >= 1,
            "max_inflight_iters must be >= 1 (1 = lockstep admission)"
        );
        anyhow::ensure!(self.max_new_tokens >= 1, "max_new_tokens must be >= 1");
        anyhow::ensure!(
            self.lease_ticks >= 2,
            "lease_ticks must be >= 2: a lease of T ticks expires on the T-th \
             tick after grant/renewal, so T=1 would reclaim held claims on the \
             very pass that renewed them"
        );
        anyhow::ensure!(self.dock_shards >= 1, "--dock-shards must be >= 1");
        anyhow::ensure!(
            self.steal_threshold == 0 || self.dock_shards > 1,
            "--steal-threshold requires --dock-shards > 1 (a single shard has \
             no sibling to steal from)"
        );
        anyhow::ensure!(
            !self.use_replay_buffer || self.dock_shards == 1,
            "--dock-shards > 1 requires the transfer dock (the replay-buffer \
             baseline is the centralized K=1 design by definition)"
        );
        self.fault_plan().map(|p| p.validate()).unwrap_or(Ok(()))?;
        anyhow::ensure!(
            self.fault_plan().is_none() || self.pipeline == PipelineMode::Pipelined,
            "chaos fault injection requires --pipeline pipelined (sync has no \
             concurrent stage workers to kill)"
        );
        anyhow::ensure!(
            self.stage_replicas.min_count() >= 1,
            "--stage-replicas: every stage needs at least one replica"
        );
        anyhow::ensure!(
            (self.stage_replicas.all_single() && !self.autoscale)
                || self.pipeline == PipelineMode::Pipelined,
            "--stage-replicas / --autoscale require --pipeline pipelined (sync \
             runs every stage on one thread by definition)"
        );
        anyhow::ensure!(
            !self.gen_streaming || self.pipeline == PipelineMode::Pipelined,
            "--gen-streaming requires --pipeline pipelined (sync mode's \
             barrier semantics are the batch-decode baseline by definition)"
        );
        anyhow::ensure!(self.prefill_chunk >= 1, "prefill_chunk must be >= 1");
        anyhow::ensure!(self.kv_block_tokens >= 1, "kv_block_tokens must be >= 1");
        anyhow::ensure!(
            !self.partial_rollouts || self.gen_streaming,
            "--partial-rollouts requires --gen-streaming (only the streaming \
             session holds per-sequence decode state worth persisting)"
        );
        anyhow::ensure!(
            !self.preempt_on_publish || self.partial_rollouts,
            "--preempt-on-publish requires --partial-rollouts (preemption \
             without persistence would discard decoded prefixes)"
        );
        // the tenant roster's own invariants (counts, weight/quota list
        // lengths and ranges) — built once here so a bad `--tenant-weight`
        // fails at config load, not mid-run
        self.tenant_set()?;
        if let Some(ac) = self.autoscale_config() {
            ac.validate()?;
            anyhow::ensure!(
                (ac.min_replicas..=ac.max_replicas)
                    .contains(&self.stage_replicas.max_count())
                    && (ac.min_replicas..=ac.max_replicas)
                        .contains(&self.stage_replicas.min_count()),
                "--stage-replicas ({}) must start inside the autoscale bounds \
                 [{}, {}]",
                self.stage_replicas.describe(),
                ac.min_replicas,
                ac.max_replicas
            );
        }
        Ok(())
    }

    /// The configured autoscaler, if enabled.
    pub fn autoscale_config(&self) -> Option<super::autoscale::AutoscaleConfig> {
        self.autoscale.then(|| super::autoscale::AutoscaleConfig {
            min_replicas: self.autoscale_min,
            max_replicas: self.autoscale_max,
            backlog_hi: self.autoscale_backlog_hi,
            backlog_lo: self.autoscale_backlog_lo,
            up_ticks: self.autoscale_up_ticks,
            down_ticks: self.autoscale_down_ticks,
        })
    }

    /// The configured tenant roster (always at least the default tenant).
    pub fn tenant_set(&self) -> Result<super::tenancy::TenantSet> {
        super::tenancy::TenantSet::from_config(
            self.tenants,
            &self.tenant_weights,
            &self.tenant_quota_mb,
        )
    }

    /// The configured chaos schedule, if any (None when both rates are 0).
    pub fn fault_plan(&self) -> Option<super::faults::FaultPlan> {
        let plan = super::faults::FaultPlan {
            // default the fault stream to the workload seed, but keep it
            // overridable so the same training stream can be replayed
            // under a different fault schedule
            seed: if self.chaos_seed != 0 { self.chaos_seed } else { self.seed ^ 0xc4a0_5 },
            kill_rate: self.chaos_kill_rate,
            stall_rate: self.chaos_stall_rate,
            stall_ticks: self.chaos_stall_ticks,
            max_faults: self.chaos_max_faults,
        };
        plan.enabled().then_some(plan)
    }
}

impl Default for GrpoConfig {
    fn default() -> Self {
        Self {
            iterations: 50,
            prompts_per_iter: 16,
            group_size: 4,
            lr: 1e-3,
            max_new_tokens: 8,
            temperature: 1.0,
            seed: 0,
            nodes: 4,
            use_replay_buffer: false,
            pipeline: PipelineMode::Sync,
            max_inflight_iters: 2,
            gen_logprobs: false,
            keep_weight_history: false,
            lease_ticks: crate::transfer_dock::DEFAULT_LEASE_TICKS,
            dock_shards: 1,
            steal_threshold: 0,
            chaos_kill_rate: 0.0,
            chaos_stall_rate: 0.0,
            chaos_stall_ticks: 12,
            chaos_seed: 0,
            chaos_max_faults: 0,
            stage_replicas: super::autoscale::StageReplicas::default(),
            autoscale: false,
            autoscale_min: 1,
            autoscale_max: 4,
            autoscale_backlog_hi: 16,
            autoscale_backlog_lo: 0,
            autoscale_up_ticks: 3,
            autoscale_down_ticks: 6,
            gen_streaming: false,
            prefill_chunk: 4,
            kv_block_tokens: 16,
            partial_rollouts: false,
            preempt_on_publish: false,
            tenants: 1,
            tenant_weights: Vec::new(),
            tenant_quota_mb: Vec::new(),
            eval_every: 0,
            eval_size: 64,
            log_every: 10,
        }
    }
}

#[derive(Debug, Clone, Copy)]
pub struct IterationMetrics {
    pub iter: usize,
    pub reward_mean: f32,
    pub exact_frac: f32,
    pub loss: f32,
    pub kl: f32,
    pub ratio: f32,
    /// per-stage seconds; zero in pipelined mode, where stages overlap and
    /// the run-level [`PipelineReport`] carries the busy breakdown
    pub gen_secs: f64,
    pub infer_secs: f64,
    pub update_secs: f64,
    pub total_secs: f64,
    /// Eq. (5) throughput on this testbed (1 real device)
    pub tps: f64,
    /// simulated dispatch seconds implied by the iteration's comm bytes
    pub dispatch_secs: f64,
}

#[derive(Debug)]
pub struct TrainReport {
    pub config: GrpoConfig,
    pub iterations: Vec<IterationMetrics>,
    pub evals: Vec<(usize, Vec<EvalResult>)>,
    /// wall-clock vs per-stage busy time (overlap accounting); also the
    /// single home of per-stage totals — sync mode reports stage times
    /// here, pipelined mode reports thread busy time — and the
    /// per-iteration behavior-policy version-lag stats
    pub pipeline: PipelineReport,
    pub final_ledger: crate::transfer_dock::CommLedger,
    /// every published weight snapshot, when
    /// [`GrpoConfig::keep_weight_history`] was set (None otherwise)
    pub weight_history: Option<Arc<crate::weights::WeightBus>>,
}

impl TrainReport {
    pub fn summary(&self) -> String {
        let last = self.iterations.last();
        let first = self.iterations.first();
        format!(
            "GRPO {} iters: reward {:.3} → {:.3}, exact {:.2} → {:.2}, mean TPS {:.1}, dispatch(sim) {}\n{}",
            self.iterations.len(),
            first.map(|m| m.reward_mean).unwrap_or(0.0),
            last.map(|m| m.reward_mean).unwrap_or(0.0),
            first.map(|m| m.exact_frac).unwrap_or(0.0),
            last.map(|m| m.exact_frac).unwrap_or(0.0),
            self.mean_tps(),
            crate::util::fmt_secs(
                self.iterations.iter().map(|m| m.dispatch_secs).sum::<f64>()
            ),
            self.pipeline.summary(),
        )
    }

    pub fn mean_tps(&self) -> f64 {
        if self.iterations.is_empty() {
            return 0.0;
        }
        self.iterations.iter().map(|m| m.tps).sum::<f64>() / self.iterations.len() as f64
    }

    /// Reward curve as (iter, reward) pairs (Fig. 8 / Fig. 11 series).
    pub fn reward_curve(&self) -> Vec<(usize, f32)> {
        self.iterations.iter().map(|m| (m.iter, m.reward_mean)).collect()
    }
}

/// Run GRPO end-to-end on the loaded artifacts.
pub fn run_grpo(engine: &Engine, cfg: &GrpoConfig) -> Result<TrainReport> {
    let flow: Arc<dyn SampleFlow> = if cfg.use_replay_buffer {
        Arc::new(ReplayBuffer::with_lease(0, cfg.lease_ticks))
    } else {
        Arc::new(TransferDock::with_shards(
            DockTopology::spread(cfg.nodes),
            cfg.lease_ticks,
            cfg.dock_shards,
            cfg.steal_threshold,
        ))
    };
    run_grpo_on_flow(engine, cfg, flow)
}

/// Run GRPO over a caller-supplied sample flow (used by benches to A/B
/// the dock against the replay buffer with everything else fixed).
pub fn run_grpo_on_flow(
    engine: &Engine,
    cfg: &GrpoConfig,
    flow: Arc<dyn SampleFlow>,
) -> Result<TrainReport> {
    executor::run(engine, cfg, flow)
}

/// Assemble one train_step batch from update-ready samples; short chunks
/// are padded with zero-mask rows that contribute nothing to the loss.
pub(crate) fn assemble_batch(
    samples: &[Sample],
    advs: &[f32],
    b: usize,
    s: usize,
    tokenizer: &Tokenizer,
) -> Result<TrainBatch> {
    anyhow::ensure!(!samples.is_empty() && samples.len() <= b);
    let mut tokens = Vec::with_capacity(b * s);
    let mut mask = Vec::with_capacity(b * (s - 1));
    let mut old_lp = Vec::with_capacity(b * (s - 1));
    let mut ref_lp = Vec::with_capacity(b * (s - 1));
    let mut adv = Vec::with_capacity(b);

    for (sample, &a) in samples.iter().zip(advs) {
        let mut row = sample.get(FieldKind::Tokens).unwrap().as_i32()?.to_vec();
        row.resize(s, tokenizer.pad_id);
        tokens.extend(row);
        mask.extend(resize_f32(sample.get(FieldKind::RespMask).unwrap().as_f32()?, s - 1));
        old_lp.extend(resize_f32(sample.get(FieldKind::OldLp).unwrap().as_f32()?, s - 1));
        ref_lp.extend(resize_f32(sample.get(FieldKind::RefLp).unwrap().as_f32()?, s - 1));
        adv.push(a);
    }
    // pad to the artifact batch with inert rows
    for _ in samples.len()..b {
        tokens.extend(std::iter::repeat_n(tokenizer.pad_id, s));
        mask.extend(std::iter::repeat_n(0.0f32, s - 1));
        old_lp.extend(std::iter::repeat_n(0.0f32, s - 1));
        ref_lp.extend(std::iter::repeat_n(0.0f32, s - 1));
        adv.push(0.0);
    }
    Ok(TrainBatch {
        tokens: Tensor::i32(&[b, s], tokens)?,
        resp_mask: Tensor::f32(&[b, s - 1], mask)?,
        old_lp: Tensor::f32(&[b, s - 1], old_lp)?,
        ref_lp: Tensor::f32(&[b, s - 1], ref_lp)?,
        adv: Tensor::f32(&[b], adv)?,
    })
}

fn resize_f32(v: &[f32], n: usize) -> Vec<f32> {
    let mut out = v.to_vec();
    out.resize(n, 0.0);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact_dir;

    #[test]
    fn chaos_config_gating() {
        // no rates → no plan
        assert!(GrpoConfig::default().fault_plan().is_none());
        // rates in pipelined mode validate; in sync mode they are rejected
        let mut cfg = GrpoConfig {
            chaos_kill_rate: 0.2,
            chaos_stall_rate: 0.1,
            pipeline: PipelineMode::Pipelined,
            ..Default::default()
        };
        assert!(cfg.validate().is_ok());
        let plan = cfg.fault_plan().expect("rates > 0 must build a plan");
        assert!(plan.enabled());
        assert_ne!(plan.seed, 0, "fault seed must default off the workload seed");
        cfg.pipeline = PipelineMode::Sync;
        assert!(cfg.validate().is_err(), "chaos requires the pipelined executor");
        // degenerate lease is rejected
        let bad = GrpoConfig { lease_ticks: 0, ..Default::default() };
        assert!(bad.validate().is_err());
        // out-of-range rates are rejected
        let bad = GrpoConfig {
            chaos_kill_rate: 1.5,
            pipeline: PipelineMode::Pipelined,
            ..Default::default()
        };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn elastic_config_gating() {
        use super::super::autoscale::StageReplicas;
        // replicas / autoscale require the pipelined executor
        let bad = GrpoConfig {
            stage_replicas: StageReplicas::parse("gen=4,logprob=2").unwrap(),
            ..Default::default()
        };
        assert!(bad.validate().is_err(), "replicas in sync mode must be rejected");
        let bad = GrpoConfig { autoscale: true, ..Default::default() };
        assert!(bad.validate().is_err(), "autoscale in sync mode must be rejected");
        let ok = GrpoConfig {
            stage_replicas: StageReplicas::parse("gen=4,logprob=2").unwrap(),
            pipeline: PipelineMode::Pipelined,
            ..Default::default()
        };
        assert!(ok.validate().is_ok());
        // autoscale bounds must admit the starting counts
        let bad = GrpoConfig {
            stage_replicas: StageReplicas::uniform(8),
            autoscale: true,
            autoscale_max: 4,
            pipeline: PipelineMode::Pipelined,
            ..Default::default()
        };
        assert!(bad.validate().is_err());
        let ok = GrpoConfig {
            autoscale: true,
            pipeline: PipelineMode::Pipelined,
            ..Default::default()
        };
        assert!(ok.validate().is_ok());
        let ac = ok.autoscale_config().expect("autoscale on builds a config");
        assert_eq!(ac.max_replicas, 4);
        assert!(GrpoConfig::default().autoscale_config().is_none());
        // degenerate knobs are rejected at validation
        let bad = GrpoConfig {
            autoscale: true,
            autoscale_up_ticks: 0,
            pipeline: PipelineMode::Pipelined,
            ..Default::default()
        };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn streaming_config_gating() {
        // streaming requires the pipelined executor
        let bad = GrpoConfig { gen_streaming: true, ..Default::default() };
        assert!(bad.validate().is_err(), "streaming in sync mode must be rejected");
        let ok = GrpoConfig {
            gen_streaming: true,
            pipeline: PipelineMode::Pipelined,
            ..Default::default()
        };
        assert!(ok.validate().is_ok());
        // degenerate knobs are rejected
        let bad = GrpoConfig {
            gen_streaming: true,
            prefill_chunk: 0,
            pipeline: PipelineMode::Pipelined,
            ..Default::default()
        };
        assert!(bad.validate().is_err());
        let bad = GrpoConfig {
            gen_streaming: true,
            kv_block_tokens: 0,
            pipeline: PipelineMode::Pipelined,
            ..Default::default()
        };
        assert!(bad.validate().is_err());
        // streaming composes with chaos + replicas at the config layer
        let ok = GrpoConfig {
            gen_streaming: true,
            chaos_kill_rate: 0.2,
            stage_replicas: super::super::autoscale::StageReplicas::parse("gen=2")
                .unwrap(),
            pipeline: PipelineMode::Pipelined,
            ..Default::default()
        };
        assert!(ok.validate().is_ok());
    }

    #[test]
    fn partial_rollout_config_gating() {
        // partial rollouts require the streaming scheduler (which itself
        // requires the pipelined executor)
        let bad = GrpoConfig {
            partial_rollouts: true,
            pipeline: PipelineMode::Pipelined,
            ..Default::default()
        };
        assert!(bad.validate().is_err(), "partial rollouts need --gen-streaming");
        let ok = GrpoConfig {
            partial_rollouts: true,
            gen_streaming: true,
            pipeline: PipelineMode::Pipelined,
            ..Default::default()
        };
        assert!(ok.validate().is_ok());
        // publish preemption needs persistence to be lossless
        let bad = GrpoConfig {
            preempt_on_publish: true,
            gen_streaming: true,
            pipeline: PipelineMode::Pipelined,
            ..Default::default()
        };
        assert!(bad.validate().is_err(), "preemption needs --partial-rollouts");
        let ok = GrpoConfig {
            partial_rollouts: true,
            preempt_on_publish: true,
            gen_streaming: true,
            pipeline: PipelineMode::Pipelined,
            ..Default::default()
        };
        assert!(ok.validate().is_ok());
        // and the whole stack composes with chaos at the config layer
        let ok = GrpoConfig {
            partial_rollouts: true,
            gen_streaming: true,
            chaos_kill_rate: 0.2,
            pipeline: PipelineMode::Pipelined,
            ..Default::default()
        };
        assert!(ok.validate().is_ok());
    }

    #[test]
    fn sharded_dock_config_gating() {
        // K=1 (the default) validates everywhere
        assert!(GrpoConfig::default().validate().is_ok());
        // K>1 validates in both executors — sharding is a dock property,
        // not an executor property
        let ok = GrpoConfig { dock_shards: 4, ..Default::default() };
        assert!(ok.validate().is_ok());
        let ok = GrpoConfig {
            dock_shards: 4,
            steal_threshold: 2,
            pipeline: PipelineMode::Pipelined,
            ..Default::default()
        };
        assert!(ok.validate().is_ok());
        // degenerate K is rejected
        let bad = GrpoConfig { dock_shards: 0, ..Default::default() };
        assert!(bad.validate().is_err());
        // a steal threshold without siblings is meaningless
        let bad = GrpoConfig { steal_threshold: 2, ..Default::default() };
        assert!(bad.validate().is_err(), "steal threshold needs K > 1");
        // the replay-buffer baseline is centralized by definition
        let bad = GrpoConfig { dock_shards: 4, use_replay_buffer: true, ..Default::default() };
        assert!(bad.validate().is_err(), "replay buffer cannot shard");
        // the full stack composes at the config layer
        let ok = GrpoConfig {
            dock_shards: 4,
            steal_threshold: 1,
            gen_streaming: true,
            partial_rollouts: true,
            chaos_kill_rate: 0.2,
            pipeline: PipelineMode::Pipelined,
            ..Default::default()
        };
        assert!(ok.validate().is_ok());
    }

    #[test]
    fn two_iterations_end_to_end_dock() {
        let engine = Engine::load(artifact_dir("tiny")).expect("make artifacts first");
        let cfg = GrpoConfig {
            iterations: 2,
            prompts_per_iter: 4,
            group_size: 2,
            max_new_tokens: 4,
            log_every: 0,
            ..Default::default()
        };
        let report = run_grpo(&engine, &cfg).unwrap();
        assert_eq!(report.iterations.len(), 2);
        for m in &report.iterations {
            assert!(m.loss.is_finite());
            assert!(m.reward_mean >= 0.0 && m.reward_mean <= 1.0);
            assert!(m.tps > 0.0);
        }
        assert!(report.final_ledger.total_bytes() > 0);
        assert_eq!(report.pipeline.mode, "sync");
        assert!(report.pipeline.wall_secs > 0.0);
    }

    #[test]
    fn replay_buffer_baseline_matches_math() {
        // same seed → same generation/rewards regardless of dataflow
        let engine = Engine::load(artifact_dir("tiny")).expect("make artifacts first");
        let mk = |rb| GrpoConfig {
            iterations: 1,
            prompts_per_iter: 4,
            group_size: 2,
            max_new_tokens: 4,
            use_replay_buffer: rb,
            log_every: 0,
            ..Default::default()
        };
        let a = run_grpo(&engine, &mk(false)).unwrap();
        let b = run_grpo(&engine, &mk(true)).unwrap();
        assert_eq!(a.iterations[0].reward_mean, b.iterations[0].reward_mean);
        assert!((a.iterations[0].loss - b.iterations[0].loss).abs() < 1e-5);
        // Both dataflows move comparable payload; at this micro scale
        // (8 samples, co-located workers) dispatch seconds are small for
        // both — the paper's point exactly ("an RL system only spends a
        // few seconds on sample flow with low loads", Table 1). The
        // dock-wins-at-scale claim is exercised by the Fig. 9 linearity
        // bench and tests/dataflow_scale.rs with realistic G×N and spread
        // workers.
        let net = crate::transfer_dock::NetworkModel::paper();
        let dock_secs = a.final_ledger.dispatch_secs_sharded(&net, 4);
        let rb_secs = b.final_ledger.dispatch_secs(&net);
        assert!(dock_secs < 1.0 && rb_secs < 1.0);
        assert!(a.final_ledger.total_bytes() > 0 && b.final_ledger.total_bytes() > 0);
        // the centralized store is the single hottest store by traffic
        assert!(b.final_ledger.max_store_bytes >= a.final_ledger.max_store_bytes);
    }

    #[test]
    fn sync_mode_is_deterministic() {
        // the determinism contract the pipelined refactor must preserve:
        // two sync runs with the same seed produce identical reward/loss
        let engine = Engine::load(artifact_dir("tiny")).expect("make artifacts first");
        let cfg = GrpoConfig {
            iterations: 2,
            prompts_per_iter: 4,
            group_size: 2,
            max_new_tokens: 4,
            log_every: 0,
            ..Default::default()
        };
        let a = run_grpo(&engine, &cfg).unwrap();
        let b = run_grpo(&engine, &cfg).unwrap();
        for (ma, mb) in a.iterations.iter().zip(&b.iterations) {
            assert_eq!(ma.reward_mean, mb.reward_mean);
            assert_eq!(ma.loss, mb.loss);
            assert_eq!(ma.kl, mb.kl);
        }
    }

    #[test]
    fn pipelined_mode_end_to_end() {
        let engine = Engine::load(artifact_dir("tiny")).expect("make artifacts first");
        let cfg = GrpoConfig {
            iterations: 3,
            prompts_per_iter: 4,
            group_size: 2,
            max_new_tokens: 4,
            pipeline: PipelineMode::Pipelined,
            max_inflight_iters: 2,
            log_every: 0,
            ..Default::default()
        };
        let report = run_grpo(&engine, &cfg).unwrap();
        assert_eq!(report.iterations.len(), 3, "every iteration must finalize");
        for m in &report.iterations {
            assert!(m.loss.is_finite());
            assert!(m.reward_mean >= 0.0 && m.reward_mean <= 1.0);
        }
        assert_eq!(report.pipeline.mode, "pipelined");
        // the versioned bus reports its shard-level retention accounting
        let bus = &report.pipeline.bus;
        assert!(bus.versions > 0 && bus.retained_bytes > 0, "bus retention unreported");
        assert!(
            bus.retained_bytes <= bus.naive_equivalent_bytes,
            "dedup retention can never exceed the full-copy equivalent"
        );
        // every stage must have recorded busy time
        for stage in ["generation", "old_logprob", "ref_logprob", "reward", "update"] {
            assert!(
                report.pipeline.busy.contains_key(stage),
                "missing busy time for {stage}"
            );
        }
        // flow fully drained: nothing left resident after the run
        assert!(report.final_ledger.total_bytes() > 0);
    }

    #[test]
    fn pipelined_trains_comparably_to_sync() {
        // the two modes use different generation RNG streams and the
        // pipelined mode is off-policy by a bounded window, so bitwise
        // parity is only guaranteed for sync; here we assert the
        // pipelined trainer actually *trains*: every iteration finalizes
        // with the full sample count reflected in its metrics, losses are
        // finite, and rewards/exact stay in range in both modes.
        let engine = Engine::load(artifact_dir("tiny")).expect("make artifacts first");
        let mk = |mode| GrpoConfig {
            iterations: 2,
            prompts_per_iter: 4,
            group_size: 2,
            max_new_tokens: 4,
            pipeline: mode,
            log_every: 0,
            ..Default::default()
        };
        let a = run_grpo(&engine, &mk(PipelineMode::Sync)).unwrap();
        let b = run_grpo(&engine, &mk(PipelineMode::Pipelined)).unwrap();
        assert_eq!(a.iterations.len(), b.iterations.len());
        for (ma, mb) in a.iterations.iter().zip(&b.iterations) {
            for m in [ma, mb] {
                assert!(m.loss.is_finite());
                assert!(m.reward_mean >= 0.0 && m.reward_mean <= 1.0);
                assert!(m.exact_frac >= 0.0 && m.exact_frac <= 1.0);
                assert!(m.kl.is_finite());
            }
        }
        // both runs must have moved real bytes through the dock
        assert!(b.final_ledger.total_bytes() > 0);
    }

    #[test]
    fn tenancy_config_gating() {
        // the default config is the single default tenant
        let cfg = GrpoConfig::default();
        let roster = cfg.tenant_set().unwrap();
        assert_eq!(roster.len(), 1);
        assert!(!roster.is_multi());
        cfg.validate().unwrap();

        // a weighted two-tenant roster validates and exposes its weights
        let cfg = GrpoConfig {
            tenants: 2,
            tenant_weights: vec![3, 1],
            tenant_quota_mb: vec![64],
            ..Default::default()
        };
        cfg.validate().unwrap();
        let roster = cfg.tenant_set().unwrap();
        assert_eq!(roster.weights(), vec![(0, 3), (1, 1)]);
        assert_eq!(roster.spec(0).unwrap().quota_bytes, Some(64 << 20));
        assert_eq!(roster.spec(1).unwrap().quota_bytes, None);

        // bad rosters fail at validate, not mid-run
        let zero = GrpoConfig { tenants: 0, ..Default::default() };
        assert!(zero.validate().is_err(), "zero tenants must be rejected");
        let extra = GrpoConfig {
            tenants: 1,
            tenant_weights: vec![1, 2],
            ..Default::default()
        };
        assert!(extra.validate().is_err(), "more weights than tenants must be rejected");
        let zero_w = GrpoConfig {
            tenants: 2,
            tenant_weights: vec![0],
            ..Default::default()
        };
        assert!(zero_w.validate().is_err(), "zero weight must be rejected");
    }
}
