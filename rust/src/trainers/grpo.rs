//! End-to-end GRPO trainer: the actor update state plus the iteration
//! loop that drives every worker over the sample flow.
//!
//! One iteration (paper Fig. 1):
//!   1. admit G prompts × N group copies into the sample flow
//!   2. actor generation state: batched rollout (continuous batcher)
//!   3. actor inference (old log-probs), reference inference, rule reward
//!   4. group advantages (GRPO), assemble update batches, train_step
//!   5. retire finished samples; record metrics + comm accounting

use anyhow::Result;
use std::sync::Arc;

use crate::data::TaskGenerator;
use crate::generation::{GenEngine, SamplingParams};
use crate::metrics::{throughput_tps, StageTimers};
use crate::rewards::group_advantages;
use crate::runtime::{Engine, Policy, Tensor, TrainBatch, TrainStats};
use crate::tokenizer::Tokenizer;
use crate::transfer_dock::{
    DockTopology, FieldKind, NetworkModel, ReplayBuffer, Sample, SampleFlow, Stage,
    TransferDock,
};
use crate::util::rng::Rng;
use crate::workers::{ActorWorker, ReferenceWorker, RewardWorker};

use super::eval::{evaluate, EvalResult};

#[derive(Debug, Clone)]
pub struct GrpoConfig {
    pub iterations: usize,
    /// G: prompts per iteration
    pub prompts_per_iter: usize,
    /// N: responses per prompt (group size)
    pub group_size: usize,
    pub lr: f32,
    pub max_new_tokens: usize,
    pub temperature: f32,
    pub seed: u64,
    /// simulated cluster nodes for dataflow accounting
    pub nodes: usize,
    /// run the centralized replay-buffer baseline instead of the dock
    pub use_replay_buffer: bool,
    /// evaluate every k iterations (0 = only at the end)
    pub eval_every: usize,
    pub eval_size: usize,
    pub log_every: usize,
}

impl Default for GrpoConfig {
    fn default() -> Self {
        Self {
            iterations: 50,
            prompts_per_iter: 16,
            group_size: 4,
            lr: 1e-3,
            max_new_tokens: 8,
            temperature: 1.0,
            seed: 0,
            nodes: 4,
            use_replay_buffer: false,
            eval_every: 0,
            eval_size: 64,
            log_every: 10,
        }
    }
}

#[derive(Debug, Clone, Copy)]
pub struct IterationMetrics {
    pub iter: usize,
    pub reward_mean: f32,
    pub exact_frac: f32,
    pub loss: f32,
    pub kl: f32,
    pub ratio: f32,
    pub gen_secs: f64,
    pub infer_secs: f64,
    pub update_secs: f64,
    pub total_secs: f64,
    /// Eq. (5) throughput on this testbed (1 real device)
    pub tps: f64,
    /// simulated dispatch seconds implied by the iteration's comm bytes
    pub dispatch_secs: f64,
}

#[derive(Debug)]
pub struct TrainReport {
    pub config: GrpoConfig,
    pub iterations: Vec<IterationMetrics>,
    pub evals: Vec<(usize, Vec<EvalResult>)>,
    pub timers: StageTimers,
    pub final_ledger: crate::transfer_dock::CommLedger,
}

impl TrainReport {
    pub fn summary(&self) -> String {
        let last = self.iterations.last();
        let first = self.iterations.first();
        format!(
            "GRPO {} iters: reward {:.3} → {:.3}, exact {:.2} → {:.2}, mean TPS {:.1}, dispatch(sim) {}\n{}",
            self.iterations.len(),
            first.map(|m| m.reward_mean).unwrap_or(0.0),
            last.map(|m| m.reward_mean).unwrap_or(0.0),
            first.map(|m| m.exact_frac).unwrap_or(0.0),
            last.map(|m| m.exact_frac).unwrap_or(0.0),
            self.mean_tps(),
            crate::util::fmt_secs(
                self.iterations.iter().map(|m| m.dispatch_secs).sum::<f64>()
            ),
            self.timers.summary(),
        )
    }

    pub fn mean_tps(&self) -> f64 {
        if self.iterations.is_empty() {
            return 0.0;
        }
        self.iterations.iter().map(|m| m.tps).sum::<f64>() / self.iterations.len() as f64
    }

    /// Reward curve as (iter, reward) pairs (Fig. 8 / Fig. 11 series).
    pub fn reward_curve(&self) -> Vec<(usize, f32)> {
        self.iterations.iter().map(|m| (m.iter, m.reward_mean)).collect()
    }
}

/// Run GRPO end-to-end on the loaded artifacts.
pub fn run_grpo(engine: &Engine, cfg: &GrpoConfig) -> Result<TrainReport> {
    let flow: Arc<dyn SampleFlow> = if cfg.use_replay_buffer {
        Arc::new(ReplayBuffer::new(0))
    } else {
        Arc::new(TransferDock::new(DockTopology::spread(cfg.nodes)))
    };
    run_grpo_on_flow(engine, cfg, flow)
}

/// Run GRPO over a caller-supplied sample flow (used by benches to A/B
/// the dock against the replay buffer with everything else fixed).
pub fn run_grpo_on_flow(
    engine: &Engine,
    cfg: &GrpoConfig,
    flow: Arc<dyn SampleFlow>,
) -> Result<TrainReport> {
    let mut rng = Rng::new(cfg.seed);
    let mut task_gen = TaskGenerator::train(cfg.seed);
    let tokenizer = Tokenizer::from_manifest(&engine.manifest);
    let net = NetworkModel::paper();

    let mut policy = Policy::load_initial(engine, cfg.lr)?;
    let reference = ReferenceWorker::new(engine, 1 % cfg.nodes)?;
    let gen_engine = GenEngine::from_manifest(
        engine,
        SamplingParams { temperature: cfg.temperature, top_k: 0 },
    )?;
    let actor = ActorWorker::new(engine, 0, gen_engine, cfg.max_new_tokens);
    let reward_worker = RewardWorker::new(2 % cfg.nodes);

    let a = engine.manifest.artifact("train_step")?.clone();
    let (b, s) = (a.batch, a.seq);

    let mut timers = StageTimers::default();
    let mut iterations = Vec::with_capacity(cfg.iterations);
    let mut evals = Vec::new();
    let mut dispatch_prev = 0.0f64;

    for iter in 0..cfg.iterations {
        let t_iter = std::time::Instant::now();

        // 1. admit prompts (G × N samples, grouped)
        let tasks = task_gen.batch(cfg.prompts_per_iter);
        let mut samples = Vec::with_capacity(cfg.prompts_per_iter * cfg.group_size);
        for (gi, t) in tasks.iter().enumerate() {
            let group = (iter * cfg.prompts_per_iter + gi) as u64;
            for _ in 0..cfg.group_size {
                samples.push(Sample::new_prompt(u64::MAX, group, t.prompt.clone(), t.answer));
            }
        }
        flow.put_samples(samples)?;

        // 2. generation until drained
        let t0 = std::time::Instant::now();
        loop {
            let out = actor.run_generation(engine, &policy, flow.as_ref(), &mut rng, 64)?;
            if out.sequences == 0 {
                break;
            }
        }
        let gen_secs = t0.elapsed().as_secs_f64();
        timers.add("generation", gen_secs);

        // 3. inference + reward
        let t0 = std::time::Instant::now();
        actor.run_old_logprobs(engine, &policy, flow.as_ref(), b)?;
        reference.run(engine, flow.as_ref(), b)?;
        let reward_out = reward_worker.run(flow.as_ref(), 64)?;
        let infer_secs = t0.elapsed().as_secs_f64();
        timers.add("inference", infer_secs);

        // 4. update: collect ready samples, group advantages, train
        let t0 = std::time::Instant::now();
        let metas = flow.request_ready(Stage::Update, usize::MAX)?;
        let mut ready = flow.fetch(0, &metas)?;
        ready.sort_by_key(|s| (s.group, s.index));

        let mut stats_acc: Vec<TrainStats> = Vec::new();
        // complete groups only (all group members present by construction)
        let rewards: Vec<f32> = ready
            .iter()
            .map(|s| s.get(FieldKind::Reward).unwrap().scalar().unwrap_or(0.0))
            .collect();
        let advs = group_advantages(&rewards, cfg.group_size);

        for (chunk, adv_chunk) in ready.chunks(b).zip(advs.chunks(b)) {
            let batch = assemble_batch(chunk, adv_chunk, b, s, &tokenizer)?;
            let st = policy.train_step(engine, &batch)?;
            stats_acc.push(st);
        }
        for sm in &ready {
            flow.retire(sm.index);
        }
        let update_secs = t0.elapsed().as_secs_f64();
        timers.add("update", update_secs);

        // 5. metrics
        let total_secs = t_iter.elapsed().as_secs_f64();
        let dispatch_total = flow.dispatch_secs(&net);
        let n = ready.len().max(1);
        let loss = stats_acc.iter().map(|s| s.loss).sum::<f32>() / stats_acc.len().max(1) as f32;
        let kl = stats_acc.iter().map(|s| s.kl).sum::<f32>() / stats_acc.len().max(1) as f32;
        let ratio = stats_acc.iter().map(|s| s.ratio).sum::<f32>() / stats_acc.len().max(1) as f32;
        let m = IterationMetrics {
            iter,
            reward_mean: rewards.iter().sum::<f32>() / n as f32,
            exact_frac: reward_out.exact as f32 / reward_out.scored.max(1) as f32,
            loss,
            kl,
            ratio,
            gen_secs,
            infer_secs,
            update_secs,
            total_secs,
            tps: throughput_tps(
                cfg.prompts_per_iter as u64,
                cfg.group_size as u64,
                16,
                cfg.max_new_tokens as u64,
                1,
                total_secs,
            ),
            dispatch_secs: dispatch_total - dispatch_prev,
        };
        dispatch_prev = dispatch_total;
        if cfg.log_every > 0 && iter % cfg.log_every == 0 {
            eprintln!(
                "[grpo] iter {iter:>4} reward={:.3} exact={:.2} loss={:+.4} kl={:.4} gen={} upd={}",
                m.reward_mean,
                m.exact_frac,
                m.loss,
                m.kl,
                crate::util::fmt_secs(gen_secs),
                crate::util::fmt_secs(update_secs)
            );
        }
        iterations.push(m);

        if cfg.eval_every > 0 && (iter + 1) % cfg.eval_every == 0 {
            let ev = evaluate(engine, &policy, cfg.eval_size, cfg.seed, 1)?;
            evals.push((iter + 1, ev));
        }
    }

    Ok(TrainReport {
        config: cfg.clone(),
        iterations,
        evals,
        timers,
        final_ledger: flow.ledger(),
    })
}

/// Assemble one train_step batch from update-ready samples; short chunks
/// are padded with zero-mask rows that contribute nothing to the loss.
fn assemble_batch(
    samples: &[Sample],
    advs: &[f32],
    b: usize,
    s: usize,
    tokenizer: &Tokenizer,
) -> Result<TrainBatch> {
    anyhow::ensure!(!samples.is_empty() && samples.len() <= b);
    let mut tokens = Vec::with_capacity(b * s);
    let mut mask = Vec::with_capacity(b * (s - 1));
    let mut old_lp = Vec::with_capacity(b * (s - 1));
    let mut ref_lp = Vec::with_capacity(b * (s - 1));
    let mut adv = Vec::with_capacity(b);

    for (sample, &a) in samples.iter().zip(advs) {
        let mut row = sample.get(FieldKind::Tokens).unwrap().as_i32()?.to_vec();
        row.resize(s, tokenizer.pad_id);
        tokens.extend(row);
        mask.extend(resize_f32(sample.get(FieldKind::RespMask).unwrap().as_f32()?, s - 1));
        old_lp.extend(resize_f32(sample.get(FieldKind::OldLp).unwrap().as_f32()?, s - 1));
        ref_lp.extend(resize_f32(sample.get(FieldKind::RefLp).unwrap().as_f32()?, s - 1));
        adv.push(a);
    }
    // pad to the artifact batch with inert rows
    for _ in samples.len()..b {
        tokens.extend(std::iter::repeat_n(tokenizer.pad_id, s));
        mask.extend(std::iter::repeat_n(0.0f32, s - 1));
        old_lp.extend(std::iter::repeat_n(0.0f32, s - 1));
        ref_lp.extend(std::iter::repeat_n(0.0f32, s - 1));
        adv.push(0.0);
    }
    Ok(TrainBatch {
        tokens: Tensor::i32(&[b, s], tokens)?,
        resp_mask: Tensor::f32(&[b, s - 1], mask)?,
        old_lp: Tensor::f32(&[b, s - 1], old_lp)?,
        ref_lp: Tensor::f32(&[b, s - 1], ref_lp)?,
        adv: Tensor::f32(&[b], adv)?,
    })
}

fn resize_f32(v: &[f32], n: usize) -> Vec<f32> {
    let mut out = v.to_vec();
    out.resize(n, 0.0);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact_dir;

    #[test]
    fn two_iterations_end_to_end_dock() {
        let engine = Engine::load(artifact_dir("tiny")).expect("make artifacts first");
        let cfg = GrpoConfig {
            iterations: 2,
            prompts_per_iter: 4,
            group_size: 2,
            max_new_tokens: 4,
            log_every: 0,
            ..Default::default()
        };
        let report = run_grpo(&engine, &cfg).unwrap();
        assert_eq!(report.iterations.len(), 2);
        for m in &report.iterations {
            assert!(m.loss.is_finite());
            assert!(m.reward_mean >= 0.0 && m.reward_mean <= 1.0);
            assert!(m.tps > 0.0);
        }
        assert!(report.final_ledger.total_bytes() > 0);
    }

    #[test]
    fn replay_buffer_baseline_matches_math() {
        // same seed → same generation/rewards regardless of dataflow
        let engine = Engine::load(artifact_dir("tiny")).expect("make artifacts first");
        let mk = |rb| GrpoConfig {
            iterations: 1,
            prompts_per_iter: 4,
            group_size: 2,
            max_new_tokens: 4,
            use_replay_buffer: rb,
            log_every: 0,
            ..Default::default()
        };
        let a = run_grpo(&engine, &mk(false)).unwrap();
        let b = run_grpo(&engine, &mk(true)).unwrap();
        assert_eq!(a.iterations[0].reward_mean, b.iterations[0].reward_mean);
        assert!((a.iterations[0].loss - b.iterations[0].loss).abs() < 1e-5);
        // Both dataflows move comparable payload; at this micro scale
        // (8 samples, co-located workers) dispatch seconds are small for
        // both — the paper's point exactly ("an RL system only spends a
        // few seconds on sample flow with low loads", Table 1). The
        // dock-wins-at-scale claim is exercised by the Fig. 9 linearity
        // bench and tests/dataflow_scale.rs with realistic G×N and spread
        // workers.
        let net = NetworkModel::paper();
        let dock_secs = a.final_ledger.dispatch_secs_sharded(&net, 4);
        let rb_secs = b.final_ledger.dispatch_secs(&net);
        assert!(dock_secs < 1.0 && rb_secs < 1.0);
        assert!(a.final_ledger.total_bytes() > 0 && b.final_ledger.total_bytes() > 0);
        // the centralized store is the single hottest store by traffic
        assert!(b.final_ledger.max_store_bytes >= a.final_ledger.max_store_bytes);
    }
}
