//! Algorithm trainers (the top layer of the paper's Fig. 6 architecture).
//!
//! [`grpo`] is fully wired end-to-end over the PJRT runtime, driven by the
//! [`executor`] in either `sync` (barrier-per-stage) or `pipelined`
//! (concurrent stage workers) mode; [`variants`] implements the PPO /
//! DAPO / PF-PPO advantage-and-filtering variants on top of the same
//! sample flow (Table 2 feature rows).

pub mod autoscale;
mod eval;
mod executor;
pub mod faults;
mod grpo;
pub mod tenancy;
mod variants;

pub use autoscale::{AutoscaleConfig, Autoscaler, ReplicaSet, ScaleDecision, StageReplicas};
pub use eval::{evaluate, EvalResult};
pub use executor::{PipelineMode, StagePlacement};
pub use faults::{FaultInjector, FaultKind, FaultPlan, StageExit};
pub use grpo::{run_grpo, run_grpo_on_flow, GrpoConfig, IterationMetrics, TrainReport};
pub use tenancy::{TenantSet, TenantSpec};
pub use variants::{AdvantageKind, filter_groups_dapo, pf_ppo_reweight, ppo_gae_advantages};
