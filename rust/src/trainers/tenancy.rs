//! Multi-tenant job specs: several RL jobs multiplexed over one shared
//! set of stage replica pools.
//!
//! A **tenant** is one logical training job — its own dataset slice, its
//! own reward function, its own staleness window — sharing the
//! generation / logprob / reward replicas with every other tenant instead
//! of carving the cluster into static slices. Two mechanisms keep the
//! sharing honest:
//!
//! * **weighted-fair claims** — every [`crate::transfer_dock::SampleFlow`]
//!   hands out ready samples by deficit-weighted round robin over
//!   backlogged tenants (see `SampleFlow::set_tenant_weights`), so a
//!   tenant's long-run claim share tracks its [`TenantSpec::weight`]
//!   without reserving replicas for idle tenants (an idle tenant's share
//!   is donated, not wasted).
//! * **byte quotas** — KV blocks and bus retention are charged per
//!   tenant against [`TenantSpec::quota_bytes`]; a tenant at its quota is
//!   deferred (admission backpressure) or preempted via the
//!   drain-then-retire + partial-rollout persist path, so its overrun
//!   never evicts a sibling's live state and no decoded tokens are lost.
//!
//! Tenant id 0 is the **default tenant**: a run configured with one
//! tenant takes every bit-identical pre-tenancy code path (placement salt
//! 0, empty dock tenant map, index-order handout).

use anyhow::{ensure, Result};

/// One tenant job's scheduling contract.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TenantSpec {
    /// Stable tenant id (0 = the default tenant).
    pub id: u32,
    /// Relative claim weight (≥ 1): a weight-3 tenant receives 3× the
    /// claims of a weight-1 tenant while both are backlogged.
    pub weight: u32,
    /// Shared-pool byte quota (KV blocks + bus retention). `None` means
    /// uncapped — the single-tenant default.
    pub quota_bytes: Option<u64>,
    /// Per-tenant staleness window override (max iterations in flight);
    /// `None` inherits the run-level window.
    pub max_inflight_iters: Option<usize>,
}

impl TenantSpec {
    /// The default tenant: weight 1, no quota, inherited staleness.
    pub fn default_tenant() -> Self {
        Self { id: 0, weight: 1, quota_bytes: None, max_inflight_iters: None }
    }
}

/// The full tenant roster for a run. Always non-empty; a fresh set holds
/// exactly the default tenant.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TenantSet {
    specs: Vec<TenantSpec>,
}

impl Default for TenantSet {
    fn default() -> Self {
        Self::single()
    }
}

impl TenantSet {
    /// The single-tenant roster (id 0, weight 1, uncapped) — the
    /// configuration every pre-tenancy run is equivalent to.
    pub fn single() -> Self {
        Self { specs: vec![TenantSpec::default_tenant()] }
    }

    /// Build a roster of `n` tenants with ids `0..n`. `weights` and
    /// `quota_mb` are positional per-tenant lists; short lists are padded
    /// with the defaults (weight 1, uncapped) so `--tenant-weight 3`
    /// alone weights tenant 0 and leaves the rest at 1.
    pub fn from_config(n: usize, weights: &[u32], quota_mb: &[u64]) -> Result<Self> {
        ensure!(n >= 1, "a run needs at least one tenant, got {n}");
        ensure!(
            weights.len() <= n,
            "{} tenant weights for {n} tenants",
            weights.len()
        );
        ensure!(
            quota_mb.len() <= n,
            "{} tenant quotas for {n} tenants",
            quota_mb.len()
        );
        for (t, &w) in weights.iter().enumerate() {
            ensure!(w >= 1, "tenant {t} weight must be >= 1, got {w}");
        }
        for (t, &q) in quota_mb.iter().enumerate() {
            ensure!(q >= 1, "tenant {t} quota must be >= 1 MiB, got {q}");
        }
        let specs = (0..n)
            .map(|t| TenantSpec {
                id: t as u32,
                weight: weights.get(t).copied().unwrap_or(1),
                quota_bytes: quota_mb.get(t).map(|&mb| mb * (1 << 20)),
                max_inflight_iters: None,
            })
            .collect();
        Ok(Self { specs })
    }

    pub fn len(&self) -> usize {
        self.specs.len()
    }

    pub fn is_empty(&self) -> bool {
        false // a roster always holds at least the default tenant
    }

    /// More than one tenant shares the pools — the gate for every
    /// tenancy-only code path (DRR handout, quota registry, placement
    /// salt). Single-tenant runs must stay bit-identical to pre-tenancy.
    pub fn is_multi(&self) -> bool {
        self.specs.len() > 1
    }

    pub fn specs(&self) -> &[TenantSpec] {
        &self.specs
    }

    pub fn spec(&self, id: u32) -> Option<&TenantSpec> {
        self.specs.iter().find(|s| s.id == id)
    }

    /// Whether any tenant carries a byte quota — the gate for building
    /// the run's [`crate::memory::TenantQuotas`] registry at all.
    pub fn has_quotas(&self) -> bool {
        self.specs.iter().any(|s| s.quota_bytes.is_some())
    }

    /// `(tenant, weight)` pairs for `SampleFlow::set_tenant_weights`.
    pub fn weights(&self) -> Vec<(u32, u32)> {
        self.specs.iter().map(|s| (s.id, s.weight)).collect()
    }

    /// Sum of the roster's weights (the denominator of expected claim
    /// shares: tenant t's fair share is `weight_t / total_weight`).
    pub fn total_weight(&self) -> u64 {
        self.specs.iter().map(|s| s.weight as u64).sum()
    }

    /// The dataset slice: which tenant owns the sample at global
    /// admission position `pos`. Tenants stripe the deterministic prompt
    /// stream round-robin, so the i-th prompt of tenant t in a shared run
    /// is exactly the i-th prompt tenant t would admit running isolated —
    /// the re-keying the differential oracle relies on.
    pub fn tenant_of_position(&self, pos: u64) -> u32 {
        (pos % self.specs.len() as u64) as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_roster_is_the_default_tenant() {
        let t = TenantSet::single();
        assert_eq!(t.len(), 1);
        assert!(!t.is_multi());
        assert_eq!(t.specs()[0], TenantSpec::default_tenant());
        assert_eq!(t.weights(), vec![(0, 1)]);
        assert_eq!(t.total_weight(), 1);
        for pos in 0..16 {
            assert_eq!(t.tenant_of_position(pos), 0);
        }
    }

    #[test]
    fn from_config_pads_short_lists_with_defaults() {
        let t = TenantSet::from_config(3, &[3], &[64]).unwrap();
        assert_eq!(t.len(), 3);
        assert!(t.is_multi());
        assert_eq!(t.weights(), vec![(0, 3), (1, 1), (2, 1)]);
        assert_eq!(t.total_weight(), 5);
        assert_eq!(t.spec(0).unwrap().quota_bytes, Some(64 << 20));
        assert_eq!(t.spec(1).unwrap().quota_bytes, None);
        assert_eq!(t.spec(3), None);
    }

    #[test]
    fn from_config_rejects_bad_rosters() {
        assert!(TenantSet::from_config(0, &[], &[]).is_err(), "zero tenants");
        assert!(TenantSet::from_config(1, &[1, 1], &[]).is_err(), "more weights than tenants");
        assert!(TenantSet::from_config(1, &[], &[1, 1]).is_err(), "more quotas than tenants");
        assert!(TenantSet::from_config(2, &[0], &[]).is_err(), "zero weight");
        assert!(TenantSet::from_config(2, &[], &[0]).is_err(), "zero quota");
    }

    #[test]
    fn position_striping_is_round_robin() {
        let t = TenantSet::from_config(3, &[], &[]).unwrap();
        let seq: Vec<u32> = (0..9).map(|p| t.tenant_of_position(p)).collect();
        assert_eq!(seq, vec![0, 1, 2, 0, 1, 2, 0, 1, 2]);
    }
}
