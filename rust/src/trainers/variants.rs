//! RL algorithm variants on top of the GRPO substrate (Table 2 rows):
//!
//! * **PPO**: GAE advantages from a value estimate instead of group
//!   normalization (here: reward-to-go with a constant baseline, the
//!   critic-free form used when no value model is trained).
//! * **DAPO**: dynamic-sampling group filter — drop groups whose rewards
//!   are all-equal (zero gradient) and oversample to refill.
//! * **PF-PPO**: policy-filtration reweighting — down-weight groups whose
//!   reward signal is unreliable (low variance ∧ mid reward).

use crate::rewards::group_advantages;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdvantageKind {
    Grpo,
    PpoGae,
    Dapo,
    PfPpo,
}

/// Critic-free PPO-style advantages: reward minus running mean baseline,
/// optionally discounted reward-to-go for multi-step episodes (our
/// episodes are single-step, so this reduces to centered rewards scaled
/// by a fixed std estimate).
pub fn ppo_gae_advantages(rewards: &[f32], baseline: f32, scale: f32) -> Vec<f32> {
    rewards.iter().map(|r| (r - baseline) / scale.max(1e-6)).collect()
}

/// DAPO dynamic sampling: groups where every reward is identical carry no
/// GRPO gradient; return the indices of groups to KEEP.
pub fn filter_groups_dapo(rewards: &[f32], group_size: usize) -> Vec<usize> {
    assert!(group_size > 0 && rewards.len() % group_size == 0);
    rewards
        .chunks(group_size)
        .enumerate()
        .filter(|(_, g)| {
            let first = g[0];
            g.iter().any(|&r| (r - first).abs() > 1e-6)
        })
        .map(|(i, _)| i)
        .collect()
}

/// PF-PPO filtration: weight per group in [0, 1]; groups with confident
/// signal (high variance or extreme mean) keep weight 1, ambiguous
/// mid-reward low-variance groups are down-weighted.
pub fn pf_ppo_reweight(rewards: &[f32], group_size: usize) -> Vec<f32> {
    assert!(group_size > 0 && rewards.len() % group_size == 0);
    rewards
        .chunks(group_size)
        .map(|g| {
            let mean = g.iter().sum::<f32>() / g.len() as f32;
            let var = g.iter().map(|r| (r - mean) * (r - mean)).sum::<f32>() / g.len() as f32;
            if var > 0.01 {
                1.0
            } else {
                // all-same groups: keep confident extremes, drop ambiguity
                let extremity = (mean - 0.5).abs() * 2.0;
                extremity.clamp(0.0, 1.0)
            }
        })
        .collect()
}

/// Apply an advantage variant to group-major rewards.
pub fn advantages(kind: AdvantageKind, rewards: &[f32], group_size: usize) -> Vec<f32> {
    match kind {
        AdvantageKind::Grpo => group_advantages(rewards, group_size),
        AdvantageKind::PpoGae => {
            let mean = rewards.iter().sum::<f32>() / rewards.len().max(1) as f32;
            ppo_gae_advantages(rewards, mean, 0.5)
        }
        AdvantageKind::Dapo => {
            // zero out filtered groups, GRPO-normalize the rest
            let keep = filter_groups_dapo(rewards, group_size);
            let mut adv = group_advantages(rewards, group_size);
            for (gi, chunk) in adv.chunks_mut(group_size).enumerate() {
                if !keep.contains(&gi) {
                    chunk.iter_mut().for_each(|a| *a = 0.0);
                }
            }
            adv
        }
        AdvantageKind::PfPpo => {
            let w = pf_ppo_reweight(rewards, group_size);
            let mut adv = group_advantages(rewards, group_size);
            for (gi, chunk) in adv.chunks_mut(group_size).enumerate() {
                chunk.iter_mut().for_each(|a| *a *= w[gi]);
            }
            adv
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dapo_drops_uniform_groups() {
        // group 0 uniform, group 1 mixed
        let rewards = [0.0, 0.0, 1.0, 0.0];
        let keep = filter_groups_dapo(&rewards, 2);
        assert_eq!(keep, vec![1]);
        let adv = advantages(AdvantageKind::Dapo, &rewards, 2);
        assert_eq!(&adv[..2], &[0.0, 0.0]);
        assert!(adv[2] > 0.0 && adv[3] < 0.0);
    }

    #[test]
    fn pf_ppo_keeps_confident_groups() {
        // uniform-success group: confident, weight 1
        let w = pf_ppo_reweight(&[1.0, 1.0, 0.5, 0.5], 2);
        assert!(w[0] > 0.9);
        // uniform mid-reward group: ambiguous, low weight
        assert!(w[1] < 0.2);
    }

    #[test]
    fn ppo_advantages_centered() {
        let adv = ppo_gae_advantages(&[1.0, 0.0], 0.5, 0.5);
        assert_eq!(adv, vec![1.0, -1.0]);
    }

    #[test]
    fn grpo_variant_delegates() {
        let a = advantages(AdvantageKind::Grpo, &[1.0, 0.0, 0.0, 0.0], 4);
        assert!(a[0] > 0.0);
    }
}
