//! TD controller: per-worker-state metadata tracker.
//!
//! Controllers hold no payloads — only `SampleMeta` records (sample index,
//! warehouse id, presence bitmask). A worker asks *its own* controller for
//! ready samples (a node-local request when the controller is co-located
//! with the worker, which is the paper's point: it removes the cross-node
//! request storm of a central buffer).

use std::collections::{BTreeMap, HashSet};
use std::sync::Mutex;

use super::sample::{FieldKind, Stage};

/// Metadata about one sample, as replicated to every controller.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SampleMeta {
    pub index: u64,
    pub group: u64,
    pub warehouse: usize,
    pub present: u8,
    pub prompt_len: u32,
    pub resp_len: u32,
    /// weight version that generated this sample (0 = not yet stamped);
    /// replicated on every broadcast so stage workers can pin the
    /// behavior policy without fetching the payload
    pub behavior_version: u64,
}

impl SampleMeta {
    /// Nominal wire size of a metadata record: 7 scalars × 4 bytes
    /// (the paper's M∈[3,5] per-sample scalar count plus routing and the
    /// behavior-policy version stamp).
    pub const WIRE_BYTES: u64 = 28;

    fn has(&self, f: FieldKind) -> bool {
        self.present & f.bit() != 0
    }

    /// Is this sample ready to be processed by `stage`?
    pub fn ready_for(&self, stage: Stage) -> bool {
        match stage {
            Stage::Generation => !self.has(FieldKind::Tokens),
            Stage::OldLogprob => self.has(FieldKind::Tokens) && !self.has(FieldKind::OldLp),
            Stage::RefLogprob => self.has(FieldKind::Tokens) && !self.has(FieldKind::RefLp),
            Stage::Reward => self.has(FieldKind::Tokens) && !self.has(FieldKind::Reward),
            Stage::Update => {
                self.has(FieldKind::Tokens)
                    && self.has(FieldKind::OldLp)
                    && self.has(FieldKind::RefLp)
                    && self.has(FieldKind::Reward)
            }
        }
    }
}

/// One controller: the metadata view for a single worker state.
#[derive(Debug)]
pub struct Controller {
    pub stage: Stage,
    /// node the controller lives on (co-located with its worker)
    pub node: usize,
    inner: Mutex<Inner>,
}

#[derive(Debug, Default)]
struct Inner {
    metas: BTreeMap<u64, SampleMeta>,
    /// samples handed out for this stage and not yet re-broadcast
    in_flight: HashSet<u64>,
    /// metadata traffic received (bytes), for Eq. (4) accounting
    meta_bytes: u64,
}

impl Controller {
    pub fn new(stage: Stage, node: usize) -> Self {
        Self { stage, node, inner: Mutex::new(Inner::default()) }
    }

    /// Receive a metadata broadcast from a warehouse.
    ///
    /// The in-flight latch is cleared only when the broadcast shows the
    /// sample is no longer ready for *this* stage (its work completed).
    /// A cross-stage writeback — e.g. the reward landing while an
    /// old-logprob claim is outstanding — leaves the claim latched, so
    /// concurrent stage workers never dispatch the same work twice.
    pub fn on_broadcast(&self, meta: SampleMeta) {
        let mut g = self.inner.lock().unwrap();
        g.meta_bytes += SampleMeta::WIRE_BYTES;
        if meta.ready_for(self.stage) {
            g.metas.insert(meta.index, meta);
        } else {
            g.metas.remove(&meta.index);
            g.in_flight.remove(&meta.index);
        }
    }

    /// Remove a sample entirely (consumed by Update).
    pub fn on_retire(&self, index: u64) {
        let mut g = self.inner.lock().unwrap();
        g.meta_bytes += SampleMeta::WIRE_BYTES;
        g.metas.remove(&index);
        g.in_flight.remove(&index);
    }

    /// Hand out up to `max_n` ready samples (marks them in-flight so the
    /// same work is not dispatched twice).
    pub fn request(&self, max_n: usize) -> Vec<SampleMeta> {
        let mut g = self.inner.lock().unwrap();
        let mut out = Vec::new();
        for (&idx, meta) in g.metas.iter() {
            if out.len() >= max_n {
                break;
            }
            if !g.in_flight.contains(&idx) {
                out.push(*meta);
            }
        }
        for m in &out {
            g.in_flight.insert(m.index);
        }
        out
    }

    /// Put samples back without processing (e.g. partial batch returned).
    pub fn release(&self, indices: &[u64]) {
        let mut g = self.inner.lock().unwrap();
        for i in indices {
            g.in_flight.remove(i);
        }
    }

    pub fn ready_count(&self) -> usize {
        let g = self.inner.lock().unwrap();
        g.metas.len() - g.in_flight.len()
    }

    pub fn meta_bytes(&self) -> u64 {
        self.inner.lock().unwrap().meta_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta(index: u64, present: u8) -> SampleMeta {
        SampleMeta {
            index,
            group: 0,
            warehouse: 0,
            present,
            prompt_len: 5,
            resp_len: 0,
            behavior_version: 0,
        }
    }

    #[test]
    fn readiness_per_stage() {
        let fresh = meta(0, 0);
        assert!(fresh.ready_for(Stage::Generation));
        assert!(!fresh.ready_for(Stage::OldLogprob));
        assert!(!fresh.ready_for(Stage::Update));

        let gen_done = meta(0, FieldKind::Tokens.bit() | FieldKind::RespMask.bit());
        assert!(!gen_done.ready_for(Stage::Generation));
        assert!(gen_done.ready_for(Stage::OldLogprob));
        assert!(gen_done.ready_for(Stage::RefLogprob));
        assert!(gen_done.ready_for(Stage::Reward));
        assert!(!gen_done.ready_for(Stage::Update));

        let all = meta(
            0,
            FieldKind::Tokens.bit()
                | FieldKind::RespMask.bit()
                | FieldKind::OldLp.bit()
                | FieldKind::RefLp.bit()
                | FieldKind::Reward.bit(),
        );
        assert!(all.ready_for(Stage::Update));
    }

    #[test]
    fn request_marks_in_flight() {
        let c = Controller::new(Stage::Generation, 0);
        c.on_broadcast(meta(1, 0));
        c.on_broadcast(meta(2, 0));
        let first = c.request(10);
        assert_eq!(first.len(), 2);
        assert!(c.request(10).is_empty(), "in-flight must not be re-issued");
        c.release(&[1]);
        assert_eq!(c.request(10).len(), 1);
    }

    #[test]
    fn broadcast_updates_readiness() {
        let c = Controller::new(Stage::OldLogprob, 0);
        c.on_broadcast(meta(1, 0)); // not ready: no tokens yet
        assert_eq!(c.ready_count(), 0);
        c.on_broadcast(meta(1, FieldKind::Tokens.bit()));
        assert_eq!(c.ready_count(), 1);
        c.on_broadcast(meta(1, FieldKind::Tokens.bit() | FieldKind::OldLp.bit()));
        assert_eq!(c.ready_count(), 0, "done samples leave the queue");
    }

    #[test]
    fn cross_stage_broadcast_keeps_claim() {
        let c = Controller::new(Stage::OldLogprob, 0);
        c.on_broadcast(meta(1, FieldKind::Tokens.bit()));
        assert_eq!(c.request(10).len(), 1);
        // the reward lands while the old-lp claim is outstanding: the
        // sample is still old-lp-ready, so the claim must hold
        c.on_broadcast(meta(1, FieldKind::Tokens.bit() | FieldKind::Reward.bit()));
        assert!(c.request(10).is_empty(), "cross-stage writeback re-dispatched a claim");
        // the stage's own writeback completes and clears the claim
        c.on_broadcast(meta(
            1,
            FieldKind::Tokens.bit() | FieldKind::Reward.bit() | FieldKind::OldLp.bit(),
        ));
        assert_eq!(c.ready_count(), 0);
    }

    #[test]
    fn meta_traffic_counted() {
        let c = Controller::new(Stage::Reward, 0);
        c.on_broadcast(meta(1, 0));
        c.on_retire(1);
        assert_eq!(c.meta_bytes(), 2 * SampleMeta::WIRE_BYTES);
    }
}
