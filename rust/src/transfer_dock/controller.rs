//! TD controller: per-worker-state metadata tracker.
//!
//! Controllers hold no payloads — only `SampleMeta` records (sample index,
//! warehouse id, presence bitmask). A worker asks *its own* controller for
//! ready samples (a node-local request when the controller is co-located
//! with the worker, which is the paper's point: it removes the cross-node
//! request storm of a central buffer).
//!
//! Dispatch is **lease-based** (see [`super::lease`]): a handout latches
//! the sample against double dispatch only for as long as the claiming
//! worker shows liveness. Writebacks renew the lease, completion clears
//! it, and expiry returns the sample to the ready pool with a bumped
//! attempt counter so a died/stalled worker can never strand work.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use super::lease::{LeaseClock, LeaseTable, DEFAULT_LEASE_TICKS};
use super::sample::{FieldKind, Stage};
use crate::metrics::FlowRecovery;

/// Metadata about one sample, as replicated to every controller.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SampleMeta {
    pub index: u64,
    pub group: u64,
    /// tenant job id (0 = the default single-tenant job), replicated so
    /// claim handouts can deficit-share across tenant jobs without
    /// fetching payloads
    pub tenant: u32,
    pub warehouse: usize,
    pub present: u8,
    pub prompt_len: u32,
    pub resp_len: u32,
    /// weight version that generated this sample (0 = not yet stamped);
    /// replicated on every broadcast so stage workers can pin the
    /// behavior policy without fetching the payload
    pub behavior_version: u64,
}

impl SampleMeta {
    /// Nominal wire size of a metadata record: 8 scalars × 4 bytes
    /// (the paper's M∈[3,5] per-sample scalar count plus routing, the
    /// behavior-policy version stamp, and the tenant id).
    pub const WIRE_BYTES: u64 = 32;

    fn has(&self, f: FieldKind) -> bool {
        self.present & f.bit() != 0
    }

    /// Is this sample ready to be processed by `stage`?
    pub fn ready_for(&self, stage: Stage) -> bool {
        match stage {
            Stage::Generation => !self.has(FieldKind::Tokens),
            Stage::OldLogprob => self.has(FieldKind::Tokens) && !self.has(FieldKind::OldLp),
            Stage::RefLogprob => self.has(FieldKind::Tokens) && !self.has(FieldKind::RefLp),
            Stage::Reward => self.has(FieldKind::Tokens) && !self.has(FieldKind::Reward),
            Stage::Update => {
                self.has(FieldKind::Tokens)
                    && self.has(FieldKind::OldLp)
                    && self.has(FieldKind::RefLp)
                    && self.has(FieldKind::Reward)
            }
        }
    }
}

/// One controller: the metadata view for a single worker state.
#[derive(Debug)]
pub struct Controller {
    pub stage: Stage,
    /// node the controller lives on (co-located with its worker)
    pub node: usize,
    /// flow-wide logical clock the claim leases are measured against
    clock: Arc<LeaseClock>,
    /// lease duration granted to this stage's claims, in clock ticks
    lease_ticks: u64,
    /// concurrent replica workers pulling this stage (fair-share claim
    /// batching divides handouts by this; 0/1 = no cap, the pre-elastic
    /// behavior)
    pullers: AtomicUsize,
    inner: Mutex<Inner>,
}

#[derive(Debug, Default)]
struct Inner {
    metas: BTreeMap<u64, SampleMeta>,
    /// samples handed out for this stage, with lease + attempt tracking
    leases: LeaseTable,
    /// metadata traffic received (bytes), for Eq. (4) accounting
    meta_bytes: u64,
    /// configured per-tenant scheduling weights (empty = every tenant at
    /// weight 1, the single-tenant degenerate case)
    tenant_weights: BTreeMap<u32, u32>,
    /// samples handed out per tenant since the weights were set — the
    /// deficit state of the weighted round robin
    tenant_served: BTreeMap<u32, u64>,
}

impl Controller {
    /// Standalone controller with its own clock (unit tests; a clock
    /// nobody ticks reproduces the pre-lease latch semantics exactly).
    pub fn new(stage: Stage, node: usize) -> Self {
        Self::with_lease(stage, node, Arc::new(LeaseClock::default()), DEFAULT_LEASE_TICKS)
    }

    /// Controller sharing the owning flow's lease clock.
    pub fn with_lease(
        stage: Stage,
        node: usize,
        clock: Arc<LeaseClock>,
        lease_ticks: u64,
    ) -> Self {
        Self {
            stage,
            node,
            clock,
            lease_ticks,
            pullers: AtomicUsize::new(1),
            inner: Mutex::new(Inner::default()),
        }
    }

    /// Register how many replica workers concurrently pull this stage.
    pub fn set_pullers(&self, n: usize) {
        self.pullers.store(n.max(1), Ordering::Relaxed);
    }

    /// Register per-tenant scheduling weights for deficit-weighted claim
    /// handouts. Resets the round robin's deficit state — weights are a
    /// job-level reconfiguration, not a per-claim knob. Tenants absent
    /// from the list (and every tenant when the list is empty) run at
    /// weight 1, the single-tenant degenerate case.
    pub fn set_tenant_weights(&self, weights: &[(u32, u32)]) {
        let mut g = self.inner.lock().unwrap();
        g.tenant_weights = weights.iter().map(|&(t, w)| (t, w.max(1))).collect();
        g.tenant_served.clear();
    }

    /// Samples handed out per tenant since the weights were last set —
    /// the claim-share evidence behind `TenantReport` and the fairness
    /// gates.
    pub fn tenant_served(&self) -> Vec<(u32, u64)> {
        let g = self.inner.lock().unwrap();
        g.tenant_served.iter().map(|(&t, &n)| (t, n)).collect()
    }

    /// Receive a metadata broadcast from a warehouse.
    ///
    /// The claim lease is cleared only when the broadcast shows the
    /// sample is no longer ready for *this* stage (its work completed).
    /// A cross-stage writeback — e.g. the reward landing while an
    /// old-logprob claim is outstanding — leaves the claim latched but
    /// **renews its lease**: writeback traffic for the sample is evidence
    /// the flow is alive, so concurrent stage workers never dispatch the
    /// same work twice while progress is being made.
    pub fn on_broadcast(&self, meta: SampleMeta) {
        let mut g = self.inner.lock().unwrap();
        g.meta_bytes += SampleMeta::WIRE_BYTES;
        if meta.ready_for(self.stage) {
            g.metas.insert(meta.index, meta);
            if g.leases.is_claimed(meta.index) {
                g.leases.renew(meta.index, self.clock.now(), self.lease_ticks);
            }
        } else {
            g.metas.remove(&meta.index);
            g.leases.complete(meta.index);
        }
    }

    /// Remove a sample entirely (consumed by Update).
    pub fn on_retire(&self, index: u64) {
        let mut g = self.inner.lock().unwrap();
        g.meta_bytes += SampleMeta::WIRE_BYTES;
        g.metas.remove(&index);
        g.leases.forget(index);
    }

    /// Hand out up to `max_n` ready samples under fresh leases (live
    /// leases are not re-issued, so the same work is never dispatched
    /// twice while the claimant is live).
    ///
    /// With `P > 1` registered pullers the handout is additionally
    /// capped at `⌈available / P⌉` (never below 1): N replicas racing
    /// `wait_ready` each take a fair share of the ready queue instead
    /// of the first one draining it into a single oversized batch and
    /// starving its peers.
    ///
    /// With more than one tenant backlogged, the picks inside the cap
    /// are **deficit-weighted round robin** across tenants: each pick
    /// goes to the backlogged tenant with the smallest served/weight
    /// ratio, so the long-run claim share tracks the configured weights
    /// without ever reserving slots for an idle tenant (a zero-backlog
    /// tenant is simply absent, donating its share). With one tenant
    /// this degenerates to the historical index-order handout exactly.
    pub fn request(&self, max_n: usize) -> Vec<SampleMeta> {
        let now = self.clock.now();
        let pullers = self.pullers.load(Ordering::Relaxed).max(1);
        let mut g = self.inner.lock().unwrap();
        let cap = if pullers > 1 {
            let avail = g.metas.len() - g.leases.live();
            max_n.min(avail.div_ceil(pullers).max(1))
        } else {
            max_n
        };
        // bucket the ready pool per tenant, index-ascending within each
        // (the BTreeMap order the pre-tenancy handout used globally)
        let mut queues: BTreeMap<u32, Vec<SampleMeta>> = BTreeMap::new();
        for (&idx, meta) in g.metas.iter() {
            if !g.leases.is_claimed(idx) {
                queues.entry(meta.tenant).or_default().push(*meta);
            }
        }
        let mut out = Vec::new();
        if queues.len() <= 1 {
            if let Some((t, q)) = queues.into_iter().next() {
                out.extend(q.into_iter().take(cap));
                *g.tenant_served.entry(t).or_insert(0) += out.len() as u64;
            }
        } else {
            // integer cross-multiplied ratio compare (no float drift);
            // ties break to the lower tenant id for determinism
            let mut cursors: BTreeMap<u32, usize> = BTreeMap::new();
            while out.len() < cap {
                let mut best: Option<(u32, u64, u64)> = None; // (tenant, served, weight)
                for (&t, q) in queues.iter() {
                    if cursors.get(&t).copied().unwrap_or(0) >= q.len() {
                        continue;
                    }
                    let served = g.tenant_served.get(&t).copied().unwrap_or(0);
                    let weight = g.tenant_weights.get(&t).copied().unwrap_or(1) as u64;
                    let better = match best {
                        None => true,
                        Some((_, bs, bw)) => served * bw < bs * weight,
                    };
                    if better {
                        best = Some((t, served, weight));
                    }
                }
                let Some((t, _, _)) = best else { break };
                let cur = cursors.entry(t).or_insert(0);
                out.push(queues[&t][*cur]);
                *cur += 1;
                *g.tenant_served.entry(t).or_insert(0) += 1;
            }
        }
        for m in &out {
            g.leases.claim(m.index, now, self.lease_ticks);
        }
        out
    }

    /// Put samples back without processing (e.g. partial batch returned).
    pub fn release(&self, indices: &[u64]) {
        let mut g = self.inner.lock().unwrap();
        for i in indices {
            g.leases.release(*i);
        }
    }

    /// Extend the leases of claims the caller still holds.
    pub fn renew(&self, indices: &[u64]) {
        let now = self.clock.now();
        let mut g = self.inner.lock().unwrap();
        for i in indices {
            g.leases.renew(*i, now, self.lease_ticks);
        }
    }

    /// Reclaim claims whose lease expired by `now`; the samples become
    /// requestable again. Returns the reclaimed count.
    pub fn expire(&self, now: u64) -> usize {
        self.inner.lock().unwrap().leases.expire(now).len()
    }

    /// Prior expired dispatches of one sample (0 once it completes).
    pub fn attempt(&self, index: u64) -> u32 {
        self.inner.lock().unwrap().leases.attempt(index)
    }

    pub fn ready_count(&self) -> usize {
        let g = self.inner.lock().unwrap();
        g.metas.len() - g.leases.live()
    }

    pub fn meta_bytes(&self) -> u64 {
        self.inner.lock().unwrap().meta_bytes
    }

    /// Lease accounting for this controller.
    pub fn lease_stats(&self) -> FlowRecovery {
        self.inner.lock().unwrap().leases.stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta(index: u64, present: u8) -> SampleMeta {
        SampleMeta {
            index,
            group: 0,
            tenant: 0,
            warehouse: 0,
            present,
            prompt_len: 5,
            resp_len: 0,
            behavior_version: 0,
        }
    }

    fn tenant_meta(index: u64, tenant: u32) -> SampleMeta {
        SampleMeta { tenant, ..meta(index, 0) }
    }

    #[test]
    fn readiness_per_stage() {
        let fresh = meta(0, 0);
        assert!(fresh.ready_for(Stage::Generation));
        assert!(!fresh.ready_for(Stage::OldLogprob));
        assert!(!fresh.ready_for(Stage::Update));

        let gen_done = meta(0, FieldKind::Tokens.bit() | FieldKind::RespMask.bit());
        assert!(!gen_done.ready_for(Stage::Generation));
        assert!(gen_done.ready_for(Stage::OldLogprob));
        assert!(gen_done.ready_for(Stage::RefLogprob));
        assert!(gen_done.ready_for(Stage::Reward));
        assert!(!gen_done.ready_for(Stage::Update));

        let all = meta(
            0,
            FieldKind::Tokens.bit()
                | FieldKind::RespMask.bit()
                | FieldKind::OldLp.bit()
                | FieldKind::RefLp.bit()
                | FieldKind::Reward.bit(),
        );
        assert!(all.ready_for(Stage::Update));
    }

    #[test]
    fn request_marks_in_flight() {
        let c = Controller::new(Stage::Generation, 0);
        c.on_broadcast(meta(1, 0));
        c.on_broadcast(meta(2, 0));
        let first = c.request(10);
        assert_eq!(first.len(), 2);
        assert!(c.request(10).is_empty(), "in-flight must not be re-issued");
        c.release(&[1]);
        assert_eq!(c.request(10).len(), 1);
    }

    #[test]
    fn broadcast_updates_readiness() {
        let c = Controller::new(Stage::OldLogprob, 0);
        c.on_broadcast(meta(1, 0)); // not ready: no tokens yet
        assert_eq!(c.ready_count(), 0);
        c.on_broadcast(meta(1, FieldKind::Tokens.bit()));
        assert_eq!(c.ready_count(), 1);
        c.on_broadcast(meta(1, FieldKind::Tokens.bit() | FieldKind::OldLp.bit()));
        assert_eq!(c.ready_count(), 0, "done samples leave the queue");
    }

    #[test]
    fn cross_stage_broadcast_keeps_claim() {
        let c = Controller::new(Stage::OldLogprob, 0);
        c.on_broadcast(meta(1, FieldKind::Tokens.bit()));
        assert_eq!(c.request(10).len(), 1);
        // the reward lands while the old-lp claim is outstanding: the
        // sample is still old-lp-ready, so the claim must hold
        c.on_broadcast(meta(1, FieldKind::Tokens.bit() | FieldKind::Reward.bit()));
        assert!(c.request(10).is_empty(), "cross-stage writeback re-dispatched a claim");
        // the stage's own writeback completes and clears the claim
        c.on_broadcast(meta(
            1,
            FieldKind::Tokens.bit() | FieldKind::Reward.bit() | FieldKind::OldLp.bit(),
        ));
        assert_eq!(c.ready_count(), 0);
    }

    #[test]
    fn fair_share_caps_handouts_across_pullers() {
        let c = Controller::new(Stage::Generation, 0);
        for i in 0..8 {
            c.on_broadcast(meta(i, 0));
        }
        c.set_pullers(2);
        // 8 ready over 2 pullers: one greedy request gets ⌈8/2⌉ = 4
        let a = c.request(usize::MAX);
        assert_eq!(a.len(), 4, "fair share must cap a greedy claim");
        // the remaining 4 split again: ⌈4/2⌉ = 2, then 1, then 1
        assert_eq!(c.request(usize::MAX).len(), 2);
        assert_eq!(c.request(usize::MAX).len(), 1);
        assert_eq!(c.request(usize::MAX).len(), 1);
        assert!(c.request(usize::MAX).is_empty(), "everything claimed exactly once");
        // the explicit max_n still binds below the fair cap
        c.release(&a.iter().map(|m| m.index).collect::<Vec<_>>());
        assert_eq!(c.request(1).len(), 1);
        // deregistering pullers restores the greedy handout
        c.set_pullers(1);
        assert_eq!(c.request(usize::MAX).len(), 3);
    }

    #[test]
    fn weighted_round_robin_tracks_configured_weights() {
        let c = Controller::new(Stage::Generation, 0);
        c.set_tenant_weights(&[(0, 3), (1, 1)]);
        for i in 0..24 {
            c.on_broadcast(tenant_meta(i, (i % 2) as u32));
        }
        // 8 picks over tenants at 3:1 → 6 for tenant 0, 2 for tenant 1
        let got = c.request(8);
        let t0 = got.iter().filter(|m| m.tenant == 0).count();
        assert_eq!((t0, got.len() - t0), (6, 2), "3:1 weights must yield a 3:1 split");
        // deficit carries over: the next handout keeps the long-run ratio
        let got = c.request(4);
        let served = c.tenant_served();
        let s0 = served.iter().find(|(t, _)| *t == 0).unwrap().1;
        let s1 = served.iter().find(|(t, _)| *t == 1).unwrap().1;
        assert_eq!(got.len(), 4);
        assert_eq!((s0, s1), (9, 3), "cumulative shares must stay 3:1");
    }

    #[test]
    fn zero_backlog_tenant_donates_its_share() {
        let c = Controller::new(Stage::Generation, 0);
        c.set_tenant_weights(&[(0, 1), (1, 9)]);
        // tenant 1 (weight 9) has no backlog: tenant 0 takes everything
        for i in 0..4 {
            c.on_broadcast(tenant_meta(i, 0));
        }
        assert_eq!(c.request(10).len(), 4, "idle tenant must not stall siblings");
    }

    #[test]
    fn weighted_handout_never_double_dispatches() {
        let c = Controller::new(Stage::Generation, 0);
        c.set_tenant_weights(&[(0, 2), (1, 1), (2, 1)]);
        for i in 0..12 {
            c.on_broadcast(tenant_meta(i, (i % 3) as u32));
        }
        let mut seen = std::collections::HashSet::new();
        loop {
            let got = c.request(3);
            if got.is_empty() {
                break;
            }
            for m in got {
                assert!(seen.insert(m.index), "index {} dispatched twice", m.index);
            }
        }
        assert_eq!(seen.len(), 12, "every sample claimed exactly once");
    }

    #[test]
    fn meta_traffic_counted() {
        let c = Controller::new(Stage::Reward, 0);
        c.on_broadcast(meta(1, 0));
        c.on_retire(1);
        assert_eq!(c.meta_bytes(), 2 * SampleMeta::WIRE_BYTES);
    }

    #[test]
    fn expired_lease_reclaims_and_counts_redispatch() {
        let clock = Arc::new(LeaseClock::default());
        let c = Controller::with_lease(Stage::Generation, 0, Arc::clone(&clock), 2);
        c.on_broadcast(meta(1, 0));
        assert_eq!(c.request(10).len(), 1);
        assert!(c.request(10).is_empty());
        // one tick: lease (2 ticks) still live
        assert_eq!(c.expire(clock.advance()), 0);
        assert!(c.request(10).is_empty(), "live lease must hold through a tick");
        // second tick: lease expires, sample returns to the pool
        assert_eq!(c.expire(clock.advance()), 1);
        assert_eq!(c.attempt(1), 1);
        let again = c.request(10);
        assert_eq!(again.len(), 1, "reclaimed sample must be requestable");
        let s = c.lease_stats();
        assert_eq!(s.reclaimed, 1);
        assert_eq!(s.redispatched, 1);
        assert!(s.consistent());
    }

    #[test]
    fn writeback_renews_outstanding_lease() {
        let clock = Arc::new(LeaseClock::default());
        let c = Controller::with_lease(Stage::OldLogprob, 0, Arc::clone(&clock), 2);
        c.on_broadcast(meta(1, FieldKind::Tokens.bit()));
        assert_eq!(c.request(10).len(), 1);
        clock.advance();
        // a cross-stage writeback (reward) renews the old-lp claim's
        // lease: granted at tick 0 (expiry 2), renewed at tick 1 → 3
        c.on_broadcast(meta(1, FieldKind::Tokens.bit() | FieldKind::Reward.bit()));
        // the original expiry (tick 2) passes without a reclaim ...
        assert_eq!(c.expire(clock.advance()), 0, "renewed lease expired early");
        // ... and the renewed lease expires at tick 3
        assert_eq!(c.expire(clock.advance()), 1);
        assert!(c.lease_stats().leases_renewed >= 1);
    }

    #[test]
    fn completion_clears_attempt_history() {
        let clock = Arc::new(LeaseClock::default());
        let c = Controller::with_lease(Stage::Generation, 0, Arc::clone(&clock), 1);
        c.on_broadcast(meta(1, 0));
        c.request(10);
        c.expire(clock.advance());
        assert_eq!(c.attempt(1), 1);
        c.request(10);
        // generation completes: sample no longer generation-ready
        c.on_broadcast(meta(1, FieldKind::Tokens.bit()));
        assert_eq!(c.attempt(1), 0, "completion must clear the attempt counter");
        assert_eq!(c.ready_count(), 0);
    }
}
