//! The distributed transfer dock proper: S warehouses + C×K controllers.
//!
//! Controllers are **sharded**: each worker state runs K controller
//! shards, and every sample is owned by exactly one shard per stage
//! ([`Placement::shard_of`], a pure function of the sample index). A
//! shard owns its slice of the ready pool, its lease table, its claim
//! latches, its notify channel, and its own `meta_order` broadcast lock —
//! metadata snapshots serialize per shard, never dock-wide. A shard whose
//! ready pool drains steals work from sibling shards; the stolen claim is
//! granted by the *victim* shard's lease table, so expiry / reclaim /
//! redispatch semantics are unchanged by stealing. K = 1 reproduces the
//! pre-sharding dock bit-for-bit.

use anyhow::{anyhow, Result};
use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use super::controller::{Controller, SampleMeta};
use super::lease::{LeaseClock, DEFAULT_LEASE_TICKS};
use super::network::{CommLedger, LinkClass, SharedLedger};
use super::notify::{wait_ready_impl, Notifier};
use super::placement::Placement;
use super::sample::{FieldKind, PartialRollout, Sample, Segment, Stage};
use super::warehouse::{Conservation, StoreOutcome, Warehouse};
use super::SampleFlow;
use crate::metrics::{DockShard, DockShardReport, FlowRecovery};
use crate::runtime::Tensor;

/// Placement of the dock across the cluster: which node hosts each
/// warehouse and each worker-state controller.
#[derive(Debug, Clone)]
pub struct DockTopology {
    /// node id per warehouse (paper: one warehouse per node, S = nodes)
    pub warehouse_nodes: Vec<usize>,
    /// node id per worker state's controller (co-located with its worker);
    /// with K > 1 controller shards, shard k of a stage lives on
    /// `(node + k) % n_nodes`
    pub controller_nodes: BTreeMap<Stage, usize>,
}

impl DockTopology {
    /// One warehouse per node; controllers co-located with their workers,
    /// spread round-robin over nodes.
    pub fn spread(n_nodes: usize) -> Self {
        let warehouse_nodes = (0..n_nodes).collect();
        let controller_nodes = Stage::ALL
            .iter()
            .enumerate()
            .map(|(i, &s)| (s, i % n_nodes))
            .collect();
        Self { warehouse_nodes, controller_nodes }
    }
}

/// The distributed transfer dock (paper Fig. 4), with K controller shards
/// per worker state.
pub struct TransferDock {
    warehouses: Vec<Arc<Warehouse>>,
    /// per worker state: K controller shards; shard k owns the samples
    /// [`Placement::shard_of`] maps to k
    controllers: BTreeMap<Stage, Vec<Controller>>,
    /// the single shared sample → (shard, warehouse) routing policy
    placement: Placement,
    /// steal from siblings once the home shard's ready pool has drained
    /// to at most this depth (0 = steal only when empty)
    steal_threshold: usize,
    ledger: SharedLedger,
    next_index: AtomicU64,
    /// per-shard wakeup channel: a claim waits on its home shard and is
    /// woken by that shard's broadcasts / releases / reclaims (wait_ready)
    notify: Vec<Notifier>,
    /// per-shard broadcast lock, indexed by [`Placement::shard_of`].
    /// Serializes the snapshot→broadcast section so the shard's
    /// controllers always observe presence masks in monotone order.
    /// Without it, two stage threads writing different fields of the same
    /// sample could broadcast their snapshots out of order, and the older
    /// mask would un-ready (or re-ready) the sample at a controller
    /// forever. A snapshot taken under this lock reflects every store
    /// that preceded any earlier-broadcast snapshot, so payload stores
    /// themselves (and all fetches / readiness requests) stay outside the
    /// lock and run concurrently across stage threads — and since a
    /// sample's broadcasts only ever touch its owning shard's
    /// controllers, writebacks to *different* shards never contend.
    meta_order: Vec<Mutex<()>>,
    /// round-robin cursor per stage: spreads pullers' home shards so K
    /// shards serve K claimants in parallel instead of all hammering
    /// shard 0 and stealing the rest
    cursor: BTreeMap<Stage, AtomicUsize>,
    /// per-shard dispatch counters (samples handed out by the shard to a
    /// home claimant / stolen from it by a sibling's claimant)
    shard_claims: Vec<AtomicU64>,
    shard_steals: Vec<AtomicU64>,
    /// flow-wide logical clock the claim leases are measured against;
    /// advanced only via [`SampleFlow::tick_lease_clock`]
    clock: Arc<LeaseClock>,
    /// tenant of each resident *non-default-tenant* sample. Placement is
    /// tenant-aware ([`Placement::shard_of_t`]), but most routing sites
    /// (retire / release / renew / writeback) receive only an index, so
    /// the dock remembers the tenant from admission to retirement.
    /// Default-tenant samples are never inserted — single-tenant runs
    /// keep an empty map and the exact pre-tenancy routing.
    tenant_of: Mutex<HashMap<u64, u32>>,
}

impl TransferDock {
    pub fn new(topology: DockTopology) -> Self {
        Self::with_lease(topology, DEFAULT_LEASE_TICKS)
    }

    /// Build with an explicit claim-lease duration (logical ticks). A
    /// clock nobody ticks never expires anything, so flows driven by the
    /// sync executor behave exactly as before.
    pub fn with_lease(topology: DockTopology, lease_ticks: u64) -> Self {
        Self::with_shards(topology, lease_ticks, 1, 0)
    }

    /// Build with K controller shards per worker state. `steal_threshold`
    /// is the home-shard ready depth at or below which a short claim
    /// steals from siblings. K = 1 is the degenerate single-controller
    /// dock (bit-identical retired sets and stamps to the pre-sharding
    /// dock — the refactor's differential oracle).
    pub fn with_shards(
        topology: DockTopology,
        lease_ticks: u64,
        shards: usize,
        steal_threshold: usize,
    ) -> Self {
        let shards = shards.max(1);
        let clock = Arc::new(LeaseClock::default());
        let warehouses: Vec<Arc<Warehouse>> = topology
            .warehouse_nodes
            .iter()
            .enumerate()
            .map(|(id, &node)| Arc::new(Warehouse::new(id, node)))
            .collect();
        let n_nodes = topology.warehouse_nodes.len().max(1);
        let controllers: BTreeMap<Stage, Vec<Controller>> = topology
            .controller_nodes
            .iter()
            .map(|(&stage, &node)| {
                let cs = (0..shards)
                    .map(|k| {
                        // shard 0 keeps the declared node (K=1 identity);
                        // siblings spread round-robin from it
                        let cnode = if k == 0 { node } else { (node + k) % n_nodes };
                        Controller::with_lease(stage, cnode, Arc::clone(&clock), lease_ticks)
                    })
                    .collect();
                (stage, cs)
            })
            .collect();
        let placement = if shards == 1 {
            Placement::modulo(warehouses.len())
        } else {
            // a shard's home node is its Generation controller's node
            // (the payload producer); the co-located warehouse — when one
            // exists — stores the shard's samples
            let gen_base = topology
                .controller_nodes
                .get(&Stage::Generation)
                .copied()
                .unwrap_or(0);
            let affinity = (0..shards)
                .map(|k| {
                    let home = if k == 0 { gen_base } else { (gen_base + k) % n_nodes };
                    topology.warehouse_nodes.iter().position(|&n| n == home)
                })
                .collect();
            Placement::sharded(warehouses.len(), affinity)
        };
        let cursor = controllers.keys().map(|&s| (s, AtomicUsize::new(0))).collect();
        Self {
            warehouses,
            controllers,
            placement,
            steal_threshold,
            ledger: SharedLedger::default(),
            next_index: AtomicU64::new(0),
            notify: (0..shards).map(|_| Notifier::default()).collect(),
            meta_order: (0..shards).map(|_| Mutex::new(())).collect(),
            cursor,
            shard_claims: (0..shards).map(|_| AtomicU64::new(0)).collect(),
            shard_steals: (0..shards).map(|_| AtomicU64::new(0)).collect(),
            clock,
            tenant_of: Mutex::new(HashMap::new()),
        }
    }

    pub fn n_warehouses(&self) -> usize {
        self.warehouses.len()
    }

    /// Number of worker states (the paper's C), not controller instances.
    pub fn n_controllers(&self) -> usize {
        self.controllers.len()
    }

    /// Controller shards per worker state (K).
    pub fn controller_shards(&self) -> usize {
        self.placement.shards()
    }

    /// The dock's sample → (shard, warehouse) routing policy, exposed so
    /// tests and tools can recompute ownership deterministically.
    pub fn placement(&self) -> &Placement {
        &self.placement
    }

    /// Tenant recorded at admission (0 for default-tenant samples and
    /// for anything already retired — routing retired indices lands on
    /// the tenant-0 policy, which is where pre-tenancy samples lived).
    fn tenant_lookup(&self, index: u64) -> u32 {
        *self.tenant_of.lock().unwrap().get(&index).unwrap_or(&0)
    }

    /// Owning controller shard of an index, tenant-aware.
    fn shard_of_idx(&self, index: u64) -> usize {
        self.placement.shard_of_t(index, self.tenant_lookup(index))
    }

    fn warehouse_for(&self, index: u64) -> &Arc<Warehouse> {
        &self.warehouses[self.placement.warehouse_of_t(index, self.tenant_lookup(index))]
    }

    fn link(&self, a: usize, b: usize) -> LinkClass {
        if a == b {
            LinkClass::Local
        } else {
            LinkClass::InterNode
        }
    }

    /// Broadcast a metadata record from a warehouse to the owning shard's
    /// controller of every worker state (Eq. 4's `(C+1)·M` metadata cost:
    /// C controller copies + the warehouse's own bookkeeping write).
    /// Callers must hold the owning shard's `meta_order` lock.
    fn broadcast(&self, from_node: usize, meta: SampleMeta) {
        let shard = self.placement.shard_of_t(meta.index, meta.tenant);
        self.ledger.record(LinkClass::Local, SampleMeta::WIRE_BYTES); // warehouse bookkeeping
        for cs in self.controllers.values() {
            let c = &cs[shard];
            self.ledger.record(self.link(from_node, c.node), SampleMeta::WIRE_BYTES);
            c.on_broadcast(meta);
        }
    }

    fn meta_of(&self, s: &Sample, warehouse: usize) -> SampleMeta {
        SampleMeta {
            index: s.index,
            group: s.group,
            tenant: s.tenant,
            warehouse,
            present: s.present_mask(),
            prompt_len: s.prompt_len as u32,
            resp_len: s.resp_len as u32,
            behavior_version: s.behavior_version,
        }
    }

    /// Home shard for one claim: round-robin over shards so concurrent
    /// pullers spread instead of all draining shard 0.
    fn home_shard(&self, stage: Stage) -> usize {
        let k = self.placement.shards();
        if k <= 1 {
            return 0;
        }
        self.cursor
            .get(&stage)
            .map(|c| c.fetch_add(1, Ordering::Relaxed) % k)
            .unwrap_or(0)
    }

    /// The claim path: ask the home shard's controller, then — if the
    /// handout came up short and the home pool has drained to the steal
    /// threshold — steal from sibling shards. A stolen claim is granted
    /// by the *victim's* lease table (it owns the sample), so lease
    /// expiry / reclaim / redispatch behave exactly as for a home claim;
    /// the steal itself is a cross-node controller→controller RPC charged
    /// to the ledger as `InterNode` per the `NetworkModel`. `charge_empty`
    /// preserves the per-entry-point accounting convention: a blocking or
    /// streaming poll is free when it returns nothing, a one-shot
    /// `request_ready` always pays its round-trip.
    fn claim_at(
        &self,
        stage: Stage,
        home: usize,
        max_n: usize,
        charge_empty: bool,
    ) -> Result<Vec<SampleMeta>> {
        let cs = self
            .controllers
            .get(&stage)
            .ok_or_else(|| anyhow!("no controller for stage {stage:?}"))?;
        let k = cs.len();
        let mut metas = cs[home].request(max_n);
        if !metas.is_empty() {
            self.shard_claims[home].fetch_add(metas.len() as u64, Ordering::Relaxed);
        }
        if k > 1 && metas.len() < max_n && cs[home].ready_count() <= self.steal_threshold {
            for off in 1..k {
                if metas.len() >= max_n {
                    break;
                }
                let victim = (home + off) % k;
                let got = cs[victim].request(max_n - metas.len());
                if got.is_empty() {
                    continue;
                }
                // exactly one InterNode RPC per cross-shard steal, metas
                // on the wire; the victim's fair-share cap and lease
                // grant applied above in `request`
                self.ledger
                    .record(LinkClass::InterNode, (got.len() as u64 + 1) * SampleMeta::WIRE_BYTES);
                self.ledger.note_requests_on(LinkClass::InterNode, 1);
                self.shard_steals[victim].fetch_add(got.len() as u64, Ordering::Relaxed);
                metas.extend(got);
            }
        }
        if !metas.is_empty() || charge_empty {
            // the worker→home-controller request itself: node-local by
            // construction (controller co-located), metadata-sized
            self.ledger
                .record(LinkClass::Local, (metas.len() as u64 + 1) * SampleMeta::WIRE_BYTES);
            self.ledger.note_requests_on(LinkClass::Local, 1);
        }
        Ok(metas)
    }

    /// Consume a finished sample after the update stage: remove the
    /// payload from its warehouse and retire the metadata at its owning
    /// shard everywhere.
    fn retire_inner(&self, index: u64) -> Option<Sample> {
        let shard = self.shard_of_idx(index);
        let _order = self.meta_order[shard].lock().unwrap();
        let w = self.warehouse_for(index).clone();
        let s = w.remove(index)?;
        for cs in self.controllers.values() {
            let c = &cs[shard];
            self.ledger.record(self.link(w.node, c.node), SampleMeta::WIRE_BYTES);
            c.on_retire(index);
        }
        // the sample is gone from every table: drop its tenant routing
        // entry (late stale writebacks route via tenant 0 and land on
        // the Superseded path regardless of warehouse)
        self.tenant_of.lock().unwrap().remove(&index);
        Some(s)
    }

    /// Total payload bytes resident across warehouses, and the max single
    /// warehouse (balance check).
    pub fn residency(&self) -> (u64, u64) {
        let per: Vec<u64> = self.warehouses.iter().map(|w| w.resident_bytes()).collect();
        (per.iter().sum(), per.iter().copied().max().unwrap_or(0))
    }

    /// Per-warehouse byte-conservation snapshots (admitted / resident /
    /// retired) — the chaos suite's loss detector.
    pub fn conservation(&self) -> Vec<Conservation> {
        self.warehouses.iter().map(|w| w.conservation()).collect()
    }

    /// Stale writebacks dropped across all warehouses.
    pub fn superseded_writebacks(&self) -> u64 {
        self.warehouses.iter().map(|w| w.superseded_writebacks()).sum()
    }

    /// Shard k's controller for `stage` (shard 0 is the only shard of an
    /// unsharded dock).
    pub fn controller(&self, stage: Stage, shard: usize) -> Option<&Controller> {
        self.controllers.get(&stage).and_then(|cs| cs.get(shard))
    }
}

impl SampleFlow for TransferDock {
    /// Batched admission: payloads land in their shards first, then the
    /// metadata is broadcast per owning controller shard — each shard's
    /// slice of the batch under **one** acquisition of *that shard's*
    /// `meta_order`, with that shard's waiters woken **once**. Round
    /// trips: one admission RPC per distinct warehouse touched plus one
    /// metadata RPC per distinct (warehouse, controller) pair — the
    /// batch's metas travel to each controller together, never one RPC
    /// per sample (Eq. 4's per-sample byte volume is still recorded).
    fn put_samples(&self, samples: Vec<Sample>) -> Result<Vec<u64>> {
        let k = self.placement.shards();
        let mut indices = Vec::with_capacity(samples.len());
        let mut by_shard: Vec<Vec<(usize, SampleMeta)>> = vec![Vec::new(); k];
        let mut touched: Vec<usize> = Vec::new();
        let ingest_node = self.warehouses[0].node;
        for mut s in samples {
            let index = self.next_index.fetch_add(1, Ordering::Relaxed);
            s.index = index;
            if s.tenant != 0 {
                self.tenant_of.lock().unwrap().insert(index, s.tenant);
            }
            let w = self.warehouses[self.placement.warehouse_of_t(index, s.tenant)].clone();
            // admission: payload moves from the ingest node (node of
            // warehouse 0, where the data loader runs) to the shard
            self.ledger
                .record(self.link(ingest_node, w.node), s.payload_bytes() as u64);
            by_shard[self.placement.shard_of_t(index, s.tenant)].push((w.id, self.meta_of(&s, w.id)));
            touched.push(w.id);
            w.put(s)?;
            indices.push(index);
        }
        touched.sort_unstable();
        touched.dedup();
        for &wid in &touched {
            let w = &self.warehouses[wid];
            self.ledger.note_requests_on(self.link(ingest_node, w.node), 1);
            self.ledger.note_store_bytes(w.traffic_bytes());
        }
        for (shard, metas) in by_shard.iter().enumerate() {
            if metas.is_empty() {
                continue;
            }
            // one batched metadata RPC per distinct (warehouse,
            // controller) pair feeding this shard
            let mut wids: Vec<usize> = metas.iter().map(|&(wid, _)| wid).collect();
            wids.sort_unstable();
            wids.dedup();
            for &wid in &wids {
                let wnode = self.warehouses[wid].node;
                for cs in self.controllers.values() {
                    self.ledger.note_requests_on(self.link(wnode, cs[shard].node), 1);
                }
            }
            let _order = self.meta_order[shard].lock().unwrap();
            for &(wid, meta) in metas {
                self.broadcast(self.warehouses[wid].node, meta);
            }
            drop(_order);
            self.notify[shard].notify();
        }
        Ok(indices)
    }

    fn wait_ready(
        &self,
        stage: Stage,
        max_n: usize,
        timeout: std::time::Duration,
    ) -> Result<Vec<SampleMeta>> {
        // a blocking worker sits on its home shard's controller and is
        // woken by that shard's (already-accounted) metadata broadcasts —
        // empty re-polls are free, only a successful handout is charged.
        // Charging every wakeup would make dispatch accounting scale with
        // wall-clock time instead of data movement. A sample turning
        // ready on a *sibling* shard doesn't wake this waiter; the steal
        // path picks it up on the next poll (workers loop with bounded
        // timeouts), so cross-shard work costs at most one timeout of
        // latency, never a lost sample.
        let home = self.home_shard(stage);
        wait_ready_impl(&self.notify[home], timeout, || {
            self.claim_at(stage, home, max_n, false)
        })
    }

    fn release(&self, stage: Stage, indices: &[u64]) {
        if let Some(cs) = self.controllers.get(&stage) {
            if cs.len() == 1 {
                cs[0].release(indices);
                self.notify[0].notify();
                return;
            }
            let mut woke = vec![false; cs.len()];
            for &i in indices {
                let shard = self.shard_of_idx(i);
                cs[shard].release(&[i]);
                woke[shard] = true;
            }
            for (shard, w) in woke.into_iter().enumerate() {
                self.notify[shard].notify_if(w);
            }
        }
    }

    fn tick_lease_clock(&self) -> usize {
        let now = self.clock.advance();
        let mut reclaimed = 0;
        let mut woke = vec![false; self.placement.shards()];
        for cs in self.controllers.values() {
            for (shard, c) in cs.iter().enumerate() {
                // reclaim is controller-local bookkeeping (no wire
                // traffic: the metadata never left the shard's table)
                let n = c.expire(now);
                reclaimed += n;
                if n > 0 {
                    woke[shard] = true;
                }
            }
        }
        for (shard, w) in woke.into_iter().enumerate() {
            self.notify[shard].notify_if(w);
        }
        reclaimed
    }

    fn lease_now(&self) -> u64 {
        self.clock.now()
    }

    fn renew(&self, stage: Stage, indices: &[u64]) {
        if let Some(cs) = self.controllers.get(&stage) {
            if cs.len() == 1 {
                cs[0].renew(indices);
                return;
            }
            for &i in indices {
                cs[self.shard_of_idx(i)].renew(&[i]);
            }
        }
    }

    fn lease_stats(&self) -> FlowRecovery {
        let mut out = FlowRecovery::default();
        for cs in self.controllers.values() {
            for c in cs {
                out.merge(&c.lease_stats());
            }
        }
        out.superseded_writebacks = self.superseded_writebacks();
        out
    }

    fn ready_depth(&self, stage: Stage) -> usize {
        self.controllers
            .get(&stage)
            .map(|cs| cs.iter().map(|c| c.ready_count()).sum())
            .unwrap_or(0)
    }

    /// Register pullers **per shard**: n pullers spread round-robin over
    /// the K shards, so each shard's fair-share cap reflects the pullers
    /// whose home it is (a shard with 2 of 8 pullers caps handouts at
    /// ⌈its ready/2⌉, not ⌈its ready/8⌉).
    fn note_pullers(&self, stage: Stage, n: usize) {
        if let Some(cs) = self.controllers.get(&stage) {
            let k = cs.len();
            for (shard, c) in cs.iter().enumerate() {
                c.set_pullers(n / k + usize::from(shard < n % k));
            }
        }
    }

    /// Thread the tenant weights to every controller shard of every
    /// stage: the deficit round robin runs per shard (each shard owns an
    /// independent slice of the ready pool), and the work-stealing path
    /// applies the victim shard's weights — the same authority rule as
    /// leases.
    fn set_tenant_weights(&self, weights: &[(u32, u32)]) {
        for cs in self.controllers.values() {
            for c in cs {
                c.set_tenant_weights(weights);
            }
        }
    }

    /// Claim share per tenant, summed over every stage and shard —
    /// the numerator of the Jain fairness gate.
    fn tenant_claims(&self) -> Vec<(u32, u64)> {
        let mut acc: BTreeMap<u32, u64> = BTreeMap::new();
        for cs in self.controllers.values() {
            for c in cs {
                for (t, n) in c.tenant_served() {
                    *acc.entry(t).or_insert(0) += n;
                }
            }
        }
        acc.into_iter().collect()
    }

    fn request_ready(&self, stage: Stage, max_n: usize) -> Result<Vec<SampleMeta>> {
        let home = self.home_shard(stage);
        self.claim_at(stage, home, max_n, true)
    }

    fn try_claim(&self, stage: Stage, max_n: usize) -> Result<Vec<SampleMeta>> {
        // same charging rule as `wait_ready`: the streaming scheduler
        // polls between decode steps, and an empty poll moves no
        // metadata — only a successful handout is a dispatch event
        let home = self.home_shard(stage);
        self.claim_at(stage, home, max_n, false)
    }

    fn fetch(&self, requester_node: usize, metas: &[SampleMeta]) -> Result<Vec<Sample>> {
        let mut out = Vec::with_capacity(metas.len());
        // one RPC per distinct warehouse touched (batched fetch)
        let mut warehouses: Vec<usize> = metas.iter().map(|m| m.warehouse).collect();
        warehouses.sort_unstable();
        warehouses.dedup();
        for &wid in &warehouses {
            let wnode = self.warehouses[wid].node;
            self.ledger.note_requests_on(self.link(wnode, requester_node), 1);
        }
        for m in metas {
            let w = &self.warehouses[m.warehouse];
            let s = w.fetch(m.index)?;
            self.ledger
                .record(self.link(w.node, requester_node), s.payload_bytes() as u64);
            self.ledger.note_store_bytes(w.traffic_bytes());
            out.push(s);
        }
        Ok(out)
    }

    fn fetch_resident(&self, requester_node: usize, metas: &[SampleMeta]) -> Result<Vec<Sample>> {
        let mut out = Vec::with_capacity(metas.len());
        let mut warehouses: Vec<usize> = metas.iter().map(|m| m.warehouse).collect();
        warehouses.sort_unstable();
        warehouses.dedup();
        for &wid in &warehouses {
            let wnode = self.warehouses[wid].node;
            self.ledger.note_requests_on(self.link(wnode, requester_node), 1);
        }
        for m in metas {
            let w = &self.warehouses[m.warehouse];
            // a missing sample is a stale claim (reclaimed + retired
            // while the requester was stalled), not an error
            let Ok(s) = w.fetch(m.index) else { continue };
            self.ledger
                .record(self.link(w.node, requester_node), s.payload_bytes() as u64);
            self.ledger.note_store_bytes(w.traffic_bytes());
            out.push(s);
        }
        Ok(out)
    }

    fn store_fields(
        &self,
        requester_node: usize,
        index: u64,
        fields: Vec<(FieldKind, Tensor)>,
    ) -> Result<()> {
        self.writeback(requester_node, index, fields, None, Vec::new())
    }

    fn store_generation(
        &self,
        requester_node: usize,
        index: u64,
        fields: Vec<(FieldKind, Tensor)>,
        completion: String,
        resp_len: usize,
        behavior_version: u64,
    ) -> Result<()> {
        let gen = Some((completion, resp_len, behavior_version));
        self.writeback(requester_node, index, fields, gen, Vec::new())
    }

    fn store_generation_with_segments(
        &self,
        requester_node: usize,
        index: u64,
        fields: Vec<(FieldKind, Tensor)>,
        completion: String,
        resp_len: usize,
        behavior_version: u64,
        segments: Vec<Segment>,
    ) -> Result<()> {
        let gen = Some((completion, resp_len, behavior_version));
        self.writeback(requester_node, index, fields, gen, segments)
    }

    /// Persist an interrupted generation's decoded prefix into the
    /// sample's warehouse. No metadata broadcast: the sample's presence
    /// mask is unchanged (it stays generation-ready, claimed or not), so
    /// controllers have nothing to learn — and crucially a partial from a
    /// *dead* worker must not renew that worker's lease and delay the
    /// reclaim that hands the prefix to a live one.
    fn store_partial_generation(
        &self,
        requester_node: usize,
        index: u64,
        partial: PartialRollout,
    ) -> Result<()> {
        let w = self.warehouse_for(index).clone();
        let bytes = partial.payload_bytes() as u64;
        self.ledger.record(self.link(requester_node, w.node), bytes);
        self.ledger.note_requests_on(self.link(requester_node, w.node), 1);
        w.store_partial(index, partial)?;
        self.ledger.note_store_bytes(w.traffic_bytes());
        Ok(())
    }

    fn retire(&self, index: u64) -> Option<Sample> {
        // resolve the shard before retire_inner drops the tenant entry
        let shard = self.shard_of_idx(index);
        let out = self.retire_inner(index);
        self.notify[shard].notify();
        out
    }

    fn ledger(&self) -> CommLedger {
        self.ledger.snapshot()
    }

    fn shards(&self) -> usize {
        self.warehouses.len()
    }

    fn dock_report(&self) -> DockShardReport {
        let k = self.placement.shards();
        let mut per_shard = Vec::with_capacity(k);
        for shard in 0..k {
            let mut reclaimed = 0;
            for cs in self.controllers.values() {
                reclaimed += cs[shard].lease_stats().reclaimed;
            }
            per_shard.push(DockShard {
                claims: self.shard_claims[shard].load(Ordering::Relaxed),
                stolen: self.shard_steals[shard].load(Ordering::Relaxed),
                reclaimed,
            });
        }
        DockShardReport { shards: k, per_shard }
    }

    fn len(&self) -> usize {
        self.warehouses.iter().map(|w| w.len()).sum()
    }
}

impl TransferDock {
    /// The single writeback path for every producing stage: record the
    /// payload movement, merge fields (plus the decoded completion when
    /// the generation state writes), re-broadcast metadata to the owning
    /// shard, wake that shard's waiters.
    fn writeback(
        &self,
        requester_node: usize,
        index: u64,
        fields: Vec<(FieldKind, Tensor)>,
        completion: Option<(String, usize, u64)>,
        segments: Vec<Segment>,
    ) -> Result<()> {
        let w = self.warehouse_for(index).clone();
        let mut bytes: u64 = fields.iter().map(|(_, t)| t.size_bytes() as u64).sum();
        bytes += (segments.len() * Segment::WIRE_BYTES) as u64;
        if let Some((text, ..)) = &completion {
            bytes += text.len() as u64;
        }
        self.ledger.record(self.link(requester_node, w.node), bytes);
        self.ledger.note_requests_on(self.link(requester_node, w.node), 1);
        let outcome = w.store_fields_with_segments(index, fields, completion, segments)?;
        self.ledger.note_store_bytes(w.traffic_bytes());
        if matches!(outcome, StoreOutcome::Superseded) {
            // a stale writeback (late worker after reclaim/retire)
            // changed no state: nothing to broadcast, nobody to wake.
            // Staleness requires a reclaim, and reclaims require ticks —
            // in a never-ticked flow (sync mode, most tests) a dropped
            // writeback is a caller bug, so keep it loud in debug builds.
            debug_assert!(
                self.clock.now() > 0,
                "writeback for sample {index} dropped as superseded, but this \
                 flow's lease clock never ticked (no reclaim can have happened \
                 — wrong index or write-after-retire at the call site?)"
            );
            return Ok(());
        }
        // snapshot + broadcast under the owning shard's meta_order:
        // whichever writeback snapshots later necessarily sees a superset
        // mask, so broadcast order is monotone per sample while payload
        // stores (above) run concurrently across stage threads — and
        // across shards, broadcasts never serialize at all
        let shard = self.shard_of_idx(index);
        let _order = self.meta_order[shard].lock().unwrap();
        let meta = w.fetch_meta_snapshot(index)?;
        self.broadcast(w.node, meta);
        drop(_order);
        self.notify[shard].notify();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dock(nodes: usize) -> TransferDock {
        TransferDock::new(DockTopology::spread(nodes))
    }

    fn sharded(nodes: usize, shards: usize, steal_threshold: usize) -> TransferDock {
        TransferDock::with_shards(DockTopology::spread(nodes), DEFAULT_LEASE_TICKS, shards, steal_threshold)
    }

    fn prompts(n: usize) -> Vec<Sample> {
        (0..n)
            .map(|i| Sample::new_prompt(u64::MAX, i as u64 / 4, format!("{i}+1="), i as i64 + 1))
            .collect()
    }

    #[test]
    fn samples_spread_across_warehouses() {
        let d = dock(4);
        d.put_samples(prompts(16)).unwrap();
        for w in &d.warehouses {
            assert_eq!(w.len(), 4, "round-robin must balance shards");
        }
        let (_total, max) = d.residency();
        assert!(max > 0);
    }

    #[test]
    fn generation_flow_round_trip() {
        let d = dock(2);
        let idx = d.put_samples(prompts(2)).unwrap();
        let metas = d.request_ready(Stage::Generation, 10).unwrap();
        assert_eq!(metas.len(), 2);
        // generation completes for sample 0
        d.store_generation(
            0,
            idx[0],
            vec![(FieldKind::Tokens, Tensor::i32(&[8], vec![1; 8]).unwrap())],
            "42".into(),
            3,
            4,
        )
        .unwrap();
        // now inference stages see exactly one ready sample
        let ready = d.request_ready(Stage::OldLogprob, 10).unwrap();
        assert_eq!(ready.len(), 1);
        assert_eq!(ready[0].index, idx[0]);
        assert_eq!(ready[0].resp_len, 3);
        assert_eq!(ready[0].behavior_version, 4, "metadata must carry the version stamp");
        let fetched = d.fetch(1, &ready).unwrap();
        assert_eq!(fetched[0].completion_text, "42");
        assert_eq!(fetched[0].behavior_version, 4);
    }

    #[test]
    fn update_requires_all_fields() {
        let d = dock(1);
        let idx = d.put_samples(prompts(1)).unwrap()[0];
        d.store_generation(
            0,
            idx,
            vec![(FieldKind::Tokens, Tensor::i32(&[4], vec![1; 4]).unwrap())],
            "2".into(),
            1,
            1,
        )
        .unwrap();
        assert!(d.request_ready(Stage::Update, 1).unwrap().is_empty());
        d.store_fields(0, idx, vec![(FieldKind::OldLp, Tensor::zeros(&[3]))]).unwrap();
        d.store_fields(0, idx, vec![(FieldKind::RefLp, Tensor::zeros(&[3]))]).unwrap();
        d.store_fields(0, idx, vec![(FieldKind::Reward, Tensor::scalar_f32(1.0))])
            .unwrap();
        let ready = d.request_ready(Stage::Update, 1).unwrap();
        assert_eq!(ready.len(), 1);
        let s = d.retire(idx).unwrap();
        assert!(s.ready_for_update());
        assert_eq!(d.len(), 0);
    }

    #[test]
    fn ledger_records_cross_node_payloads() {
        let d = dock(4);
        let idx = d.put_samples(prompts(4)).unwrap();
        let metas = d.request_ready(Stage::Generation, 10).unwrap();
        d.fetch(0, &metas).unwrap();
        let led = d.ledger();
        assert!(led.inter_node_bytes > 0, "shards on other nodes must cost inter-node bytes");
        assert!(led.local_bytes > 0);
        assert!(led.requests > 0);
        drop(idx);
    }

    #[test]
    fn batched_put_ledger_cost_pinned() {
        // one admission batch of 8 samples over 4 warehouses must cost:
        // * payload bytes: Σ payload per sample (link by shard placement)
        // * metadata: per sample, (C+1) broadcast records + 1 warehouse
        //   bookkeeping record — identical to per-sample admission
        // * round-trips: ONE admission RPC per distinct warehouse touched
        //   plus ONE metadata RPC per distinct (warehouse, controller)
        //   pair — the batch's metas reach each controller together,
        //   never one RPC per sample (the batching this pin protects)
        let d = dock(4);
        let batch = prompts(8);
        let payload: u64 = batch.iter().map(|s| s.payload_bytes() as u64).sum();
        let before = d.ledger();
        d.put_samples(batch).unwrap();
        let after = d.ledger();
        let c = d.n_controllers() as u64;
        let meta_bytes = 8 * (c + 1) * SampleMeta::WIRE_BYTES;
        assert_eq!(
            after.total_bytes() - before.total_bytes(),
            payload + meta_bytes,
            "admission bytes must be payload + (C+1) metadata records per sample"
        );
        // 4 warehouses each feed all 5 stage controllers: 20 broadcast
        // RPCs; plus 4 admission RPCs. Controllers sit on nodes
        // [0, 1, 2, 3, 0] (spread(4)), warehouses on [0, 1, 2, 3]: the
        // node-local pairs are w0→{gen, update} and wi→ci for i in 1..3,
        // plus the node-local admission into warehouse 0.
        let trips =
            (after.requests + after.local_requests) - (before.requests + before.local_requests);
        assert_eq!(
            trips,
            4 + 4 * c,
            "one admission RPC per warehouse + one broadcast RPC per (warehouse, controller) pair"
        );
        assert_eq!(after.local_requests - before.local_requests, 6);
        assert_eq!(after.requests - before.requests, 18);
    }

    #[test]
    fn lease_expiry_reclaims_through_the_dock() {
        let d = TransferDock::with_lease(DockTopology::spread(2), 2);
        d.put_samples(prompts(2)).unwrap();
        let claimed = d.request_ready(Stage::Generation, 10).unwrap();
        assert_eq!(claimed.len(), 2);
        assert!(d.request_ready(Stage::Generation, 10).unwrap().is_empty());
        // logical time: nothing expires while the clock stands still
        assert_eq!(d.tick_lease_clock(), 0);
        // second tick hits the 2-tick lease
        assert_eq!(d.tick_lease_clock(), 2);
        let again = d.request_ready(Stage::Generation, 10).unwrap();
        assert_eq!(again.len(), 2, "reclaimed samples must be requestable");
        let s = d.lease_stats();
        assert_eq!(s.reclaimed, 2);
        assert_eq!(s.redispatched, 2);
        assert!(s.consistent());
    }

    #[test]
    fn renew_holds_a_lease_across_ticks() {
        let d = TransferDock::with_lease(DockTopology::spread(1), 2);
        let idx = d.put_samples(prompts(1)).unwrap();
        assert_eq!(d.request_ready(Stage::Generation, 1).unwrap().len(), 1);
        d.tick_lease_clock();
        d.renew(Stage::Generation, &idx);
        // original expiry (tick 2) passes; renewed lease lives to tick 3
        assert_eq!(d.tick_lease_clock(), 0, "renewed lease reclaimed early");
        assert_eq!(d.tick_lease_clock(), 1);
    }

    #[test]
    fn fetch_resident_skips_stale_claims() {
        let d = dock(2);
        let idx = d.put_samples(prompts(2)).unwrap();
        let metas = d.request_ready(Stage::Generation, 10).unwrap();
        // sample 0 is reclaimed+retired elsewhere while this worker held
        // its claim: strict fetch errors, tolerant fetch serves the rest
        d.retire(idx[0]).unwrap();
        assert!(d.fetch(0, &metas).is_err());
        let got = d.fetch_resident(0, &metas).unwrap();
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].index, idx[1]);
    }

    #[test]
    fn conservation_holds_across_lifecycle() {
        let d = dock(2);
        let idx = d.put_samples(prompts(4)).unwrap();
        for &i in &idx {
            d.store_generation(
                0,
                i,
                vec![(FieldKind::Tokens, Tensor::i32(&[4], vec![1; 4]).unwrap())],
                "2".into(),
                1,
                1,
            )
            .unwrap();
        }
        d.retire(idx[0]).unwrap();
        for c in d.conservation() {
            assert!(c.holds(), "{c:?}");
        }
        let (total, _) = d.residency();
        let resident_sum: u64 = d.conservation().iter().map(|c| c.resident_bytes).sum();
        assert_eq!(total, resident_sum);
    }

    #[test]
    fn partial_prefix_survives_reclaim_and_redispatch() {
        let d = TransferDock::with_lease(DockTopology::spread(2), 2);
        let idx = d.put_samples(prompts(1)).unwrap()[0];
        assert_eq!(d.request_ready(Stage::Generation, 1).unwrap().len(), 1);
        // the claiming worker checkpoints its decoded prefix, then dies
        let p = PartialRollout {
            response_ids: vec![4, 5, 6],
            response_logprobs: vec![-0.1; 3],
            segments: vec![Segment { start: 0, len: 3, version: 1 }],
        };
        d.store_partial_generation(0, idx, p.clone()).unwrap();
        // lease expires; the sample is redispatched WITH the prefix
        d.tick_lease_clock();
        assert_eq!(d.tick_lease_clock(), 1);
        let again = d.request_ready(Stage::Generation, 1).unwrap();
        assert_eq!(again.len(), 1);
        let fetched = d.fetch_resident(1, &again).unwrap();
        assert_eq!(fetched[0].partial.as_ref(), Some(&p), "reclaim must hand the prefix back");
        // the resumed worker finishes across the version boundary
        let segs = vec![
            Segment { start: 0, len: 3, version: 1 },
            Segment { start: 3, len: 2, version: 2 },
        ];
        d.store_generation_with_segments(
            1,
            idx,
            vec![(FieldKind::Tokens, Tensor::i32(&[8], vec![1; 8]).unwrap())],
            "done".into(),
            5,
            2,
            segs.clone(),
        )
        .unwrap();
        let ready = d.request_ready(Stage::OldLogprob, 1).unwrap();
        let s = d.fetch(0, &ready).unwrap().remove(0);
        assert!(s.partial.is_none(), "completion clears the persisted prefix");
        assert_eq!(s.segments, segs);
        // a late partial from the dead worker is dropped, counted once
        d.store_partial_generation(0, idx, p).unwrap();
        assert_eq!(d.superseded_writebacks(), 1);
        for c in d.conservation() {
            assert!(c.holds(), "{c:?}");
        }
    }

    #[test]
    fn double_dispatch_prevented() {
        let d = dock(2);
        d.put_samples(prompts(4)).unwrap();
        let a = d.request_ready(Stage::Generation, 2).unwrap();
        let b = d.request_ready(Stage::Generation, 10).unwrap();
        assert_eq!(a.len(), 2);
        assert_eq!(b.len(), 2);
        let ai: Vec<u64> = a.iter().map(|m| m.index).collect();
        assert!(b.iter().all(|m| !ai.contains(&m.index)));
    }

    // ------------------------------------------------- sharded dock

    #[test]
    fn sharded_dock_claims_every_sample_exactly_once() {
        let d = sharded(4, 4, 0);
        let idx = d.put_samples(prompts(32)).unwrap();
        let mut seen = std::collections::HashSet::new();
        loop {
            let metas = d.request_ready(Stage::Generation, 4).unwrap();
            if metas.is_empty() {
                break;
            }
            for m in &metas {
                assert!(seen.insert(m.index), "sample {} dispatched twice across shards", m.index);
            }
        }
        assert_eq!(seen.len(), idx.len(), "every sample claimed exactly once over 4 shards");
        let rep = d.dock_report();
        assert_eq!(rep.shards, 4);
        let handed: u64 = rep.per_shard.iter().map(|s| s.claims + s.stolen).sum();
        assert_eq!(handed as usize, idx.len(), "per-shard counters must cover every handout");
    }

    #[test]
    fn affinity_places_payloads_with_the_owning_shard() {
        let d = sharded(4, 4, 0);
        let idx = d.put_samples(prompts(32)).unwrap();
        let p = d.placement();
        for &i in &idx {
            let expect = p.warehouse_of(i);
            assert!(d.warehouses[expect].fetch(i).is_ok(), "sample {i} missing from its shard warehouse");
        }
        // spread(4) co-locates a warehouse with every shard node, so the
        // modulo fallback must never fire: each sample sits exactly where
        // its owning shard lives
        for &i in &idx {
            assert_eq!(p.warehouse_of(i), (d.placement().shard_of(i) + 0) % 4);
        }
    }

    #[test]
    fn drained_shard_steals_from_siblings_with_one_internode_rpc_each() {
        let d = sharded(4, 2, 0);
        let idx = d.put_samples(prompts(8)).unwrap();
        let p = d.placement().clone();
        let owned: Vec<usize> = (0..2)
            .map(|k| idx.iter().filter(|&&i| p.shard_of(i) == k).count())
            .collect();
        assert!(owned.iter().all(|&n| n > 0), "mix must populate both shards: {owned:?}");
        let before = d.ledger();
        // one greedy claim: the home shard (cursor starts at 0) drains its
        // own pool, then steals the sibling's entire pool
        let metas = d.request_ready(Stage::Generation, usize::MAX).unwrap();
        assert_eq!(metas.len(), 8, "steal must surface the sibling's work");
        let after = d.ledger();
        assert_eq!(
            after.requests - before.requests,
            1,
            "exactly one InterNode RPC per cross-shard steal"
        );
        let rep = d.dock_report();
        assert_eq!(rep.per_shard[0].claims as usize, owned[0]);
        assert_eq!(rep.per_shard[1].stolen as usize, owned[1]);
        // stolen claims are leases in the victim's table: releasing them
        // hands the work back to the owning shard, not the thief
        let stolen: Vec<u64> =
            metas.iter().map(|m| m.index).filter(|&i| p.shard_of(i) == 1).collect();
        d.release(Stage::Generation, &stolen);
        assert_eq!(d.ready_depth(Stage::Generation), stolen.len());
    }

    #[test]
    fn steal_threshold_holds_work_back() {
        // threshold 0 and a bounded claim that leaves the home pool
        // non-empty: the claimant must NOT steal
        let d = sharded(4, 2, 0);
        d.put_samples(prompts(16)).unwrap();
        let before = d.ledger();
        let metas = d.request_ready(Stage::Generation, 1).unwrap();
        assert_eq!(metas.len(), 1);
        let after = d.ledger();
        assert_eq!(after.requests, before.requests, "home pool not drained: no steal RPC");
        let rep = d.dock_report();
        assert_eq!(rep.per_shard.iter().map(|s| s.stolen).sum::<u64>(), 0);
    }

    #[test]
    fn sharded_lease_expiry_reclaims_stolen_claims_at_the_owner() {
        let d = TransferDock::with_shards(DockTopology::spread(4), 2, 2, 0);
        let idx = d.put_samples(prompts(6)).unwrap();
        // claim everything (home + steals), then go silent
        let claimed = d.request_ready(Stage::Generation, usize::MAX).unwrap();
        assert_eq!(claimed.len(), 6);
        assert!(d.request_ready(Stage::Generation, usize::MAX).unwrap().is_empty());
        d.tick_lease_clock();
        assert_eq!(d.tick_lease_clock(), 6, "stolen leases expire in their owners' tables");
        let again = d.request_ready(Stage::Generation, usize::MAX).unwrap();
        assert_eq!(again.len(), 6, "reclaimed samples redispatch across shards");
        let s = d.lease_stats();
        assert_eq!(s.reclaimed, 6);
        assert!(s.consistent(), "{s:?}");
        let rep = d.dock_report();
        assert_eq!(rep.per_shard.iter().map(|s| s.reclaimed).sum::<u64>(), 6);
        drop(idx);
    }
}
