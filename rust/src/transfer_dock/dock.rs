//! The distributed transfer dock proper: S warehouses + C controllers.

use anyhow::{anyhow, Result};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::controller::{Controller, SampleMeta};
use super::lease::{LeaseClock, DEFAULT_LEASE_TICKS};
use super::network::{CommLedger, LinkClass, SharedLedger};
use super::notify::{wait_ready_impl, Notifier};
use super::sample::{FieldKind, PartialRollout, Sample, Segment, Stage};
use super::warehouse::{Conservation, StoreOutcome, Warehouse};
use super::SampleFlow;
use crate::metrics::FlowRecovery;
use crate::runtime::Tensor;

/// Placement of the dock across the cluster: which node hosts each
/// warehouse and each worker-state controller.
#[derive(Debug, Clone)]
pub struct DockTopology {
    /// node id per warehouse (paper: one warehouse per node, S = nodes)
    pub warehouse_nodes: Vec<usize>,
    /// node id per worker state's controller (co-located with its worker)
    pub controller_nodes: BTreeMap<Stage, usize>,
}

impl DockTopology {
    /// One warehouse per node; controllers co-located with their workers,
    /// spread round-robin over nodes.
    pub fn spread(n_nodes: usize) -> Self {
        let warehouse_nodes = (0..n_nodes).collect();
        let controller_nodes = Stage::ALL
            .iter()
            .enumerate()
            .map(|(i, &s)| (s, i % n_nodes))
            .collect();
        Self { warehouse_nodes, controller_nodes }
    }
}

/// The distributed transfer dock (paper Fig. 4).
pub struct TransferDock {
    warehouses: Vec<Arc<Warehouse>>,
    controllers: BTreeMap<Stage, Controller>,
    ledger: SharedLedger,
    next_index: AtomicU64,
    /// wakes blocked stage workers on every state change (wait_ready)
    notify: Notifier,
    /// serializes the snapshot→broadcast section so controllers always
    /// observe presence masks in monotone order. Without it, two stage
    /// threads writing different fields of the same sample could
    /// broadcast their snapshots out of order, and the older mask would
    /// un-ready (or re-ready) the sample at a controller forever. A
    /// snapshot taken under this lock reflects every store that preceded
    /// any earlier-broadcast snapshot, so payload stores themselves (and
    /// all fetches / readiness requests) stay outside the lock and run
    /// concurrently across stage threads.
    meta_order: Mutex<()>,
    /// flow-wide logical clock the claim leases are measured against;
    /// advanced only via [`SampleFlow::tick_lease_clock`]
    clock: Arc<LeaseClock>,
}

impl TransferDock {
    pub fn new(topology: DockTopology) -> Self {
        Self::with_lease(topology, DEFAULT_LEASE_TICKS)
    }

    /// Build with an explicit claim-lease duration (logical ticks). A
    /// clock nobody ticks never expires anything, so flows driven by the
    /// sync executor behave exactly as before.
    pub fn with_lease(topology: DockTopology, lease_ticks: u64) -> Self {
        let clock = Arc::new(LeaseClock::default());
        let warehouses = topology
            .warehouse_nodes
            .iter()
            .enumerate()
            .map(|(id, &node)| Arc::new(Warehouse::new(id, node)))
            .collect();
        let controllers = topology
            .controller_nodes
            .iter()
            .map(|(&stage, &node)| {
                (stage, Controller::with_lease(stage, node, Arc::clone(&clock), lease_ticks))
            })
            .collect();
        Self {
            warehouses,
            controllers,
            ledger: SharedLedger::default(),
            next_index: AtomicU64::new(0),
            notify: Notifier::default(),
            meta_order: Mutex::new(()),
            clock,
        }
    }

    pub fn n_warehouses(&self) -> usize {
        self.warehouses.len()
    }

    pub fn n_controllers(&self) -> usize {
        self.controllers.len()
    }

    fn warehouse_for(&self, index: u64) -> &Arc<Warehouse> {
        &self.warehouses[(index % self.warehouses.len() as u64) as usize]
    }

    fn link(&self, a: usize, b: usize) -> LinkClass {
        if a == b {
            LinkClass::Local
        } else {
            LinkClass::InterNode
        }
    }

    /// Broadcast a metadata record from a warehouse to every controller
    /// (Eq. 4's `(C+1)·M` metadata cost: C controller copies + the
    /// warehouse's own bookkeeping write).
    fn broadcast(&self, from_node: usize, meta: SampleMeta) {
        self.ledger.record(LinkClass::Local, SampleMeta::WIRE_BYTES); // warehouse bookkeeping
        for c in self.controllers.values() {
            self.ledger.record(self.link(from_node, c.node), SampleMeta::WIRE_BYTES);
            c.on_broadcast(meta);
        }
    }

    fn meta_of(&self, s: &Sample, warehouse: usize) -> SampleMeta {
        SampleMeta {
            index: s.index,
            group: s.group,
            warehouse,
            present: s.present_mask(),
            prompt_len: s.prompt_len as u32,
            resp_len: s.resp_len as u32,
            behavior_version: s.behavior_version,
        }
    }

    /// Consume a finished sample after the update stage: remove the
    /// payload from its warehouse and retire the metadata everywhere.
    fn retire_inner(&self, index: u64) -> Option<Sample> {
        let _order = self.meta_order.lock().unwrap();
        let w = self.warehouse_for(index).clone();
        let s = w.remove(index)?;
        for c in self.controllers.values() {
            self.ledger.record(self.link(w.node, c.node), SampleMeta::WIRE_BYTES);
            c.on_retire(index);
        }
        Some(s)
    }

    /// Total payload bytes resident across warehouses, and the max single
    /// warehouse (balance check).
    pub fn residency(&self) -> (u64, u64) {
        let per: Vec<u64> = self.warehouses.iter().map(|w| w.resident_bytes()).collect();
        (per.iter().sum(), per.iter().copied().max().unwrap_or(0))
    }

    /// Per-warehouse byte-conservation snapshots (admitted / resident /
    /// retired) — the chaos suite's loss detector.
    pub fn conservation(&self) -> Vec<Conservation> {
        self.warehouses.iter().map(|w| w.conservation()).collect()
    }

    /// Stale writebacks dropped across all warehouses.
    pub fn superseded_writebacks(&self) -> u64 {
        self.warehouses.iter().map(|w| w.superseded_writebacks()).sum()
    }

    pub fn controller(&self, stage: Stage) -> Option<&Controller> {
        self.controllers.get(&stage)
    }
}

impl SampleFlow for TransferDock {
    /// Batched admission: payloads land in their shards first, then the
    /// metadata for the whole batch is broadcast under **one**
    /// `meta_order` acquisition and waiters are woken **once** — an
    /// admission RPC per distinct warehouse touched, not per sample (the
    /// same batching `fetch` already does).
    fn put_samples(&self, samples: Vec<Sample>) -> Result<Vec<u64>> {
        let mut indices = Vec::with_capacity(samples.len());
        let mut metas: Vec<(usize, SampleMeta)> = Vec::with_capacity(samples.len());
        let mut touched: Vec<usize> = Vec::new();
        let ingest_node = self.warehouses[0].node;
        for mut s in samples {
            let index = self.next_index.fetch_add(1, Ordering::Relaxed);
            s.index = index;
            let w = self.warehouse_for(index).clone();
            // admission: payload moves from the ingest node (node of
            // warehouse 0, where the data loader runs) to the shard
            self.ledger
                .record(self.link(ingest_node, w.node), s.payload_bytes() as u64);
            metas.push((w.node, self.meta_of(&s, w.id)));
            touched.push(w.id);
            w.put(s)?;
            indices.push(index);
        }
        touched.sort_unstable();
        touched.dedup();
        for &wid in &touched {
            let w = &self.warehouses[wid];
            self.ledger.note_requests_on(self.link(ingest_node, w.node), 1);
            self.ledger.note_store_bytes(w.traffic_bytes());
        }
        let _order = self.meta_order.lock().unwrap();
        for (wnode, meta) in metas {
            self.broadcast(wnode, meta);
        }
        drop(_order);
        self.notify.notify();
        Ok(indices)
    }

    fn wait_ready(
        &self,
        stage: Stage,
        max_n: usize,
        timeout: std::time::Duration,
    ) -> Result<Vec<SampleMeta>> {
        // a blocking worker sits on its co-located controller and is woken
        // by the (already-accounted) metadata broadcasts — empty re-polls
        // are free, only a successful handout is charged. Charging every
        // wakeup would make dispatch accounting scale with wall-clock
        // time instead of data movement.
        wait_ready_impl(&self.notify, timeout, || {
            let c = self
                .controllers
                .get(&stage)
                .ok_or_else(|| anyhow!("no controller for stage {stage:?}"))?;
            let metas = c.request(max_n);
            if !metas.is_empty() {
                self.ledger.record(
                    LinkClass::Local,
                    (metas.len() as u64 + 1) * SampleMeta::WIRE_BYTES,
                );
                self.ledger.note_requests_on(LinkClass::Local, 1);
            }
            Ok(metas)
        })
    }

    fn release(&self, stage: Stage, indices: &[u64]) {
        if let Some(c) = self.controllers.get(&stage) {
            c.release(indices);
            self.notify.notify();
        }
    }

    fn tick_lease_clock(&self) -> usize {
        let now = self.clock.advance();
        let mut reclaimed = 0;
        for c in self.controllers.values() {
            // reclaim is controller-local bookkeeping (no wire traffic:
            // the metadata never left the controller's table)
            reclaimed += c.expire(now);
        }
        self.notify.notify_if(reclaimed > 0);
        reclaimed
    }

    fn lease_now(&self) -> u64 {
        self.clock.now()
    }

    fn renew(&self, stage: Stage, indices: &[u64]) {
        if let Some(c) = self.controllers.get(&stage) {
            c.renew(indices);
        }
    }

    fn lease_stats(&self) -> FlowRecovery {
        let mut out = FlowRecovery::default();
        for c in self.controllers.values() {
            out.merge(&c.lease_stats());
        }
        out.superseded_writebacks = self.superseded_writebacks();
        out
    }

    fn ready_depth(&self, stage: Stage) -> usize {
        self.controllers.get(&stage).map(|c| c.ready_count()).unwrap_or(0)
    }

    fn note_pullers(&self, stage: Stage, n: usize) {
        if let Some(c) = self.controllers.get(&stage) {
            c.set_pullers(n);
        }
    }

    fn request_ready(&self, stage: Stage, max_n: usize) -> Result<Vec<SampleMeta>> {
        let c = self
            .controllers
            .get(&stage)
            .ok_or_else(|| anyhow!("no controller for stage {stage:?}"))?;
        let metas = c.request(max_n);
        // the request itself is worker→controller, node-local by
        // construction (controller co-located), metadata-sized
        self.ledger
            .record(LinkClass::Local, (metas.len() as u64 + 1) * SampleMeta::WIRE_BYTES);
        self.ledger.note_requests_on(LinkClass::Local, 1);
        Ok(metas)
    }

    fn try_claim(&self, stage: Stage, max_n: usize) -> Result<Vec<SampleMeta>> {
        let c = self
            .controllers
            .get(&stage)
            .ok_or_else(|| anyhow!("no controller for stage {stage:?}"))?;
        let metas = c.request(max_n);
        // same charging rule as `wait_ready`: the streaming scheduler
        // polls between decode steps, and an empty poll moves no
        // metadata — only a successful handout is a dispatch event
        if !metas.is_empty() {
            self.ledger
                .record(LinkClass::Local, (metas.len() as u64 + 1) * SampleMeta::WIRE_BYTES);
            self.ledger.note_requests_on(LinkClass::Local, 1);
        }
        Ok(metas)
    }

    fn fetch(&self, requester_node: usize, metas: &[SampleMeta]) -> Result<Vec<Sample>> {
        let mut out = Vec::with_capacity(metas.len());
        // one RPC per distinct warehouse touched (batched fetch)
        let mut warehouses: Vec<usize> = metas.iter().map(|m| m.warehouse).collect();
        warehouses.sort_unstable();
        warehouses.dedup();
        for &wid in &warehouses {
            let wnode = self.warehouses[wid].node;
            self.ledger.note_requests_on(self.link(wnode, requester_node), 1);
        }
        for m in metas {
            let w = &self.warehouses[m.warehouse];
            let s = w.fetch(m.index)?;
            self.ledger
                .record(self.link(w.node, requester_node), s.payload_bytes() as u64);
            self.ledger.note_store_bytes(w.traffic_bytes());
            out.push(s);
        }
        Ok(out)
    }

    fn fetch_resident(&self, requester_node: usize, metas: &[SampleMeta]) -> Result<Vec<Sample>> {
        let mut out = Vec::with_capacity(metas.len());
        let mut warehouses: Vec<usize> = metas.iter().map(|m| m.warehouse).collect();
        warehouses.sort_unstable();
        warehouses.dedup();
        for &wid in &warehouses {
            let wnode = self.warehouses[wid].node;
            self.ledger.note_requests_on(self.link(wnode, requester_node), 1);
        }
        for m in metas {
            let w = &self.warehouses[m.warehouse];
            // a missing sample is a stale claim (reclaimed + retired
            // while the requester was stalled), not an error
            let Ok(s) = w.fetch(m.index) else { continue };
            self.ledger
                .record(self.link(w.node, requester_node), s.payload_bytes() as u64);
            self.ledger.note_store_bytes(w.traffic_bytes());
            out.push(s);
        }
        Ok(out)
    }

    fn store_fields(
        &self,
        requester_node: usize,
        index: u64,
        fields: Vec<(FieldKind, Tensor)>,
    ) -> Result<()> {
        self.writeback(requester_node, index, fields, None, Vec::new())
    }

    fn store_generation(
        &self,
        requester_node: usize,
        index: u64,
        fields: Vec<(FieldKind, Tensor)>,
        completion: String,
        resp_len: usize,
        behavior_version: u64,
    ) -> Result<()> {
        let gen = Some((completion, resp_len, behavior_version));
        self.writeback(requester_node, index, fields, gen, Vec::new())
    }

    fn store_generation_with_segments(
        &self,
        requester_node: usize,
        index: u64,
        fields: Vec<(FieldKind, Tensor)>,
        completion: String,
        resp_len: usize,
        behavior_version: u64,
        segments: Vec<Segment>,
    ) -> Result<()> {
        let gen = Some((completion, resp_len, behavior_version));
        self.writeback(requester_node, index, fields, gen, segments)
    }

    /// Persist an interrupted generation's decoded prefix into the
    /// sample's warehouse. No metadata broadcast: the sample's presence
    /// mask is unchanged (it stays generation-ready, claimed or not), so
    /// controllers have nothing to learn — and crucially a partial from a
    /// *dead* worker must not renew that worker's lease and delay the
    /// reclaim that hands the prefix to a live one.
    fn store_partial_generation(
        &self,
        requester_node: usize,
        index: u64,
        partial: PartialRollout,
    ) -> Result<()> {
        let w = self.warehouse_for(index).clone();
        let bytes = partial.payload_bytes() as u64;
        self.ledger.record(self.link(requester_node, w.node), bytes);
        self.ledger.note_requests_on(self.link(requester_node, w.node), 1);
        w.store_partial(index, partial)?;
        self.ledger.note_store_bytes(w.traffic_bytes());
        Ok(())
    }

    fn retire(&self, index: u64) -> Option<Sample> {
        let out = self.retire_inner(index);
        self.notify.notify();
        out
    }

    fn ledger(&self) -> CommLedger {
        self.ledger.snapshot()
    }

    fn shards(&self) -> usize {
        self.warehouses.len()
    }

    fn len(&self) -> usize {
        self.warehouses.iter().map(|w| w.len()).sum()
    }
}

impl TransferDock {
    /// The single writeback path for every producing stage: record the
    /// payload movement, merge fields (plus the decoded completion when
    /// the generation state writes), re-broadcast metadata, wake waiters.
    fn writeback(
        &self,
        requester_node: usize,
        index: u64,
        fields: Vec<(FieldKind, Tensor)>,
        completion: Option<(String, usize, u64)>,
        segments: Vec<Segment>,
    ) -> Result<()> {
        let w = self.warehouse_for(index).clone();
        let mut bytes: u64 = fields.iter().map(|(_, t)| t.size_bytes() as u64).sum();
        bytes += (segments.len() * Segment::WIRE_BYTES) as u64;
        if let Some((text, ..)) = &completion {
            bytes += text.len() as u64;
        }
        self.ledger.record(self.link(requester_node, w.node), bytes);
        self.ledger.note_requests_on(self.link(requester_node, w.node), 1);
        let outcome = w.store_fields_with_segments(index, fields, completion, segments)?;
        self.ledger.note_store_bytes(w.traffic_bytes());
        if matches!(outcome, StoreOutcome::Superseded) {
            // a stale writeback (late worker after reclaim/retire)
            // changed no state: nothing to broadcast, nobody to wake.
            // Staleness requires a reclaim, and reclaims require ticks —
            // in a never-ticked flow (sync mode, most tests) a dropped
            // writeback is a caller bug, so keep it loud in debug builds.
            debug_assert!(
                self.clock.now() > 0,
                "writeback for sample {index} dropped as superseded, but this \
                 flow's lease clock never ticked (no reclaim can have happened \
                 — wrong index or write-after-retire at the call site?)"
            );
            return Ok(());
        }
        // snapshot + broadcast under meta_order: whichever writeback
        // snapshots later necessarily sees a superset mask, so broadcast
        // order is monotone per sample while payload stores (above) run
        // concurrently across stage threads
        let _order = self.meta_order.lock().unwrap();
        let meta = w.fetch_meta_snapshot(index)?;
        self.broadcast(w.node, meta);
        drop(_order);
        self.notify.notify();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dock(nodes: usize) -> TransferDock {
        TransferDock::new(DockTopology::spread(nodes))
    }

    fn prompts(n: usize) -> Vec<Sample> {
        (0..n)
            .map(|i| Sample::new_prompt(u64::MAX, i as u64 / 4, format!("{i}+1="), i as i64 + 1))
            .collect()
    }

    #[test]
    fn samples_spread_across_warehouses() {
        let d = dock(4);
        d.put_samples(prompts(16)).unwrap();
        for w in &d.warehouses {
            assert_eq!(w.len(), 4, "round-robin must balance shards");
        }
        let (_total, max) = d.residency();
        assert!(max > 0);
    }

    #[test]
    fn generation_flow_round_trip() {
        let d = dock(2);
        let idx = d.put_samples(prompts(2)).unwrap();
        let metas = d.request_ready(Stage::Generation, 10).unwrap();
        assert_eq!(metas.len(), 2);
        // generation completes for sample 0
        d.store_generation(
            0,
            idx[0],
            vec![(FieldKind::Tokens, Tensor::i32(&[8], vec![1; 8]).unwrap())],
            "42".into(),
            3,
            4,
        )
        .unwrap();
        // now inference stages see exactly one ready sample
        let ready = d.request_ready(Stage::OldLogprob, 10).unwrap();
        assert_eq!(ready.len(), 1);
        assert_eq!(ready[0].index, idx[0]);
        assert_eq!(ready[0].resp_len, 3);
        assert_eq!(ready[0].behavior_version, 4, "metadata must carry the version stamp");
        let fetched = d.fetch(1, &ready).unwrap();
        assert_eq!(fetched[0].completion_text, "42");
        assert_eq!(fetched[0].behavior_version, 4);
    }

    #[test]
    fn update_requires_all_fields() {
        let d = dock(1);
        let idx = d.put_samples(prompts(1)).unwrap()[0];
        d.store_generation(
            0,
            idx,
            vec![(FieldKind::Tokens, Tensor::i32(&[4], vec![1; 4]).unwrap())],
            "2".into(),
            1,
            1,
        )
        .unwrap();
        assert!(d.request_ready(Stage::Update, 1).unwrap().is_empty());
        d.store_fields(0, idx, vec![(FieldKind::OldLp, Tensor::zeros(&[3]))]).unwrap();
        d.store_fields(0, idx, vec![(FieldKind::RefLp, Tensor::zeros(&[3]))]).unwrap();
        d.store_fields(0, idx, vec![(FieldKind::Reward, Tensor::scalar_f32(1.0))])
            .unwrap();
        let ready = d.request_ready(Stage::Update, 1).unwrap();
        assert_eq!(ready.len(), 1);
        let s = d.retire(idx).unwrap();
        assert!(s.ready_for_update());
        assert_eq!(d.len(), 0);
    }

    #[test]
    fn ledger_records_cross_node_payloads() {
        let d = dock(4);
        let idx = d.put_samples(prompts(4)).unwrap();
        let metas = d.request_ready(Stage::Generation, 10).unwrap();
        d.fetch(0, &metas).unwrap();
        let led = d.ledger();
        assert!(led.inter_node_bytes > 0, "shards on other nodes must cost inter-node bytes");
        assert!(led.local_bytes > 0);
        assert!(led.requests > 0);
        drop(idx);
    }

    #[test]
    fn batched_put_ledger_cost_pinned() {
        // one admission batch of 8 samples over 4 warehouses must cost:
        // * payload bytes: Σ payload per sample (link by shard placement)
        // * metadata: per sample, (C+1) broadcast records + 1 warehouse
        //   bookkeeping record — identical to per-sample admission
        // * round-trips: ONE per distinct warehouse touched, not one per
        //   sample (the batching this pin protects)
        let d = dock(4);
        let batch = prompts(8);
        let payload: u64 = batch.iter().map(|s| s.payload_bytes() as u64).sum();
        let before = d.ledger();
        d.put_samples(batch).unwrap();
        let after = d.ledger();
        let c = d.n_controllers() as u64;
        let meta_bytes = 8 * (c + 1) * SampleMeta::WIRE_BYTES;
        assert_eq!(
            after.total_bytes() - before.total_bytes(),
            payload + meta_bytes,
            "admission bytes must be payload + (C+1) metadata records per sample"
        );
        let trips =
            (after.requests + after.local_requests) - (before.requests + before.local_requests);
        assert_eq!(trips, 4, "one admission round-trip per distinct warehouse, not per sample");
    }

    #[test]
    fn lease_expiry_reclaims_through_the_dock() {
        let d = TransferDock::with_lease(DockTopology::spread(2), 2);
        d.put_samples(prompts(2)).unwrap();
        let claimed = d.request_ready(Stage::Generation, 10).unwrap();
        assert_eq!(claimed.len(), 2);
        assert!(d.request_ready(Stage::Generation, 10).unwrap().is_empty());
        // logical time: nothing expires while the clock stands still
        assert_eq!(d.tick_lease_clock(), 0);
        // second tick hits the 2-tick lease
        assert_eq!(d.tick_lease_clock(), 2);
        let again = d.request_ready(Stage::Generation, 10).unwrap();
        assert_eq!(again.len(), 2, "reclaimed samples must be requestable");
        let s = d.lease_stats();
        assert_eq!(s.reclaimed, 2);
        assert_eq!(s.redispatched, 2);
        assert!(s.consistent());
    }

    #[test]
    fn renew_holds_a_lease_across_ticks() {
        let d = TransferDock::with_lease(DockTopology::spread(1), 2);
        let idx = d.put_samples(prompts(1)).unwrap();
        assert_eq!(d.request_ready(Stage::Generation, 1).unwrap().len(), 1);
        d.tick_lease_clock();
        d.renew(Stage::Generation, &idx);
        // original expiry (tick 2) passes; renewed lease lives to tick 3
        assert_eq!(d.tick_lease_clock(), 0, "renewed lease reclaimed early");
        assert_eq!(d.tick_lease_clock(), 1);
    }

    #[test]
    fn fetch_resident_skips_stale_claims() {
        let d = dock(2);
        let idx = d.put_samples(prompts(2)).unwrap();
        let metas = d.request_ready(Stage::Generation, 10).unwrap();
        // sample 0 is reclaimed+retired elsewhere while this worker held
        // its claim: strict fetch errors, tolerant fetch serves the rest
        d.retire(idx[0]).unwrap();
        assert!(d.fetch(0, &metas).is_err());
        let got = d.fetch_resident(0, &metas).unwrap();
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].index, idx[1]);
    }

    #[test]
    fn conservation_holds_across_lifecycle() {
        let d = dock(2);
        let idx = d.put_samples(prompts(4)).unwrap();
        for &i in &idx {
            d.store_generation(
                0,
                i,
                vec![(FieldKind::Tokens, Tensor::i32(&[4], vec![1; 4]).unwrap())],
                "2".into(),
                1,
                1,
            )
            .unwrap();
        }
        d.retire(idx[0]).unwrap();
        for c in d.conservation() {
            assert!(c.holds(), "{c:?}");
        }
        let (total, _) = d.residency();
        let resident_sum: u64 = d.conservation().iter().map(|c| c.resident_bytes).sum();
        assert_eq!(total, resident_sum);
    }

    #[test]
    fn partial_prefix_survives_reclaim_and_redispatch() {
        let d = TransferDock::with_lease(DockTopology::spread(2), 2);
        let idx = d.put_samples(prompts(1)).unwrap()[0];
        assert_eq!(d.request_ready(Stage::Generation, 1).unwrap().len(), 1);
        // the claiming worker checkpoints its decoded prefix, then dies
        let p = PartialRollout {
            response_ids: vec![4, 5, 6],
            response_logprobs: vec![-0.1; 3],
            segments: vec![Segment { start: 0, len: 3, version: 1 }],
        };
        d.store_partial_generation(0, idx, p.clone()).unwrap();
        // lease expires; the sample is redispatched WITH the prefix
        d.tick_lease_clock();
        assert_eq!(d.tick_lease_clock(), 1);
        let again = d.request_ready(Stage::Generation, 1).unwrap();
        assert_eq!(again.len(), 1);
        let fetched = d.fetch_resident(1, &again).unwrap();
        assert_eq!(fetched[0].partial.as_ref(), Some(&p), "reclaim must hand the prefix back");
        // the resumed worker finishes across the version boundary
        let segs = vec![
            Segment { start: 0, len: 3, version: 1 },
            Segment { start: 3, len: 2, version: 2 },
        ];
        d.store_generation_with_segments(
            1,
            idx,
            vec![(FieldKind::Tokens, Tensor::i32(&[8], vec![1; 8]).unwrap())],
            "done".into(),
            5,
            2,
            segs.clone(),
        )
        .unwrap();
        let ready = d.request_ready(Stage::OldLogprob, 1).unwrap();
        let s = d.fetch(0, &ready).unwrap().remove(0);
        assert!(s.partial.is_none(), "completion clears the persisted prefix");
        assert_eq!(s.segments, segs);
        // a late partial from the dead worker is dropped, counted once
        d.store_partial_generation(0, idx, p).unwrap();
        assert_eq!(d.superseded_writebacks(), 1);
        for c in d.conservation() {
            assert!(c.holds(), "{c:?}");
        }
    }

    #[test]
    fn double_dispatch_prevented() {
        let d = dock(2);
        d.put_samples(prompts(4)).unwrap();
        let a = d.request_ready(Stage::Generation, 2).unwrap();
        let b = d.request_ready(Stage::Generation, 10).unwrap();
        assert_eq!(a.len(), 2);
        assert_eq!(b.len(), 2);
        let ai: Vec<u64> = a.iter().map(|m| m.index).collect();
        assert!(b.iter().all(|m| !ai.contains(&m.index)));
    }
}
