//! Claim leases: logical-time liveness tracking for dispatched samples.
//!
//! A `request`/`wait_ready` handout is no longer an unconditional latch —
//! it is a **lease** against a logical clock. The clock is ticked by the
//! driving executor (never by wall time, so fault tests stay fully
//! deterministic): while the stage workers make progress the driver has
//! work and the clock stands still; when the flow stalls the driver's
//! idle passes advance it. A lease that outlives `lease_ticks` ticks
//! without a renewing writeback is **reclaimed** — the sample returns to
//! the ready pool with a bumped attempt counter, and the next grant of
//! that sample counts as a **redispatch**. `release` cancels a lease
//! cooperatively (no attempt bump: the worker gave the claim back);
//! completion and retire drop the lease and its attempt history.
//!
//! This is the recovery half of the paper's reliability claim: a stage
//! worker that dies or stalls after claiming work can no longer strand
//! its samples forever — the dataflow notices the silence and re-routes.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::metrics::FlowRecovery;

/// Default lease duration in logical ticks. The executor ticks only on
/// idle driver passes (~50 ms apart), so the default tolerates several
/// seconds of a stage worker making zero writebacks before reclaiming.
pub const DEFAULT_LEASE_TICKS: u64 = 64;

/// The flow-wide logical clock leases are measured against. Shared by
/// every controller of a flow; advanced only by the driving executor.
#[derive(Debug, Default)]
pub struct LeaseClock {
    tick: AtomicU64,
}

impl LeaseClock {
    pub fn now(&self) -> u64 {
        self.tick.load(Ordering::Acquire)
    }

    /// Advance logical time by one tick; returns the new now.
    pub fn advance(&self) -> u64 {
        self.tick.fetch_add(1, Ordering::AcqRel) + 1
    }
}

/// Lease bookkeeping for one claim domain (one controller's stage, or one
/// stage partition of the centralized replay buffer). Not thread-safe on
/// its own — lives inside the owning flow's mutex.
#[derive(Debug, Default)]
pub struct LeaseTable {
    /// live claims: sample index → tick at which the lease expires
    leases: HashMap<u64, u64>,
    /// reclaim history: sample index → expired dispatch attempts
    attempts: HashMap<u64, u32>,
    granted: u64,
    renewed: u64,
    reclaimed: u64,
    redispatched: u64,
    attempt_bumps: u64,
    max_attempt: u32,
}

impl LeaseTable {
    pub fn is_claimed(&self, index: u64) -> bool {
        self.leases.contains_key(&index)
    }

    pub fn live(&self) -> usize {
        self.leases.len()
    }

    /// Prior expired dispatches of this sample.
    pub fn attempt(&self, index: u64) -> u32 {
        self.attempts.get(&index).copied().unwrap_or(0)
    }

    /// A lease of `ticks` expires on the `ticks`-th tick after the grant
    /// or renewal (`expires_at <= now` reclaims). Drivers that renew
    /// once per pass and tick on the same pass therefore need
    /// `ticks >= 2` for renewal to be effective — `GrpoConfig::validate`
    /// enforces that for the executor.
    fn expiry(now: u64, ticks: u64) -> u64 {
        now.saturating_add(ticks.max(1))
    }

    /// Grant a lease (the caller has verified the sample is ready and
    /// unclaimed). A grant of a previously-reclaimed sample counts as a
    /// redispatch.
    pub fn claim(&mut self, index: u64, now: u64, ticks: u64) {
        self.granted += 1;
        if self.attempt(index) > 0 {
            self.redispatched += 1;
        }
        self.leases.insert(index, Self::expiry(now, ticks));
    }

    /// Extend a live lease (writeback activity or an explicit renew from
    /// a long-holding consumer). No-op for unclaimed samples.
    pub fn renew(&mut self, index: u64, now: u64, ticks: u64) -> bool {
        match self.leases.get_mut(&index) {
            Some(exp) => {
                *exp = Self::expiry(now, ticks);
                self.renewed += 1;
                true
            }
            None => false,
        }
    }

    /// Cooperative give-back: the worker still holds the claim and hands
    /// it back unprocessed. Not a failure — no attempt bump.
    pub fn release(&mut self, index: u64) {
        self.leases.remove(&index);
    }

    /// The claimed work completed (a writeback made the sample unready
    /// for this domain): drop the lease and the attempt history.
    pub fn complete(&mut self, index: u64) {
        self.leases.remove(&index);
        self.attempts.remove(&index);
    }

    /// The sample left the flow entirely (retired).
    pub fn forget(&mut self, index: u64) {
        self.leases.remove(&index);
        self.attempts.remove(&index);
    }

    /// Reclaim every lease that expired at or before `now`: the sample
    /// returns to the ready pool and its attempt counter bumps. Returns
    /// the reclaimed sample indices.
    pub fn expire(&mut self, now: u64) -> Vec<u64> {
        let dead: Vec<u64> = self
            .leases
            .iter()
            .filter(|(_, &exp)| exp <= now)
            .map(|(&idx, _)| idx)
            .collect();
        for &idx in &dead {
            self.leases.remove(&idx);
            let a = self.attempts.entry(idx).or_insert(0);
            *a += 1;
            self.max_attempt = self.max_attempt.max(*a);
            self.reclaimed += 1;
            self.attempt_bumps += 1;
        }
        dead
    }

    /// Accounting snapshot (lease counters only; the executor fills the
    /// fault-injection fields).
    pub fn stats(&self) -> FlowRecovery {
        FlowRecovery {
            leases_granted: self.granted,
            leases_renewed: self.renewed,
            reclaimed: self.reclaimed,
            redispatched: self.redispatched,
            attempt_bumps: self.attempt_bumps,
            max_attempt: self.max_attempt,
            ..Default::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clock_advances_monotonically() {
        let c = LeaseClock::default();
        assert_eq!(c.now(), 0);
        assert_eq!(c.advance(), 1);
        assert_eq!(c.advance(), 2);
        assert_eq!(c.now(), 2);
    }

    #[test]
    fn lease_lifecycle_grant_expire_redispatch() {
        let mut t = LeaseTable::default();
        t.claim(7, 0, 2);
        assert!(t.is_claimed(7));
        // not yet: expires at tick 2
        assert!(t.expire(1).is_empty());
        assert_eq!(t.expire(2), vec![7]);
        assert!(!t.is_claimed(7));
        assert_eq!(t.attempt(7), 1);
        // the re-grant is a redispatch
        t.claim(7, 2, 2);
        let s = t.stats();
        assert_eq!(s.leases_granted, 2);
        assert_eq!(s.reclaimed, 1);
        assert_eq!(s.redispatched, 1);
        assert_eq!(s.attempt_bumps, 1);
        assert_eq!(s.max_attempt, 1);
        assert!(s.consistent());
    }

    #[test]
    fn renew_extends_and_complete_clears_history() {
        let mut t = LeaseTable::default();
        t.claim(1, 0, 2);
        assert!(t.renew(1, 3, 2)); // now expires at 5
        assert!(t.expire(4).is_empty());
        assert_eq!(t.expire(5), vec![1]);
        // second dispatch completes: attempt history is dropped
        t.claim(1, 5, 2);
        t.complete(1);
        assert_eq!(t.attempt(1), 0);
        assert!(!t.renew(9, 0, 2), "renewing an unclaimed sample is a no-op");
    }

    #[test]
    fn release_is_not_a_failure() {
        let mut t = LeaseTable::default();
        t.claim(3, 0, 2);
        t.release(3);
        assert!(!t.is_claimed(3));
        assert_eq!(t.attempt(3), 0, "release must not bump attempts");
        assert_eq!(t.stats().reclaimed, 0);
    }

    #[test]
    fn saturating_lease_never_expires() {
        let mut t = LeaseTable::default();
        t.claim(1, 5, u64::MAX);
        assert!(t.expire(u64::MAX - 1).is_empty());
    }
}
