//! Sample-flow dataflow: the paper's **distributed transfer dock** (TD)
//! and the centralized replay-buffer baseline it replaces.
//!
//! The TD splits the conventional replay buffer two ways (paper Fig. 4):
//!
//! * **warehouses** — the sample payload store is sharded along the global
//!   batch dimension across `S` nodes, so payload dispatch bandwidth is
//!   spread over `S` servers instead of one (Eq. 4's `/S`).
//! * **controllers** — one *per worker state* (actor generation, actor
//!   inference, reference inference, reward, actor update), holding only
//!   metadata (sample index, warehouse id, readiness). Workers ask their
//!   own controller what to fetch, then fetch payloads directly from
//!   warehouses; warehouses broadcast metadata deltas to all `C`
//!   controllers (Eq. 4's `8(C+1)M` term).
//!
//! Every byte moved is recorded in a [`CommLedger`] with the link class it
//! crossed (local / inter-node / host-device), which is how Table 1 and
//! Fig. 9 are regenerated without 384 real NPUs: the payload movement is
//! real (`Tensor` clones between stores), the *time* is derived from the
//! paper's measured bandwidths.

mod controller;
mod dock;
pub mod lease;
mod network;
mod notify;
mod placement;
mod replay_buffer;
mod sample;
pub mod volume;
mod warehouse;

pub use controller::{Controller, SampleMeta};
pub use dock::{DockTopology, TransferDock};
pub use lease::{LeaseClock, DEFAULT_LEASE_TICKS};
pub use network::{CommLedger, LinkClass, NetworkModel};
pub use placement::Placement;
pub use replay_buffer::ReplayBuffer;
pub use sample::{push_segment, FieldKind, PartialRollout, Sample, Segment, Stage, FIELD_ORDER};
pub use volume::{td_tcv_gb, tcv_gb, cv_update_gb, VolumeParams};
pub use warehouse::{Conservation, StoreOutcome, Warehouse};

use anyhow::Result;

/// Common interface over the transfer dock and the replay-buffer baseline,
/// so trainers and the simulator can run either dataflow (Fig. 7/9's
/// MSRL-vs-MSRLB ablation).
pub trait SampleFlow: Send + Sync {
    /// Admit new prompt samples; returns their global indices.
    fn put_samples(&self, samples: Vec<Sample>) -> Result<Vec<u64>>;
    /// Ask the dataflow for up to `max_n` samples ready for `stage`.
    fn request_ready(&self, stage: Stage, max_n: usize) -> Result<Vec<SampleMeta>>;
    /// Blocking variant of [`Self::request_ready`] for long-lived stage
    /// workers: returns as soon as work is available, or an empty vec once
    /// `timeout` expires with nothing ready. Implementations are
    /// condvar-notified on every state change — no busy-polling.
    fn wait_ready(
        &self,
        stage: Stage,
        max_n: usize,
        timeout: std::time::Duration,
    ) -> Result<Vec<SampleMeta>>;
    /// Non-blocking incremental claim for streaming stage workers polling
    /// *between decode steps*: returns whatever is ready right now, up to
    /// `max_n`, never waiting. Implementations with a comm ledger charge
    /// the metadata round-trip only when the claim is non-empty —
    /// step-granularity polling must not inflate dispatch accounting,
    /// which is a function of data movement, not of how often a scheduler
    /// looks (the default forwards to [`Self::request_ready`], which
    /// charges every poll; ledgered flows override).
    fn try_claim(&self, stage: Stage, max_n: usize) -> Result<Vec<SampleMeta>> {
        self.request_ready(stage, max_n)
    }
    /// Return claimed-but-unprocessed samples to the ready pool (e.g. the
    /// update state handing back groups that are not yet complete).
    /// Cooperative: the caller asserts it still holds the claim — a worker
    /// that outlived its lease must NOT release (its claim already went
    /// back to the pool, possibly to another worker).
    fn release(&self, stage: Stage, indices: &[u64]);
    /// Advance the flow's logical lease clock by one tick and reclaim
    /// every claim whose lease expired — the sample returns to the ready
    /// pool with a bumped attempt counter. Called by the *driving*
    /// executor on idle passes (logical time, never wall time, so chaos
    /// tests stay deterministic). Returns how many claims were reclaimed.
    fn tick_lease_clock(&self) -> usize {
        0
    }
    /// Current logical lease time (0 for flows without a lease clock).
    fn lease_now(&self) -> u64 {
        0
    }
    /// Extend the leases of claims the caller legitimately still holds
    /// (e.g. the update state holding partial GRPO groups across ticks).
    fn renew(&self, _stage: Stage, _indices: &[u64]) {}
    /// Lease / reclaim / redispatch accounting across the flow.
    fn lease_stats(&self) -> crate::metrics::FlowRecovery {
        crate::metrics::FlowRecovery::default()
    }
    /// Ready-and-unclaimed queue depth for `stage` — the backlog signal
    /// the elastic autoscaler samples on lease ticks. Control-plane
    /// introspection by the driving executor: costs no ledger bytes
    /// (the driver reads its co-located controller's counter, it does
    /// not move metadata). Sharded flows report the **sum** across
    /// controller shards — with work stealing any shard's pool is
    /// reachable from any puller, so the backlog signal is global.
    fn ready_depth(&self, _stage: Stage) -> usize {
        0
    }
    /// Tell the flow how many replica workers concurrently pull `stage`
    /// so claim handouts can be fair-shared: with `n > 1` pullers a
    /// single request is capped near `⌈ready/n⌉` instead of draining the
    /// whole queue into one replica's batch. Called by the executor
    /// whenever a stage's replica count changes; flows without fairness
    /// support ignore it. Sharded flows distribute the `n` pullers over
    /// their controller shards (the fair-share cap is **per shard**: a
    /// shard serving 2 of 8 pullers caps at ⌈its ready/2⌉).
    fn note_pullers(&self, _stage: Stage, _n: usize) {}
    /// Register per-tenant scheduling weights: claim handouts become
    /// deficit-weighted round robin across backlogged tenants, so each
    /// tenant's long-run claim share tracks its weight without reserving
    /// slots for idle tenants. Flows without tenancy support ignore it —
    /// every tenant then runs at weight 1, which is also the behavior
    /// for tenants absent from the list.
    fn set_tenant_weights(&self, _weights: &[(u32, u32)]) {}
    /// Samples handed out per tenant since the weights were set — the
    /// claim-share evidence behind `TenantReport` and the Jain fairness
    /// gate. Empty for flows without tenancy support.
    fn tenant_claims(&self) -> Vec<(u32, u64)> {
        Vec::new()
    }
    /// Fetch full payloads for the given metadata (records comm bytes).
    fn fetch(&self, requester_node: usize, metas: &[SampleMeta]) -> Result<Vec<Sample>>;
    /// Lease-tolerant fetch for stage workers: metas whose sample is no
    /// longer resident (a stale claim whose sample was reclaimed,
    /// re-processed, and retired while this worker was stalled) are
    /// silently skipped instead of erroring, so a recovered flow never
    /// kills the late worker. Defaults to the strict [`Self::fetch`] for
    /// flows without leases.
    fn fetch_resident(&self, requester_node: usize, metas: &[SampleMeta]) -> Result<Vec<Sample>> {
        self.fetch(requester_node, metas)
    }
    /// Write fields back for a sample after a stage completes.
    fn store_fields(
        &self,
        requester_node: usize,
        index: u64,
        fields: Vec<(FieldKind, crate::runtime::Tensor)>,
    ) -> Result<()>;
    /// Generation writeback: fields plus the decoded completion text and
    /// the behavior-policy weight version the response was sampled under
    /// (stamped onto the sample and every subsequent metadata broadcast;
    /// pass `1` for flows without a versioned weight channel).
    fn store_generation(
        &self,
        requester_node: usize,
        index: u64,
        fields: Vec<(FieldKind, crate::runtime::Tensor)>,
        completion: String,
        resp_len: usize,
        behavior_version: u64,
    ) -> Result<()>;
    /// [`Self::store_generation`] with an explicit per-version segment
    /// list for a response assembled across interruptions (partial
    /// rollouts). Flows that store segments override this; the default
    /// drops the list and stores the completion plainly, which is correct
    /// for single-segment responses (the store synthesizes the full-span
    /// segment) and merely loses per-span stamps otherwise.
    fn store_generation_with_segments(
        &self,
        requester_node: usize,
        index: u64,
        fields: Vec<(FieldKind, crate::runtime::Tensor)>,
        completion: String,
        resp_len: usize,
        behavior_version: u64,
        segments: Vec<Segment>,
    ) -> Result<()> {
        let _ = segments;
        self.store_generation(requester_node, index, fields, completion, resp_len, behavior_version)
    }
    /// Persist the decoded prefix of an *interrupted* generation as
    /// first-class partial state, so a redispatch of the same sample can
    /// resume from the prefix instead of regenerating from the prompt.
    /// Does not change stage readiness (the sample stays
    /// generation-ready) and never overwrites a finished response —
    /// stale/duplicate persists are dropped as superseded writebacks.
    /// Flows without partial-rollout support ignore it (the prefix is
    /// simply lost and the redispatch regenerates from scratch, the
    /// pre-partial behavior).
    fn store_partial_generation(
        &self,
        _requester_node: usize,
        _index: u64,
        _partial: PartialRollout,
    ) -> Result<()> {
        Ok(())
    }
    /// Consume a finished sample after the update stage.
    fn retire(&self, index: u64) -> Option<Sample>;
    /// Snapshot of accumulated communication accounting.
    fn ledger(&self) -> CommLedger;
    /// Number of parallel payload stores (warehouses). Dispatch time
    /// divides by this: warehouses serve concurrently (Eq. 4's /S).
    fn shards(&self) -> usize;
    /// Per-controller-shard dispatch counters (claims handed out at the
    /// home shard, samples stolen *from* each shard by siblings, leases
    /// reclaimed per shard). Unsharded flows report the empty default.
    fn dock_report(&self) -> crate::metrics::DockShardReport {
        crate::metrics::DockShardReport::default()
    }
    /// Dispatch seconds implied by the accumulated ledger under `net`,
    /// honouring store parallelism.
    fn dispatch_secs(&self, net: &NetworkModel) -> f64 {
        self.ledger().dispatch_secs_sharded(net, self.shards())
    }
    /// Number of samples currently resident.
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
}
