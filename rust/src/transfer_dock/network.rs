//! Communication accounting: link classes, bandwidth model, byte ledger.
//!
//! The paper's measured testbed numbers (Experiment Setup): inter-server
//! bandwidth 300 MB/s, host↔device 50 GB/s. Every payload/metadata
//! movement in the sample flow and resharding flow records (bytes, link
//! class) here; dispatch *time* is then `bytes / bandwidth(link)` — this
//! is the calibration-free part of the cost model since it uses the
//! paper's own constants.

use std::sync::atomic::{AtomicU64, Ordering};

/// Which physical link a transfer crosses.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LinkClass {
    /// same node, device-to-device or in-memory (effectively free at the
    /// sample-flow scale; modeled at memory bandwidth)
    Local,
    /// server-to-server network (the paper's 300 MB/s)
    InterNode,
    /// host ↔ device swap path (the paper's 50 GB/s)
    HostDevice,
}

/// Bandwidths in bytes/second.
#[derive(Debug, Clone, Copy)]
pub struct NetworkModel {
    pub local_bps: f64,
    pub inter_node_bps: f64,
    pub host_device_bps: f64,
    /// per-request latency for a cross-node RPC (serialization +
    /// scheduler overhead the paper attributes to Ray dispatch), seconds
    pub request_latency_s: f64,
    /// per-request latency for a node-local call (co-located controller /
    /// warehouse — the transfer dock's case), seconds
    pub local_request_latency_s: f64,
}

impl NetworkModel {
    /// The paper's measured testbed.
    pub fn paper() -> Self {
        Self {
            local_bps: 200e9,
            inter_node_bps: 300e6,
            host_device_bps: 50e9,
            request_latency_s: 300e-6,
            local_request_latency_s: 15e-6,
        }
    }

    /// Table 1's two connection columns (100 MB/s and 1 GB/s).
    pub fn with_inter_node(inter_node_bps: f64) -> Self {
        Self { inter_node_bps, ..Self::paper() }
    }

    pub fn bandwidth(&self, link: LinkClass) -> f64 {
        match link {
            LinkClass::Local => self.local_bps,
            LinkClass::InterNode => self.inter_node_bps,
            LinkClass::HostDevice => self.host_device_bps,
        }
    }

    pub fn transfer_secs(&self, link: LinkClass, bytes: u64) -> f64 {
        bytes as f64 / self.bandwidth(link)
    }
}

/// Accumulated transfer statistics. Cheap to clone (snapshotting).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CommLedger {
    pub local_bytes: u64,
    pub inter_node_bytes: u64,
    pub host_device_bytes: u64,
    /// cross-node RPC round-trips
    pub requests: u64,
    /// node-local round-trips (co-located controller/warehouse)
    pub local_requests: u64,
    /// peak bytes moved through any single store (the congestion point a
    /// centralized buffer creates; warehouses spread this)
    pub max_store_bytes: u64,
}

impl CommLedger {
    /// Record moved bytes. Does NOT count an RPC: metadata broadcasts are
    /// piggybacked/async; count round-trips explicitly via
    /// [`Self::note_requests`].
    pub fn record(&mut self, link: LinkClass, bytes: u64) {
        match link {
            LinkClass::Local => self.local_bytes += bytes,
            LinkClass::InterNode => self.inter_node_bytes += bytes,
            LinkClass::HostDevice => self.host_device_bytes += bytes,
        }
    }

    /// Count synchronous request round-trips (each pays
    /// `request_latency_s`, the paper's Ray dispatch overhead).
    pub fn note_requests(&mut self, n: u64) {
        self.requests += n;
    }

    /// Count round-trips classified by the link they cross: node-local
    /// calls pay `local_request_latency_s` instead.
    pub fn note_requests_on(&mut self, link: LinkClass, n: u64) {
        if matches!(link, LinkClass::Local) {
            self.local_requests += n;
        } else {
            self.requests += n;
        }
    }

    pub fn total_bytes(&self) -> u64 {
        self.local_bytes + self.inter_node_bytes + self.host_device_bytes
    }

    /// Serial dispatch time under a network model: all transfers paid at
    /// their link bandwidth plus per-request latency.
    pub fn dispatch_secs(&self, net: &NetworkModel) -> f64 {
        net.transfer_secs(LinkClass::Local, self.local_bytes)
            + net.transfer_secs(LinkClass::InterNode, self.inter_node_bytes)
            + net.transfer_secs(LinkClass::HostDevice, self.host_device_bytes)
            + self.requests as f64 * net.request_latency_s
            + self.local_requests as f64 * net.local_request_latency_s
    }

    /// Dispatch time when the store side is sharded over `s` equal servers
    /// (warehouse parallelism): payload cost divides, latency stays.
    pub fn dispatch_secs_sharded(&self, net: &NetworkModel, s: usize) -> f64 {
        let s = s.max(1) as f64;
        net.transfer_secs(LinkClass::Local, self.local_bytes) / s
            + net.transfer_secs(LinkClass::InterNode, self.inter_node_bytes) / s
            + net.transfer_secs(LinkClass::HostDevice, self.host_device_bytes)
            + self.requests as f64 * net.request_latency_s / s
            + self.local_requests as f64 * net.local_request_latency_s
    }

    pub fn merge(&mut self, other: &CommLedger) {
        self.local_bytes += other.local_bytes;
        self.inter_node_bytes += other.inter_node_bytes;
        self.host_device_bytes += other.host_device_bytes;
        self.requests += other.requests;
        self.local_requests += other.local_requests;
        self.max_store_bytes = self.max_store_bytes.max(other.max_store_bytes);
    }
}

/// Shared, thread-safe ledger.
///
/// Lock-free: every stage thread of the pipelined executor records bytes
/// on every request/fetch/store, so a single `Mutex<CommLedger>` would be
/// the hottest lock in the system. Each counter is an independent
/// `AtomicU64` (relaxed ordering — the ledger is statistics, not a
/// synchronization point; `snapshot` tolerates being mid-update).
#[derive(Debug, Default)]
pub struct SharedLedger {
    local_bytes: AtomicU64,
    inter_node_bytes: AtomicU64,
    host_device_bytes: AtomicU64,
    requests: AtomicU64,
    local_requests: AtomicU64,
    max_store_bytes: AtomicU64,
}

impl SharedLedger {
    pub fn record(&self, link: LinkClass, bytes: u64) {
        let counter = match link {
            LinkClass::Local => &self.local_bytes,
            LinkClass::InterNode => &self.inter_node_bytes,
            LinkClass::HostDevice => &self.host_device_bytes,
        };
        counter.fetch_add(bytes, Ordering::Relaxed);
    }

    pub fn note_requests(&self, n: u64) {
        self.requests.fetch_add(n, Ordering::Relaxed);
    }

    pub fn note_requests_on(&self, link: LinkClass, n: u64) {
        if matches!(link, LinkClass::Local) {
            self.local_requests.fetch_add(n, Ordering::Relaxed);
        } else {
            self.requests.fetch_add(n, Ordering::Relaxed);
        }
    }

    pub fn note_store_bytes(&self, bytes: u64) {
        self.max_store_bytes.fetch_max(bytes, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> CommLedger {
        CommLedger {
            local_bytes: self.local_bytes.load(Ordering::Relaxed),
            inter_node_bytes: self.inter_node_bytes.load(Ordering::Relaxed),
            host_device_bytes: self.host_device_bytes.load(Ordering::Relaxed),
            requests: self.requests.load(Ordering::Relaxed),
            local_requests: self.local_requests.load(Ordering::Relaxed),
            max_store_bytes: self.max_store_bytes.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_bandwidths() {
        let n = NetworkModel::paper();
        assert_eq!(n.bandwidth(LinkClass::InterNode), 300e6);
        assert_eq!(n.bandwidth(LinkClass::HostDevice), 50e9);
    }

    #[test]
    fn transfer_time_scales() {
        let n = NetworkModel::with_inter_node(100e6);
        let t = n.transfer_secs(LinkClass::InterNode, 1_000_000_000);
        assert!((t - 10.0).abs() < 1e-9);
    }

    #[test]
    fn ledger_accumulates_and_merges() {
        let mut a = CommLedger::default();
        a.record(LinkClass::InterNode, 100);
        a.note_requests(2);
        a.record(LinkClass::Local, 50);
        let mut b = CommLedger::default();
        b.record(LinkClass::InterNode, 200);
        b.note_requests(1);
        a.merge(&b);
        assert_eq!(a.inter_node_bytes, 300);
        assert_eq!(a.requests, 3);
        assert_eq!(a.total_bytes(), 350);
    }

    #[test]
    fn sharded_dispatch_divides_payload_not_latency() {
        let mut l = CommLedger::default();
        l.record(LinkClass::InterNode, 300_000_000); // 1s at paper bandwidth
        l.note_requests(1);
        let net = NetworkModel::paper();
        let t1 = l.dispatch_secs(&net);
        let t4 = l.dispatch_secs_sharded(&net, 4);
        assert!(t4 < t1);
        assert!(t4 >= 0.25 * (t1 - net.request_latency_s));
    }
}
