//! Readiness notification for blocking stage workers.
//!
//! The pipelined executor runs one long-lived thread per worker state, each
//! pulling from the shared [`super::SampleFlow`]. Busy-polling
//! `request_ready` would burn a core per stage; instead every state change
//! in a flow (admission, field writeback, retire, release) bumps an epoch
//! counter and wakes waiters on a `Condvar`. `wait_ready` then re-polls
//! only when the epoch moved, which makes the wait race-free: an update
//! that lands between the poll and the wait changes the epoch, so the
//! waiter re-checks instead of sleeping through the wakeup.

use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Epoch counter + condvar: the flow-side half of `wait_ready`.
#[derive(Debug, Default)]
pub(crate) struct Notifier {
    epoch: Mutex<u64>,
    cv: Condvar,
}

impl Notifier {
    /// Signal that flow state changed (new sample, field written, retire,
    /// release). Wakes every blocked stage worker.
    pub fn notify(&self) {
        let mut g = self.epoch.lock().unwrap();
        *g = g.wrapping_add(1);
        self.cv.notify_all();
    }

    /// Conditional [`Self::notify`]: wake waiters only when something
    /// actually changed (the lease-clock tick path reclaims in bulk and
    /// must not wake every stage worker on a quiet tick).
    pub fn notify_if(&self, changed: bool) {
        if changed {
            self.notify();
        }
    }

    /// Current epoch; read *before* polling so a concurrent change between
    /// poll and wait is never missed.
    pub fn epoch(&self) -> u64 {
        *self.epoch.lock().unwrap()
    }

    /// Block until the epoch differs from `seen` or `deadline` passes.
    /// Returns the epoch observed on exit (== `seen` means timeout with no
    /// state change).
    pub fn wait_past(&self, seen: u64, deadline: Instant) -> u64 {
        let mut g = self.epoch.lock().unwrap();
        while *g == seen {
            let now = Instant::now();
            if now >= deadline {
                return *g;
            }
            let (g2, res) = self.cv.wait_timeout(g, deadline - now).unwrap();
            g = g2;
            if res.timed_out() {
                return *g;
            }
        }
        *g
    }
}

/// Shared `wait_ready` skeleton for flow implementations: poll, and if
/// empty, sleep on the notifier until the state epoch moves or the
/// timeout expires. `poll` is the flow's own `request_ready`.
pub(crate) fn wait_ready_impl<F>(
    notifier: &Notifier,
    timeout: Duration,
    mut poll: F,
) -> anyhow::Result<Vec<super::SampleMeta>>
where
    F: FnMut() -> anyhow::Result<Vec<super::SampleMeta>>,
{
    let deadline = Instant::now() + timeout;
    loop {
        let seen = notifier.epoch();
        let metas = poll()?;
        if !metas.is_empty() {
            return Ok(metas);
        }
        if notifier.wait_past(seen, deadline) == seen {
            // deadline passed with no state change since the last poll
            return Ok(Vec::new());
        }
        if Instant::now() >= deadline {
            // state moved at the deadline edge: one final poll, then out
            return poll();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn notify_bumps_epoch_and_wakes() {
        let n = Arc::new(Notifier::default());
        let seen = n.epoch();
        let n2 = n.clone();
        let h = std::thread::spawn(move || {
            n2.wait_past(seen, Instant::now() + Duration::from_secs(5))
        });
        std::thread::sleep(Duration::from_millis(10));
        n.notify();
        assert_ne!(h.join().unwrap(), seen);
    }

    #[test]
    fn wait_past_times_out_unchanged() {
        let n = Notifier::default();
        let seen = n.epoch();
        let t0 = Instant::now();
        let out = n.wait_past(seen, Instant::now() + Duration::from_millis(20));
        assert_eq!(out, seen);
        assert!(t0.elapsed() >= Duration::from_millis(15));
    }

    #[test]
    fn wait_ready_sees_concurrent_publish() {
        use super::super::SampleMeta;
        let n = Arc::new(Notifier::default());
        let published = Arc::new(Mutex::new(Vec::<SampleMeta>::new()));
        let (n2, p2) = (n.clone(), published.clone());
        let h = std::thread::spawn(move || {
            wait_ready_impl(&n2, Duration::from_secs(5), || {
                Ok(p2.lock().unwrap().clone())
            })
            .unwrap()
        });
        std::thread::sleep(Duration::from_millis(10));
        published.lock().unwrap().push(SampleMeta {
            index: 7,
            group: 0,
            warehouse: 0,
            present: 0,
            prompt_len: 1,
            resp_len: 0,
            behavior_version: 0,
        });
        n.notify();
        let got = h.join().unwrap();
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].index, 7);
    }
}
