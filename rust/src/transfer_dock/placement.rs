//! The single shared placement policy: which controller shard owns a
//! sample, and which warehouse stores its payload.
//!
//! Both routing decisions used to live apart — `TransferDock` hardcoded
//! `index % n_warehouses` while controller sharding didn't exist — so the
//! policy is now defined exactly once and used by both. The invariants the
//! rest of the dock builds on:
//!
//! * **Determinism** — shard and warehouse are pure functions of the
//!   sample index. Any worker (or test) can recompute ownership without
//!   asking the dock, and reclaim/redispatch/steal never move a sample's
//!   home.
//! * **K = 1 degeneracy** — with one shard the warehouse rule is exactly
//!   the historical `index % n_warehouses` round-robin, so a single-shard
//!   dock is bit-identical to the pre-sharding dock (the refactor's
//!   differential oracle).
//! * **Affinity** — with K > 1 shards a sample's payload lands on the
//!   warehouse co-located with its owning shard's node when one exists,
//!   falling back to the modulo policy otherwise, so a shard's claims
//!   fetch node-locally in the common case.

/// Sample → (controller shard, warehouse) routing policy.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Placement {
    shards: usize,
    n_warehouses: usize,
    /// per shard: the warehouse co-located with the shard's home node,
    /// `None` when no warehouse lives there (modulo fallback)
    affinity: Vec<Option<usize>>,
}

impl Placement {
    /// The historical single-shard policy: warehouse = `index % n`.
    pub fn modulo(n_warehouses: usize) -> Self {
        Self { shards: 1, n_warehouses: n_warehouses.max(1), affinity: vec![None] }
    }

    /// K controller shards with explicit per-shard warehouse affinity
    /// (`affinity.len()` defines K; entries are `None` where the shard's
    /// node hosts no warehouse).
    pub fn sharded(n_warehouses: usize, affinity: Vec<Option<usize>>) -> Self {
        let n_warehouses = n_warehouses.max(1);
        assert!(!affinity.is_empty(), "placement needs at least one shard");
        for w in affinity.iter().flatten() {
            assert!(*w < n_warehouses, "affinity points past the warehouse list");
        }
        Self { shards: affinity.len(), n_warehouses, affinity }
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    pub fn n_warehouses(&self) -> usize {
        self.n_warehouses
    }

    /// 64-bit finalizer (splitmix64): sample indices are sequential, so a
    /// plain `index % K` would stripe whole admission batches shard by
    /// shard in lockstep with the warehouse modulo; the mix decorrelates
    /// the two while staying a pure function of the index.
    fn mix(mut x: u64) -> u64 {
        x ^= x >> 33;
        x = x.wrapping_mul(0xff51_afd7_ed55_8ccd);
        x ^= x >> 33;
        x = x.wrapping_mul(0xc4ce_b9fe_1a85_ec53);
        x ^ (x >> 33)
    }

    /// Per-tenant salt folded into the home-shard mix. Zero for the
    /// default tenant — tenant-0 routing is bit-identical to the
    /// pre-tenancy policy, which is what pins single-tenant runs to the
    /// historical retired maps. Non-zero tenants get a full-width odd
    /// multiplier (golden-ratio constant) so one tenant's sequential
    /// admission burst cannot pile onto the shard sequence another
    /// tenant's burst landed on.
    fn tenant_salt(tenant: u32) -> u64 {
        (tenant as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
    }

    /// Which controller shard owns this sample. Stable for the sample's
    /// whole lifetime; 0 for every index when K = 1.
    pub fn shard_of(&self, index: u64) -> usize {
        self.shard_of_t(index, 0)
    }

    /// Tenant-aware shard ownership: the tenant id is hashed into the
    /// splitmix64 input, decorrelating tenants' shard sequences.
    pub fn shard_of_t(&self, index: u64, tenant: u32) -> usize {
        if self.shards <= 1 {
            0
        } else {
            (Self::mix(index ^ Self::tenant_salt(tenant)) % self.shards as u64) as usize
        }
    }

    /// Which warehouse stores this sample's payload: the owning shard's
    /// co-located warehouse when K > 1 and one exists, else the modulo
    /// policy (and always the modulo policy at K = 1).
    pub fn warehouse_of(&self, index: u64) -> usize {
        self.warehouse_of_t(index, 0)
    }

    /// Tenant-aware warehouse placement: affinity follows the
    /// tenant-aware owning shard; the modulo fallback stays a pure
    /// function of the index (payload striping need not decorrelate —
    /// only the controller home must).
    pub fn warehouse_of_t(&self, index: u64, tenant: u32) -> usize {
        if self.shards > 1 {
            if let Some(w) = self.affinity[self.shard_of_t(index, tenant)] {
                return w;
            }
        }
        (index % self.n_warehouses as u64) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_shard_is_the_modulo_policy() {
        let p = Placement::modulo(4);
        assert_eq!(p.shards(), 1);
        for i in 0..64u64 {
            assert_eq!(p.shard_of(i), 0, "K=1 owns everything on shard 0");
            assert_eq!(
                p.warehouse_of(i),
                (i % 4) as usize,
                "K=1 must reproduce the historical round-robin exactly"
            );
        }
    }

    #[test]
    fn shard_assignment_is_stable_and_in_range() {
        let p = Placement::sharded(4, vec![Some(0), Some(1), Some(2), None]);
        for i in 0..256u64 {
            let s = p.shard_of(i);
            assert!(s < 4);
            assert_eq!(s, p.shard_of(i), "ownership must be a pure function of the index");
        }
    }

    #[test]
    fn shards_all_receive_samples() {
        // the mix must spread sequential indices over every shard — a
        // biased hash would turn "K shards" into one hot shard plus
        // permanent steal traffic
        for k in [2usize, 3, 4, 7] {
            let p = Placement::sharded(k, vec![None; k]);
            let mut counts = vec![0usize; k];
            for i in 0..(k as u64 * 64) {
                counts[p.shard_of(i)] += 1;
            }
            for (s, &c) in counts.iter().enumerate() {
                assert!(c > 16, "shard {s}/{k} starved: {counts:?}");
            }
        }
    }

    #[test]
    fn affinity_routes_to_the_shard_warehouse() {
        let p = Placement::sharded(4, vec![Some(3), Some(1), Some(0), Some(2)]);
        for i in 0..128u64 {
            let expect = [3usize, 1, 0, 2][p.shard_of(i)];
            assert_eq!(p.warehouse_of(i), expect, "payload must follow the owning shard");
        }
    }

    #[test]
    fn missing_affinity_falls_back_to_modulo() {
        let p = Placement::sharded(4, vec![None, None, None]);
        for i in 0..64u64 {
            assert_eq!(p.warehouse_of(i), (i % 4) as usize);
        }
    }

    #[test]
    fn tenant_zero_routing_is_bit_identical_to_tenant_blind() {
        // the pre-tenancy differential pin: default-tenant samples must
        // route exactly as every sample did before tenancy existed
        let p = Placement::sharded(4, vec![Some(0), Some(1), None, Some(3)]);
        for i in 0..256u64 {
            assert_eq!(p.shard_of_t(i, 0), p.shard_of(i));
            assert_eq!(p.warehouse_of_t(i, 0), p.warehouse_of(i));
        }
    }

    #[test]
    fn tenants_decorrelate_the_shard_sequence() {
        // two tenants admitting the same index burst must not land on
        // the same shard sequence — that is the tenant-blind pileup the
        // salt exists to break
        let p = Placement::sharded(4, vec![None; 4]);
        let same = (0..256u64).filter(|&i| p.shard_of_t(i, 1) == p.shard_of_t(i, 2)).count();
        assert!(same < 128, "tenant shard sequences barely differ: {same}/256 identical");
        // and each tenant's own sequence still covers every shard
        for t in [1u32, 2, 3] {
            let mut counts = vec![0usize; 4];
            for i in 0..256u64 {
                counts[p.shard_of_t(i, t)] += 1;
            }
            for (s, &c) in counts.iter().enumerate() {
                assert!(c > 32, "tenant {t} starves shard {s}: {counts:?}");
            }
        }
    }
}
