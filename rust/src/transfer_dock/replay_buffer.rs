//! Centralized replay buffer — the baseline the transfer dock replaces
//! (paper Fig. 2, and the MSRLB configuration of Fig. 9).
//!
//! One store on one node; every worker on every other node pays an
//! inter-node payload transfer for every request, and readiness tracking
//! is a scan of the central map (the congestion the paper's Eq. 2
//! quantifies). Dispatch is lease-based exactly like the dock's (one
//! [`LeaseTable`] per stage against a shared logical clock), so the
//! `SampleFlow` recovery contract holds identically for both dataflows.

use anyhow::{anyhow, Result};
use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::controller::SampleMeta;
use super::lease::{LeaseClock, LeaseTable, DEFAULT_LEASE_TICKS};
use super::network::{CommLedger, LinkClass, SharedLedger};
use super::notify::{wait_ready_impl, Notifier};
use super::sample::{FieldKind, PartialRollout, Sample, Segment, Stage};
use super::warehouse::Conservation;
use super::SampleFlow;
use crate::metrics::FlowRecovery;
use crate::runtime::Tensor;

pub struct ReplayBuffer {
    /// node hosting the buffer (all traffic converges here)
    pub node: usize,
    inner: Mutex<Inner>,
    ledger: SharedLedger,
    next_index: AtomicU64,
    /// wakes blocked stage workers on every state change (wait_ready)
    notify: Notifier,
    clock: Arc<LeaseClock>,
    lease_ticks: u64,
}

#[derive(Default)]
struct Inner {
    samples: BTreeMap<u64, Sample>,
    /// per-stage claim leases (the dock keeps these in its controllers)
    leases: HashMap<Stage, LeaseTable>,
    /// per-stage registered concurrent pullers (fair-share claim cap,
    /// matching the dock controller's semantics)
    pullers: HashMap<Stage, usize>,
    traffic_bytes: u64,
    /// running resident-byte counter + conservation accounting, matching
    /// the warehouse's invariant: admitted == resident + retired
    resident_bytes: u64,
    admitted_bytes: u64,
    retired_bytes: u64,
    superseded: u64,
    /// per-tenant scheduling weights (empty = tenancy off, the
    /// single-tenant degenerate case with the historical scan semantics)
    tenant_weights: BTreeMap<u32, u32>,
    /// claims handed out per tenant — the weighted round robin's deficit
    /// state, shared across stages (the central store has one queue)
    tenant_served: BTreeMap<u32, u64>,
}

impl Inner {
    fn lease(&mut self, stage: Stage) -> &mut LeaseTable {
        self.leases.entry(stage).or_default()
    }
}

impl ReplayBuffer {
    pub fn new(node: usize) -> Self {
        Self::with_lease(node, DEFAULT_LEASE_TICKS)
    }

    /// Build with an explicit claim-lease duration (logical ticks).
    pub fn with_lease(node: usize, lease_ticks: u64) -> Self {
        Self {
            node,
            inner: Mutex::new(Inner::default()),
            ledger: SharedLedger::default(),
            next_index: AtomicU64::new(0),
            notify: Notifier::default(),
            clock: Arc::new(LeaseClock::default()),
            lease_ticks,
        }
    }

    fn link(&self, other: usize) -> LinkClass {
        if other == self.node {
            LinkClass::Local
        } else {
            LinkClass::InterNode
        }
    }

    fn meta_of(s: &Sample) -> SampleMeta {
        SampleMeta {
            index: s.index,
            group: s.group,
            tenant: s.tenant,
            warehouse: 0,
            present: s.present_mask(),
            prompt_len: s.prompt_len as u32,
            resp_len: s.resp_len as u32,
            behavior_version: s.behavior_version,
        }
    }

    /// Scan for ready samples and lease them out; returns the picks plus
    /// how many candidates were scanned (the ledger-cost driver). With
    /// `P > 1` registered pullers the handout is fair-share capped at
    /// `⌈available / P⌉` like the dock controller's — which forces a full
    /// scan (the cap needs the total), the centralized store paying its
    /// readiness-scan tax once more.
    fn scan_ready(&self, stage: Stage, max_n: usize) -> (Vec<SampleMeta>, u64) {
        let now = self.clock.now();
        let mut g = self.inner.lock().unwrap();
        let pullers = g.pullers.get(&stage).copied().unwrap_or(1);
        // tenancy forces a full candidate scan (the deficit round robin
        // needs every backlogged tenant's queue); with weights unset the
        // historical early-break scan — and its scanned-count accounting
        // — is preserved exactly
        let multi_tenant = !g.tenant_weights.is_empty();
        let mut out = Vec::new();
        let mut scanned = 0u64;
        for (&idx, s) in g.samples.iter() {
            scanned += 1;
            if !multi_tenant && pullers <= 1 && out.len() >= max_n {
                break;
            }
            let meta = Self::meta_of(s);
            if meta.ready_for(stage) && !g.leases.get(&stage).is_some_and(|t| t.is_claimed(idx)) {
                out.push(meta);
            }
        }
        let cap = if pullers > 1 {
            max_n.min(out.len().div_ceil(pullers).max(1))
        } else {
            max_n
        };
        let inner = &mut *g;
        if multi_tenant && out.len() > cap {
            // deficit-weighted round robin over the candidates: each pick
            // goes to the backlogged tenant with the smallest
            // served/weight ratio (integer cross-multiplication, ties to
            // the lower tenant id) — identical policy to the dock
            // controller's handout
            let mut queues: BTreeMap<u32, Vec<SampleMeta>> = BTreeMap::new();
            for m in out.drain(..) {
                queues.entry(m.tenant).or_default().push(m);
            }
            let mut cursors: BTreeMap<u32, usize> = BTreeMap::new();
            while out.len() < cap {
                let mut best: Option<(u32, u64, u64)> = None;
                for (&t, q) in queues.iter() {
                    if cursors.get(&t).copied().unwrap_or(0) >= q.len() {
                        continue;
                    }
                    let served = inner.tenant_served.get(&t).copied().unwrap_or(0);
                    let weight = inner.tenant_weights.get(&t).copied().unwrap_or(1) as u64;
                    let better = match best {
                        None => true,
                        Some((_, bs, bw)) => served * bw < bs * weight,
                    };
                    if better {
                        best = Some((t, served, weight));
                    }
                }
                let Some((t, _, _)) = best else { break };
                let cur = cursors.entry(t).or_insert(0);
                out.push(queues[&t][*cur]);
                *cur += 1;
                *inner.tenant_served.entry(t).or_insert(0) += 1;
            }
        } else {
            out.truncate(cap);
            for m in &out {
                *inner.tenant_served.entry(m.tenant).or_insert(0) += 1;
            }
        }
        let ticks = self.lease_ticks;
        let table = g.lease(stage);
        for m in &out {
            table.claim(m.index, now, ticks);
        }
        (out, scanned)
    }

    /// Consume a finished sample (post-update).
    fn retire_inner(&self, index: u64) -> Option<Sample> {
        let mut g = self.inner.lock().unwrap();
        for st in Stage::ALL {
            g.lease(st).forget(index);
        }
        let s = g.samples.remove(&index)?;
        let bytes = s.payload_bytes() as u64;
        g.resident_bytes -= bytes;
        g.retired_bytes += bytes;
        Some(s)
    }

    /// Byte-conservation snapshot of the central store.
    pub fn conservation(&self) -> Conservation {
        let g = self.inner.lock().unwrap();
        debug_assert_eq!(
            g.resident_bytes,
            g.samples.values().map(|s| s.payload_bytes() as u64).sum::<u64>(),
            "replay buffer: resident-byte counter diverged from the scan"
        );
        Conservation {
            admitted_bytes: g.admitted_bytes,
            resident_bytes: g.resident_bytes,
            retired_bytes: g.retired_bytes,
        }
    }

    /// Stale writebacks dropped (first-writer-wins / post-retire).
    pub fn superseded_writebacks(&self) -> u64 {
        self.inner.lock().unwrap().superseded
    }

    /// The single writeback path: merge fields (plus the generation
    /// completion when present) under the lease rules — missing samples
    /// and duplicate generations are dropped as superseded, completed
    /// claims clear their lease, still-ready claimed samples get a lease
    /// renewal (writeback activity is liveness evidence).
    fn writeback(
        &self,
        requester_node: usize,
        index: u64,
        fields: Vec<(FieldKind, Tensor)>,
        completion: Option<(String, usize, u64)>,
        segments: Vec<Segment>,
    ) -> Result<()> {
        let now = self.clock.now();
        let mut g = self.inner.lock().unwrap();
        let field_bytes: u64 = fields.iter().map(|(_, t)| t.size_bytes() as u64).sum();
        let wire_bytes = field_bytes + (segments.len() * Segment::WIRE_BYTES) as u64;
        self.ledger.record(self.link(requester_node), wire_bytes);
        self.ledger.note_requests_on(self.link(requester_node), 1);
        g.traffic_bytes += wire_bytes;
        let stale = match g.samples.get(&index) {
            None => true,
            Some(s) => completion.is_some() && s.has(FieldKind::Tokens),
        };
        if stale {
            // staleness requires a reclaim, and reclaims require ticks —
            // in a never-ticked flow a dropped writeback is a caller bug,
            // so keep it loud in debug builds (mirrors the dock)
            debug_assert!(
                self.clock.now() > 0,
                "writeback for sample {index} dropped as superseded, but this \
                 flow's lease clock never ticked (no reclaim can have happened \
                 — wrong index or write-after-retire at the call site?)"
            );
            g.superseded += 1;
            return Ok(());
        }
        // residency deltas can differ from wire bytes: a completing
        // writeback with no explicit segments stores a synthesized
        // full-span segment (see the warehouse's store for the rationale)
        let mut added: u64 = field_bytes;
        let mut overwritten: u64 = 0;
        let s = g.samples.get_mut(&index).expect("residency checked above");
        for (k, t) in fields {
            if let Some(old) = s.get(k) {
                overwritten += old.size_bytes() as u64;
            }
            s.put(k, t);
        }
        if let Some((text, resp_len, behavior_version)) = completion {
            s.completion_text = text;
            s.resp_len = resp_len;
            s.behavior_version = behavior_version;
            let segs = if segments.is_empty() && resp_len > 0 {
                vec![Segment { start: 0, len: resp_len, version: behavior_version }]
            } else {
                segments
            };
            added += (segs.len() * Segment::WIRE_BYTES) as u64;
            overwritten += (s.segments.len() * Segment::WIRE_BYTES) as u64;
            s.segments = segs;
            if let Some(p) = s.partial.take() {
                overwritten += p.payload_bytes() as u64;
            }
        }
        let meta = Self::meta_of(s);
        g.resident_bytes += added;
        g.resident_bytes -= overwritten;
        g.admitted_bytes += added;
        g.retired_bytes += overwritten;
        // clear leases only for stages this write completed; a cross-stage
        // write must not re-dispatch an outstanding claim, but it renews
        // the claim's lease (the sample is visibly alive)
        let ticks = self.lease_ticks;
        for st in Stage::ALL {
            let table = g.lease(st);
            if !meta.ready_for(st) {
                table.complete(index);
            } else if table.is_claimed(index) {
                table.renew(index, now, ticks);
            }
        }
        self.ledger.note_store_bytes(g.traffic_bytes);
        drop(g);
        self.notify.notify();
        Ok(())
    }
}

impl SampleFlow for ReplayBuffer {
    fn put_samples(&self, samples: Vec<Sample>) -> Result<Vec<u64>> {
        let mut g = self.inner.lock().unwrap();
        let mut out = Vec::with_capacity(samples.len());
        for mut s in samples {
            let index = self.next_index.fetch_add(1, Ordering::Relaxed);
            s.index = index;
            // ingest from node 0's data loader to the buffer node
            let bytes = s.payload_bytes() as u64;
            self.ledger.record(self.link(0), bytes);
            self.ledger.note_requests_on(self.link(0), 1);
            g.traffic_bytes += bytes;
            g.resident_bytes += bytes;
            g.admitted_bytes += bytes;
            g.samples.insert(index, s);
            out.push(index);
        }
        self.ledger.note_store_bytes(g.traffic_bytes);
        drop(g);
        self.notify.notify();
        Ok(out)
    }

    fn wait_ready(
        &self,
        stage: Stage,
        max_n: usize,
        timeout: std::time::Duration,
    ) -> Result<Vec<SampleMeta>> {
        // like the dock: only a successful claim pays the metadata
        // round-trip; empty wakeup re-polls are not wire traffic, so
        // dispatch accounting stays a function of data movement rather
        // than of wall-clock time spent blocked
        wait_ready_impl(&self.notify, timeout, || {
            let (out, scanned) = self.scan_ready(stage, max_n);
            if !out.is_empty() {
                self.ledger
                    .record(LinkClass::InterNode, (scanned + 1) * SampleMeta::WIRE_BYTES);
                self.ledger.note_requests_on(LinkClass::InterNode, 1);
            }
            Ok(out)
        })
    }

    fn release(&self, stage: Stage, indices: &[u64]) {
        let mut g = self.inner.lock().unwrap();
        for &i in indices {
            g.lease(stage).release(i);
        }
        drop(g);
        self.notify.notify();
    }

    fn tick_lease_clock(&self) -> usize {
        let now = self.clock.advance();
        let mut g = self.inner.lock().unwrap();
        let mut reclaimed = 0;
        for st in Stage::ALL {
            reclaimed += g.lease(st).expire(now).len();
        }
        drop(g);
        self.notify.notify_if(reclaimed > 0);
        reclaimed
    }

    fn lease_now(&self) -> u64 {
        self.clock.now()
    }

    fn renew(&self, stage: Stage, indices: &[u64]) {
        let now = self.clock.now();
        let mut g = self.inner.lock().unwrap();
        let ticks = self.lease_ticks;
        let table = g.lease(stage);
        for &i in indices {
            table.renew(i, now, ticks);
        }
    }

    fn lease_stats(&self) -> FlowRecovery {
        let mut g = self.inner.lock().unwrap();
        let mut out = FlowRecovery::default();
        for st in Stage::ALL {
            out.merge(&g.lease(st).stats());
        }
        out.superseded_writebacks = g.superseded;
        out
    }

    fn ready_depth(&self, stage: Stage) -> usize {
        // Control-plane introspection for the driving executor: no
        // claims, no ledger charge (symmetric with the dock's counter).
        // O(resident) scan, but residency is bounded by the admission
        // window (max_inflight × G × N samples), not the run length —
        // and the central store pays a scan per readiness query anyway;
        // that asymmetry vs the dock's O(1) counter IS the baseline's
        // modeled cost.
        let g = self.inner.lock().unwrap();
        g.samples
            .values()
            .filter(|s| {
                Self::meta_of(s).ready_for(stage)
                    && !g.leases.get(&stage).is_some_and(|t| t.is_claimed(s.index))
            })
            .count()
    }

    fn note_pullers(&self, stage: Stage, n: usize) {
        self.inner.lock().unwrap().pullers.insert(stage, n.max(1));
    }

    fn set_tenant_weights(&self, weights: &[(u32, u32)]) {
        let mut g = self.inner.lock().unwrap();
        g.tenant_weights = weights.iter().map(|&(t, w)| (t, w.max(1))).collect();
        g.tenant_served.clear();
    }

    fn tenant_claims(&self) -> Vec<(u32, u64)> {
        let g = self.inner.lock().unwrap();
        g.tenant_served.iter().map(|(&t, &n)| (t, n)).collect()
    }

    fn request_ready(&self, stage: Stage, max_n: usize) -> Result<Vec<SampleMeta>> {
        // a centralized buffer must answer readiness queries itself: the
        // requester pays a metadata round-trip per *candidate scanned*,
        // not per ready sample — this is the dispatch-overhead term
        let (out, scanned) = self.scan_ready(stage, max_n);
        self.ledger
            .record(LinkClass::InterNode, (scanned + 1) * SampleMeta::WIRE_BYTES);
        // readiness queries come from workers anywhere in the cluster
        self.ledger.note_requests_on(LinkClass::InterNode, 1);
        Ok(out)
    }

    fn try_claim(&self, stage: Stage, max_n: usize) -> Result<Vec<SampleMeta>> {
        // same charging rule as `wait_ready`: a streaming scheduler polls
        // between decode steps, and only a successful handout (scan that
        // found work) is a dispatch event
        let (out, scanned) = self.scan_ready(stage, max_n);
        if !out.is_empty() {
            self.ledger
                .record(LinkClass::InterNode, (scanned + 1) * SampleMeta::WIRE_BYTES);
            self.ledger.note_requests_on(LinkClass::InterNode, 1);
        }
        Ok(out)
    }

    fn fetch(&self, requester_node: usize, metas: &[SampleMeta]) -> Result<Vec<Sample>> {
        self.ledger.note_requests_on(self.link(requester_node), 1);
        let mut g = self.inner.lock().unwrap();
        let mut out = Vec::with_capacity(metas.len());
        for m in metas {
            let s = g
                .samples
                .get(&m.index)
                .ok_or_else(|| anyhow!("replay buffer: no sample {}", m.index))?
                .clone();
            self.ledger.record(self.link(requester_node), s.payload_bytes() as u64);
            g.traffic_bytes += s.payload_bytes() as u64;
            out.push(s);
        }
        self.ledger.note_store_bytes(g.traffic_bytes);
        Ok(out)
    }

    fn fetch_resident(&self, requester_node: usize, metas: &[SampleMeta]) -> Result<Vec<Sample>> {
        self.ledger.note_requests_on(self.link(requester_node), 1);
        let mut g = self.inner.lock().unwrap();
        let mut out = Vec::with_capacity(metas.len());
        for m in metas {
            // a missing sample is a stale claim, not an error
            let Some(s) = g.samples.get(&m.index).cloned() else { continue };
            self.ledger.record(self.link(requester_node), s.payload_bytes() as u64);
            g.traffic_bytes += s.payload_bytes() as u64;
            out.push(s);
        }
        self.ledger.note_store_bytes(g.traffic_bytes);
        Ok(out)
    }

    fn store_fields(
        &self,
        requester_node: usize,
        index: u64,
        fields: Vec<(FieldKind, Tensor)>,
    ) -> Result<()> {
        self.writeback(requester_node, index, fields, None, Vec::new())
    }

    fn store_generation(
        &self,
        requester_node: usize,
        index: u64,
        fields: Vec<(FieldKind, Tensor)>,
        completion: String,
        resp_len: usize,
        behavior_version: u64,
    ) -> Result<()> {
        self.writeback(
            requester_node,
            index,
            fields,
            Some((completion, resp_len, behavior_version)),
            Vec::new(),
        )
    }

    fn store_generation_with_segments(
        &self,
        requester_node: usize,
        index: u64,
        fields: Vec<(FieldKind, Tensor)>,
        completion: String,
        resp_len: usize,
        behavior_version: u64,
        segments: Vec<Segment>,
    ) -> Result<()> {
        self.writeback(
            requester_node,
            index,
            fields,
            Some((completion, resp_len, behavior_version)),
            segments,
        )
    }

    /// Persist an interrupted generation's prefix (mirrors the dock:
    /// longest-prefix-wins, never after the final response, no lease
    /// changes — a dead worker's checkpoint must not delay its reclaim).
    fn store_partial_generation(
        &self,
        requester_node: usize,
        index: u64,
        partial: PartialRollout,
    ) -> Result<()> {
        anyhow::ensure!(
            partial.well_formed(),
            "replay buffer: malformed partial rollout for sample {index}"
        );
        let mut g = self.inner.lock().unwrap();
        let new_bytes = partial.payload_bytes() as u64;
        self.ledger.record(self.link(requester_node), new_bytes);
        self.ledger.note_requests_on(self.link(requester_node), 1);
        g.traffic_bytes += new_bytes;
        let stale = match g.samples.get(&index) {
            None => true,
            Some(s) => {
                s.has(FieldKind::Tokens)
                    || s.partial.as_ref().is_some_and(|p| p.token_len() >= partial.token_len())
            }
        };
        if stale {
            g.superseded += 1;
            return Ok(());
        }
        let s = g.samples.get_mut(&index).expect("residency checked above");
        let old_bytes = s.partial.replace(partial).map_or(0, |p| p.payload_bytes() as u64);
        g.resident_bytes += new_bytes;
        g.resident_bytes -= old_bytes;
        g.admitted_bytes += new_bytes;
        g.retired_bytes += old_bytes;
        self.ledger.note_store_bytes(g.traffic_bytes);
        Ok(())
    }

    fn retire(&self, index: u64) -> Option<Sample> {
        let out = self.retire_inner(index);
        self.notify.notify();
        out
    }

    fn ledger(&self) -> CommLedger {
        self.ledger.snapshot()
    }

    fn shards(&self) -> usize {
        1
    }

    fn len(&self) -> usize {
        self.inner.lock().unwrap().samples.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn prompts(n: usize) -> Vec<Sample> {
        (0..n)
            .map(|i| Sample::new_prompt(u64::MAX, 0, format!("{i}+1="), i as i64 + 1))
            .collect()
    }

    #[test]
    fn same_lifecycle_as_dock() {
        let rb = ReplayBuffer::new(0);
        let idx = rb.put_samples(prompts(2)).unwrap();
        let metas = rb.request_ready(Stage::Generation, 10).unwrap();
        assert_eq!(metas.len(), 2);
        rb.store_generation(
            1,
            idx[0],
            vec![(FieldKind::Tokens, Tensor::i32(&[4], vec![1; 4]).unwrap())],
            "2".into(),
            1,
            3,
        )
        .unwrap();
        let ready = rb.request_ready(Stage::RefLogprob, 10).unwrap();
        assert_eq!(ready.len(), 1);
        assert_eq!(ready[0].behavior_version, 3, "stamp must round-trip the central store");
    }

    #[test]
    fn all_traffic_hits_one_store() {
        let rb = ReplayBuffer::new(0);
        rb.put_samples(prompts(8)).unwrap();
        let metas = rb.request_ready(Stage::Generation, 8).unwrap();
        rb.fetch(3, &metas).unwrap(); // remote worker
        let led = rb.ledger();
        assert!(led.inter_node_bytes > 0);
        assert!(led.max_store_bytes >= led.inter_node_bytes / 2);
    }

    #[test]
    fn readiness_scan_costs_metadata_bytes() {
        let rb = ReplayBuffer::new(0);
        rb.put_samples(prompts(100)).unwrap();
        let before = rb.ledger().inter_node_bytes;
        rb.request_ready(Stage::Update, 4).unwrap();
        let after = rb.ledger().inter_node_bytes;
        // scanning 100 unready samples costs ~100 metadata records
        assert!(after - before >= 100 * SampleMeta::WIRE_BYTES);
    }

    #[test]
    fn lease_expiry_matches_dock_semantics() {
        let rb = ReplayBuffer::with_lease(0, 2);
        rb.put_samples(prompts(2)).unwrap();
        assert_eq!(rb.request_ready(Stage::Generation, 10).unwrap().len(), 2);
        assert!(rb.request_ready(Stage::Generation, 10).unwrap().is_empty());
        assert_eq!(rb.tick_lease_clock(), 0);
        assert_eq!(rb.tick_lease_clock(), 2);
        assert_eq!(rb.request_ready(Stage::Generation, 10).unwrap().len(), 2);
        let s = rb.lease_stats();
        assert_eq!(s.reclaimed, 2);
        assert_eq!(s.redispatched, 2);
        assert!(s.consistent());
    }

    #[test]
    fn duplicate_generation_and_post_retire_writebacks_drop() {
        let rb = ReplayBuffer::new(0);
        // a ticked clock marks this as a lease-driven flow (stale
        // writebacks are a legitimate possibility, not a caller bug)
        rb.tick_lease_clock();
        let idx = rb.put_samples(prompts(1)).unwrap()[0];
        rb.store_generation(
            0,
            idx,
            vec![(FieldKind::Tokens, Tensor::i32(&[4], vec![1; 4]).unwrap())],
            "first".into(),
            1,
            3,
        )
        .unwrap();
        rb.store_generation(
            0,
            idx,
            vec![(FieldKind::Tokens, Tensor::i32(&[4], vec![9; 4]).unwrap())],
            "late".into(),
            2,
            9,
        )
        .unwrap();
        let s = rb.fetch(0, &rb.request_ready(Stage::Reward, 1).unwrap()).unwrap();
        assert_eq!(s[0].completion_text, "first", "first generation must win");
        assert_eq!(s[0].behavior_version, 3);
        assert!(rb.retire(idx).is_some());
        rb.store_fields(0, idx, vec![(FieldKind::Reward, Tensor::scalar_f32(1.0))]).unwrap();
        assert_eq!(rb.superseded_writebacks(), 2);
        assert!(rb.conservation().holds());
    }
}
