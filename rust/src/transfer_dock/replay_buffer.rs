//! Centralized replay buffer — the baseline the transfer dock replaces
//! (paper Fig. 2, and the MSRLB configuration of Fig. 9).
//!
//! One store on one node; every worker on every other node pays an
//! inter-node payload transfer for every request, and readiness tracking
//! is a scan of the central map (the congestion the paper's Eq. 2
//! quantifies).

use anyhow::{anyhow, Result};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use super::controller::SampleMeta;
use super::network::{CommLedger, LinkClass, SharedLedger};
use super::notify::{wait_ready_impl, Notifier};
use super::sample::{FieldKind, Sample, Stage};
use super::SampleFlow;
use crate::runtime::Tensor;

pub struct ReplayBuffer {
    /// node hosting the buffer (all traffic converges here)
    pub node: usize,
    inner: Mutex<Inner>,
    ledger: SharedLedger,
    next_index: AtomicU64,
    /// wakes blocked stage workers on every state change (wait_ready)
    notify: Notifier,
}

#[derive(Default)]
struct Inner {
    samples: BTreeMap<u64, Sample>,
    in_flight: std::collections::HashSet<(Stage, u64)>,
    traffic_bytes: u64,
}

impl ReplayBuffer {
    pub fn new(node: usize) -> Self {
        Self {
            node,
            inner: Mutex::new(Inner::default()),
            ledger: SharedLedger::default(),
            next_index: AtomicU64::new(0),
            notify: Notifier::default(),
        }
    }

    fn link(&self, other: usize) -> LinkClass {
        if other == self.node {
            LinkClass::Local
        } else {
            LinkClass::InterNode
        }
    }

    fn meta_of(s: &Sample) -> SampleMeta {
        SampleMeta {
            index: s.index,
            group: s.group,
            warehouse: 0,
            present: s.present_mask(),
            prompt_len: s.prompt_len as u32,
            resp_len: s.resp_len as u32,
            behavior_version: s.behavior_version,
        }
    }

    /// Scan for ready samples and latch them in-flight; returns the picks
    /// plus how many candidates were scanned (the ledger-cost driver).
    fn scan_ready(&self, stage: Stage, max_n: usize) -> (Vec<SampleMeta>, u64) {
        let mut g = self.inner.lock().unwrap();
        let mut out = Vec::new();
        let mut scanned = 0u64;
        let mut picked = Vec::new();
        for (&idx, s) in g.samples.iter() {
            scanned += 1;
            if out.len() >= max_n {
                break;
            }
            let meta = Self::meta_of(s);
            if meta.ready_for(stage) && !g.in_flight.contains(&(stage, idx)) {
                out.push(meta);
                picked.push(idx);
            }
        }
        for idx in picked {
            g.in_flight.insert((stage, idx));
        }
        (out, scanned)
    }

    /// Consume a finished sample (post-update).
    fn retire_inner(&self, index: u64) -> Option<Sample> {
        let mut g = self.inner.lock().unwrap();
        for st in Stage::ALL {
            g.in_flight.remove(&(st, index));
        }
        g.samples.remove(&index)
    }
}

impl SampleFlow for ReplayBuffer {
    fn put_samples(&self, samples: Vec<Sample>) -> Result<Vec<u64>> {
        let mut g = self.inner.lock().unwrap();
        let mut out = Vec::with_capacity(samples.len());
        for mut s in samples {
            let index = self.next_index.fetch_add(1, Ordering::Relaxed);
            s.index = index;
            // ingest from node 0's data loader to the buffer node
            self.ledger.record(self.link(0), s.payload_bytes() as u64);
            self.ledger.note_requests_on(self.link(0), 1);
            g.traffic_bytes += s.payload_bytes() as u64;
            g.samples.insert(index, s);
            out.push(index);
        }
        self.ledger.note_store_bytes(g.traffic_bytes);
        drop(g);
        self.notify.notify();
        Ok(out)
    }

    fn wait_ready(
        &self,
        stage: Stage,
        max_n: usize,
        timeout: std::time::Duration,
    ) -> Result<Vec<SampleMeta>> {
        // like the dock: only a successful claim pays the metadata
        // round-trip; empty wakeup re-polls are not wire traffic, so
        // dispatch accounting stays a function of data movement rather
        // than of wall-clock time spent blocked
        wait_ready_impl(&self.notify, timeout, || {
            let (out, scanned) = self.scan_ready(stage, max_n);
            if !out.is_empty() {
                self.ledger
                    .record(LinkClass::InterNode, (scanned + 1) * SampleMeta::WIRE_BYTES);
                self.ledger.note_requests_on(LinkClass::InterNode, 1);
            }
            Ok(out)
        })
    }

    fn release(&self, stage: Stage, indices: &[u64]) {
        let mut g = self.inner.lock().unwrap();
        for &i in indices {
            g.in_flight.remove(&(stage, i));
        }
        drop(g);
        self.notify.notify();
    }

    fn request_ready(&self, stage: Stage, max_n: usize) -> Result<Vec<SampleMeta>> {
        // a centralized buffer must answer readiness queries itself: the
        // requester pays a metadata round-trip per *candidate scanned*,
        // not per ready sample — this is the dispatch-overhead term
        let (out, scanned) = self.scan_ready(stage, max_n);
        self.ledger
            .record(LinkClass::InterNode, (scanned + 1) * SampleMeta::WIRE_BYTES);
        // readiness queries come from workers anywhere in the cluster
        self.ledger.note_requests_on(LinkClass::InterNode, 1);
        Ok(out)
    }

    fn fetch(&self, requester_node: usize, metas: &[SampleMeta]) -> Result<Vec<Sample>> {
        self.ledger.note_requests_on(self.link(requester_node), 1);
        let mut g = self.inner.lock().unwrap();
        let mut out = Vec::with_capacity(metas.len());
        for m in metas {
            let s = g
                .samples
                .get(&m.index)
                .ok_or_else(|| anyhow!("replay buffer: no sample {}", m.index))?
                .clone();
            self.ledger.record(self.link(requester_node), s.payload_bytes() as u64);
            g.traffic_bytes += s.payload_bytes() as u64;
            out.push(s);
        }
        self.ledger.note_store_bytes(g.traffic_bytes);
        Ok(out)
    }

    fn store_fields(
        &self,
        requester_node: usize,
        index: u64,
        fields: Vec<(FieldKind, Tensor)>,
    ) -> Result<()> {
        let mut g = self.inner.lock().unwrap();
        let bytes: u64 = fields.iter().map(|(_, t)| t.size_bytes() as u64).sum();
        self.ledger.record(self.link(requester_node), bytes);
        self.ledger.note_requests_on(self.link(requester_node), 1);
        g.traffic_bytes += bytes;
        let s = g
            .samples
            .get_mut(&index)
            .ok_or_else(|| anyhow!("replay buffer: no sample {index}"))?;
        for (k, t) in fields {
            s.put(k, t);
        }
        // clear in-flight latches only for stages this write completed —
        // a cross-stage write must not re-dispatch an outstanding claim
        let meta = Self::meta_of(s);
        for st in Stage::ALL {
            if !meta.ready_for(st) {
                g.in_flight.remove(&(st, index));
            }
        }
        self.ledger.note_store_bytes(g.traffic_bytes);
        drop(g);
        self.notify.notify();
        Ok(())
    }

    fn store_generation(
        &self,
        requester_node: usize,
        index: u64,
        fields: Vec<(FieldKind, Tensor)>,
        completion: String,
        resp_len: usize,
        behavior_version: u64,
    ) -> Result<()> {
        self.store_generation_inner(
            requester_node,
            index,
            fields,
            completion,
            resp_len,
            behavior_version,
        )
    }

    fn retire(&self, index: u64) -> Option<Sample> {
        let out = self.retire_inner(index);
        self.notify.notify();
        out
    }

    fn ledger(&self) -> CommLedger {
        self.ledger.snapshot()
    }

    fn shards(&self) -> usize {
        1
    }

    fn len(&self) -> usize {
        self.inner.lock().unwrap().samples.len()
    }
}

impl ReplayBuffer {
    /// Generation-stage writeback including the completion text and the
    /// behavior-policy version stamp.
    fn store_generation_inner(
        &self,
        requester_node: usize,
        index: u64,
        fields: Vec<(FieldKind, Tensor)>,
        completion: String,
        resp_len: usize,
        behavior_version: u64,
    ) -> Result<()> {
        {
            let mut g = self.inner.lock().unwrap();
            let s = g
                .samples
                .get_mut(&index)
                .ok_or_else(|| anyhow!("replay buffer: no sample {index}"))?;
            s.completion_text = completion;
            s.resp_len = resp_len;
            s.behavior_version = behavior_version;
        }
        self.store_fields(requester_node, index, fields)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn prompts(n: usize) -> Vec<Sample> {
        (0..n)
            .map(|i| Sample::new_prompt(u64::MAX, 0, format!("{i}+1="), i as i64 + 1))
            .collect()
    }

    #[test]
    fn same_lifecycle_as_dock() {
        let rb = ReplayBuffer::new(0);
        let idx = rb.put_samples(prompts(2)).unwrap();
        let metas = rb.request_ready(Stage::Generation, 10).unwrap();
        assert_eq!(metas.len(), 2);
        rb.store_generation(
            1,
            idx[0],
            vec![(FieldKind::Tokens, Tensor::i32(&[4], vec![1; 4]).unwrap())],
            "2".into(),
            1,
            3,
        )
        .unwrap();
        let ready = rb.request_ready(Stage::RefLogprob, 10).unwrap();
        assert_eq!(ready.len(), 1);
        assert_eq!(ready[0].behavior_version, 3, "stamp must round-trip the central store");
    }

    #[test]
    fn all_traffic_hits_one_store() {
        let rb = ReplayBuffer::new(0);
        rb.put_samples(prompts(8)).unwrap();
        let metas = rb.request_ready(Stage::Generation, 8).unwrap();
        rb.fetch(3, &metas).unwrap(); // remote worker
        let led = rb.ledger();
        assert!(led.inter_node_bytes > 0);
        assert!(led.max_store_bytes >= led.inter_node_bytes / 2);
    }

    #[test]
    fn readiness_scan_costs_metadata_bytes() {
        let rb = ReplayBuffer::new(0);
        rb.put_samples(prompts(100)).unwrap();
        let before = rb.ledger().inter_node_bytes;
        rb.request_ready(Stage::Update, 4).unwrap();
        let after = rb.ledger().inter_node_bytes;
        // scanning 100 unready samples costs ~100 metadata records
        assert!(after - before >= 100 * SampleMeta::WIRE_BYTES);
    }
}
