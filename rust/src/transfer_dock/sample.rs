//! The TensorDict-like sample record that flows through the system.

use std::collections::BTreeMap;

use crate::runtime::Tensor;

/// Worker states, each of which owns a TD controller (paper Fig. 4: the
/// number of controllers C is set by the RL algorithm; GRPO has 5).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Stage {
    /// actor generation: prompt → response tokens
    Generation,
    /// actor inference: old-policy log-probs of the response
    OldLogprob,
    /// reference inference: reference log-probs
    RefLogprob,
    /// rule reward scoring
    Reward,
    /// actor update: consume the finished sample
    Update,
}

impl Stage {
    pub const ALL: [Stage; 5] = [
        Stage::Generation,
        Stage::OldLogprob,
        Stage::RefLogprob,
        Stage::Reward,
        Stage::Update,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Stage::Generation => "generation",
            Stage::OldLogprob => "old_logprob",
            Stage::RefLogprob => "ref_logprob",
            Stage::Reward => "reward",
            Stage::Update => "update",
        }
    }
}

/// Tensor fields a sample accumulates as it flows through stages.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum FieldKind {
    /// prompt+response token ids `[S] i32` (padded)
    Tokens,
    /// response mask `[S-1] f32`
    RespMask,
    /// old-policy per-token log-probs `[S-1] f32`
    OldLp,
    /// reference per-token log-probs `[S-1] f32`
    RefLp,
    /// scalar rule reward
    Reward,
    /// scalar group-normalized advantage
    Advantage,
}

/// Field production order used for readiness bitmasks.
pub const FIELD_ORDER: [FieldKind; 6] = [
    FieldKind::Tokens,
    FieldKind::RespMask,
    FieldKind::OldLp,
    FieldKind::RefLp,
    FieldKind::Reward,
    FieldKind::Advantage,
];

impl FieldKind {
    pub fn bit(&self) -> u8 {
        1 << FIELD_ORDER.iter().position(|f| f == self).unwrap()
    }

    pub fn name(&self) -> &'static str {
        match self {
            FieldKind::Tokens => "tokens",
            FieldKind::RespMask => "resp_mask",
            FieldKind::OldLp => "old_lp",
            FieldKind::RefLp => "ref_lp",
            FieldKind::Reward => "reward",
            FieldKind::Advantage => "advantage",
        }
    }
}

/// One contiguous span of response tokens decoded under a single weight
/// version. A sample generated without interruption has exactly one
/// segment covering the whole response; a partial rollout that was
/// preempted/reclaimed and resumed under a newer publish accumulates one
/// segment per behavior version it was decoded under. Spans are in
/// response-token coordinates (`start`/`len` index into the response,
/// not the padded sequence).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Segment {
    pub start: usize,
    pub len: usize,
    /// weight version the tokens of this span were sampled under
    pub version: u64,
}

impl Segment {
    /// Nominal wire size of one segment record: 3 scalars × 4 bytes
    /// (same convention as [`Sample::scalar_bytes`]).
    pub const WIRE_BYTES: usize = 12;

    pub fn end(&self) -> usize {
        self.start + self.len
    }
}

/// Append a span to a segment list, merging into the last segment when
/// it is contiguous and decoded under the same version (checkpoint
/// persists within one lease would otherwise fragment the list).
pub fn push_segment(segments: &mut Vec<Segment>, start: usize, len: usize, version: u64) {
    if len == 0 {
        return;
    }
    if let Some(last) = segments.last_mut() {
        if last.version == version && last.end() == start {
            last.len += len;
            return;
        }
    }
    segments.push(Segment { start, len, version });
}

/// The decoded prefix of an interrupted generation, persisted through the
/// dock as first-class partial state so a redispatch resumes from here
/// instead of regenerating from the prompt. `segments` always covers
/// `[0, response_ids.len())` exactly, each span stamped with the weight
/// version it was decoded under.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PartialRollout {
    pub response_ids: Vec<i32>,
    /// behavior log-prob captured at sampling time, one per token
    pub response_logprobs: Vec<f32>,
    pub segments: Vec<Segment>,
}

impl PartialRollout {
    pub fn token_len(&self) -> usize {
        self.response_ids.len()
    }

    /// Payload bytes this prefix occupies in a warehouse (tokens i32 +
    /// logprobs f32 + segment records).
    pub fn payload_bytes(&self) -> usize {
        self.response_ids.len() * 4
            + self.response_logprobs.len() * 4
            + self.segments.len() * Segment::WIRE_BYTES
    }

    /// Internal consistency: segments tile the prefix exactly and the
    /// logprob stream is token-aligned.
    pub fn well_formed(&self) -> bool {
        if self.response_logprobs.len() != self.response_ids.len() {
            return false;
        }
        let mut at = 0usize;
        for s in &self.segments {
            if s.start != at || s.len == 0 {
                return false;
            }
            at = s.end();
        }
        at == self.response_ids.len()
    }
}

/// One RL sample (a prompt with one generated response and its transient
/// data). The paper implements this as a Ray TensorDict; here it is a
/// plain map of named host tensors plus scalar metadata.
#[derive(Debug, Clone)]
pub struct Sample {
    pub index: u64,
    /// group id: samples of the same prompt share it (GRPO group)
    pub group: u64,
    /// tenant job this sample belongs to (0 = the default single-tenant
    /// job). Assigned at admission and immutable for the sample's
    /// lifetime; routed on every metadata broadcast so claim handouts
    /// can be weighted-fair across tenants and memory charges can be
    /// attributed per tenant.
    pub tenant: u32,
    pub prompt_len: usize,
    pub resp_len: usize,
    /// weight version active when this sample's response was generated
    /// (the behavior policy's identity; 0 = not yet generated/stamped).
    /// Stamped by the generation writeback and carried on every metadata
    /// broadcast so the old-logprob stage can score under the true
    /// behavior policy instead of the weight-bus head. For a
    /// multi-segment sample this is the version of the *final* segment;
    /// `segments` carries the full per-span history.
    pub behavior_version: u64,
    pub prompt_text: String,
    pub answer: i64,
    pub completion_text: String,
    /// decoded prefix of an interrupted generation (present only between
    /// an interruption and the final generation writeback, which clears
    /// it); travels with every fetch so a redispatched claim can resume
    pub partial: Option<PartialRollout>,
    /// per-version spans of the finished response, stamped at the final
    /// generation writeback (single full-span segment for uninterrupted
    /// samples); the old-logprob stage scores each span under its own
    /// version
    pub segments: Vec<Segment>,
    pub fields: BTreeMap<FieldKind, Tensor>,
}

impl Sample {
    pub fn new_prompt(index: u64, group: u64, prompt_text: String, answer: i64) -> Self {
        Self {
            index,
            group,
            tenant: 0,
            prompt_len: prompt_text.len() + 1, // + BOS
            resp_len: 0,
            behavior_version: 0,
            prompt_text,
            answer,
            completion_text: String::new(),
            partial: None,
            segments: Vec::new(),
            fields: BTreeMap::new(),
        }
    }

    /// Builder-style tenant assignment (admission-time only).
    pub fn with_tenant(mut self, tenant: u32) -> Self {
        self.tenant = tenant;
        self
    }

    pub fn put(&mut self, kind: FieldKind, t: Tensor) {
        self.fields.insert(kind, t);
    }

    pub fn get(&self, kind: FieldKind) -> Option<&Tensor> {
        self.fields.get(&kind)
    }

    pub fn has(&self, kind: FieldKind) -> bool {
        self.fields.contains_key(&kind)
    }

    /// Bitmask of present fields (mirrors controller metadata).
    pub fn present_mask(&self) -> u8 {
        self.fields.keys().fold(0u8, |m, k| m | k.bit())
    }

    /// Payload bytes (the `CV` term of Eq. 1: tokens + n·SL items +
    /// scalars, plus any persisted partial prefix and segment records —
    /// partial state is first-class payload, so warehouse byte
    /// conservation covers it too).
    pub fn payload_bytes(&self) -> usize {
        let tensor_bytes: usize = self.fields.values().map(|t| t.size_bytes()).sum();
        let partial_bytes = self.partial.as_ref().map_or(0, |p| p.payload_bytes());
        let segment_bytes = self.segments.len() * Segment::WIRE_BYTES;
        tensor_bytes + partial_bytes + segment_bytes + self.scalar_bytes()
    }

    /// Scalar metadata bytes (the `M` term of Eq. 1): index, group,
    /// tenant, prompt_len, resp_len, answer, behavior_version —
    /// 7 scalars × 4 bytes nominal.
    pub fn scalar_bytes(&self) -> usize {
        7 * 4
    }

    /// Which stages still need to produce data for this sample.
    pub fn next_stages(&self) -> Vec<Stage> {
        let mut out = Vec::new();
        if !self.has(FieldKind::Tokens) {
            out.push(Stage::Generation);
            return out; // nothing else can run before generation
        }
        if !self.has(FieldKind::OldLp) {
            out.push(Stage::OldLogprob);
        }
        if !self.has(FieldKind::RefLp) {
            out.push(Stage::RefLogprob);
        }
        if !self.has(FieldKind::Reward) {
            out.push(Stage::Reward);
        }
        if out.is_empty() {
            out.push(Stage::Update);
        }
        out
    }

    pub fn ready_for_update(&self) -> bool {
        self.has(FieldKind::Tokens)
            && self.has(FieldKind::OldLp)
            && self.has(FieldKind::RefLp)
            && self.has(FieldKind::Reward)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Sample {
        Sample::new_prompt(3, 1, "1+2=".into(), 3)
    }

    #[test]
    fn lifecycle_stages() {
        let mut s = sample();
        assert_eq!(s.next_stages(), vec![Stage::Generation]);
        s.put(FieldKind::Tokens, Tensor::i32(&[8], vec![1; 8]).unwrap());
        let next = s.next_stages();
        assert!(next.contains(&Stage::OldLogprob));
        assert!(next.contains(&Stage::RefLogprob));
        assert!(next.contains(&Stage::Reward));
        s.put(FieldKind::OldLp, Tensor::zeros(&[7]));
        s.put(FieldKind::RefLp, Tensor::zeros(&[7]));
        s.put(FieldKind::Reward, Tensor::scalar_f32(1.0));
        assert!(s.ready_for_update());
        assert_eq!(s.next_stages(), vec![Stage::Update]);
    }

    #[test]
    fn payload_accounting() {
        let mut s = sample();
        assert_eq!(s.payload_bytes(), s.scalar_bytes());
        s.put(FieldKind::Tokens, Tensor::i32(&[16], vec![0; 16]).unwrap());
        assert_eq!(s.payload_bytes(), 16 * 4 + s.scalar_bytes());
    }

    #[test]
    fn partial_rollout_payload_is_first_class() {
        let mut s = sample();
        let base = s.payload_bytes();
        let p = PartialRollout {
            response_ids: vec![1, 2, 3],
            response_logprobs: vec![-0.1, -0.2, -0.3],
            segments: vec![Segment { start: 0, len: 3, version: 2 }],
        };
        assert!(p.well_formed());
        let pb = p.payload_bytes();
        assert_eq!(pb, 3 * 4 + 3 * 4 + Segment::WIRE_BYTES);
        s.partial = Some(p);
        assert_eq!(s.payload_bytes(), base + pb);
        // clearing the partial returns the bytes
        s.partial = None;
        assert_eq!(s.payload_bytes(), base);
        // final segment stamps are counted too
        s.segments = vec![
            Segment { start: 0, len: 3, version: 2 },
            Segment { start: 3, len: 2, version: 4 },
        ];
        assert_eq!(s.payload_bytes(), base + 2 * Segment::WIRE_BYTES);
    }

    #[test]
    fn segment_push_merges_contiguous_same_version() {
        let mut segs = Vec::new();
        push_segment(&mut segs, 0, 0, 1); // empty spans are dropped
        assert!(segs.is_empty());
        push_segment(&mut segs, 0, 4, 1);
        push_segment(&mut segs, 4, 2, 1); // contiguous, same version → merge
        assert_eq!(segs, vec![Segment { start: 0, len: 6, version: 1 }]);
        push_segment(&mut segs, 6, 3, 2); // version boundary → new segment
        assert_eq!(segs.len(), 2);
        assert_eq!(segs[1], Segment { start: 6, len: 3, version: 2 });
    }

    #[test]
    fn partial_well_formed_rejects_gaps_and_misalignment() {
        let mut p = PartialRollout {
            response_ids: vec![1, 2, 3, 4],
            response_logprobs: vec![0.0; 4],
            segments: vec![
                Segment { start: 0, len: 2, version: 1 },
                Segment { start: 2, len: 2, version: 2 },
            ],
        };
        assert!(p.well_formed());
        p.segments[1].start = 3; // gap
        assert!(!p.well_formed());
        p.segments[1].start = 2;
        p.response_logprobs.pop(); // logprob stream misaligned
        assert!(!p.well_formed());
    }

    #[test]
    fn bitmask_round_trip() {
        let mut s = sample();
        s.put(FieldKind::Tokens, Tensor::zeros(&[1]));
        s.put(FieldKind::Reward, Tensor::scalar_f32(0.0));
        let m = s.present_mask();
        assert_ne!(m & FieldKind::Tokens.bit(), 0);
        assert_ne!(m & FieldKind::Reward.bit(), 0);
        assert_eq!(m & FieldKind::OldLp.bit(), 0);
    }
}
