//! The TensorDict-like sample record that flows through the system.

use std::collections::BTreeMap;

use crate::runtime::Tensor;

/// Worker states, each of which owns a TD controller (paper Fig. 4: the
/// number of controllers C is set by the RL algorithm; GRPO has 5).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Stage {
    /// actor generation: prompt → response tokens
    Generation,
    /// actor inference: old-policy log-probs of the response
    OldLogprob,
    /// reference inference: reference log-probs
    RefLogprob,
    /// rule reward scoring
    Reward,
    /// actor update: consume the finished sample
    Update,
}

impl Stage {
    pub const ALL: [Stage; 5] = [
        Stage::Generation,
        Stage::OldLogprob,
        Stage::RefLogprob,
        Stage::Reward,
        Stage::Update,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Stage::Generation => "generation",
            Stage::OldLogprob => "old_logprob",
            Stage::RefLogprob => "ref_logprob",
            Stage::Reward => "reward",
            Stage::Update => "update",
        }
    }
}

/// Tensor fields a sample accumulates as it flows through stages.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum FieldKind {
    /// prompt+response token ids `[S] i32` (padded)
    Tokens,
    /// response mask `[S-1] f32`
    RespMask,
    /// old-policy per-token log-probs `[S-1] f32`
    OldLp,
    /// reference per-token log-probs `[S-1] f32`
    RefLp,
    /// scalar rule reward
    Reward,
    /// scalar group-normalized advantage
    Advantage,
}

/// Field production order used for readiness bitmasks.
pub const FIELD_ORDER: [FieldKind; 6] = [
    FieldKind::Tokens,
    FieldKind::RespMask,
    FieldKind::OldLp,
    FieldKind::RefLp,
    FieldKind::Reward,
    FieldKind::Advantage,
];

impl FieldKind {
    pub fn bit(&self) -> u8 {
        1 << FIELD_ORDER.iter().position(|f| f == self).unwrap()
    }

    pub fn name(&self) -> &'static str {
        match self {
            FieldKind::Tokens => "tokens",
            FieldKind::RespMask => "resp_mask",
            FieldKind::OldLp => "old_lp",
            FieldKind::RefLp => "ref_lp",
            FieldKind::Reward => "reward",
            FieldKind::Advantage => "advantage",
        }
    }
}

/// One RL sample (a prompt with one generated response and its transient
/// data). The paper implements this as a Ray TensorDict; here it is a
/// plain map of named host tensors plus scalar metadata.
#[derive(Debug, Clone)]
pub struct Sample {
    pub index: u64,
    /// group id: samples of the same prompt share it (GRPO group)
    pub group: u64,
    pub prompt_len: usize,
    pub resp_len: usize,
    /// weight version active when this sample's response was generated
    /// (the behavior policy's identity; 0 = not yet generated/stamped).
    /// Stamped by the generation writeback and carried on every metadata
    /// broadcast so the old-logprob stage can score under the true
    /// behavior policy instead of the weight-bus head.
    pub behavior_version: u64,
    pub prompt_text: String,
    pub answer: i64,
    pub completion_text: String,
    pub fields: BTreeMap<FieldKind, Tensor>,
}

impl Sample {
    pub fn new_prompt(index: u64, group: u64, prompt_text: String, answer: i64) -> Self {
        Self {
            index,
            group,
            prompt_len: prompt_text.len() + 1, // + BOS
            resp_len: 0,
            behavior_version: 0,
            prompt_text,
            answer,
            completion_text: String::new(),
            fields: BTreeMap::new(),
        }
    }

    pub fn put(&mut self, kind: FieldKind, t: Tensor) {
        self.fields.insert(kind, t);
    }

    pub fn get(&self, kind: FieldKind) -> Option<&Tensor> {
        self.fields.get(&kind)
    }

    pub fn has(&self, kind: FieldKind) -> bool {
        self.fields.contains_key(&kind)
    }

    /// Bitmask of present fields (mirrors controller metadata).
    pub fn present_mask(&self) -> u8 {
        self.fields.keys().fold(0u8, |m, k| m | k.bit())
    }

    /// Payload bytes (the `CV` term of Eq. 1: tokens + n·SL items + scalars).
    pub fn payload_bytes(&self) -> usize {
        let tensor_bytes: usize = self.fields.values().map(|t| t.size_bytes()).sum();
        tensor_bytes + self.scalar_bytes()
    }

    /// Scalar metadata bytes (the `M` term of Eq. 1): index, group,
    /// prompt_len, resp_len, answer, behavior_version — 6 scalars ×
    /// 4 bytes nominal.
    pub fn scalar_bytes(&self) -> usize {
        6 * 4
    }

    /// Which stages still need to produce data for this sample.
    pub fn next_stages(&self) -> Vec<Stage> {
        let mut out = Vec::new();
        if !self.has(FieldKind::Tokens) {
            out.push(Stage::Generation);
            return out; // nothing else can run before generation
        }
        if !self.has(FieldKind::OldLp) {
            out.push(Stage::OldLogprob);
        }
        if !self.has(FieldKind::RefLp) {
            out.push(Stage::RefLogprob);
        }
        if !self.has(FieldKind::Reward) {
            out.push(Stage::Reward);
        }
        if out.is_empty() {
            out.push(Stage::Update);
        }
        out
    }

    pub fn ready_for_update(&self) -> bool {
        self.has(FieldKind::Tokens)
            && self.has(FieldKind::OldLp)
            && self.has(FieldKind::RefLp)
            && self.has(FieldKind::Reward)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Sample {
        Sample::new_prompt(3, 1, "1+2=".into(), 3)
    }

    #[test]
    fn lifecycle_stages() {
        let mut s = sample();
        assert_eq!(s.next_stages(), vec![Stage::Generation]);
        s.put(FieldKind::Tokens, Tensor::i32(&[8], vec![1; 8]).unwrap());
        let next = s.next_stages();
        assert!(next.contains(&Stage::OldLogprob));
        assert!(next.contains(&Stage::RefLogprob));
        assert!(next.contains(&Stage::Reward));
        s.put(FieldKind::OldLp, Tensor::zeros(&[7]));
        s.put(FieldKind::RefLp, Tensor::zeros(&[7]));
        s.put(FieldKind::Reward, Tensor::scalar_f32(1.0));
        assert!(s.ready_for_update());
        assert_eq!(s.next_stages(), vec![Stage::Update]);
    }

    #[test]
    fn payload_accounting() {
        let mut s = sample();
        assert_eq!(s.payload_bytes(), s.scalar_bytes());
        s.put(FieldKind::Tokens, Tensor::i32(&[16], vec![0; 16]).unwrap());
        assert_eq!(s.payload_bytes(), 16 * 4 + s.scalar_bytes());
    }

    #[test]
    fn bitmask_round_trip() {
        let mut s = sample();
        s.put(FieldKind::Tokens, Tensor::zeros(&[1]));
        s.put(FieldKind::Reward, Tensor::scalar_f32(0.0));
        let m = s.present_mask();
        assert_ne!(m & FieldKind::Tokens.bit(), 0);
        assert_ne!(m & FieldKind::Reward.bit(), 0);
        assert_eq!(m & FieldKind::OldLp.bit(), 0);
    }
}
