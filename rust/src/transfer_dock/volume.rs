//! Analytic communication-volume model: the paper's Eqs. (1), (2), (4).
//!
//! These closed forms are cross-checked against the *measured* byte
//! ledger of the implemented dataflow in the test suite — the equations
//! are the paper's model; the ledger is our ground truth.

/// Hyperparameters of Eqs. (1)–(4) / Table 1.
#[derive(Debug, Clone, Copy)]
pub struct VolumeParams {
    /// global batch size (prompts)
    pub g: u64,
    /// responses per prompt
    pub n_resp: u64,
    /// bytes per element
    pub b: u64,
    /// max prompt length (tokens)
    pub pl: u64,
    /// max response length (tokens)
    pub sl: u64,
    /// number of response-length items (old logits, ref logits, ...)
    pub n_items: u64,
    /// number of scalar metadata items
    pub m: u64,
}

const GB: f64 = 1024.0 * 1024.0 * 1024.0;

/// Eq. (1): communication volume (GB) of the update-batch request alone.
pub fn cv_update_gb(p: &VolumeParams) -> f64 {
    (p.g * p.n_resp * p.b) as f64 * (p.pl + p.n_items * p.sl + p.m) as f64 / GB
}

/// Eq. (2): total communication volume (GB) over the last three steps of
/// the centralized replay-buffer flow (Fig. 2).
pub fn tcv_gb(p: &VolumeParams) -> f64 {
    (p.g * p.n_resp * p.b) as f64 * (2 * p.pl + 3 * p.n_items * p.sl + 8 * p.m) as f64 / GB
}

/// Eq. (4): per-warehouse total communication volume (GB) under the
/// transfer dock with `c` controllers and `s` warehouses.
pub fn td_tcv_gb(p: &VolumeParams, c: u64, s: u64) -> f64 {
    (p.g * p.n_resp * p.b) as f64
        * (2 * p.pl + 3 * p.n_items * p.sl + 8 * (c + 1) * p.m) as f64
        / s as f64
        / GB
}

/// Dispatch seconds for a volume at a given server bandwidth (Table 1's
/// T100 / T1K columns).
pub fn dispatch_secs(volume_gb: f64, bandwidth_bytes_per_sec: f64) -> f64 {
    volume_gb * GB / bandwidth_bytes_per_sec
}

/// The exact rows of Table 1 (G, N, PL, n, SL, M).
pub fn table1_rows() -> Vec<VolumeParams> {
    let k = 1024u64;
    [
        (256, 8, 2 * k, 5, 8 * k, 3),
        (256, 16, 2 * k, 5, 16 * k, 3),
        (k, 16, 2 * k, 5, 16 * k, 3),
        (k, 32, 4 * k, 8, 32 * k, 5),
        (4 * k, 32, 4 * k, 8, 32 * k, 5),
        (8 * k, 64, 4 * k, 8, 64 * k, 5),
    ]
    .iter()
    .map(|&(g, n_resp, pl, n_items, sl, m)| VolumeParams {
        g,
        n_resp,
        b: 4,
        pl,
        sl,
        n_items,
        m,
    })
    .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Table 1 published values: TCV(GB), T100(s), T1K(s).
    const PAPER: [(f64, f64, f64); 6] = [
        (0.96, 9.92, 0.97),
        (3.81, 39.0, 3.81),
        (15.2, 156.1, 15.2),
        (97.0, 993.3, 97.0),
        (388.0, 3900.0, 388.0),
        (3100.0, 31000.0, 3100.0),
    ];

    #[test]
    fn tcv_matches_table1() {
        for (row, &(tcv_paper, _, _)) in table1_rows().iter().zip(&PAPER) {
            let got = tcv_gb(row);
            let rel = (got - tcv_paper).abs() / tcv_paper;
            assert!(rel < 0.03, "row {row:?}: got {got}, paper {tcv_paper}");
        }
    }

    #[test]
    fn dispatch_times_match_table1() {
        for (row, &(_, t100, t1k)) in table1_rows().iter().zip(&PAPER) {
            let v = tcv_gb(row);
            let got100 = dispatch_secs(v, 100e6);
            let got1k = dispatch_secs(v, 1e9);
            // paper rounds to ~3 significant digits; also the "100 MB/s"
            // column is consistent with MB = 1e6 bytes
            assert!((got100 - t100).abs() / t100 < 0.08, "T100 {got100} vs {t100}");
            assert!((got1k - t1k).abs() / t1k < 0.08, "T1K {got1k} vs {t1k}");
        }
    }

    #[test]
    fn td_reduces_volume_per_warehouse() {
        let p = table1_rows()[2];
        let central = tcv_gb(&p);
        let td = td_tcv_gb(&p, 5, 16);
        // paper's claim: ~S× reduction since metadata term is negligible
        assert!(td < central / 14.0, "td {td} central {central}");
        assert!(td > central / 17.0);
    }

    #[test]
    fn metadata_term_grows_with_controllers() {
        let p = table1_rows()[0];
        assert!(td_tcv_gb(&p, 10, 16) > td_tcv_gb(&p, 5, 16));
    }

    #[test]
    fn update_cv_is_part_of_tcv() {
        let p = table1_rows()[0];
        assert!(cv_update_gb(&p) < tcv_gb(&p));
    }
}
